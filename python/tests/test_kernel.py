"""Kernel vs oracle correctness — the core L1 signal.

The Pallas kernel (`sliced_mm`) must reproduce the pure-jnp oracle
(`dpe_matmul_ref`) bit-for-bit (same preprocessing, same noise sample,
same ADC): hypothesis sweeps shapes, slice configs, modes, and noise
settings.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (
    DpeCfg,
    adc_quantize,
    dpe_matmul_ref,
    quantize_blocks,
    slice_digits,
    slice_weights,
)
from compile.kernels.sliced_mm import dpe_matmul


def _rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0.0, scale, shape), dtype=jnp.float32)


def _key(seed=0):
    return jax.random.PRNGKey(seed)


# ---------------------------------------------------------------- slicing


def test_slice_weights_int8():
    w, s = slice_weights((1, 1, 2, 4))
    assert w == (-128.0, 64.0, 16.0, 1.0)
    assert s == (7, 6, 4, 0)


@given(
    widths=st.lists(st.integers(1, 4), min_size=1, max_size=4).map(
        lambda ws: tuple([1] + ws)
    )
)
@settings(max_examples=30, deadline=None)
def test_slice_digits_reconstruct(widths):
    total = sum(widths)
    lo, hi = -(2 ** (total - 1)), 2 ** (total - 1) - 1
    vals = jnp.arange(lo, hi + 1, dtype=jnp.float32)
    planes = slice_digits(vals, widths)
    w, _ = slice_weights(widths)
    recon = sum(float(wk) * planes[k] for k, wk in enumerate(w))
    np.testing.assert_array_equal(np.asarray(recon), np.asarray(vals))


def test_quantize_blocks_error_bound():
    x = _rand((4, 16, 16), seed=1)
    for mode in ("quantize", "prealign"):
        q, scale = quantize_blocks(x, 8, mode)
        recon = q * scale[:, None, None]
        err = jnp.max(jnp.abs(recon - x))
        assert float(err) <= float(jnp.max(scale)) / 2 + 1e-6


def test_quantize_blocks_zero_block():
    x = jnp.zeros((2, 4, 4))
    q, scale = quantize_blocks(x, 8, "quantize")
    assert float(jnp.max(jnp.abs(q))) == 0.0
    assert float(jnp.max(scale)) == 0.0


def test_prealign_scale_power_of_two():
    x = _rand((3, 8, 8), seed=2)
    _, scale = quantize_blocks(x, 8, "prealign")
    v = np.asarray(scale) * 128.0
    log = np.log2(v)
    np.testing.assert_allclose(log, np.round(log), atol=1e-6)


def test_adc_quantize_bounds():
    x = jnp.linspace(-5.0, 70.0, 100)
    y = adc_quantize(x, 64.0, 1024)
    assert float(jnp.min(y)) >= 0.0
    assert float(jnp.max(y)) <= 64.0
    mid = adc_quantize(jnp.asarray([13.37]), 64.0, 1024)
    assert abs(float(mid[0]) - 13.37) <= 64.0 / 1023 / 2 + 1e-6


# ------------------------------------------------- kernel vs oracle


CFG_IDEAL = DpeCfg(noise_free=True, cv=0.0)


@pytest.mark.parametrize("fmt_widths,mode", [
    ((1, 1, 2, 4), "quantize"),
    ((1, 1, 2), "quantize"),
    ((1, 1, 2, 4, 4), "prealign"),
    ((1, 1, 2, 4), "prealign"),
])
@pytest.mark.parametrize("shape", [(8, 64, 64), (16, 128, 96), (4, 100, 130)])
def test_kernel_matches_ref(fmt_widths, mode, shape):
    m, k, n = shape
    cfg = DpeCfg(
        widths_a=fmt_widths, widths_w=fmt_widths, mode_a=mode, mode_w=mode,
        cv=0.05, noise_free=False,
    )
    a, b = _rand((m, k), seed=10), _rand((k, n), seed=11)
    key = _key(3)
    ref = dpe_matmul_ref(a, b, cfg, key)
    ker = dpe_matmul(a, b, cfg, key)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref), rtol=1e-4, atol=1e-3)


@given(
    m=st.integers(1, 24),
    k=st.integers(1, 150),
    n=st.integers(1, 150),
    seed=st.integers(0, 2**31 - 1),
    noisy=st.booleans(),
)
@settings(max_examples=25, deadline=None)
def test_kernel_matches_ref_hypothesis(m, k, n, seed, noisy):
    cfg = DpeCfg(cv=0.05 if noisy else 0.0, noise_free=not noisy, kblk=32, nblk=32)
    a, b = _rand((m, k), seed=seed), _rand((k, n), seed=seed + 1)
    key = _key(seed)
    ref = dpe_matmul_ref(a, b, cfg, key)
    ker = dpe_matmul(a, b, cfg, key)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref), rtol=1e-4, atol=1e-3)


def test_kernel_jits():
    cfg = DpeCfg()
    a, b = _rand((8, 64), seed=20), _rand((64, 64), seed=21)
    f = jax.jit(lambda a, b, k: dpe_matmul(a, b, cfg, k))
    out = f(a, b, _key(0))
    ref = dpe_matmul_ref(a, b, cfg, _key(0))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-3)


# ---------------------------------------------------- DPE semantics


def test_ideal_int8_accuracy():
    a, b = _rand((32, 64), 30, 0.5), _rand((64, 32), 31, 0.5)
    out = dpe_matmul(a, b, CFG_IDEAL, _key(0))
    ideal = a @ b
    re = float(jnp.linalg.norm(out - ideal) / jnp.linalg.norm(ideal))
    assert re < 0.02, re


def test_more_bits_less_error():
    a, b = _rand((32, 64), 32), _rand((64, 32), 33)
    ideal = a @ b

    def re(widths):
        cfg = DpeCfg(widths_a=widths, widths_w=widths, noise_free=True, cv=0.0)
        out = dpe_matmul(a, b, cfg, _key(0))
        return float(jnp.linalg.norm(out - ideal) / jnp.linalg.norm(ideal))

    assert re((1, 1, 2, 4, 4)) < re((1, 1, 2, 4)) < re((1, 1, 2))


def test_noise_increases_error():
    a, b = _rand((32, 64), 34), _rand((64, 32), 35)
    ideal = a @ b

    def re(cv):
        cfg = DpeCfg(cv=cv, noise_free=False)
        out = dpe_matmul(a, b, cfg, _key(7))
        return float(jnp.linalg.norm(out - ideal) / jnp.linalg.norm(ideal))

    assert re(0.2) > re(0.01)


def test_noise_is_keyed():
    cfg = DpeCfg(cv=0.1)
    a, b = _rand((8, 64), 36), _rand((64, 16), 37)
    o1 = dpe_matmul(a, b, cfg, _key(1))
    o2 = dpe_matmul(a, b, cfg, _key(2))
    assert not np.allclose(np.asarray(o1), np.asarray(o2))
    o1b = dpe_matmul(a, b, cfg, _key(1))
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o1b))

"""L2: JAX compute graphs built on the DPE kernel.

Everything here is build-time only: `aot.py` lowers these functions once to
HLO text; the Rust coordinator executes the artifacts via PJRT. Weights are
graph *inputs*, so the Rust side can run inference with any trained weights
without re-lowering.

Contents:
- :func:`dpe_matmul_graph` — the DPE matmul as an exportable function;
- :func:`linear_fwd` / :func:`conv2d_fwd` — hardware layers (conv is
  lowered to a dot product by im2col, paper Fig 8(c));
- :func:`lenet_fwd` — the full LeNet-5 forward pass on DPE layers
  (Fig 16 / Table 3);
- :func:`mlp_fwd` — a 2-layer MLP head used by the quickstart.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .kernels.ref import DpeCfg
from .kernels.sliced_mm import dpe_matmul

# Named slice methods (paper §5).
METHODS: Dict[str, dict] = {
    "int4": dict(widths=(1, 1, 2), mode="quantize"),
    "int8": dict(widths=(1, 1, 2, 4), mode="quantize"),
    "fp16": dict(widths=(1, 1, 2, 4, 4), mode="prealign"),
    "bf16": dict(widths=(1, 1, 2, 4), mode="prealign"),
    "fp32": dict(widths=(1, 1, 2, 4, 4, 4, 4, 4), mode="prealign"),
    "flex16": dict(widths=(1, 1, 2, 4, 4, 4), mode="prealign"),
}


def cfg_for(method: str, *, noise_free: bool = False, cv: float = 0.05,
            kblk: int = 64, nblk: int = 64, radc: int = 1024) -> DpeCfg:
    spec = METHODS[method]
    return DpeCfg(
        widths_a=spec["widths"],
        widths_w=spec["widths"],
        mode_a=spec["mode"],
        mode_w=spec["mode"],
        kblk=kblk,
        nblk=nblk,
        radc=radc,
        cv=0.0 if noise_free else cv,
        noise_free=noise_free,
    )


def dpe_matmul_graph(a, b, key, cfg: DpeCfg):
    """Exported signature: (a f32[M,K], b f32[K,N], key u32[2]) → (c,)."""
    return (dpe_matmul(a, b, cfg, key),)


def linear_fwd(x, w, bias, key, cfg: DpeCfg):
    """Hardware linear layer: x (B, in) · w (in, out) + bias."""
    return dpe_matmul(x, w, cfg, key) + bias


def conv2d_fwd(x, w, bias, key, cfg: DpeCfg, *, stride: int = 1, pad: int = 0):
    """Hardware conv layer via im2col (paper Fig 8(c)).

    x (B, C, H, W); w (out_c, C·kh·kw); bias (out_c,). Returns
    (B, out_c, OH, OW).
    """
    bsz, c, h, wdt = x.shape
    out_c, patch = w.shape
    kh = kw = int(round((patch // c) ** 0.5))
    assert c * kh * kw == patch, "kernel must be square"
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
    )  # (B, C*kh*kw, OH, OW)
    oh, ow = patches.shape[2], patches.shape[3]
    cols = patches.transpose(0, 2, 3, 1).reshape(bsz * oh * ow, patch)
    y = dpe_matmul(cols, w.T, cfg, key) + bias  # (B·OH·OW, out_c)
    return y.reshape(bsz, oh, ow, out_c).transpose(0, 3, 1, 2)


def avg_pool2(x):
    """2×2 average pooling (LeNet's subsampling)."""
    b, c, h, w = x.shape
    return x.reshape(b, c, h // 2, 2, w // 2, 2).mean(axis=(3, 5))


def lenet_fwd(x, params, key, cfg: DpeCfg):
    """LeNet-5 forward on DPE layers.

    x (B, 1, 28, 28). params (in order):
      conv1_w (6, 25), conv1_b (6,), conv2_w (16, 150), conv2_b (16,),
      fc1_w (256, 120), fc1_b (120,), fc2_w (120, 84), fc2_b (84,),
      fc3_w (84, 10), fc3_b (10,).
    Returns logits (B, 10).
    """
    (c1w, c1b, c2w, c2b, f1w, f1b, f2w, f2b, f3w, f3b) = params
    keys = jax.random.split(key, 5)
    h = conv2d_fwd(x, c1w, c1b, keys[0], cfg)          # (B, 6, 24, 24)
    h = avg_pool2(jnp.maximum(h, 0.0))                  # (B, 6, 12, 12)
    h = conv2d_fwd(h, c2w, c2b, keys[1], cfg)           # (B, 16, 8, 8)
    h = avg_pool2(jnp.maximum(h, 0.0))                  # (B, 16, 4, 4)
    h = h.reshape(h.shape[0], -1)                       # (B, 256)
    h = jnp.maximum(linear_fwd(h, f1w, f1b, keys[2], cfg), 0.0)
    h = jnp.maximum(linear_fwd(h, f2w, f2b, keys[3], cfg), 0.0)
    return linear_fwd(h, f3w, f3b, keys[4], cfg)


def lenet_param_shapes():
    """Parameter shapes in `lenet_fwd` order."""
    return [
        (6, 25), (6,), (16, 150), (16,),
        (256, 120), (120,), (120, 84), (84,),
        (84, 10), (10,),
    ]


def mlp_fwd(x, w1, b1, w2, b2, key, cfg: DpeCfg):
    """2-layer MLP: x (B, d) → logits."""
    k1, k2 = jax.random.split(key)
    h = jnp.maximum(linear_fwd(x, w1, b1, k1, cfg), 0.0)
    return linear_fwd(h, w2, b2, k2, cfg)

"""AOT compilation: lower the L2 graphs to HLO **text** artifacts.

Run once at build time (`make artifacts`); the Rust runtime loads the text
with `HloModuleProto::from_text_file` and compiles it on the PJRT CPU
client. Text — not `.serialize()` — because jax ≥ 0.5 emits protos with
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md and gen_hlo.py).

Usage:
    python -m compile.aot --out-dir ../artifacts [--only NAME_PREFIX]

Every artifact function is lowered with `return_tuple=True`; the Rust side
unwraps with `decompose_tuple()`.

Artifact inventory (shape-specialized; the Rust engine falls back to the
native path for any other shape):
  _smoke                         tiny sanity matmul (runtime unit test)
  dpe_mm_<M>x<K>x<N>_<fmt>       DPE matmul, noisy
  dpe_mm_<M>x<K>x<N>_<fmt>_ideal DPE matmul, noise-free (backend cross-val)
  lenet_fwd_b<B>_<fmt>           full LeNet-5 forward on DPE layers
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.ref import DpeCfg


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-compatible route)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _key_spec():
    return jax.ShapeDtypeStruct((2,), jnp.uint32)


def _wrap_key(raw):
    return jax.random.wrap_key_data(raw, impl="threefry2x32")


def smoke():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    return jax.jit(fn, keep_unused=True).lower(spec, spec)


def dpe_mm(m: int, k: int, n: int, fmt: str, ideal: bool):
    cfg = model.cfg_for(fmt, noise_free=ideal)

    def fn(a, b, raw_key):
        return model.dpe_matmul_graph(a, b, _wrap_key(raw_key), cfg)

    return jax.jit(fn, keep_unused=True).lower(
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32),
        _key_spec(),
    )


def lenet(batch: int, fmt: str, ideal: bool):
    cfg = model.cfg_for(fmt, noise_free=ideal)
    param_specs = [
        jax.ShapeDtypeStruct(s, jnp.float32) for s in model.lenet_param_shapes()
    ]

    def fn(x, raw_key, *params):
        return (model.lenet_fwd(x, params, _wrap_key(raw_key), cfg),)

    return jax.jit(fn, keep_unused=True).lower(
        jax.ShapeDtypeStruct((batch, 1, 28, 28), jnp.float32),
        _key_spec(),
        *param_specs,
    )


#: name → thunk producing a lowered computation.
ARTIFACTS = {
    "_smoke": smoke,
    "dpe_mm_128x128x128_int8": lambda: dpe_mm(128, 128, 128, "int8", False),
    "dpe_mm_128x128x128_int8_ideal": lambda: dpe_mm(128, 128, 128, "int8", True),
    "dpe_mm_128x128x128_fp16": lambda: dpe_mm(128, 128, 128, "fp16", False),
    "dpe_mm_256x256x256_int8": lambda: dpe_mm(256, 256, 256, "int8", False),
    "lenet_fwd_b32_int8": lambda: lenet(32, "int8", False),
    "lenet_fwd_b32_int8_ideal": lambda: lenet(32, "int8", True),
    "lenet_fwd_b128_fp16": lambda: lenet(128, "fp16", False),
}


def sources_fingerprint() -> str:
    """Hash of the compile-path sources; artifacts rebuild when it changes."""
    root = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for dirpath, _, files in sorted(os.walk(root)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(dirpath, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="only artifacts starting with this prefix")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    stamp_path = os.path.join(args.out_dir, "MANIFEST.json")
    fingerprint = sources_fingerprint()
    manifest = {}
    if os.path.exists(stamp_path) and not args.force:
        with open(stamp_path) as fh:
            manifest = json.load(fh)

    built = 0
    for name, thunk in ARTIFACTS.items():
        if args.only and not name.startswith(args.only):
            continue
        out_path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        if (
            not args.force
            and os.path.exists(out_path)
            and manifest.get(name) == fingerprint
        ):
            print(f"[aot] {name}: up to date")
            continue
        t0 = time.time()
        text = to_hlo_text(thunk())
        with open(out_path, "w") as fh:
            fh.write(text)
        manifest[name] = fingerprint
        built += 1
        print(f"[aot] {name}: {len(text)} chars in {time.time() - t0:.1f}s")

    with open(stamp_path, "w") as fh:
        json.dump(manifest, fh, indent=1)
    print(f"[aot] done ({built} rebuilt, {len(ARTIFACTS) - built} cached)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Pure-jnp oracle for the bit-sliced DPE matmul.

This is the correctness reference for the Pallas kernel
(:mod:`compile.kernels.sliced_mm`): identical preprocessing and math, but the
inner slice-pair loop is plain ``jnp`` einsum instead of a Pallas grid. It
also mirrors the Rust native engine (``rust/src/dpe/engine.rs``) so the two
backends can be cross-validated through the noise-free path.

All functions are trace-friendly (shapes static, no Python branches on traced
values) so both the oracle and the kernel lower to HLO.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DpeCfg:
    """Static DPE configuration (mirrors rust `DpeConfig` + slice methods)."""

    widths_a: Tuple[int, ...] = (1, 1, 2, 4)
    widths_w: Tuple[int, ...] = (1, 1, 2, 4)
    #: "quantize" (full-precision per-block scale) or "prealign" (2^e scale).
    mode_a: str = "quantize"
    mode_w: str = "quantize"
    #: array (block) size: contraction rows x output cols.
    kblk: int = 64
    nblk: int = 64
    radc: int = 1024
    #: conductance coefficient of variation (0 disables device noise).
    cv: float = 0.05
    #: LGS / conductance step — offset term of the conductance mapping
    #: (Table 2 values: 1e-7 / ((1e-5 - 1e-7)/15) ≈ 0.1515...).
    lgs_over_step: float = 1e-7 / ((1e-5 - 1e-7) / 15.0)
    #: disable noise *and* ADC quantization (ideal sliced arithmetic).
    noise_free: bool = False

    @property
    def total_bits_a(self) -> int:
        return sum(self.widths_a)

    @property
    def total_bits_w(self) -> int:
        return sum(self.widths_w)


def slice_weights(widths: Sequence[int]) -> Tuple[Tuple[float, ...], Tuple[int, ...]]:
    """Signed shift-and-add weights and LSB shifts, MSB-first (sign slice
    first, weight −2^shift; see rust `SliceSpec::weight`)."""
    total = sum(widths)
    shifts, used = [], 0
    for w in widths:
        used += w
        shifts.append(total - used)
    weights = [float(2**s) for s in shifts]
    weights[0] = -weights[0]
    return tuple(weights), tuple(shifts)


def quantize_blocks(x: jnp.ndarray, bits: int, mode: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-block quantization along the leading (block) axis.

    ``x``: (B, ...) where B indexes blocks. Returns (q, scale) with
    ``q`` integer-valued (float32) in [-2^(bits-1), 2^(bits-1)-1] and
    ``scale`` of shape (B,) such that ``x ≈ q * scale``.
    """
    max_int = float(2 ** (bits - 1) - 1)
    flat = x.reshape(x.shape[0], -1)
    max_abs = jnp.max(jnp.abs(flat), axis=1)
    if mode == "quantize":
        scale = max_abs / max_int
    elif mode == "prealign":
        e = jnp.ceil(jnp.log2(jnp.maximum(max_abs, 1e-300)))
        scale = jnp.exp2(e) / (max_int + 1.0)
    else:
        raise ValueError(f"unknown mode {mode}")
    safe = jnp.where(scale > 0, scale, 1.0)
    bshape = (x.shape[0],) + (1,) * (x.ndim - 1)
    q = jnp.round(x / safe.reshape(bshape))
    q = jnp.clip(q, -(max_int + 1.0), max_int)
    q = jnp.where(scale.reshape(bshape) > 0, q, 0.0)
    return q.astype(jnp.float32), scale.astype(jnp.float32)


def slice_digits(q: jnp.ndarray, widths: Sequence[int]) -> jnp.ndarray:
    """Two's-complement digit planes, MSB-first. Returns (S, *q.shape)."""
    total = sum(widths)
    u = jnp.where(q < 0, q + float(2**total), q).astype(jnp.uint32)
    planes = []
    shift = total
    for w in widths:
        shift -= w
        planes.append(((u >> shift) & (2**w - 1)).astype(jnp.float32))
    return jnp.stack(planes)


def device_noise(planes: jnp.ndarray, cfg: DpeCfg, key: jax.Array) -> jnp.ndarray:
    """Conductance-domain lognormal programming noise on digit planes.

    Matches rust ``DotProductEngine::program_plane``: digit → conductance
    ``G = lgs + digit·step`` → lognormal(G, cv) → back to digit units
    ``(G′ − lgs)/step = digit·η + (lgs/step)·(η − 1)`` with η mean-1
    lognormal.
    """
    if cfg.noise_free or cfg.cv <= 0.0:
        return planes
    import math

    sigma = math.sqrt(math.log(cfg.cv**2 + 1.0))
    mu = -(sigma**2) / 2.0
    z = jax.random.normal(key, planes.shape, dtype=jnp.float32)
    eta = jnp.exp(mu + sigma * z)
    return planes * eta + cfg.lgs_over_step * (eta - 1.0)


def adc_quantize(partial: jnp.ndarray, full_scale: float, radc: int) -> jnp.ndarray:
    """Uniform mid-tread ADC over [0, full_scale] with ``radc`` codes."""
    step = full_scale / (radc - 1.0)
    return jnp.clip(jnp.round(partial / step), 0.0, radc - 1.0) * step


def _pad_to(x: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    return jnp.pad(x, ((0, rows - x.shape[0]), (0, cols - x.shape[1])))


def preprocess(a: jnp.ndarray, b: jnp.ndarray, cfg: DpeCfg, key: jax.Array):
    """Shared front half of the DPE: block, quantize, slice, add noise.

    Returns
    -------
    a_digits : (Sa, KB, M, kblk)   input digit planes per k-block
    a_scale  : (KB,)
    w_digits : (Sw, KB, NB, kblk, nblk)  noisy weight digit planes per block
    w_scale  : (KB, NB)
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"matmul dim mismatch {a.shape} @ {b.shape}"
    kb = -(-k // cfg.kblk)
    nb = -(-n // cfg.nblk)
    a_p = _pad_to(a, m, kb * cfg.kblk)
    b_p = _pad_to(b, kb * cfg.kblk, nb * cfg.nblk)

    # Input: blocks along k only → (KB, M, kblk).
    a_blocks = a_p.reshape(m, kb, cfg.kblk).transpose(1, 0, 2)
    a_q, a_scale = quantize_blocks(a_blocks, cfg.total_bits_a, cfg.mode_a)
    a_digits = slice_digits(a_q, cfg.widths_a)  # (Sa, KB, M, kblk)

    # Weights: blocks over (k, n) → (KB*NB, kblk, nblk).
    w_blocks = (
        b_p.reshape(kb, cfg.kblk, nb, cfg.nblk)
        .transpose(0, 2, 1, 3)
        .reshape(kb * nb, cfg.kblk, cfg.nblk)
    )
    w_q, w_scale = quantize_blocks(w_blocks, cfg.total_bits_w, cfg.mode_w)
    w_digits = slice_digits(w_q, cfg.widths_w)  # (Sw, KB*NB, kblk, nblk)
    w_digits = device_noise(w_digits, cfg, key)
    w_digits = w_digits.reshape(len(cfg.widths_w), kb, nb, cfg.kblk, cfg.nblk)
    w_scale = w_scale.reshape(kb, nb)
    return a_digits, a_scale, w_digits, w_scale


def combine(partials_fn, a_digits, a_scale, w_digits, w_scale, cfg: DpeCfg, m: int, n: int):
    """Shared back half: iterate slice pairs / blocks, ADC, shift-add.

    ``partials_fn(a_plane, w_plane) -> (M, nblk)`` computes one analog MVM;
    the oracle passes a jnp matmul.
    """
    wa, _ = slice_weights(cfg.widths_a)
    ww, _ = slice_weights(cfg.widths_w)
    ma = [float(2**w - 1) for w in cfg.widths_a]
    mw = [float(2**w - 1) for w in cfg.widths_w]
    sa, kb = a_digits.shape[0], a_digits.shape[1]
    sw, nb = w_digits.shape[0], w_digits.shape[2]
    cols = []
    for j in range(nb):
        acc_j = jnp.zeros((m, cfg.nblk), dtype=jnp.float32)
        for i in range(kb):
            blk = jnp.zeros((m, cfg.nblk), dtype=jnp.float32)
            for p in range(sa):
                for q in range(sw):
                    part = partials_fn(a_digits[p, i], w_digits[q, i, j])
                    if not cfg.noise_free:
                        fs = cfg.kblk * ma[p] * mw[q]
                        part = adc_quantize(part, fs, cfg.radc)
                    blk = blk + (wa[p] * ww[q]) * part
            acc_j = acc_j + blk * (a_scale[i] * w_scale[i, j])
        cols.append(acc_j)
    out = jnp.concatenate(cols, axis=1)
    return out[:, :n]


def dpe_matmul_ref(a: jnp.ndarray, b: jnp.ndarray, cfg: DpeCfg, key: jax.Array) -> jnp.ndarray:
    """The oracle: full DPE matmul with jnp inner products."""
    m, n = a.shape[0], b.shape[1]
    a_digits, a_scale, w_digits, w_scale = preprocess(a, b, cfg, key)
    return combine(lambda ap, wp: ap @ wp, a_digits, a_scale, w_digits, w_scale, cfg, m, n)

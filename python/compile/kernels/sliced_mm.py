"""L1 Pallas kernel: bit-sliced crossbar block matmul.

The grid mirrors the paper's hardware decomposition (Figs 6–7): one grid
step = one activated crossbar array = one (weight-slice, input-slice,
k-block, n-block) combination. Each step loads an array-sized digit tile
into VMEM, performs the analog MVM (an MXU matmul on real TPUs), applies
the ADC quantizer, and accumulates into the output tile with the signed
shift-and-add significance weights and block scales.

Grid order: ``(nb, sa, sw, kb)`` — the output tile for column-block ``nb``
stays resident while all slice pairs and k-blocks accumulate into it.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the same schedule to plain HLO so the
Rust runtime can run it (see DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import DpeCfg, preprocess, slice_weights


def _kernel(
    a_ref,      # (1, 1, M, kblk)   input digit tile
    w_ref,      # (1, 1, 1, kblk, nblk) weight digit tile
    a_scale_ref,  # (1,)
    w_scale_ref,  # (1, 1)
    wa_ref,     # (1,)  signed significance of the input slice
    ww_ref,     # (1,)  signed significance of the weight slice
    ma_ref,     # (1,)  max digit of the input slice (ADC full scale)
    mw_ref,     # (1,)
    o_ref,      # (M, nblk) output tile
    *,
    kblk: int,
    radc: int,
    noise_free: bool,
):
    sa = pl.program_id(1)
    sw = pl.program_id(2)
    kb = pl.program_id(3)

    @pl.when((sa == 0) & (sw == 0) & (kb == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a_tile = a_ref[0, 0]          # (M, kblk)
    w_tile = w_ref[0, 0, 0]       # (kblk, nblk)
    partial = jnp.dot(a_tile, w_tile, preferred_element_type=jnp.float32)
    if not noise_free:
        fs = kblk * ma_ref[0] * mw_ref[0]
        step = fs / (radc - 1.0)
        partial = jnp.clip(jnp.round(partial / step), 0.0, radc - 1.0) * step
    scale = wa_ref[0] * ww_ref[0] * a_scale_ref[0] * w_scale_ref[0, 0]
    o_ref[...] += scale * partial


def sliced_mm(a_digits, a_scale, w_digits, w_scale, cfg: DpeCfg) -> jnp.ndarray:
    """Run the Pallas bit-sliced matmul on preprocessed digit planes.

    Shapes (see :func:`compile.kernels.ref.preprocess`):
      a_digits (Sa, KB, M, kblk), a_scale (KB,),
      w_digits (Sw, KB, NB, kblk, nblk), w_scale (KB, NB).
    Returns the padded product (M, NB·nblk).
    """
    sa, kb, m, kblk = a_digits.shape
    sw, _, nb, _, nblk = w_digits.shape
    assert kblk == cfg.kblk and nblk == cfg.nblk

    wa, _ = slice_weights(cfg.widths_a)
    ww, _ = slice_weights(cfg.widths_w)
    ma = jnp.array([float(2**w - 1) for w in cfg.widths_a], dtype=jnp.float32)
    mw = jnp.array([float(2**w - 1) for w in cfg.widths_w], dtype=jnp.float32)
    wa = jnp.array(wa, dtype=jnp.float32)
    ww = jnp.array(ww, dtype=jnp.float32)

    grid = (nb, sa, sw, kb)
    kernel = functools.partial(
        _kernel, kblk=cfg.kblk, radc=cfg.radc, noise_free=cfg.noise_free
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, m, kblk), lambda j, p, q, i: (p, i, 0, 0)),
            pl.BlockSpec((1, 1, 1, kblk, nblk), lambda j, p, q, i: (q, i, j, 0, 0)),
            pl.BlockSpec((1,), lambda j, p, q, i: (i,)),
            pl.BlockSpec((1, 1), lambda j, p, q, i: (i, j)),
            pl.BlockSpec((1,), lambda j, p, q, i: (p,)),
            pl.BlockSpec((1,), lambda j, p, q, i: (q,)),
            pl.BlockSpec((1,), lambda j, p, q, i: (p,)),
            pl.BlockSpec((1,), lambda j, p, q, i: (q,)),
        ],
        out_specs=pl.BlockSpec((m, nblk), lambda j, p, q, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, nb * nblk), jnp.float32),
        interpret=True,
    )(a_digits, w_digits, a_scale, w_scale, wa, ww, ma, mw)


def dpe_matmul(a: jnp.ndarray, b: jnp.ndarray, cfg: DpeCfg, key: jax.Array) -> jnp.ndarray:
    """Full DPE matmul through the Pallas kernel (L2 entry point)."""
    m, n = a.shape[0], b.shape[1]
    a_digits, a_scale, w_digits, w_scale = preprocess(a, b, cfg, key)
    out = sliced_mm(a_digits, a_scale, w_digits, w_scale, cfg)
    return out[:, :n]

//! Bench: regenerates the paper's fig03_device artifact at full scale.
//! Run: `cargo bench --bench fig03_device`  (all benches: `cargo bench`)

use memintelli::coordinator::{run_experiment, Scale, SimConfig};

fn main() {
    let cfg = SimConfig::default();
    let t0 = std::time::Instant::now();
    run_experiment("fig03_device", &cfg, Scale::Full).expect("experiment failed");
    println!("\n[fig03_device] total {:.1} s", t0.elapsed().as_secs_f64());
}

//! Bench: multi-chip sharded execution **and** the paper-style
//! fig_sharding artifact (robustness PR tentpole).
//!
//! Shards a trained MLP across chip fleets of growing size and drives
//! the pipeline executor through clean, chip-loss, and lossy-link
//! scenarios ([`sharding_sweep`]), then serves a mixed
//! single-chip/sharded replica pool through the serving runtime.
//!
//! Before any number is reported, four invariants are hard-asserted:
//! 1. **bit-identity** — on noise-free engines, every clean sharded run
//!    (including the block-split fleet) matches single-chip
//!    `infer_batched` bit for bit;
//! 2. **conservation** — every scenario (chip loss, dropped and
//!    corrupted transfers included) ends each micro-batch `Done` or
//!    `Failed`, never silently dropped;
//! 3. **failover wins** — losing a chip with failover on (stage
//!    re-replicated onto the spare) yields strictly better accuracy
//!    than the same loss served degraded with failover off;
//! 4. **pipeline wins** — at fleet size >= 2 the pipeline's throughput
//!    is at least the single-chip baseline under the same clock.
//!
//! Emits the machine-readable `BENCH_sharding.json` (per-scenario
//! throughput/accuracy/fault accounting plus a mixed-pool serving
//! report serialized by the shared [`ServeReport::to_json`] helper).
//!
//! Run: `cargo bench --bench fig_sharding`
//! CI smoke: `MEMINTELLI_BENCH_SMOKE=1 cargo bench --bench fig_sharding`
//! (quick-scale workload and artifact regeneration).

use memintelli::arch::{
    uniform_fleet, ChipSpec, ReplicaModel, ReplicaSpec, Request, ServingRuntime, ServingSpec,
};
use memintelli::coordinator::experiments::{sharding_sweep, ShardingPoint};
use memintelli::coordinator::{run_experiment, Scale, SimConfig};
use memintelli::dpe::{DotProductEngine, RepairSpec, SliceMethod, SliceSpec};
use memintelli::nn::models::mlp;
use memintelli::nn::HwSpec;
use std::fmt::Write as _;
use std::time::Instant;

const SEED: u64 = 2024;

fn by_label<'a>(pts: &'a [ShardingPoint], label: &str) -> &'a ShardingPoint {
    pts.iter()
        .find(|p| p.label == label)
        .unwrap_or_else(|| panic!("sharding_sweep returned no '{label}' scenario"))
}

/// A small mixed pool (replica 0 single-chip, replica 1 sharded across
/// two chips) served clean; the report is serialized with the same
/// `ServeReport::to_json` helper the serving bench uses.
fn mixed_pool_report_json(seed: u64) -> String {
    let ideal = move || {
        HwSpec::uniform(DotProductEngine::ideal((64, 64)), SliceMethod::int(SliceSpec::int8()))
    };
    let factory = Box::new(move |i: usize, _c: &ReplicaSpec| -> anyhow::Result<ReplicaModel> {
        let m = mlp(96, 32, 8, Some(ideal()), seed);
        if i % 2 == 0 {
            let chip = ChipSpec::single_tile(m.mapped_planes(), (64, 64));
            Ok(ReplicaModel::Single(m.compile(&chip)?))
        } else {
            Ok(ReplicaModel::Sharded(m.compile_sharded(&uniform_fleet(2, 8, (64, 64)))?))
        }
    });
    let spec = ServingSpec {
        replicas: 2,
        max_batch: 4,
        shards_per_replica: 2,
        ..ServingSpec::default()
    };
    let mut rt = ServingRuntime::new_mixed(spec, RepairSpec::none(), vec![96], factory)
        .expect("mixed pool construction failed");
    let work: Vec<Request> = (0..24)
        .map(|j| Request {
            arrive_us: j as u64 * 120,
            sample: (0..96).map(|k| (((j * 7 + k) % 23) as f64) / 11.5 - 1.0).collect(),
        })
        .collect();
    let report = rt.run(&work, &[]).expect("mixed pool run failed");
    assert_eq!(report.completed(), 24, "mixed pool must complete every request");
    report.to_json()
}

fn main() {
    let smoke = std::env::var("MEMINTELLI_BENCH_SMOKE").is_ok();
    let t0 = Instant::now();

    let cfg = SimConfig { seed: SEED, ..SimConfig::default() };
    let scale = if smoke { Scale::Quick } else { Scale::Full };
    let pts = sharding_sweep(&cfg, scale).expect("sharding_sweep failed");

    // Invariant 2: conservation — every micro-batch in every scenario
    // (chip loss and lossy links included) ended Done or Failed.
    for p in &pts {
        assert!(p.conserved, "scenario '{}' lost samples", p.label);
    }

    // Invariant 1: clean sharded inference is bit-identical to the
    // single-chip model, at every fleet size.
    for p in pts.iter().filter(|p| p.label.starts_with("clean")) {
        assert_eq!(
            p.bit_exact,
            Some(true),
            "scenario '{}' diverged from single-chip infer_batched",
            p.label
        );
        assert_eq!(p.failed_batches, 0, "clean scenario '{}' failed batches", p.label);
        assert_eq!(p.completed_samples, p.samples, "clean scenario '{}' dropped", p.label);
    }

    // Invariant 4: the pipeline beats the single chip under the same
    // clock once it has >= 2 chips.
    let one = by_label(&pts, "clean, 1 chip(s)");
    let two = by_label(&pts, "clean, 2 chip(s)");
    assert!(
        two.images_per_sec >= one.images_per_sec,
        "2-chip pipeline throughput {:.0} img/s below single-chip {:.0} img/s",
        two.images_per_sec,
        one.images_per_sec
    );
    assert!(
        two.makespan_us <= one.makespan_us,
        "2-chip pipeline makespan {} µs above single-chip {} µs",
        two.makespan_us,
        one.makespan_us
    );

    // Invariant 3: failover-on accuracy strictly beats failover-off
    // under the same chip loss.
    let on = by_label(&pts, "chip loss, failover on");
    let off = by_label(&pts, "chip loss, failover off");
    assert!(on.failovers > 0, "failover-on scenario never failed over");
    assert!(off.degraded_batches > 0, "failover-off scenario never degraded");
    assert!(
        on.accuracy > off.accuracy,
        "failover-on accuracy {:.3} not above failover-off {:.3}",
        on.accuracy,
        off.accuracy
    );

    let lossy = by_label(&pts, "lossy links");
    for p in &pts {
        println!(
            "[fig_sharding] {:<25} chips {} stages {} {}/{} ok, {} failed, {} degraded, \
             {} failovers, {} link retries, makespan {} µs, {:.0} img/s, accuracy {:.3}",
            p.label,
            p.fleet_chips,
            p.stages,
            p.completed_samples,
            p.samples,
            p.failed_batches,
            p.degraded_batches,
            p.failovers,
            p.link_retries,
            p.makespan_us,
            p.images_per_sec,
            p.accuracy
        );
    }
    println!(
        "[fig_sharding] failover wins: accuracy {:.3} (off) -> {:.3} (on); \
         pipeline wins: {} µs (1 chip) -> {} µs (2 chips); \
         lossy links: {} retries, {} corruptions detected, conserved",
        off.accuracy,
        on.accuracy,
        one.makespan_us,
        two.makespan_us,
        lossy.link_retries,
        lossy.corrupt_detected
    );

    // Mixed single-chip/sharded pool through the serving runtime, via
    // the shared ServeReport::to_json emitter.
    let pool_json = mixed_pool_report_json(SEED);
    println!("[fig_sharding] mixed pool: {pool_json}");

    // Machine-readable record.
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"bench\": \"fig_sharding\",\n");
    json.push_str(
        "  \"pipeline\": \"shard plan -> per-chip stages -> linked pipeline -> \
         failover/degrade\",\n",
    );
    let _ = writeln!(json, "  \"smoke\": {smoke},");
    json.push_str("  \"workload\": \"mlp_784x16x10_int8_noise_free\",\n");
    json.push_str("  \"samples_conserved\": true,\n");
    json.push_str("  \"sharded_bit_exact\": true,\n");
    let _ = writeln!(
        json,
        "  \"pipeline_beats_single_chip\": {{\"makespan_1chip_us\": {}, \
         \"makespan_2chip_us\": {}}},",
        one.makespan_us, two.makespan_us
    );
    let _ = writeln!(
        json,
        "  \"failover_beats_degraded\": {{\"accuracy_off\": {:.4}, \"accuracy_on\": {:.4}}},",
        off.accuracy, on.accuracy
    );
    json.push_str("  \"points\": [\n");
    for (i, p) in pts.iter().enumerate() {
        let _ = write!(
            json,
            "    {{\"scenario\": \"{}\", \"fleet_chips\": {}, \"stages\": {}, \
             \"samples\": {}, \"completed_samples\": {}, \"failed_batches\": {}, \
             \"degraded_batches\": {}, \"failovers\": {}, \"link_retries\": {}, \
             \"corrupt_detected\": {}, \"makespan_us\": {}, \"images_per_sec\": {:.2}, \
             \"accuracy\": {:.4}, \"conserved\": {}}}",
            p.label,
            p.fleet_chips,
            p.stages,
            p.samples,
            p.completed_samples,
            p.failed_batches,
            p.degraded_batches,
            p.failovers,
            p.link_retries,
            p.corrupt_detected,
            p.makespan_us,
            p.images_per_sec,
            p.accuracy,
            p.conserved
        );
        json.push_str(if i + 1 < pts.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ],\n");
    let _ = writeln!(json, "  \"mixed_pool\": {pool_json},");
    let _ = writeln!(json, "  \"total_s\": {:.3}", t0.elapsed().as_secs_f64());
    json.push_str("}\n");
    std::fs::write("BENCH_sharding.json", &json).expect("writing BENCH_sharding.json");
    println!("\nwrote BENCH_sharding.json");

    // Paper-style artifact: the fig_sharding scenario table.
    run_experiment("fig_sharding", &cfg, scale).expect("experiment failed");
    println!("\n[fig_sharding] total {:.1} s", t0.elapsed().as_secs_f64());
}

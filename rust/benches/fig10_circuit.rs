//! Bench: regenerates the paper's fig10_circuit artifact at full scale.
//! Run: `cargo bench --bench fig10_circuit`  (all benches: `cargo bench`)

use memintelli::coordinator::{run_experiment, Scale, SimConfig};

fn main() {
    let cfg = SimConfig::default();
    let t0 = std::time::Instant::now();
    run_experiment("fig10_circuit", &cfg, Scale::Full).expect("experiment failed");
    println!("\n[fig10_circuit] total {:.1} s", t0.elapsed().as_secs_f64());
}

//! Bench: regenerates the paper's fig16_training artifact at full scale.
//! Run: `cargo bench --bench fig16_training`  (all benches: `cargo bench`)

use memintelli::coordinator::{run_experiment, Scale, SimConfig};

fn main() {
    let cfg = SimConfig::default();
    let t0 = std::time::Instant::now();
    run_experiment("fig16_training", &cfg, Scale::Full).expect("experiment failed");
    println!("\n[fig16_training] total {:.1} s", t0.elapsed().as_secs_f64());
}

//! Bench: fast hardware-aware training (perf PR tentpole) **and** the
//! paper-style fig16_training artifact.
//!
//! Headline point: LeNet-5 under INT8 slicing on the default (noisy)
//! engine, trained with the legacy loop (full array reprogram every step,
//! naive backward) and the fast loop (template-delta reprogramming,
//! packed-kernel backward, reused batch buffers) at the **same seeds**.
//!
//! Before any number is reported, four invariants are hard-asserted:
//! 1. **accuracy parity** — same seeds, same data: the fast loop's test
//!    accuracy must match the legacy loop's within a small tolerance
//!    (noisy engines keep the programmed noise of unchanged cells, so the
//!    curves are statistically — not bit — equal), and both must learn;
//! 2. **bit-exact parity (noise-free)** — on an ideal engine the delta
//!    path writes exactly the digits a full reprogram writes, so the two
//!    loops' training curves must agree bit for bit;
//! 3. **delta counters** — a delta step with unchanged weights must
//!    classify every block clean and redraw zero cells, and a change
//!    confined to one layer must redraw blocks in that layer only
//!    (per-core program-call counters);
//! 4. **speedup** — fast steps/sec must beat legacy (>1.0x in smoke,
//!    >=2.0x at full scale on the headline point).
//!
//! Emits `BENCH_fig16.json`: steps/sec before/after, the per-step phase
//! breakdown (batch/forward/backward/optim/reprogram), delta-programming
//! counters, and the parity accuracies.
//!
//! Run: `cargo bench --bench fig16_training`
//! CI smoke: `MEMINTELLI_BENCH_SMOKE=1 cargo bench --bench fig16_training`

use memintelli::coordinator::{run_experiment, Scale, SimConfig};
use memintelli::data::mnist_like;
use memintelli::dpe::{DotProductEngine, DpeConfig, SliceMethod, SliceSpec};
use memintelli::nn::models::lenet5;
use memintelli::nn::train::{evaluate, train, train_fast, TrainConfig};
use memintelli::nn::HwSpec;
use std::fmt::Write as _;
use std::time::Instant;

const SEED: u64 = 2016;

fn main() {
    let smoke = std::env::var("MEMINTELLI_BENCH_SMOKE").is_ok();
    let t0 = Instant::now();

    let (n_train, steps, eval_n) = if smoke { (192, 12, 64) } else { (1024, 80, 256) };
    let data = mnist_like::load(n_train + 128, SEED);
    let (train_set, test_set) = data.split(n_train);
    let tcfg = TrainConfig {
        steps,
        batch_size: 16,
        lr: 0.05,
        log_every: 1,
        seed: SEED,
        ..Default::default()
    };
    let hw = || {
        HwSpec::uniform(
            DotProductEngine::new(DpeConfig::default(), SEED),
            SliceMethod::int(SliceSpec::int8()),
        )
    };

    // ------------------------------------------------ headline point
    let mut legacy = lenet5(Some(hw()), SEED);
    let t = Instant::now();
    let legacy_logs = train(&mut legacy, &train_set, &tcfg);
    let legacy_secs = t.elapsed().as_secs_f64();
    let legacy_acc = evaluate(&mut legacy, &test_set, 32, eval_n);

    let mut fast = lenet5(Some(hw()), SEED);
    let t = Instant::now();
    let rep = train_fast(&mut fast, &train_set, &tcfg);
    let fast_secs = t.elapsed().as_secs_f64();
    let fast_acc = evaluate(&mut fast, &test_set, 32, eval_n);

    let legacy_sps = steps as f64 / legacy_secs;
    let fast_sps = steps as f64 / fast_secs;
    let speedup = legacy_secs / fast_secs;
    println!(
        "[fig16] LeNet-5 INT8: legacy {legacy_sps:.2} steps/s, fast {fast_sps:.2} steps/s \
         ({speedup:.2}x), acc legacy {legacy_acc:.3} vs fast {fast_acc:.3}"
    );
    println!(
        "[fig16] fast phase breakdown: batch {:.3}s forward {:.3}s backward {:.3}s \
         optim {:.3}s reprogram {:.3}s",
        rep.batch_s, rep.forward_s, rep.backward_s, rep.optim_s, rep.reprogram_s
    );
    println!(
        "[fig16] delta: {} blocks seen, {} clean, {} scale-only, {} redrawn, \
         {} cells redrawn, {} full reprograms",
        rep.delta.blocks,
        rep.delta.blocks_clean,
        rep.delta.blocks_scale_only,
        rep.delta.blocks_redrawn,
        rep.delta.cells_redrawn,
        rep.delta.full_reprograms
    );

    // Invariant 1: accuracy parity at the same seeds, and both loops learn.
    let tol = if smoke { 0.20 } else { 0.10 };
    assert!(
        (legacy_acc - fast_acc).abs() <= tol,
        "accuracy parity broke: legacy {legacy_acc:.3} vs fast {fast_acc:.3} (tol {tol})"
    );
    let (l_first, l_last) = (legacy_logs.first().unwrap().loss, legacy_logs.last().unwrap().loss);
    let (f_first, f_last) = (rep.logs.first().unwrap().loss, rep.logs.last().unwrap().loss);
    assert!(l_last.is_finite() && l_last < l_first, "legacy loop failed to learn");
    assert!(f_last.is_finite() && f_last < f_first, "fast loop failed to learn");

    // Invariant 3a: counters are consistent and the delta path engaged —
    // full programs only on the template-seeding first step per core.
    let cores = 5; // LeNet-5: 2 conv + 3 fc hardware cores
    assert_eq!(rep.delta.full_reprograms, cores, "full reprograms beyond template seeding");
    assert_eq!(
        rep.delta.blocks_clean + rep.delta.dirty_blocks(),
        rep.delta.blocks,
        "every block must be classified exactly once per step"
    );

    // Invariant 3b: a delta step with unchanged weights redraws nothing...
    let quiet = fast.update_weight_delta();
    assert_eq!(quiet.full_reprograms, 0);
    assert_eq!(quiet.blocks_clean, quiet.blocks, "unchanged weights must be all-clean");
    assert_eq!(quiet.cells_redrawn, 0);
    // ...and a change confined to the first layer dirties blocks there only.
    let mut first_param = true;
    fast.visit_params(&mut |p| {
        if first_param {
            p.value[0] += 0.5;
            first_param = false;
        }
    });
    let one = fast.update_weight_delta();
    assert_eq!(one.full_reprograms, 0);
    assert!(one.dirty_blocks() >= 1, "the changed layer must redraw");
    assert!(
        one.dirty_blocks() < quiet.blocks,
        "a one-layer change must leave other layers' blocks clean \
         ({}/{} dirty)",
        one.dirty_blocks(),
        one.blocks
    );

    // Invariant 2: noise-free arm — curves bit-identical between loops.
    let ideal = || {
        HwSpec::uniform(
            DotProductEngine::ideal((64, 64)),
            SliceMethod::int(SliceSpec::int8()),
        )
    };
    let nf_cfg = TrainConfig {
        steps: if smoke { 6 } else { 20 },
        batch_size: 16,
        lr: 0.05,
        log_every: 1,
        seed: SEED,
        ..Default::default()
    };
    let mut nf_legacy = lenet5(Some(ideal()), SEED);
    let mut nf_fast = lenet5(Some(ideal()), SEED);
    let nf_logs = train(&mut nf_legacy, &train_set, &nf_cfg);
    let nf_rep = train_fast(&mut nf_fast, &train_set, &nf_cfg);
    assert_eq!(nf_logs.len(), nf_rep.logs.len());
    for (a, b) in nf_logs.iter().zip(&nf_rep.logs) {
        assert_eq!(
            a.loss.to_bits(),
            b.loss.to_bits(),
            "noise-free curves diverged at step {} ({} vs {})",
            a.step,
            a.loss,
            b.loss
        );
    }
    println!("[fig16] noise-free parity: {} steps bit-identical", nf_logs.len());

    // Invariant 4: the fast loop must actually be faster.
    let need = if smoke { 1.0 } else { 2.0 };
    assert!(
        speedup > need,
        "fast loop speedup {speedup:.2}x below the {need:.1}x bar \
         (legacy {legacy_secs:.3}s vs fast {fast_secs:.3}s)"
    );

    // ------------------------------------------------ machine-readable record
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"bench\": \"fig16_training\",\n");
    json.push_str(
        "  \"pipeline\": \"batch reuse -> DPE forward -> packed backward -> SGD -> template-delta reprogram\",\n",
    );
    let _ = writeln!(json, "  \"smoke\": {smoke},");
    json.push_str("  \"workload\": \"lenet5_int8_mnist_like\",\n");
    let _ = writeln!(json, "  \"steps\": {steps},");
    let _ = writeln!(json, "  \"batch_size\": {},", tcfg.batch_size);
    let _ = writeln!(json, "  \"legacy_steps_per_sec\": {legacy_sps:.3},");
    let _ = writeln!(json, "  \"fast_steps_per_sec\": {fast_sps:.3},");
    let _ = writeln!(json, "  \"speedup\": {speedup:.3},");
    let _ = writeln!(
        json,
        "  \"fast_phase_s\": {{\"batch\": {:.4}, \"forward\": {:.4}, \"backward\": {:.4}, \
         \"optim\": {:.4}, \"reprogram\": {:.4}}},",
        rep.batch_s, rep.forward_s, rep.backward_s, rep.optim_s, rep.reprogram_s
    );
    let _ = writeln!(
        json,
        "  \"delta\": {{\"blocks\": {}, \"clean\": {}, \"scale_only\": {}, \"redrawn\": {}, \
         \"cells_redrawn\": {}, \"full_reprograms\": {}}},",
        rep.delta.blocks,
        rep.delta.blocks_clean,
        rep.delta.blocks_scale_only,
        rep.delta.blocks_redrawn,
        rep.delta.cells_redrawn,
        rep.delta.full_reprograms
    );
    let _ = writeln!(
        json,
        "  \"accuracy\": {{\"legacy\": {legacy_acc:.4}, \"fast\": {fast_acc:.4}, \"tolerance\": {tol}}},"
    );
    json.push_str("  \"noise_free_curves_bit_identical\": true,\n");
    json.push_str("  \"single_layer_delta_isolated\": true,\n");
    let _ = writeln!(json, "  \"total_s\": {:.3}", t0.elapsed().as_secs_f64());
    json.push_str("}\n");
    std::fs::write("BENCH_fig16.json", &json).expect("writing BENCH_fig16.json");
    println!("\nwrote BENCH_fig16.json");

    // Paper-style artifact: the fig16 tables (legacy + fast + CIFAR point).
    let cfg = SimConfig { seed: SEED, ..SimConfig::default() };
    let scale = if smoke { Scale::Quick } else { Scale::Full };
    run_experiment("fig16_training", &cfg, scale).expect("experiment failed");
    println!("\n[fig16_training] total {:.1} s", t0.elapsed().as_secs_f64());
}

//! Bench: self-healing chip runtime **and** the paper-style fig_repair
//! artifact (robustness PR tentpole).
//!
//! Sweeps stuck-at cell rate × spare budget over repeated deploy cycles of
//! a LinearMem(128→64) INT8 layer on a one-tile chip, measuring relative
//! error vs the digital twin before and after one `MappedModel::self_heal`
//! round (program-and-verify → ABFT column probes → remap-to-spare).
//!
//! Before any number is reported, two invariants are hard-asserted:
//! 1. on a fault-free chip the repair loop is a **no-op**: zero retries,
//!    zero migrations, and bit-identical per-cycle RE before/after;
//! 2. there **exists** a swept stuck-at rate at which the unrepaired chip
//!    falls below the yield bound and one repair round strictly improves
//!    yield@RE-bound. If the primary grid happens to miss the window the
//!    bench escalates through extra rates before failing.
//!
//! Emits the machine-readable `BENCH_repair.json` (yield@RE-bound before/
//! after repair per point, probe/verify overhead, retries-per-block
//! histogram).
//!
//! Run: `cargo bench --bench fig_repair`
//! CI smoke: `MEMINTELLI_BENCH_SMOKE=1 cargo bench --bench fig_repair`
//! (fewer cycles, quick-scale artifact regeneration).

use memintelli::coordinator::experiments::{repair_sweep, RepairPoint};
use memintelli::coordinator::{run_experiment, Scale, SimConfig};
use std::fmt::Write as _;
use std::time::Instant;

const SEED: u64 = 2024;

/// Fraction of cycles whose RE meets the bound.
fn yield_at(res: &[f64], bound: f64) -> f64 {
    let ok = res.iter().filter(|&&re| re <= bound).count();
    ok as f64 / res.len().max(1) as f64
}

/// First point (by sweep order) where repair strictly improved yield at a
/// rate where the unrepaired chip misses the bound on some cycle.
fn improvement_at(pts: &[RepairPoint], bound: f64) -> Option<(f64, usize, f64, f64)> {
    pts.iter()
        .filter(|p| p.rate > 0.0 && p.spares > 0)
        .map(|p| (p.rate, p.spares, yield_at(&p.re_before, bound), yield_at(&p.re_after, bound)))
        .find(|&(_, _, yb, ya)| yb < 1.0 && ya > yb)
}

fn main() {
    let smoke = std::env::var("MEMINTELLI_BENCH_SMOKE").is_ok();
    let t0 = Instant::now();

    let cfg = SimConfig { seed: SEED, ..SimConfig::default() };

    let cycles = if smoke { 8 } else { 24 };
    let rates: Vec<f64> = if smoke {
        vec![0.0, 2e-5, 5e-5, 1e-4]
    } else {
        vec![0.0, 2e-5, 5e-5, 1e-4, 2e-4, 1e-3]
    };
    let spares_list = [0usize, 8];
    // Provisional bound; the assert below uses an adaptive bound derived
    // from the fault-free points so it tracks pure-quantization RE.
    let yield_re = 0.1;

    let mut pts = repair_sweep(&cfg, cycles, &rates, &spares_list, yield_re)
        .expect("repair_sweep failed");

    // Invariant 1: fault-free chip ⇒ the whole repair loop is a no-op.
    let clean: Vec<&RepairPoint> = pts.iter().filter(|p| p.rate == 0.0).collect();
    assert!(!clean.is_empty(), "sweep must include the fault-free anchor point");
    let mut clean_max = 0.0f64;
    for p in &clean {
        assert_eq!(p.moves, 0, "fault-free chip must not migrate blocks (spares={})", p.spares);
        assert_eq!(p.unplaced, 0, "fault-free chip must not strand groups");
        assert_eq!(p.retries, 0, "fault-free programming must verify on the first pass");
        assert_eq!(p.degraded_cycles, 0, "fault-free chip must never degrade");
        assert!(p.probe_matmuls > 0, "probes must actually run on the healthy chip");
        assert_eq!(
            p.re_before, p.re_after,
            "no-op repair must leave inference bit-identical (spares={})",
            p.spares
        );
        clean_max = p.re_before.iter().fold(clean_max, |m, &re| m.max(re));
    }
    let bound = (3.0 * clean_max).max(yield_re);
    println!(
        "[fig_repair] no-op anchor OK: clean RE max {clean_max:.4}, yield bound {bound:.4}"
    );

    // Invariant 2: repair strictly improves yield at some swept rate. If
    // the primary grid misses the window (all cycles clean, or every spare
    // drew its own fault), escalate through intermediate rates first.
    for &r in &[3e-5, 8e-5, 1.5e-4] {
        if improvement_at(&pts, bound).is_some() {
            break;
        }
        println!("[fig_repair] no improvement yet — escalating to rate {r:.1e}");
        let more = repair_sweep(&cfg, 2 * cycles, &[r], &[8], yield_re)
            .expect("repair_sweep (escalation) failed");
        pts.extend(more);
    }
    let improved = improvement_at(&pts, bound);
    let (imp_rate, imp_spares, imp_yb, imp_ya) = improved.expect(
        "no swept stuck-at rate showed yield_before < 1.0 with yield_after > yield_before",
    );
    println!(
        "[fig_repair] repair wins at rate {imp_rate:.1e} with {imp_spares} spares: \
         yield {imp_yb:.2} -> {imp_ya:.2} @ RE <= {bound:.3}"
    );

    let total_cycles: usize = pts.iter().map(|p| p.cycles).sum();
    let total_probes: usize = pts.iter().map(|p| p.probe_matmuls).sum();
    for p in &pts {
        println!(
            "[fig_repair] rate {:>7.1e} spares {}: RE {:.4} -> {:.4}, yield {:.2} -> {:.2}, \
             moves {}, unplaced {}, retries {}, probes {}, degraded {}/{}",
            p.rate,
            p.spares,
            p.re_before_mean(),
            p.re_after_mean(),
            yield_at(&p.re_before, bound),
            yield_at(&p.re_after, bound),
            p.moves,
            p.unplaced,
            p.retries,
            p.probe_matmuls,
            p.degraded_cycles,
            p.cycles
        );
    }

    // Machine-readable record.
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"bench\": \"fig_repair\",\n");
    json.push_str("  \"pipeline\": \"program-verify -> probe -> remap-to-spare\",\n");
    let _ = writeln!(json, "  \"smoke\": {smoke},");
    json.push_str("  \"workload\": \"linear_128x64_int8\",\n");
    let _ = writeln!(json, "  \"cycles_per_point\": {cycles},");
    let _ = writeln!(json, "  \"yield_re_bound\": {bound:.6},");
    json.push_str("  \"noop_on_clean_chip\": true,\n");
    json.push_str("  \"points\": [\n");
    for (i, p) in pts.iter().enumerate() {
        let hist: Vec<String> = p.retry_hist.iter().map(|c| c.to_string()).collect();
        let _ = write!(
            json,
            "    {{\"rate\": {:e}, \"spares\": {}, \"cycles\": {}, \
             \"re_before_mean\": {:.6}, \"re_after_mean\": {:.6}, \
             \"yield_before\": {:.4}, \"yield_after\": {:.4}, \
             \"moves\": {}, \"unplaced\": {}, \"retries\": {}, \
             \"probe_matmuls\": {}, \"degraded_cycles\": {}, \
             \"retry_hist\": [{}]}}",
            p.rate,
            p.spares,
            p.cycles,
            p.re_before_mean(),
            p.re_after_mean(),
            yield_at(&p.re_before, bound),
            yield_at(&p.re_after, bound),
            p.moves,
            p.unplaced,
            p.retries,
            p.probe_matmuls,
            p.degraded_cycles,
            hist.join(", ")
        );
        json.push_str(if i + 1 < pts.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ],\n");
    let _ = writeln!(
        json,
        "  \"improved_at\": {{\"rate\": {imp_rate:e}, \"spares\": {imp_spares}, \
         \"yield_before\": {imp_yb:.4}, \"yield_after\": {imp_ya:.4}}},"
    );
    let _ = writeln!(
        json,
        "  \"probe_overhead\": {{\"total_probe_matmuls\": {total_probes}, \
         \"probe_matmuls_per_cycle\": {:.3}}},",
        total_probes as f64 / total_cycles.max(1) as f64
    );
    let _ = writeln!(json, "  \"total_s\": {:.3}", t0.elapsed().as_secs_f64());
    json.push_str("}\n");
    std::fs::write("BENCH_repair.json", &json).expect("writing BENCH_repair.json");
    println!("\nwrote BENCH_repair.json");

    // Paper-style artifact: the fig_repair sweep tables.
    let scale = if smoke { Scale::Quick } else { Scale::Full };
    run_experiment("fig_repair", &cfg, scale).expect("experiment failed");
    println!("\n[fig_repair] total {:.1} s", t0.elapsed().as_secs_f64());
}

//! Bench: regenerates the paper's fig12_montecarlo artifact at full scale.
//! Run: `cargo bench --bench fig12_montecarlo`  (all benches: `cargo bench`)

use memintelli::coordinator::{run_experiment, Scale, SimConfig};

fn main() {
    let cfg = SimConfig::default();
    let t0 = std::time::Instant::now();
    run_experiment("fig12_montecarlo", &cfg, Scale::Full).expect("experiment failed");
    println!("\n[fig12_montecarlo] total {:.1} s", t0.elapsed().as_secs_f64());
}

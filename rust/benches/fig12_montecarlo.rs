//! Bench: regenerates the paper's fig12_montecarlo artifact **and** emits
//! `BENCH_mc.json`, the machine-readable Monte-Carlo perf-trajectory
//! record for the cached per-cycle path (the `WeightTemplate` +
//! `PreparedInputs` split, see `dpe::engine` §Perf).
//!
//! Two timings per case:
//! - **before**: the pre-split per-cycle loop — every cycle re-quantizes,
//!   re-slices, and re-packs both operands via `prepare_weights` +
//!   `matmul_prepared`, with the nested thread scopes that implies inside
//!   the cycle-level `par_map`;
//! - **after**: `run_point` / `run_fault_point` as shipped — template and
//!   prepared inputs built once, cycles pay only the noise-draw + pack +
//!   matmul cost, serial inside each cycle.
//!
//! The two paths are asserted **bit-identical** (same seed → same RE
//! statistics) before any number is reported. Headline acceptance case:
//! 128×128 operands, INT8 (1,1,2,4), 64×64 arrays, cv = 0.05, 100 cycles.
//!
//! Run: `cargo bench --bench fig12_montecarlo`
//! CI smoke: `MEMINTELLI_BENCH_SMOKE=1 cargo bench --bench fig12_montecarlo`
//! (fewer cycles, quick-scale experiment regeneration).

use memintelli::coordinator::{run_experiment, Scale, SimConfig};
use memintelli::device::faults::{FaultSpec, NonIdealitySpec};
use memintelli::dpe::montecarlo::{
    fault_point_operands, point_operands, run_fault_point, run_point, spec_for_bits, McConfig,
};
use memintelli::dpe::{DataMode, DotProductEngine, DpeConfig, SliceMethod};
use memintelli::tensor::Matrix;
use memintelli::util::parallel::par_map;
use std::fmt::Write as _;
use std::time::Instant;

struct PathTiming {
    wall_s: f64,
    cycles_per_s: f64,
    per_cycle_us: f64,
}

fn path_timing(wall_s: f64, cycles: usize) -> PathTiming {
    PathTiming {
        wall_s,
        cycles_per_s: cycles as f64 / wall_s,
        per_cycle_us: wall_s / cycles as f64 * 1e6,
    }
}

struct Case {
    name: &'static str,
    before: PathTiming,
    after: PathTiming,
    re_mean: f64,
}

impl Case {
    fn speedup(&self) -> f64 {
        self.after.cycles_per_s / self.before.cycles_per_s
    }
}

/// The pre-split per-cycle loop over fixed operands: per cycle a fresh
/// engine, full `prepare_weights` (quantize + slice + program + pack), and
/// `matmul_prepared` (re-slices the input). Returns the per-cycle REs in
/// cycle order — the same statistic stream the cached path must reproduce.
fn presplit_cycles(
    cfg: &McConfig,
    dpe_cfg: &DpeConfig,
    a: &Matrix,
    b: &Matrix,
    method: &SliceMethod,
) -> Vec<f64> {
    let ideal = a.matmul(b);
    par_map(cfg.cycles, |cycle| {
        let engine = DotProductEngine::new(dpe_cfg.clone(), cfg.seed.wrapping_add(cycle as u64));
        let w = engine.prepare_weights(b, method, cycle as u64);
        engine
            .matmul_prepared(a, &w, method, cycle as u64)
            .relative_error(&ideal)
    })
}

fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len() as f64
}

fn headline_case(cfg: &McConfig, bits: usize, block: usize, cv: f64) -> Case {
    let (a, b) = point_operands(cfg);
    let method = SliceMethod { spec: spec_for_bits(bits), mode: DataMode::Quantize };
    let mut dpe_cfg = cfg.base.clone();
    dpe_cfg.array = (block, block);
    dpe_cfg.device.cv = cv;

    let t0 = Instant::now();
    let before_res = presplit_cycles(cfg, &dpe_cfg, &a, &b, &method);
    let before = path_timing(t0.elapsed().as_secs_f64(), cfg.cycles);

    let t0 = Instant::now();
    let point = run_point(cfg, bits, block, cv, DataMode::Quantize);
    let after = path_timing(t0.elapsed().as_secs_f64(), cfg.cycles);

    assert_eq!(
        point.re_mean.to_bits(),
        mean(&before_res).to_bits(),
        "cached MC path must be bit-identical to the pre-split loop"
    );
    Case { name: "mc_128x128_int8_64x64", before, after, re_mean: point.re_mean }
}

fn fault_case(cfg: &McConfig, bits: usize, cv: f64) -> Case {
    let mut ni = NonIdealitySpec::none();
    ni.faults = FaultSpec::cells(0.02);
    ni.adc.offset_std_lsb = 0.3;
    let (a, b) = fault_point_operands(cfg);
    let method = SliceMethod { spec: spec_for_bits(bits), mode: DataMode::Quantize };
    let mut dpe_cfg = cfg.base.clone();
    dpe_cfg.device.cv = cv;
    dpe_cfg.nonideal = ni.clone();

    let t0 = Instant::now();
    let before_res = presplit_cycles(cfg, &dpe_cfg, &a, &b, &method);
    let before = path_timing(t0.elapsed().as_secs_f64(), cfg.cycles);

    let t0 = Instant::now();
    let point = run_fault_point(cfg, bits, cv, &ni, 0.1);
    let after = path_timing(t0.elapsed().as_secs_f64(), cfg.cycles);

    assert_eq!(
        point.re_mean.to_bits(),
        mean(&before_res).to_bits(),
        "cached fault-sweep path must be bit-identical to the pre-split loop"
    );
    Case { name: "fault_128x128_int8_64x64", before, after, re_mean: point.re_mean }
}

fn emit_json(cases: &[Case], cfg: &McConfig, smoke: bool, total_s: f64) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"fig12_montecarlo\",\n");
    out.push_str("  \"pipeline\": \"template-split-cached-mc\",\n");
    let _ = writeln!(out, "  \"smoke\": {smoke},");
    let _ = writeln!(out, "  \"size\": {}, \"cycles\": {},", cfg.size, cfg.cycles);
    let _ = writeln!(out, "  \"total_s\": {total_s:.3},");
    out.push_str("  \"cases\": [\n");
    for (i, c) in cases.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"name\": \"{}\", \"re_mean\": {:.6}, \"bit_identical\": true,\n     \
             \"before\": {{\"wall_s\": {:.4}, \"cycles_per_s\": {:.3}, \"per_cycle_us\": {:.1}}},\n     \
             \"after\": {{\"wall_s\": {:.4}, \"cycles_per_s\": {:.3}, \"per_cycle_us\": {:.1}}},\n     \
             \"speedup\": {:.3}}}",
            c.name,
            c.re_mean,
            c.before.wall_s,
            c.before.cycles_per_s,
            c.before.per_cycle_us,
            c.after.wall_s,
            c.after.cycles_per_s,
            c.after.per_cycle_us,
            c.speedup(),
        );
        out.push_str(if i + 1 < cases.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

fn main() {
    let smoke = std::env::var("MEMINTELLI_BENCH_SMOKE").is_ok();
    let t0 = Instant::now();
    // Headline acceptance point: 128×128, INT8 (spec_for_bits(8) ==
    // (1,1,2,4)), 64×64 arrays, Table-2 cv. Smoke mode trims cycles only —
    // the workload shape stays the headline one.
    let cycles = if smoke { 20 } else { 100 };
    let cfg = McConfig { size: 128, cycles, ..McConfig::default() };

    let cases = vec![headline_case(&cfg, 8, 64, 0.05), fault_case(&cfg, 8, 0.05)];

    for c in &cases {
        println!(
            "[{}] before {:.1} cycles/s ({:.0} µs/cycle) → after {:.1} cycles/s ({:.0} µs/cycle): {:.2}×",
            c.name,
            c.before.cycles_per_s,
            c.before.per_cycle_us,
            c.after.cycles_per_s,
            c.after.per_cycle_us,
            c.speedup(),
        );
    }

    // Paper artifact: the Fig-12 sweep tables.
    let sim_cfg = SimConfig::default();
    let scale = if smoke { Scale::Quick } else { Scale::Full };
    run_experiment("fig12_montecarlo", &sim_cfg, scale).expect("experiment failed");

    let json = emit_json(&cases, &cfg, smoke, t0.elapsed().as_secs_f64());
    std::fs::write("BENCH_mc.json", &json).expect("writing BENCH_mc.json");
    println!("\nwrote BENCH_mc.json");
    println!("[fig12_montecarlo] total {:.1} s", t0.elapsed().as_secs_f64());
}

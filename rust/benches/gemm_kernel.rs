//! Bench: the digit-domain GEMM kernels head to head, emitting
//! `BENCH_gemm.json` — the perf-trajectory record for the byte-packed /
//! slice-stacked datapath compression (`tensor` §Perf, `dpe::engine`
//! §Perf).
//!
//! Two levels are measured:
//!
//! - **Kernel level** (`kernel_cases`): all `S_a = 4` INT8 input digit
//!   planes of one k-block against one packed weight block
//!   (`k = 256`, `n = S_w·l_n = 256`, integer weight digits), comparing
//!   three kernels: the pre-stacking datapath — f64 digit planes, one
//!   [`matmul_packed_into`] pass per slice, B streamed `S_a` times — the
//!   stacked f64 kernel — byte-packed [`DigitPlanes`], one
//!   [`matmul_packed_stacked_into`] pass, B streamed once — and the
//!   integer stacked kernel — u8 weight panels ([`PackedU8`]), i32
//!   accumulation via [`matmul_packed_stacked_int_into`], B streamed once
//!   as bytes. `m ∈ {1, 8, 128}` covers single-sample inference through
//!   the table3 batch shape. Each case reports GFLOP/s-equiv, nominal
//!   operand/output bytes moved (cache reuse ignored), and both speedups.
//!   All three kernels' outputs are hard-asserted **bit-identical** before
//!   any number is recorded.
//! - **Engine level** (`engine_cases`): `matmul_prepared` on the table3
//!   headline config (INT8, 64×64 arrays, 512×512 weights, reused
//!   `PreparedWeights`) at `m = 1` (the 2-D-scheduling target shape) and
//!   `m = 128` (the table3 headline batch) — on the noisy device (f64
//!   kernel, analog conductances) AND the noise-free engine (integer
//!   kernel; `int_panel_blocks` is hard-asserted to cover every block).
//!   Every case is hard-asserted bit-identical to the per-slice-pair
//!   oracle (`matmul_prepared_reference`) — if any assert trips, the
//!   pipeline regressed and the job must fail.
//!
//! Run: `cargo bench --bench gemm_kernel`
//! CI smoke: `MEMINTELLI_BENCH_SMOKE=1 cargo bench --bench gemm_kernel`
//! (fewer iterations; every bit-identity assert still runs).

use memintelli::dpe::slicing::quantize_slice_block;
use memintelli::dpe::{DataMode, DotProductEngine, DpeConfig, SliceMethod, SliceSpec};
use memintelli::tensor::{
    int_accum_for, matmul_packed_into, matmul_packed_stacked_int_into, matmul_packed_stacked_into,
    Matrix, PackedB, PackedU8,
};
use memintelli::util::report::{time_it, Timing};
use memintelli::util::rng::Pcg64;
use std::fmt::Write as _;
use std::time::Instant;

/// One kernel-level comparison point.
struct KernelCase {
    m: usize,
    k: usize,
    n: usize,
    s_a: usize,
    per_slice: Timing,
    stacked: Timing,
    stacked_int: Timing,
    /// Nominal bytes moved per call (operands + output, no cache model).
    per_slice_bytes: usize,
    stacked_bytes: usize,
    stacked_int_bytes: usize,
}

fn kernel_case(m: usize, k: usize, n: usize, iters: usize, seed: u64) -> KernelCase {
    let spec = SliceSpec::int8();
    let s_a = spec.num_slices();
    assert_eq!(s_a, 4, "headline kernel case is S_a = 4 (INT8)");
    let mut rng = Pcg64::seeded(seed);
    let x = Matrix::random_normal(m, k, 0.0, 1.0, &mut rng);
    let planes = quantize_slice_block(&x, &spec, DataMode::Quantize).planes;
    // f64 materializations of the same digits — the pre-stacking operand.
    let f64_planes: Vec<Matrix> = (0..s_a).map(|s| planes.plane(s)).collect();
    // Weight digits as the engine programs them noise-free: integers in
    // the device's level range — the operand shape on which the integer
    // kernel engages.
    let b = Matrix::from_fn(k, n, |_, _| rng.below(16) as f64);
    let packed = PackedB::pack(&b);
    let packed_int = PackedU8::from_packed(&packed).expect("integer weight digits must mirror");
    let acc = int_accum_for(k, 255, packed_int.max_digit() as u64)
        .expect("kernel-case bound must fit an integer accumulator");

    let mut per_slice_out = vec![0.0f64; s_a * m * n];
    let mut stacked_out = vec![0.0f64; s_a * m * n];
    let mut int_out = vec![0.0f64; s_a * m * n];

    // Bit-identity first: the stacked kernel must reproduce the per-slice
    // kernel exactly on every plane, and the integer kernel must reproduce
    // the stacked kernel exactly.
    for (s, plane) in f64_planes.iter().enumerate() {
        matmul_packed_into(plane, &packed, &mut per_slice_out[s * m * n..(s + 1) * m * n]);
    }
    matmul_packed_stacked_into(&planes, &packed, &mut stacked_out);
    assert_eq!(
        per_slice_out, stacked_out,
        "stacked kernel diverged from the per-slice kernel at {m}x{k}x{n}"
    );
    matmul_packed_stacked_int_into(&planes, &packed_int, acc, &mut int_out);
    assert_eq!(
        int_out, stacked_out,
        "integer kernel diverged from the stacked f64 kernel at {m}x{k}x{n}"
    );

    let per_slice = time_it(1, iters, || {
        for (s, plane) in f64_planes.iter().enumerate() {
            matmul_packed_into(plane, &packed, &mut per_slice_out[s * m * n..(s + 1) * m * n]);
        }
    });
    let stacked = time_it(1, iters, || {
        matmul_packed_stacked_into(&planes, &packed, &mut stacked_out);
    });
    let stacked_int = time_it(1, iters, || {
        matmul_packed_stacked_int_into(&planes, &packed_int, acc, &mut int_out);
    });

    // Nominal traffic: the per-slice path reads f64 planes and streams the
    // packed block once per slice; the stacked path reads u8 planes and
    // streams the f64 block once; the integer path streams the block as
    // bytes. All write S_a·m·n f64 partials.
    let per_slice_bytes = s_a * m * k * 8 + s_a * k * n * 8 + s_a * m * n * 8;
    let stacked_bytes = s_a * m * k + k * n * 8 + s_a * m * n * 8;
    let stacked_int_bytes = s_a * m * k + k * n + s_a * m * n * 8;
    KernelCase {
        m,
        k,
        n,
        s_a,
        per_slice,
        stacked,
        stacked_int,
        per_slice_bytes,
        stacked_bytes,
        stacked_int_bytes,
    }
}

/// One engine-level trajectory point (stacked pipeline, reused weights).
struct EngineCase {
    m: usize,
    k: usize,
    n: usize,
    noise_free: bool,
    timing: Timing,
}

fn engine_case(m: usize, k: usize, n: usize, iters: usize, noise_free: bool) -> EngineCase {
    let cfg = DpeConfig { noise_free, ..DpeConfig::default() };
    let engine = DotProductEngine::new(cfg, 2024);
    let med = SliceMethod::int(SliceSpec::int8());
    let mut rng = Pcg64::seeded(99 + m as u64);
    let a = Matrix::random_normal(m, k, 0.0, 1.0, &mut rng);
    let b = Matrix::random_normal(k, n, 0.0, 1.0, &mut rng);
    let w = engine.prepare_weights(&b, &med, 0);
    if noise_free {
        // The integer kernel must actually serve this case: noise-free
        // programming leaves every block's digits exact.
        assert_eq!(
            w.int_panel_blocks(),
            w.num_blocks(),
            "noise-free blocks must all carry the byte mirror at {m}x{k}x{n}"
        );
    }
    // The tentpole contract, asserted in the bench itself: the stacked
    // pipeline (f64 or integer kernel alike) is bit-identical to the
    // per-slice-pair reference oracle.
    let stacked = engine.matmul_prepared(&a, &w, &med, 0);
    let oracle = engine.matmul_prepared_reference(&a, &w, &med, 0);
    assert_eq!(
        stacked.data, oracle.data,
        "stacked matmul_prepared diverged from the per-slice-pair oracle at {m}x{k}x{n} \
         (noise_free={noise_free})"
    );
    let timing = time_it(1, iters, || {
        let _ = engine.matmul_prepared(&a, &w, &med, 0);
    });
    EngineCase { m, k, n, noise_free, timing }
}

fn main() {
    let smoke = std::env::var("MEMINTELLI_BENCH_SMOKE").is_ok();
    let t0 = Instant::now();
    let (k, n, s_w_iters) = (256usize, 256usize, if smoke { 10 } else { 60 });

    let kernel_cases: Vec<KernelCase> = [1usize, 8, 128]
        .iter()
        .map(|&m| {
            // Scale iteration counts so each case takes comparable time.
            let iters = (s_w_iters * 128 / m.max(1)).clamp(s_w_iters, 2000);
            kernel_case(m, k, n, iters, 7 + m as u64)
        })
        .collect();

    for c in &kernel_cases {
        let flops = 2.0 * (c.s_a * c.m * c.k * c.n) as f64;
        println!(
            "[gemm_kernel] m={:>3} k={} n={} S_a={}: per-slice {:.3} ms ({:.2} GF/s), \
             stacked {:.3} ms ({:.2} GF/s), int {:.3} ms ({:.2} GF/s), \
             int speedup vs stacked {:.2}x, bytes {} -> {} -> {}",
            c.m,
            c.k,
            c.n,
            c.s_a,
            c.per_slice.mean_s * 1e3,
            flops / c.per_slice.mean_s / 1e9,
            c.stacked.mean_s * 1e3,
            flops / c.stacked.mean_s / 1e9,
            c.stacked_int.mean_s * 1e3,
            flops / c.stacked_int.mean_s / 1e9,
            c.stacked.mean_s / c.stacked_int.mean_s,
            c.per_slice_bytes,
            c.stacked_bytes,
            c.stacked_int_bytes,
        );
    }

    let engine_iters = if smoke { 3 } else { 15 };
    let engine_cases = vec![
        engine_case(1, 512, 512, engine_iters, false),
        engine_case(128, 512, 512, engine_iters, false),
        engine_case(1, 512, 512, engine_iters, true),
        engine_case(128, 512, 512, engine_iters, true),
    ];
    for c in &engine_cases {
        println!(
            "[gemm_kernel] matmul_prepared int8 {}x{}x{} ({}): mean {:.3} ms ({:.1}/s), \
             oracle bit-identical",
            c.m,
            c.k,
            c.n,
            if c.noise_free { "noise-free, int kernel" } else { "noisy, f64 kernel" },
            c.timing.mean_s * 1e3,
            1.0 / c.timing.mean_s,
        );
    }

    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"bench\": \"gemm_kernel\",\n");
    json.push_str("  \"pipeline\": \"stacked-slice-plane-gemm\",\n");
    let _ = writeln!(json, "  \"smoke\": {smoke},");
    json.push_str("  \"bit_identical_to_per_slice_kernel\": true,\n");
    json.push_str("  \"int_kernel_bit_identical_to_stacked\": true,\n");
    json.push_str("  \"bit_identical_to_reference_oracle\": true,\n");
    json.push_str("  \"kernel_cases\": [\n");
    for (i, c) in kernel_cases.iter().enumerate() {
        let flops = 2.0 * (c.s_a * c.m * c.k * c.n) as f64;
        let _ = write!(
            json,
            "    {{\"m\": {}, \"k\": {}, \"n\": {}, \"s_a\": {}, \"iters\": {}, \
             \"per_slice_s_mean\": {:.9}, \"stacked_s_mean\": {:.9}, \
             \"stacked_int_s_mean\": {:.9}, \
             \"per_slice_gflops_equiv\": {:.4}, \"stacked_gflops_equiv\": {:.4}, \
             \"stacked_int_gflops_equiv\": {:.4}, \
             \"per_slice_bytes_moved\": {}, \"stacked_bytes_moved\": {}, \
             \"stacked_int_bytes_moved\": {}, \
             \"speedup\": {:.4}, \"int_speedup_vs_stacked\": {:.4}}}",
            c.m,
            c.k,
            c.n,
            c.s_a,
            c.per_slice.iters,
            c.per_slice.mean_s,
            c.stacked.mean_s,
            c.stacked_int.mean_s,
            flops / c.per_slice.mean_s / 1e9,
            flops / c.stacked.mean_s / 1e9,
            flops / c.stacked_int.mean_s / 1e9,
            c.per_slice_bytes,
            c.stacked_bytes,
            c.stacked_int_bytes,
            c.per_slice.mean_s / c.stacked.mean_s,
            c.stacked.mean_s / c.stacked_int.mean_s,
        );
        json.push_str(if i + 1 < kernel_cases.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ],\n");
    json.push_str("  \"engine_cases\": [\n");
    for (i, c) in engine_cases.iter().enumerate() {
        let flops = 2.0 * (c.m * c.k * c.n) as f64;
        let variant = if c.noise_free { "noisefree_intkernel" } else { "noisy" };
        let _ = write!(
            json,
            "    {{\"name\": \"matmul_prepared_int8_64x64_{}_b{}\", \"m\": {}, \"k\": {}, \
             \"n\": {}, \"noise_free\": {}, \"int_kernel\": {}, \
             \"iters\": {}, \"wall_s_mean\": {:.9}, \"matmuls_per_s\": {:.3}, \
             \"gflops_equiv\": {:.4}}}",
            variant,
            c.m,
            c.m,
            c.k,
            c.n,
            c.noise_free,
            c.noise_free,
            c.timing.iters,
            c.timing.mean_s,
            1.0 / c.timing.mean_s,
            flops / c.timing.mean_s / 1e9,
        );
        json.push_str(if i + 1 < engine_cases.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ],\n");
    let _ = writeln!(json, "  \"total_s\": {:.3}", t0.elapsed().as_secs_f64());
    json.push_str("}\n");
    std::fs::write("BENCH_gemm.json", &json).expect("writing BENCH_gemm.json");
    println!("\nwrote BENCH_gemm.json");
    println!("[gemm_kernel] total {:.1} s", t0.elapsed().as_secs_f64());
}

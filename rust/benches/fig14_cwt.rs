//! Bench: regenerates the paper's fig14_cwt artifact at full scale.
//! Run: `cargo bench --bench fig14_cwt`  (all benches: `cargo bench`)

use memintelli::coordinator::{run_experiment, Scale, SimConfig};

fn main() {
    let cfg = SimConfig::default();
    let t0 = std::time::Instant::now();
    run_experiment("fig14_cwt", &cfg, Scale::Full).expect("experiment failed");
    println!("\n[fig14_cwt] total {:.1} s", t0.elapsed().as_secs_f64());
}

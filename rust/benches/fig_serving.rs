//! Bench: fault-tolerant serving runtime **and** the paper-style
//! fig_serving artifact (robustness PR tentpole).
//!
//! Drives a replicated [`ServingRuntime`] pool of trained-MLP replicas
//! under open-loop load through three scenarios — clean, mid-run stuck-at
//! faults with healing disabled, and the same faults with the background
//! health/heal pass on — and reports p50/p99 latency, throughput
//! (images/sec), and top-1 accuracy per scenario.
//!
//! Before any number is reported, three invariants are hard-asserted:
//! 1. **conservation** — every scenario resolves exactly one outcome per
//!    request (`completed + failed == requests`; the runtime itself
//!    panics on a lost or double-answered request);
//! 2. **bit-identity** — on the clean pool, every dispatched batch
//!    replayed on a twin replica via direct `infer_batched` matches the
//!    served outputs bit for bit;
//! 3. **healing wins** — under injected faults, accuracy with the
//!    health/heal pass on is strictly better than with healing disabled.
//!    If the primary fault rate happens not to separate the two arms
//!    (faults may land on sign slices that barely move the argmax), the
//!    bench escalates through higher rates before failing.
//!
//! Emits the machine-readable `BENCH_serving.json` (per-scenario latency
//! percentiles, throughput, accuracy, retry/heal accounting).
//!
//! Run: `cargo bench --bench fig_serving`
//! CI smoke: `MEMINTELLI_BENCH_SMOKE=1 cargo bench --bench fig_serving`
//! (quick-scale workload and artifact regeneration).

use memintelli::coordinator::experiments::{serving_sweep, ServingPoint};
use memintelli::coordinator::{run_experiment, Scale, SimConfig};
use std::fmt::Write as _;
use std::time::Instant;

const SEED: u64 = 2024;

fn by_label<'a>(pts: &'a [ServingPoint], label: &str) -> &'a ServingPoint {
    pts.iter()
        .find(|p| p.label == label)
        .unwrap_or_else(|| panic!("serving_sweep returned no '{label}' scenario"))
}

fn main() {
    let smoke = std::env::var("MEMINTELLI_BENCH_SMOKE").is_ok();
    let t0 = Instant::now();

    let cfg = SimConfig { seed: SEED, ..SimConfig::default() };
    let scale = if smoke { Scale::Quick } else { Scale::Full };

    // Escalating stuck-at rates: stop at the first rate where healing
    // strictly beats healing-off on accuracy. Invariants 1 and 2 are
    // checked at EVERY rate — they must hold unconditionally.
    let rates = [3e-5, 1e-4, 3e-4];
    let mut chosen: Option<(f64, Vec<ServingPoint>)> = None;
    for &rate in &rates {
        let pts = serving_sweep(&cfg, scale, rate).expect("serving_sweep failed");

        // Invariant 1: conservation — no request lost, none double-answered.
        for p in &pts {
            assert_eq!(
                p.completed + p.failed,
                p.requests,
                "scenario '{}' at rate {rate:.1e} lost requests ({} + {} != {})",
                p.label,
                p.completed,
                p.failed,
                p.requests
            );
            assert_eq!(
                p.failed,
                p.queue_full + p.deadline_exceeded + p.retries_exhausted,
                "scenario '{}' has failures outside the typed breakdown",
                p.label
            );
        }

        // Invariant 2: the healthy pool is bit-identical to direct inference.
        let clean = by_label(&pts, "clean");
        assert_eq!(
            clean.clean_bit_exact,
            Some(true),
            "clean pool outputs diverged from direct infer_batched at rate {rate:.1e}"
        );
        assert_eq!(clean.failed, 0, "clean pool must complete every request");

        // Invariant 3 (per rate): does healing separate the arms here?
        let off = by_label(&pts, "faults, healing off");
        let on = by_label(&pts, "faults, healing on");
        println!(
            "[fig_serving] rate {rate:>7.1e}: accuracy clean {:.3}, heal-off {:.3}, \
             heal-on {:.3} ({} heals, {} moves, {} fenced)",
            clean.accuracy, off.accuracy, on.accuracy, on.heals, on.moves, on.fenced
        );
        if on.accuracy > off.accuracy {
            chosen = Some((rate, pts));
            break;
        }
        println!("[fig_serving] healing not separated at {rate:.1e} — escalating");
    }
    let (rate, pts) = chosen.expect(
        "no swept stuck-at rate showed healing-on accuracy strictly above healing-off",
    );
    let on = by_label(&pts, "faults, healing on");
    let off = by_label(&pts, "faults, healing off");
    assert!(on.heals > 0, "the winning healing arm must actually have healed");
    println!(
        "[fig_serving] healing wins at rate {rate:.1e}: accuracy {:.3} -> {:.3} \
         with {} heal rounds",
        off.accuracy, on.accuracy, on.heals
    );

    for p in &pts {
        println!(
            "[fig_serving] {:<20} {}/{} ok, {} retries, p50 {} µs, p99 {} µs, \
             {:.0} img/s, accuracy {:.3}, heals {}, moves {}, fenced {}",
            p.label,
            p.completed,
            p.requests,
            p.retries,
            p.p50_us,
            p.p99_us,
            p.images_per_sec,
            p.accuracy,
            p.heals,
            p.moves,
            p.fenced
        );
    }

    // Machine-readable record.
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"bench\": \"fig_serving\",\n");
    json.push_str(
        "  \"pipeline\": \"replicated pool -> micro-batch -> deadline/retry -> health scan -> self-heal\",\n",
    );
    let _ = writeln!(json, "  \"smoke\": {smoke},");
    json.push_str("  \"workload\": \"mlp_784x16x10_int8_open_loop\",\n");
    let _ = writeln!(json, "  \"fault_rate\": {rate:e},");
    json.push_str("  \"requests_conserved\": true,\n");
    json.push_str("  \"clean_bit_exact\": true,\n");
    let _ = writeln!(
        json,
        "  \"healing_beats_disabled\": {{\"accuracy_off\": {:.4}, \"accuracy_on\": {:.4}}},",
        off.accuracy, on.accuracy
    );
    json.push_str("  \"points\": [\n");
    for (i, p) in pts.iter().enumerate() {
        let _ = write!(
            json,
            "    {{\"scenario\": \"{}\", \"requests\": {}, \"completed\": {}, \
             \"failed\": {}, \"queue_full\": {}, \"deadline_exceeded\": {}, \
             \"retries_exhausted\": {}, \"retries\": {}, \
             \"p50_us\": {}, \"p99_us\": {}, \"images_per_sec\": {:.2}, \
             \"accuracy\": {:.4}, \"heals\": {}, \"moves\": {}, \"fenced\": {}}}",
            p.label,
            p.requests,
            p.completed,
            p.failed,
            p.queue_full,
            p.deadline_exceeded,
            p.retries_exhausted,
            p.retries,
            p.p50_us,
            p.p99_us,
            p.images_per_sec,
            p.accuracy,
            p.heals,
            p.moves,
            p.fenced
        );
        json.push_str(if i + 1 < pts.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ],\n");
    let _ = writeln!(json, "  \"total_s\": {:.3}", t0.elapsed().as_secs_f64());
    json.push_str("}\n");
    std::fs::write("BENCH_serving.json", &json).expect("writing BENCH_serving.json");
    println!("\nwrote BENCH_serving.json");

    // Paper-style artifact: the fig_serving scenario table.
    run_experiment("fig_serving", &cfg, scale).expect("experiment failed");
    println!("\n[fig_serving] total {:.1} s", t0.elapsed().as_secs_f64());
}

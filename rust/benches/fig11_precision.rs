//! Bench: regenerates the paper's fig11_precision artifact at full scale.
//! Run: `cargo bench --bench fig11_precision`  (all benches: `cargo bench`)

use memintelli::coordinator::{run_experiment, Scale, SimConfig};

fn main() {
    let cfg = SimConfig::default();
    let t0 = std::time::Instant::now();
    run_experiment("fig11_precision", &cfg, Scale::Full).expect("experiment failed");
    println!("\n[fig11_precision] total {:.1} s", t0.elapsed().as_secs_f64());
}

//! Bench: regenerates the paper's table3_throughput artifact at full scale
//! **and** emits `BENCH_table3.json`, the machine-readable perf-trajectory
//! record for the DPE hot path (the stacked slice-plane GEMM pipeline over
//! byte-packed digit planes in `dpe::engine`). Compare the JSON across
//! commits to track the `matmul_prepared` throughput: the headline case is
//! INT8 on 64×64 arrays with batch 128 and a reused `PreparedWeights`
//! (prepared-weight reuse is exactly the NN training/inference hot loop);
//! the `b1` case is the single-sample serving shape that the 2-D
//! (row-band × panel-group) dispatch targets. Kernel-level per-slice vs
//! stacked numbers live in `benches/gemm_kernel.rs` (`BENCH_gemm.json`).
//!
//! Run: `cargo bench --bench table3_throughput`
//! CI smoke: `MEMINTELLI_BENCH_SMOKE=1 cargo bench --bench table3_throughput`
//! (smaller iteration counts, quick-scale experiment).

use memintelli::coordinator::{run_experiment, Scale, SimConfig};
use memintelli::dpe::{DotProductEngine, DpeConfig, SliceMethod, SliceSpec};
use memintelli::tensor::Matrix;
use memintelli::util::report::{time_it, Timing};
use memintelli::util::rng::Pcg64;
use std::fmt::Write as _;
use std::time::Instant;

struct Case {
    name: &'static str,
    method_name: &'static str,
    m: usize,
    k: usize,
    n: usize,
    arrays_used: usize,
    prepare_s: f64,
    timing: Timing,
}

/// Time `matmul_prepared` against weights programmed once (the reuse path).
fn bench_prepared(
    name: &'static str,
    method_name: &'static str,
    method: SliceMethod,
    (m, k, n): (usize, usize, usize),
    iters: usize,
) -> Case {
    // Table-2 defaults: 64×64 arrays, noisy device, worst-case ADC.
    let engine = DotProductEngine::new(DpeConfig::default(), 2024);
    let mut rng = Pcg64::seeded(7);
    let a = Matrix::random_normal(m, k, 0.0, 1.0, &mut rng);
    let b = Matrix::random_normal(k, n, 0.0, 1.0, &mut rng);
    let t0 = Instant::now();
    let w = engine.prepare_weights(&b, &method, 0);
    let prepare_s = t0.elapsed().as_secs_f64();
    let timing = time_it(1, iters, || {
        let _ = engine.matmul_prepared(&a, &w, &method, 0);
    });
    Case { name, method_name, m, k, n, arrays_used: w.arrays_used(), prepare_s, timing }
}

fn emit_json(cases: &[Case], smoke: bool, total_s: f64) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"table3_throughput\",\n");
    out.push_str("  \"pipeline\": \"stacked-slice-plane-gemm\",\n");
    let _ = writeln!(out, "  \"smoke\": {smoke},");
    let _ = writeln!(out, "  \"total_s\": {total_s:.3},");
    out.push_str("  \"cases\": [\n");
    for (i, c) in cases.iter().enumerate() {
        // GFLOP/s-equivalent of the logical GEMM the DPE emulates.
        let flops = 2.0 * (c.m * c.k * c.n) as f64;
        let _ = write!(
            out,
            "    {{\"name\": \"{}\", \"method\": \"{}\", \"m\": {}, \"k\": {}, \"n\": {}, \
             \"arrays_used\": {}, \"iters\": {}, \"prepare_s\": {:.6}, \
             \"wall_s_mean\": {:.6}, \"wall_s_min\": {:.6}, \
             \"matmuls_per_s\": {:.3}, \"gflops_equiv\": {:.4}}}",
            c.name,
            c.method_name,
            c.m,
            c.k,
            c.n,
            c.arrays_used,
            c.timing.iters,
            c.prepare_s,
            c.timing.mean_s,
            c.timing.min_s,
            1.0 / c.timing.mean_s,
            flops / c.timing.mean_s / 1e9,
        );
        out.push_str(if i + 1 < cases.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

fn main() {
    let smoke = std::env::var("MEMINTELLI_BENCH_SMOKE").is_ok();
    let iters = if smoke { 3 } else { 10 };
    let t0 = Instant::now();

    let cases = vec![
        // Headline perf-acceptance case: INT8, 64×64 arrays, batch 128,
        // reused PreparedWeights.
        bench_prepared(
            "matmul_prepared_int8_64x64_b128",
            "int8",
            SliceMethod::int(SliceSpec::int8()),
            (128, 512, 512),
            iters,
        ),
        // FP16 (5 slices/operand): larger fusion factor, bigger win.
        bench_prepared(
            "matmul_prepared_fp16_64x64_b128",
            "fp16",
            SliceMethod::fp(SliceSpec::fp16()),
            (128, 512, 512),
            iters,
        ),
        // Small-operand dispatch-overhead probe (LeNet-layer sized).
        bench_prepared(
            "matmul_prepared_int8_64x64_b32_small",
            "int8",
            SliceMethod::int(SliceSpec::int8()),
            (32, 256, 120),
            iters,
        ),
        // Single-sample serving shape: one input row over a wide layer —
        // the case the total-work pair dispatch + 2-D grid scheduling
        // keeps parallel (a row-band-only split has exactly one band).
        bench_prepared(
            "matmul_prepared_int8_64x64_b1",
            "int8",
            SliceMethod::int(SliceSpec::int8()),
            (1, 512, 512),
            iters * 8,
        ),
    ];

    for c in &cases {
        println!(
            "[{}] {}x{}x{} {}: prepare {:.1} ms, matmul mean {:.2} ms ({:.1}/s, {:.2} GFLOP/s-equiv)",
            c.name,
            c.m,
            c.k,
            c.n,
            c.method_name,
            c.prepare_s * 1e3,
            c.timing.mean_s * 1e3,
            1.0 / c.timing.mean_s,
            2.0 * (c.m * c.k * c.n) as f64 / c.timing.mean_s / 1e9,
        );
    }

    // Paper artifact: the end-to-end inference-throughput table.
    let cfg = SimConfig::default();
    let scale = if smoke { Scale::Quick } else { Scale::Full };
    run_experiment("table3_throughput", &cfg, scale).expect("experiment failed");

    let json = emit_json(&cases, smoke, t0.elapsed().as_secs_f64());
    std::fs::write("BENCH_table3.json", &json).expect("writing BENCH_table3.json");
    println!("\nwrote BENCH_table3.json");
    println!("[table3_throughput] total {:.1} s", t0.elapsed().as_secs_f64());
}

//! Bench: regenerates the paper's table3_throughput artifact at full scale.
//! Run: `cargo bench --bench table3_throughput`  (all benches: `cargo bench`)

use memintelli::coordinator::{run_experiment, Scale, SimConfig};

fn main() {
    let cfg = SimConfig::default();
    let t0 = std::time::Instant::now();
    run_experiment("table3_throughput", &cfg, Scale::Full).expect("experiment failed");
    println!("\n[table3_throughput] total {:.1} s", t0.elapsed().as_secs_f64());
}

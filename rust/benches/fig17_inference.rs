//! Bench: regenerates the paper's fig17_inference artifact at full scale.
//! Run: `cargo bench --bench fig17_inference`  (all benches: `cargo bench`)

use memintelli::coordinator::{run_experiment, Scale, SimConfig};

fn main() {
    let cfg = SimConfig::default();
    let t0 = std::time::Instant::now();
    run_experiment("fig17_inference", &cfg, Scale::Full).expect("experiment failed");
    println!("\n[fig17_inference] total {:.1} s", t0.elapsed().as_secs_f64());
}

//! Bench: chip-mapped batched inference throughput **and** the paper's
//! fig17_inference artifact.
//!
//! The headline case maps LeNet-5 (INT8, 64×64 arrays) onto a single-tile
//! chip via `Sequential::compile` and measures `MappedModel` throughput:
//!
//! - **single-stream baseline**: one image per `infer` call (the
//!   request-at-a-time serving shape — since the digit-domain datapath
//!   compression, these m = 1 DPE calls parallelize over (kb, nb) array
//!   pairs by total grid work, with lone big pairs 2-D-scheduled over
//!   (row-band × panel-group) items instead of starving on one row band;
//!   see `dpe::engine` §Perf);
//! - **batched**: `infer_batched` over the full image set at several
//!   micro-batch sizes.
//!
//! Before any number is reported, two invariants are hard-asserted:
//! 1. the single-tile mapping is **bit-identical** to the unmapped
//!    `Sequential` hardware path (the placement anchor);
//! 2. results are identical for every micro-batch size (batch-global
//!    input slicing under the fixed-range ADC).
//!
//! Emits the machine-readable `BENCH_fig17.json` (images/sec per
//! micro-batch size, single-stream baseline, speedup) and asserts the
//! best batched throughput is at least the single-stream baseline.
//!
//! Run: `cargo bench --bench fig17_inference`
//! CI smoke: `MEMINTELLI_BENCH_SMOKE=1 cargo bench --bench fig17_inference`
//! (fewer images, quick-scale artifact regeneration).

use memintelli::arch::ChipSpec;
use memintelli::coordinator::{run_experiment, Scale, SimConfig};
use memintelli::data::mnist_like;
use memintelli::dpe::{DotProductEngine, DpeConfig, SliceMethod, SliceSpec};
use memintelli::nn::models::lenet5;
use memintelli::nn::train::make_batch;
use memintelli::nn::HwSpec;
use std::fmt::Write as _;
use std::time::Instant;

const SEED: u64 = 2024;

fn int8_hw() -> HwSpec {
    HwSpec::uniform(
        DotProductEngine::new(DpeConfig::default(), SEED),
        SliceMethod::int(SliceSpec::int8()),
    )
}

struct BatchCase {
    micro_batch: usize,
    images_per_s: f64,
}

fn main() {
    let smoke = std::env::var("MEMINTELLI_BENCH_SMOKE").is_ok();
    let t0 = Instant::now();
    let n_imgs = if smoke { 64 } else { 256 };

    // Headline model: LeNet-5 INT8 on a single-tile chip.
    let mut unmapped = lenet5(Some(int8_hw()), SEED);
    let model = lenet5(Some(int8_hw()), SEED);
    let planes = model.mapped_planes();
    let chip = ChipSpec::single_tile(planes, (64, 64));
    let mapped = model.compile(&chip).expect("single-tile compile");
    println!("{}", mapped.placement().report());

    let data = mnist_like::load(n_imgs, SEED);
    let idx: Vec<usize> = (0..n_imgs).collect();
    let (x, _) = make_batch(&data, &idx);

    // Hard invariants (see module docs).
    let y_seq = unmapped.forward(&x, false);
    let y_map = mapped.infer(&x);
    assert_eq!(
        y_seq.data, y_map.data,
        "single-tile mapped inference must be bit-identical to the unmapped Sequential path"
    );
    for mb in [1usize, 5, 32, n_imgs] {
        assert_eq!(
            mapped.infer_batched(&x, mb).data,
            y_map.data,
            "micro_batch={mb} changed the results"
        );
    }
    println!("[fig17_inference] bit-identity anchor OK ({planes} arrays, {n_imgs} images)");

    // Single-stream baseline: one image per inference call.
    let single_iters = if smoke { 16 } else { 64 };
    let t = Instant::now();
    for i in 0..single_iters {
        let (xi, _) = make_batch(&data, &[i % n_imgs]);
        let _ = mapped.infer(&xi);
    }
    let single_ips = single_iters as f64 / t.elapsed().as_secs_f64();

    // Batched inference at several micro-batch sizes.
    let reps = if smoke { 1 } else { 3 };
    let mut cases = Vec::new();
    for &mb in &[4usize, 16, 64] {
        let t = Instant::now();
        for _ in 0..reps {
            let _ = mapped.infer_batched(&x, mb);
        }
        let images_per_s = (reps * n_imgs) as f64 / t.elapsed().as_secs_f64();
        println!(
            "[fig17_inference] micro_batch={mb:>3}: {images_per_s:>8.1} img/s \
             ({:.2}x single-stream {single_ips:.1} img/s)",
            images_per_s / single_ips
        );
        cases.push(BatchCase { micro_batch: mb, images_per_s });
    }
    let best = cases
        .iter()
        .max_by(|a, b| a.images_per_s.total_cmp(&b.images_per_s))
        .expect("cases non-empty");
    if smoke {
        // Smoke mode takes one sample per case on a loaded CI runner —
        // record the numbers, don't fail the job on a timing hiccup.
        println!(
            "[fig17_inference] smoke: best batched {:.1} img/s vs single-stream {single_ips:.1} img/s (not asserted)",
            best.images_per_s
        );
    } else {
        assert!(
            best.images_per_s >= single_ips,
            "batched inference ({:.1} img/s) must not lose to single-stream ({single_ips:.1} img/s)",
            best.images_per_s
        );
    }

    // Machine-readable record.
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"bench\": \"fig17_inference\",\n");
    json.push_str("  \"pipeline\": \"mapped-batched-inference\",\n");
    let _ = writeln!(json, "  \"smoke\": {smoke},");
    json.push_str("  \"model\": \"lenet5\", \"method\": \"int8\",\n");
    let _ = writeln!(
        json,
        "  \"chip\": {{\"tiles\": {}, \"arrays_per_tile\": {}, \"array\": [{}, {}]}},",
        chip.tiles, chip.arrays_per_tile, chip.array.0, chip.array.1
    );
    let _ = writeln!(json, "  \"images\": {n_imgs},");
    json.push_str("  \"bit_identical_single_tile\": true,\n");
    let _ = writeln!(json, "  \"single_stream_images_per_s\": {single_ips:.3},");
    json.push_str("  \"cases\": [\n");
    for (i, c) in cases.iter().enumerate() {
        let _ = write!(
            json,
            "    {{\"micro_batch\": {}, \"images_per_s\": {:.3}, \"speedup\": {:.3}}}",
            c.micro_batch,
            c.images_per_s,
            c.images_per_s / single_ips
        );
        json.push_str(if i + 1 < cases.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ],\n");
    let _ = writeln!(
        json,
        "  \"best\": {{\"micro_batch\": {}, \"images_per_s\": {:.3}, \"speedup\": {:.3}}},",
        best.micro_batch,
        best.images_per_s,
        best.images_per_s / single_ips
    );
    let _ = writeln!(json, "  \"total_s\": {:.3}", t0.elapsed().as_secs_f64());
    json.push_str("}\n");
    std::fs::write("BENCH_fig17.json", &json).expect("writing BENCH_fig17.json");
    println!("\nwrote BENCH_fig17.json");

    // Paper artifact: the Fig-17 accuracy tables + chip placement report,
    // evaluated through the mapped runtime.
    let cfg = SimConfig::default();
    let scale = if smoke { Scale::Quick } else { Scale::Full };
    run_experiment("fig17_inference", &cfg, scale).expect("experiment failed");
    println!("\n[fig17_inference] total {:.1} s", t0.elapsed().as_secs_f64());
}

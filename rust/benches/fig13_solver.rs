//! Bench: regenerates the paper's fig13_solver artifact at full scale.
//! Run: `cargo bench --bench fig13_solver`  (all benches: `cargo bench`)

use memintelli::coordinator::{run_experiment, Scale, SimConfig};

fn main() {
    let cfg = SimConfig::default();
    let t0 = std::time::Instant::now();
    run_experiment("fig13_solver", &cfg, Scale::Full).expect("experiment failed");
    println!("\n[fig13_solver] total {:.1} s", t0.elapsed().as_secs_f64());
}

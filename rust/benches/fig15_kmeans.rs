//! Bench: regenerates the paper's fig15_kmeans artifact at full scale.
//! Run: `cargo bench --bench fig15_kmeans`  (all benches: `cargo bench`)

use memintelli::coordinator::{run_experiment, Scale, SimConfig};

fn main() {
    let cfg = SimConfig::default();
    let t0 = std::time::Instant::now();
    run_experiment("fig15_kmeans", &cfg, Scale::Full).expect("experiment failed");
    println!("\n[fig15_kmeans] total {:.1} s", t0.elapsed().as_secs_f64());
}

//! Minimal, dependency-free shim of the `anyhow` error-handling API.
//!
//! The offline build environment has no crate registry, so this vendored
//! crate provides exactly the surface memintelli uses from the real
//! `anyhow`: [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`]
//! macros, and the [`Context`] extension trait for `Result` and `Option`.
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` conversion (and therefore `?` on any
//! standard error type) possible.

use std::fmt;

/// A string-backed error value with an optional chain of context lines
/// (most recent context first, matching `anyhow`'s Display behaviour).
pub struct Error {
    context: Vec<String>,
    source: String,
}

impl Error {
    /// Construct from anything displayable (what `anyhow!` expands to).
    pub fn msg(message: impl fmt::Display) -> Self {
        Error { context: Vec::new(), source: message.to_string() }
    }

    /// Push a higher-level context line onto the chain.
    pub fn add_context(mut self, c: impl fmt::Display) -> Self {
        self.context.insert(0, c.to_string());
        self
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        &self.source
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.context.first() {
            Some(c) => write!(f, "{c}"),
            None => write!(f, "{}", self.source),
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for c in &self.context {
            writeln!(f, "{c}")?;
            writeln!(f, "\nCaused by:")?;
        }
        write!(f, "{}", self.source)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

/// Attach context to errors (and convert `Option` to `Result`).
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: fmt::Display> Context<T, E> for Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::msg(e).add_context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(e).add_context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_number(s: &str) -> Result<i32> {
        let n: i32 = s.parse()?; // via blanket From<ParseIntError>
        ensure!(n >= 0, "negative: {n}");
        Ok(n)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse_number("42").unwrap(), 42);
        assert!(parse_number("nope").is_err());
        assert!(parse_number("-3").is_err());
    }

    #[test]
    fn bail_and_anyhow_format() {
        fn f() -> Result<()> {
            bail!("broke with code {}", 7)
        }
        let e = f().unwrap_err();
        assert_eq!(format!("{e}"), "broke with code 7");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(std::io::Error::other("io boom"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(e.root_cause(), "io boom");

        let o: Option<i32> = None;
        let e = o.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(format!("{e}"), "missing thing");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let r: Result<(), std::io::Error> = Err(std::io::Error::other("root"));
        let e = r.context("top").unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("top") && dbg.contains("Caused by") && dbg.contains("root"));
    }
}

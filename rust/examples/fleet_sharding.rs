//! fleet_sharding: a model sharded across a chip fleet, end to end.
//!
//! ```bash
//! cd rust && cargo run --release --example fleet_sharding
//! ```
//!
//! Shards an MLP(96→32→8) INT8 model across a three-chip fleet (two
//! pipeline stages plus one spare), prints the shard plan, then:
//!
//! - runs the pipeline clean and asserts the outputs are **bit-identical**
//!   to a single-chip `MappedModel::infer_batched` twin — partitioning is
//!   purely spatial on noise-free engines;
//! - kills chip 0 (the stage-0 fault domain) mid-run with a
//!   `ChipFaultSpec` and prints the recorded timeline: the in-flight
//!   micro-batch re-runs, the stage fails over onto the spare chip
//!   (template reprogram + placement substitution), and the stream
//!   finishes without losing a sample;
//! - asserts conservation (every micro-batch ends `Done` or `Failed`)
//!   and that the failed-over outputs are *still* bit-identical — the
//!   noise-free reprogram restores the exact weights.
//!
//! Every knob comes from the `[fleet]` TOML section in production runs
//! (`memintelli run fig_sharding`, see `examples/README.md`); here the
//! spec is built inline so the timeline stays small and readable.

use memintelli::arch::{
    uniform_fleet, BatchOutcome, ChipFaultSpec, ChipSpec, FleetEventKind, FleetSpec,
};
use memintelli::dpe::{DotProductEngine, SliceMethod, SliceSpec};
use memintelli::nn::models::mlp;
use memintelli::nn::HwSpec;
use memintelli::tensor::Tensor;

const SEED: u64 = 41;

fn ideal_hw() -> HwSpec {
    HwSpec::uniform(DotProductEngine::ideal((64, 64)), SliceMethod::int(SliceSpec::int8()))
}

fn main() -> anyhow::Result<()> {
    // The same template three times (compile consumes the model): a
    // single-chip twin for the bit-identity reference, plus two sharded
    // instances (clean run, chip-loss run). Same seed ⇒ same weights.
    let twin = {
        let m = mlp(96, 32, 8, Some(ideal_hw()), SEED);
        let chip = ChipSpec::single_tile(m.mapped_planes(), (64, 64));
        m.compile(&chip)?
    };

    // Three chips of 8 arrays each: stage 0 takes layer 0..3 (8 planes),
    // stage 1 takes layer 3..4 (4 planes), chip 2 stays spare.
    let fleet = uniform_fleet(3, 8, (64, 64));
    let mut sharded = mlp(96, 32, 8, Some(ideal_hw()), SEED).compile_sharded(&fleet)?;
    println!("=== shard plan ===\n\n{}", sharded.plan().report());

    // Deterministic 32-sample workload: 4 micro-batches of 8.
    let n = 32;
    let x = Tensor::from_vec(
        &[n, 96],
        (0..n * 96).map(|i| (((i * 7) % 23) as f64) / 11.5 - 1.0).collect(),
    );
    let spec = FleetSpec::default();

    // Clean pipeline run: bit-identical to the single-chip twin.
    let clean = sharded.run(&x, &spec, &[])?;
    let y_ref = twin.infer_batched(&x, n);
    let y_clean = clean.output_tensor().expect("clean run completed every batch");
    assert_eq!(y_clean.shape, y_ref.shape);
    let exact = |a: &Tensor, b: &Tensor| {
        a.data.iter().zip(&b.data).all(|(p, q)| p.to_bits() == q.to_bits())
    };
    assert!(exact(&y_clean, &y_ref), "clean sharded run must match the single-chip twin");
    println!(
        "clean run    : {}/{} batches done in {} µs ({:.0} images/sec), bit-identical to twin\n",
        clean.completed(),
        clean.outcomes.len(),
        clean.makespan_us,
        clean.images_per_sec()
    );

    // Chip-loss run: kill chip 0 a third of the way through the clean
    // makespan — stage 0 loses its fault domain mid-stream.
    let fault_at = (clean.makespan_us / 3).max(1);
    let mut survivor = mlp(96, 32, 8, Some(ideal_hw()), SEED).compile_sharded(&fleet)?;
    let report = survivor.run(&x, &spec, &[ChipFaultSpec { at_us: fault_at, chip: 0 }])?;

    println!("=== chip-loss timeline (chip 0 dies at {fault_at} µs) ===\n");
    for e in &report.events {
        let t = e.at_us;
        match &e.kind {
            FleetEventKind::ChipFault { chip } => {
                println!("{t:>7} µs  FAULT     chip {chip} went dark")
            }
            FleetEventKind::Failover { stage, to_chips } => {
                println!("{t:>7} µs  failover  stage {stage} -> chips {to_chips:?}")
            }
            FleetEventKind::Degraded { stage, condemned } => println!(
                "{t:>7} µs  DEGRADED  stage {stage}: {condemned} group(s) condemned in place"
            ),
            FleetEventKind::Rerun { stage, batch } => {
                println!("{t:>7} µs  rerun     batch {batch} re-runs on stage {stage}")
            }
            FleetEventKind::LinkTimeout { stage, batch, attempt } => println!(
                "{t:>7} µs  timeout   batch {batch} hop into stage {stage} (attempt {attempt})"
            ),
            FleetEventKind::CorruptDetected { stage, batch, attempt } => println!(
                "{t:>7} µs  corrupt   batch {batch} hop into stage {stage} (attempt {attempt}): \
                 checksum caught it"
            ),
            FleetEventKind::BatchFailed { batch, stage } => {
                println!("{t:>7} µs  FAILED    batch {batch} at stage {stage}")
            }
        }
    }

    println!("\n=== outcome ===\n");
    for (b, o) in report.outcomes.iter().enumerate() {
        match o {
            BatchOutcome::Done { completed_us, degraded } => println!(
                "batch {b}: done at {completed_us} µs{}",
                if *degraded { " (DEGRADED)" } else { "" }
            ),
            BatchOutcome::Failed { error, at_us } => {
                println!("batch {b}: FAILED at {at_us} µs ({error})")
            }
        }
    }
    let failovers = report
        .events
        .iter()
        .filter(|e| matches!(e.kind, FleetEventKind::Failover { .. }))
        .count();
    println!(
        "\nchips down   : {:?}  (spares left: {})",
        survivor.chip_down(),
        survivor.spares_left()
    );
    println!("failovers    : {failovers}; degraded report: {:?}", survivor.degraded().is_some());
    println!(
        "samples      : {}/{} completed in {} µs ({:.0} images/sec)",
        report.completed_samples(),
        report.samples,
        report.makespan_us,
        report.images_per_sec()
    );

    assert!(report.conserved(), "every micro-batch must end Done or Failed");
    assert!(failovers >= 1, "losing chip 0 must trigger a stage failover");
    let y_failover = report.output_tensor().expect("failover kept every batch alive");
    assert!(exact(&y_failover, &y_ref), "failover reprogram must restore exact outputs");
    println!("\nfailed-over outputs are bit-identical to the single-chip twin");
    Ok(())
}

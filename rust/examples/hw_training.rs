//! hw_training: hardware-in-the-loop training on the fast path.
//!
//! ```bash
//! cd rust && cargo run --release --example hw_training
//! ```
//!
//! Trains the same INT8 MLP on the synthetic digit task twice with the
//! same seeds — once through the legacy loop (`nn::train::train`), which
//! re-quantizes and re-programs every array cell after every optimizer
//! step, and once through the fast loop (`nn::train::train_fast`), which:
//!
//! - re-programs by **template delta**: each step re-derives the quantized
//!   digit planes per block, compares against the cached template, and
//!   redraws programming noise only for the cells whose digits actually
//!   moved — unchanged cells keep the conductances already on the array,
//!   exactly like real reprogramming hardware;
//! - runs the backward gradient GEMMs through the packed register-tiled
//!   training kernel (`tensor::matmul_train`), with an exact integer rung
//!   when the operands are digit-valued;
//! - reuses the batch assembly buffers across steps (no per-step
//!   allocation).
//!
//! Prints both training curves, the fast loop's per-phase time breakdown,
//! and the delta-programming counters (clean / scale-only / redrawn blocks,
//! cells redrawn). On a noise-free engine the two curves would be
//! bit-identical; on this noisy engine they differ only because the delta
//! path deliberately keeps the programmed noise of unchanged cells.

use memintelli::data::mnist_like;
use memintelli::dpe::{DotProductEngine, DpeConfig, SliceMethod, SliceSpec};
use memintelli::nn::models::mlp;
use memintelli::nn::train::{evaluate, train, train_fast, TrainConfig};
use memintelli::nn::HwSpec;
use std::time::Instant;

const SEED: u64 = 9;

fn main() {
    let data = mnist_like::load(512, SEED);
    let (train_set, test_set) = data.split(448);
    let cfg = TrainConfig {
        steps: 40,
        batch_size: 16,
        lr: 0.05,
        log_every: 8,
        seed: SEED,
        ..Default::default()
    };
    let hw = || {
        HwSpec::uniform(
            DotProductEngine::new(DpeConfig::default(), SEED),
            SliceMethod::int(SliceSpec::int8()),
        )
    };

    println!("legacy loop (full reprogram every step):");
    let mut legacy = mlp(784, 32, 10, Some(hw()), SEED);
    let t = Instant::now();
    let logs = train(&mut legacy, &train_set, &cfg);
    let legacy_secs = t.elapsed().as_secs_f64();
    for l in &logs {
        println!("  step {:>3}  loss {:.4}  train acc {:.3}", l.step, l.loss, l.train_acc);
    }
    let legacy_acc = evaluate(&mut legacy, &test_set, 32, 64);
    println!("  {:.2} steps/s, test acc {legacy_acc:.3}", cfg.steps as f64 / legacy_secs);

    println!("\nfast loop (template-delta reprogram + packed backward):");
    let mut fast = mlp(784, 32, 10, Some(hw()), SEED);
    let t = Instant::now();
    let rep = train_fast(&mut fast, &train_set, &cfg);
    let fast_secs = t.elapsed().as_secs_f64();
    for l in &rep.logs {
        println!("  step {:>3}  loss {:.4}  train acc {:.3}", l.step, l.loss, l.train_acc);
    }
    let fast_acc = evaluate(&mut fast, &test_set, 32, 64);
    println!("  {:.2} steps/s, test acc {fast_acc:.3}", cfg.steps as f64 / fast_secs);
    println!("  speedup {:.2}x over the legacy loop", legacy_secs / fast_secs);

    println!("\nfast-loop phase breakdown:");
    println!("  batch assembly {:.3} s", rep.batch_s);
    println!("  forward        {:.3} s", rep.forward_s);
    println!("  backward       {:.3} s", rep.backward_s);
    println!("  optimizer      {:.3} s", rep.optim_s);
    println!("  reprogram      {:.3} s", rep.reprogram_s);

    let d = &rep.delta;
    println!("\ndelta-programming counters over {} steps:", cfg.steps);
    println!("  blocks classified  {}", d.blocks);
    println!("  clean (no write)   {}", d.blocks_clean);
    println!("  scale-only update  {}", d.blocks_scale_only);
    println!("  redrawn blocks     {}", d.blocks_redrawn);
    println!("  cells redrawn      {}", d.cells_redrawn);
    println!("  full reprograms    {} (template seeding)", d.full_reprograms);
}

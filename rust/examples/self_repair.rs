//! self_repair: the closed-loop chip reliability layer, end to end.
//!
//! ```bash
//! cd rust && cargo run --release --example self_repair
//! ```
//!
//! Maps a LinearMem(128→64) INT8 layer onto a one-tile chip whose fabric
//! has stuck cells, then runs one `MappedModel::self_heal` round and
//! prints every stage of the loop:
//!
//! 1. **program-and-verify** — each digit plane is read back after
//!    programming and re-drawn while its error exceeds the tolerance;
//!    stuck-at-HGS cells pin digits at the device max, so the affected
//!    planes never converge — that *is* the detection signal;
//! 2. **online probes** — ABFT column-checksum vectors through the real
//!    fused GEMM path localize faulty `(k-block, n-block)` groups without
//!    any ground-truth output;
//! 3. **remap-to-spare** — condemned groups migrate whole onto the tile's
//!    spare tail (`ChipSpec::with_spares`) and reprogram under their new
//!    physical streams;
//! 4. **graceful degradation** — with the spare tail exhausted, leftover
//!    condemned groups are reported in a `DegradedReport` and the model
//!    keeps serving.
//!
//! The full rate × spare-budget yield study is the `fig_repair`
//! experiment (`cargo run --release -- fig_repair --quick`), and
//! `benches/fig_repair.rs` records it in `BENCH_repair.json`.

use memintelli::arch::ChipSpec;
use memintelli::device::faults::{FaultSpec, NonIdealitySpec};
use memintelli::dpe::{DotProductEngine, DpeConfig, RepairSpec, SliceMethod, SliceSpec};
use memintelli::nn::layers::LinearMem;
use memintelli::nn::{HwSpec, Sequential};
use memintelli::tensor::Tensor;
use memintelli::util::rng::Pcg64;

const SEED: u64 = 41;

/// INT8 hardware whose arrays carry stuck cells at `rate` (half SA0,
/// half SA1) on every physical slot's fault stream.
fn faulty_hw(rate: f64) -> HwSpec {
    HwSpec::uniform(
        DotProductEngine::new(
            DpeConfig {
                nonideal: NonIdealitySpec {
                    faults: FaultSpec::cells(rate),
                    ..NonIdealitySpec::none()
                },
                ..DpeConfig::default()
            },
            SEED,
        ),
        SliceMethod::int(SliceSpec::int8()),
    )
}

/// One LinearMem(128, 64): a 2-block × 4-slice grid = 8 digit planes.
/// `hw = None` builds the digital twin with bit-identical weights.
fn model(hw: Option<HwSpec>) -> Sequential {
    let mut rng = Pcg64::new(SEED, 0xF00D);
    Sequential::new(vec![Box::new(LinearMem::new(128, 64, hw, &mut rng))])
}

fn relative_err(got: &[f64], want: &[f64]) -> f64 {
    let num: f64 = got.iter().zip(want).map(|(a, b)| (a - b) * (a - b)).sum();
    let den: f64 = want.iter().map(|v| v * v).sum();
    (num / den.max(1e-300)).sqrt()
}

fn main() {
    let x = Tensor::from_vec(
        &[4, 128],
        (0..4 * 128).map(|i| ((i * 7 % 23) as f64) / 11.0 - 1.0).collect(),
    );
    let mut twin = model(None);
    let y_ref = twin.forward(&x, false);
    // A rate high enough that every 4-plane group deterministically hits
    // stuck-at-HGS cells — detection is the star here, not yield (the
    // yield story, at realistic sparse rates, is `fig_repair`).
    let rate = 0.05;
    let spec = RepairSpec::enabled();

    // ------------------------------------------------------------------
    // Scenario A: enough spares — every condemned group finds a new home.
    // 8 data slots hold the model's 8 planes; 8 spares = 2 whole groups.
    // ------------------------------------------------------------------
    println!("=== scenario A: 1 tile x (8 data + 8 spare) arrays ===\n");
    let chip = ChipSpec::new(1, 16, (64, 64)).with_spares(8);
    let mut mapped = model(Some(faulty_hw(rate))).compile(&chip).expect("compile");
    let re_before = relative_err(&mapped.infer(&x).data, &y_ref.data);

    let out = mapped.self_heal(&spec).expect("self_heal");

    for rep in &out.program_reports {
        for b in &rep.blocks {
            println!(
                "verify  block {} (stream {:>2}): {} retries, {} unconverged plane(s), \
                 worst plane err {:.1} digits",
                b.block, b.stream, b.retries, b.unconverged_planes, b.worst_err
            );
        }
    }
    for s in &out.health.slots {
        println!(
            "probe   layer {} block {} @ tile {} slot {:>2}: RE {:.3} -> {}",
            s.layer,
            s.block,
            s.slot.tile,
            s.slot.index,
            s.score,
            if s.healthy { "healthy" } else { "CONDEMNED" }
        );
    }
    println!("probe overhead: {} checksum matmuls\n", out.health.probe_matmuls);
    for m in &out.plan.moves {
        println!(
            "remap   layer {} block {}: slots {:?} -> {:?} (new stream {})",
            m.layer,
            m.block,
            m.from.iter().map(|s| s.index).collect::<Vec<_>>(),
            m.to.iter().map(|s| s.index).collect::<Vec<_>>(),
            m.new_stream
        );
    }
    let re_after = relative_err(&mapped.infer(&x).data, &y_ref.data);
    println!(
        "\nRE vs digital twin: {re_before:.4} before repair, {re_after:.4} after \
         (spares draw from the same {rate} stuck-cell fabric — at sparse realistic \
         rates the move lands on clean arrays and yield recovers; see fig_repair)"
    );
    assert!(out.degraded.is_none(), "8 spares fit both condemned groups");
    println!("\n{}", mapped.placement().report());

    // ------------------------------------------------------------------
    // Scenario B: spare tail exhausted — degrade gracefully, keep serving.
    // ------------------------------------------------------------------
    println!("\n=== scenario B: 1 tile x (8 data + 4 spare) arrays ===\n");
    let chip = ChipSpec::new(1, 12, (64, 64)).with_spares(4);
    let mut mapped = model(Some(faulty_hw(rate))).compile(&chip).expect("compile");
    let out = mapped.self_heal(&spec).expect("self_heal");
    println!(
        "{} group(s) moved, {} condemned group(s) had no spare left",
        out.plan.moves.len(),
        out.plan.unplaced.len()
    );
    let deg = mapped.degraded().expect("one group must be left degraded");
    println!(
        "degraded: groups {:?} at slots {:?}, estimated RE impact {:.3}",
        deg.condemned,
        deg.slots.iter().map(|s| (s.tile, s.index)).collect::<Vec<_>>(),
        deg.estimated_re_impact
    );
    let y = mapped.infer(&x);
    println!(
        "degraded chip keeps serving: output shape {:?}, RE vs twin {:.4}",
        y.shape,
        relative_err(&y.data, &y_ref.data)
    );
}

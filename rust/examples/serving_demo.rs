//! serving_demo: the fault-tolerant serving runtime, end to end.
//!
//! ```bash
//! cd rust && cargo run --release --example serving_demo
//! ```
//!
//! Serves an open-loop request stream through a two-replica
//! `ServingRuntime` pool of LinearMem(128→64) INT8 chips, injects a
//! stuck-cell fault event into replica 0 mid-run, and prints the full
//! failover/heal timeline the runtime records:
//!
//! - the fault kills replica 0's in-flight batch; its requests retry
//!   (with backoff) on replica 1 — nothing is lost or double-answered;
//! - the next background health scan probes both replicas with ABFT
//!   checksum vectors, flags the damaged one, and pulls it from rotation;
//! - a `MappedModel::self_heal` round reprograms it (program-and-verify,
//!   probe, remap-to-spare) and it rejoins the pool;
//! - requests keep completing throughout — the pool never goes dark.
//!
//! Every knob comes from the `[serving]` TOML section in production runs
//! (`memintelli serve`, see `examples/README.md`); here the spec is built
//! inline so the timeline stays small and readable.

use memintelli::arch::{
    ChipSpec, EventKind, FaultEvent, Outcome, ReplicaSpec, Request, ServingRuntime, ServingSpec,
};
use memintelli::device::faults::{FaultSpec, NonIdealitySpec};
use memintelli::dpe::{DotProductEngine, DpeConfig, RepairSpec, SliceMethod, SliceSpec};
use memintelli::nn::layers::LinearMem;
use memintelli::nn::{HwSpec, Sequential};
use memintelli::util::rng::Pcg64;

const SEED: u64 = 41;

fn main() -> anyhow::Result<()> {
    // Replica factory: LinearMem(128→64) INT8 on a one-tile chip with a
    // 4-slot spare tail. The condition tells us how to build it: a replica
    // that has sustained a fault event gets stuck cells on its fabric.
    let factory = |r: usize, cond: &ReplicaSpec| {
        let faults = if cond.faulty { FaultSpec::cells(0.02) } else { FaultSpec::none() };
        let dpe = DpeConfig {
            nonideal: NonIdealitySpec {
                faults,
                t_read: cond.t_read_s,
                ..NonIdealitySpec::none()
            },
            ..DpeConfig::default()
        };
        let hw = HwSpec::uniform(
            DotProductEngine::new(dpe, SEED + r as u64),
            SliceMethod::int(SliceSpec::int8()),
        );
        let mut rng = Pcg64::new(SEED, 0xF00D);
        let model = Sequential::new(vec![Box::new(LinearMem::new(128, 64, Some(hw), &mut rng))]);
        model.compile(&ChipSpec::new(1, 12, (64, 64)).with_spares(4))
    };

    let spec = ServingSpec {
        replicas: 2,
        max_batch: 4,
        batch_deadline_us: 1_000,
        request_deadline_us: 100_000,
        max_retries: 2,
        retry_backoff_us: 500,
        health_period_us: 2_000, // background ABFT scan cadence
        heal_us: 1_000,          // time a pulled replica spends healing
        service_base_us: 200,
        service_per_sample_us: 50,
        ..ServingSpec::default()
    };
    let mut rt = ServingRuntime::new(spec, RepairSpec::enabled(), vec![128], Box::new(factory))?;

    // Open-loop workload: 24 requests, one every 400 µs; stuck cells hit
    // replica 0 at t = 2 ms, mid-stream.
    let workload: Vec<Request> = (0..24)
        .map(|i| Request {
            arrive_us: i as u64 * 400,
            sample: (0..128).map(|k| (((i * 7 + k) % 23) as f64) / 11.0 - 1.0).collect(),
        })
        .collect();
    let faults = [FaultEvent { at_us: 2_000, replica: 0 }];

    let report = rt.run(&workload, &faults)?;

    println!("=== failover / heal timeline ===\n");
    for e in &report.events {
        let t = e.at_us;
        match &e.kind {
            EventKind::Dispatch { batch, replica, requests } => println!(
                "{t:>7} µs  dispatch  batch {batch} -> replica {replica} ({requests} reqs)"
            ),
            EventKind::BatchDone { batch, replica } => {
                println!("{t:>7} µs  done      batch {batch} on replica {replica}")
            }
            EventKind::BatchFailed { batch, replica, retried, exhausted } => println!(
                "{t:>7} µs  FAILED    batch {batch} on replica {replica}: \
                 {retried} retrying, {exhausted} exhausted"
            ),
            EventKind::FaultInjected { replica } => {
                println!("{t:>7} µs  FAULT     stuck cells hit replica {replica}")
            }
            EventKind::Rejected { request, error } => {
                println!("{t:>7} µs  rejected  request {request}: {error}")
            }
            EventKind::HealthScan { replica, worst_score, pulled } => println!(
                "{t:>7} µs  scan      replica {replica}: worst probe RE {worst_score:.3} -> {}",
                if *pulled { "PULLED from rotation" } else { "healthy" }
            ),
            EventKind::HealStart { replica } => {
                println!("{t:>7} µs  heal      replica {replica} starts self_heal")
            }
            EventKind::HealDone { replica, moves, fenced } => println!(
                "{t:>7} µs  healed    replica {replica} rejoins: \
                 {moves} group(s) remapped, {fenced} fenced"
            ),
            EventKind::DriftRefresh { replica, t_read_s } => println!(
                "{t:>7} µs  drift     replica {replica} reprogrammed at age {t_read_s:.3} s"
            ),
        }
    }

    println!("\n=== outcome ===\n");
    let done = report.completed();
    let retries = report.total_retries();
    println!("requests     : {done}/{} completed, {retries} retry dispatches", workload.len());
    println!(
        "latency      : p50 {} µs, p99 {} µs, {:.0} images/sec",
        report.percentile_latency_us(0.50).unwrap_or(0),
        report.percentile_latency_us(0.99).unwrap_or(0),
        report.images_per_sec()
    );
    for h in &report.heals {
        println!(
            "heal record  : replica {} [{}..{} µs], {} move(s), {} fenced, {} verify retries",
            h.replica, h.started_us, h.finished_us, h.moves, h.fenced, h.verify_retries
        );
    }
    let failed_over = report
        .outcomes
        .iter()
        .filter(|o| matches!(o, Outcome::Done(c) if c.attempts > 1))
        .count();
    println!("failover     : {failed_over} request(s) completed on a retry after the fault");
    assert_eq!(done, workload.len(), "the pool must not lose requests");
    Ok(())
}

//! Chip-level mapping walkthrough: compile LeNet-5 onto a small tiled
//! chip, print the placement/utilization tables, and run batched
//! inference through the mapped runtime.
//!
//! Run: `cargo run --release --example chip_mapping`

use memintelli::arch::ChipSpec;
use memintelli::data::mnist_like;
use memintelli::dpe::{DotProductEngine, DpeConfig, SliceMethod, SliceSpec};
use memintelli::nn::models::lenet5;
use memintelli::nn::train::make_batch;
use memintelli::nn::HwSpec;

fn main() {
    let seed = 7;
    let hw = HwSpec::uniform(
        DotProductEngine::new(DpeConfig::default(), seed),
        SliceMethod::int(SliceSpec::int8()),
    );

    // LeNet-5 with every matmul layer on INT8 hardware. The model's
    // weight block grids demand `mapped_planes()` physical arrays.
    let model = lenet5(Some(hw), seed);
    let planes = model.mapped_planes();
    println!("LeNet-5 INT8 demands {planes} physical 64x64 arrays\n");

    // A small chip: 4 tiles of 24 arrays. int8 block groups are 4 digit
    // planes, and a group never straddles tiles, so layers spill across
    // tile boundaries as the allocator fills the chip.
    let chip = ChipSpec::new(4, 24, (64, 64));
    let mapped = model.compile(&chip).expect("lenet5 fits a 4x24 chip");

    // Placement & utilization report, plus the per-layer summary with the
    // arrays/tiles columns.
    println!("{}", mapped.placement().report());
    println!("{}", mapped.summary(vec![1, 1, 28, 28]));

    // Batched inference through the mapped runtime: micro-batches run in
    // parallel, results are bit-identical for every micro-batch size.
    let data = mnist_like::load(32, seed);
    let idx: Vec<usize> = (0..32).collect();
    let (x, labels) = make_batch(&data, &idx);
    let logits = mapped.infer_batched(&x, 8);
    let correct = logits
        .to_matrix()
        .data
        .chunks(10)
        .zip(&labels)
        .filter(|(row, &want)| {
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap();
            argmax == want
        })
        .count();
    println!("batched inference on 32 untrained-model images: {correct}/32 correct (chance ~3)");

    // A chip that is too small produces a capacity report instead of a
    // mapping.
    let tiny = ChipSpec::new(1, 16, (64, 64));
    let model = lenet5(
        Some(HwSpec::uniform(
            DotProductEngine::new(DpeConfig::default(), seed),
            SliceMethod::int(SliceSpec::int8()),
        )),
        seed,
    );
    match model.compile(&tiny) {
        Ok(_) => unreachable!("lenet5 needs more than 16 arrays"),
        Err(e) => println!("\nexpected capacity error on a 1x16 chip:\n{e}"),
    }
}

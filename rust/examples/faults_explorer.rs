//! faults_explorer: stress a DPE matmul against the unified fault model.
//!
//! ```bash
//! cd rust && cargo run --release --example faults_explorer
//! ```
//!
//! Walks the `device::faults` knobs one at a time — stuck-at cells, dead
//! lines, retention at read time, per-column ADC error, floor rounding —
//! and prints the accuracy impact of each, then a small Monte-Carlo
//! yield curve vs stuck-at rate (the `fig_faults` experiment runs the
//! full grid: `cargo run --release -- fig_faults --quick`).

use memintelli::device::drift::DriftSpec;
use memintelli::device::faults::{AdcErrorSpec, AdcRounding, FaultSpec, NonIdealitySpec};
use memintelli::dpe::montecarlo::{run_fault_point, McConfig};
use memintelli::dpe::{DotProductEngine, DpeConfig, SliceMethod, SliceSpec};
use memintelli::tensor::Matrix;
use memintelli::util::rng::Pcg64;

fn main() {
    let mut rng = Pcg64::seeded(7);
    let a = Matrix::random_normal(64, 128, 0.0, 1.0, &mut rng);
    let b = Matrix::random_normal(128, 128, 0.0, 1.0, &mut rng);
    let ideal = a.matmul(&b);
    let med = SliceMethod::int(SliceSpec::int8());

    // 1. One knob at a time: how much error does each non-ideality add on
    //    top of the Table-2 baseline (cv = 5%, worst-case ADC)?
    let cases: Vec<(&str, NonIdealitySpec)> = vec![
        ("baseline (no faults)", NonIdealitySpec::none()),
        (
            "1% stuck-at cells",
            NonIdealitySpec { faults: FaultSpec::cells(0.01), ..NonIdealitySpec::none() },
        ),
        (
            "5% stuck-at cells",
            NonIdealitySpec { faults: FaultSpec::cells(0.05), ..NonIdealitySpec::none() },
        ),
        (
            "2% dead rows + cols",
            NonIdealitySpec {
                faults: FaultSpec { dead_row: 0.02, dead_col: 0.02, ..FaultSpec::none() },
                ..NonIdealitySpec::none()
            },
        ),
        (
            "retention, read at t=1e6 s",
            NonIdealitySpec {
                drift: DriftSpec { nu: 0.05, nu_std: 0.01, t0: 1.0 },
                t_read: 1e6,
                ..NonIdealitySpec::none()
            },
        ),
        (
            "ADC offset 0.5 LSB + gain 2%",
            NonIdealitySpec {
                adc: AdcErrorSpec { gain_std: 0.02, offset_std_lsb: 0.5, rounding: AdcRounding::Round },
                ..NonIdealitySpec::none()
            },
        ),
        (
            "ADC floor rounding",
            NonIdealitySpec {
                adc: AdcErrorSpec { rounding: AdcRounding::Floor, ..AdcErrorSpec::none() },
                ..NonIdealitySpec::none()
            },
        ),
    ];
    println!("INT8 128x128 matmul, 64x64 arrays, cv = 5% — relative error per injection:\n");
    for (name, ni) in cases {
        let engine =
            DotProductEngine::new(DpeConfig { nonideal: ni, ..DpeConfig::default() }, 42);
        let w = engine.prepare_weights(&b, &med, 0);
        let re = engine.matmul_prepared(&a, &w, &med, 0).relative_error(&ideal);
        println!("  {name:<30} RE = {re:.4}");
    }

    // 2. Yield vs stuck-at rate: the fraction of independently programmed
    //    array instances whose error stays within a 10% budget.
    println!("\nMonte-Carlo yield @ RE <= 0.1 (20 programming cycles, 64x64 operands):\n");
    let mc = McConfig { size: 64, cycles: 20, ..McConfig::default() };
    for rate in [0.0, 0.005, 0.01, 0.02, 0.05, 0.1] {
        let ni = NonIdealitySpec { faults: FaultSpec::cells(rate), ..NonIdealitySpec::none() };
        let p = run_fault_point(&mc, 8, 0.05, &ni, 0.1);
        let bar = "#".repeat((p.yield_frac * 30.0).round() as usize);
        println!(
            "  rate {rate:<6} RE mean {:.4}  yield {:>5.1}% {bar}",
            p.re_mean,
            p.yield_frac * 100.0
        );
    }
    println!("\nFull grid (rate x cv x bits, dead lines, retention, ADC):");
    println!("  cargo run --release -- fig_faults --quick");
}

//! Integration tests for the unified fault-injection subsystem: engine
//! behavior through the public API (the fused-vs-oracle bit-identity under
//! injection lives in `dpe::engine`'s unit tests, where the `#[cfg(test)]`
//! reference oracle is visible), Monte-Carlo determinism across runs and
//! thread counts, and end-to-end sanity of the yield experiment.

use memintelli::device::drift::DriftSpec;
use memintelli::device::faults::{AdcErrorSpec, AdcRounding, FaultSpec, NonIdealitySpec};
use memintelli::dpe::montecarlo::{run_fault_point, McConfig};
use memintelli::dpe::{DotProductEngine, DpeConfig, SliceMethod, SliceSpec};
use memintelli::tensor::Matrix;
use memintelli::util::rng::Pcg64;

fn rand_mat(m: usize, n: usize, seed: u64) -> Matrix {
    let mut rng = Pcg64::seeded(seed);
    Matrix::random_uniform(m, n, -1.0, 1.0, &mut rng)
}

fn engine_with(ni: NonIdealitySpec, seed: u64) -> DotProductEngine {
    DotProductEngine::new(DpeConfig { nonideal: ni, ..DpeConfig::default() }, seed)
}

#[test]
fn zero_rate_spec_is_bit_identical_to_default_engine() {
    // An all-off NonIdealitySpec must not perturb a single bit of the
    // default engine's output even when its injection seed differs — a
    // broken gate (fault RNG consulted, ADC chain sampled) would let the
    // differing seed change the result.
    let a = rand_mat(7, 100, 1);
    let b = rand_mat(100, 50, 2);
    let med = SliceMethod::int(SliceSpec::int8());
    let base = DotProductEngine::new(DpeConfig::default(), 11);
    let explicit = engine_with(
        NonIdealitySpec { seed: 0x5EED_F00D, ..NonIdealitySpec::none() },
        11,
    );
    let wb = base.prepare_weights(&b, &med, 3);
    let we = explicit.prepare_weights(&b, &med, 3);
    assert_eq!(
        base.matmul_prepared(&a, &wb, &med, 0).data,
        explicit.matmul_prepared(&a, &we, &med, 0).data
    );
}

#[test]
fn each_injection_class_changes_results_deterministically() {
    let a = rand_mat(8, 64, 3);
    let b = rand_mat(64, 64, 4);
    let med = SliceMethod::int(SliceSpec::int8());
    let clean = engine_with(NonIdealitySpec::none(), 7);
    let w_clean = clean.prepare_weights(&b, &med, 0);
    let y_clean = clean.matmul_prepared(&a, &w_clean, &med, 0);
    let variants = [
        NonIdealitySpec { faults: FaultSpec::cells(0.05), ..NonIdealitySpec::none() },
        NonIdealitySpec {
            drift: DriftSpec { nu: 0.08, nu_std: 0.01, t0: 1.0 },
            t_read: 1e4,
            ..NonIdealitySpec::none()
        },
        NonIdealitySpec {
            adc: AdcErrorSpec { gain_std: 0.03, offset_std_lsb: 0.5, rounding: AdcRounding::Round },
            ..NonIdealitySpec::none()
        },
        NonIdealitySpec {
            adc: AdcErrorSpec { rounding: AdcRounding::Floor, ..AdcErrorSpec::none() },
            ..NonIdealitySpec::none()
        },
    ];
    for (i, ni) in variants.into_iter().enumerate() {
        let e1 = engine_with(ni.clone(), 7);
        let e2 = engine_with(ni, 7);
        let w1 = e1.prepare_weights(&b, &med, 0);
        let w2 = e2.prepare_weights(&b, &med, 0);
        let y1 = e1.matmul_prepared(&a, &w1, &med, 0);
        let y2 = e2.matmul_prepared(&a, &w2, &med, 0);
        // Injection changes the result vs clean…
        assert_ne!(y1.data, y_clean.data, "variant {i} had no effect");
        // …and is fully reproducible for the same seeds.
        assert_eq!(y1.data, y2.data, "variant {i} is not deterministic");
        assert!(y1.data.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn retention_at_read_time_degrades_accuracy_monotonically() {
    let a = rand_mat(8, 96, 5);
    let b = rand_mat(96, 48, 6);
    let med = SliceMethod::int(SliceSpec::int8());
    let ideal = a.matmul(&b);
    let re_at = |t_read: f64| {
        let ni = NonIdealitySpec {
            drift: DriftSpec { nu: 0.1, nu_std: 0.0, t0: 1.0 },
            t_read,
            ..NonIdealitySpec::none()
        };
        let e = engine_with(ni, 13);
        let w = e.prepare_weights(&b, &med, 0);
        e.matmul_prepared(&a, &w, &med, 0).relative_error(&ideal)
    };
    let re_fresh = re_at(0.0);
    let re_old = re_at(1e6);
    assert!(
        re_old > re_fresh,
        "6 decades of retention loss must degrade accuracy: {re_old} vs {re_fresh}"
    );
}

#[test]
fn montecarlo_same_seed_is_deterministic_across_runs() {
    // The thread-count half of this invariant lives in
    // tests/mc_determinism.rs, a single-test binary, because it must
    // mutate the process-global MEMINTELLI_THREADS env var.
    let cfg = McConfig { size: 24, cycles: 6, seed: 424_242, ..McConfig::default() };
    let ni = NonIdealitySpec {
        faults: FaultSpec { sa0: 0.02, sa1: 0.02, dead_row: 0.01, dead_col: 0.01 },
        adc: AdcErrorSpec { gain_std: 0.02, offset_std_lsb: 0.3, rounding: AdcRounding::Floor },
        ..NonIdealitySpec::none()
    };
    let p1 = run_fault_point(&cfg, 8, 0.05, &ni, 0.1);
    let p2 = run_fault_point(&cfg, 8, 0.05, &ni, 0.1);
    assert_eq!(p1.re_mean.to_bits(), p2.re_mean.to_bits(), "re_mean differs");
    assert_eq!(p1.re_std.to_bits(), p2.re_std.to_bits(), "re_std differs");
    assert_eq!(p1.re_max.to_bits(), p2.re_max.to_bits(), "re_max differs");
    assert_eq!(p1.yield_frac.to_bits(), p2.yield_frac.to_bits(), "yield differs");
}

#[test]
fn yield_collapses_under_heavy_faults() {
    let cfg = McConfig { size: 32, cycles: 8, seed: 99, ..McConfig::default() };
    let clean = run_fault_point(&cfg, 8, 0.02, &NonIdealitySpec::none(), 0.1);
    let heavy = run_fault_point(
        &cfg,
        8,
        0.02,
        &NonIdealitySpec { faults: FaultSpec::cells(0.25), ..NonIdealitySpec::none() },
        0.1,
    );
    assert!(heavy.re_mean > clean.re_mean, "{} !> {}", heavy.re_mean, clean.re_mean);
    assert!(heavy.yield_frac <= clean.yield_frac);
    assert_eq!(heavy.fault_rate, 0.25);
}

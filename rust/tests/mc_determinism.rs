//! Thread-count determinism (Monte-Carlo stats AND chip-mapped batched
//! inference), isolated in its own test binary: proving that the same
//! seed yields bit-identical results at any worker count requires
//! mutating the process-global `MEMINTELLI_THREADS` env var, and
//! concurrent `setenv`/`getenv` from parallel sibling tests would be
//! undefined behavior on glibc. As the only test in this binary, every
//! `set_var` here happens while no other thread is running: the `par_map`
//! workers spawned inside `run_fault_point` / `infer_batched` are scoped,
//! so they start after the write completes and join before the next one.

use memintelli::arch::{
    uniform_fleet, ChipSpec, FaultEvent, ReplicaSpec, Request, ServingRuntime, ServingSpec,
};
use memintelli::data::Dataset;
use memintelli::device::faults::{AdcErrorSpec, AdcRounding, FaultSpec, NonIdealitySpec};
use memintelli::dpe::montecarlo::{run_fault_point, FaultPoint, McConfig};
use memintelli::dpe::{DotProductEngine, DpeConfig, RepairSpec, SliceMethod, SliceSpec};
use memintelli::nn::models::mlp;
use memintelli::nn::train::{train_fast, TrainConfig};
use memintelli::nn::HwSpec;
use memintelli::tensor::Tensor;

fn assert_points_identical(p: &FaultPoint, q: &FaultPoint) {
    assert_eq!(p.re_mean.to_bits(), q.re_mean.to_bits(), "re_mean differs");
    assert_eq!(p.re_std.to_bits(), q.re_std.to_bits(), "re_std differs");
    assert_eq!(p.re_max.to_bits(), q.re_max.to_bits(), "re_max differs");
    assert_eq!(p.yield_frac.to_bits(), q.yield_frac.to_bits(), "yield differs");
}

#[test]
fn montecarlo_stats_identical_across_thread_counts() {
    let cfg = McConfig { size: 24, cycles: 6, seed: 424_242, ..McConfig::default() };
    let ni = NonIdealitySpec {
        faults: FaultSpec { sa0: 0.02, sa1: 0.02, dead_row: 0.01, dead_col: 0.01 },
        adc: AdcErrorSpec { gain_std: 0.02, offset_std_lsb: 0.3, rounding: AdcRounding::Floor },
        ..NonIdealitySpec::none()
    };
    let prev = std::env::var("MEMINTELLI_THREADS").ok();
    // Per-cycle state derives only from the cycle index, so the stats
    // must not depend on how par_map schedules cycles across workers.
    let mut points = Vec::new();
    let mut infer_outputs: Vec<Vec<f64>> = Vec::new();
    let mut serve_reports = Vec::new();
    let mut train_runs: Vec<(Vec<u64>, Vec<f64>)> = Vec::new();
    let mut sharded_outputs: Vec<Vec<Vec<u64>>> = Vec::new();
    let x = Tensor::from_vec(&[6, 48], (0..288).map(|i| ((i % 13) as f64) / 6.5 - 1.0).collect());
    for workers in ["1", "2", "7"] {
        std::env::set_var("MEMINTELLI_THREADS", workers);
        points.push(run_fault_point(&cfg, 8, 0.05, &ni, 0.1));
        // Chip-mapped micro-batched inference must be thread-count
        // invariant too: programming streams come from the placement and
        // micro-batch results from index-derived chunks.
        let hw = HwSpec::uniform(
            DotProductEngine::new(DpeConfig::default(), 11),
            SliceMethod::int(SliceSpec::int8()),
        );
        let model = mlp(48, 12, 4, Some(hw), 5);
        let planes = model.mapped_planes();
        let mapped = model.compile(&ChipSpec::single_tile(planes, (64, 64))).unwrap();
        infer_outputs.push(mapped.infer_batched(&x, 2).data);
        // The serving runtime's event loop dispatches micro-batches through
        // the same par_map inference path; the whole ServeReport (outcomes,
        // batch records, event log) must also be worker-count invariant,
        // including the retry path exercised by a mid-run fault.
        let factory = |ri: usize, _cond: &ReplicaSpec| {
            let hw = HwSpec::uniform(
                DotProductEngine::new(DpeConfig::default(), 300 + ri as u64),
                SliceMethod::int(SliceSpec::int8()),
            );
            let m = mlp(48, 12, 4, Some(hw), 5);
            let planes = m.mapped_planes();
            m.compile(&ChipSpec::single_tile(planes, (64, 64)))
        };
        let spec = ServingSpec { replicas: 2, max_batch: 3, ..ServingSpec::default() };
        let mut rt =
            ServingRuntime::new(spec, RepairSpec::none(), vec![48], Box::new(factory)).unwrap();
        let workload: Vec<Request> = (0..8)
            .map(|i| Request {
                arrive_us: i as u64 * 100,
                sample: (0..48).map(|k| (((i * 5 + k) % 13) as f64) / 6.5 - 1.0).collect(),
            })
            .collect();
        let faults = [FaultEvent { at_us: 250, replica: 0 }];
        serve_reports.push(rt.run(&workload, &faults).unwrap());
        // Fast hardware-in-the-loop training must be worker-count
        // invariant too: template-delta redraws key off per-slot RNG
        // streams and the batch index, never off which worker runs a
        // band, so the loss curve and the trained model's outputs are
        // bit-identical at any thread count.
        let data = Dataset {
            sample_shape: vec![48],
            features: (0..48 * 40).map(|i| (((i * 7) % 23) as f64) / 11.5 - 1.0).collect(),
            labels: (0..40usize).map(|i| i % 4).collect(),
            num_classes: 4,
        };
        let hw = HwSpec::uniform(
            DotProductEngine::new(DpeConfig::default(), 17),
            SliceMethod::int(SliceSpec::int8()),
        );
        let mut model = mlp(48, 12, 4, Some(hw), 5);
        let tcfg = TrainConfig {
            steps: 4,
            batch_size: 8,
            lr: 0.05,
            log_every: 1,
            seed: 99,
            ..TrainConfig::default()
        };
        let rep = train_fast(&mut model, &data, &tcfg);
        let curve: Vec<u64> = rep.logs.iter().map(|l| l.loss.to_bits()).collect();
        let trained_y = model.forward(&x, false).data;
        train_runs.push((curve, trained_y));
        // Sharded pipeline inference must be thread-count invariant too,
        // and — on noise-free engines — fleet-size invariant: stages chain
        // the full micro-batch, so splitting layers across chips is purely
        // spatial and every fleet size reproduces the single-chip bits.
        let ideal = || {
            HwSpec::uniform(DotProductEngine::ideal((64, 64)), SliceMethod::int(SliceSpec::int8()))
        };
        let m0 = mlp(48, 12, 4, Some(ideal()), 5);
        let planes = m0.mapped_planes();
        let single = m0.compile(&ChipSpec::single_tile(planes, (64, 64))).unwrap();
        let y_single: Vec<u64> =
            single.infer_batched(&x, 2).data.iter().map(|v| v.to_bits()).collect();
        let mut sharded_bits: Vec<Vec<u64>> = Vec::new();
        for chips in [1usize, 2] {
            let sharded = mlp(48, 12, 4, Some(ideal()), 5)
                .compile_sharded(&uniform_fleet(chips, planes / chips, (64, 64)))
                .unwrap();
            assert_eq!(sharded.stage_count(), chips, "fleet of {chips} chips, stage count");
            let y: Vec<u64> =
                sharded.infer_batched(&x, 2).data.iter().map(|v| v.to_bits()).collect();
            assert_eq!(y, y_single, "sharded ({chips} chips) != single-chip bits");
            sharded_bits.push(y);
        }
        sharded_outputs.push(sharded_bits);
    }
    match prev {
        Some(v) => std::env::set_var("MEMINTELLI_THREADS", v),
        None => std::env::remove_var("MEMINTELLI_THREADS"),
    }
    assert_points_identical(&points[0], &points[1]);
    assert_points_identical(&points[0], &points[2]);
    assert_eq!(infer_outputs[0], infer_outputs[1], "mapped inference differs at 2 workers");
    assert_eq!(infer_outputs[0], infer_outputs[2], "mapped inference differs at 7 workers");
    assert_eq!(serve_reports[0], serve_reports[1], "serving report differs at 2 workers");
    assert_eq!(serve_reports[0], serve_reports[2], "serving report differs at 7 workers");
    assert_eq!(train_runs[0], train_runs[1], "train_fast differs at 2 workers");
    assert_eq!(train_runs[0], train_runs[2], "train_fast differs at 7 workers");
    assert_eq!(sharded_outputs[0], sharded_outputs[1], "sharded inference differs at 2 workers");
    assert_eq!(sharded_outputs[0], sharded_outputs[2], "sharded inference differs at 7 workers");
}

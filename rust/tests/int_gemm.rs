//! Integration tests for the exact integer-domain stacked GEMM: the int
//! kernel must engage automatically on noise-free engines through the
//! public API (prepare → matmul, compiled chip inference) and stay
//! bit-identical to the f64 path and the reference oracle, while noisy
//! engines must keep the analog f64 path without any opt-in.

use memintelli::arch::ChipSpec;
use memintelli::dpe::{DotProductEngine, DpeConfig, SliceMethod, SliceSpec};
use memintelli::nn::layers::{Flatten, LinearMem, Relu};
use memintelli::nn::{HwSpec, Sequential};
use memintelli::tensor::{Matrix, Tensor};
use memintelli::util::rng::Pcg64;

#[test]
fn noise_free_engine_engages_int_kernel_and_matches_oracle() {
    // Digits program verbatim on a noise-free engine, so every block must
    // grow a byte mirror and the fused path must dispatch to the integer
    // kernel — with results bit-identical to the shift-add oracle.
    let med = SliceMethod::int(SliceSpec::int8());
    let engine = DotProductEngine::ideal((64, 64));
    let mut rng = Pcg64::seeded(71);
    for &(m, k, n) in &[(1usize, 200usize, 130usize), (33, 100, 70), (300, 64, 64)] {
        let a = Matrix::random_normal(m, k, 0.0, 1.0, &mut rng);
        let b = Matrix::random_normal(k, n, 0.0, 1.0, &mut rng);
        let w = engine.prepare_weights(&b, &med, 0);
        assert_eq!(
            w.int_panel_blocks(),
            w.num_blocks(),
            "noise-free {m}x{k}x{n}: every block must carry a byte mirror"
        );
        let fused = engine.matmul_prepared(&a, &w, &med, 0);
        let oracle = engine.matmul_prepared_reference(&a, &w, &med, 0);
        assert_eq!(fused.data, oracle.data, "int kernel vs oracle at {m}x{k}x{n}");
    }
}

#[test]
fn noisy_engine_keeps_analog_kernel_and_matches_oracle() {
    // Lognormal programming noise makes conductances non-integer, so no
    // block may claim the byte mirror; the f64 path still matches the
    // oracle bit for bit.
    let med = SliceMethod::int(SliceSpec::int8());
    let engine = DotProductEngine::new(DpeConfig::default(), 5);
    let mut rng = Pcg64::seeded(72);
    let a = Matrix::random_normal(17, 130, 0.0, 1.0, &mut rng);
    let b = Matrix::random_normal(130, 96, 0.0, 1.0, &mut rng);
    let w = engine.prepare_weights(&b, &med, 0);
    assert_eq!(w.int_panel_blocks(), 0, "analog programming must not mirror to bytes");
    let fused = engine.matmul_prepared(&a, &w, &med, 0);
    let oracle = engine.matmul_prepared_reference(&a, &w, &med, 0);
    assert_eq!(fused.data, oracle.data);
}

#[test]
fn int_kernel_preserves_fp32_accuracy_through_public_matmul() {
    // The one-shot matmul entry point on an ideal engine rides the integer
    // kernel (fp32 slicing has ≤ 4-bit digits → i32 accumulators); the
    // sliced result must still track the exact product at fp32-level RE.
    let med = SliceMethod::fp(SliceSpec::fp32());
    let engine = DotProductEngine::ideal((64, 64));
    let mut rng = Pcg64::seeded(73);
    let a = Matrix::random_normal(24, 96, 0.0, 1.0, &mut rng);
    let b = Matrix::random_normal(96, 80, 0.0, 1.0, &mut rng);
    let re = engine.matmul(&a, &b, &med, &med).relative_error(&a.matmul(&b));
    assert!(re < 1e-5, "fp32 slicing on the int kernel drifted: RE {re}");
}

/// A small FC model on noise-free hardware so the compiled chip runtime
/// exercises the integer kernel in every LinearMem forward.
fn noise_free_model(seed: u64) -> Sequential {
    let hw = HwSpec::uniform(
        DotProductEngine::ideal((64, 64)),
        SliceMethod::int(SliceSpec::int8()),
    );
    let mut rng = Pcg64::new(seed, 0xA11C);
    Sequential::new(vec![
        Box::new(Flatten::new()),
        Box::new(LinearMem::new(64, 48, Some(hw.clone()), &mut rng)),
        Box::new(Relu::new()),
        Box::new(LinearMem::new(48, 10, Some(hw), &mut rng)),
    ])
}

fn feature_batch(n: usize) -> Tensor {
    Tensor::from_vec(
        &[n, 64],
        (0..n * 64).map(|i| ((i * 13 % 19) as f64) / 9.0 - 1.0).collect(),
    )
}

#[test]
fn mapped_inference_on_int_kernel_bit_identical_across_micro_batches() {
    // The chip-mapped batched runtime inherits the integer kernel through
    // the same value-driven dispatch; it must stay invisible — unmapped
    // forward, whole-batch infer, and every micro-batch split agree bit
    // for bit.
    let mut unmapped = noise_free_model(9);
    let mapped = {
        let m = noise_free_model(9);
        let chip = ChipSpec::single_tile(m.mapped_planes(), (64, 64));
        m.compile(&chip).expect("single-tile compile")
    };
    let x = feature_batch(7);
    let y_seq = unmapped.forward(&x, false);
    let full = mapped.infer(&x);
    assert_eq!(y_seq.data, full.data, "mapped vs unmapped on noise-free hardware");
    for mb in [1usize, 2, 3, 7, 64] {
        assert_eq!(mapped.infer_batched(&x, mb).data, full.data, "micro_batch={mb}");
    }
}

//! Integration tests across modules: config → engine → layers → training →
//! evaluation, backend cross-validation, and experiment registry smoke.

use memintelli::apps::kmeans;
use memintelli::coordinator::SimConfig;
use memintelli::data::{iris, mnist_like};
use memintelli::dpe::{DotProductEngine, SliceMethod, SliceSpec};
use memintelli::nn::models::{lenet5, mlp};
use memintelli::nn::train::{evaluate, train, TrainConfig};
use memintelli::nn::HwSpec;
use memintelli::tensor::Matrix;
use memintelli::util::config::Doc;
use memintelli::util::rng::Pcg64;

#[test]
fn config_to_engine_to_matmul() {
    // A config file drives an engine that multiplies correctly.
    let doc = Doc::parse(
        "[engine]\nvar = 0.0\nnoise_free = true\narray_size = [32, 32]\n[run]\nseed = 5\nmethod = \"fp32\"\n",
    )
    .unwrap();
    let cfg = SimConfig::from_doc(&doc).unwrap();
    let engine = cfg.engine();
    let method = SliceMethod::parse(&cfg.method).unwrap();
    let mut rng = Pcg64::seeded(5);
    let a = Matrix::random_normal(48, 40, 0.0, 1.0, &mut rng);
    let b = Matrix::random_normal(40, 56, 0.0, 1.0, &mut rng);
    let re = engine.matmul(&a, &b, &method, &method).relative_error(&a.matmul(&b));
    assert!(re < 1e-5, "config-driven fp32 engine RE {re}");
}

#[test]
fn hardware_mlp_trains_on_digits() {
    // The full training stack on hardware layers: data gen → slicing →
    // noisy DPE forward → straight-through backward → SGD → update_weight.
    let data = mnist_like::load(320, 11);
    let (train_set, test_set) = data.split(256);
    let hw = HwSpec::uniform(
        DotProductEngine::new(Default::default(), 11),
        SliceMethod::int(SliceSpec::int8()),
    );
    let mut model = mlp(784, 32, 10, Some(hw), 11);
    let cfg = TrainConfig { steps: 50, batch_size: 32, lr: 0.1, log_every: 10, seed: 11, ..Default::default() };
    let logs = train(&mut model, &train_set, &cfg);
    assert!(
        logs.last().unwrap().loss < logs.first().unwrap().loss * 0.8,
        "hardware training must reduce loss: {:?} -> {:?}",
        logs.first().unwrap().loss,
        logs.last().unwrap().loss
    );
    let acc = evaluate(&mut model, &test_set, 32, 64);
    assert!(acc > 0.3, "hardware MLP test accuracy {acc}");
}

#[test]
fn lenet_digital_vs_hardware_ideal_agree() {
    // Ideal (noise-free) hardware LeNet must track the digital model.
    let hw = HwSpec::uniform(
        DotProductEngine::ideal((64, 64)),
        SliceMethod::fp(SliceSpec::fp32()),
    );
    let mut m_hw = lenet5(Some(hw), 3);
    let mut m_dig = lenet5(None, 3);
    let data = mnist_like::load(8, 3);
    let idx: Vec<usize> = (0..8).collect();
    let (x, _) = memintelli::nn::train::make_batch(&data, &idx);
    let y_hw = m_hw.forward(&x, false).to_matrix();
    let y_dig = m_dig.forward(&x, false).to_matrix();
    assert!(y_hw.relative_error(&y_dig) < 0.01);
}

#[test]
fn state_transfer_preserves_predictions() {
    // load_state_from moves parameters AND buffers between bindings.
    let data = mnist_like::load(64, 13);
    let mut digital = mlp(784, 16, 10, None, 13);
    let cfg = TrainConfig { steps: 10, batch_size: 16, lr: 0.05, log_every: 5, seed: 13, ..Default::default() };
    let _ = train(&mut digital, &data, &cfg);
    let hw = HwSpec::uniform(
        DotProductEngine::ideal((64, 64)),
        SliceMethod::fp(SliceSpec::fp32()),
    );
    let mut hw_model = mlp(784, 16, 10, Some(hw), 99); // different init seed
    hw_model.load_state_from(&digital); // donor is read-only
    hw_model.update_weight();
    let idx: Vec<usize> = (0..16).collect();
    let (x, _) = memintelli::nn::train::make_batch(&data, &idx);
    let y_d = digital.forward(&x, false).to_matrix();
    let y_h = hw_model.forward(&x, false).to_matrix();
    assert!(y_h.relative_error(&y_d) < 0.01, "transfer RE {}", y_h.relative_error(&y_d));
}

#[test]
fn caching_split_end_to_end_bit_identical() {
    // The WeightTemplate + PreparedInputs caching API must reproduce the
    // uncached prepare_weights + matmul_prepared path bit for bit across
    // reprogramming tags — the contract every cached hot loop (Monte-
    // Carlo, k-means, CWT, layer input caches) relies on.
    let engine = DotProductEngine::new(Default::default(), 9);
    let med = SliceMethod::int(SliceSpec::int8());
    let mut rng = Pcg64::seeded(31);
    let a = Matrix::random_normal(16, 100, 0.0, 1.0, &mut rng);
    let b = Matrix::random_normal(100, 48, 0.0, 1.0, &mut rng);
    let template = engine.weight_template(&b, &med);
    let inputs = engine.prepare_inputs(&a, &med);
    for tag in 0..3u64 {
        let cached = engine.matmul_prepared_inputs(&inputs, &template.program(&engine, tag), tag);
        let w = engine.prepare_weights(&b, &med, tag);
        let uncached = engine.matmul_prepared(&a, &w, &med, tag);
        assert_eq!(cached.data, uncached.data, "tag {tag}");
    }
}

#[test]
fn kmeans_pipeline_from_dataset() {
    let ds = iris::load(50, 21);
    let mut x = Matrix::from_vec(ds.len(), 4, ds.features.clone());
    kmeans::min_max_normalize(&mut x);
    let res = kmeans::kmeans(&x, &kmeans::KmeansConfig::default(), None);
    let acc = kmeans::clustering_accuracy(&res.assignments, &ds.labels, 3);
    assert!(acc > 0.8, "end-to-end clustering accuracy {acc}");
}

#[test]
fn xla_and_native_backends_agree_when_artifacts_present() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("dpe_mm_128x128x128_int8_ideal.hlo.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = memintelli::runtime::Runtime::cpu(&dir).unwrap();
    let xd = memintelli::runtime::XlaDpe::new(rt);
    let mut rng = Pcg64::seeded(31);
    let a = Matrix::random_normal(128, 128, 0.0, 1.0, &mut rng);
    let b = Matrix::random_normal(128, 128, 0.0, 1.0, &mut rng);
    let xla = xd.matmul(&a, &b, "int8", true, 0).unwrap();
    let native = DotProductEngine::ideal((64, 64)).matmul(
        &a,
        &b,
        &SliceMethod::int(SliceSpec::int8()),
        &SliceMethod::int(SliceSpec::int8()),
    );
    assert!(xla.relative_error(&native) < 0.01);
}

#[test]
fn mixed_precision_model_runs_and_trains() {
    // Fig 9: per-layer engines — first layer INT8 hardware, second digital.
    let mut rng = Pcg64::new(17, 0);
    let hw = HwSpec::uniform(
        DotProductEngine::new(Default::default(), 17),
        SliceMethod::int(SliceSpec::int8()),
    );
    let mut model = memintelli::nn::Sequential::new(vec![
        Box::new(memintelli::nn::layers::Flatten::new()),
        Box::new(memintelli::nn::layers::LinearMem::new(784, 24, Some(hw), &mut rng)),
        Box::new(memintelli::nn::layers::Relu::new()),
        Box::new(memintelli::nn::layers::LinearMem::new(24, 10, None, &mut rng)),
    ]);
    let data = mnist_like::load(128, 17);
    let cfg = TrainConfig { steps: 20, batch_size: 16, lr: 0.05, log_every: 5, seed: 17, ..Default::default() };
    let logs = train(&mut model, &data, &cfg);
    assert!(logs.last().unwrap().loss.is_finite());
    assert!(logs.last().unwrap().loss < logs.first().unwrap().loss);
}

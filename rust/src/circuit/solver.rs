//! Nodal solvers for the crossbar circuit: exact banded-LU and the paper's
//! fast cross-iteration (alternating tridiagonal line relaxation).

use super::banded::{solve_tridiagonal, Banded};
use super::CrossbarCircuit;
use crate::tensor::Matrix;
use crate::util::parallel::par_map;

/// Solved node voltages and derived outputs.
#[derive(Debug, Clone)]
pub struct CircuitSolution {
    /// Word-line node voltages, `rows × cols`.
    pub v_word: Matrix,
    /// Bit-line node voltages, `rows × cols`.
    pub v_bit: Matrix,
    /// Column output currents into the TIAs (A).
    pub i_out: Vec<f64>,
}

/// Convergence log of the cross-iteration solver.
#[derive(Debug, Clone)]
pub struct IterStats {
    pub iterations: usize,
    /// Max |ΔV| per sweep (monitoring Fig 10(d)'s error-vs-iteration).
    pub deltas: Vec<f64>,
    pub converged: bool,
}

impl CrossbarCircuit {
    /// Output currents as the sum of device currents into each bit line.
    fn currents_from(&self, v_word: &Matrix, v_bit: &Matrix) -> Vec<f64> {
        let (rows, cols) = (self.rows(), self.cols());
        let mut out = vec![0.0; cols];
        for i in 0..rows {
            let gw = self.g.row(i);
            let vw = v_word.row(i);
            let vb = v_bit.row(i);
            for j in 0..cols {
                out[j] += (vw[j] - vb[j]) * gw[j];
            }
        }
        out
    }

    /// Exact nodal solution via banded LU (the Fig 10 "LTspice" reference).
    ///
    /// Unknown ordering interleaves word/bit nodes per cell
    /// (`idx_w = 2(i·cols + j)`, `idx_b = idx_w + 1`), giving half-bandwidth
    /// `2·cols`. Cost O(rows·cols·cols²) — intended for arrays ≤ ~256 wide.
    pub fn solve_direct(&self, v_in: &[f64]) -> anyhow::Result<CircuitSolution> {
        let (rows, cols) = (self.rows(), self.cols());
        assert_eq!(v_in.len(), rows);
        if self.r_wire == 0.0 {
            return Ok(self.ideal_solution(v_in));
        }
        let gw = 1.0 / self.r_wire;
        let n = 2 * rows * cols;
        let bw = 2 * cols;
        let mut a = Banded::zeros(n, bw, bw);
        let mut b = vec![0.0; n];
        let idx_w = |i: usize, j: usize| 2 * (i * cols + j);
        let idx_b = |i: usize, j: usize| 2 * (i * cols + j) + 1;
        for i in 0..rows {
            for j in 0..cols {
                let g = self.g.at(i, j);
                let w = idx_w(i, j);
                let bidx = idx_b(i, j);
                // Word node: segments + device.
                let mut wdiag = g;
                if j == 0 {
                    // drive through source segment
                    wdiag += gw;
                    b[w] += gw * v_in[i];
                } else {
                    wdiag += gw;
                    a.add(w, idx_w(i, j - 1), -gw);
                }
                if j + 1 < cols {
                    wdiag += gw;
                    a.add(w, idx_w(i, j + 1), -gw);
                }
                a.add(w, w, wdiag);
                a.add(w, bidx, -g);
                // Bit node: segments + device.
                let mut bdiag = g;
                if i > 0 {
                    bdiag += gw;
                    a.add(bidx, idx_b(i - 1, j), -gw);
                }
                if i + 1 < rows {
                    bdiag += gw;
                    a.add(bidx, idx_b(i + 1, j), -gw);
                } else {
                    // terminated into TIA virtual ground
                    bdiag += gw;
                }
                a.add(bidx, bidx, bdiag);
                a.add(bidx, w, -g);
            }
        }
        a.lu_factor()?;
        let x = a.lu_solve(&b);
        let mut v_word = Matrix::zeros(rows, cols);
        let mut v_bit = Matrix::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                *v_word.at_mut(i, j) = x[idx_w(i, j)];
                *v_bit.at_mut(i, j) = x[idx_b(i, j)];
            }
        }
        let i_out = self.currents_from(&v_word, &v_bit);
        Ok(CircuitSolution { v_word, v_bit, i_out })
    }

    fn ideal_solution(&self, v_in: &[f64]) -> CircuitSolution {
        let (rows, cols) = (self.rows(), self.cols());
        let v_word = Matrix::from_fn(rows, cols, |i, _| v_in[i]);
        let v_bit = Matrix::zeros(rows, cols);
        let i_out = self.ideal_currents(v_in);
        CircuitSolution { v_word, v_bit, i_out }
    }

    /// The paper's cross-iteration solver: alternate between solving every
    /// word line (tridiagonal in `j`, bit-line voltages frozen) and every
    /// bit line (tridiagonal in `i`, word-line voltages frozen). Each line
    /// solve is exact (Thomas algorithm); sweeps repeat until the max node
    /// update falls below `tol` or `max_iter` sweeps.
    ///
    /// Lines are independent within a sweep, so they are solved in parallel.
    pub fn solve_cross_iteration(
        &self,
        v_in: &[f64],
        tol: f64,
        max_iter: usize,
    ) -> (CircuitSolution, IterStats) {
        let (rows, cols) = (self.rows(), self.cols());
        assert_eq!(v_in.len(), rows);
        if self.r_wire == 0.0 {
            let sol = self.ideal_solution(v_in);
            return (sol, IterStats { iterations: 0, deltas: vec![], converged: true });
        }
        let gw = 1.0 / self.r_wire;
        // Initial guess: ideal voltages.
        let mut v_word = Matrix::from_fn(rows, cols, |i, _| v_in[i]);
        let mut v_bit = Matrix::zeros(rows, cols);
        let mut deltas = Vec::new();
        let mut converged = false;
        for _sweep in 0..max_iter {
            // --- word-line sweep: for each row i solve tridiagonal in j.
            let new_rows: Vec<Vec<f64>> = par_map(rows, |i| {
                let mut lower = vec![0.0; cols];
                let mut diag = vec![0.0; cols];
                let mut upper = vec![0.0; cols];
                let mut rhs = vec![0.0; cols];
                for j in 0..cols {
                    let g = self.g.at(i, j);
                    let mut d = g;
                    if j == 0 {
                        d += gw;
                        rhs[j] += gw * v_in[i];
                    } else {
                        d += gw;
                        lower[j] = -gw;
                    }
                    if j + 1 < cols {
                        d += gw;
                        upper[j] = -gw;
                    }
                    rhs[j] += g * v_bit.at(i, j);
                    diag[j] = d;
                }
                solve_tridiagonal(&lower, &diag, &upper, &rhs)
            });
            let mut delta = 0.0f64;
            for (i, row) in new_rows.iter().enumerate() {
                for (j, &v) in row.iter().enumerate() {
                    delta = delta.max((v - v_word.at(i, j)).abs());
                    *v_word.at_mut(i, j) = v;
                }
            }
            // --- bit-line sweep: for each column j solve tridiagonal in i.
            let new_cols: Vec<Vec<f64>> = par_map(cols, |j| {
                let mut lower = vec![0.0; rows];
                let mut diag = vec![0.0; rows];
                let mut upper = vec![0.0; rows];
                let mut rhs = vec![0.0; rows];
                for i in 0..rows {
                    let g = self.g.at(i, j);
                    let mut d = g;
                    if i > 0 {
                        d += gw;
                        lower[i] = -gw;
                    }
                    if i + 1 < rows {
                        d += gw;
                        upper[i] = -gw;
                    } else {
                        d += gw; // ground termination
                    }
                    rhs[i] += g * v_word.at(i, j);
                    diag[i] = d;
                }
                solve_tridiagonal(&lower, &diag, &upper, &rhs)
            });
            for (j, col) in new_cols.iter().enumerate() {
                for (i, &v) in col.iter().enumerate() {
                    delta = delta.max((v - v_bit.at(i, j)).abs());
                    *v_bit.at_mut(i, j) = v;
                }
            }
            deltas.push(delta);
            if delta < tol {
                converged = true;
                break;
            }
        }
        let i_out = self.currents_from(&v_word, &v_bit);
        (
            CircuitSolution { v_word, v_bit, i_out },
            IterStats { iterations: deltas.len(), deltas, converged },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_xbar(rows: usize, cols: usize, r_wire: f64, seed: u64) -> CrossbarCircuit {
        let mut rng = Pcg64::seeded(seed);
        let g = Matrix::random_uniform(rows, cols, 1e-7, 1e-5, &mut rng);
        CrossbarCircuit::new(g, r_wire)
    }

    /// Dense reference: assemble the full nodal system and Gauss-eliminate.
    fn solve_dense_reference(xb: &CrossbarCircuit, v_in: &[f64]) -> Vec<f64> {
        let (rows, cols) = (xb.rows(), xb.cols());
        let gw = 1.0 / xb.r_wire;
        let n = 2 * rows * cols;
        let idx_w = |i: usize, j: usize| 2 * (i * cols + j);
        let idx_b = |i: usize, j: usize| 2 * (i * cols + j) + 1;
        let mut a = vec![vec![0.0f64; n]; n];
        let mut b = vec![0.0f64; n];
        for i in 0..rows {
            for j in 0..cols {
                let g = xb.g.at(i, j);
                let w = idx_w(i, j);
                let bb = idx_b(i, j);
                let mut wd = g;
                if j == 0 {
                    wd += gw;
                    b[w] += gw * v_in[i];
                } else {
                    wd += gw;
                    a[w][idx_w(i, j - 1)] -= gw;
                }
                if j + 1 < cols {
                    wd += gw;
                    a[w][idx_w(i, j + 1)] -= gw;
                }
                a[w][w] += wd;
                a[w][bb] -= g;
                let mut bd = g;
                if i > 0 {
                    bd += gw;
                    a[bb][idx_b(i - 1, j)] -= gw;
                }
                if i + 1 < rows {
                    bd += gw;
                    a[bb][idx_b(i + 1, j)] -= gw;
                } else {
                    bd += gw;
                }
                a[bb][bb] += bd;
                a[bb][w] -= g;
            }
        }
        // Gaussian elimination with partial pivoting.
        for k in 0..n {
            let piv = (k..n).max_by(|&p, &q| a[p][k].abs().total_cmp(&a[q][k].abs())).unwrap();
            a.swap(k, piv);
            b.swap(k, piv);
            let pk = a[k][k];
            for i in (k + 1)..n {
                let m = a[i][k] / pk;
                if m != 0.0 {
                    for j in k..n {
                        a[i][j] -= m * a[k][j];
                    }
                    b[i] -= m * b[k];
                }
            }
        }
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut acc = b[i];
            for j in (i + 1)..n {
                acc -= a[i][j] * x[j];
            }
            x[i] = acc / a[i][i];
        }
        // currents
        let mut out = vec![0.0; cols];
        for i in 0..rows {
            for j in 0..cols {
                out[j] += (x[idx_w(i, j)] - x[idx_b(i, j)]) * xb.g.at(i, j);
            }
        }
        out
    }

    #[test]
    fn direct_matches_dense_reference() {
        let xb = random_xbar(6, 5, 2.93, 41);
        let v: Vec<f64> = (0..6).map(|i| 0.05 + 0.01 * i as f64).collect();
        let direct = xb.solve_direct(&v).unwrap();
        let dense = solve_dense_reference(&xb, &v);
        for (a, b) in direct.i_out.iter().zip(&dense) {
            assert!((a - b).abs() / b.abs().max(1e-30) < 1e-8, "{a} vs {b}");
        }
    }

    #[test]
    fn cross_iteration_matches_direct() {
        for &(rows, cols) in &[(8usize, 8usize), (16, 12), (32, 32)] {
            let xb = random_xbar(rows, cols, 2.93, 42);
            let v: Vec<f64> = (0..rows).map(|i| 0.1 * ((i % 5) as f64 + 1.0) / 5.0).collect();
            let direct = xb.solve_direct(&v).unwrap();
            let (iter, stats) = xb.solve_cross_iteration(&v, 1e-12, 100);
            assert!(stats.converged, "not converged for {rows}x{cols}");
            for (a, b) in iter.i_out.iter().zip(&direct.i_out) {
                assert!(
                    (a - b).abs() / b.abs().max(1e-30) < 1e-6,
                    "{rows}x{cols}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn converges_within_20_iterations_at_1e3() {
        // Fig 10(d): error < 1e-3 within 20 iterations, even for large
        // arrays. Check the relative-delta criterion at 256 here (fast);
        // the bench exercises 1024.
        let xb = random_xbar(256, 256, 2.93, 43);
        let v: Vec<f64> = (0..256).map(|i| 0.1 * ((i as f64 / 40.0).sin().abs())).collect();
        let (_, stats) = xb.solve_cross_iteration(&v, 1e-3 * 0.1, 20);
        assert!(stats.converged, "deltas={:?}", stats.deltas);
        assert!(stats.iterations <= 20);
    }

    #[test]
    fn ir_drop_attenuates_voltage_and_current() {
        // Fig 10(b)(c): with wire resistance, far-end word-line voltage is
        // below the drive and currents are below ideal.
        let xb = random_xbar(64, 64, 2.93, 44);
        let v = vec![0.2; 64];
        let sol = xb.solve_direct(&v).unwrap();
        for i in 0..64 {
            assert!(sol.v_word.at(i, 63) < 0.2);
            assert!(sol.v_word.at(i, 0) <= 0.2 + 1e-12);
            // Monotone decay along the word line.
            for j in 1..64 {
                assert!(sol.v_word.at(i, j) <= sol.v_word.at(i, j - 1) + 1e-12);
            }
        }
        let ideal = xb.ideal_currents(&v);
        for (a, b) in sol.i_out.iter().zip(&ideal) {
            assert!(a < b, "sim current should be attenuated: {a} vs {b}");
        }
    }

    #[test]
    fn zero_wire_resistance_is_ideal() {
        let mut rng = Pcg64::seeded(45);
        let g = Matrix::random_uniform(16, 16, 1e-7, 1e-5, &mut rng);
        let xb = CrossbarCircuit::new(g.clone(), 0.0);
        let v: Vec<f64> = (0..16).map(|_| rng.uniform_range(0.0, 0.2)).collect();
        let sol = xb.solve_direct(&v).unwrap();
        let ideal = xb.ideal_currents(&v);
        for (a, b) in sol.i_out.iter().zip(&ideal) {
            assert!((a - b).abs() < 1e-18);
        }
    }

    #[test]
    fn small_wire_resistance_approaches_ideal() {
        let xb = random_xbar(16, 16, 1e-4, 46);
        let v = vec![0.1; 16];
        let sol = xb.solve_direct(&v).unwrap();
        let ideal = xb.ideal_currents(&v);
        for (a, b) in sol.i_out.iter().zip(&ideal) {
            assert!((a - b).abs() / b < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn delta_sequence_is_decreasing() {
        let xb = random_xbar(32, 32, 2.93, 47);
        let v = vec![0.15; 32];
        let (_, stats) = xb.solve_cross_iteration(&v, 0.0, 12);
        for w in stats.deltas.windows(2) {
            assert!(w[1] <= w[0] * 1.5, "delta not contracting: {:?}", stats.deltas);
        }
        assert!(stats.deltas.last().unwrap() < &1e-6);
    }
}

//! Crossbar circuit model (paper §3.2, Fig 4, Fig 10).
//!
//! Models a `rows × cols` 1T1R-less passive crossbar:
//! - each cell `(i, j)` is a memristor of conductance `G[i][j]` connecting
//!   word-line node `Vw(i,j)` to bit-line node `Vb(i,j)`;
//! - word-line wire segments of resistance `r_wire` join `Vw(i,j)` to
//!   `Vw(i,j+1)`, with the drive voltage `v_in[i]` applied through one
//!   segment at `j = 0` (far end open);
//! - bit-line segments join `Vb(i,j)` to `Vb(i+1,j)`, terminated at
//!   `i = rows-1` into the virtual ground of the column TIA through one
//!   segment (far end open).
//!
//! Two solvers compute the node voltages:
//! - [`CrossbarCircuit::solve_direct`] — exact banded-LU nodal solution
//!   (the "LTspice" reference of Fig 10);
//! - [`CrossbarCircuit::solve_cross_iteration`] — the paper's fast
//!   alternating line solver: hold bit lines fixed and solve every word
//!   line as a tridiagonal system, then vice versa; converges in ~10–20
//!   sweeps even at 1024×1024 (Fig 10(d)).

pub mod banded;
mod solver;

pub use solver::{CircuitSolution, IterStats};

use crate::tensor::Matrix;

/// A crossbar with wire parasitics.
#[derive(Debug, Clone)]
pub struct CrossbarCircuit {
    /// Conductance matrix (S), `rows × cols`.
    pub g: Matrix,
    /// Wire segment resistance (Ω). Fig 10 uses 2.93 Ω.
    pub r_wire: f64,
    /// Per-cell parasitic capacitance (F) for settling-time estimates.
    pub c_cell: f64,
}

impl CrossbarCircuit {
    pub fn new(g: Matrix, r_wire: f64) -> Self {
        assert!(r_wire >= 0.0);
        CrossbarCircuit { g, r_wire, c_cell: 1e-15 }
    }

    pub fn rows(&self) -> usize {
        self.g.rows
    }

    pub fn cols(&self) -> usize {
        self.g.cols
    }

    /// Ideal (zero wire resistance) output currents: `I_j = Σ_i v[i]·G[i][j]`.
    pub fn ideal_currents(&self, v_in: &[f64]) -> Vec<f64> {
        assert_eq!(v_in.len(), self.rows());
        let mut out = vec![0.0; self.cols()];
        for i in 0..self.rows() {
            let vi = v_in[i];
            if vi == 0.0 {
                continue;
            }
            let row = self.g.row(i);
            for (o, &g) in out.iter_mut().zip(row) {
                *o += vi * g;
            }
        }
        out
    }

    /// Elmore-delay settling estimate for one word line: each of the `cols`
    /// segments (resistance `r_wire`) drives the downstream capacitance, so
    /// `τ ≈ Σ_k r_wire · (cols − k) · c_cell = r_wire·c_cell·cols(cols+1)/2`.
    pub fn elmore_delay(&self) -> f64 {
        let n = self.cols() as f64;
        self.r_wire * self.c_cell * n * (n + 1.0) / 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn ideal_currents_match_matvec() {
        let mut rng = Pcg64::seeded(31);
        let g = Matrix::random_uniform(8, 6, 1e-7, 1e-5, &mut rng);
        let v: Vec<f64> = (0..8).map(|_| rng.uniform_range(0.0, 0.2)).collect();
        let xb = CrossbarCircuit::new(g.clone(), 2.93);
        let i1 = xb.ideal_currents(&v);
        let i2 = g.transpose().matvec(&v);
        for (a, b) in i1.iter().zip(&i2) {
            assert!((a - b).abs() < 1e-18);
        }
    }

    #[test]
    fn elmore_grows_quadratically() {
        let g = Matrix::zeros(4, 64);
        let a = CrossbarCircuit::new(g, 2.93).elmore_delay();
        let g = Matrix::zeros(4, 128);
        let b = CrossbarCircuit::new(g, 2.93).elmore_delay();
        assert!(b / a > 3.9 && b / a < 4.1);
    }
}

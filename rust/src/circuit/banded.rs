//! Banded LU solver (LAPACK `gbsv`-style, no pivoting).
//!
//! The crossbar nodal matrix is symmetric, weakly diagonally dominant and
//! irreducible (an M-matrix), so LU factorization without pivoting is
//! numerically stable. With the interleaved node ordering used in
//! [`super::solver`], the half-bandwidth is `2·cols`, giving
//! O(n·bw²) factorization — exact "LTspice-style" ground truth for arrays
//! up to a few hundred rows/cols.

/// Banded matrix with `kl` sub- and `ku` super-diagonals, stored
/// column-wise by diagonal offset: `band[d + kl][i]` holds `A[i, i + d]`
/// for `d ∈ [-kl, ku]`.
#[derive(Debug, Clone)]
pub struct Banded {
    pub n: usize,
    pub kl: usize,
    pub ku: usize,
    /// (kl + ku + 1) rows of length n; row `k` is diagonal offset `k - kl`.
    diags: Vec<Vec<f64>>,
}

impl Banded {
    pub fn zeros(n: usize, kl: usize, ku: usize) -> Self {
        Banded { n, kl, ku, diags: vec![vec![0.0; n]; kl + ku + 1] }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        let d = j as isize - i as isize;
        if d < -(self.kl as isize) || d > self.ku as isize {
            return 0.0;
        }
        self.diags[(d + self.kl as isize) as usize][i]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        let d = j as isize - i as isize;
        assert!(
            d >= -(self.kl as isize) && d <= self.ku as isize,
            "({i},{j}) outside band kl={} ku={}",
            self.kl,
            self.ku
        );
        self.diags[(d + self.kl as isize) as usize][i] = v;
    }

    #[inline]
    pub fn add(&mut self, i: usize, j: usize, v: f64) {
        let d = j as isize - i as isize;
        assert!(d >= -(self.kl as isize) && d <= self.ku as isize, "({i},{j}) outside band");
        self.diags[(d + self.kl as isize) as usize][i] += v;
    }

    /// y = A·x (for residual checks).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n);
        let mut y = vec![0.0; self.n];
        for i in 0..self.n {
            let j_lo = i.saturating_sub(self.kl);
            let j_hi = (i + self.ku).min(self.n - 1);
            let mut acc = 0.0;
            for j in j_lo..=j_hi {
                acc += self.get(i, j) * x[j];
            }
            y[i] = acc;
        }
        y
    }

    /// In-place LU factorization without pivoting. L's unit diagonal is
    /// implicit; multipliers overwrite the sub-diagonals.
    pub fn lu_factor(&mut self) -> anyhow::Result<()> {
        let n = self.n;
        for k in 0..n {
            let pivot = self.get(k, k);
            if pivot.abs() < 1e-300 {
                anyhow::bail!("banded LU: zero pivot at {k}");
            }
            let i_hi = (k + self.kl).min(n - 1);
            let j_hi = (k + self.ku).min(n - 1);
            for i in (k + 1)..=i_hi {
                let m = self.get(i, k) / pivot;
                self.set(i, k, m);
                if m != 0.0 {
                    for j in (k + 1)..=j_hi {
                        let v = self.get(i, j) - m * self.get(k, j);
                        self.set(i, j, v);
                    }
                }
            }
        }
        Ok(())
    }

    /// Solve with a previously factored matrix (forward + back substitution).
    pub fn lu_solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n;
        let mut x = b.to_vec();
        // Ly = b
        for i in 0..n {
            let j_lo = i.saturating_sub(self.kl);
            let mut acc = x[i];
            for j in j_lo..i {
                acc -= self.get(i, j) * x[j];
            }
            x[i] = acc;
        }
        // Ux = y
        for i in (0..n).rev() {
            let j_hi = (i + self.ku).min(n - 1);
            let mut acc = x[i];
            for j in (i + 1)..=j_hi {
                acc -= self.get(i, j) * x[j];
            }
            x[i] = acc / self.get(i, i);
        }
        x
    }
}

/// Thomas algorithm for a tridiagonal system `(lower, diag, upper)·x = rhs`.
/// `lower[0]` and `upper[n-1]` are ignored. Panics on zero pivot (the
/// crossbar line systems are strictly diagonally dominant).
pub fn solve_tridiagonal(lower: &[f64], diag: &[f64], upper: &[f64], rhs: &[f64]) -> Vec<f64> {
    let n = diag.len();
    assert!(lower.len() == n && upper.len() == n && rhs.len() == n);
    let mut c = vec![0.0; n];
    let mut d = vec![0.0; n];
    c[0] = upper[0] / diag[0];
    d[0] = rhs[0] / diag[0];
    for i in 1..n {
        let m = diag[i] - lower[i] * c[i - 1];
        c[i] = upper[i] / m;
        d[i] = (rhs[i] - lower[i] * d[i - 1]) / m;
    }
    let mut x = vec![0.0; n];
    x[n - 1] = d[n - 1];
    for i in (0..n - 1).rev() {
        x[i] = d[i] - c[i] * x[i + 1];
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_dd_banded(n: usize, kl: usize, ku: usize, rng: &mut Pcg64) -> Banded {
        // Diagonally dominant random banded matrix.
        let mut a = Banded::zeros(n, kl, ku);
        for i in 0..n {
            let mut offsum = 0.0;
            for j in i.saturating_sub(kl)..=(i + ku).min(n - 1) {
                if j != i {
                    let v = rng.uniform_range(-1.0, 1.0);
                    a.set(i, j, v);
                    offsum += v.abs();
                }
            }
            a.set(i, i, offsum + rng.uniform_range(0.5, 2.0));
        }
        a
    }

    #[test]
    fn lu_solves_diagonally_dominant_systems() {
        let mut rng = Pcg64::seeded(21);
        for &(n, kl, ku) in &[(1, 0, 0), (5, 1, 1), (40, 3, 5), (100, 7, 7)] {
            let a = random_dd_banded(n, kl, ku, &mut rng);
            let x_true: Vec<f64> = (0..n).map(|_| rng.uniform_range(-2.0, 2.0)).collect();
            let b = a.matvec(&x_true);
            let mut f = a.clone();
            f.lu_factor().unwrap();
            let x = f.lu_solve(&b);
            let err: f64 = x
                .iter()
                .zip(&x_true)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            assert!(err < 1e-8, "n={n} kl={kl} ku={ku} err={err}");
        }
    }

    #[test]
    fn get_outside_band_is_zero() {
        let a = Banded::zeros(10, 1, 1);
        assert_eq!(a.get(0, 5), 0.0);
        assert_eq!(a.get(9, 0), 0.0);
    }

    #[test]
    #[should_panic(expected = "outside band")]
    fn set_outside_band_panics() {
        let mut a = Banded::zeros(10, 1, 1);
        a.set(0, 3, 1.0);
    }

    #[test]
    fn tridiagonal_matches_banded() {
        let mut rng = Pcg64::seeded(22);
        let n = 50;
        let mut lower = vec![0.0; n];
        let mut diag = vec![0.0; n];
        let mut upper = vec![0.0; n];
        for i in 0..n {
            lower[i] = if i > 0 { rng.uniform_range(-1.0, 0.0) } else { 0.0 };
            upper[i] = if i < n - 1 { rng.uniform_range(-1.0, 0.0) } else { 0.0 };
            diag[i] = 2.5 + rng.uniform_range(0.0, 1.0);
        }
        let rhs: Vec<f64> = (0..n).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
        let x = solve_tridiagonal(&lower, &diag, &upper, &rhs);
        // residual check
        for i in 0..n {
            let mut r = diag[i] * x[i] - rhs[i];
            if i > 0 {
                r += lower[i] * x[i - 1];
            }
            if i < n - 1 {
                r += upper[i] * x[i + 1];
            }
            assert!(r.abs() < 1e-10, "row {i} residual {r}");
        }
    }

    #[test]
    fn singular_matrix_rejected() {
        let mut a = Banded::zeros(3, 1, 1);
        a.set(0, 0, 1.0);
        a.set(1, 1, 0.0);
        a.set(2, 2, 1.0);
        assert!(a.lu_factor().is_err());
    }
}

//! IRIS-like clustering data (Fig 15).
//!
//! Offline substitution for the UCI IRIS dataset: 150 samples, 4 features,
//! 3 balanced classes, sampled from the *published* per-class feature means
//! and standard deviations of Fisher's data. K-means on this data has the
//! same structure as on the original: setosa linearly separable, versicolor
//! and virginica overlapping in petal dimensions.

use super::Dataset;
use crate::util::rng::Pcg64;

/// Published per-class statistics of Fisher's IRIS
/// (features: sepal length, sepal width, petal length, petal width).
pub const CLASS_MEANS: [[f64; 4]; 3] = [
    [5.006, 3.428, 1.462, 0.246], // setosa
    [5.936, 2.770, 4.260, 1.326], // versicolor
    [6.588, 2.974, 5.552, 2.026], // virginica
];

pub const CLASS_STDS: [[f64; 4]; 3] = [
    [0.352, 0.379, 0.174, 0.105],
    [0.516, 0.314, 0.470, 0.198],
    [0.636, 0.322, 0.552, 0.275],
];

pub const CLASS_NAMES: [&str; 3] = ["setosa", "versicolor", "virginica"];

/// Generate an IRIS-like dataset: `per_class` samples per class (the
/// original has 50), deterministic in `seed`.
pub fn load(per_class: usize, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed, 0x1815);
    let n = per_class * 3;
    let mut features = Vec::with_capacity(n * 4);
    let mut labels = Vec::with_capacity(n);
    for class in 0..3 {
        for _ in 0..per_class {
            for f in 0..4 {
                let v = rng.normal_ms(CLASS_MEANS[class][f], CLASS_STDS[class][f]);
                features.push(v.max(0.05)); // measurements are positive
            }
            labels.push(class);
        }
    }
    // Shuffle samples (keeping feature/label association).
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut sf = Vec::with_capacity(n * 4);
    let mut sl = Vec::with_capacity(n);
    for &i in &order {
        sf.extend_from_slice(&features[i * 4..(i + 1) * 4]);
        sl.push(labels[i]);
    }
    Dataset { sample_shape: vec![4], features: sf, labels: sl, num_classes: 3 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_size() {
        let d = load(50, 42);
        assert_eq!(d.len(), 150);
        assert_eq!(d.sample_shape, vec![4]);
        assert_eq!(d.num_classes, 3);
    }

    #[test]
    fn deterministic() {
        let a = load(50, 42);
        let b = load(50, 42);
        assert_eq!(a.features, b.features);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn class_means_recovered() {
        let d = load(200, 7);
        for class in 0..3 {
            let rows: Vec<&[f64]> = (0..d.len())
                .filter(|&i| d.labels[i] == class)
                .map(|i| d.sample(i))
                .collect();
            for f in 0..4 {
                let mean = rows.iter().map(|r| r[f]).sum::<f64>() / rows.len() as f64;
                assert!(
                    (mean - CLASS_MEANS[class][f]).abs() < 0.1,
                    "class {class} feature {f}: mean {mean}"
                );
            }
        }
    }

    #[test]
    fn balanced_and_positive() {
        let d = load(50, 1);
        let mut counts = [0usize; 3];
        for &l in &d.labels {
            counts[l] += 1;
        }
        assert_eq!(counts, [50, 50, 50]);
        assert!(d.features.iter().all(|&x| x > 0.0));
    }
}

//! Procedural MNIST substitute (Fig 16): deterministic 28×28 grayscale
//! digit images rendered from stroke skeletons with random affine jitter and
//! pixel noise.
//!
//! Each digit 0–9 is defined as a set of polyline strokes in a unit box.
//! Rendering draws each stroke with an anti-aliased pen (intensity falls off
//! with distance to the segment), then applies a per-sample random
//! translation/scale/rotation/shear and additive noise. The task is
//! learnable by LeNet-5 to >95% with full precision, which is what the
//! INT4/INT8/FP16 training comparison (Fig 16) needs: a headroom-rich
//! baseline whose degradation under sliced precision can be observed.

use super::Dataset;
use crate::util::rng::Pcg64;

const SIDE: usize = 28;

/// Stroke skeletons per digit: polylines in [0,1]² (x right, y down).
fn strokes(digit: usize) -> Vec<Vec<(f64, f64)>> {
    // Helper to shorten literals.
    let p = |x: f64, y: f64| (x, y);
    match digit {
        0 => vec![vec![
            p(0.50, 0.08), p(0.78, 0.22), p(0.82, 0.50), p(0.78, 0.78),
            p(0.50, 0.92), p(0.22, 0.78), p(0.18, 0.50), p(0.22, 0.22), p(0.50, 0.08),
        ]],
        1 => vec![vec![p(0.35, 0.22), p(0.55, 0.08), p(0.55, 0.92)],
                  vec![p(0.35, 0.92), p(0.75, 0.92)]],
        2 => vec![vec![
            p(0.22, 0.28), p(0.35, 0.10), p(0.65, 0.10), p(0.78, 0.28),
            p(0.72, 0.48), p(0.25, 0.88), p(0.80, 0.88),
        ]],
        3 => vec![vec![
            p(0.22, 0.16), p(0.60, 0.08), p(0.78, 0.25), p(0.55, 0.45),
            p(0.80, 0.65), p(0.60, 0.90), p(0.22, 0.84),
        ]],
        4 => vec![vec![p(0.62, 0.92), p(0.62, 0.08), p(0.18, 0.62), p(0.85, 0.62)]],
        5 => vec![vec![
            p(0.75, 0.10), p(0.30, 0.10), p(0.26, 0.45), p(0.60, 0.42),
            p(0.80, 0.62), p(0.70, 0.88), p(0.25, 0.88),
        ]],
        6 => vec![vec![
            p(0.70, 0.10), p(0.35, 0.35), p(0.22, 0.65), p(0.40, 0.90),
            p(0.70, 0.85), p(0.78, 0.62), p(0.55, 0.50), p(0.28, 0.60),
        ]],
        7 => vec![vec![p(0.20, 0.10), p(0.80, 0.10), p(0.45, 0.92)],
                  vec![p(0.35, 0.50), p(0.70, 0.50)]],
        8 => vec![vec![
            p(0.50, 0.08), p(0.74, 0.20), p(0.68, 0.42), p(0.50, 0.50),
            p(0.30, 0.42), p(0.26, 0.20), p(0.50, 0.08),
        ], vec![
            p(0.50, 0.50), p(0.78, 0.62), p(0.72, 0.86), p(0.50, 0.92),
            p(0.28, 0.86), p(0.22, 0.62), p(0.50, 0.50),
        ]],
        9 => vec![vec![
            p(0.72, 0.40), p(0.45, 0.50), p(0.24, 0.38), p(0.30, 0.15),
            p(0.55, 0.08), p(0.74, 0.18), p(0.74, 0.60), p(0.60, 0.92), p(0.30, 0.88),
        ]],
        _ => panic!("digit out of range"),
    }
}

/// Squared distance from point to segment.
fn dist2_to_segment(px: f64, py: f64, a: (f64, f64), b: (f64, f64)) -> f64 {
    let (ax, ay) = a;
    let (bx, by) = b;
    let (dx, dy) = (bx - ax, by - ay);
    let len2 = dx * dx + dy * dy;
    let t = if len2 > 0.0 { (((px - ax) * dx + (py - ay) * dy) / len2).clamp(0.0, 1.0) } else { 0.0 };
    let (cx, cy) = (ax + t * dx, ay + t * dy);
    (px - cx) * (px - cx) + (py - cy) * (py - cy)
}

/// Render one digit with jitter parameters drawn from `rng`.
fn render(digit: usize, rng: &mut Pcg64, out: &mut [f64]) {
    debug_assert_eq!(out.len(), SIDE * SIDE);
    let scale = rng.uniform_range(0.85, 1.15);
    let theta = rng.uniform_range(-0.22, 0.22);
    let shear = rng.uniform_range(-0.15, 0.15);
    let (tx, ty) = (rng.uniform_range(-0.08, 0.08), rng.uniform_range(-0.08, 0.08));
    let pen = rng.uniform_range(0.045, 0.065); // stroke half-width in unit box
    let (sin_t, cos_t) = theta.sin_cos();
    // Transform skeleton points: center, shear, rotate, scale, translate.
    let tf = |(x, y): (f64, f64)| -> (f64, f64) {
        let (cx, cy) = (x - 0.5, y - 0.5);
        let sx = cx + shear * cy;
        let rx = cos_t * sx - sin_t * cy;
        let ry = sin_t * sx + cos_t * cy;
        (0.5 + scale * rx + tx, 0.5 + scale * ry + ty)
    };
    let segs: Vec<((f64, f64), (f64, f64))> = strokes(digit)
        .iter()
        .flat_map(|poly| {
            poly.windows(2).map(|w| (tf(w[0]), tf(w[1]))).collect::<Vec<_>>()
        })
        .collect();
    let pen2 = pen * pen;
    for iy in 0..SIDE {
        let py = (iy as f64 + 0.5) / SIDE as f64;
        for ix in 0..SIDE {
            let px = (ix as f64 + 0.5) / SIDE as f64;
            let mut d2 = f64::INFINITY;
            for &(a, b) in &segs {
                d2 = d2.min(dist2_to_segment(px, py, a, b));
                if d2 < pen2 * 0.25 {
                    break;
                }
            }
            // Smooth falloff: 1 inside the pen, gaussian tail outside.
            let v = if d2 <= pen2 { 1.0 } else { (-(d2 - pen2) / (pen2 * 1.5)).exp() };
            let noise = rng.uniform_range(-0.04, 0.04);
            out[iy * SIDE + ix] = (v + noise).clamp(0.0, 1.0);
        }
    }
}

/// Generate `n` labelled digit images (classes cycle 0..9), deterministic in
/// `seed`. Sample shape `[1, 28, 28]`, values in [0, 1].
pub fn load(n: usize, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed, 0x3A15);
    let d = SIDE * SIDE;
    let mut features = vec![0.0; n * d];
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let digit = rng.below(10);
        render(digit, &mut rng, &mut features[i * d..(i + 1) * d]);
        labels.push(digit);
    }
    Dataset { sample_shape: vec![1, SIDE, SIDE], features, labels, num_classes: 10 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_range() {
        let ds = load(64, 3);
        assert_eq!(ds.len(), 64);
        assert_eq!(ds.sample_shape, vec![1, 28, 28]);
        assert!(ds.features.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn deterministic() {
        let a = load(16, 5);
        let b = load(16, 5);
        assert_eq!(a.features, b.features);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn images_have_ink_and_background() {
        let ds = load(32, 9);
        for i in 0..ds.len() {
            let s = ds.sample(i);
            let ink = s.iter().filter(|&&v| v > 0.6).count();
            let bg = s.iter().filter(|&&v| v < 0.2).count();
            assert!(ink > 20, "sample {i} has too little ink ({ink})");
            assert!(bg > 300, "sample {i} has too little background ({bg})");
        }
    }

    #[test]
    fn classes_are_visually_distinct() {
        // Mean images of different digits should differ substantially.
        let ds = load(400, 11);
        let d = ds.sample_len();
        let mut means = vec![vec![0.0; d]; 10];
        let mut counts = [0usize; 10];
        for i in 0..ds.len() {
            let c = ds.labels[i];
            counts[c] += 1;
            for (m, &v) in means[c].iter_mut().zip(ds.sample(i)) {
                *m += v;
            }
        }
        for c in 0..10 {
            assert!(counts[c] > 10, "class {c} undersampled");
            for m in means[c].iter_mut() {
                *m /= counts[c] as f64;
            }
        }
        for a in 0..10 {
            for b in (a + 1)..10 {
                let dist: f64 = means[a]
                    .iter()
                    .zip(&means[b])
                    .map(|(x, y)| (x - y) * (x - y))
                    .sum::<f64>()
                    .sqrt();
                assert!(dist > 1.0, "digits {a} and {b} too similar (d={dist})");
            }
        }
    }

    #[test]
    fn all_ten_digits_renderable() {
        let mut rng = Pcg64::seeded(1);
        let mut buf = vec![0.0; SIDE * SIDE];
        for d in 0..10 {
            render(d, &mut rng, &mut buf);
            assert!(buf.iter().any(|&v| v > 0.5));
        }
    }
}

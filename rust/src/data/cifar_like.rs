//! Procedural CIFAR-10 substitute (Fig 17): deterministic 3×32×32 color
//! images with class-specific spatial structure.
//!
//! Each class pairs an orientation/frequency grating with a color palette
//! and a class-dependent blob layout, plus per-sample phase/position jitter
//! and noise. ResNet-18/VGG-16 at CIFAR scale learn this to high accuracy
//! quickly, giving the inference sweeps (slice bits, conductance variation)
//! a meaningful accuracy signal to degrade.

use super::Dataset;
use crate::util::rng::Pcg64;

const SIDE: usize = 32;
const CH: usize = 3;

/// Per-class (orientation rad, spatial freq, rgb palette, blob count).
fn class_spec(c: usize) -> (f64, f64, [f64; 3], usize) {
    match c {
        0 => (0.0, 2.0, [0.9, 0.2, 0.2], 1),
        1 => (0.6, 3.0, [0.2, 0.9, 0.2], 2),
        2 => (1.2, 4.0, [0.2, 0.2, 0.9], 3),
        3 => (1.8, 2.5, [0.9, 0.9, 0.2], 1),
        4 => (2.4, 3.5, [0.9, 0.2, 0.9], 2),
        5 => (3.0, 4.5, [0.2, 0.9, 0.9], 3),
        6 => (0.3, 5.0, [0.8, 0.5, 0.2], 2),
        7 => (0.9, 1.5, [0.5, 0.2, 0.8], 1),
        8 => (1.5, 5.5, [0.3, 0.7, 0.5], 3),
        9 => (2.1, 2.2, [0.7, 0.7, 0.7], 2),
        _ => panic!("class out of range"),
    }
}

fn render(class: usize, rng: &mut Pcg64, out: &mut [f64]) {
    debug_assert_eq!(out.len(), CH * SIDE * SIDE);
    let (theta, freq, rgb, blobs) = class_spec(class);
    let phase = rng.uniform_range(0.0, std::f64::consts::TAU);
    let theta = theta + rng.uniform_range(-0.15, 0.15);
    let freq = freq * rng.uniform_range(0.9, 1.1);
    let (sin_t, cos_t) = theta.sin_cos();
    // Blob centers jittered per sample.
    let centers: Vec<(f64, f64, f64)> = (0..blobs)
        .map(|b| {
            let base = (b as f64 + 0.5) / blobs as f64;
            (
                base + rng.uniform_range(-0.1, 0.1),
                0.5 + rng.uniform_range(-0.25, 0.25),
                rng.uniform_range(0.10, 0.18), // radius
            )
        })
        .collect();
    for iy in 0..SIDE {
        let y = (iy as f64 + 0.5) / SIDE as f64;
        for ix in 0..SIDE {
            let x = (ix as f64 + 0.5) / SIDE as f64;
            let u = cos_t * x + sin_t * y;
            let grating = 0.5 + 0.5 * (std::f64::consts::TAU * freq * u + phase).sin();
            let blob: f64 = centers
                .iter()
                .map(|&(cx, cy, r)| {
                    let d2 = (x - cx) * (x - cx) + (y - cy) * (y - cy);
                    (-d2 / (r * r)).exp()
                })
                .fold(0.0, f64::max);
            let lum = 0.55 * grating + 0.45 * blob;
            for ch in 0..CH {
                let noise = rng.uniform_range(-0.05, 0.05);
                out[ch * SIDE * SIDE + iy * SIDE + ix] = (lum * rgb[ch] + noise).clamp(0.0, 1.0);
            }
        }
    }
}

/// Generate `n` labelled images, deterministic in `seed`.
/// Sample shape `[3, 32, 32]`, values in [0, 1].
pub fn load(n: usize, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed, 0xC1FA);
    let d = CH * SIDE * SIDE;
    let mut features = vec![0.0; n * d];
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let c = rng.below(10);
        render(c, &mut rng, &mut features[i * d..(i + 1) * d]);
        labels.push(c);
    }
    Dataset { sample_shape: vec![CH, SIDE, SIDE], features, labels, num_classes: 10 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_range() {
        let ds = load(32, 1);
        assert_eq!(ds.sample_shape, vec![3, 32, 32]);
        assert!(ds.features.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn deterministic() {
        assert_eq!(load(8, 4).features, load(8, 4).features);
    }

    #[test]
    fn classes_distinct_in_mean_image() {
        let ds = load(500, 2);
        let d = ds.sample_len();
        let mut means = vec![vec![0.0; d]; 10];
        let mut counts = [0usize; 10];
        for i in 0..ds.len() {
            let c = ds.labels[i];
            counts[c] += 1;
            for (m, &v) in means[c].iter_mut().zip(ds.sample(i)) {
                *m += v;
            }
        }
        for c in 0..10 {
            for m in means[c].iter_mut() {
                *m /= counts[c].max(1) as f64;
            }
        }
        for a in 0..10 {
            for b in (a + 1)..10 {
                let dist: f64 = means[a]
                    .iter()
                    .zip(&means[b])
                    .map(|(x, y)| (x - y) * (x - y))
                    .sum::<f64>()
                    .sqrt();
                assert!(dist > 0.5, "classes {a},{b} too similar ({dist})");
            }
        }
    }
}

//! ENSO-like time series (Fig 14 CWT input).
//!
//! Offline substitution for the UCI El-Niño / NINO3 sea-surface-temperature
//! anomaly record: a monthly series combining
//! - an annual seasonal cycle,
//! - a quasi-periodic El-Niño oscillation (~3.5-year period with slow
//!   period/amplitude wander, the feature the paper's CWT power spectrum
//!   highlights around the 2–7-year band),
//! - red (AR(1)) noise.

use crate::util::rng::Pcg64;

/// Generate `n` monthly samples, deterministic in `seed`.
pub fn load(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg64::new(seed, 0x9170);
    let mut out = Vec::with_capacity(n);
    let mut ar = 0.0f64;
    // Slowly wandering ENSO phase: period drifts between ~2.5 and ~5 years.
    let mut enso_phase = 0.0f64;
    let mut period_months = 42.0f64; // 3.5 years
    for t in 0..n {
        let month = t as f64;
        // Seasonal cycle (12-month), small amplitude.
        let seasonal = 0.4 * (std::f64::consts::TAU * month / 12.0).sin();
        // ENSO oscillation with wandering instantaneous period and amplitude
        // modulation on a ~14-year envelope.
        period_months = (period_months + rng.normal_ms(0.0, 0.35)).clamp(30.0, 60.0);
        enso_phase += std::f64::consts::TAU / period_months;
        let envelope = 1.0 + 0.5 * (std::f64::consts::TAU * month / 168.0).sin();
        let enso = 1.2 * envelope * enso_phase.sin();
        // Red noise.
        ar = 0.8 * ar + rng.normal_ms(0.0, 0.25);
        out.push(seasonal + enso + ar);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn length_and_determinism() {
        let a = load(1512, 3);
        assert_eq!(a.len(), 1512);
        assert_eq!(a, load(1512, 3));
    }

    #[test]
    fn roughly_zero_mean_bounded() {
        let xs = load(2048, 5);
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.5, "mean={mean}");
        assert!(xs.iter().all(|x| x.abs() < 10.0));
    }

    #[test]
    fn has_interannual_power() {
        // Autocorrelation at ~42 months should be non-trivially negative or
        // positive (oscillatory), and at lag 1 strongly positive (red noise).
        let xs = load(2048, 7);
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var: f64 = xs.iter().map(|x| (x - mean) * (x - mean)).sum();
        let ac = |lag: usize| -> f64 {
            xs.iter()
                .zip(xs.iter().skip(lag))
                .map(|(a, b)| (a - mean) * (b - mean))
                .sum::<f64>()
                / var
        };
        assert!(ac(1) > 0.5, "lag-1 autocorrelation {}", ac(1));
        assert!(ac(21).abs() > 0.05, "no interannual structure: {}", ac(21));
    }
}

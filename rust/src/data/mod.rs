//! Datasets for the paper's application experiments.
//!
//! The container is offline, so real MNIST/CIFAR-10/NINO3 downloads are
//! replaced by deterministic synthetic equivalents that exercise the exact
//! same code paths (see DESIGN.md §Substitutions):
//! - [`iris`] — Fisher-IRIS-like data sampled from the published per-class
//!   feature statistics (Fig 15 clustering);
//! - [`mnist_like`] — procedurally rasterized 28×28 digits (Fig 16 LeNet-5
//!   training);
//! - [`cifar_like`] — class-structured 3×32×32 color images (Fig 17
//!   ResNet/VGG inference);
//! - [`nino`] — ENSO-like monthly sea-surface-temperature anomaly series
//!   (Fig 14 CWT).

pub mod cifar_like;
pub mod iris;
pub mod mnist_like;
pub mod nino;

/// A labelled dataset of flat feature vectors.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Per-sample feature dimensions (e.g. `[1, 28, 28]`).
    pub sample_shape: Vec<usize>,
    /// `n × prod(sample_shape)`, row-major.
    pub features: Vec<f64>,
    pub labels: Vec<usize>,
    pub num_classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn sample_len(&self) -> usize {
        self.sample_shape.iter().product()
    }

    pub fn sample(&self, i: usize) -> &[f64] {
        let d = self.sample_len();
        &self.features[i * d..(i + 1) * d]
    }

    /// Split into (train, test) at `n_train`.
    pub fn split(&self, n_train: usize) -> (Dataset, Dataset) {
        assert!(n_train <= self.len());
        let d = self.sample_len();
        let train = Dataset {
            sample_shape: self.sample_shape.clone(),
            features: self.features[..n_train * d].to_vec(),
            labels: self.labels[..n_train].to_vec(),
            num_classes: self.num_classes,
        };
        let test = Dataset {
            sample_shape: self.sample_shape.clone(),
            features: self.features[n_train * d..].to_vec(),
            labels: self.labels[n_train..].to_vec(),
            num_classes: self.num_classes,
        };
        (train, test)
    }

    /// Gather a batch of samples into a `(batch, d)` row-major buffer.
    pub fn batch(&self, idx: &[usize]) -> (Vec<f64>, Vec<usize>) {
        let mut feats = Vec::new();
        let mut labels = Vec::new();
        self.batch_into(idx, &mut feats, &mut labels);
        (feats, labels)
    }

    /// Gather a batch into caller-owned buffers (cleared first) — the
    /// allocation-free variant of [`Dataset::batch`]: a training loop that
    /// passes the same buffers every step assembles each batch with plain
    /// row copies and no per-step allocation once capacity has grown.
    pub fn batch_into(&self, idx: &[usize], feats: &mut Vec<f64>, labels: &mut Vec<usize>) {
        let d = self.sample_len();
        feats.clear();
        feats.reserve(idx.len() * d);
        labels.clear();
        labels.reserve(idx.len());
        for &i in idx {
            feats.extend_from_slice(self.sample(i));
            labels.push(self.labels[i]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            sample_shape: vec![2],
            features: vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0],
            labels: vec![0, 1, 0],
            num_classes: 2,
        }
    }

    #[test]
    fn sample_access() {
        let d = tiny();
        assert_eq!(d.len(), 3);
        assert_eq!(d.sample(1), &[2.0, 3.0]);
    }

    #[test]
    fn split_partitions() {
        let d = tiny();
        let (tr, te) = d.split(2);
        assert_eq!(tr.len(), 2);
        assert_eq!(te.len(), 1);
        assert_eq!(te.sample(0), &[4.0, 5.0]);
    }

    #[test]
    fn batch_into_matches_batch_and_reuses_buffers() {
        let d = tiny();
        let (f, l) = d.batch(&[1, 2, 0]);
        let mut feats = Vec::new();
        let mut labels = Vec::new();
        d.batch_into(&[1, 2, 0], &mut feats, &mut labels);
        assert_eq!(feats, f);
        assert_eq!(labels, l);
        // Refilling the same buffers replaces, never appends.
        d.batch_into(&[0], &mut feats, &mut labels);
        assert_eq!(feats, vec![0.0, 1.0]);
        assert_eq!(labels, vec![0]);
    }

    #[test]
    fn batch_gathers() {
        let d = tiny();
        let (f, l) = d.batch(&[2, 0]);
        assert_eq!(f, vec![4.0, 5.0, 0.0, 1.0]);
        assert_eq!(l, vec![2usize, 0].iter().map(|&i| d.labels[i]).collect::<Vec<_>>());
    }
}

//! Experiment registry: one entry per paper table/figure. The CLI
//! (`memintelli run <id>`) and the bench binaries (`benches/`) share these
//! implementations; benches run `Scale::Full`, the CLI defaults to
//! `Scale::Quick`.

use super::SimConfig;
use crate::apps::{cwt, kmeans, solver};
use crate::arch::{
    uniform_fleet, ChipFaultSpec, ChipSpec, FaultEvent, FleetReport, MappedModel, Outcome,
    ReplicaModel, ReplicaSpec, Request, ServingRuntime,
};
use crate::circuit::CrossbarCircuit;
use crate::data::{cifar_like, iris, mnist_like, nino};
use crate::device::faults::{AdcErrorSpec, AdcRounding, FaultSpec, NonIdealitySpec};
use crate::device::{conductance_clouds, DeviceSpec};
use crate::dpe::engine::AdcPolicy;
use crate::dpe::montecarlo::{run_fault_point, sweep, sweep_faults, McConfig};
use crate::dpe::{DataMode, DotProductEngine, RepairSpec, SliceMethod, SliceSpec};
use crate::nn::models::{lenet5, mlp, resnet18_cifar, vgg16_cifar};
use crate::nn::train::{evaluate, evaluate_mapped, train, train_fast, TrainConfig};
use crate::nn::{HwSpec, Sequential};
use crate::tensor::{Matrix, Tensor};
use crate::util::report::{fmt_duration, fmt_sig, time_it, Table};
use crate::util::rng::Pcg64;

/// Experiment scale: Quick for the CLI smoke path, Full for benches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Quick,
    Full,
}

impl Scale {
    pub fn pick(&self, quick: usize, full: usize) -> usize {
        match self {
            Scale::Quick => quick,
            Scale::Full => full,
        }
    }
}

/// All experiment ids, in paper order.
pub const EXPERIMENTS: &[(&str, &str)] = &[
    ("fig03_device", "Device model: lognormal HRS/LRS conductance clouds"),
    ("fig10_circuit", "Crossbar circuit: IR-drop + cross-iteration solver convergence"),
    ("fig11_precision", "Variable-precision 128x128 matmul: INT8/FP32/BF16/FlexPoint16"),
    ("fig12_montecarlo", "Monte-Carlo: RE vs bits, block size, variation; quant vs prealign"),
    ("fig_faults", "Fault injection: accuracy/yield vs stuck-at rate x cv x bits; lines, retention, ADC error"),
    ("fig_repair", "Self-healing chip: program-and-verify, probe localization, remap-to-spare yield recovery"),
    ("fig_serving", "Fault-tolerant serving: replicated pool, deadlines/retries, drift-triggered online healing"),
    ("fig_sharding", "Multi-chip sharding: pipeline stages across a fleet, chip-loss failover to spares, link retry"),
    ("fig13_solver", "Linear equation solving: software vs hardware CG"),
    ("fig14_cwt", "Morlet CWT of the ENSO-like series with INT4 kernels"),
    ("fig15_kmeans", "K-means on IRIS with the dot-product distance trick"),
    ("fig16_training", "LeNet-5 training under INT4/INT8/FP16"),
    ("fig17_inference", "ResNet-18/VGG-16 inference vs slice bits and variation"),
    ("table3_throughput", "Inference throughput (img/s): native vs XLA backend"),
];

/// Run one experiment by id; returns the emitted tables.
pub fn run(id: &str, cfg: &SimConfig, scale: Scale) -> anyhow::Result<Vec<Table>> {
    let tables = match id {
        "fig03_device" => fig03_device(cfg, scale),
        "fig10_circuit" => fig10_circuit(cfg, scale),
        "fig11_precision" => fig11_precision(cfg, scale),
        "fig12_montecarlo" => fig12_montecarlo(cfg, scale),
        "fig_faults" => fig_faults(cfg, scale),
        "fig_repair" => fig_repair(cfg, scale)?,
        "fig_serving" => fig_serving(cfg, scale)?,
        "fig_sharding" => fig_sharding(cfg, scale)?,
        "fig13_solver" => fig13_solver(cfg, scale),
        "fig14_cwt" => fig14_cwt(cfg, scale),
        "fig15_kmeans" => fig15_kmeans(cfg, scale),
        "fig16_training" => fig16_training(cfg, scale),
        "fig17_inference" => fig17_inference(cfg, scale)?,
        "table3_throughput" => table3_throughput(cfg, scale),
        _ => anyhow::bail!(
            "unknown experiment '{id}' — did you mean '{}'? (see `memintelli list`)",
            closest_experiment(id)
        ),
    };
    for t in &tables {
        t.emit(&format!("{id}_{}", sanitize(&t.title)));
    }
    Ok(tables)
}

fn sanitize(s: &str) -> String {
    s.chars().map(|c| if c.is_alphanumeric() { c.to_ascii_lowercase() } else { '_' }).collect()
}

/// The registered experiment id closest to `id` — the CLI's "did you
/// mean" hint for typos. An id that extends (or abbreviates) a registered
/// one wins outright; otherwise smallest edit distance.
pub fn closest_experiment(id: &str) -> &'static str {
    if !id.is_empty() {
        let by_prefix =
            EXPERIMENTS.iter().find(|(eid, _)| eid.starts_with(id) || id.starts_with(eid));
        if let Some(&(eid, _)) = by_prefix {
            return eid;
        }
    }
    EXPERIMENTS
        .iter()
        .map(|(eid, _)| *eid)
        .min_by_key(|eid| levenshtein(id, eid))
        .expect("registry is non-empty")
}

fn levenshtein(a: &str, b: &str) -> usize {
    let (a, b): (Vec<char>, Vec<char>) = (a.chars().collect(), b.chars().collect());
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

// ---------------------------------------------------------------- Fig 3

pub fn fig03_device(cfg: &SimConfig, scale: Scale) -> Vec<Table> {
    let n = scale.pick(2_000, 20_000);
    let mut t = Table::new(
        "Fig 3 — conductance clouds (lognormal device model)",
        &["state", "target G (S)", "mean (S)", "std (S)", "realized cv", "min", "max"],
    );
    for cv in [0.05, 0.1, 0.2] {
        let spec = DeviceSpec { cv, ..cfg.dpe.device };
        let (hrs, lrs) = conductance_clouds(&spec, n, cfg.seed);
        for (name, target, xs) in [("HRS", spec.lgs, &hrs), ("LRS", spec.hgs, &lrs)] {
            let mean = xs.iter().sum::<f64>() / xs.len() as f64;
            let std = (xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64).sqrt();
            t.row(&[
                format!("{name} cv={cv}"),
                fmt_sig(target),
                fmt_sig(mean),
                fmt_sig(std),
                format!("{:.4}", std / mean),
                fmt_sig(xs.iter().cloned().fold(f64::INFINITY, f64::min)),
                fmt_sig(xs.iter().cloned().fold(0.0, f64::max)),
            ]);
        }
    }
    vec![t]
}

// --------------------------------------------------------------- Fig 10

pub fn fig10_circuit(cfg: &SimConfig, scale: Scale) -> Vec<Table> {
    let mut rng = Pcg64::new(cfg.seed, 0xF16);
    // (a)-(c): 64×64 array, Rw = 2.93 Ω, sinusoidal word-line drive.
    let g = Matrix::random_uniform(64, 64, cfg.dpe.device.lgs, cfg.dpe.device.hgs, &mut rng);
    let xb = CrossbarCircuit::new(g, 2.93);
    let v_in: Vec<f64> = (0..64).map(|i| 0.1 + 0.1 * (i as f64 / 6.0).sin().abs()).collect();
    let direct = xb.solve_direct(&v_in).expect("direct solve");
    let ideal = xb.ideal_currents(&v_in);
    let mut t1 = Table::new(
        "Fig 10(b)(c) — IR-drop attenuation, 64x64, Rw=2.93",
        &["quantity", "near end", "mid", "far end"],
    );
    let row_v = |r: usize| {
        vec![
            format!("word-line V, row {r}"),
            format!("{:.4}", direct.v_word.at(r, 0)),
            format!("{:.4}", direct.v_word.at(r, 32)),
            format!("{:.4}", direct.v_word.at(r, 63)),
        ]
    };
    t1.row(&row_v(0));
    t1.row(&row_v(31));
    let att: Vec<f64> = direct.i_out.iter().zip(&ideal).map(|(s, i)| s / i).collect();
    t1.row(&[
        "I_out / I_ideal".into(),
        format!("{:.4}", att[0]),
        format!("{:.4}", att[32]),
        format!("{:.4}", att[63]),
    ]);

    // (d): cross-iteration convergence vs array size.
    let sizes: Vec<usize> = match scale {
        Scale::Quick => vec![64, 128, 256],
        Scale::Full => vec![64, 128, 256, 512, 1024],
    };
    let mut t2 = Table::new(
        "Fig 10(d) — cross-iteration solver vs array size (target err < 1e-3·Vmax)",
        &["size", "iterations", "max |dV| at stop", "vs direct (RE)", "solve time"],
    );
    for &n in &sizes {
        let g = Matrix::random_uniform(n, n, cfg.dpe.device.lgs, cfg.dpe.device.hgs, &mut rng);
        let xb = CrossbarCircuit::new(g, 2.93);
        let v: Vec<f64> = (0..n).map(|i| 0.1 + 0.1 * (i as f64 / 9.0).sin().abs()).collect();
        let t0 = std::time::Instant::now();
        let (sol, stats) = xb.solve_cross_iteration(&v, 1e-3 * 0.2, 20);
        let dt = t0.elapsed().as_secs_f64();
        let re = if n <= 128 {
            let d = xb.solve_direct(&v).unwrap();
            let num: f64 = sol.i_out.iter().zip(&d.i_out).map(|(a, b)| (a - b) * (a - b)).sum();
            let den: f64 = d.i_out.iter().map(|v| v * v).sum();
            format!("{:.2e}", (num / den).sqrt())
        } else {
            "-".into()
        };
        t2.row(&[
            format!("{n}x{n}"),
            stats.iterations.to_string(),
            format!("{:.2e}", stats.deltas.last().unwrap()),
            re,
            fmt_duration(dt),
        ]);
    }
    vec![t1, t2]
}

// --------------------------------------------------------------- Fig 11

pub fn fig11_precision(cfg: &SimConfig, scale: Scale) -> Vec<Table> {
    let size = 128;
    let reps = scale.pick(1, 5);
    let mut rng = Pcg64::new(cfg.seed, 0xF11);
    let mut t = Table::new(
        "Fig 11 — variable-precision matmul RE, 128x128 (FP64 source)",
        &["format", "slices", "mode", "RE worst-case ADC", "RE calibrated ADC", "RE noise-free"],
    );
    let formats: Vec<(&str, SliceMethod)> = vec![
        ("INT8", SliceMethod::int(SliceSpec::int8())),
        ("FP32", SliceMethod::fp(SliceSpec::fp32())),
        ("BF16", SliceMethod::fp(SliceSpec::bf16())),
        ("FlexPoint16+5", SliceMethod::fp(SliceSpec::flex16())),
    ];
    for (name, method) in formats {
        let mut means = Vec::new();
        for variant in 0..3usize {
            let mut res = Vec::new();
            for rep in 0..reps {
                let mut rng_rep = Pcg64::new(cfg.seed + rep as u64, 0xF11);
                let a = Matrix::random_normal(size, size, 0.0, 1.0, &mut rng_rep);
                let b = Matrix::random_normal(size, size, 0.0, 1.0, &mut rng_rep);
                let mut dpe = cfg.dpe.clone();
                match variant {
                    0 => dpe.adc_policy = AdcPolicy::WorstCase,
                    1 => dpe.adc_policy = AdcPolicy::Calibrated,
                    _ => dpe.noise_free = true,
                }
                let engine = DotProductEngine::new(dpe, cfg.seed + rep as u64);
                res.push(engine.relative_error(&a, &b, &method, &method));
            }
            means.push(res.iter().sum::<f64>() / res.len() as f64);
        }
        t.row(&[
            name.into(),
            format!("{:?}", method.spec.widths),
            format!("{:?}", method.mode),
            fmt_sig(means[0]),
            fmt_sig(means[1]),
            fmt_sig(means[2]),
        ]);
    }
    let _ = &mut rng;
    vec![t]
}

// --------------------------------------------------------------- Fig 12

pub fn fig12_montecarlo(cfg: &SimConfig, scale: Scale) -> Vec<Table> {
    let mc = McConfig {
        size: scale.pick(64, 128),
        cycles: scale.pick(10, 100),
        base: cfg.dpe.clone(),
        seed: cfg.seed,
    };
    let bits = [4usize, 6, 8, 12];
    let blocks = [32usize, 64, 128];
    let cvs = [0.0, 0.02, 0.05, 0.1];
    let modes = [DataMode::Quantize, DataMode::PreAlign];
    let pts = sweep(&mc, &bits, &blocks, &cvs, &modes);
    let mut t = Table::new(
        &format!("Fig 12 — Monte Carlo ({} cycles, {}x{} operands)", mc.cycles, mc.size, mc.size),
        &["mode", "bits", "block", "cv", "RE mean", "RE std", "RE max"],
    );
    for p in pts {
        t.row(&[
            format!("{:?}", p.mode),
            p.bits.to_string(),
            p.block.to_string(),
            format!("{}", p.cv),
            fmt_sig(p.re_mean),
            fmt_sig(p.re_std),
            fmt_sig(p.re_max),
        ]);
    }
    vec![t]
}

// ------------------------------------------------------------ fig_faults

/// Fault-injection robustness study (extension beyond the paper, see
/// `device::faults`): Monte-Carlo accuracy **and yield** under stuck-at
/// cells, dead lines, retention loss at read time, and per-column ADC
/// gain/offset error — the pre-verification question "what fraction of
/// programmed chips still meets the error budget?".
pub fn fig_faults(cfg: &SimConfig, scale: Scale) -> Vec<Table> {
    let mc = McConfig {
        size: scale.pick(48, 128),
        cycles: scale.pick(8, 50),
        base: cfg.dpe.clone(),
        seed: cfg.seed,
    };
    let yield_re = 0.1;
    let bits: Vec<usize> = match scale {
        Scale::Quick => vec![4, 8],
        Scale::Full => vec![4, 8, 12],
    };
    let cvs: Vec<f64> = match scale {
        Scale::Quick => vec![0.0, 0.05],
        Scale::Full => vec![0.0, 0.02, 0.05, 0.1],
    };
    let rates: Vec<f64> = match scale {
        Scale::Quick => vec![0.0, 0.01, 0.05],
        Scale::Full => vec![0.0, 0.001, 0.01, 0.05, 0.1],
    };

    // The configured [faults] spec is the base everywhere: each table
    // overrides only the knob it studies, so retention/ADC/seed settings
    // from `--config` carry through (table (a) replaces the cell rates,
    // (b) the fault/retention knobs, (c) the ADC error).
    let base = &cfg.dpe.nonideal;

    // (a) stuck-at cell sweep: fault rate × cv × bit width.
    let mut t1 = Table::new(
        &format!(
            "fig_faults(a) — stuck-at cells: RE and yield@RE<={yield_re} ({} cycles, {}x{})",
            mc.cycles, mc.size, mc.size
        ),
        &["bits", "cv", "fault rate", "RE mean", "RE std", "RE max", "yield"],
    );
    for p in sweep_faults(&mc, &bits, &cvs, &rates, base, yield_re) {
        t1.row(&[
            p.bits.to_string(),
            format!("{}", p.cv),
            format!("{}", p.fault_rate),
            fmt_sig(p.re_mean),
            fmt_sig(p.re_std),
            fmt_sig(p.re_max),
            format!("{:.2}", p.yield_frac),
        ]);
    }

    // (b) line faults and retention at read time, 8-bit at the config cv.
    let cv = cfg.dpe.device.cv;
    let mut t2 = Table::new(
        "fig_faults(b) — dead lines and retention (8-bit)",
        &["injection", "RE mean", "RE max", "yield"],
    );
    // Each case pins the fault/retention knobs, inheriting drift
    // parameters, ADC error, and the injection seed from the config base.
    let with = |faults: FaultSpec, t_read: f64| NonIdealitySpec { faults, t_read, ..base.clone() };
    let line_cases: Vec<(String, NonIdealitySpec)> = vec![
        ("none".into(), with(FaultSpec::none(), 0.0)),
        (
            "dead rows 2%".into(),
            with(FaultSpec { dead_row: 0.02, ..FaultSpec::none() }, 0.0),
        ),
        (
            "dead cols 2%".into(),
            with(FaultSpec { dead_col: 0.02, ..FaultSpec::none() }, 0.0),
        ),
        ("retention t_read=1e3 s".into(), with(FaultSpec::none(), 1e3)),
        ("retention t_read=1e6 s".into(), with(FaultSpec::none(), 1e6)),
    ];
    for (name, ni) in &line_cases {
        let p = run_fault_point(&mc, 8, cv, ni, yield_re);
        t2.row(&[
            name.clone(),
            fmt_sig(p.re_mean),
            fmt_sig(p.re_max),
            format!("{:.2}", p.yield_frac),
        ]);
    }

    // (c) ADC peripheral error: per-column offset/gain and rounding mode.
    let mut t3 = Table::new(
        "fig_faults(c) — per-column ADC error (8-bit)",
        &["adc error", "RE mean", "RE max", "yield"],
    );
    let adc_cases: Vec<(String, AdcErrorSpec)> = vec![
        ("ideal".into(), AdcErrorSpec::none()),
        (
            "offset 0.5 LSB".into(),
            AdcErrorSpec { offset_std_lsb: 0.5, ..AdcErrorSpec::none() },
        ),
        ("gain 2%".into(), AdcErrorSpec { gain_std: 0.02, ..AdcErrorSpec::none() }),
        (
            "floor rounding".into(),
            AdcErrorSpec { rounding: AdcRounding::Floor, ..AdcErrorSpec::none() },
        ),
        (
            "offset+gain+floor".into(),
            AdcErrorSpec { gain_std: 0.02, offset_std_lsb: 0.5, rounding: AdcRounding::Floor },
        ),
    ];
    for (name, adc) in &adc_cases {
        let ni = NonIdealitySpec { adc: *adc, ..base.clone() };
        let p = run_fault_point(&mc, 8, cv, &ni, yield_re);
        t3.row(&[
            name.clone(),
            fmt_sig(p.re_mean),
            fmt_sig(p.re_max),
            format!("{:.2}", p.yield_frac),
        ]);
    }
    vec![t1, t2, t3]
}

// ------------------------------------------------------------ fig_repair

/// One stuck-at-rate × spare-budget operating point of the self-healing
/// sweep ([`repair_sweep`]): per-cycle relative errors against the
/// digital twin before and after [`crate::arch::MappedModel::self_heal`],
/// plus the repair-loop accounting the bench serializes.
#[derive(Debug, Clone, Default)]
pub struct RepairPoint {
    pub rate: f64,
    pub spares: usize,
    pub cycles: usize,
    /// Per-cycle RE vs the digital twin, before any repair.
    pub re_before: Vec<f64>,
    /// Per-cycle RE after one `self_heal` round.
    pub re_after: Vec<f64>,
    /// Fraction of cycles meeting `RE <= yield_re` before / after repair.
    pub yield_before: f64,
    pub yield_after: f64,
    pub yield_re: f64,
    /// Block-group migrations applied, summed over cycles.
    pub moves: usize,
    /// Condemned groups with no spare left, summed over cycles.
    pub unplaced: usize,
    /// Verify-loop retries, summed over cycles.
    pub retries: usize,
    /// Health-probe matmuls executed, summed over cycles (the probe
    /// overhead relative to `cycles` real inference batches).
    pub probe_matmuls: usize,
    /// Cycles that ended degraded (spares exhausted).
    pub degraded_cycles: usize,
    /// Retries-per-block histogram over all cycles (`hist[r]` = blocks
    /// that took `r` retries; the last bin absorbs `>= max_retries`).
    pub retry_hist: Vec<usize>,
}

impl RepairPoint {
    pub fn re_before_mean(&self) -> f64 {
        self.re_before.iter().sum::<f64>() / self.re_before.len().max(1) as f64
    }

    pub fn re_after_mean(&self) -> f64 {
        self.re_after.iter().sum::<f64>() / self.re_after.len().max(1) as f64
    }
}

fn relative_err(got: &[f64], want: &[f64]) -> f64 {
    let num: f64 = got.iter().zip(want).map(|(a, b)| (a - b) * (a - b)).sum();
    let den: f64 = want.iter().map(|v| v * v).sum();
    (num / den.max(1e-300)).sqrt()
}

/// Shared driver for the `fig_repair` experiment and `benches/fig_repair`:
/// for each stuck-at rate × spare budget, `cycles` independently-seeded
/// chips (fresh engine per cycle, fixed weights and input) run through
/// compile → infer → [`crate::arch::MappedModel::self_heal`] → infer, and
/// yield is scored as the fraction of cycles whose relative error against
/// the digital twin stays within `yield_re`.
///
/// The workload is one `LinearMem(128, 64)` on 64×64 arrays (two int8
/// block groups of four digit planes) mapped onto a single tile with
/// exactly the data capacity it needs plus `spares` spare arrays — so at
/// `spares = 0` every condemned group degrades, and each spare-group pair
/// added lets one more condemned group move.
pub fn repair_sweep(
    cfg: &SimConfig,
    cycles: usize,
    rates: &[f64],
    spares_list: &[usize],
    yield_re: f64,
) -> anyhow::Result<Vec<RepairPoint>> {
    use crate::nn::layers::LinearMem;
    let (k, n, m) = (128usize, 64usize, 8usize);
    let planes = 2 * 4;
    let weight_rng = || Pcg64::new(cfg.seed, 0x4EA1);
    let x = Tensor::from_vec(
        &[m, k],
        (0..m * k).map(|i| ((i * 31 % 97) as f64) / 48.0 - 1.0).collect(),
    );
    let mut digital =
        Sequential::new(vec![Box::new(LinearMem::new(k, n, None, &mut weight_rng()))]);
    let y_ref = digital.forward(&x, false);
    // Honor a configured [repair] policy; default to the enabled one —
    // a sweep with verification off would never condemn via retries.
    let spec = if cfg.repair.verify { cfg.repair.clone() } else { RepairSpec::enabled() };
    let mut points = Vec::new();
    for &rate in rates {
        for &spares in spares_list {
            let chip = ChipSpec::new(1, planes + spares, (64, 64)).with_spares(spares);
            let mut pt = RepairPoint {
                rate,
                spares,
                cycles,
                yield_re,
                retry_hist: vec![0; spec.max_retries + 1],
                ..RepairPoint::default()
            };
            for c in 0..cycles {
                let mut dpe = cfg.dpe.clone();
                dpe.array = (64, 64);
                dpe.nonideal.faults = FaultSpec {
                    sa0: rate / 2.0,
                    sa1: rate / 2.0,
                    ..cfg.dpe.nonideal.faults
                };
                let hw = HwSpec::uniform(
                    DotProductEngine::new(dpe, cfg.seed.wrapping_add(c as u64)),
                    SliceMethod::int(SliceSpec::int8()),
                );
                let model = Sequential::new(vec![Box::new(LinearMem::new(
                    k,
                    n,
                    Some(hw),
                    &mut weight_rng(),
                ))]);
                let mut mapped = model.compile(&chip)?;
                let re_b = relative_err(&mapped.infer(&x).data, &y_ref.data);
                let out = mapped.self_heal(&spec)?;
                let re_a = relative_err(&mapped.infer(&x).data, &y_ref.data);
                pt.re_before.push(re_b);
                pt.re_after.push(re_a);
                pt.yield_before += f64::from(u8::from(re_b <= yield_re));
                pt.yield_after += f64::from(u8::from(re_a <= yield_re));
                pt.moves += out.plan.moves.len();
                pt.unplaced += out.plan.unplaced.len();
                pt.retries += out.total_retries();
                pt.probe_matmuls += out.health.probe_matmuls;
                pt.degraded_cycles += usize::from(out.degraded.is_some());
                for rep in &out.program_reports {
                    for (r, cnt) in rep.retry_histogram(spec.max_retries).iter().enumerate() {
                        pt.retry_hist[r] += cnt;
                    }
                }
            }
            pt.yield_before /= cycles as f64;
            pt.yield_after /= cycles as f64;
            points.push(pt);
        }
    }
    Ok(points)
}

/// Self-healing chip study (tentpole of the robustness PR; see
/// `arch::repair`): yield@RE-bound before/after one closed-loop repair
/// round, across stuck-at rate × spare budget, with the probe/verify
/// overhead that pays for it.
pub fn fig_repair(cfg: &SimConfig, scale: Scale) -> anyhow::Result<Vec<Table>> {
    let cycles = scale.pick(4, 24);
    let rates: Vec<f64> = match scale {
        Scale::Quick => vec![0.0, 1e-4, 1e-3],
        Scale::Full => vec![0.0, 2e-5, 1e-4, 5e-4, 2e-3],
    };
    let spares_list: Vec<usize> = match scale {
        Scale::Quick => vec![0, 8],
        Scale::Full => vec![0, 4, 8],
    };
    let yield_re = 0.1;
    let pts = repair_sweep(cfg, cycles, &rates, &spares_list, yield_re)?;
    let mut t = Table::new(
        &format!(
            "fig_repair — self-healing yield@RE<={yield_re} \
             ({cycles} cycles, LinearMem 128x64 int8, 1 tile + spares)"
        ),
        &[
            "stuck rate",
            "spares",
            "RE before",
            "RE after",
            "yield before",
            "yield after",
            "moves",
            "unplaced",
            "retries",
            "probe matmuls",
            "degraded cycles",
        ],
    );
    for p in &pts {
        t.row(&[
            format!("{}", p.rate),
            p.spares.to_string(),
            fmt_sig(p.re_before_mean()),
            fmt_sig(p.re_after_mean()),
            format!("{:.2}", p.yield_before),
            format!("{:.2}", p.yield_after),
            p.moves.to_string(),
            p.unplaced.to_string(),
            p.retries.to_string(),
            p.probe_matmuls.to_string(),
            p.degraded_cycles.to_string(),
        ]);
    }
    Ok(vec![t])
}

// ----------------------------------------------------------- fig_serving

/// One scenario of the fault-tolerant serving sweep ([`serving_sweep`]):
/// latency/throughput/accuracy under open-loop load, plus the failover
/// and healing accounting the bench serializes.
#[derive(Debug, Clone, Default)]
pub struct ServingPoint {
    pub label: String,
    pub requests: usize,
    pub completed: usize,
    pub failed: usize,
    pub queue_full: usize,
    pub deadline_exceeded: usize,
    pub retries_exhausted: usize,
    /// Retry dispatches beyond each request's first attempt.
    pub retries: usize,
    pub p50_us: u64,
    pub p99_us: u64,
    pub images_per_sec: f64,
    /// Top-1 accuracy over ALL requests (failures count as wrong).
    pub accuracy: f64,
    pub heals: usize,
    /// Condemned groups remapped onto spares, summed over heal rounds.
    pub moves: usize,
    /// Groups fenced off (zeroed), summed over heal rounds.
    pub fenced: usize,
    /// Fault-free scenarios only: every dispatched batch replayed on a
    /// twin replica built by the same factory matched bit for bit.
    pub clean_bit_exact: Option<bool>,
}

/// Shared driver for the `fig_serving` experiment and
/// `benches/fig_serving`: a trained MLP served by a replicated
/// [`ServingRuntime`] pool under three scenarios — clean, mid-run stuck-at
/// faults with healing disabled, and the same faults with the background
/// health/heal pass on. Every replica programs the same trained template;
/// per-replica engine seeds decorrelate the hardware noise. The chip
/// reserves six spare groups for remap-to-spare healing, and the `[serving]`
/// knobs come from the config (healing scenarios force a scan period when
/// the config leaves scans off).
pub fn serving_sweep(
    cfg: &SimConfig,
    scale: Scale,
    fault_rate: f64,
) -> anyhow::Result<Vec<ServingPoint>> {
    let (input, hidden, classes) = (784usize, 16usize, 10usize);
    let imgs = scale.pick(320, 768);
    let data = mnist_like::load(imgs, cfg.seed);
    let (train_set, test_set) = data.split(imgs * 4 / 5);
    let mut digital = mlp(input, hidden, classes, None, cfg.seed);
    let tcfg = TrainConfig {
        steps: scale.pick(60, 150),
        batch_size: 32,
        lr: 0.1,
        seed: cfg.seed,
        ..TrainConfig::default()
    };
    train(&mut digital, &train_set, &tcfg);

    let repair = if cfg.repair.verify { cfg.repair.clone() } else { RepairSpec::enabled() };
    // 13 + 1 int8 block groups × 4 digit planes on 64×64 arrays, plus six
    // spare groups for the healer to remap onto.
    let spares = 24usize;
    // `[serving] shards_per_replica > 1` turns the pool mixed: odd
    // replicas shard across that many chips (pipeline stages), even ones
    // stay single-chip — both behind the same queue and heal policy.
    let shards = cfg.serving.shards_per_replica;
    let make = |r: usize, cond: &ReplicaSpec| -> anyhow::Result<ReplicaModel> {
        let mut dpe = cfg.dpe.clone();
        dpe.array = (64, 64);
        if cond.faulty {
            dpe.nonideal.faults = FaultSpec::cells(fault_rate);
        }
        dpe.nonideal.t_read = cond.t_read_s;
        let hw = HwSpec::uniform(
            DotProductEngine::new(dpe, cfg.seed.wrapping_add(1000 + r as u64)),
            SliceMethod::int(SliceSpec::int8()),
        );
        let mut m = mlp(input, hidden, classes, Some(hw), cfg.seed);
        m.load_state_from(&digital);
        m.update_weight();
        if shards > 1 && r % 2 == 1 {
            // Each fleet chip is sized to the biggest layer (the 784-in
            // linear: ceil(784/64) row blocks × 4 int8 planes), so the
            // planner assigns one stage per chip.
            let apt = input.div_ceil(64) * 4;
            let fleet: Vec<ChipSpec> = (0..shards)
                .map(|_| ChipSpec::new(1, apt + spares, (64, 64)).with_spares(spares))
                .collect();
            Ok(ReplicaModel::Sharded(m.compile_sharded(&fleet)?))
        } else {
            let chip = ChipSpec::new(1, m.mapped_planes() + spares, (64, 64)).with_spares(spares);
            Ok(ReplicaModel::Single(m.compile(&chip)?))
        }
    };

    // Open-loop workload from the held-out split; failed requests score
    // zero in the accuracy column.
    let n_req = scale.pick(48, 160);
    let gap = 150u64;
    let horizon = gap * n_req as u64;
    let workload: Vec<Request> = (0..n_req)
        .map(|i| Request {
            arrive_us: i as u64 * gap,
            sample: test_set.sample(i % test_set.len()).to_vec(),
        })
        .collect();
    let labels: Vec<usize> = (0..n_req).map(|i| test_set.labels[i % test_set.len()]).collect();
    let argmax = |row: &[f64]| -> usize {
        row.iter()
            .enumerate()
            .fold(
                (0usize, f64::NEG_INFINITY),
                |best, (i, &v)| if v > best.1 { (i, v) } else { best },
            )
            .0
    };

    let scenarios: [(&str, bool, bool); 3] = [
        ("clean", false, true),
        ("faults, healing off", true, false),
        ("faults, healing on", true, true),
    ];
    let mut points = Vec::new();
    for (label, inject, healing) in scenarios {
        let mut spec = cfg.serving.clone();
        spec.health_period_us = if healing {
            if spec.health_period_us > 0 {
                spec.health_period_us
            } else {
                2_000
            }
        } else {
            0
        };
        let faults: Vec<FaultEvent> = if inject {
            vec![
                FaultEvent { at_us: horizon * 3 / 10, replica: 0 },
                FaultEvent { at_us: horizon * 6 / 10, replica: spec.replicas - 1 },
            ]
        } else {
            Vec::new()
        };
        let mut rt = ServingRuntime::new_mixed(
            spec.clone(),
            repair.clone(),
            vec![input],
            Box::new(|r, c| make(r, c)),
        )?;
        let report = rt.run(&workload, &faults)?;

        let mut correct = 0usize;
        for (i, o) in report.outcomes.iter().enumerate() {
            if let Outcome::Done(c) = o {
                if argmax(&c.output) == labels[i] {
                    correct += 1;
                }
            }
        }
        let (queue_full, deadline_exceeded, retries_exhausted) = report.failure_breakdown();
        let clean_bit_exact = if inject {
            None
        } else {
            // Replay every dispatched batch on a twin replica: the pool's
            // outputs must be bit-identical to direct `infer_batched`.
            let mut exact = true;
            for b in &report.batches {
                let twin = make(b.replica, &ReplicaSpec::default())?;
                let mut data = Vec::with_capacity(b.requests.len() * input);
                for &id in &b.requests {
                    data.extend_from_slice(&workload[id].sample);
                }
                let y = twin.infer_batched(
                    &Tensor::from_vec(&[b.requests.len(), input], data),
                    b.requests.len(),
                );
                let cols = y.data.len() / b.requests.len();
                for (row, &id) in b.requests.iter().enumerate() {
                    let Outcome::Done(c) = &report.outcomes[id] else {
                        exact = false;
                        break;
                    };
                    let want = &y.data[row * cols..(row + 1) * cols];
                    if c.output.iter().zip(want).any(|(a, w)| a.to_bits() != w.to_bits()) {
                        exact = false;
                    }
                }
            }
            Some(exact)
        };
        points.push(ServingPoint {
            label: label.to_string(),
            requests: n_req,
            completed: report.completed(),
            failed: report.failed(),
            queue_full,
            deadline_exceeded,
            retries_exhausted,
            retries: report.total_retries(),
            p50_us: report.percentile_latency_us(0.50).unwrap_or(0),
            p99_us: report.percentile_latency_us(0.99).unwrap_or(0),
            images_per_sec: report.images_per_sec(),
            accuracy: correct as f64 / n_req as f64,
            heals: report.heals.len(),
            moves: report.heals.iter().map(|h| h.moves).sum(),
            fenced: report.heals.iter().map(|h| h.fenced).sum(),
            clean_bit_exact,
        });
    }
    Ok(points)
}

/// The serving-runtime figure: p50/p99 latency, throughput, and accuracy
/// of a replicated pool under open-loop load — clean, faulted with
/// healing off, and faulted with the health/heal pass on.
pub fn fig_serving(cfg: &SimConfig, scale: Scale) -> anyhow::Result<Vec<Table>> {
    let fault_rate = 1e-4;
    let pts = serving_sweep(cfg, scale, fault_rate)?;
    let mut t = Table::new(
        &format!("fig_serving — replicated serving pool (stuck-at {fault_rate} mid-run)"),
        &[
            "scenario", "completed", "failed", "retries", "p50 (µs)", "p99 (µs)", "img/s",
            "accuracy", "heals", "moves", "fenced", "bit-exact",
        ],
    );
    for p in &pts {
        t.row(&[
            p.label.clone(),
            format!("{}/{}", p.completed, p.requests),
            p.failed.to_string(),
            p.retries.to_string(),
            p.p50_us.to_string(),
            p.p99_us.to_string(),
            format!("{:.0}", p.images_per_sec),
            format!("{:.3}", p.accuracy),
            p.heals.to_string(),
            p.moves.to_string(),
            p.fenced.to_string(),
            match p.clean_bit_exact {
                Some(true) => "yes".into(),
                Some(false) => "NO".into(),
                None => "-".into(),
            },
        ]);
    }
    Ok(vec![t])
}

// ---------------------------------------------------------- fig_sharding

/// One scenario of the multi-chip sharding sweep ([`sharding_sweep`]):
/// pipeline throughput, chip-loss failover, and link-fault accounting.
#[derive(Debug, Clone, Default)]
pub struct ShardingPoint {
    pub label: String,
    pub fleet_chips: usize,
    pub stages: usize,
    pub samples: usize,
    /// Samples in completed micro-batches.
    pub completed_samples: usize,
    pub failed_batches: usize,
    pub degraded_batches: usize,
    pub failovers: usize,
    pub link_retries: usize,
    pub corrupt_detected: usize,
    pub makespan_us: u64,
    pub images_per_sec: f64,
    /// Top-1 accuracy over ALL samples (failed batches count as wrong).
    pub accuracy: f64,
    pub conserved: bool,
    /// Clean scenarios only: the assembled pipeline output matched
    /// single-chip `infer_batched` bit for bit (noise-free engines).
    pub bit_exact: Option<bool>,
}

/// Shared driver for the `fig_sharding` experiment and
/// `benches/fig_sharding`: a trained MLP sharded across chip fleets of
/// growing size (noise-free engines, so sharded inference is
/// bit-identical to single-chip), then a chip-loss scenario with
/// failover on vs off, and a lossy-link scenario exercising the
/// retry/checksum path. Fleet knobs come from the `[fleet]` config
/// section; the sweep overrides fault rates per scenario.
pub fn sharding_sweep(cfg: &SimConfig, scale: Scale) -> anyhow::Result<Vec<ShardingPoint>> {
    let (input, hidden, classes) = (784usize, 16usize, 10usize);
    let imgs = scale.pick(320, 768);
    let data = mnist_like::load(imgs, cfg.seed);
    let (train_set, test_set) = data.split(imgs * 4 / 5);
    let mut digital = mlp(input, hidden, classes, None, cfg.seed);
    let tcfg = TrainConfig {
        steps: scale.pick(60, 150),
        batch_size: 32,
        lr: 0.1,
        seed: cfg.seed,
        ..TrainConfig::default()
    };
    train(&mut digital, &train_set, &tcfg);

    // Noise-free engines: the sharded-vs-single bit-identity contract is
    // exact, and failover reprogramming restores the exact weights.
    let make = || -> Sequential {
        let mut dpe = cfg.dpe.clone();
        dpe.array = (64, 64);
        dpe.noise_free = true;
        let hw = HwSpec::uniform(
            DotProductEngine::new(dpe, cfg.seed.wrapping_add(7000)),
            SliceMethod::int(SliceSpec::int8()),
        );
        let mut m = mlp(input, hidden, classes, Some(hw), cfg.seed);
        m.load_state_from(&digital);
        m.update_weight();
        m
    };

    let n = scale.pick(96, 256);
    let mut xdata = Vec::with_capacity(n * input);
    for i in 0..n {
        xdata.extend_from_slice(test_set.sample(i % test_set.len()));
    }
    let x = Tensor::from_vec(&[n, input], xdata);
    let labels: Vec<usize> = (0..n).map(|i| test_set.labels[i % test_set.len()]).collect();
    let argmax = |row: &[f64]| -> usize {
        row.iter()
            .enumerate()
            .fold(
                (0usize, f64::NEG_INFINITY),
                |best, (i, &v)| if v > best.1 { (i, v) } else { best },
            )
            .0
    };

    // The single-chip reference: its placement sizes the fleets (chips
    // hold whole block groups of the biggest layer) and its output is
    // the bit-identity oracle.
    let single = {
        let m = make();
        let chip = ChipSpec::single_tile(m.mapped_planes(), (64, 64));
        m.compile(&chip)?
    };
    let layers_bs: Vec<(usize, usize)> =
        single.placement().layers.iter().map(|lp| (lp.blocks, lp.slices)).collect();
    let p_total: usize = layers_bs.iter().map(|(b, s)| b * s).sum();
    let (b_max, s_max) = layers_bs.iter().copied().max_by_key(|(b, s)| b * s).unwrap_or((1, 1));
    let p_max = b_max * s_max;
    let y_ref = single.infer_batched(&x, n);

    let accuracy_of = |rep: &FleetReport| -> f64 {
        let mut correct = 0usize;
        for (b, out) in rep.outputs.iter().enumerate() {
            let Some(rows) = out else { continue };
            for (j, row) in rows.chunks(classes).enumerate() {
                if argmax(row) == labels[b * rep.micro_batch + j] {
                    correct += 1;
                }
            }
        }
        correct as f64 / n as f64
    };
    let bit_exact_of = |rep: &FleetReport| -> bool {
        rep.output_tensor().is_some_and(|y| {
            y.data.len() == y_ref.data.len()
                && y.data.iter().zip(&y_ref.data).all(|(a, b)| a.to_bits() == b.to_bits())
        })
    };

    let mut clean_spec = cfg.fleet.spec.clone();
    clean_spec.link.drop_rate = 0.0;
    clean_spec.link.corrupt_rate = 0.0;

    let mut points = Vec::new();
    let mut point = |label: String,
                     chips: usize,
                     stages: usize,
                     rep: &FleetReport,
                     bit_exact: Option<bool>| {
        points.push(ShardingPoint {
            label,
            fleet_chips: chips,
            stages,
            samples: n,
            completed_samples: rep.completed_samples(),
            failed_batches: rep.failed(),
            degraded_batches: rep.degraded_batches(),
            failovers: rep.failovers(),
            link_retries: rep.link_retries(),
            corrupt_detected: rep.corrupt_detected(),
            makespan_us: rep.makespan_us,
            images_per_sec: rep.images_per_sec(),
            accuracy: accuracy_of(rep),
            conserved: rep.conserved(),
            bit_exact,
        });
    };

    // Throughput vs fleet size: 1 chip (pipeline of one stage — the
    // baseline under the same clock), 2 chips (layer split), and at full
    // scale 3 chips (the big layer block-splits across two chips).
    let sizes: &[usize] = match scale {
        Scale::Quick => &[1, 2],
        Scale::Full => &[1, 2, 3],
    };
    let mut makespan_2chip = 0u64;
    for &k in sizes {
        let fleet = match k {
            1 => uniform_fleet(1, p_total, (64, 64)),
            2 => uniform_fleet(2, p_max, (64, 64)),
            // Half the big layer's groups per chip: it block-splits
            // across chips 0–1 and the rest pipelines onto chip 2.
            _ => uniform_fleet(3, b_max.div_ceil(2) * s_max, (64, 64)),
        };
        let mut sharded = make().compile_sharded(&fleet)?;
        let rep = sharded.run(&x, &clean_spec, &[])?;
        anyhow::ensure!(rep.conserved(), "fig_sharding: clean fleet={k} run lost samples");
        if k == 2 {
            makespan_2chip = rep.makespan_us;
        }
        let exact = bit_exact_of(&rep);
        point(format!("clean, {k} chip(s)"), k, sharded.stage_count(), &rep, Some(exact));
    }

    // Chip loss mid-run on a 2-stage fleet with one spare: failover
    // re-replicates stage 0 onto the spare; with failover off the same
    // loss condemns the stage in place and accuracy collapses.
    let fault_at = (makespan_2chip / 3).max(1);
    for failover in [true, false] {
        let fleet = uniform_fleet(3, p_max, (64, 64));
        let mut sharded = make().compile_sharded(&fleet)?;
        let mut spec = clean_spec.clone();
        spec.failover = failover;
        let faults = [ChipFaultSpec { at_us: fault_at, chip: 0 }];
        let rep = sharded.run(&x, &spec, &faults)?;
        anyhow::ensure!(rep.conserved(), "fig_sharding: chip-loss run lost samples");
        point(
            format!("chip loss, failover {}", if failover { "on" } else { "off" }),
            3,
            sharded.stage_count(),
            &rep,
            None,
        );
    }

    // Lossy links on the 2-chip fleet: drops and corruptions retry under
    // the hop deadline; every micro-batch still ends Done or Failed.
    {
        let fleet = uniform_fleet(2, p_max, (64, 64));
        let mut sharded = make().compile_sharded(&fleet)?;
        let mut spec = clean_spec.clone();
        spec.link.drop_rate = 0.05;
        spec.link.corrupt_rate = 0.15;
        spec.link.max_retries = 10;
        let rep = sharded.run(&x, &spec, &[])?;
        anyhow::ensure!(rep.conserved(), "fig_sharding: lossy-link run lost samples");
        point("lossy links".into(), 2, sharded.stage_count(), &rep, None);
    }

    Ok(points)
}

/// The multi-chip sharding figure: pipeline throughput vs fleet size
/// (bit-exact against single-chip inference), chip-loss failover vs
/// degraded serving, and link-fault retry/conservation accounting.
pub fn fig_sharding(cfg: &SimConfig, scale: Scale) -> anyhow::Result<Vec<Table>> {
    let pts = sharding_sweep(cfg, scale)?;
    let mut t = Table::new(
        "fig_sharding — model sharded across a chip fleet (pipeline + fault domains)",
        &[
            "scenario", "chips", "stages", "completed", "failed", "degraded", "failovers",
            "link retries", "makespan (µs)", "img/s", "accuracy", "conserved", "bit-exact",
        ],
    );
    for p in &pts {
        t.row(&[
            p.label.clone(),
            p.fleet_chips.to_string(),
            p.stages.to_string(),
            format!("{}/{}", p.completed_samples, p.samples),
            p.failed_batches.to_string(),
            p.degraded_batches.to_string(),
            p.failovers.to_string(),
            p.link_retries.to_string(),
            p.makespan_us.to_string(),
            format!("{:.0}", p.images_per_sec),
            format!("{:.3}", p.accuracy),
            if p.conserved { "yes" } else { "NO" }.into(),
            match p.bit_exact {
                Some(true) => "yes".into(),
                Some(false) => "NO".into(),
                None => "-".into(),
            },
        ]);
    }
    Ok(vec![t])
}

// --------------------------------------------------------------- Fig 13

pub fn fig13_solver(cfg: &SimConfig, scale: Scale) -> Vec<Table> {
    let n = scale.pick(32, 64);
    let mut rng = Pcg64::new(cfg.seed, 0xF13);
    let g_load: Vec<f64> =
        (0..n).map(|_| rng.uniform_range(cfg.dpe.device.lgs, cfg.dpe.device.hgs)).collect();
    let (a, b) = solver::wordline_equation(&g_load, 2.93, 0.2);
    let sw = solver::conjugate_gradient(&a, &b, &solver::MatvecBackend::Software, 1e-10, 400);
    let mut t = Table::new(
        "Fig 13(b) — CG convergence: software vs hardware (block 32x32, pre-aligned)",
        &["solver", "cv", "iters", "best residual", "max |dV| vs software"],
    );
    t.row(&[
        "software".into(),
        "-".into(),
        sw.residuals.len().to_string(),
        fmt_sig(*sw.residuals.last().unwrap()),
        "0".into(),
    ]);
    for cv in [0.0, 0.02, 0.05] {
        let mut dpe_cfg = cfg.dpe.clone();
        dpe_cfg.array = (32, 32);
        dpe_cfg.device.cv = cv;
        dpe_cfg.adc_policy = AdcPolicy::IntegerSnap;
        let engine = DotProductEngine::new(dpe_cfg, cfg.seed);
        let method = SliceMethod::fp(SliceSpec::solver26());
        let backend = solver::MatvecBackend::hardware(&engine, method, &a);
        let hw = solver::conjugate_gradient(&a, &b, &backend, 1e-6, 400);
        let maxdv = hw.x.iter().zip(&sw.x).map(|(h, s)| (h - s).abs()).fold(0.0, f64::max);
        t.row(&[
            "hardware".into(),
            format!("{cv}"),
            hw.residuals.len().to_string(),
            fmt_sig(hw.residuals.iter().cloned().fold(f64::INFINITY, f64::min)),
            fmt_sig(maxdv),
        ]);
    }
    vec![t]
}

// --------------------------------------------------------------- Fig 14

pub fn fig14_cwt(cfg: &SimConfig, scale: Scale) -> Vec<Table> {
    let len = scale.pick(512, 1512);
    let signal = nino::load(len, cfg.seed);
    let scales = cwt::scale_ladder(4.0, 128.0, 4);
    let proc = cwt::CwtProcessor::new(scale.pick(128, 256), scales.clone());
    let digital = proc.power(&signal, None);
    let engine = DotProductEngine::new(cfg.dpe.clone(), cfg.seed);
    let method = cwt::int4_method();
    let hw = proc.power(&signal, Some((&engine, &method)));
    // Per-scale mean power + correlation.
    let mut t = Table::new(
        "Fig 14 — Morlet CWT power: digital vs INT4 hardware mapping",
        &["scale (months)", "digital mean power", "hw mean power", "ratio"],
    );
    for (si, &s) in scales.iter().enumerate().step_by(3) {
        let md = digital.row(si).iter().sum::<f64>() / digital.cols as f64;
        let mh = hw.row(si).iter().sum::<f64>() / hw.cols as f64;
        t.row(&[
            format!("{s:.1}"),
            fmt_sig(md),
            fmt_sig(mh),
            format!("{:.3}", mh / md.max(1e-300)),
        ]);
    }
    let corr = pearson(&digital.data, &hw.data);
    let mut t2 = Table::new("Fig 14 — spectrum agreement", &["metric", "value"]);
    t2.row(&["pearson(digital, hw)".into(), format!("{corr:.4}")]);
    let peak_d = argmax_scale(&digital, &scales);
    let peak_h = argmax_scale(&hw, &scales);
    t2.row(&["peak scale digital (months)".into(), format!("{peak_d:.1}")]);
    t2.row(&["peak scale hardware (months)".into(), format!("{peak_h:.1}")]);
    vec![t, t2]
}

fn argmax_scale(power: &Matrix, scales: &[f64]) -> f64 {
    let means: Vec<f64> =
        (0..power.rows).map(|s| power.row(s).iter().sum::<f64>() / power.cols as f64).collect();
    scales[means.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0]
}

fn pearson(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let cov: f64 = a.iter().zip(b).map(|(x, y)| (x - ma) * (y - mb)).sum();
    let va: f64 = a.iter().map(|x| (x - ma) * (x - ma)).sum();
    let vb: f64 = b.iter().map(|y| (y - mb) * (y - mb)).sum();
    cov / (va.sqrt() * vb.sqrt()).max(1e-300)
}

// --------------------------------------------------------------- Fig 15

pub fn fig15_kmeans(cfg: &SimConfig, scale: Scale) -> Vec<Table> {
    let ds = iris::load(50, cfg.seed);
    let mut x = Matrix::from_vec(ds.len(), 4, ds.features.clone());
    kmeans::min_max_normalize(&mut x);
    let kcfg = kmeans::KmeansConfig { max_iter: scale.pick(15, 30), ..Default::default() };
    let digital = kmeans::kmeans(&x, &kcfg, None);
    let mut t = Table::new(
        "Fig 15 — K-means on IRIS (INT8 slices 1,1,2,4; n=10 tail)",
        &["engine", "cv", "accuracy", "agreement w/ digital", "iterations"],
    );
    let acc_d = kmeans::clustering_accuracy(&digital.assignments, &ds.labels, 3);
    t.row(&["digital".into(), "-".into(), format!("{acc_d:.3}"), "1.000".into(), digital.iterations.to_string()]);
    for cv in [0.02, 0.05] {
        let mut dpe_cfg = cfg.dpe.clone();
        dpe_cfg.device.cv = cv;
        let engine = DotProductEngine::new(dpe_cfg, cfg.seed + 1);
        let method = kmeans::int8_method();
        let hw = kmeans::kmeans(&x, &kcfg, Some((&engine, &method)));
        t.row(&[
            "hardware".into(),
            format!("{cv}"),
            format!("{:.3}", kmeans::clustering_accuracy(&hw.assignments, &ds.labels, 3)),
            format!("{:.3}", kmeans::clustering_accuracy(&hw.assignments, &digital.assignments, 3)),
            hw.iterations.to_string(),
        ]);
    }
    vec![t]
}

// --------------------------------------------------------------- Fig 16

pub fn fig16_training(cfg: &SimConfig, scale: Scale) -> Vec<Table> {
    let n_train = scale.pick(512, 2048);
    let data = mnist_like::load(n_train + 256, cfg.seed);
    let (train_set, test_set) = data.split(n_train);
    let steps = scale.pick(60, 300);
    let tcfg = TrainConfig {
        steps,
        batch_size: 32,
        lr: 0.05,
        log_every: (steps / 10).max(1),
        seed: cfg.seed,
        ..Default::default()
    };
    let mut t = Table::new(
        "Fig 16 — LeNet-5 hardware-aware training (loss / train acc / test acc)",
        &["format", "first loss", "last loss", "final train acc", "test acc"],
    );
    let mut curves = Table::new(
        "Fig 16 curves — loss per logged step",
        &["format", "step", "loss", "train acc"],
    );
    let formats: Vec<(&str, Option<SliceMethod>)> = vec![
        ("full precision", None),
        ("INT4 (1,1,2)", Some(SliceMethod::int(SliceSpec::int4()))),
        ("INT8 (1,1,2,4)", Some(SliceMethod::int(SliceSpec::int8()))),
        ("FP16 (1,1,2,4,4)", Some(SliceMethod::fp(SliceSpec::fp16()))),
    ];
    let mut fast = Table::new(
        "Fig 16 fast loop — template-delta reprogramming + packed backward",
        &[
            "format",
            "legacy steps/s",
            "fast steps/s",
            "speedup",
            "reprogram share",
            "dirty blocks",
            "fast test acc",
        ],
    );
    for (name, method) in formats {
        let hw = method
            .map(|m| HwSpec::uniform(DotProductEngine::new(cfg.dpe.clone(), cfg.seed), m));
        let mut model = lenet5(hw.clone(), cfg.seed);
        let t0 = std::time::Instant::now();
        let logs = train(&mut model, &train_set, &tcfg);
        let legacy_secs = t0.elapsed().as_secs_f64();
        let test_acc = evaluate(&mut model, &test_set, 32, scale.pick(128, 256));
        for l in &logs {
            curves.row(&[name.into(), l.step.to_string(), format!("{:.4}", l.loss), format!("{:.3}", l.train_acc)]);
        }
        t.row(&[
            name.into(),
            format!("{:.4}", logs.first().unwrap().loss),
            format!("{:.4}", logs.last().unwrap().loss),
            format!("{:.3}", logs.last().unwrap().train_acc),
            format!("{:.3}", test_acc),
        ]);
        // Same seeds through the fast loop: delta reprogramming, packed
        // gradient GEMMs, reused batch buffers.
        let mut model_fast = lenet5(hw, cfg.seed);
        let t1 = std::time::Instant::now();
        let rep = train_fast(&mut model_fast, &train_set, &tcfg);
        let fast_secs = t1.elapsed().as_secs_f64();
        let fast_acc = evaluate(&mut model_fast, &test_set, 32, scale.pick(128, 256));
        fast.row(&[
            name.into(),
            format!("{:.2}", steps as f64 / legacy_secs),
            format!("{:.2}", steps as f64 / fast_secs),
            format!("{:.2}x", legacy_secs / fast_secs),
            format!("{:.0}%", 100.0 * rep.reprogram_s / fast_secs.max(1e-12)),
            format!("{}/{}", rep.delta.dirty_blocks(), rep.delta.blocks),
            format!("{:.3}", fast_acc),
        ]);
    }
    // CIFAR-scale point: ResNet-18 under INT8 through the fast loop only —
    // per-step full-array reprogramming at this size is exactly the cost
    // the delta path removes.
    let cifar_steps = scale.pick(3, 20);
    let n_cifar = scale.pick(64, 384);
    let cdata = cifar_like::load(n_cifar + 32, cfg.seed + 1);
    let (ctrain, ctest) = cdata.split(n_cifar);
    let chw = HwSpec::uniform(
        DotProductEngine::new(cfg.dpe.clone(), cfg.seed),
        SliceMethod::int(SliceSpec::int8()),
    );
    let mut cmodel = resnet18_cifar(scale.pick(1, 2), Some(chw), cfg.seed);
    let ccfg = TrainConfig {
        steps: cifar_steps,
        batch_size: 8,
        lr: 0.02,
        log_every: (cifar_steps / 4).max(1),
        seed: cfg.seed,
        ..Default::default()
    };
    let t2 = std::time::Instant::now();
    let crep = train_fast(&mut cmodel, &ctrain, &ccfg);
    let cifar_secs = t2.elapsed().as_secs_f64();
    let cacc = evaluate(&mut cmodel, &ctest, 8, scale.pick(16, 32));
    fast.row(&[
        "ResNet-18/CIFAR INT8 (fast only)".into(),
        "-".into(),
        format!("{:.2}", cifar_steps as f64 / cifar_secs),
        "-".into(),
        format!("{:.0}%", 100.0 * crep.reprogram_s / cifar_secs.max(1e-12)),
        format!("{}/{}", crep.delta.dirty_blocks(), crep.delta.blocks),
        format!("{cacc:.3}"),
    ]);
    vec![t, curves, fast]
}

// --------------------------------------------------------------- Fig 17

/// Build a CIFAR model by architecture name; unknown names are a proper
/// error propagated through the experiment `run` path (not a panic).
fn cifar_model(
    arch: &str,
    width: usize,
    hw: Option<HwSpec>,
    seed: u64,
) -> anyhow::Result<Sequential> {
    match arch {
        "resnet18" => Ok(resnet18_cifar(width, hw, seed)),
        "vgg16" => Ok(vgg16_cifar(width, hw, seed)),
        _ => anyhow::bail!("unknown CIFAR architecture '{arch}' (expected resnet18 or vgg16)"),
    }
}

/// Train a small digital CIFAR model once, then evaluate it under varying
/// hardware configurations (the paper's direct-mapping inference flow).
fn trained_cifar_model(
    arch: &str,
    width: usize,
    train_imgs: usize,
    steps: usize,
    seed: u64,
) -> anyhow::Result<(Sequential, crate::data::Dataset)> {
    let data = cifar_like::load(train_imgs + 256, seed);
    let (train_set, test_set) = data.split(train_imgs);
    let mut model = cifar_model(arch, width, None, seed)?;
    let tcfg = TrainConfig {
        steps,
        batch_size: 16,
        lr: 0.02,
        log_every: steps,
        seed,
        ..Default::default()
    };
    let _ = train(&mut model, &train_set, &tcfg);
    Ok((model, test_set))
}

/// Rebuild the model with hardware layers and copy the trained weights in
/// (the paper's `torch.load_state_dict` + `update_weight()` flow). The
/// donor model is only read.
fn to_hardware(
    arch: &str,
    width: usize,
    seed: u64,
    digital: &Sequential,
    hw: HwSpec,
) -> anyhow::Result<Sequential> {
    let mut model = cifar_model(arch, width, Some(hw), seed)?;
    // `load_state_dict` + `update_weight()` flow: parameters AND buffers
    // (BatchNorm running stats) transfer, then the arrays are programmed.
    model.load_state_from(digital);
    model.update_weight();
    Ok(model)
}

/// Compile a hardware model onto the configured `[chip]`, or — when the
/// config has none — a chip auto-sized to the model's array demand
/// (64-array tiles). Capacity errors propagate with the allocator's
/// per-layer report.
fn map_onto_chip(cfg: &SimConfig, model: Sequential) -> anyhow::Result<MappedModel> {
    let chip = match &cfg.chip {
        Some(c) => c.clone(),
        None => model.auto_chip(64, cfg.dpe.array),
    };
    model.compile(&chip)
}

/// Placement/utilization tables for one mapped model (the coordinator's
/// chip report): per-tile occupancy and the per-layer placement map.
fn placement_tables(tag: &str, m: &MappedModel) -> (Table, Table) {
    let p = m.placement();
    let mut tiles = Table::new(
        &format!("Fig 17 — per-tile utilization ({tag})"),
        &["tile", "arrays used", "capacity", "utilization"],
    );
    let cap = p.chip.arrays_per_tile;
    for (t, &used) in p.used_per_tile.iter().enumerate() {
        tiles.row(&[
            t.to_string(),
            used.to_string(),
            cap.to_string(),
            format!("{:.1}%", 100.0 * used as f64 / cap as f64),
        ]);
    }
    let mut layers = Table::new(
        &format!("Fig 17 — per-layer placement ({tag})"),
        &["layer", "kind", "blocks", "slices/block", "arrays", "tiles", "condemned"],
    );
    let condemned = m.condemned_per_layer();
    for (li, lp) in p.layers.iter().enumerate() {
        layers.row(&[
            lp.layer.to_string(),
            lp.name.to_string(),
            lp.blocks.to_string(),
            lp.slices.to_string(),
            lp.planes().to_string(),
            format!("{}..={}", lp.tile_first, lp.tile_last),
            condemned.get(li).copied().unwrap_or(0).to_string(),
        ]);
    }
    (tiles, layers)
}

pub fn fig17_inference(cfg: &SimConfig, scale: Scale) -> anyhow::Result<Vec<Table>> {
    let width = scale.pick(4, 6);
    let train_imgs = scale.pick(256, 768);
    let steps = scale.pick(40, 120);
    let eval_imgs = scale.pick(64, 128);
    let micro_batch = 8;
    let mut t1 = Table::new(
        "Fig 17(a) — accuracy vs number of 1-bit slices",
        &["model", "digital acc", "3 bits", "4 bits", "5 bits", "6 bits", "8 bits"],
    );
    let mut t2 = Table::new(
        "Fig 17(b) — accuracy vs conductance variation (INT8)",
        &["model", "cv=0", "cv=0.02", "cv=0.05", "cv=0.1"],
    );
    // Chip report for the headline mapping (first INT8 resnet18 compile).
    let mut chip_tables: Option<(Table, Table)> = None;
    for arch in ["resnet18", "vgg16"] {
        let (mut digital, test_set) = trained_cifar_model(arch, width, train_imgs, steps, cfg.seed)?;
        let acc_digital = evaluate(&mut digital, &test_set, 16, eval_imgs);
        // (a) slice-bit sweep at low noise — every evaluation runs through
        // the chip-mapped batched inference runtime.
        let mut row1 = vec![arch.to_string(), format!("{acc_digital:.3}")];
        for bits in [3usize, 4, 5, 6, 8] {
            let mut dpe_cfg = cfg.dpe.clone();
            dpe_cfg.device.cv = 0.01;
            let hw = HwSpec::uniform(
                DotProductEngine::new(dpe_cfg, cfg.seed),
                SliceMethod::int(SliceSpec::ones(bits)),
            );
            let mapped = map_onto_chip(cfg, to_hardware(arch, width, cfg.seed, &digital, hw)?)?;
            row1.push(format!(
                "{:.3}",
                evaluate_mapped(&mapped, &test_set, 16, eval_imgs, micro_batch)
            ));
        }
        t1.row(&row1);
        // (b) variation sweep at INT8.
        let mut row2 = vec![arch.to_string()];
        for cv in [0.0, 0.02, 0.05, 0.1] {
            let mut dpe_cfg = cfg.dpe.clone();
            dpe_cfg.device.cv = cv;
            let hw = HwSpec::uniform(
                DotProductEngine::new(dpe_cfg, cfg.seed),
                SliceMethod::int(SliceSpec::int8()),
            );
            let mapped = map_onto_chip(cfg, to_hardware(arch, width, cfg.seed, &digital, hw)?)?;
            if chip_tables.is_none() {
                let tag = format!("{arch} int8, w={width}");
                chip_tables = Some(placement_tables(&tag, &mapped));
            }
            row2.push(format!(
                "{:.3}",
                evaluate_mapped(&mapped, &test_set, 16, eval_imgs, micro_batch)
            ));
        }
        t2.row(&row2);
    }
    let (t3, t4) = chip_tables.expect("at least one INT8 mapping ran");
    Ok(vec![t1, t2, t3, t4])
}

// -------------------------------------------------------------- Table 3

pub fn table3_throughput(cfg: &SimConfig, scale: Scale) -> Vec<Table> {
    let mut t = Table::new(
        "Table 3 — inference throughput (img/s), FP16 slices (1,1,2,4,4)",
        &["dataset", "model", "batch", "backend", "img/s", "latency/batch"],
    );
    let method = SliceMethod::fp(SliceSpec::fp16());
    // LeNet-5 on digit data — native engine.
    let data = mnist_like::load(64, cfg.seed);
    let batch = scale.pick(16, 32);
    let hw = HwSpec::uniform(DotProductEngine::new(cfg.dpe.clone(), cfg.seed), method.clone());
    let mut lenet = lenet5(Some(hw), cfg.seed);
    let idx: Vec<usize> = (0..batch).collect();
    let (x, _) = crate::nn::train::make_batch(&data, &idx);
    let timing = time_it(1, scale.pick(2, 5), || {
        let _ = lenet.forward(&x, false);
    });
    t.row(&[
        "MNIST-like".into(),
        "LeNet-5".into(),
        batch.to_string(),
        "native".into(),
        format!("{:.1}", timing.throughput(batch as f64)),
        fmt_duration(timing.mean_s),
    ]);
    // LeNet-5 via the fused XLA artifact, when built.
    if let Ok(rt) = crate::runtime::Runtime::cpu(&cfg.artifacts_dir) {
        let xd = crate::runtime::XlaDpe::new(rt);
        if xd.runtime().has_artifact("lenet_fwd_b32_int8") {
            let xf: Vec<f32> = data.features[..32 * 784].iter().map(|&v| v as f32).collect();
            let params = lenet_params_f32(&mut lenet);
            let timing = time_it(1, scale.pick(3, 10), || {
                let _ = xd.lenet_forward(32, "int8", false, &xf, &params, 1).unwrap();
            });
            t.row(&[
                "MNIST-like".into(),
                "LeNet-5".into(),
                "32".into(),
                "xla (AOT pallas)".into(),
                format!("{:.1}", timing.throughput(32.0)),
                fmt_duration(timing.mean_s),
            ]);
        }
    }
    // CIFAR models — native only (document relative ordering).
    let cdata = cifar_like::load(scale.pick(8, 16), cfg.seed);
    for (arch, width) in [("resnet18", scale.pick(4, 8)), ("vgg16", scale.pick(4, 8))] {
        let hw = HwSpec::uniform(DotProductEngine::new(cfg.dpe.clone(), cfg.seed), method.clone());
        let mut model = match arch {
            "resnet18" => resnet18_cifar(width, Some(hw), cfg.seed),
            _ => vgg16_cifar(width, Some(hw), cfg.seed),
        };
        let b = scale.pick(4, 8);
        let idx: Vec<usize> = (0..b).collect();
        let (x, _) = crate::nn::train::make_batch(&cdata, &idx);
        let timing = time_it(0, scale.pick(1, 3), || {
            let _ = model.forward(&x, false);
        });
        t.row(&[
            "CIFAR-like".into(),
            format!("{arch} (w={width})"),
            b.to_string(),
            "native".into(),
            format!("{:.2}", timing.throughput(b as f64)),
            fmt_duration(timing.mean_s),
        ]);
    }
    vec![t]
}

/// Extract LeNet parameter buffers as f32 in `lenet_fwd` artifact order.
pub fn lenet_params_f32(model: &mut Sequential) -> Vec<(Vec<usize>, Vec<f32>)> {
    // Artifact order: conv1_w (6,25), conv1_b, conv2_w (16,150), conv2_b,
    // fc1_w (256,120), fc1_b, fc2_w, fc2_b, fc3_w, fc3_b.
    // LinearMem stores (in,out) = artifact layout; Conv2dMem stores
    // (out_c, patch) = artifact layout. visit order matches construction.
    let shapes: Vec<Vec<usize>> = vec![
        vec![6, 25], vec![6], vec![16, 150], vec![16],
        vec![256, 120], vec![120], vec![120, 84], vec![84],
        vec![84, 10], vec![10],
    ];
    let mut bufs: Vec<Vec<f32>> = Vec::new();
    model.visit_params(&mut |p| bufs.push(p.value.iter().map(|&v| v as f32).collect()));
    assert_eq!(bufs.len(), shapes.len(), "unexpected LeNet parameter count");
    shapes.into_iter().zip(bufs).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> SimConfig {
        SimConfig::default()
    }

    #[test]
    fn registry_lists_all_paper_artifacts() {
        assert_eq!(EXPERIMENTS.len(), 14);
        assert!(EXPERIMENTS.iter().any(|(id, _)| *id == "table3_throughput"));
        assert!(EXPERIMENTS.iter().any(|(id, _)| *id == "fig_faults"));
        assert!(EXPERIMENTS.iter().any(|(id, _)| *id == "fig_repair"));
        assert!(EXPERIMENTS.iter().any(|(id, _)| *id == "fig_serving"));
        assert!(EXPERIMENTS.iter().any(|(id, _)| *id == "fig_sharding"));
    }

    #[test]
    fn unknown_experiment_is_error_with_suggestion() {
        let err = run("nope", &quick_cfg(), Scale::Quick).unwrap_err().to_string();
        assert!(err.contains("did you mean"), "{err}");
        // A near-miss suggests the experiment the user meant.
        let err = run("fig_repar", &quick_cfg(), Scale::Quick).unwrap_err().to_string();
        assert!(err.contains("fig_repair"), "{err}");
        assert_eq!(closest_experiment("fig_fautls"), "fig_faults");
        assert_eq!(closest_experiment("table3"), "table3_throughput");
    }

    #[test]
    fn fig03_quick_runs() {
        let t = fig03_device(&quick_cfg(), Scale::Quick);
        assert_eq!(t[0].rows.len(), 6);
    }

    #[test]
    fn fig11_quick_runs() {
        let t = fig11_precision(&quick_cfg(), Scale::Quick);
        assert_eq!(t[0].rows.len(), 4);
    }

    #[test]
    fn fig15_quick_runs() {
        let t = fig15_kmeans(&quick_cfg(), Scale::Quick);
        assert!(t[0].rows.len() >= 3);
    }

    #[test]
    fn fig_faults_quick_runs_and_tables_well_formed() {
        let tables = fig_faults(&quick_cfg(), Scale::Quick);
        assert_eq!(tables.len(), 3);
        // (a): bits × cv × rate grid fully populated.
        assert_eq!(tables[0].rows.len(), 2 * 2 * 3);
        // Yield column parses and stays within [0, 1].
        for row in &tables[0].rows {
            let y: f64 = row.last().unwrap().parse().unwrap();
            assert!((0.0..=1.0).contains(&y), "yield {y}");
        }
        assert_eq!(tables[1].rows.len(), 5);
        assert_eq!(tables[2].rows.len(), 5);
    }

    #[test]
    fn fig_repair_quick_runs_and_clean_point_needs_no_repair() {
        let cycles = 2;
        let pts = repair_sweep(&quick_cfg(), cycles, &[0.0], &[0, 4], 0.5).unwrap();
        assert_eq!(pts.len(), 2);
        for p in &pts {
            assert_eq!(p.re_before.len(), cycles);
            assert_eq!(p.moves, 0, "clean chip must not move blocks");
            assert_eq!(p.unplaced, 0);
            assert_eq!(p.retries, 0, "clean programming must converge first try");
            assert_eq!(p.degraded_cycles, 0);
            assert!(p.probe_matmuls > 0, "probes must run even on a clean chip");
            assert_eq!(
                p.re_before, p.re_after,
                "a repair round that moves nothing must leave the bits untouched"
            );
        }
        // Heavy stuck-at with zero spares: everything condemned degrades
        // gracefully (the sweep completes instead of erroring).
        let pts = repair_sweep(&quick_cfg(), 1, &[0.05], &[0], 0.5).unwrap();
        assert!(pts[0].unplaced > 0, "zero spares must leave condemned groups behind");
        assert_eq!(pts[0].degraded_cycles, 1);
        assert!(pts[0].retries > 0);
    }
}

//! The framework coordinator: typed simulation config (Table 2 defaults +
//! TOML overrides), the experiment registry behind the CLI, and run
//! orchestration (engine construction, backend routing, report emission).

pub mod experiments;

pub use experiments::{closest_experiment, run as run_experiment, Scale, EXPERIMENTS};

use crate::arch::{ChipSpec, FleetSpec, LinkSpec, ServingSpec};
use crate::device::drift::DriftSpec;
use crate::device::faults::{AdcErrorSpec, AdcRounding, FaultSpec};
use crate::device::DeviceSpec;
use crate::dpe::engine::AdcPolicy;
use crate::dpe::{DotProductEngine, DpeConfig, RepairSpec, SliceMethod};
use crate::nn::HwSpec;
use crate::util::config::Doc;
use std::path::Path;

/// Fully-resolved simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub dpe: DpeConfig,
    pub seed: u64,
    /// "native" or "xla" (AOT artifacts via PJRT where available).
    pub backend: String,
    pub artifacts_dir: String,
    /// Default slice method name for examples (e.g. "int8").
    pub method: String,
    /// Chip geometry for network mapping (`[chip]` section). `None` means
    /// experiments auto-size a chip to the model they map
    /// ([`crate::nn::Sequential::auto_chip`], which reserves slack for
    /// group-spill fragmentation — plain [`ChipSpec::fit`] does not).
    pub chip: Option<ChipSpec>,
    /// Closed-loop repair policy (`[repair]` section). The default all-off
    /// spec keeps every path bit-identical to unverified programming; a
    /// bare `[repair]` section enables verification with the
    /// [`RepairSpec::enabled`] defaults.
    pub repair: RepairSpec,
    /// Fault-tolerant serving runtime knobs (`[serving]` section,
    /// `crate::arch::serve`): pool size, queue bound, micro-batching,
    /// deadlines/retries, and the background heal cadence. The defaults
    /// apply whether or not the section is present; the `serve`
    /// subcommand and `fig_serving` experiment consume them.
    pub serving: ServingSpec,
    /// Multi-chip sharded execution knobs (`[fleet]` section,
    /// `crate::arch::fleet`): fleet size, spare chips, and the
    /// pipeline/link/failover model. Like `[serving]`, the defaults
    /// apply whether or not the section appears; the `fig_sharding`
    /// experiment and `serve --shards` consume them.
    pub fleet: FleetConfig,
}

/// Resolved `[fleet]` section: how many chips a sharded model is planned
/// across, how many idle spares back them, and the [`FleetSpec`]
/// execution model (see [`crate::arch::fleet`]).
#[derive(Debug, Clone, PartialEq)]
pub struct FleetConfig {
    /// Pipeline chips a sharded model is planned across (stage owners).
    pub chips: usize,
    /// Extra chips kept idle as failover spares.
    pub spare_chips: usize,
    /// Pipeline service-time, inter-chip link, and failover model.
    pub spec: FleetSpec,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig { chips: 2, spare_chips: 1, spec: FleetSpec::default() }
    }
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            dpe: DpeConfig::default(),
            seed: 2024,
            backend: "native".into(),
            artifacts_dir: "artifacts".into(),
            method: "int8".into(),
            chip: None,
            repair: RepairSpec::none(),
            serving: ServingSpec::default(),
            fleet: FleetConfig::default(),
        }
    }
}

/// Reject keys in `section` that no typed loader reads — a typo'd knob is
/// an error naming the offending path, not a silently-ignored setting.
fn reject_unknown_keys(doc: &Doc, section: &str, known: &[&str]) -> anyhow::Result<()> {
    for key in doc.keys(section) {
        anyhow::ensure!(
            known.contains(&key.as_str()),
            "config key `{section}.{key}` is not recognized (known `[{section}]` keys: {})",
            known.join(", ")
        );
    }
    Ok(())
}

impl SimConfig {
    /// Load from a TOML-subset file (missing keys keep Table-2 defaults).
    /// Malformed typed values — e.g. an `array_size` that is not a
    /// two-element array of non-negative integers — are errors naming the
    /// offending key, not silently ignored.
    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let doc = Doc::load(path)?;
        Self::from_doc(&doc)
    }

    pub fn from_doc(doc: &Doc) -> anyhow::Result<Self> {
        let mut cfg = SimConfig::default();
        let d = &mut cfg.dpe;
        d.device = DeviceSpec {
            hgs: doc.f64_or("engine", "hgs", 1e-5),
            lgs: doc.f64_or("engine", "lgs", 1e-7),
            g_levels: doc.usize_or("engine", "g_levels", 16),
            cv: doc.f64_or("engine", "var", 0.05),
            read_cv: doc.f64_or("engine", "read_var", 0.0),
        };
        d.rdac = doc.usize_or("engine", "rdac", 256);
        d.radc = doc.usize_or("engine", "radc", 1024);
        if let Some(arr) = doc.usize_array("engine", "array_size")? {
            anyhow::ensure!(
                arr.len() == 2 && arr[0] > 0 && arr[1] > 0,
                "config key `engine.array_size`: expected two positive integers, got {arr:?}"
            );
            d.array = (arr[0], arr[1]);
        }
        d.noise_free = doc.bool_or("engine", "noise_free", false);
        d.use_circuit = doc.bool_or("engine", "use_circuit", false);
        d.r_wire = doc.f64_or("engine", "r_wire", 2.93);
        d.adc_policy = match doc.str_or("engine", "adc_policy", "worst_case") {
            "calibrated" => AdcPolicy::Calibrated,
            "integer_snap" => AdcPolicy::IntegerSnap,
            _ => AdcPolicy::WorstCase,
        };
        // [faults] — unified non-ideality injection (all-off by default;
        // see `device::faults` for knob semantics and sources).
        reject_unknown_keys(
            doc,
            "faults",
            &[
                "sa0", "sa1", "dead_row", "dead_col", "t_read", "drift_nu", "drift_nu_std",
                "drift_t0", "adc_gain_std", "adc_offset_lsb", "adc_rounding", "seed",
            ],
        )?;
        let ni = &mut d.nonideal;
        ni.faults = FaultSpec {
            sa0: doc.f64_or("faults", "sa0", 0.0),
            sa1: doc.f64_or("faults", "sa1", 0.0),
            dead_row: doc.f64_or("faults", "dead_row", 0.0),
            dead_col: doc.f64_or("faults", "dead_col", 0.0),
        };
        ni.t_read = doc.f64_or("faults", "t_read", 0.0);
        ni.drift = DriftSpec {
            nu: doc.f64_or("faults", "drift_nu", ni.drift.nu),
            nu_std: doc.f64_or("faults", "drift_nu_std", ni.drift.nu_std),
            t0: doc.f64_or("faults", "drift_t0", ni.drift.t0),
        };
        ni.adc = AdcErrorSpec {
            gain_std: doc.f64_or("faults", "adc_gain_std", 0.0),
            offset_std_lsb: doc.f64_or("faults", "adc_offset_lsb", 0.0),
            rounding: match doc.str_or("faults", "adc_rounding", "round") {
                "floor" => AdcRounding::Floor,
                _ => AdcRounding::Round,
            },
        };
        ni.seed = doc.usize_or("faults", "seed", ni.seed as usize) as u64;
        // [chip] — tile hierarchy for network mapping (crate::arch). The
        // array shape is the engine's: a chip hosts arrays of one geometry.
        reject_unknown_keys(doc, "chip", &["tiles", "arrays_per_tile", "spares_per_tile"])?;
        if doc.sections().any(|s| s == "chip") {
            let tiles = doc.usize_or("chip", "tiles", 16);
            let apt = doc.usize_or("chip", "arrays_per_tile", 64);
            anyhow::ensure!(
                tiles > 0 && apt > 0,
                "config section `[chip]`: tiles and arrays_per_tile must be positive \
                 (got tiles = {tiles}, arrays_per_tile = {apt})"
            );
            let spares = doc.usize_or("chip", "spares_per_tile", 0);
            anyhow::ensure!(
                spares < apt,
                "config key `chip.spares_per_tile`: {spares} spares leave no data arrays \
                 in a {apt}-array tile"
            );
            cfg.chip = Some(ChipSpec::new(tiles, apt, d.array).with_spares(spares));
        }
        // [repair] — closed-loop program-and-verify / probe / remap policy
        // (crate::arch::repair). Absent section → all-off (bit-identical
        // programming); a bare section enables verification.
        reject_unknown_keys(
            doc,
            "repair",
            &["verify", "tolerance", "max_retries", "probe_re_bound", "probe_vectors"],
        )?;
        if doc.sections().any(|s| s == "repair") {
            let def = RepairSpec::enabled();
            cfg.repair = RepairSpec {
                verify: doc.bool_or("repair", "verify", def.verify),
                tolerance: doc.f64_or("repair", "tolerance", def.tolerance),
                max_retries: doc.usize_or("repair", "max_retries", def.max_retries),
                probe_re_bound: doc.f64_or("repair", "probe_re_bound", def.probe_re_bound),
                probe_vectors: doc.usize_or("repair", "probe_vectors", def.probe_vectors),
            };
            anyhow::ensure!(
                cfg.repair.tolerance >= 0.0,
                "config key `repair.tolerance`: must be non-negative, got {}",
                cfg.repair.tolerance
            );
            anyhow::ensure!(
                (1..=2).contains(&cfg.repair.probe_vectors),
                "config key `repair.probe_vectors`: expected 1 or 2, got {}",
                cfg.repair.probe_vectors
            );
        }
        // [serving] — fault-tolerant serving runtime (crate::arch::serve).
        // All times are simulated microseconds; the defaults match
        // `ServingSpec::default()` whether or not the section appears.
        reject_unknown_keys(
            doc,
            "serving",
            &[
                "replicas", "queue_capacity", "max_batch", "batch_deadline_us",
                "request_deadline_us", "max_retries", "retry_backoff_us", "health_period_us",
                "heal_us", "service_base_us", "service_per_sample_us", "drift_refresh",
                "shards_per_replica",
            ],
        )?;
        if doc.sections().any(|s| s == "serving") {
            let def = ServingSpec::default();
            cfg.serving = ServingSpec {
                replicas: doc.usize_or("serving", "replicas", def.replicas),
                queue_capacity: doc.usize_or("serving", "queue_capacity", def.queue_capacity),
                max_batch: doc.usize_or("serving", "max_batch", def.max_batch),
                batch_deadline_us: doc.usize_or(
                    "serving",
                    "batch_deadline_us",
                    def.batch_deadline_us as usize,
                ) as u64,
                request_deadline_us: doc.usize_or(
                    "serving",
                    "request_deadline_us",
                    def.request_deadline_us as usize,
                ) as u64,
                max_retries: doc.usize_or("serving", "max_retries", def.max_retries),
                retry_backoff_us: doc.usize_or(
                    "serving",
                    "retry_backoff_us",
                    def.retry_backoff_us as usize,
                ) as u64,
                health_period_us: doc.usize_or(
                    "serving",
                    "health_period_us",
                    def.health_period_us as usize,
                ) as u64,
                heal_us: doc.usize_or("serving", "heal_us", def.heal_us as usize) as u64,
                service_base_us: doc.usize_or(
                    "serving",
                    "service_base_us",
                    def.service_base_us as usize,
                ) as u64,
                service_per_sample_us: doc.usize_or(
                    "serving",
                    "service_per_sample_us",
                    def.service_per_sample_us as usize,
                ) as u64,
                drift_refresh: doc.bool_or("serving", "drift_refresh", def.drift_refresh),
                shards_per_replica: doc.usize_or(
                    "serving",
                    "shards_per_replica",
                    def.shards_per_replica,
                ),
            };
            anyhow::ensure!(
                cfg.serving.replicas >= 1,
                "config key `serving.replicas`: pool needs at least one replica, got {}",
                cfg.serving.replicas
            );
            anyhow::ensure!(
                cfg.serving.queue_capacity >= 1,
                "config key `serving.queue_capacity`: must be >= 1, got {}",
                cfg.serving.queue_capacity
            );
            anyhow::ensure!(
                cfg.serving.max_batch >= 1,
                "config key `serving.max_batch`: must be >= 1, got {}",
                cfg.serving.max_batch
            );
            anyhow::ensure!(
                cfg.serving.shards_per_replica >= 1,
                "config key `serving.shards_per_replica`: must be >= 1, got {}",
                cfg.serving.shards_per_replica
            );
        }
        // [fleet] — multi-chip sharded execution (crate::arch::fleet):
        // fleet sizing plus the pipeline service, inter-chip link, and
        // failover model. Defaults match `FleetConfig::default()`.
        reject_unknown_keys(
            doc,
            "fleet",
            &[
                "chips", "spare_chips", "micro_batch", "service_base_us",
                "service_per_sample_us", "failover", "failover_us", "link_base_us",
                "link_per_sample_us", "hop_deadline_us", "link_retries", "link_backoff_us",
                "drop_rate", "corrupt_rate", "seed",
            ],
        )?;
        if doc.sections().any(|s| s == "fleet") {
            let def = FleetConfig::default();
            let ds = &def.spec;
            cfg.fleet = FleetConfig {
                chips: doc.usize_or("fleet", "chips", def.chips),
                spare_chips: doc.usize_or("fleet", "spare_chips", def.spare_chips),
                spec: FleetSpec {
                    micro_batch: doc.usize_or("fleet", "micro_batch", ds.micro_batch),
                    service_base_us: doc.usize_or(
                        "fleet",
                        "service_base_us",
                        ds.service_base_us as usize,
                    ) as u64,
                    service_per_sample_us: doc.usize_or(
                        "fleet",
                        "service_per_sample_us",
                        ds.service_per_sample_us as usize,
                    ) as u64,
                    link: LinkSpec {
                        base_us: doc.usize_or("fleet", "link_base_us", ds.link.base_us as usize)
                            as u64,
                        per_sample_us: doc.usize_or(
                            "fleet",
                            "link_per_sample_us",
                            ds.link.per_sample_us as usize,
                        ) as u64,
                        hop_deadline_us: doc.usize_or(
                            "fleet",
                            "hop_deadline_us",
                            ds.link.hop_deadline_us as usize,
                        ) as u64,
                        max_retries: doc.usize_or("fleet", "link_retries", ds.link.max_retries),
                        retry_backoff_us: doc.usize_or(
                            "fleet",
                            "link_backoff_us",
                            ds.link.retry_backoff_us as usize,
                        ) as u64,
                        drop_rate: doc.f64_or("fleet", "drop_rate", ds.link.drop_rate),
                        corrupt_rate: doc.f64_or("fleet", "corrupt_rate", ds.link.corrupt_rate),
                    },
                    failover: doc.bool_or("fleet", "failover", ds.failover),
                    failover_us: doc.usize_or("fleet", "failover_us", ds.failover_us as usize)
                        as u64,
                    seed: doc.usize_or("fleet", "seed", ds.seed as usize) as u64,
                },
            };
            anyhow::ensure!(
                cfg.fleet.chips >= 1,
                "config key `fleet.chips`: a sharded pipeline needs at least one chip, got {}",
                cfg.fleet.chips
            );
            anyhow::ensure!(
                cfg.fleet.spec.micro_batch >= 1,
                "config key `fleet.micro_batch`: must be >= 1, got {}",
                cfg.fleet.spec.micro_batch
            );
            for (key, v) in [
                ("drop_rate", cfg.fleet.spec.link.drop_rate),
                ("corrupt_rate", cfg.fleet.spec.link.corrupt_rate),
            ] {
                anyhow::ensure!(
                    (0.0..=1.0).contains(&v),
                    "config key `fleet.{key}`: expected a probability in [0, 1], got {v}"
                );
            }
        }
        cfg.seed = doc.usize_or("run", "seed", 2024) as u64;
        cfg.backend = doc.str_or("run", "backend", "native").to_string();
        cfg.artifacts_dir = doc.str_or("run", "artifacts_dir", "artifacts").to_string();
        cfg.method = doc.str_or("run", "method", "int8").to_string();
        Ok(cfg)
    }

    /// Build an engine from this config.
    pub fn engine(&self) -> DotProductEngine {
        DotProductEngine::new(self.dpe.clone(), self.seed)
    }

    /// Build a hardware layer spec with the configured default method.
    pub fn hw_spec(&self) -> anyhow::Result<HwSpec> {
        let method = SliceMethod::parse(&self.method)?;
        Ok(HwSpec::uniform(self.engine(), method))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_table2() {
        let cfg = SimConfig::default();
        assert_eq!(cfg.dpe.device.hgs, 1e-5);
        assert_eq!(cfg.dpe.device.lgs, 1e-7);
        assert_eq!(cfg.dpe.device.g_levels, 16);
        assert_eq!(cfg.dpe.device.cv, 0.05);
        assert_eq!(cfg.dpe.rdac, 256);
        assert_eq!(cfg.dpe.radc, 1024);
        assert_eq!(cfg.dpe.array, (64, 64));
    }

    #[test]
    fn overrides_apply() {
        let doc = Doc::parse(
            "[engine]\nvar = 0.1\nread_var = 0.02\narray_size = [32, 32]\nadc_policy = \"calibrated\"\n[run]\nseed = 7\nmethod = \"fp16\"\n",
        )
        .unwrap();
        let cfg = SimConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.dpe.device.cv, 0.1);
        assert_eq!(cfg.dpe.device.read_cv, 0.02);
        assert_eq!(cfg.dpe.array, (32, 32));
        assert_eq!(cfg.dpe.adc_policy, AdcPolicy::Calibrated);
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.method, "fp16");
        assert!(cfg.chip.is_none());
        assert!(cfg.hw_spec().is_ok());
    }

    #[test]
    fn malformed_array_size_is_an_error_naming_the_key() {
        for toml in [
            "[engine]\narray_size = \"64x64\"\n",
            "[engine]\narray_size = [64]\n",
            "[engine]\narray_size = [64, 0]\n",
            "[engine]\narray_size = [64, -64]\n",
        ] {
            let doc = Doc::parse(toml).unwrap();
            let err = SimConfig::from_doc(&doc).unwrap_err().to_string();
            assert!(err.contains("engine.array_size"), "{toml}: {err}");
        }
    }

    #[test]
    fn chip_section_parses_and_validates() {
        let doc = Doc::parse(
            "[engine]\narray_size = [32, 32]\n[chip]\ntiles = 4\narrays_per_tile = 24\n",
        )
        .unwrap();
        let cfg = SimConfig::from_doc(&doc).unwrap();
        let chip = cfg.chip.expect("chip section parsed");
        assert_eq!((chip.tiles, chip.arrays_per_tile), (4, 24));
        assert_eq!(chip.array, (32, 32));
        // Defaults when the section is present but empty.
        let cfg =
            SimConfig::from_doc(&Doc::parse("[chip]\n").unwrap()).unwrap();
        let chip = cfg.chip.unwrap();
        assert_eq!((chip.tiles, chip.arrays_per_tile), (16, 64));
        // Zero geometry is rejected.
        let err = SimConfig::from_doc(&Doc::parse("[chip]\ntiles = 0\n").unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("[chip]"), "{err}");
    }

    #[test]
    fn faults_section_defaults_off_and_overrides_apply() {
        // No [faults] section → the all-off spec (bit-identical engine).
        let cfg = SimConfig::from_doc(&Doc::parse("[engine]\nvar = 0.05\n").unwrap()).unwrap();
        assert!(cfg.dpe.nonideal.is_none());
        let doc = Doc::parse(
            "[faults]\nsa0 = 0.01\nsa1 = 0.02\ndead_row = 0.005\nt_read = 1e4\n\
             drift_nu = 0.08\nadc_gain_std = 0.02\nadc_offset_lsb = 0.5\n\
             adc_rounding = \"floor\"\nseed = 99\n",
        )
        .unwrap();
        let cfg = SimConfig::from_doc(&doc).unwrap();
        let ni = &cfg.dpe.nonideal;
        assert_eq!(ni.faults.sa0, 0.01);
        assert_eq!(ni.faults.sa1, 0.02);
        assert_eq!(ni.faults.dead_row, 0.005);
        assert_eq!(ni.t_read, 1e4);
        assert_eq!(ni.drift.nu, 0.08);
        assert_eq!(ni.adc.gain_std, 0.02);
        assert_eq!(ni.adc.offset_std_lsb, 0.5);
        assert_eq!(ni.adc.rounding, AdcRounding::Floor);
        assert_eq!(ni.seed, 99);
        assert!(ni.drift_enabled() && !ni.is_none());
    }

    #[test]
    fn repair_section_parses_and_spares_apply() {
        let cfg = SimConfig::from_doc(&Doc::parse("[engine]\n").unwrap()).unwrap();
        assert!(!cfg.repair.verify, "absent [repair] must stay all-off");
        let doc = Doc::parse(
            "[chip]\ntiles = 2\narrays_per_tile = 16\nspares_per_tile = 4\n\
             [repair]\ntolerance = 2.5\nmax_retries = 5\nprobe_re_bound = 0.1\n\
             probe_vectors = 1\n",
        )
        .unwrap();
        let cfg = SimConfig::from_doc(&doc).unwrap();
        let chip = cfg.chip.unwrap();
        assert_eq!(chip.spares_per_tile, 4);
        assert_eq!(chip.data_arrays_per_tile(), 12);
        assert!(cfg.repair.verify, "a [repair] section enables verification");
        assert_eq!(cfg.repair.tolerance, 2.5);
        assert_eq!(cfg.repair.max_retries, 5);
        assert_eq!(cfg.repair.probe_re_bound, 0.1);
        assert_eq!(cfg.repair.probe_vectors, 1);
        // Degenerate values are errors naming the key.
        let doc = Doc::parse("[chip]\narrays_per_tile = 4\nspares_per_tile = 4\n").unwrap();
        let err = SimConfig::from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("chip.spares_per_tile"), "{err}");
        let doc = Doc::parse("[repair]\nprobe_vectors = 3\n").unwrap();
        let err = SimConfig::from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("repair.probe_vectors"), "{err}");
    }

    #[test]
    fn serving_section_parses_and_validates() {
        // No section → defaults.
        let cfg = SimConfig::from_doc(&Doc::parse("[engine]\n").unwrap()).unwrap();
        assert_eq!(cfg.serving, ServingSpec::default());
        let doc = Doc::parse(
            "[serving]\nreplicas = 3\nqueue_capacity = 64\nmax_batch = 4\n\
             batch_deadline_us = 1500\nrequest_deadline_us = 30000\nmax_retries = 1\n\
             retry_backoff_us = 250\nhealth_period_us = 5000\nheal_us = 2000\n\
             service_base_us = 120\nservice_per_sample_us = 30\ndrift_refresh = true\n",
        )
        .unwrap();
        let s = SimConfig::from_doc(&doc).unwrap().serving;
        assert_eq!(s.replicas, 3);
        assert_eq!(s.queue_capacity, 64);
        assert_eq!(s.max_batch, 4);
        assert_eq!(s.batch_deadline_us, 1_500);
        assert_eq!(s.request_deadline_us, 30_000);
        assert_eq!(s.max_retries, 1);
        assert_eq!(s.retry_backoff_us, 250);
        assert_eq!(s.health_period_us, 5_000);
        assert_eq!(s.heal_us, 2_000);
        assert_eq!(s.service_base_us, 120);
        assert_eq!(s.service_per_sample_us, 30);
        assert!(s.drift_refresh);
        // A bare section keeps the defaults too.
        let cfg = SimConfig::from_doc(&Doc::parse("[serving]\n").unwrap()).unwrap();
        assert_eq!(cfg.serving, ServingSpec::default());
        // Degenerate values are errors naming the key.
        for (toml, path) in [
            ("[serving]\nreplicas = 0\n", "serving.replicas"),
            ("[serving]\nqueue_capacity = 0\n", "serving.queue_capacity"),
            ("[serving]\nmax_batch = 0\n", "serving.max_batch"),
        ] {
            let err = SimConfig::from_doc(&Doc::parse(toml).unwrap()).unwrap_err().to_string();
            assert!(err.contains(path), "{toml}: {err}");
        }
    }

    #[test]
    fn fleet_section_parses_with_defaults_and_validates() {
        // No section (or a bare one) → the FleetConfig defaults.
        let cfg = SimConfig::from_doc(&Doc::parse("[engine]\n").unwrap()).unwrap();
        assert_eq!(cfg.fleet, FleetConfig::default());
        let cfg = SimConfig::from_doc(&Doc::parse("[fleet]\n").unwrap()).unwrap();
        assert_eq!(cfg.fleet, FleetConfig::default());

        let doc = Doc::parse(
            "[fleet]\nchips = 4\nspare_chips = 2\nmicro_batch = 16\nfailover = false\n\
             failover_us = 5000\nlink_base_us = 10\nlink_per_sample_us = 2\n\
             hop_deadline_us = 800\nlink_retries = 5\nlink_backoff_us = 40\n\
             drop_rate = 0.25\ncorrupt_rate = 0.125\nseed = 99\n",
        )
        .unwrap();
        let f = SimConfig::from_doc(&doc).unwrap().fleet;
        assert_eq!(f.chips, 4);
        assert_eq!(f.spare_chips, 2);
        assert_eq!(f.spec.micro_batch, 16);
        assert!(!f.spec.failover);
        assert_eq!(f.spec.failover_us, 5000);
        assert_eq!(f.spec.link.base_us, 10);
        assert_eq!(f.spec.link.per_sample_us, 2);
        assert_eq!(f.spec.link.hop_deadline_us, 800);
        assert_eq!(f.spec.link.max_retries, 5);
        assert_eq!(f.spec.link.retry_backoff_us, 40);
        assert_eq!(f.spec.link.drop_rate, 0.25);
        assert_eq!(f.spec.link.corrupt_rate, 0.125);
        assert_eq!(f.spec.seed, 99);

        // Degenerate values are errors naming `fleet.<key>`.
        for (toml, path) in [
            ("[fleet]\nchips = 0\n", "fleet.chips"),
            ("[fleet]\nmicro_batch = 0\n", "fleet.micro_batch"),
            ("[fleet]\ndrop_rate = 1.5\n", "fleet.drop_rate"),
            ("[fleet]\ncorrupt_rate = -0.5\n", "fleet.corrupt_rate"),
        ] {
            let err = SimConfig::from_doc(&Doc::parse(toml).unwrap()).unwrap_err().to_string();
            assert!(err.contains(path), "{toml}: {err}");
        }
    }

    #[test]
    fn serving_shards_per_replica_parses_and_validates() {
        let s = SimConfig::from_doc(&Doc::parse("[serving]\nshards_per_replica = 3\n").unwrap())
            .unwrap()
            .serving;
        assert_eq!(s.shards_per_replica, 3);
        let err =
            SimConfig::from_doc(&Doc::parse("[serving]\nshards_per_replica = 0\n").unwrap())
                .unwrap_err()
                .to_string();
        assert!(err.contains("serving.shards_per_replica"), "{err}");
    }

    #[test]
    fn unknown_keys_in_validated_sections_are_errors_naming_the_path() {
        for (toml, path) in [
            ("[faults]\nsa2 = 0.1\n", "faults.sa2"),
            ("[chip]\nspare = 1\n", "chip.spare"),
            ("[repair]\ntollerance = 1.0\n", "repair.tollerance"),
            ("[serving]\nreplica_count = 2\n", "serving.replica_count"),
            ("[fleet]\nchip_count = 2\n", "fleet.chip_count"),
        ] {
            let err = SimConfig::from_doc(&Doc::parse(toml).unwrap()).unwrap_err().to_string();
            assert!(err.contains(path), "{toml}: {err}");
        }
        // [engine] and [run] stay lenient: sample configs carry
        // backend-specific keys the native loader does not read.
        let doc = Doc::parse("[engine]\nbackend = \"native\"\n").unwrap();
        assert!(SimConfig::from_doc(&doc).is_ok());
    }

    #[test]
    fn bad_method_is_error() {
        let mut cfg = SimConfig::default();
        cfg.method = "nope".into();
        assert!(cfg.hw_spec().is_err());
    }
}

//! Shared substrates built from scratch for the offline environment:
//! deterministic RNG + distributions, a mini property-test harness, a
//! TOML-subset config system, reporting/timing helpers, and scoped-thread
//! parallel maps.

pub mod config;
pub mod parallel;
pub mod prop;
pub mod report;
pub mod rng;

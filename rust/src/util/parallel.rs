//! Scoped-thread parallel helpers (no tokio/rayon offline): a chunked
//! parallel map used by the Monte-Carlo driver and the batched NN forward,
//! and a parallel for-each over mutable chunks used by the GEMM row bands.
//!
//! Both schedulers are lock-free: workers claim work items with a single
//! shared atomic counter (`fetch_add`) instead of popping a mutex-guarded
//! queue, so sub-millisecond items don't serialize on the lock.
//!
//! A panic inside a work item is caught on the worker, stops the claim
//! loops, and is re-thrown with its original payload on the calling thread
//! once the scope joins — so a failing assertion in a kernel points at the
//! kernel, not at a scheduler internals `expect`.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Mutex, Once};

/// Number of worker threads to use: `MEMINTELLI_THREADS` env override, else
/// available parallelism, capped at 16. The override is parsed strictly
/// instead of silently ignored: `0` (a degenerate pool) clamps to 1
/// (serial) and unparseable values fall back to auto-detection — each with
/// a one-time warning on stderr.
pub fn worker_count() -> usize {
    let auto = || std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16);
    match std::env::var("MEMINTELLI_THREADS") {
        Ok(s) => match s.trim().parse::<usize>() {
            Ok(0) => {
                static WARN_ZERO: Once = Once::new();
                WARN_ZERO.call_once(|| {
                    eprintln!(
                        "warning: MEMINTELLI_THREADS=0 is not a valid pool size; \
                         clamping to 1 (serial)"
                    );
                });
                1
            }
            Ok(n) => n,
            Err(_) => {
                static WARN_PARSE: Once = Once::new();
                WARN_PARSE.call_once(|| {
                    eprintln!(
                        "warning: ignoring unparseable MEMINTELLI_THREADS={s:?} \
                         (want an integer >= 1); using auto-detected parallelism"
                    );
                });
                auto()
            }
        },
        Err(_) => auto(),
    }
}

/// First panic payload captured across the workers of one scheduler call,
/// plus the abort flag that makes the remaining claim loops drain fast.
struct PanicTrap {
    payload: Mutex<Option<Box<dyn Any + Send>>>,
    abort: AtomicBool,
}

impl PanicTrap {
    fn new() -> Self {
        PanicTrap { payload: Mutex::new(None), abort: AtomicBool::new(false) }
    }

    /// Run one work item, capturing a panic instead of unwinding through
    /// the scoped-thread join (which would surface as an opaque scheduler
    /// error on the caller). Returns `false` if the scheduler should stop.
    fn run(&self, item: impl FnOnce()) -> bool {
        if self.abort.load(Ordering::Relaxed) {
            return false;
        }
        if let Err(p) = catch_unwind(AssertUnwindSafe(item)) {
            // Keep the FIRST payload (a poisoned mutex just means another
            // worker is storing its own payload — ours loses the race).
            let mut slot = self.payload.lock().unwrap_or_else(|e| e.into_inner());
            slot.get_or_insert(p);
            self.abort.store(true, Ordering::Relaxed);
            return false;
        }
        true
    }

    /// Re-throw the captured payload (if any) on the calling thread.
    fn rethrow(self) {
        let payload = self.payload.into_inner().unwrap_or_else(|e| e.into_inner());
        if let Some(p) = payload {
            resume_unwind(p);
        }
    }
}

/// Parallel map over `0..n`: runs `f(i)` on a pool of scoped threads and
/// returns results in index order. `f` must be `Sync` (called from many
/// threads); per-iteration state should be derived from `i` (e.g. RNG
/// streams), which keeps results deterministic regardless of thread count.
/// If any `f(i)` panics, the first panic is re-thrown here with its
/// original payload.
pub fn par_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = worker_count().min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let trap = PanicTrap::new();
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots_ptr = SendPtr(slots.as_mut_ptr());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let next = &next;
            let trap = &trap;
            let f = &f;
            let slots_ptr = &slots_ptr;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let alive = trap.run(|| {
                    let v = f(i);
                    // SAFETY: each index i is claimed exactly once via the
                    // atomic counter, so no two threads write the same
                    // slot, and the scope guarantees the buffer outlives
                    // all workers.
                    unsafe { *slots_ptr.0.add(i) = Some(v) };
                });
                if !alive {
                    break;
                }
            });
        }
    });
    trap.rethrow();
    // Reachable only when no worker panicked, so every slot was filled.
    slots.into_iter().map(|s| s.expect("par_map slot unfilled")).collect()
}

/// Wrapper making a raw pointer Send+Sync for the scoped-thread pattern
/// above (disjoint index writes only).
struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Parallel for-each over `0..n` without collecting results: the same
/// lock-free atomic-counter claim loop as [`par_map`], for callers whose
/// work items write their own (pairwise disjoint) output regions — e.g.
/// the 2-D (row-band × panel-group) grid of the stacked digit-plane GEMM
/// in `tensor`, where items of one matmul target interleaved row/column
/// regions of a shared buffer that no chunking scheme can hand out as
/// contiguous `&mut` chunks. Worker panics re-throw here with the original
/// payload.
pub fn par_for<F>(n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let workers = worker_count().min(n.max(1));
    if workers <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let trap = PanicTrap::new();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let next = &next;
            let trap = &trap;
            let f = &f;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                if !trap.run(|| f(i)) {
                    break;
                }
            });
        }
    });
    trap.rethrow();
}

/// Parallel for-each over mutable chunks of a slice. Work distribution
/// uses the same lock-free atomic-counter scheme as [`par_map`]: each
/// worker claims the next chunk index with one `fetch_add`, so there is no
/// queue mutex to serialize on when chunks are sub-millisecond (the GEMM
/// row-band case). Worker panics re-throw here with the original payload.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let mut chunks: Vec<&mut [T]> = data.chunks_mut(chunk).collect();
    let n = chunks.len();
    let workers = worker_count().min(n.max(1));
    if workers <= 1 {
        for (i, c) in chunks.into_iter().enumerate() {
            f(i, c);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let trap = PanicTrap::new();
    let chunks_ptr = SendPtr(chunks.as_mut_ptr());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let next = &next;
            let trap = &trap;
            let f = &f;
            let chunks_ptr = &chunks_ptr;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let alive = trap.run(|| {
                    // SAFETY: each index i is claimed exactly once via the
                    // atomic counter, the chunk slices are pairwise
                    // disjoint, and the scope guarantees `chunks` outlives
                    // all workers.
                    let c: &mut [T] = unsafe { &mut *(*chunks_ptr.0.add(i)) };
                    f(i, c);
                });
                if !alive {
                    break;
                }
            });
        }
    });
    trap.rethrow();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let out = par_map(1000, |i| i * 2);
        assert_eq!(out, (0..1000).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_handles_small_n() {
        assert_eq!(par_map(0, |i| i), Vec::<usize>::new());
        assert_eq!(par_map(1, |i| i + 5), vec![5]);
    }

    #[test]
    fn par_chunks_mut_touches_all() {
        let mut data = vec![0u32; 103];
        par_chunks_mut(&mut data, 10, |i, c| {
            for v in c.iter_mut() {
                *v = i as u32 + 1;
            }
        });
        // Every element written exactly once, with the right chunk index.
        let want: Vec<u32> = (0..103u32).map(|j| j / 10 + 1).collect();
        assert_eq!(data, want);
    }

    #[test]
    fn par_chunks_mut_many_small_chunks() {
        // Stress the lock-free claim loop: more chunks than workers by far.
        let mut data = vec![0usize; 4096];
        par_chunks_mut(&mut data, 1, |i, c| {
            c[0] = i * 3 + 1;
        });
        assert!(data.iter().enumerate().all(|(i, &v)| v == i * 3 + 1));
    }

    #[test]
    fn par_for_runs_every_index_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let hits: Vec<AtomicUsize> = (0..500).map(|_| AtomicUsize::new(0)).collect();
        par_for(500, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        par_for(0, |_| panic!("no items"));
    }

    #[test]
    fn worker_count_env_override() {
        // Can't set env safely across tests; just check bounds.
        assert!(worker_count() >= 1);
    }

    /// The message a caught-and-rethrown worker panic carries, or `None`
    /// if `body` completed.
    fn caught_message(body: impl FnOnce() + std::panic::UnwindSafe) -> Option<String> {
        match catch_unwind(body) {
            Ok(()) => None,
            Err(p) => Some(
                p.downcast_ref::<String>()
                    .cloned()
                    .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string payload>".into()),
            ),
        }
    }

    #[test]
    fn par_map_rethrows_original_panic_payload() {
        // The bug this guards: a worker panic used to surface as the
        // unrelated "par_map slot unfilled" expect. Many items so the
        // parallel path engages regardless of the pool size.
        let msg = caught_message(|| {
            let _ = par_map(400, |i| {
                if i == 137 {
                    panic!("kernel assertion at item {i}");
                }
                i
            });
        });
        let msg = msg.expect("par_map must propagate the worker panic");
        assert!(msg.contains("kernel assertion at item 137"), "got: {msg}");
        assert!(!msg.contains("slot unfilled"), "got: {msg}");
    }

    #[test]
    fn par_for_rethrows_original_panic_payload() {
        let msg = caught_message(|| {
            par_for(400, |i| {
                if i == 73 {
                    panic!("region writer died at {i}");
                }
            });
        });
        assert!(msg.expect("must propagate").contains("region writer died at 73"));
    }

    #[test]
    fn par_chunks_mut_rethrows_original_panic_payload() {
        let msg = caught_message(|| {
            let mut data = vec![0u8; 512];
            par_chunks_mut(&mut data, 1, |i, _c| {
                if i == 99 {
                    panic!("band writer died at {i}");
                }
            });
        });
        assert!(msg.expect("must propagate").contains("band writer died at 99"));
    }
}

//! Scoped-thread parallel helpers (no tokio/rayon offline): a chunked
//! parallel map used by the Monte-Carlo driver and the batched NN forward.

/// Number of worker threads to use: `MEMINTELLI_THREADS` env override, else
/// available parallelism, capped at 16.
pub fn worker_count() -> usize {
    if let Ok(s) = std::env::var("MEMINTELLI_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Parallel map over `0..n`: runs `f(i)` on a pool of scoped threads and
/// returns results in index order. `f` must be `Sync` (called from many
/// threads); per-iteration state should be derived from `i` (e.g. RNG
/// streams), which keeps results deterministic regardless of thread count.
pub fn par_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = worker_count().min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots_ptr = SendPtr(slots.as_mut_ptr());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let next = &next;
            let f = &f;
            let slots_ptr = &slots_ptr;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                // SAFETY: each index i is claimed exactly once via the atomic
                // counter, so no two threads write the same slot, and the
                // scope guarantees the buffer outlives all workers.
                unsafe { *slots_ptr.0.add(i) = Some(v) };
            });
        }
    });
    slots.into_iter().map(|s| s.expect("par_map slot unfilled")).collect()
}

/// Wrapper making a raw pointer Send+Sync for the scoped-thread pattern
/// above (disjoint index writes only).
struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Parallel for-each over mutable chunks of a slice.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk).enumerate().collect();
    let workers = worker_count().min(chunks.len().max(1));
    if workers <= 1 {
        for (i, c) in chunks {
            f(i, c);
        }
        return;
    }
    let queue = std::sync::Mutex::new(chunks);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let queue = &queue;
            let f = &f;
            scope.spawn(move || loop {
                let item = queue.lock().unwrap().pop();
                match item {
                    Some((i, c)) => f(i, c),
                    None => break,
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let out = par_map(1000, |i| i * 2);
        assert_eq!(out, (0..1000).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_handles_small_n() {
        assert_eq!(par_map(0, |i| i), Vec::<usize>::new());
        assert_eq!(par_map(1, |i| i + 5), vec![5]);
    }

    #[test]
    fn par_chunks_mut_touches_all() {
        let mut data = vec![0u32; 103];
        par_chunks_mut(&mut data, 10, |i, c| {
            for v in c.iter_mut() {
                *v = i as u32 + 1;
            }
        });
        assert!(data.iter().all(|&v| v > 0));
    }

    #[test]
    fn worker_count_env_override() {
        // Can't set env safely across tests; just check bounds.
        assert!(worker_count() >= 1);
    }
}

//! Configuration system: a TOML-subset parser plus the typed simulation
//! config structs used across the framework.
//!
//! The offline registry has no `serde`/`toml`, so we parse a pragmatic TOML
//! subset ourselves: `[section]` headers, `key = value` with strings, bools,
//! integers, floats, and flat arrays (`[1, 1, 2, 4]`), `#` comments. This
//! covers every config the framework ships (see `memintelli.toml`).

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

/// A parsed TOML-subset value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_usize_array(&self) -> Option<Vec<usize>> {
        match self {
            Value::Array(xs) => xs.iter().map(|v| v.as_usize()).collect(),
            _ => None,
        }
    }
    pub fn as_f64_array(&self) -> Option<Vec<f64>> {
        match self {
            Value::Array(xs) => xs.iter().map(|v| v.as_f64()).collect(),
            _ => None,
        }
    }
}

/// Parse error with line information.
#[derive(Debug, Clone)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parsed document: `section.key -> Value`. Keys outside any section live in
/// the `""` section.
#[derive(Debug, Clone, Default)]
pub struct Doc {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc, ParseError> {
        let mut doc = Doc::default();
        let mut section = String::new();
        for (idx, raw) in text.lines().enumerate() {
            let line = idx + 1;
            let trimmed = strip_comment(raw).trim().to_string();
            if trimmed.is_empty() {
                continue;
            }
            if let Some(name) = trimmed.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| ParseError { line, msg: "unterminated section header".into() })?;
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let eq = trimmed
                .find('=')
                .ok_or_else(|| ParseError { line, msg: format!("expected key = value, got '{trimmed}'") })?;
            let key = trimmed[..eq].trim().to_string();
            if key.is_empty() {
                return Err(ParseError { line, msg: "empty key".into() });
            }
            let value = parse_value(trimmed[eq + 1..].trim(), line)?;
            doc.sections.entry(section.clone()).or_default().insert(key, value);
        }
        Ok(doc)
    }

    pub fn load(path: &Path) -> anyhow::Result<Doc> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Doc::parse(&text)?)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    pub fn sections(&self) -> impl Iterator<Item = &String> {
        self.sections.keys()
    }

    /// All keys present in `section`, in sorted order (empty when the
    /// section is absent). Typed loaders use this to reject unknown keys
    /// with an error naming the offending path instead of silently
    /// ignoring a typo'd knob.
    pub fn keys(&self, section: &str) -> impl Iterator<Item = &String> {
        self.sections.get(section).into_iter().flat_map(|s| s.keys())
    }

    /// The `section.key` value as an array of non-negative integers:
    /// `Ok(None)` when the key is absent (defaults apply), an error naming
    /// the offending key path when it is present but malformed — typed
    /// config loaders ([`SimConfig::load`]) propagate it instead of
    /// panicking or silently ignoring the key.
    ///
    /// [`SimConfig::load`]: crate::coordinator::SimConfig::load
    pub fn usize_array(&self, section: &str, key: &str) -> anyhow::Result<Option<Vec<usize>>> {
        match self.get(section, key) {
            None => Ok(None),
            Some(v) => match v.as_usize_array() {
                Some(xs) => Ok(Some(xs)),
                None => Err(anyhow::anyhow!(
                    "config key `{section}.{key}`: expected an array of non-negative integers, got {v:?}"
                )),
            },
        }
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(Value::as_f64).unwrap_or(default)
    }
    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        self.get(section, key).and_then(Value::as_usize).unwrap_or(default)
    }
    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(Value::as_bool).unwrap_or(default)
    }
    pub fn str_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).and_then(Value::as_str).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a double-quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, line: usize) -> Result<Value, ParseError> {
    let s = s.trim();
    if s.is_empty() {
        return Err(ParseError { line, msg: "empty value".into() });
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| ParseError { line, msg: "unterminated string".into() })?;
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| ParseError { line, msg: "unterminated array".into() })?;
        let mut items = Vec::new();
        let inner = inner.trim();
        if !inner.is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_value(part.trim(), line)?);
            }
        }
        return Ok(Value::Array(items));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(ParseError { line, msg: format!("cannot parse value '{s}'") })
}

/// Split on commas that are not nested inside brackets/strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# MemIntelli defaults (Table 2 of the paper)
[engine]
hgs = 1e-5       # high conductance state (S)
lgs = 1e-7
g_levels = 16
var = 0.05
rdac = 256
radc = 1024
array_size = [64, 64]
backend = "native"
noise_free = false

[training]
lr = 0.01
slices = [1, 1, 2, 4]
"#;

    #[test]
    fn parses_table2_defaults() {
        let doc = Doc::parse(SAMPLE).unwrap();
        assert_eq!(doc.f64_or("engine", "hgs", 0.0), 1e-5);
        assert_eq!(doc.f64_or("engine", "lgs", 0.0), 1e-7);
        assert_eq!(doc.usize_or("engine", "g_levels", 0), 16);
        assert_eq!(doc.f64_or("engine", "var", 0.0), 0.05);
        assert_eq!(doc.usize_or("engine", "rdac", 0), 256);
        assert_eq!(doc.usize_or("engine", "radc", 0), 1024);
        assert_eq!(
            doc.get("engine", "array_size").unwrap().as_usize_array().unwrap(),
            vec![64, 64]
        );
        assert_eq!(doc.str_or("engine", "backend", ""), "native");
        assert!(!doc.bool_or("engine", "noise_free", true));
        assert_eq!(
            doc.get("training", "slices").unwrap().as_usize_array().unwrap(),
            vec![1, 1, 2, 4]
        );
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let doc = Doc::parse("[a]\nx = 1\n").unwrap();
        assert_eq!(doc.f64_or("a", "y", 2.5), 2.5);
        assert_eq!(doc.usize_or("b", "x", 7), 7);
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let doc = Doc::parse("# only comments\n\n  # indented\n").unwrap();
        assert_eq!(doc.sections().count(), 0);
    }

    #[test]
    fn string_with_hash_preserved() {
        let doc = Doc::parse("[s]\nname = \"a#b\"\n").unwrap();
        assert_eq!(doc.str_or("s", "name", ""), "a#b");
    }

    #[test]
    fn nested_arrays() {
        let doc = Doc::parse("[s]\nblocks = [[32, 32], [64, 64]]\n").unwrap();
        let items = match doc.get("s", "blocks") {
            Some(Value::Array(items)) => items,
            other => unreachable!("parser must yield an array for `blocks`, got {other:?}"),
        };
        assert_eq!(items.len(), 2);
        assert_eq!(items[0].as_usize_array().unwrap(), vec![32, 32]);
    }

    #[test]
    fn typed_array_accessor_reports_key_path() {
        let doc = Doc::parse("[engine]\narray_size = \"nope\"\nok = [1, 2]\n").unwrap();
        let err = doc.usize_array("engine", "array_size").unwrap_err().to_string();
        assert!(err.contains("engine.array_size"), "{err}");
        assert!(err.contains("expected an array"), "{err}");
        // Negative entries are malformed too (usize semantics).
        let doc = Doc::parse("[engine]\narray_size = [64, -64]\n").unwrap();
        assert!(doc.usize_array("engine", "array_size").is_err());
        // Present-and-valid and absent keys succeed.
        let doc = Doc::parse("[engine]\narray_size = [32, 16]\n").unwrap();
        assert_eq!(doc.usize_array("engine", "array_size").unwrap(), Some(vec![32, 16]));
        assert_eq!(doc.usize_array("engine", "missing").unwrap(), None);
    }

    #[test]
    fn keys_enumerates_section_contents() {
        let doc = Doc::parse("[a]\nx = 1\ny = 2\n[b]\nz = 3\n").unwrap();
        let ks: Vec<&String> = doc.keys("a").collect();
        assert_eq!(ks, ["x", "y"]);
        assert_eq!(doc.keys("b").count(), 1);
        assert_eq!(doc.keys("missing").count(), 0);
    }

    #[test]
    fn error_reports_line() {
        let err = Doc::parse("[s]\nkey value\n").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn rejects_bad_value() {
        assert!(Doc::parse("[s]\nx = @nope\n").is_err());
        assert!(Doc::parse("[s\nx = 1\n").is_err());
        assert!(Doc::parse("[s]\nx = \"unterminated\n").is_err());
        assert!(Doc::parse("[s]\nx = [1, 2\n").is_err());
    }

    #[test]
    fn negative_and_scientific_numbers() {
        let doc = Doc::parse("[s]\na = -3\nb = -1.5e-4\n").unwrap();
        assert_eq!(doc.get("s", "a").unwrap().as_i64().unwrap(), -3);
        assert_eq!(doc.f64_or("s", "b", 0.0), -1.5e-4);
    }
}

//! Deterministic pseudo-random number generation and the statistical
//! distributions MemIntelli's device models need.
//!
//! The container has no access to the `rand`/`rand_distr` crates, so this is
//! a from-scratch implementation of:
//! - PCG64 (O'Neill's permuted congruential generator, 128-bit state,
//!   XSL-RR output) — fast, high-quality, reproducible across platforms;
//! - uniform, standard normal (Box–Muller with caching), and lognormal
//!   sampling, the latter parameterized exactly as Eq. (1) of the paper:
//!   `sigma = sqrt(ln(cv^2 + 1))`, `mu = ln(E[G]) - sigma^2/2`.

/// PCG-XSL-RR-128/64: 128-bit LCG state, 64-bit output.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Cached second Box–Muller variate.
    cached_normal: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Different streams with
    /// the same seed are statistically independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64 | 0xda3e_39cb_94b9_5bdb) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc, cached_normal: None };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Seed-only constructor on the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0x853c_49e6_748f_ea9b)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift; bias is negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (caches the paired variate).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        // Avoid u == 0 so ln(u) is finite.
        let u = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with explicit mean / std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal sample with target mean `e_g` and coefficient of variation
    /// `cv` (std/mean), per Eq. (1) of the paper. Returns `e_g` exactly when
    /// `cv == 0`.
    pub fn lognormal_cv(&mut self, e_g: f64, cv: f64) -> f64 {
        if cv <= 0.0 || e_g <= 0.0 {
            return e_g;
        }
        let (mu, sigma) = lognormal_params(e_g, cv);
        (mu + sigma * self.normal()).exp()
    }

    /// Fill a slice with lognormal samples.
    pub fn fill_lognormal_cv(&mut self, out: &mut [f64], e_g: f64, cv: f64) {
        if cv <= 0.0 || e_g <= 0.0 {
            out.fill(e_g);
            return;
        }
        let (mu, sigma) = lognormal_params(e_g, cv);
        for v in out.iter_mut() {
            *v = (mu + sigma * self.normal()).exp();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Split off an independent child generator (for per-thread streams).
    pub fn split(&mut self, stream: u64) -> Pcg64 {
        Pcg64::new(self.next_u64(), stream)
    }
}

/// Eq. (1): lognormal `(mu, sigma)` such that the distribution has mean
/// `e_g` and coefficient of variation `cv`.
///
/// `sigma = sqrt(ln(cv^2 + 1))`; we use the exact mean-preserving
/// `mu = ln(E[G]) - sigma^2 / 2` (the paper prints `- sigma/2`, a typo: the
/// exact lognormal mean is `exp(mu + sigma^2/2)`).
#[inline]
pub fn lognormal_params(e_g: f64, cv: f64) -> (f64, f64) {
    let sigma = (cv * cv + 1.0).ln().sqrt();
    let mu = e_g.ln() - sigma * sigma / 2.0;
    (mu, sigma)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        (mean, var.sqrt())
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn different_streams_differ() {
        let mut a = Pcg64::new(7, 0);
        let mut b = Pcg64::new(7, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval_and_centered() {
        let mut rng = Pcg64::seeded(3);
        let xs: Vec<f64> = (0..20_000).map(|_| rng.uniform()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let (mean, std) = stats(&xs);
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
        assert!((std - (1.0f64 / 12.0).sqrt()).abs() < 0.01, "std={std}");
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut rng = Pcg64::seeded(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = rng.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(5);
        let xs: Vec<f64> = (0..50_000).map(|_| rng.normal()).collect();
        let (mean, std) = stats(&xs);
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((std - 1.0).abs() < 0.02, "std={std}");
    }

    #[test]
    fn lognormal_matches_target_mean_and_cv() {
        // The device-model contract (Eq. 1): samples should realize the
        // requested E[G] and cv.
        let mut rng = Pcg64::seeded(6);
        for &(e_g, cv) in &[(1e-5, 0.05), (1e-7, 0.2), (2.5e-6, 0.5)] {
            let xs: Vec<f64> = (0..100_000).map(|_| rng.lognormal_cv(e_g, cv)).collect();
            let (mean, std) = stats(&xs);
            assert!(
                (mean - e_g).abs() / e_g < 0.02,
                "e_g={e_g} cv={cv} mean={mean}"
            );
            assert!(
                (std / mean - cv).abs() / cv < 0.05,
                "e_g={e_g} cv={cv} realized_cv={}",
                std / mean
            );
        }
    }

    #[test]
    fn lognormal_zero_cv_is_exact() {
        let mut rng = Pcg64::seeded(7);
        assert_eq!(rng.lognormal_cv(1e-5, 0.0), 1e-5);
    }

    #[test]
    fn lognormal_always_positive() {
        let mut rng = Pcg64::seeded(8);
        for _ in 0..10_000 {
            assert!(rng.lognormal_cv(1e-6, 1.0) > 0.0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(9);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_are_independent() {
        let mut parent = Pcg64::seeded(10);
        let mut a = parent.split(0);
        let mut b = parent.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}

//! Reporting helpers for benches and experiments: aligned console/markdown
//! tables, CSV emission, and simple timing statistics.

use std::fmt::Write as _;
use std::time::Instant;

/// A table of results (string cells) printed as GitHub-flavored markdown —
/// the benches use this to emit exactly the rows/series a paper table shows.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render as markdown with aligned columns.
    pub fn markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n### {}\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                let _ = write!(line, " {:<w$} |", c, w = w);
            }
            line
        };
        let _ = writeln!(out, "{}", fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{:-<w$}|", "", w = w + 2);
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        out
    }

    /// Render as CSV (title as a comment line).
    pub fn csv(&self) -> String {
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = format!("# {}\n", self.title);
        let _ = writeln!(out, "{}", self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Print markdown to stdout and append CSV under `target/reports/`.
    pub fn emit(&self, file_stem: &str) {
        println!("{}", self.markdown());
        let dir = std::path::Path::new("target/reports");
        if std::fs::create_dir_all(dir).is_ok() {
            let _ = std::fs::write(dir.join(format!("{file_stem}.csv")), self.csv());
        }
    }
}

/// Timing statistics over repeated runs of a closure.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub std_s: f64,
}

impl Timing {
    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean_s
    }
}

/// Benchmark a closure: `warmup` unmeasured runs then `iters` measured runs.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let n = samples.len() as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
    Timing {
        iters,
        mean_s: mean,
        min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        max_s: samples.iter().cloned().fold(0.0, f64::max),
        std_s: var.sqrt(),
    }
}

/// Format seconds human-readably (ns/µs/ms/s).
pub fn fmt_duration(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Format a float in compact scientific-or-fixed form for tables.
pub fn fmt_sig(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1e4 || x.abs() < 1e-3 {
        format!("{x:.3e}")
    } else {
        format!("{x:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_markdown_shape() {
        let mut t = Table::new("Demo", &["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["333".into(), "4".into()]);
        let md = t.markdown();
        assert!(md.contains("### Demo"));
        assert!(md.lines().filter(|l| l.starts_with('|')).count() == 4);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("x", &["a"]);
        t.row(&["hello, world".into()]);
        assert!(t.csv().contains("\"hello, world\""));
    }

    #[test]
    fn time_it_counts_iters() {
        let mut n = 0usize;
        let t = time_it(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(t.iters, 5);
        assert!(t.min_s <= t.mean_s && t.mean_s <= t.max_s);
    }

    #[test]
    fn fmt_duration_ranges() {
        assert!(fmt_duration(2e-9).ends_with("ns"));
        assert!(fmt_duration(2e-6).ends_with("µs"));
        assert!(fmt_duration(2e-3).ends_with("ms"));
        assert!(fmt_duration(2.0).ends_with('s'));
    }

    #[test]
    fn fmt_sig_ranges() {
        assert_eq!(fmt_sig(0.0), "0");
        assert!(fmt_sig(1.23456e-7).contains('e'));
        assert!(!fmt_sig(12.3).contains('e'));
    }
}

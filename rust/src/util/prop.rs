//! Minimal property-based testing harness (the registry has no `proptest`
//! offline, so we roll our own seeded-case runner).
//!
//! Usage:
//! ```ignore
//! prop_check("slicing roundtrip", 200, |g| {
//!     let n = g.usize_in(1..=64);
//!     let xs = g.vec_f64(n, -1e3..1e3);
//!     // ... assert invariant, return Err(msg) on failure ...
//!     Ok(())
//! });
//! ```
//! Each case gets an independent RNG stream derived from the case index, so a
//! failing case can be re-run in isolation by seed; the failure message
//! includes the case index.

use super::rng::Pcg64;
use std::ops::{Range, RangeInclusive};

/// Per-case generator handed to the property closure.
pub struct Gen {
    rng: Pcg64,
    /// Case index, for failure reports.
    pub case: usize,
}

impl Gen {
    #[inline]
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }

    pub fn usize_in(&mut self, r: RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*r.start(), *r.end());
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn i64_in(&mut self, r: RangeInclusive<i64>) -> i64 {
        let (lo, hi) = (*r.start(), *r.end());
        lo + self.rng.below((hi - lo + 1) as usize) as i64
    }

    pub fn f64_in(&mut self, r: Range<f64>) -> f64 {
        self.rng.uniform_range(r.start, r.end)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f64(&mut self, n: usize, r: Range<f64>) -> Vec<f64> {
        (0..n).map(|_| self.rng.uniform_range(r.start, r.end)).collect()
    }

    /// A vector mixing magnitudes (exercises FP pre-alignment paths).
    pub fn vec_f64_multiscale(&mut self, n: usize) -> Vec<f64> {
        (0..n)
            .map(|_| {
                let exp = self.i64_in(-8..=8) as i32;
                let mantissa = self.rng.uniform_range(-1.0, 1.0);
                mantissa * (2f64).powi(exp)
            })
            .collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Effective case count for [`prop_check`]: the `MEMINTELLI_PROP_CASES`
/// env var, when set to a positive integer, overrides the per-property
/// default — nightly CI sweeps harder than a local `cargo test` without
/// touching the test code. Unset/invalid values keep the default.
pub fn case_count(default_cases: usize) -> usize {
    std::env::var("MEMINTELLI_PROP_CASES")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(default_cases)
}

/// Run `cases` randomized cases of `prop` (subject to the
/// `MEMINTELLI_PROP_CASES` override, see [`case_count`]). Panics (test
/// failure) with the case index and message on the first failing case.
pub fn prop_check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    prop_check_seeded(name, 0xC0FFEE, case_count(cases), &mut prop);
}

/// Like [`prop_check`] with an explicit base seed (reproduce failures).
pub fn prop_check_seeded<F>(name: &str, seed: u64, cases: usize, prop: &mut F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let mut g = Gen { rng: Pcg64::new(seed, case as u64), case };
        if let Err(msg) = prop(&mut g) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        prop_check("true", 50, |g| {
            let n = g.usize_in(1..=10);
            if n >= 1 && n <= 10 {
                Ok(())
            } else {
                Err(format!("n={n} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'false'")]
    fn reports_failing_property() {
        prop_check("false", 50, |g| {
            let n = g.usize_in(0..=100);
            if n < 95 {
                Ok(())
            } else {
                Err(format!("n={n}"))
            }
        });
    }

    #[test]
    fn multiscale_vec_spans_magnitudes() {
        let mut any_small = false;
        let mut any_large = false;
        prop_check("multiscale", 20, |g| {
            let xs = g.vec_f64_multiscale(64);
            any_small |= xs.iter().any(|x| x.abs() < 1e-2 && *x != 0.0);
            any_large |= xs.iter().any(|x| x.abs() > 1e2);
            Ok(())
        });
        assert!(any_small && any_large);
    }

    #[test]
    fn case_count_default_when_env_unset() {
        // Only assert the default path when the override is not active
        // (CI's elevated sweep sets MEMINTELLI_PROP_CASES for the whole
        // process).
        match std::env::var("MEMINTELLI_PROP_CASES") {
            Err(_) => assert_eq!(case_count(7), 7),
            Ok(v) => {
                let n: usize = v.parse().unwrap_or(0);
                if n > 0 {
                    assert_eq!(case_count(7), n);
                }
            }
        }
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<usize> = vec![];
        prop_check("record", 10, |g| {
            first.push(g.usize_in(0..=1_000_000));
            Ok(())
        });
        let mut second: Vec<usize> = vec![];
        prop_check("record", 10, |g| {
            second.push(g.usize_in(0..=1_000_000));
            Ok(())
        });
        assert_eq!(first, second);
    }
}

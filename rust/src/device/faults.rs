//! Unified fault / non-ideality injection for the crossbar arrays.
//!
//! The paper's engine models programming variation ([`DeviceSpec::cv`],
//! Eq. 1) and ships a standalone drift model ([`super::drift`]); real
//! deployments are additionally dominated by hard faults and peripheral
//! errors. This module makes those first-class, mapping each knob to its
//! source in the paper or the related-work simulators:
//!
//! | knob | models | source |
//! |---|---|---|
//! | [`FaultSpec::sa0`] / [`FaultSpec::sa1`] | cells stuck at LGS / HGS (forming/endurance failures) | the `stuck_at_fault` parameter surface of MemMIMO/simbrain; IMAC-Sim's circuit-level defect injection |
//! | [`FaultSpec::dead_row`] / [`FaultSpec::dead_col`] | whole word/bit lines dead (driver or selector failure, all cells read as LGS) | IMAC-Sim line-defect modeling |
//! | [`NonIdealitySpec::t_read`] + [`DriftSpec`] | retention loss between programming and read, folded into the programming path | the paper's stated future work ("conductance drift"); `retention_loss` in MemMIMO/simbrain; Ielmini/Le Gallo PCM power law |
//! | [`AdcErrorSpec::gain_std`] / [`AdcErrorSpec::offset_std_lsb`] | per-column ADC gain/offset mismatch | CrossSim's calibrated-ADC error model; IMAC-Sim peripheral non-idealities |
//! | [`AdcErrorSpec::rounding`] | ADC transfer-curve rounding mode (mid-tread round vs truncating floor) | ADC rounding in the MemMIMO/simbrain parameter surface |
//!
//! # Composition order (deterministic, seeded)
//!
//! [`NonIdealitySpec::inject_plane`] applies the program-time effects to
//! one programmed digit plane in a fixed order, drawing from one seeded
//! RNG stream per (weight-block, tag):
//!
//! 1. **programming variation** has already been applied by
//!    [`DeviceSpec::sample_level`] (unchanged, separate RNG stream);
//! 2. **retention/drift** to the configured read time `t_read`, in the
//!    conductance domain (digit → G → power-law decay → digit), one
//!    per-device drift exponent per cell;
//! 3. **stuck-at cell faults** (row-major, one draw per cell);
//! 4. **dead rows**, then **dead columns** (one draw per line), which
//!    override cell state with SA0.
//!
//! Stuck cells are pinned *after* drift: a stuck-at-HGS cell reads the
//! full-scale conductance regardless of retention loss. ADC gain/offset
//! error is a **read-time** effect sampled per physical column of each
//! array block, deterministically in (engine seed, injection seed, block
//! id) ([`AdcChain`]); the engine applies it inside `adc_readout` so the
//! stacked pipeline and the per-slice-pair reference oracle stay
//! bit-identical under every injection.
//!
//! Everything is gated so that a zero-rate spec draws **no** random
//! numbers and leaves the engine bit-identical to no injection.
//!
//! The engine's `noise_free` flag remains the master kill-switch for all
//! analog effects, injection included. To isolate faults from
//! programming noise, set `device.cv = 0` (and keep `noise_free` off)
//! rather than enabling `noise_free`.

use super::drift::DriftSpec;
use super::DeviceSpec;
use crate::tensor::Matrix;
use crate::util::rng::Pcg64;

/// Stuck-at cell and dead-line fault rates (probabilities per cell/line).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct FaultSpec {
    /// Probability a cell is stuck at the low conductance state (reads as
    /// digit 0 regardless of the programmed value).
    pub sa0: f64,
    /// Probability a cell is stuck at the high conductance state (reads as
    /// the device's maximum digit).
    pub sa1: f64,
    /// Probability an entire array row (word line) is dead — all its cells
    /// read as SA0.
    pub dead_row: f64,
    /// Probability an entire array column (bit line) is dead (SA0).
    pub dead_col: f64,
}

impl FaultSpec {
    /// No faults.
    pub fn none() -> Self {
        FaultSpec::default()
    }

    /// True iff every rate is zero (no injection, no RNG draws).
    pub fn is_none(&self) -> bool {
        self.sa0 == 0.0 && self.sa1 == 0.0 && self.dead_row == 0.0 && self.dead_col == 0.0
    }

    /// Combined per-cell stuck-at rate (reporting label).
    pub fn cell_rate(&self) -> f64 {
        self.sa0 + self.sa1
    }

    /// Symmetric cell-fault shorthand: total `rate` split evenly between
    /// SA0 and SA1, no line faults.
    pub fn cells(rate: f64) -> Self {
        FaultSpec { sa0: rate / 2.0, sa1: rate / 2.0, dead_row: 0.0, dead_col: 0.0 }
    }
}

/// Per-cell fault state in a sampled [`FaultMask`].
const CELL_OK: u8 = 0;
const CELL_SA0: u8 = 1;
const CELL_SA1: u8 = 2;

/// One sampled fault pattern for an `rows × cols` physical array plane.
/// Sampling is deterministic in the RNG; applying is idempotent.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultMask {
    pub rows: usize,
    pub cols: usize,
    cells: Vec<u8>,
}

impl FaultMask {
    /// Sample a mask. Draw order (fixed, so masks are reproducible per
    /// seed): one uniform per cell row-major, then one per row, then one
    /// per column. A zero-rate spec returns a clean mask **without
    /// consuming any RNG draws**.
    pub fn sample(spec: &FaultSpec, rows: usize, cols: usize, rng: &mut Pcg64) -> FaultMask {
        let mut cells = vec![CELL_OK; rows * cols];
        if spec.is_none() {
            return FaultMask { rows, cols, cells };
        }
        let p0 = spec.sa0.clamp(0.0, 1.0);
        let p1 = spec.sa1.clamp(0.0, 1.0 - p0);
        if p0 > 0.0 || p1 > 0.0 {
            for c in cells.iter_mut() {
                let u = rng.uniform();
                if u < p0 {
                    *c = CELL_SA0;
                } else if u < p0 + p1 {
                    *c = CELL_SA1;
                }
            }
        }
        if spec.dead_row > 0.0 {
            for row in cells.chunks_mut(cols.max(1)) {
                if rng.uniform() < spec.dead_row {
                    row.fill(CELL_SA0);
                }
            }
        }
        if spec.dead_col > 0.0 {
            for col in 0..cols {
                if rng.uniform() < spec.dead_col {
                    for r in 0..rows {
                        cells[r * cols + col] = CELL_SA0;
                    }
                }
            }
        }
        FaultMask { rows, cols, cells }
    }

    /// Pin faulty cells of a programmed digit plane: SA0 → 0 (LGS), SA1 →
    /// `max_digit` (HGS). Healthy cells are untouched; applying a mask
    /// twice equals applying it once.
    pub fn apply(&self, plane: &mut Matrix, max_digit: f64) {
        assert_eq!(
            (plane.rows, plane.cols),
            (self.rows, self.cols),
            "fault mask shape mismatch"
        );
        for (v, &c) in plane.data.iter_mut().zip(&self.cells) {
            match c {
                CELL_SA0 => *v = 0.0,
                CELL_SA1 => *v = max_digit,
                _ => {}
            }
        }
    }

    /// `(sa0, sa1)` faulty-cell counts (line faults count as SA0 cells).
    pub fn counts(&self) -> (usize, usize) {
        let sa0 = self.cells.iter().filter(|&&c| c == CELL_SA0).count();
        let sa1 = self.cells.iter().filter(|&&c| c == CELL_SA1).count();
        (sa0, sa1)
    }

    /// True iff no cell is faulty.
    pub fn is_clean(&self) -> bool {
        self.cells.iter().all(|&c| c == CELL_OK)
    }
}

/// ADC transfer-curve rounding mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdcRounding {
    /// Mid-tread rounding to the nearest code (the ideal quantizer).
    #[default]
    Round,
    /// Truncating converter: the output code is the largest code below the
    /// input (a systematic −0.5 LSB bias, common in low-power flash ADCs).
    Floor,
}

/// Per-column ADC gain/offset mismatch and rounding behavior.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct AdcErrorSpec {
    /// Std of the multiplicative per-column gain error (mean 1).
    pub gain_std: f64,
    /// Std of the additive per-column offset error, in ADC LSBs of the
    /// selected full-scale range.
    pub offset_std_lsb: f64,
    /// Code rounding mode.
    pub rounding: AdcRounding,
}

impl AdcErrorSpec {
    pub fn none() -> Self {
        AdcErrorSpec::default()
    }

    /// True iff the ADC behaves ideally (no error terms, nearest-code
    /// rounding) — the engine then keeps its original readout path.
    pub fn is_ideal(&self) -> bool {
        self.gain_std == 0.0 && self.offset_std_lsb == 0.0 && self.rounding == AdcRounding::Round
    }
}

/// The sampled per-column ADC chain of one physical array: one
/// `(gain, offset)` pair per output column, shared by every digit plane
/// of that block column (the shift-and-add periphery funnels all planes
/// of one output column through the same converter), while distinct
/// array blocks sample independent chains. The engine seeds sampling
/// from (engine seed, injection seed, block id), so repeated reads see
/// the same mismatch — it is a static calibration error, not noise.
#[derive(Debug, Clone, PartialEq)]
pub struct AdcChain {
    gain: Vec<f64>,
    /// Offsets in LSB units (scaled by the per-readout step at apply time).
    offset_lsb: Vec<f64>,
    rounding: AdcRounding,
}

impl AdcChain {
    /// The ideal chain: no per-column state, nearest-code rounding.
    pub fn ideal() -> Self {
        AdcChain { gain: Vec::new(), offset_lsb: Vec::new(), rounding: AdcRounding::Round }
    }

    /// Sample a chain for `cols` physical columns. Draw order: gains
    /// (one normal per column), then offsets.
    pub fn sample(spec: &AdcErrorSpec, cols: usize, rng: &mut Pcg64) -> AdcChain {
        let gain = (0..cols).map(|_| rng.normal_ms(1.0, spec.gain_std)).collect();
        let offset_lsb = (0..cols).map(|_| rng.normal_ms(0.0, spec.offset_std_lsb)).collect();
        AdcChain { gain, offset_lsb, rounding: spec.rounding }
    }

    /// True for [`AdcChain::ideal`] — callers keep the fast readout path.
    pub fn is_ideal(&self) -> bool {
        self.gain.is_empty() && self.rounding == AdcRounding::Round
    }

    /// Convert one analog partial on column `col` through the erroneous
    /// chain: apply gain and offset, round per the mode, clamp the code to
    /// `[0, max_code]`, and reconstruct. `step` is the per-readout LSB.
    #[inline]
    pub fn convert(&self, v: f64, col: usize, step: f64, max_code: f64) -> f64 {
        debug_assert!(col < self.gain.len(), "ADC chain column out of range");
        let y = self.gain[col] * v + self.offset_lsb[col] * step;
        let code = match self.rounding {
            AdcRounding::Round => (y / step).round(),
            AdcRounding::Floor => (y / step).floor(),
        };
        code.clamp(0.0, max_code) * step
    }
}

/// The unified non-ideality specification threaded through
/// [`crate::dpe::DpeConfig`]. Defaults are all-off: the engine is then
/// bit-identical to one with no injection at all.
#[derive(Debug, Clone, PartialEq)]
pub struct NonIdealitySpec {
    /// Stuck-at cell and dead-line faults (program-time mask).
    pub faults: FaultSpec,
    /// Retention/drift model applied between programming and read.
    pub drift: DriftSpec,
    /// Read time (s) for the drift model; `t_read <= drift.t0` disables
    /// retention loss (the default `0.0` always does).
    pub t_read: f64,
    /// Per-column ADC gain/offset error and rounding mode (read-time).
    pub adc: AdcErrorSpec,
    /// Extra seed decorrelating injection from programming noise; folded
    /// with the engine seed so two engines can share weights-noise streams
    /// while sampling different fault patterns.
    pub seed: u64,
}

impl Default for NonIdealitySpec {
    fn default() -> Self {
        NonIdealitySpec {
            faults: FaultSpec::none(),
            drift: DriftSpec::default(),
            t_read: 0.0,
            adc: AdcErrorSpec::none(),
            seed: 0x0FA1_7D05,
        }
    }
}

impl NonIdealitySpec {
    /// The all-off spec.
    pub fn none() -> Self {
        NonIdealitySpec::default()
    }

    /// True iff retention loss is active at read time.
    pub fn drift_enabled(&self) -> bool {
        self.t_read > self.drift.t0 && self.drift.nu != 0.0
    }

    /// True iff the spec injects nothing anywhere.
    pub fn is_none(&self) -> bool {
        self.faults.is_none() && !self.drift_enabled() && self.adc.is_ideal()
    }

    /// True iff any *program-time* effect is active (drift or stuck-at);
    /// the engine skips the injection pass — and all its RNG draws —
    /// otherwise.
    pub fn injects_at_program(&self) -> bool {
        self.drift_enabled() || !self.faults.is_none()
    }

    /// Apply the program-time effects to one programmed digit plane
    /// (values are offset-corrected analog digits, `(G − LGS)/step`), in
    /// the documented order: drift to `t_read`, then stuck-at cells, then
    /// dead lines. Deterministic in `rng`.
    pub fn inject_plane(&self, plane: &mut Matrix, dev: &DeviceSpec, rng: &mut Pcg64) {
        let _ = self.inject_plane_masked(plane, dev, rng);
    }

    /// [`NonIdealitySpec::inject_plane`], additionally returning the
    /// sampled [`FaultMask`] (clean and draw-free when no fault rate is
    /// set). Program-and-verify retries re-apply this captured mask to
    /// each redraw — faults are a property of the physical array, so a
    /// reprogramming attempt on the same slot must see the *same* stuck
    /// cells, which is what makes them unconvergeable (the detection
    /// signal). Draw order and values are identical to `inject_plane`.
    pub fn inject_plane_masked(
        &self,
        plane: &mut Matrix,
        dev: &DeviceSpec,
        rng: &mut Pcg64,
    ) -> FaultMask {
        if self.drift_enabled() {
            let step = dev.step();
            for v in plane.data.iter_mut() {
                let g = *v * step + dev.lgs;
                let nu = rng.normal_ms(self.drift.nu, self.drift.nu_std);
                *v = (self.drift.apply_one(g, nu, self.t_read) - dev.lgs) / step;
            }
        }
        let mask = FaultMask::sample(&self.faults, plane.rows, plane.cols, rng);
        if !mask.is_clean() {
            mask.apply(plane, dev.max_digit() as f64);
        }
        mask
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn prop_stuck_at_rate_matches_request() {
        // Injected SA0/SA1 rates must match the requested probabilities
        // within a binomial confidence bound (6σ + discreteness slack).
        prop_check("stuck-at rate matches request", 60, |g| {
            let rows = g.usize_in(32..=96);
            let cols = g.usize_in(32..=96);
            let sa0 = g.f64_in(0.0..0.15);
            let sa1 = g.f64_in(0.0..0.15);
            let spec = FaultSpec { sa0, sa1, dead_row: 0.0, dead_col: 0.0 };
            let mask = FaultMask::sample(&spec, rows, cols, g.rng());
            let n = (rows * cols) as f64;
            let (c0, c1) = mask.counts();
            for (want, got) in [(sa0, c0 as f64 / n), (sa1, c1 as f64 / n)] {
                let tol = 6.0 * (want * (1.0 - want) / n).sqrt() + 2.0 / n;
                if (got - want).abs() > tol {
                    return Err(format!(
                        "rate {got:.4} vs requested {want:.4} (n={n}, tol={tol:.4})"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_mask_deterministic_per_seed_and_idempotent() {
        prop_check("mask deterministic + idempotent", 100, |g| {
            let rows = g.usize_in(1..=48);
            let cols = g.usize_in(1..=48);
            let spec = FaultSpec {
                sa0: g.f64_in(0.0..0.3),
                sa1: g.f64_in(0.0..0.3),
                dead_row: g.f64_in(0.0..0.1),
                dead_col: g.f64_in(0.0..0.1),
            };
            let seed = g.rng().next_u64();
            let m1 = FaultMask::sample(&spec, rows, cols, &mut Pcg64::new(seed, 1));
            let m2 = FaultMask::sample(&spec, rows, cols, &mut Pcg64::new(seed, 1));
            if m1 != m2 {
                return Err("same seed produced different masks".into());
            }
            let mut plane = Matrix::from_fn(rows, cols, |i, j| ((i * cols + j) % 16) as f64);
            let mut once = plane.clone();
            m1.apply(&mut once, 15.0);
            plane = once.clone();
            m1.apply(&mut plane, 15.0);
            if plane.data != once.data {
                return Err("mask application is not idempotent".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_zero_rate_is_bit_identical_and_draw_free() {
        prop_check("zero-rate spec injects nothing", 100, |g| {
            let rows = g.usize_in(1..=32);
            let cols = g.usize_in(1..=32);
            let vals = g.vec_f64(rows * cols, 0.0..15.0);
            let mut plane = Matrix::from_vec(rows, cols, vals);
            let orig = plane.clone();
            let mut rng = Pcg64::new(g.rng().next_u64(), 7);
            let mut untouched = rng.clone();
            let mask = FaultMask::sample(&FaultSpec::none(), rows, cols, &mut rng);
            if !mask.is_clean() {
                return Err("zero-rate mask has faults".into());
            }
            mask.apply(&mut plane, 15.0);
            if plane.data != orig.data {
                return Err("zero-rate apply changed bits".into());
            }
            // No RNG draws may have been consumed.
            if rng.next_u64() != untouched.next_u64() {
                return Err("zero-rate sampling consumed RNG draws".into());
            }
            // Same for the full spec-level injection entry point.
            let ni = NonIdealitySpec::none();
            let mut rng2 = Pcg64::new(g.rng().next_u64(), 9);
            let mut untouched2 = rng2.clone();
            if ni.injects_at_program() {
                return Err("none() spec claims program-time injection".into());
            }
            ni.inject_plane(&mut plane, &DeviceSpec::default(), &mut rng2);
            if plane.data != orig.data {
                return Err("none() inject_plane changed bits".into());
            }
            if rng2.next_u64() != untouched2.next_u64() {
                return Err("none() inject_plane consumed RNG draws".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_dead_lines_zero_whole_rows_and_cols() {
        prop_check("dead lines pin whole rows/cols to SA0", 40, |g| {
            let rows = g.usize_in(2..=24);
            let cols = g.usize_in(2..=24);
            // Certain line faults: every row and column dead.
            let spec = FaultSpec { sa0: 0.0, sa1: 1.0, dead_row: 1.0, dead_col: 1.0 };
            let mask = FaultMask::sample(&spec, rows, cols, g.rng());
            let mut plane = Matrix::from_fn(rows, cols, |_, _| 7.0);
            mask.apply(&mut plane, 15.0);
            if plane.data.iter().any(|&v| v != 0.0) {
                return Err("dead lines did not override SA1 cells".into());
            }
            Ok(())
        });
    }

    #[test]
    fn sa1_pins_to_max_digit() {
        let spec = FaultSpec { sa0: 0.0, sa1: 1.0, dead_row: 0.0, dead_col: 0.0 };
        let mask = FaultMask::sample(&spec, 4, 4, &mut Pcg64::seeded(3));
        let mut plane = Matrix::zeros(4, 4);
        mask.apply(&mut plane, 15.0);
        assert!(plane.data.iter().all(|&v| v == 15.0));
        let (c0, c1) = mask.counts();
        assert_eq!((c0, c1), (0, 16));
    }

    #[test]
    fn drift_at_read_shrinks_digits() {
        let dev = DeviceSpec::default();
        let ni = NonIdealitySpec {
            drift: DriftSpec { nu: 0.1, nu_std: 0.0, t0: 1.0 },
            t_read: 1e4,
            ..NonIdealitySpec::none()
        };
        assert!(ni.drift_enabled());
        let mut plane = Matrix::from_vec(1, 3, vec![5.0, 10.0, 15.0]);
        ni.inject_plane(&mut plane, &dev, &mut Pcg64::seeded(8));
        // Power-law decay with nu_std = 0 is deterministic: each G decays
        // by (1e4)^-0.1, and the offset-corrected digit strictly shrinks.
        for (got, &orig) in plane.data.iter().zip(&[5.0, 10.0, 15.0]) {
            let g = orig * dev.step() + dev.lgs;
            let want = (g * 1e4f64.powf(-0.1) - dev.lgs) / dev.step();
            assert!((got - want).abs() < 1e-9, "got {got} want {want}");
            assert!(*got < orig);
        }
    }

    #[test]
    fn stuck_cells_ignore_drift() {
        // SA1 pins to max digit even when retention would have decayed it.
        let dev = DeviceSpec::default();
        let ni = NonIdealitySpec {
            faults: FaultSpec { sa1: 1.0, ..FaultSpec::none() },
            drift: DriftSpec { nu: 0.1, nu_std: 0.0, t0: 1.0 },
            t_read: 1e6,
            ..NonIdealitySpec::none()
        };
        let mut plane = Matrix::from_vec(2, 2, vec![3.0; 4]);
        ni.inject_plane(&mut plane, &dev, &mut Pcg64::seeded(9));
        assert!(plane.data.iter().all(|&v| v == 15.0));
    }

    #[test]
    fn adc_chain_ideal_and_sampled() {
        assert!(AdcChain::ideal().is_ideal());
        assert!(AdcErrorSpec::none().is_ideal());
        let spec = AdcErrorSpec { gain_std: 0.05, offset_std_lsb: 0.5, rounding: AdcRounding::Round };
        assert!(!spec.is_ideal());
        let c1 = AdcChain::sample(&spec, 64, &mut Pcg64::seeded(4));
        let c2 = AdcChain::sample(&spec, 64, &mut Pcg64::seeded(4));
        assert_eq!(c1, c2, "chain sampling must be deterministic per seed");
        assert!(!c1.is_ideal());
    }

    #[test]
    fn adc_chain_floor_biases_down() {
        let spec = AdcErrorSpec { gain_std: 0.0, offset_std_lsb: 0.0, rounding: AdcRounding::Floor };
        assert!(!spec.is_ideal(), "floor rounding is a non-ideal chain");
        let chain = AdcChain::sample(&spec, 1, &mut Pcg64::seeded(5));
        // 2.9 LSB floors to code 2 where round gives 3.
        assert_eq!(chain.convert(2.9, 0, 1.0, 100.0), 2.0);
        // Codes clamp to [0, max_code].
        assert_eq!(chain.convert(-3.0, 0, 1.0, 100.0), 0.0);
        assert_eq!(chain.convert(500.0, 0, 1.0, 100.0), 100.0);
    }

    #[test]
    fn prop_inject_plane_masked_matches_inject_plane() {
        // The mask-returning variant must consume the same draws and
        // produce the same bits as the original entry point, and the
        // returned mask must reproduce the pinning when re-applied.
        prop_check("inject_plane_masked == inject_plane", 60, |g| {
            let rows = g.usize_in(1..=32);
            let cols = g.usize_in(1..=32);
            let ni = NonIdealitySpec {
                faults: FaultSpec {
                    sa0: g.f64_in(0.0..0.2),
                    sa1: g.f64_in(0.0..0.2),
                    dead_row: g.f64_in(0.0..0.05),
                    dead_col: g.f64_in(0.0..0.05),
                },
                drift: DriftSpec { nu: g.f64_in(0.0..0.1), nu_std: 0.01, t0: 1.0 },
                t_read: if g.bool() { 1e4 } else { 0.0 },
                ..NonIdealitySpec::none()
            };
            let dev = DeviceSpec::default();
            let vals = g.vec_f64(rows * cols, 0.0..15.0);
            let seed = g.rng().next_u64();
            let mut p1 = Matrix::from_vec(rows, cols, vals.clone());
            let mut p2 = Matrix::from_vec(rows, cols, vals);
            let mut rng1 = Pcg64::new(seed, 3);
            let mut rng2 = Pcg64::new(seed, 3);
            ni.inject_plane(&mut p1, &dev, &mut rng1);
            let mask = ni.inject_plane_masked(&mut p2, &dev, &mut rng2);
            if p1.data != p2.data {
                return Err("masked variant changed the injected bits".into());
            }
            if rng1.next_u64() != rng2.next_u64() {
                return Err("masked variant consumed different draws".into());
            }
            // Re-applying the captured mask is a fixed point.
            let before = p2.clone();
            mask.apply(&mut p2, dev.max_digit() as f64);
            if p2.data != before.data {
                return Err("captured mask re-application not idempotent".into());
            }
            Ok(())
        });
    }

    #[test]
    fn spec_gates_report_correctly() {
        let mut ni = NonIdealitySpec::none();
        assert!(ni.is_none() && !ni.injects_at_program());
        ni.faults.sa0 = 0.01;
        assert!(!ni.is_none() && ni.injects_at_program());
        let mut ni2 = NonIdealitySpec::none();
        ni2.adc.offset_std_lsb = 0.5;
        // ADC error is read-time only: no program-time injection pass.
        assert!(!ni2.is_none() && !ni2.injects_at_program());
        let mut ni3 = NonIdealitySpec::none();
        ni3.t_read = 1e5;
        assert!(ni3.drift_enabled() && ni3.injects_at_program());
    }
}

//! Memristive device models (paper §3.2, Fig 3).
//!
//! The conductance of a programmed memristor is modeled as a lognormal
//! random variable around its target state (Eq. 1): device-to-device and
//! cycle-to-cycle variation are folded into one coefficient-of-variation
//! `cv` applied as real-time noise on the ideal conductance matrix. The
//! mapping between digital slice values and conductance is linear between
//! the low (`lgs`) and high (`hgs`) conductance states with `g_levels`
//! programmable levels.
//!
//! Beyond Eq. 1, [`drift`] models power-law retention loss and [`faults`]
//! composes the unified non-ideality injection (stuck-at cells, dead
//! lines, drift at read time, per-column ADC error) threaded through the
//! DPE's weight-programming path.

pub mod drift;
pub mod faults;

use crate::tensor::Matrix;
use crate::util::rng::Pcg64;

/// Device/array electrical parameters (Table 2 defaults).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceSpec {
    /// High conductance state (S). Table 2: 1e-5.
    pub hgs: f64,
    /// Low conductance state (S). Table 2: 1e-7.
    pub lgs: f64,
    /// Number of programmable conductance levels. Table 2: 16.
    pub g_levels: usize,
    /// Coefficient of variation of the programmed conductance. Table 2: 0.05.
    pub cv: f64,
    /// Coefficient of variation of the per-read conductance fluctuation
    /// (cycle-to-cycle read noise, the "read noise" knob of CrossSim-style
    /// simulators). Unlike `cv` — frozen at program time — this is
    /// re-drawn on every readout, applied multiplicatively to each analog
    /// partial before the ADC; the `tag` of the prepared-matmul entry
    /// points decorrelates it between calls. `0.0` (default) disables it
    /// and draws no random numbers, leaving reads deterministic.
    pub read_cv: f64,
}

impl Default for DeviceSpec {
    fn default() -> Self {
        DeviceSpec { hgs: 1e-5, lgs: 1e-7, g_levels: 16, cv: 0.05, read_cv: 0.0 }
    }
}

impl DeviceSpec {
    /// Maximum digital value storable on a single device.
    pub fn max_digit(&self) -> u32 {
        (self.g_levels - 1) as u32
    }

    /// Conductance step between adjacent levels.
    pub fn step(&self) -> f64 {
        (self.hgs - self.lgs) / (self.g_levels as f64 - 1.0)
    }

    /// Ideal conductance for a digital level `d ∈ [0, g_levels)`.
    #[inline]
    pub fn level_to_g(&self, d: u32) -> f64 {
        debug_assert!((d as usize) < self.g_levels, "level {d} out of range");
        self.lgs + self.step() * d as f64
    }

    /// Nearest digital level for a target conductance (clamped).
    pub fn g_to_level(&self, g: f64) -> u32 {
        let d = ((g - self.lgs) / self.step()).round();
        d.clamp(0.0, (self.g_levels - 1) as f64) as u32
    }

    /// Program-and-read sample: lognormal noise with mean `level_to_g(d)`
    /// and the spec's `cv` (Eq. 1).
    #[inline]
    pub fn sample_level(&self, d: u32, rng: &mut Pcg64) -> f64 {
        rng.lognormal_cv(self.level_to_g(d), self.cv)
    }

    /// Map a matrix of digital levels to a noisy conductance matrix — this
    /// is what one crossbar array "stores" for one weight slice.
    pub fn program_matrix(&self, digits: &Matrix, rng: &mut Pcg64) -> Matrix {
        Matrix {
            rows: digits.rows,
            cols: digits.cols,
            data: digits
                .data
                .iter()
                .map(|&d| {
                    debug_assert!(d >= 0.0 && (d as usize) < self.g_levels);
                    self.sample_level(d as u32, rng)
                })
                .collect(),
        }
    }

    /// Relative-noise shortcut used on the DPE hot path: multiply each ideal
    /// value by a lognormal factor of mean 1 and the spec's cv. Equivalent
    /// in distribution to `program_matrix` for nonzero targets but
    /// independent of the conductance mapping, so it can be applied directly
    /// in digit space.
    pub fn noise_factor(&self, rng: &mut Pcg64) -> f64 {
        rng.lognormal_cv(1.0, self.cv)
    }
}

/// Generate the Fig-3-style conductance clouds: `n` reads of devices
/// programmed to HRS (low conductance) and LRS (high conductance).
/// Returns (hrs_samples, lrs_samples).
pub fn conductance_clouds(spec: &DeviceSpec, n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = Pcg64::new(seed, 0xDE71CE);
    let hrs = (0..n).map(|_| spec.sample_level(0, &mut rng)).collect();
    let lrs = (0..n).map(|_| spec.sample_level(spec.max_digit(), &mut rng)).collect();
    (hrs, lrs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_mapping_endpoints() {
        let s = DeviceSpec::default();
        assert_eq!(s.level_to_g(0), 1e-7);
        assert!((s.level_to_g(15) - 1e-5).abs() < 1e-18);
    }

    #[test]
    fn g_to_level_roundtrip() {
        let s = DeviceSpec::default();
        for d in 0..16 {
            assert_eq!(s.g_to_level(s.level_to_g(d)), d);
        }
    }

    #[test]
    fn g_to_level_clamps() {
        let s = DeviceSpec::default();
        assert_eq!(s.g_to_level(-1.0), 0);
        assert_eq!(s.g_to_level(1.0), 15);
    }

    #[test]
    fn sample_statistics_match_eq1() {
        let s = DeviceSpec { cv: 0.1, ..DeviceSpec::default() };
        let mut rng = Pcg64::seeded(1);
        let xs: Vec<f64> = (0..60_000).map(|_| s.sample_level(8, &mut rng)).collect();
        let target = s.level_to_g(8);
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let std =
            (xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64).sqrt();
        assert!((mean - target).abs() / target < 0.02);
        assert!((std / mean - 0.1).abs() < 0.01);
    }

    #[test]
    fn clouds_separated_for_small_cv() {
        // Fig 3: HRS and LRS distributions must be clearly separated at
        // cv = 0.05 with the Table-2 on/off ratio of 100.
        let (hrs, lrs) = conductance_clouds(&DeviceSpec::default(), 5000, 9);
        let hrs_max = hrs.iter().cloned().fold(0.0f64, f64::max);
        let lrs_min = lrs.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(hrs_max < lrs_min, "hrs_max={hrs_max} lrs_min={lrs_min}");
    }

    #[test]
    fn program_matrix_shape_and_positivity() {
        let s = DeviceSpec::default();
        let digits = Matrix::from_fn(4, 4, |i, j| ((i + j) % 16) as f64);
        let mut rng = Pcg64::seeded(2);
        let g = s.program_matrix(&digits, &mut rng);
        assert_eq!((g.rows, g.cols), (4, 4));
        assert!(g.data.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn noise_factor_mean_one() {
        let s = DeviceSpec { cv: 0.2, ..DeviceSpec::default() };
        let mut rng = Pcg64::seeded(3);
        let mean =
            (0..50_000).map(|_| s.noise_factor(&mut rng)).sum::<f64>() / 50_000.0;
        assert!((mean - 1.0).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn zero_cv_is_noise_free() {
        let s = DeviceSpec { cv: 0.0, ..DeviceSpec::default() };
        let mut rng = Pcg64::seeded(4);
        assert_eq!(s.sample_level(5, &mut rng), s.level_to_g(5));
        assert_eq!(s.noise_factor(&mut rng), 1.0);
    }
}

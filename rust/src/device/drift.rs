//! Conductance drift model — the paper's stated *future work* ("we plan to
//! add more complex device models, such as the conductance drift"),
//! implemented here as an extension.
//!
//! We use the standard PCM power-law drift model
//! (Ielmini/Le Gallo): `G(t) = G(t0) · (t / t0)^(-ν)`, with a
//! device-to-device spread on the drift exponent ν. RRAM-style retention
//! loss toward an equilibrium conductance is also provided.

use crate::tensor::Matrix;
use crate::util::rng::Pcg64;

/// Power-law drift parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DriftSpec {
    /// Mean drift exponent ν (PCM ≈ 0.05–0.1; 0 disables drift).
    pub nu: f64,
    /// Device-to-device std of ν.
    pub nu_std: f64,
    /// Reference time t0 (s) at which conductance was programmed/read.
    pub t0: f64,
}

impl Default for DriftSpec {
    fn default() -> Self {
        DriftSpec { nu: 0.05, nu_std: 0.01, t0: 1.0 }
    }
}

impl DriftSpec {
    /// Drift a single conductance from `t0` to time `t` with exponent `nu`.
    #[inline]
    pub fn apply_one(&self, g: f64, nu: f64, t: f64) -> f64 {
        if t <= self.t0 || self.nu == 0.0 {
            return g;
        }
        g * (t / self.t0).powf(-nu.max(0.0))
    }

    /// Drift a whole conductance matrix to time `t`, sampling a per-device
    /// exponent. Deterministic in `rng`.
    pub fn apply_matrix(&self, g: &Matrix, t: f64, rng: &mut Pcg64) -> Matrix {
        g.map_with(|v| {
            let nu = rng.normal_ms(self.nu, self.nu_std);
            self.apply_one(v, nu, t)
        })
    }

    /// Mean multiplicative decay factor at time `t` (for reporting).
    pub fn mean_decay(&self, t: f64) -> f64 {
        if t <= self.t0 {
            1.0
        } else {
            (t / self.t0).powf(-self.nu)
        }
    }
}

impl Matrix {
    /// Map with a stateful closure (sequential; used by drift sampling).
    pub fn map_with(&self, mut f: impl FnMut(f64) -> f64) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_drift_before_t0() {
        let d = DriftSpec::default();
        assert_eq!(d.apply_one(1e-5, 0.05, 0.5), 1e-5);
        assert_eq!(d.apply_one(1e-5, 0.05, 1.0), 1e-5);
    }

    #[test]
    fn drift_decreases_conductance() {
        let d = DriftSpec::default();
        let g1 = d.apply_one(1e-5, 0.05, 10.0);
        let g2 = d.apply_one(1e-5, 0.05, 1000.0);
        assert!(g1 < 1e-5);
        assert!(g2 < g1);
    }

    #[test]
    fn power_law_decade_ratio() {
        // One decade of time -> factor 10^-nu.
        let d = DriftSpec { nu: 0.1, nu_std: 0.0, t0: 1.0 };
        let g10 = d.apply_one(1.0, 0.1, 10.0);
        let g100 = d.apply_one(1.0, 0.1, 100.0);
        assert!((g10 / 1.0 - 10f64.powf(-0.1)).abs() < 1e-12);
        assert!((g100 / g10 - 10f64.powf(-0.1)).abs() < 1e-12);
    }

    #[test]
    fn matrix_drift_mean_matches() {
        let d = DriftSpec { nu: 0.08, nu_std: 0.01, t0: 1.0 };
        let g = Matrix::from_vec(50, 50, vec![1e-5; 2500]);
        let mut rng = Pcg64::seeded(5);
        let dg = d.apply_matrix(&g, 1e4, &mut rng);
        let mean = dg.mean();
        let expect = 1e-5 * d.mean_decay(1e4);
        // nu spread skews the mean slightly; allow 5%.
        assert!((mean - expect).abs() / expect < 0.05, "mean={mean} expect={expect}");
    }

    #[test]
    fn zero_nu_disables() {
        let d = DriftSpec { nu: 0.0, nu_std: 0.0, t0: 1.0 };
        assert_eq!(d.mean_decay(1e6), 1.0);
        assert_eq!(d.apply_one(2e-6, 0.0, 1e6), 2e-6);
    }
}

//! Closed-loop chip repair: health scoring, remap-to-spare planning, and
//! graceful degradation (the runtime response to the fault fragility
//! `fig_faults` measures).
//!
//! The loop has three stages, spanning the whole stack:
//!
//! 1. **Program-and-verify** ([`crate::dpe::WeightTemplate::program_verified`],
//!    `[repair]` TOML section → [`crate::dpe::RepairSpec`]): each digit
//!    plane is read back after programming and re-drawn while it exceeds
//!    the digit-error tolerance. Stuck cells never converge, so a block
//!    group whose planes exhaust their retries condemns its physical
//!    slots.
//! 2. **Online probes** ([`crate::nn::MemCore::probe_block_scores`]):
//!    column-checksum test vectors — zero outside one k-block, so every
//!    other k-block quantizes to scale 0 and contributes *exactly* zero —
//!    run through the genuine fused GEMM path and are compared against
//!    the digitally-computed expectation. This localizes faulty arrays at
//!    `(k-block, n-block)` group granularity at runtime, without ground
//!    truth activations, and is scored into a [`HealthReport`].
//! 3. **Remap-to-spare** ([`RepairPlan::plan`]): condemned groups migrate
//!    whole into the spare tail arrays reserved by
//!    [`super::ChipSpec::with_spares`], preserving the allocator's
//!    group-within-one-tile invariant and drawing all programming noise /
//!    fault masks / ADC chains from the *new* physical slot's streams
//!    ([`crate::dpe::DotProductEngine::reprogram_prepared_blocks`]). When
//!    spares run out the chip **keeps serving**: the unrepairable groups
//!    are recorded in a [`DegradedReport`] instead of erroring.
//!
//! [`crate::arch::MappedModel::self_heal`] drives all three stages.

use super::{ArraySlot, Placement};
use crate::dpe::ProgramReport;

/// Probe health of one placed block group (its `slices` digit planes
/// share fate — they sit on consecutive slots of one tile and are read
/// out together).
#[derive(Debug, Clone, PartialEq)]
pub struct SlotHealth {
    /// First physical slot of the group.
    pub slot: ArraySlot,
    /// Model layer (core index in compile order).
    pub layer: usize,
    /// Block index within the layer's weight grid.
    pub block: usize,
    /// Probe relative error of the group's checksum readout.
    pub score: f64,
    /// `score <= probe_re_bound` — healthy groups are left in place.
    pub healthy: bool,
}

/// Chip-wide probe results plus the overhead accounting the yield bench
/// reports (`BENCH_repair.json`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct HealthReport {
    pub slots: Vec<SlotHealth>,
    /// Probe matmuls executed (probe vectors × k-blocks, summed over
    /// cores) — the probe overhead relative to real inference work.
    pub probe_matmuls: usize,
}

impl HealthReport {
    /// `(layer, block)` of every group failing its probe bound.
    pub fn condemned(&self) -> Vec<(usize, usize)> {
        self.slots.iter().filter(|s| !s.healthy).map(|s| (s.layer, s.block)).collect()
    }

    /// Probe score of one group, if it was probed.
    pub fn score_of(&self, layer: usize, block: usize) -> Option<f64> {
        self.slots.iter().find(|s| s.layer == layer && s.block == block).map(|s| s.score)
    }
}

/// One planned migration: a condemned block group leaves its `from` slots
/// for `to` (spare slots within one tile) and reprograms at `new_stream`.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockMove {
    pub layer: usize,
    pub block: usize,
    pub from: Vec<ArraySlot>,
    pub to: Vec<ArraySlot>,
    /// Global slot id of `to[0]` — the block's new programming stream.
    pub new_stream: u64,
}

/// The remap plan for one repair round: which groups move where, and
/// which condemned groups found no spare capacity (they stay in place and
/// degrade the chip instead).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RepairPlan {
    pub moves: Vec<BlockMove>,
    /// Condemned `(layer, block)` groups with no spare group left.
    pub unplaced: Vec<(usize, usize)>,
}

impl RepairPlan {
    /// Plan spare allocations for `condemned` `(layer, block)` groups of
    /// `placement`. Deterministic and never double-booking: each tile's
    /// spare tail is handed out first-fit in index order, whole groups
    /// only (the allocator invariant — a group's planes share input
    /// drivers), with the group's home tile preferred so a repair stays
    /// local when it can. Spare slots *already occupied* by the placement
    /// (blocks moved there by an earlier repair round) stay booked, so
    /// repeated heal rounds on a long-serving chip never double-allocate.
    /// Groups that fit nowhere land in `unplaced`.
    pub fn plan(placement: &Placement, condemned: &[(usize, usize)]) -> RepairPlan {
        let chip = &placement.chip;
        let data_cap = chip.data_arrays_per_tile();
        let mut free: Vec<Vec<bool>> = vec![vec![true; chip.spares_per_tile]; chip.tiles];
        for lp in &placement.layers {
            for s in &lp.slots {
                if s.index >= data_cap {
                    free[s.tile][s.index - data_cap] = false;
                }
            }
        }
        let fit = |tail: &[bool], slices: usize| -> Option<usize> {
            (0..tail.len().saturating_sub(slices - 1))
                .find(|&i| tail[i..i + slices].iter().all(|&f| f))
        };
        let mut plan = RepairPlan::default();
        for &(layer, block) in condemned {
            let lp = &placement.layers[layer];
            assert!(block < lp.blocks, "block {block} out of layer {layer}'s {}", lp.blocks);
            let slices = lp.slices;
            let from = lp.slots[block * slices..(block + 1) * slices].to_vec();
            let home = from[0].tile;
            // Prefer the home tile, then scan the chip in tile order.
            let found = std::iter::once(home)
                .chain(0..chip.tiles)
                .find_map(|t| fit(&free[t], slices).map(|off| (t, off)));
            let Some((tile, off)) = found else {
                plan.unplaced.push((layer, block));
                continue;
            };
            let to: Vec<ArraySlot> =
                (0..slices).map(|s| ArraySlot { tile, index: data_cap + off + s }).collect();
            for s in 0..slices {
                free[tile][off + s] = false;
            }
            plan.moves.push(BlockMove {
                layer,
                block,
                new_stream: chip.slot_id(to[0]),
                from,
                to,
            });
        }
        plan
    }
}

/// Structured graceful-degradation record: the chip keeps serving, but
/// these condemned groups could not be repaired and still sit on faulty
/// arrays. Attached to [`crate::arch::MappedModel`] instead of erroring.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DegradedReport {
    /// Unrepaired `(layer, block)` groups.
    pub condemned: Vec<(usize, usize)>,
    /// Their physical slots (first slot per group).
    pub slots: Vec<ArraySlot>,
    /// Worst probe relative error among the unrepaired groups — the
    /// estimated RE impact of continuing to serve degraded.
    pub estimated_re_impact: f64,
}

impl DegradedReport {
    /// Build from the groups a [`RepairPlan`] could not place, scoring
    /// the impact with their probe results.
    pub fn from_unplaced(
        placement: &Placement,
        health: &HealthReport,
        plan: &RepairPlan,
    ) -> Option<DegradedReport> {
        if plan.unplaced.is_empty() {
            return None;
        }
        let mut rep = DegradedReport::default();
        for &(layer, block) in &plan.unplaced {
            let lp = &placement.layers[layer];
            rep.condemned.push((layer, block));
            rep.slots.push(lp.slots[block * lp.slices]);
            if let Some(score) = health.score_of(layer, block) {
                rep.estimated_re_impact = rep.estimated_re_impact.max(score);
            }
        }
        Some(rep)
    }

    /// Fold `other` into this report, shifting its core indices by
    /// `layer_offset` — how [`crate::arch::ShardedModel`] merges the
    /// per-stage reports (each stage counts placed cores from zero) into
    /// one fleet-wide view.
    pub fn merge(&mut self, other: &DegradedReport, layer_offset: usize) {
        for (i, &(layer, block)) in other.condemned.iter().enumerate() {
            self.condemned.push((layer + layer_offset, block));
            self.slots.push(other.slots[i]);
        }
        self.estimated_re_impact = self.estimated_re_impact.max(other.estimated_re_impact);
    }
}

/// The result of one [`crate::arch::MappedModel::self_heal`] round.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RepairOutcome {
    /// Per-core program-and-verify accounting (empty when the spec
    /// disables verification).
    pub program_reports: Vec<ProgramReport>,
    /// Probe scores of every placed block group.
    pub health: HealthReport,
    /// The migrations applied (and the groups left behind).
    pub plan: RepairPlan,
    /// Present iff some condemned groups could not be repaired.
    pub degraded: Option<DegradedReport>,
}

impl RepairOutcome {
    /// Total verify retries across all cores.
    pub fn total_retries(&self) -> usize {
        self.program_reports.iter().map(ProgramReport::total_retries).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ChipSpec, CoreDemand, TileAllocator};
    use crate::util::prop::prop_check;

    fn demand(layer: usize, blocks: usize, slices: usize) -> CoreDemand {
        CoreDemand { layer, name: "TestCore", blocks, slices }
    }

    #[test]
    fn plan_prefers_home_tile_and_spills_to_others() {
        // 2 tiles x (8 data + 4 spare), 4-slice groups: tile 0 holds
        // layer-0 groups 0..2, tile 1 groups 2..4. Condemning two groups
        // of tile 0 uses tile 0's one spare group, then tile 1's.
        let chip = ChipSpec::new(2, 12, (64, 64)).with_spares(4);
        let p = TileAllocator::allocate(&chip, &[demand(0, 4, 4)]).unwrap();
        let plan = RepairPlan::plan(&p, &[(0, 0), (0, 1)]);
        assert_eq!(plan.moves.len(), 2);
        assert!(plan.unplaced.is_empty());
        assert_eq!(plan.moves[0].to[0], ArraySlot { tile: 0, index: 8 });
        assert_eq!(plan.moves[0].new_stream, 8);
        assert_eq!(plan.moves[1].to[0], ArraySlot { tile: 1, index: 8 });
        assert_eq!(plan.moves[1].new_stream, 20);
        assert_eq!(plan.moves[0].from, p.layers[0].slots[0..4].to_vec());
    }

    #[test]
    fn exhausted_spares_degrade_instead_of_erroring() {
        let chip = ChipSpec::new(1, 12, (64, 64)).with_spares(4);
        let p = TileAllocator::allocate(&chip, &[demand(0, 2, 4)]).unwrap();
        let plan = RepairPlan::plan(&p, &[(0, 0), (0, 1)]);
        assert_eq!(plan.moves.len(), 1, "one spare group available");
        assert_eq!(plan.unplaced, vec![(0, 1)]);
        let health = HealthReport {
            slots: vec![
                SlotHealth {
                    slot: p.layers[0].slots[0],
                    layer: 0,
                    block: 0,
                    score: 0.9,
                    healthy: false,
                },
                SlotHealth {
                    slot: p.layers[0].slots[4],
                    layer: 0,
                    block: 1,
                    score: 0.7,
                    healthy: false,
                },
            ],
            probe_matmuls: 4,
        };
        assert_eq!(health.condemned(), vec![(0, 0), (0, 1)]);
        let deg = DegradedReport::from_unplaced(&p, &health, &plan).unwrap();
        assert_eq!(deg.condemned, vec![(0, 1)]);
        assert_eq!(deg.estimated_re_impact, 0.7);
        // A fully-placed plan reports no degradation.
        let ok = RepairPlan::plan(&p, &[(0, 0)]);
        assert!(DegradedReport::from_unplaced(&p, &health, &ok).is_none());
    }

    #[test]
    fn second_round_planning_respects_occupied_spares() {
        // After applying a first round's moves to the placement, a second
        // round must not hand out the same spare slots again.
        let chip = ChipSpec::new(1, 20, (64, 64)).with_spares(12);
        let mut p = TileAllocator::allocate(&chip, &[demand(0, 2, 4)]).unwrap();
        let first = RepairPlan::plan(&p, &[(0, 0)]);
        assert_eq!(first.moves.len(), 1);
        assert_eq!(first.moves[0].to[0], ArraySlot { tile: 0, index: 8 });
        {
            let lp = &mut p.layers[0];
            lp.slots[0..4].copy_from_slice(&first.moves[0].to);
            lp.block_streams[0] = first.moves[0].new_stream;
        }
        let second = RepairPlan::plan(&p, &[(0, 1)]);
        assert_eq!(second.moves.len(), 1);
        assert_eq!(
            second.moves[0].to[0],
            ArraySlot { tile: 0, index: 12 },
            "round 2 must skip the spare group round 1 occupies"
        );
        // And a third group has nowhere to go: only 4 free spare slots
        // remain and they are already booked by round 2's plan state in a
        // combined plan.
        let both = RepairPlan::plan(&p, &[(0, 0), (0, 1)]);
        assert_eq!(both.moves.len(), 2);
        assert!(both.unplaced.is_empty());
        assert_ne!(both.moves[0].to[0], both.moves[1].to[0]);
    }

    #[test]
    fn prop_remap_preserves_bijection_and_never_double_books() {
        // Satellite property: over random chips, demands, and condemned
        // subsets — every move targets whole spare groups within one
        // tile, no spare slot is booked twice, no move targets a data
        // slot, and moves + unplaced partition the condemned set.
        prop_check("repair plan slot bijection", 200, |g| {
            let apt = g.usize_in(6..=24);
            let spares = g.usize_in(0..=apt - 2);
            let slices = g.usize_in(1..=4.min(apt - spares));
            let n_layers = g.usize_in(1..=3);
            let demands: Vec<CoreDemand> =
                (0..n_layers).map(|li| demand(li, g.usize_in(1..=4), slices)).collect();
            let total: usize = demands.iter().map(CoreDemand::planes).sum();
            let chip = ChipSpec::fit(2 * total + apt, apt, (64, 64)).with_spares(spares);
            let p = TileAllocator::allocate(&chip, &demands)
                .map_err(|e| format!("unexpected capacity error: {e}"))?;
            // Condemn a random subset of groups.
            let mut condemned = Vec::new();
            for (li, d) in demands.iter().enumerate() {
                for b in 0..d.blocks {
                    if g.bool() {
                        condemned.push((li, b));
                    }
                }
            }
            let plan = RepairPlan::plan(&p, &condemned);
            if plan.moves.len() + plan.unplaced.len() != condemned.len() {
                return Err("moves + unplaced do not partition the condemned set".into());
            }
            let data_cap = chip.data_arrays_per_tile();
            let mut booked = std::collections::HashSet::new();
            for m in &plan.moves {
                if m.to.len() != slices {
                    return Err("move does not carry the whole group".into());
                }
                if m.to.iter().any(|s| s.tile != m.to[0].tile) {
                    return Err("moved group straddles tiles".into());
                }
                for s in &m.to {
                    if s.index < data_cap || s.index >= apt {
                        return Err(format!("move target {s:?} is not a spare slot"));
                    }
                    if !booked.insert(chip.slot_id(*s)) {
                        return Err(format!("spare slot {s:?} double-booked"));
                    }
                }
                if m.new_stream != chip.slot_id(m.to[0]) {
                    return Err("new_stream is not the first target slot's id".into());
                }
                let lp = &p.layers[m.layer];
                if m.from != lp.slots[m.block * slices..(m.block + 1) * slices] {
                    return Err("move.from does not match the placement".into());
                }
            }
            // Unplaced groups really had no capacity: with a uniform
            // group size, a group is only left behind once every tile's
            // spare tail holds fewer than `slices` free arrays — i.e. all
            // whole spare groups are booked.
            if !plan.unplaced.is_empty() && plan.moves.len() != chip.tiles * (spares / slices) {
                return Err("group unplaced while spare capacity remained".into());
            }
            // Determinism.
            if RepairPlan::plan(&p, &condemned) != plan {
                return Err("plan not deterministic".into());
            }
            Ok(())
        });
    }
}

//! Fault-tolerant serving runtime over replicated [`MappedModel`]s: the
//! "millions of users" layer that puts the self-healing chip machinery
//! ([`super::repair`]) under live traffic.
//!
//! A [`ServingRuntime`] owns a pool of N replicas compiled from the same
//! `Sequential` template by a caller-supplied [`ReplicaFactory`]. Each
//! replica binds its own engine seed, so hardware noise decorrelates
//! across the pool while the weights stay identical. Requests flow
//! through:
//!
//! - a **bounded FIFO queue** with admission control — a full queue
//!   rejects new arrivals with a typed [`ServeError::QueueFull`], never a
//!   silent drop;
//! - **dynamic micro-batching** — a batch dispatches to the lowest-id
//!   free replica as soon as `max_batch` requests wait, or when the
//!   oldest waiting request has aged past `batch_deadline_us`;
//! - **per-request deadlines** — a request that waits out
//!   `request_deadline_us` end-to-end fails typed
//!   ([`ServeError::DeadlineExceeded`]);
//! - **bounded retry with backoff** — a fault event that strikes a
//!   replica mid-service kills its in-flight batch; every killed request
//!   re-enters the queue after `retry_backoff_us · 2^(attempt-1)` and is
//!   steered to a *different* replica (best effort: the exclusion is
//!   waived when only one replica remains in rotation), up to
//!   `max_retries` retries ([`ServeError::RetriesExhausted`] after);
//! - a **background health pass** every `health_period_us`: the ABFT
//!   checksum probes ([`MappedModel::health_probe`]) scan each idle
//!   replica; a suspect or failing replica leaves rotation for
//!   `heal_us`, runs [`MappedModel::self_heal`], and returns — possibly
//!   degraded (condemned groups zeroed, [`super::DegradedReport`]
//!   attached) when spares are exhausted. Groups already fenced off do
//!   not re-trigger the pull, so a degraded replica keeps serving
//!   instead of thrashing in and out of rotation.
//!
//! **Time is simulated.** [`SimClock`] is integer microseconds advanced
//! by a deterministic discrete-event loop — no `std::time::Instant`
//! anywhere in the hot path, so every run (latencies, retries, heal
//! timing, outputs) is bit-reproducible for a fixed workload, spec, and
//! factory. Inference itself is real: every dispatched batch runs
//! [`MappedModel::infer_batched`] through the full DPE pipeline; only
//! the *duration* of that work is modeled (`service_base_us +
//! service_per_sample_us · batch`).
//!
//! **Drift.** With `drift_refresh` on, each health pass rebuilds idle
//! replicas at `t_read = seconds since their last programming`, so the
//! existing power-law retention model
//! ([`crate::device::faults::NonIdealitySpec::t_read`]) ages the
//! conductances in simulated time; when drift pushes the probes over
//! their bound the replica is pulled and healing reprograms it fresh
//! (`t_read = 0` — a rewrite restarts the drift clock).

use super::fleet::ShardedModel;
use super::mapped::MappedModel;
use super::repair::{DegradedReport, HealthReport, RepairOutcome};
use crate::dpe::RepairSpec;
use crate::tensor::Tensor;
use std::collections::VecDeque;
use std::fmt;

/// Simulated wall-clock in integer microseconds. The serving runtime
/// never reads host time; tests and benches are deterministic.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SimClock {
    now_us: u64,
}

impl SimClock {
    pub fn now_us(&self) -> u64 {
        self.now_us
    }
    fn advance_to(&mut self, t: u64) {
        debug_assert!(t >= self.now_us, "simulated time must not run backwards");
        self.now_us = t;
    }
}

/// The `[serving]` knobs (TOML section, see
/// [`crate::coordinator::SimConfig`]). All times are simulated
/// microseconds.
#[derive(Debug, Clone, PartialEq)]
pub struct ServingSpec {
    /// Pool size (replica `MappedModel`s, decorrelated noise streams).
    pub replicas: usize,
    /// Bounded request queue: arrivals beyond this are rejected typed.
    pub queue_capacity: usize,
    /// Dispatch as soon as this many requests wait for one batch.
    pub max_batch: usize,
    /// …or when the oldest waiting request has waited this long.
    pub batch_deadline_us: u64,
    /// End-to-end per-request deadline (arrival → completion).
    pub request_deadline_us: u64,
    /// Max retries after a mid-service fault (attempts = retries + 1).
    pub max_retries: usize,
    /// Base retry backoff; attempt k waits `backoff · 2^(k-1)`.
    pub retry_backoff_us: u64,
    /// Background health-scan period; 0 disables scans (and healing).
    pub health_period_us: u64,
    /// Time a replica spends out of rotation for one self-heal round.
    pub heal_us: u64,
    /// Service-time model: fixed cost per dispatched batch…
    pub service_base_us: u64,
    /// …plus marginal cost per sample in the batch.
    pub service_per_sample_us: u64,
    /// Age replicas by rebuilding them at `t_read = time since last
    /// programming` on each scan (power-law drift); healing resets the
    /// drift clock by reprogramming at `t_read = 0`.
    pub drift_refresh: bool,
    /// Chips per replica: 1 serves single-chip [`MappedModel`]s; ≥ 2
    /// asks the factory for [`ShardedModel`] pipelines spanning a fleet
    /// of that many chips (see [`super::fleet`]). Pools may still mix —
    /// the value sizes the fleet handed to [`MixedFactory`] callers.
    pub shards_per_replica: usize,
}

impl Default for ServingSpec {
    fn default() -> Self {
        ServingSpec {
            replicas: 2,
            queue_capacity: 32,
            max_batch: 8,
            batch_deadline_us: 2_000,
            request_deadline_us: 50_000,
            max_retries: 2,
            retry_backoff_us: 500,
            health_period_us: 0,
            heal_us: 10_000,
            service_base_us: 200,
            service_per_sample_us: 50,
            drift_refresh: false,
            shards_per_replica: 1,
        }
    }
}

/// Typed request-failure reasons — backpressure and timeouts are part of
/// the serving contract, never silent drops.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Rejected at admission: the bounded queue was full.
    QueueFull { queued: usize, capacity: usize },
    /// Waited out its end-to-end deadline before a replica served it.
    DeadlineExceeded { waited_us: u64, deadline_us: u64 },
    /// Killed by faults on every attempt the retry budget allowed.
    RetriesExhausted { attempts: usize },
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::QueueFull { queued, capacity } => {
                write!(f, "queue full ({queued}/{capacity})")
            }
            ServeError::DeadlineExceeded { waited_us, deadline_us } => {
                write!(f, "deadline exceeded (waited {waited_us}µs > {deadline_us}µs)")
            }
            ServeError::RetriesExhausted { attempts } => {
                write!(f, "retries exhausted after {attempts} attempts")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// One request: an arrival time and a flat sample (shape given to
/// [`ServingRuntime::new`]).
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub arrive_us: u64,
    pub sample: Vec<f64>,
}

/// A scripted mid-run hardware fault: at `at_us`, `replica`'s chip
/// acquires the factory's faulty condition (stuck cells etc.), killing
/// whatever batch it was serving.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    pub at_us: u64,
    pub replica: usize,
}

/// Successful completion of one request.
#[derive(Debug, Clone, PartialEq)]
pub struct Completion {
    /// The model's output row for this request.
    pub output: Vec<f64>,
    pub replica: usize,
    /// Dispatch attempts (1 = served first try; ≤ `max_retries + 1`).
    pub attempts: usize,
    /// Arrival → delivery, simulated µs.
    pub latency_us: u64,
    /// Index into [`ServeReport::batches`].
    pub batch: usize,
}

/// Exactly-once resolution of one request.
#[derive(Debug, Clone, PartialEq)]
pub enum Outcome {
    Done(Completion),
    Failed { error: ServeError, at_us: u64 },
}

/// One dispatched micro-batch (also the replay unit for bit-identity
/// checks: stack the member samples, run `infer_batched` on a twin
/// replica, compare rows).
#[derive(Debug, Clone, PartialEq)]
pub struct BatchRecord {
    pub batch: usize,
    pub replica: usize,
    /// Member request ids in dispatch (FIFO) order.
    pub requests: Vec<usize>,
    pub dispatched_us: u64,
    /// Delivery time, or the kill time for a failed batch.
    pub completed_us: u64,
    /// False iff a fault event killed the batch mid-service.
    pub ok: bool,
}

/// One self-heal round a health pass triggered.
#[derive(Debug, Clone, PartialEq)]
pub struct HealRecord {
    pub replica: usize,
    pub started_us: u64,
    pub finished_us: u64,
    /// Condemned groups remapped onto spares.
    pub moves: usize,
    /// Groups fenced off (zeroed) because no healthy spare remained.
    pub fenced: usize,
    /// Program-and-verify retries the round spent.
    pub verify_retries: usize,
}

/// Timeline entry kinds (see [`Event`]).
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    Dispatch { batch: usize, replica: usize, requests: usize },
    BatchDone { batch: usize, replica: usize },
    BatchFailed { batch: usize, replica: usize, retried: usize, exhausted: usize },
    FaultInjected { replica: usize },
    Rejected { request: usize, error: ServeError },
    HealthScan { replica: usize, worst_score: f64, pulled: bool },
    HealStart { replica: usize },
    HealDone { replica: usize, moves: usize, fenced: usize },
    DriftRefresh { replica: usize, t_read_s: f64 },
}

/// One timeline entry — the failover/heal story of a run, in time order.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub at_us: u64,
    pub kind: EventKind,
}

/// The condition a replica should be (re)built under — handed to the
/// [`ReplicaFactory`] so the runtime stays agnostic of engine plumbing.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ReplicaSpec {
    /// Drift age in seconds since the replica's last programming
    /// (feeds `NonIdealitySpec::t_read`; 0 = freshly programmed).
    pub t_read_s: f64,
    /// Whether the replica's chip has sustained a fault event.
    pub faulty: bool,
}

/// Builds replica `i` of the pool under the given condition. Must be
/// deterministic per `(i, condition)`: the factory is re-invoked to age
/// (drift), damage (fault events), and reprogram (healing) replicas, and
/// twin rebuilds are how benches verify bit-identity.
pub type ReplicaFactory<'a> = Box<dyn Fn(usize, &ReplicaSpec) -> anyhow::Result<MappedModel> + 'a>;

/// One pool member: a single-chip [`MappedModel`] or a multi-chip
/// [`ShardedModel`] pipeline (see [`super::fleet`]). Mixed pools let one
/// deployment serve an oversized sharded model next to ordinary
/// single-chip replicas behind the same queue, retry, and heal policy.
pub enum ReplicaModel {
    Single(MappedModel),
    Sharded(ShardedModel),
}

impl ReplicaModel {
    /// Chips backing this replica (1 for `Single`).
    pub fn chip_count(&self) -> usize {
        match self {
            ReplicaModel::Single(_) => 1,
            ReplicaModel::Sharded(s) => s.plan().fleet.len(),
        }
    }

    pub fn as_single(&self) -> Option<&MappedModel> {
        match self {
            ReplicaModel::Single(m) => Some(m),
            ReplicaModel::Sharded(_) => None,
        }
    }

    pub fn as_sharded(&self) -> Option<&ShardedModel> {
        match self {
            ReplicaModel::Single(_) => None,
            ReplicaModel::Sharded(s) => Some(s),
        }
    }

    pub fn infer_batched(&self, x: &Tensor, micro_batch: usize) -> Tensor {
        match self {
            ReplicaModel::Single(m) => m.infer_batched(x, micro_batch),
            ReplicaModel::Sharded(s) => s.infer_batched(x, micro_batch),
        }
    }

    pub fn health_probe(&self, spec: &RepairSpec) -> anyhow::Result<HealthReport> {
        match self {
            ReplicaModel::Single(m) => m.health_probe(spec),
            ReplicaModel::Sharded(s) => s.health_probe(spec),
        }
    }

    pub fn self_heal(&mut self, spec: &RepairSpec) -> anyhow::Result<RepairOutcome> {
        match self {
            ReplicaModel::Single(m) => m.self_heal(spec),
            ReplicaModel::Sharded(s) => s.self_heal(spec),
        }
    }

    pub fn degraded(&self) -> Option<&DegradedReport> {
        match self {
            ReplicaModel::Single(m) => m.degraded(),
            ReplicaModel::Sharded(s) => s.degraded(),
        }
    }
}

/// Like [`ReplicaFactory`], but each replica may come up single-chip or
/// sharded — the mixed-pool entry point ([`ServingRuntime::new_mixed`]).
pub type MixedFactory<'a> = Box<dyn Fn(usize, &ReplicaSpec) -> anyhow::Result<ReplicaModel> + 'a>;

/// Full account of one [`ServingRuntime::run`]: exactly one [`Outcome`]
/// per request (index-aligned with the workload), every dispatched
/// batch, the heal rounds, and the event timeline.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeReport {
    pub outcomes: Vec<Outcome>,
    pub batches: Vec<BatchRecord>,
    pub heals: Vec<HealRecord>,
    pub events: Vec<Event>,
    /// Time of the last request resolution (simulated µs).
    pub makespan_us: u64,
}

impl ServeReport {
    pub fn completed(&self) -> usize {
        self.outcomes.iter().filter(|o| matches!(o, Outcome::Done(_))).count()
    }

    pub fn failed(&self) -> usize {
        self.outcomes.len() - self.completed()
    }

    /// `(queue_full, deadline_exceeded, retries_exhausted)` counts.
    pub fn failure_breakdown(&self) -> (usize, usize, usize) {
        let mut counts = (0usize, 0usize, 0usize);
        for o in &self.outcomes {
            if let Outcome::Failed { error, .. } = o {
                match error {
                    ServeError::QueueFull { .. } => counts.0 += 1,
                    ServeError::DeadlineExceeded { .. } => counts.1 += 1,
                    ServeError::RetriesExhausted { .. } => counts.2 += 1,
                }
            }
        }
        counts
    }

    /// Completed-request latencies, ascending (simulated µs).
    pub fn latencies_us(&self) -> Vec<u64> {
        let mut l: Vec<u64> = self
            .outcomes
            .iter()
            .filter_map(|o| match o {
                Outcome::Done(c) => Some(c.latency_us),
                Outcome::Failed { .. } => None,
            })
            .collect();
        l.sort_unstable();
        l
    }

    /// Latency percentile over completed requests (`q` in `(0, 1]`,
    /// nearest-rank). `None` when nothing completed.
    pub fn percentile_latency_us(&self, q: f64) -> Option<u64> {
        let l = self.latencies_us();
        if l.is_empty() {
            return None;
        }
        let idx = ((q * l.len() as f64).ceil() as usize).clamp(1, l.len()) - 1;
        Some(l[idx])
    }

    /// Completed requests per simulated second of makespan.
    pub fn images_per_sec(&self) -> f64 {
        if self.makespan_us == 0 {
            return 0.0;
        }
        self.completed() as f64 / (self.makespan_us as f64 * 1e-6)
    }

    /// Total retry dispatches (attempts beyond each request's first).
    pub fn total_retries(&self) -> usize {
        self.outcomes
            .iter()
            .filter_map(|o| match o {
                Outcome::Done(c) => Some(c.attempts - 1),
                Outcome::Failed { .. } => None,
            })
            .sum()
    }

    /// The headline metrics as one compact JSON object — the shared
    /// emitter behind `BENCH_serving.json` and `BENCH_sharding.json`
    /// scenario entries. Percentiles over an empty completion set come
    /// out `null`.
    pub fn to_json(&self) -> String {
        use std::fmt::Write as _;
        let (queue_full, deadline, exhausted) = self.failure_breakdown();
        let mut s = String::new();
        let _ = write!(
            s,
            "{{\"requests\":{},\"completed\":{},\"failed\":{},\"queue_full\":{queue_full},\
             \"deadline_exceeded\":{deadline},\"retries_exhausted\":{exhausted},\
             \"retries\":{},\"heals\":{},\"batches\":{},\"makespan_us\":{},\
             \"images_per_sec\":{:.3}",
            self.outcomes.len(),
            self.completed(),
            self.failed(),
            self.total_retries(),
            self.heals.len(),
            self.batches.len(),
            self.makespan_us,
            self.images_per_sec()
        );
        for (name, q) in [("p50", 0.50), ("p95", 0.95), ("p99", 0.99)] {
            match self.percentile_latency_us(q) {
                Some(v) => {
                    let _ = write!(s, ",\"{name}_us\":{v}");
                }
                None => {
                    let _ = write!(s, ",\"{name}_us\":null");
                }
            }
        }
        s.push('}');
        s
    }
}

/// A queued request (or a retry waiting out its backoff).
#[derive(Debug, Clone)]
struct Pending {
    id: usize,
    arrive_us: u64,
    /// When it (re-)entered the queue — the batching deadline reference.
    queued_since: u64,
    /// Earliest re-dispatch time (retry backoff); arrivals: arrival time.
    ready_at: u64,
    /// Dispatches so far (0 = never dispatched).
    dispatches: usize,
    /// Replica the last fault struck — steer the retry elsewhere.
    exclude: Option<usize>,
}

struct InFlight {
    batch: usize,
    reqs: Vec<Pending>,
    /// Output row per member request, computed at dispatch (the compute
    /// is real and deterministic; only delivery is delayed).
    outputs: Vec<Vec<f64>>,
    done_at: u64,
}

struct Replica {
    model: ReplicaModel,
    cond: ReplicaSpec,
    /// Last (re)programming time — the drift-age reference.
    programmed_at_us: u64,
    /// Out of rotation for healing until this time.
    healing_until: Option<u64>,
    /// A fault event struck since the last heal: the next scan pulls the
    /// replica even if the probes sneak under their bound.
    suspect: bool,
    inflight: Option<InFlight>,
    heals: usize,
    /// `(moves, fenced)` of the heal in progress, for the HealDone event.
    last_heal: (usize, usize),
}

/// The replicated serving runtime. See the module docs.
pub struct ServingRuntime<'a> {
    spec: ServingSpec,
    repair: RepairSpec,
    in_shape: Vec<usize>,
    factory: MixedFactory<'a>,
    replicas: Vec<Replica>,
}

impl<'a> ServingRuntime<'a> {
    /// Build a single-chip pool: replica `i` comes from
    /// `factory(i, &ReplicaSpec::default())`. `in_shape` is the
    /// per-sample feature shape (batches stack to `[b, in_shape…]`).
    pub fn new(
        spec: ServingSpec,
        repair: RepairSpec,
        in_shape: Vec<usize>,
        factory: ReplicaFactory<'a>,
    ) -> anyhow::Result<Self> {
        Self::new_mixed(
            spec,
            repair,
            in_shape,
            Box::new(move |i, cond| factory(i, cond).map(ReplicaModel::Single)),
        )
    }

    /// Build a pool whose members may be single-chip or sharded
    /// ([`ReplicaModel`]); otherwise identical to [`ServingRuntime::new`].
    pub fn new_mixed(
        spec: ServingSpec,
        repair: RepairSpec,
        in_shape: Vec<usize>,
        factory: MixedFactory<'a>,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(spec.replicas >= 1, "serving: pool needs at least one replica");
        anyhow::ensure!(spec.queue_capacity >= 1, "serving: queue_capacity must be >= 1");
        anyhow::ensure!(spec.max_batch >= 1, "serving: max_batch must be >= 1");
        anyhow::ensure!(spec.shards_per_replica >= 1, "serving: shards_per_replica must be >= 1");
        let sample_len: usize = in_shape.iter().product();
        anyhow::ensure!(sample_len > 0, "serving: in_shape must be non-empty");
        let mut replicas = Vec::with_capacity(spec.replicas);
        for i in 0..spec.replicas {
            let cond = ReplicaSpec::default();
            let model = factory(i, &cond)?;
            replicas.push(Replica {
                model,
                cond,
                programmed_at_us: 0,
                healing_until: None,
                suspect: false,
                inflight: None,
                heals: 0,
                last_heal: (0, 0),
            });
        }
        Ok(ServingRuntime { spec, repair, in_shape, factory, replicas })
    }

    pub fn pool_size(&self) -> usize {
        self.replicas.len()
    }

    pub fn spec(&self) -> &ServingSpec {
        &self.spec
    }

    /// The current model of replica `i` (post-run: inspect heal state via
    /// [`ReplicaModel::degraded`]).
    pub fn replica(&self, i: usize) -> &ReplicaModel {
        &self.replicas[i].model
    }

    /// The condition replica `i` was last built under.
    pub fn replica_condition(&self, i: usize) -> ReplicaSpec {
        self.replicas[i].cond
    }

    /// Self-heal rounds replica `i` has been through.
    pub fn heal_count(&self, i: usize) -> usize {
        self.replicas[i].heals
    }

    /// Serve an open-loop workload (sorted by `arrive_us`) against
    /// scripted fault events (sorted by `at_us`; events after the last
    /// resolution have no effect). Deterministic: same inputs, same
    /// report, bit for bit. Panics if any request would be lost or
    /// double-answered — those are the runtime's own invariants.
    pub fn run(
        &mut self,
        workload: &[Request],
        faults: &[FaultEvent],
    ) -> anyhow::Result<ServeReport> {
        let sample_len: usize = self.in_shape.iter().product();
        anyhow::ensure!(
            workload.windows(2).all(|w| w[0].arrive_us <= w[1].arrive_us),
            "serving: workload must be sorted by arrive_us"
        );
        for (i, r) in workload.iter().enumerate() {
            anyhow::ensure!(
                r.sample.len() == sample_len,
                "serving: request {i} sample len {} != in_shape product {sample_len}",
                r.sample.len()
            );
        }
        anyhow::ensure!(
            faults.windows(2).all(|w| w[0].at_us <= w[1].at_us),
            "serving: fault events must be sorted by at_us"
        );
        for f in faults {
            anyhow::ensure!(
                f.replica < self.replicas.len(),
                "serving: fault event targets replica {} of a {}-replica pool",
                f.replica,
                self.replicas.len()
            );
        }

        let n = workload.len();
        let mut outcomes: Vec<Option<Outcome>> = vec![None; n];
        let mut resolved = 0usize;
        let mut makespan = 0u64;
        let mut events: Vec<Event> = Vec::new();
        let mut batches: Vec<BatchRecord> = Vec::new();
        let mut heals: Vec<HealRecord> = Vec::new();
        let mut queue: VecDeque<Pending> = VecDeque::new();
        let mut retries: Vec<Pending> = Vec::new();
        let mut next_arrival = 0usize;
        let mut next_fault = 0usize;
        let mut next_scan =
            (self.spec.health_period_us > 0).then_some(self.spec.health_period_us);
        let mut clock = SimClock::default();

        fn resolve(
            slots: &mut [Option<Outcome>],
            resolved: &mut usize,
            makespan: &mut u64,
            id: usize,
            outcome: Outcome,
            at: u64,
        ) {
            assert!(slots[id].is_none(), "request {id} double-answered");
            slots[id] = Some(outcome);
            *resolved += 1;
            *makespan = (*makespan).max(at);
        }

        loop {
            let now = clock.now_us();

            // (1) Deliver batches whose service time elapsed.
            let due: Vec<usize> = self
                .replicas
                .iter()
                .enumerate()
                .filter(|(_, r)| r.inflight.as_ref().is_some_and(|fl| fl.done_at <= now))
                .map(|(i, _)| i)
                .collect();
            for ri in due {
                let Some(fl) = self.replicas[ri].inflight.take() else {
                    anyhow::bail!("serving: replica {ri} lost its in-flight batch at t={now}µs");
                };
                for (p, out) in fl.reqs.iter().zip(fl.outputs.into_iter()) {
                    resolve(
                        &mut outcomes,
                        &mut resolved,
                        &mut makespan,
                        p.id,
                        Outcome::Done(Completion {
                            output: out,
                            replica: ri,
                            attempts: p.dispatches,
                            latency_us: now - p.arrive_us,
                            batch: fl.batch,
                        }),
                        now,
                    );
                }
                batches[fl.batch].ok = true;
                batches[fl.batch].completed_us = now;
                events.push(Event {
                    at_us: now,
                    kind: EventKind::BatchDone { batch: fl.batch, replica: ri },
                });
            }

            // (2) Replicas done healing rejoin the rotation.
            let healed: Vec<usize> = self
                .replicas
                .iter()
                .enumerate()
                .filter(|(_, r)| r.healing_until.is_some_and(|t| t <= now))
                .map(|(i, _)| i)
                .collect();
            for ri in healed {
                self.replicas[ri].healing_until = None;
                let (moves, fenced) = self.replicas[ri].last_heal;
                events.push(Event {
                    at_us: now,
                    kind: EventKind::HealDone { replica: ri, moves, fenced },
                });
            }

            // (3) Fault events: the chip acquires its damaged condition;
            // any in-flight batch dies and its requests retry elsewhere.
            while next_fault < faults.len() && faults[next_fault].at_us <= now {
                let ri = faults[next_fault].replica;
                next_fault += 1;
                events.push(Event { at_us: now, kind: EventKind::FaultInjected { replica: ri } });
                self.replicas[ri].cond.faulty = true;
                self.replicas[ri].suspect = true;
                let cond = self.replicas[ri].cond;
                self.replicas[ri].model = (self.factory)(ri, &cond)?;
                if let Some(fl) = self.replicas[ri].inflight.take() {
                    batches[fl.batch].ok = false;
                    batches[fl.batch].completed_us = now;
                    let (mut retried, mut exhausted) = (0usize, 0usize);
                    for mut p in fl.reqs {
                        if p.dispatches > self.spec.max_retries {
                            resolve(
                                &mut outcomes,
                                &mut resolved,
                                &mut makespan,
                                p.id,
                                Outcome::Failed {
                                    error: ServeError::RetriesExhausted { attempts: p.dispatches },
                                    at_us: now,
                                },
                                now,
                            );
                            exhausted += 1;
                        } else {
                            let shift = (p.dispatches.min(20) as u32).saturating_sub(1);
                            let backoff = self.spec.retry_backoff_us.saturating_mul(1u64 << shift);
                            p.ready_at = now + backoff;
                            p.exclude = Some(ri);
                            retries.push(p);
                            retried += 1;
                        }
                    }
                    events.push(Event {
                        at_us: now,
                        kind: EventKind::BatchFailed {
                            batch: fl.batch,
                            replica: ri,
                            retried,
                            exhausted,
                        },
                    });
                }
            }

            // (4) Arrivals: bounded-queue admission control.
            while next_arrival < n && workload[next_arrival].arrive_us <= now {
                let id = next_arrival;
                next_arrival += 1;
                if queue.len() >= self.spec.queue_capacity {
                    let error = ServeError::QueueFull {
                        queued: queue.len(),
                        capacity: self.spec.queue_capacity,
                    };
                    events.push(Event {
                        at_us: now,
                        kind: EventKind::Rejected { request: id, error: error.clone() },
                    });
                    resolve(
                        &mut outcomes,
                        &mut resolved,
                        &mut makespan,
                        id,
                        Outcome::Failed { error, at_us: now },
                        now,
                    );
                } else {
                    queue.push_back(Pending {
                        id,
                        arrive_us: workload[id].arrive_us,
                        queued_since: now,
                        ready_at: now,
                        dispatches: 0,
                        exclude: None,
                    });
                }
            }

            // (5) Retries whose backoff elapsed re-enter the queue at
            // their arrival-order position (retries bypass admission:
            // they were already admitted once).
            retries.sort_by_key(|p| (p.ready_at, p.id));
            while let Some(pos) = retries.iter().position(|p| p.ready_at <= now) {
                let mut p = retries.remove(pos);
                p.queued_since = now;
                let at = queue.iter().position(|q| q.id > p.id).unwrap_or(queue.len());
                queue.insert(at, p);
            }

            // (6) Per-request deadlines: whether queued or waiting out a
            // backoff, a request that aged past its end-to-end budget
            // fails typed — never a silent drop.
            for list_is_queue in [true, false] {
                let mut i = 0;
                loop {
                    let (len, arrive) = if list_is_queue {
                        (queue.len(), queue.get(i).map(|p| p.arrive_us))
                    } else {
                        (retries.len(), retries.get(i).map(|p| p.arrive_us))
                    };
                    if i >= len {
                        break;
                    }
                    let Some(arrive) = arrive else { break };
                    if now.saturating_sub(arrive) < self.spec.request_deadline_us {
                        i += 1;
                        continue;
                    }
                    let p = if list_is_queue {
                        queue.remove(i).ok_or_else(|| {
                            anyhow::anyhow!(
                                "serving: queue slot {i} vanished while expiring deadlines \
                                 at t={now}µs"
                            )
                        })?
                    } else {
                        retries.remove(i)
                    };
                    let error = ServeError::DeadlineExceeded {
                        waited_us: now - p.arrive_us,
                        deadline_us: self.spec.request_deadline_us,
                    };
                    events.push(Event {
                        at_us: now,
                        kind: EventKind::Rejected { request: p.id, error: error.clone() },
                    });
                    resolve(
                        &mut outcomes,
                        &mut resolved,
                        &mut makespan,
                        p.id,
                        Outcome::Failed { error, at_us: now },
                        now,
                    );
                }
            }

            // (7) Background health pass.
            if let Some(ts) = next_scan {
                if ts <= now {
                    self.run_scan(now, &mut events, &mut heals)?;
                    let period = self.spec.health_period_us;
                    let mut next = ts;
                    while next <= now {
                        next += period;
                    }
                    next_scan = Some(next);
                }
            }

            // (8) Dispatch: micro-batches form while a trigger holds and
            // a free in-rotation replica can take eligible requests.
            loop {
                if queue.is_empty() {
                    break;
                }
                let trigger = queue.len() >= self.spec.max_batch
                    || queue
                        .iter()
                        .any(|p| now >= p.queued_since + self.spec.batch_deadline_us);
                if !trigger {
                    break;
                }
                let in_rotation =
                    self.replicas.iter().filter(|r| r.healing_until.is_none()).count();
                let chosen = (0..self.replicas.len()).find(|&ri| {
                    let r = &self.replicas[ri];
                    r.healing_until.is_none()
                        && r.inflight.is_none()
                        && queue.iter().any(|p| p.exclude != Some(ri) || in_rotation <= 1)
                });
                let Some(ri) = chosen else { break };
                let mut members: Vec<Pending> = Vec::new();
                let mut qi = 0;
                while qi < queue.len() && members.len() < self.spec.max_batch {
                    if queue[qi].exclude != Some(ri) || in_rotation <= 1 {
                        let p = queue.remove(qi).ok_or_else(|| {
                            anyhow::anyhow!(
                                "serving: queue slot {qi} vanished while batching for \
                                 replica {ri} at t={now}µs"
                            )
                        })?;
                        members.push(p);
                    } else {
                        qi += 1;
                    }
                }
                debug_assert!(!members.is_empty());
                for p in &mut members {
                    p.dispatches += 1;
                }
                let b = members.len();
                let mut data = Vec::with_capacity(b * sample_len);
                for p in &members {
                    data.extend_from_slice(&workload[p.id].sample);
                }
                let mut shape = vec![b];
                shape.extend_from_slice(&self.in_shape);
                let y = self.replicas[ri].model.infer_batched(&Tensor::from_vec(&shape, data), b);
                let cols = y.data.len() / b;
                let outputs: Vec<Vec<f64>> =
                    (0..b).map(|i| y.data[i * cols..(i + 1) * cols].to_vec()).collect();
                let service = (self.spec.service_base_us
                    + self.spec.service_per_sample_us * b as u64)
                    .max(1);
                let done_at = now + service;
                let bid = batches.len();
                batches.push(BatchRecord {
                    batch: bid,
                    replica: ri,
                    requests: members.iter().map(|p| p.id).collect(),
                    dispatched_us: now,
                    completed_us: done_at,
                    ok: false,
                });
                events.push(Event {
                    at_us: now,
                    kind: EventKind::Dispatch { batch: bid, replica: ri, requests: b },
                });
                self.replicas[ri].inflight =
                    Some(InFlight { batch: bid, reqs: members, outputs, done_at });
            }

            if resolved == n {
                break;
            }

            // (9) Advance to the next event strictly after `now`.
            let mut nt = u64::MAX;
            let mut bump = |t: u64| {
                if t > now && t < nt {
                    nt = t;
                }
            };
            if next_arrival < n {
                bump(workload[next_arrival].arrive_us);
            }
            if next_fault < faults.len() {
                bump(faults[next_fault].at_us);
            }
            for r in &self.replicas {
                if let Some(fl) = &r.inflight {
                    bump(fl.done_at);
                }
                if let Some(t) = r.healing_until {
                    bump(t);
                }
            }
            for p in &retries {
                bump(p.ready_at);
                bump(p.arrive_us + self.spec.request_deadline_us);
            }
            for p in &queue {
                bump(p.queued_since + self.spec.batch_deadline_us);
                bump(p.arrive_us + self.spec.request_deadline_us);
            }
            if let Some(ts) = next_scan {
                bump(ts);
            }
            anyhow::ensure!(
                nt != u64::MAX,
                "serving runtime stalled at t={now}µs with {resolved}/{n} requests resolved"
            );
            clock.advance_to(nt);
        }

        let mut resolved_outcomes = Vec::with_capacity(n);
        for (i, o) in outcomes.into_iter().enumerate() {
            resolved_outcomes.push(o.ok_or_else(|| {
                anyhow::anyhow!("serving: request {i} was never resolved (exactly-once invariant)")
            })?);
        }
        Ok(ServeReport {
            outcomes: resolved_outcomes,
            batches,
            heals,
            events,
            makespan_us: makespan,
        })
    }

    /// One background health pass over every idle in-rotation replica:
    /// optional drift aging, ABFT probes, and — for suspect or failing
    /// replicas — a self-heal round out of rotation. Groups the last heal
    /// already fenced off (zeroed) do not re-trigger the pull: a degraded
    /// replica keeps serving instead of thrashing.
    fn run_scan(
        &mut self,
        now: u64,
        events: &mut Vec<Event>,
        heals: &mut Vec<HealRecord>,
    ) -> anyhow::Result<()> {
        let targets: Vec<usize> = self
            .replicas
            .iter()
            .enumerate()
            .filter(|(_, r)| r.healing_until.is_none() && r.inflight.is_none())
            .map(|(i, _)| i)
            .collect();
        for ri in targets {
            if self.spec.drift_refresh {
                let age_s = (now - self.replicas[ri].programmed_at_us) as f64 * 1e-6;
                if age_s > 0.0 && age_s != self.replicas[ri].cond.t_read_s {
                    self.replicas[ri].cond.t_read_s = age_s;
                    let cond = self.replicas[ri].cond;
                    self.replicas[ri].model = (self.factory)(ri, &cond)?;
                    events.push(Event {
                        at_us: now,
                        kind: EventKind::DriftRefresh { replica: ri, t_read_s: age_s },
                    });
                }
            }
            let health = self.replicas[ri].model.health_probe(&self.repair)?;
            let worst = health.slots.iter().map(|s| s.score).fold(0.0f64, f64::max);
            let fenced: Vec<(usize, usize)> = self.replicas[ri]
                .model
                .degraded()
                .map(|d| d.condemned.clone())
                .unwrap_or_default();
            let pulled = self.replicas[ri].suspect
                || health
                    .slots
                    .iter()
                    .any(|s| !s.healthy && !fenced.contains(&(s.layer, s.block)));
            events.push(Event {
                at_us: now,
                kind: EventKind::HealthScan { replica: ri, worst_score: worst, pulled },
            });
            if !pulled {
                continue;
            }
            if self.spec.drift_refresh && self.replicas[ri].cond.t_read_s != 0.0 {
                // Healing reprograms the chip *now* — drift clock restart.
                self.replicas[ri].cond.t_read_s = 0.0;
                let cond = self.replicas[ri].cond;
                self.replicas[ri].model = (self.factory)(ri, &cond)?;
            }
            events.push(Event { at_us: now, kind: EventKind::HealStart { replica: ri } });
            let out = self.replicas[ri].model.self_heal(&self.repair)?;
            let fenced_now = out.degraded.as_ref().map_or(0, |d| d.condemned.len());
            let rec = HealRecord {
                replica: ri,
                started_us: now,
                finished_us: now + self.spec.heal_us,
                moves: out.plan.moves.len(),
                fenced: fenced_now,
                verify_retries: out.total_retries(),
            };
            self.replicas[ri].last_heal = (rec.moves, rec.fenced);
            self.replicas[ri].heals += 1;
            self.replicas[ri].suspect = false;
            self.replicas[ri].programmed_at_us = now;
            self.replicas[ri].healing_until = Some(now + self.spec.heal_us);
            heals.push(rec);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ChipSpec;
    use crate::device::drift::DriftSpec;
    use crate::device::faults::{FaultSpec, NonIdealitySpec};
    use crate::dpe::{DotProductEngine, DpeConfig, SliceMethod, SliceSpec};
    use crate::nn::layers::LinearMem;
    use crate::nn::{HwSpec, Sequential};
    use crate::util::prop::prop_check;
    use crate::util::rng::Pcg64;

    fn hw(cfg: DpeConfig, seed: u64) -> HwSpec {
        HwSpec::uniform(DotProductEngine::new(cfg, seed), SliceMethod::int(SliceSpec::int8()))
    }

    /// The tiniest servable model: one 8→4 linear block group. Weight rng
    /// is fixed, so every replica carries the same template; the engine
    /// seed decorrelates hardware noise across the pool.
    fn tiny_replica(cfg: DpeConfig, engine_seed: u64) -> anyhow::Result<MappedModel> {
        let mut rng = Pcg64::new(9, 0x5EED);
        let m = Sequential::new(vec![Box::new(LinearMem::new(
            8,
            4,
            Some(hw(cfg, engine_seed)),
            &mut rng,
        ))]);
        let chip = ChipSpec::single_tile(m.mapped_planes(), (64, 64));
        m.compile(&chip)
    }

    fn tiny_factory<'a>() -> ReplicaFactory<'a> {
        Box::new(|i, _cond| tiny_replica(DpeConfig::default(), 100 + i as u64))
    }

    fn requests(n: usize, gap_us: u64) -> Vec<Request> {
        (0..n)
            .map(|j| Request {
                arrive_us: j as u64 * gap_us,
                sample: (0..8).map(|k| ((j * 3 + k) % 7) as f64 / 3.0 - 1.0).collect(),
            })
            .collect()
    }

    #[test]
    fn clean_pool_output_is_bit_identical_to_direct_inference() {
        let spec = ServingSpec { replicas: 2, max_batch: 3, ..ServingSpec::default() };
        let mut rt =
            ServingRuntime::new(spec, RepairSpec::none(), vec![8], tiny_factory()).unwrap();
        let work = requests(12, 100);
        let report = rt.run(&work, &[]).unwrap();

        assert_eq!(report.completed(), 12);
        assert_eq!(report.total_retries(), 0);
        // Replay every dispatched batch on a twin replica built by the
        // same factory: rows must match bit for bit (the runtime's
        // outputs come from the identical infer_batched call).
        for b in &report.batches {
            assert!(b.ok);
            let twin = tiny_replica(DpeConfig::default(), 100 + b.replica as u64).unwrap();
            let mut data = Vec::new();
            for &id in &b.requests {
                data.extend_from_slice(&work[id].sample);
            }
            let y = twin.infer_batched(
                &Tensor::from_vec(&[b.requests.len(), 8], data),
                b.requests.len(),
            );
            let cols = y.data.len() / b.requests.len();
            for (row, &id) in b.requests.iter().enumerate() {
                let Outcome::Done(c) = &report.outcomes[id] else {
                    panic!("request {id} not Done")
                };
                assert_eq!(c.batch, b.batch);
                let want = &y.data[row * cols..(row + 1) * cols];
                assert_eq!(c.output.len(), cols);
                for (a, w) in c.output.iter().zip(want) {
                    assert_eq!(a.to_bits(), w.to_bits(), "request {id} output drifted");
                }
            }
        }
    }

    #[test]
    fn admission_control_and_deadlines_fail_typed() {
        let spec = ServingSpec {
            replicas: 1,
            queue_capacity: 2,
            max_batch: 1,
            request_deadline_us: 5_000,
            service_base_us: 10_000,
            ..ServingSpec::default()
        };
        let mut rt =
            ServingRuntime::new(spec, RepairSpec::none(), vec![8], tiny_factory()).unwrap();
        let work = requests(6, 0); // burst: all six arrive at t=0
        let report = rt.run(&work, &[]).unwrap();

        // One served (the head of the queue), one timed out waiting
        // behind the long-running batch, four rejected at admission.
        assert_eq!(report.completed(), 1);
        assert_eq!(report.failure_breakdown(), (4, 1, 0));
        assert!(matches!(&report.outcomes[0], Outcome::Done(c) if c.replica == 0));
        assert!(matches!(
            &report.outcomes[1],
            Outcome::Failed { error: ServeError::DeadlineExceeded { .. }, .. }
        ));
        for o in &report.outcomes[2..] {
            assert!(matches!(o, Outcome::Failed { error: ServeError::QueueFull { .. }, .. }));
        }
    }

    #[test]
    fn fault_mid_batch_retries_on_the_other_replica() {
        let spec = ServingSpec { replicas: 2, max_batch: 4, ..ServingSpec::default() };
        let mut rt =
            ServingRuntime::new(spec, RepairSpec::none(), vec![8], tiny_factory()).unwrap();
        let work = requests(4, 0);
        let faults = [FaultEvent { at_us: 100, replica: 0 }];
        let report = rt.run(&work, &faults).unwrap();

        assert_eq!(report.completed(), 4);
        for o in &report.outcomes {
            let Outcome::Done(c) = o else { panic!("expected Done") };
            assert_eq!(c.replica, 1, "retry must land on the surviving replica");
            assert_eq!(c.attempts, 2);
        }
        assert_eq!(report.total_retries(), 4);
        assert!(!report.batches[0].ok);
        assert!(report.batches[1].ok);
        assert!(report.events.iter().any(|e| matches!(
            e.kind,
            EventKind::BatchFailed { retried: 4, exhausted: 0, .. }
        )));
    }

    #[test]
    fn retries_are_bounded_and_exhaustion_is_typed() {
        let spec = ServingSpec {
            replicas: 1,
            max_batch: 1,
            max_retries: 1,
            ..ServingSpec::default()
        };
        let mut rt =
            ServingRuntime::new(spec, RepairSpec::none(), vec![8], tiny_factory()).unwrap();
        let work = requests(1, 0);
        // First dispatch at t=0 (service 250µs) dies at t=100; the single
        // retry re-dispatches at t=600 and dies at t=700.
        let faults =
            [FaultEvent { at_us: 100, replica: 0 }, FaultEvent { at_us: 700, replica: 0 }];
        let report = rt.run(&work, &faults).unwrap();

        assert_eq!(report.completed(), 0);
        assert_eq!(report.failure_breakdown(), (0, 0, 1));
        assert!(matches!(
            &report.outcomes[0],
            Outcome::Failed { error: ServeError::RetriesExhausted { attempts: 2 }, .. }
        ));
    }

    /// A 128→64 linear replica on a spare-carrying chip; faulty replicas
    /// get stuck cells at 2%, more than enough to trip the probes.
    fn healable_replica(cond: &ReplicaSpec, engine_seed: u64) -> anyhow::Result<MappedModel> {
        let cfg = if cond.faulty {
            DpeConfig {
                nonideal: NonIdealitySpec {
                    faults: FaultSpec::cells(0.02),
                    ..NonIdealitySpec::none()
                },
                ..DpeConfig::default()
            }
        } else {
            DpeConfig::default()
        };
        let mut rng = Pcg64::new(9, 0xF00D);
        let m = Sequential::new(vec![Box::new(LinearMem::new(
            128,
            64,
            Some(hw(cfg, engine_seed)),
            &mut rng,
        ))]);
        // 2 block groups × 4 slices = 8 data planes, one spare group.
        let chip = ChipSpec::new(1, 12, (64, 64)).with_spares(4);
        m.compile(&chip)
    }

    fn wide_requests(n: usize, gap_us: u64) -> Vec<Request> {
        (0..n)
            .map(|j| Request {
                arrive_us: j as u64 * gap_us,
                sample: (0..128).map(|k| ((j * 7 + k) % 23) as f64 / 11.0 - 1.0).collect(),
            })
            .collect()
    }

    #[test]
    fn health_scan_pulls_heals_and_returns_a_faulty_replica() {
        let spec = ServingSpec {
            replicas: 2,
            max_batch: 2,
            health_period_us: 2_000,
            heal_us: 1_000,
            ..ServingSpec::default()
        };
        let factory: ReplicaFactory<'_> =
            Box::new(|i, cond| healable_replica(cond, 55 + i as u64));
        let mut rt = ServingRuntime::new(spec, RepairSpec::enabled(), vec![128], factory).unwrap();
        let work = wide_requests(10, 400);
        let faults = [FaultEvent { at_us: 500, replica: 0 }];
        let report = rt.run(&work, &faults).unwrap();

        // Nothing lost: every request resolves (faulty-replica answers
        // may be wrong, but they are delivered).
        assert_eq!(report.outcomes.len(), 10);
        assert_eq!(report.completed() + report.failed(), 10);
        // The scan pulled replica 0 and healed it exactly while the pool
        // kept serving on replica 1.
        assert!(!report.heals.is_empty());
        assert_eq!(report.heals[0].replica, 0);
        assert!(rt.heal_count(0) >= 1);
        assert_eq!(rt.heal_count(1), 0);
        assert!(report
            .events
            .iter()
            .any(|e| matches!(e.kind, EventKind::HealthScan { pulled: true, .. })));
        assert!(report
            .events
            .iter()
            .any(|e| matches!(e.kind, EventKind::HealStart { replica: 0 })));
        assert!(report
            .events
            .iter()
            .any(|e| matches!(e.kind, EventKind::HealDone { replica: 0, .. })));
        // The healed replica re-entered rotation and served again.
        let heal_end = report.heals[0].finished_us;
        assert!(report
            .batches
            .iter()
            .any(|b| b.replica == 0 && b.ok && b.dispatched_us >= heal_end));
    }

    #[test]
    fn drift_refresh_ages_pulls_and_resets_the_drift_clock() {
        // Aggressive retention loss: ν = 0.5 against t0 = 1 ms collapses
        // the conductances within a simulated half-second, so the first
        // scan's probes blow through the bound and healing reprograms.
        let drifty = |t_read_s: f64, seed: u64| -> anyhow::Result<MappedModel> {
            let cfg = DpeConfig {
                nonideal: NonIdealitySpec {
                    drift: DriftSpec { nu: 0.5, nu_std: 0.0, t0: 1e-3 },
                    t_read: t_read_s,
                    ..NonIdealitySpec::none()
                },
                ..DpeConfig::default()
            };
            let mut rng = Pcg64::new(9, 0xF00D);
            let m = Sequential::new(vec![Box::new(LinearMem::new(
                128,
                64,
                Some(hw(cfg, seed)),
                &mut rng,
            ))]);
            let chip = ChipSpec::single_tile(m.mapped_planes(), (64, 64));
            m.compile(&chip)
        };
        let spec = ServingSpec {
            replicas: 1,
            max_batch: 1,
            request_deadline_us: 10_000_000,
            health_period_us: 500_000,
            heal_us: 10_000,
            drift_refresh: true,
            ..ServingSpec::default()
        };
        let factory: ReplicaFactory<'_> = Box::new(move |_i, cond| drifty(cond.t_read_s, 31));
        let mut rt = ServingRuntime::new(spec, RepairSpec::enabled(), vec![128], factory).unwrap();
        let work = wide_requests(6, 400_000);
        let report = rt.run(&work, &[]).unwrap();

        assert_eq!(report.completed(), 6);
        assert!(report
            .events
            .iter()
            .any(|e| matches!(e.kind, EventKind::DriftRefresh { t_read_s, .. } if t_read_s > 0.0)));
        assert!(rt.heal_count(0) >= 1, "drifted replica must be pulled and healed");
        // Healing reprogrammed the chip: the drift clock restarted.
        assert_eq!(rt.replica_condition(0).t_read_s, 0.0);
    }

    #[test]
    fn prop_serving_conserves_requests_fifo_batches_bounded_retries() {
        prop_check("serving_invariants", 40, |g| {
            let spec = ServingSpec {
                replicas: g.usize_in(1..=3),
                queue_capacity: g.usize_in(1..=6),
                max_batch: g.usize_in(1..=4),
                batch_deadline_us: 500,
                request_deadline_us: g.usize_in(2_000..=50_000) as u64,
                max_retries: g.usize_in(0..=2),
                retry_backoff_us: 300,
                health_period_us: 0,
                heal_us: 1_000,
                service_base_us: 100,
                service_per_sample_us: 20,
                drift_refresh: false,
            };
            let n = g.usize_in(1..=12);
            let mut work = Vec::with_capacity(n);
            let mut t = 0u64;
            for j in 0..n {
                t += g.usize_in(0..=400) as u64;
                work.push(Request {
                    arrive_us: t,
                    sample: (0..8).map(|k| ((j * 3 + k) % 7) as f64 / 3.0 - 1.0).collect(),
                });
            }
            let mut faults = Vec::new();
            for _ in 0..g.usize_in(0..=2) {
                faults.push(FaultEvent {
                    at_us: g.usize_in(0..=3_000) as u64,
                    replica: g.usize_in(0..=spec.replicas - 1),
                });
            }
            faults.sort_by_key(|f| f.at_us);

            let run_once = |spec: &ServingSpec| -> Result<ServeReport, String> {
                let mut rt = ServingRuntime::new(
                    spec.clone(),
                    RepairSpec::none(),
                    vec![8],
                    tiny_factory(),
                )
                .map_err(|e| e.to_string())?;
                rt.run(&work, &faults).map_err(|e| e.to_string())
            };
            let report = run_once(&spec)?;

            // Exactly one outcome per request (loss/double-answer panics
            // inside run), retries bounded, batches FIFO-ordered.
            if report.outcomes.len() != n {
                return Err(format!("{} outcomes for {n} requests", report.outcomes.len()));
            }
            for (id, o) in report.outcomes.iter().enumerate() {
                if let Outcome::Done(c) = o {
                    if c.attempts > spec.max_retries + 1 {
                        return Err(format!(
                            "request {id} took {} attempts (max_retries {})",
                            c.attempts, spec.max_retries
                        ));
                    }
                    if !report.batches[c.batch].requests.contains(&id) {
                        return Err(format!("request {id} missing from its batch record"));
                    }
                }
            }
            for b in &report.batches {
                if b.requests.len() > spec.max_batch {
                    return Err(format!("batch {} overflows max_batch", b.batch));
                }
                if b.requests.windows(2).any(|w| w[0] >= w[1]) {
                    return Err(format!("batch {} not FIFO-ordered: {:?}", b.batch, b.requests));
                }
            }
            // Same inputs, same report — the runtime is deterministic.
            let twin = run_once(&spec)?;
            if twin != report {
                return Err("two identical runs diverged".into());
            }
            Ok(())
        });
    }

    #[test]
    fn report_percentiles_and_breakdown_edge_cases() {
        // Empty report (no requests at all).
        let empty = ServeReport {
            outcomes: vec![],
            batches: vec![],
            heals: vec![],
            events: vec![],
            makespan_us: 0,
        };
        assert_eq!(empty.percentile_latency_us(0.5), None);
        assert_eq!(empty.percentile_latency_us(1.0), None);
        assert_eq!(empty.failure_breakdown(), (0, 0, 0));
        assert_eq!(empty.completed(), 0);
        assert_eq!(empty.images_per_sec(), 0.0);
        let json = empty.to_json();
        assert!(json.contains("\"p50_us\":null"), "{json}");
        assert!(json.contains("\"completed\":0"), "{json}");

        // A single completed sample is every percentile.
        let one = ServeReport {
            outcomes: vec![Outcome::Done(Completion {
                output: vec![1.0],
                replica: 0,
                attempts: 1,
                latency_us: 123,
                batch: 0,
            })],
            batches: vec![],
            heals: vec![],
            events: vec![],
            makespan_us: 123,
        };
        for q in [0.01, 0.5, 0.99, 1.0] {
            assert_eq!(one.percentile_latency_us(q), Some(123));
        }
        assert!(one.to_json().contains("\"p99_us\":123"));

        // An all-failed run: the breakdown sees every kind once, the
        // percentiles stay None, and the throughput is zero.
        let failed = ServeReport {
            outcomes: vec![
                Outcome::Failed {
                    error: ServeError::QueueFull { queued: 4, capacity: 4 },
                    at_us: 10,
                },
                Outcome::Failed {
                    error: ServeError::DeadlineExceeded { waited_us: 900, deadline_us: 800 },
                    at_us: 20,
                },
                Outcome::Failed {
                    error: ServeError::DeadlineExceeded { waited_us: 950, deadline_us: 800 },
                    at_us: 30,
                },
                Outcome::Failed {
                    error: ServeError::RetriesExhausted { attempts: 3 },
                    at_us: 40,
                },
            ],
            batches: vec![],
            heals: vec![],
            events: vec![],
            makespan_us: 40,
        };
        assert_eq!(failed.failure_breakdown(), (1, 2, 1));
        assert_eq!(failed.completed(), 0);
        assert_eq!(failed.failed(), 4);
        assert_eq!(failed.percentile_latency_us(0.99), None);
        assert_eq!(failed.images_per_sec(), 0.0);
        let json = failed.to_json();
        assert!(json.contains("\"retries_exhausted\":1"), "{json}");
        assert!(json.contains("\"p95_us\":null"), "{json}");
    }

    #[test]
    fn mixed_pool_sharded_replica_is_bit_identical_to_single_chip() {
        use crate::arch::fleet::uniform_fleet;
        use crate::nn::models::mlp;

        let ideal = || {
            HwSpec::uniform(DotProductEngine::ideal((64, 64)), SliceMethod::int(SliceSpec::int8()))
        };
        // Replica 0 is single-chip, replica 1 shards the same template
        // over a 2-chip fleet; noise-free engines make them comparable.
        let factory: MixedFactory = Box::new(move |i, _cond| {
            let m = mlp(96, 32, 8, Some(ideal()), 7);
            if i == 0 {
                let chip = ChipSpec::single_tile(m.mapped_planes(), (64, 64));
                Ok(ReplicaModel::Single(m.compile(&chip)?))
            } else {
                Ok(ReplicaModel::Sharded(m.compile_sharded(&uniform_fleet(2, 8, (64, 64)))?))
            }
        });
        let spec = ServingSpec {
            replicas: 2,
            max_batch: 4,
            shards_per_replica: 2,
            ..ServingSpec::default()
        };
        let mut rt =
            ServingRuntime::new_mixed(spec, RepairSpec::none(), vec![96], factory).unwrap();
        assert_eq!(rt.replica(0).chip_count(), 1);
        assert_eq!(rt.replica(1).chip_count(), 2);
        assert_eq!(rt.replica(1).as_sharded().unwrap().stage_count(), 2);
        assert!(rt.replica(0).as_single().is_some());

        let work: Vec<Request> = (0..10)
            .map(|j| Request {
                arrive_us: j as u64 * 100,
                sample: (0..96).map(|k| (((j * 7 + k) % 23) as f64) / 11.5 - 1.0).collect(),
            })
            .collect();
        let report = rt.run(&work, &[]).unwrap();
        assert_eq!(report.completed(), 10);

        // Both members hold the same noise-free template, so replaying
        // each dispatched batch on a fresh single-chip twin reproduces
        // the delivered rows bit for bit, whichever replica served.
        let t = mlp(96, 32, 8, Some(ideal()), 7);
        let chip = ChipSpec::single_tile(t.mapped_planes(), (64, 64));
        let twin = t.compile(&chip).unwrap();
        for b in &report.batches {
            let rows = b.requests.len();
            let mut data = Vec::with_capacity(rows * 96);
            for &id in &b.requests {
                data.extend_from_slice(&work[id].sample);
            }
            let y = twin.infer_batched(&Tensor::from_vec(&[rows, 96], data), rows);
            let cols = y.data.len() / rows;
            for (row, &id) in b.requests.iter().enumerate() {
                let Outcome::Done(c) = &report.outcomes[id] else {
                    panic!("request {id} failed in a clean run");
                };
                assert_eq!(c.output, y.data[row * cols..(row + 1) * cols].to_vec());
            }
        }
    }
}

//! The compiled inference runtime: a [`crate::nn::Sequential`] whose
//! hardware cores have been placed on a [`super::ChipSpec`] and programmed
//! once, exposed as a forward-only executor.
//!
//! Produced by [`crate::nn::Sequential::compile`]. Two entry points:
//!
//! - [`MappedModel::infer`] — evaluate one batch through the layer
//!   pipeline (full-batch DPE calls, engine-internal parallelism);
//! - [`MappedModel::infer_batched`] — split the batch into micro-batches
//!   and run them through each layer with `par_map` (inference-traffic
//!   shape: many independent requests). Each DPE layer slices its input
//!   **once for the full batch** ([`crate::dpe::PreparedInputs`], row
//!   slices shared across micro-batches), so quantization scales are
//!   batch-global and the result is bit-identical to [`MappedModel::infer`]
//!   for every micro-batch size and thread count under the default
//!   fixed-range (worst-case) ADC with `read_var = 0`. (A calibrated ADC
//!   ranges on the readout peak of whatever rows it sees, so there — as in
//!   the unmapped path — batch composition is part of the model.)
//!
//! Neither entry point touches training state: no activation caches, no
//! gradients, no `update_weight`.
//!
//! Single-sample `infer` calls (the request-at-a-time serving shape) no
//! longer serialize on one GEMM row band: the DPE parallelizes over
//! (k-block, n-block) array pairs by *total* grid work, and a lone big
//! pair 2-D-schedules its stacked GEMM over (row-band × panel-group)
//! items — so an m = 1 forward through a wide layer still fills the
//! worker pool (see `dpe::engine` §Perf and `examples/README.md`).

use super::Placement;
use crate::nn::Sequential;
use crate::tensor::Tensor;

/// A network compiled onto a chip: placement + programmed arrays + the
/// forward-only executor. See the module docs.
pub struct MappedModel {
    model: Sequential,
    placement: Placement,
}

impl MappedModel {
    pub(crate) fn new(model: Sequential, placement: Placement) -> Self {
        MappedModel { model, placement }
    }

    /// Evaluate one batch (forward-only, full batch per DPE call).
    pub fn infer(&self, x: &Tensor) -> Tensor {
        let mut h = x.clone();
        for l in &self.model.layers {
            h = l.forward_eval(&h);
        }
        h
    }

    /// Evaluate a batch in micro-batches of `micro_batch` samples (see the
    /// module docs for the determinism contract).
    pub fn infer_batched(&self, x: &Tensor, micro_batch: usize) -> Tensor {
        let mb = micro_batch.max(1);
        let mut h = x.clone();
        for l in &self.model.layers {
            h = l.forward_batched(&h, mb);
        }
        h
    }

    /// The chip placement this model was compiled with.
    pub fn placement(&self) -> &Placement {
        &self.placement
    }

    /// Per-layer summary including the arrays/tiles columns (delegates to
    /// [`Sequential::summary`], which reads each core's placement).
    pub fn summary(&self, in_shape: Vec<usize>) -> String {
        self.model.summary(in_shape)
    }

    /// Borrow the underlying (programmed) model.
    pub fn model(&self) -> &Sequential {
        &self.model
    }

    /// Unwrap back into the [`Sequential`] (arrays stay programmed with
    /// their mapped streams until the next slot assignment).
    pub fn into_model(self) -> Sequential {
        self.model
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ChipSpec;
    use crate::dpe::{DotProductEngine, DpeConfig, SliceMethod, SliceSpec};
    use crate::nn::layers::{Conv2dMem, Flatten, LinearMem, Relu};
    use crate::nn::{HwSpec, Layer};
    use crate::util::rng::Pcg64;

    fn hw(seed: u64) -> HwSpec {
        HwSpec::uniform(
            DotProductEngine::new(DpeConfig::default(), seed),
            SliceMethod::int(SliceSpec::int8()),
        )
    }

    /// A small conv+fc model exercising both DPE layer kinds.
    fn small_model(seed: u64) -> Sequential {
        let mut rng = Pcg64::new(seed, 0xA11C);
        Sequential::new(vec![
            Box::new(Conv2dMem::new(2, 6, 6, 3, 3, 1, 1, Some(hw(seed)), &mut rng)),
            Box::new(Relu::new()),
            Box::new(Flatten::new()),
            Box::new(LinearMem::new(3 * 6 * 6, 10, Some(hw(seed)), &mut rng)),
        ])
    }

    fn batch(n: usize) -> Tensor {
        Tensor::from_vec(
            &[n, 2, 6, 6],
            (0..n * 72).map(|i| ((i * 13 % 19) as f64) / 9.0 - 1.0).collect(),
        )
    }

    #[test]
    fn single_tile_mapping_bit_identical_to_unmapped_sequential() {
        // The bit-identity anchor: one tile large enough for the whole
        // model, layer-order assignment, reproduces the unmapped hardware
        // path exactly — noise and all.
        let mut unmapped = small_model(5);
        let model = small_model(5);
        let planes = model.mapped_planes();
        assert!(planes > 0);
        let chip = ChipSpec::single_tile(planes, (64, 64));
        let mapped = model.compile(&chip).expect("single-tile compile");
        assert_eq!(mapped.placement().total_planes(), planes);
        let x = batch(3);
        let y_seq = unmapped.forward(&x, false);
        let y_map = mapped.infer(&x);
        assert_eq!(y_seq.data, y_map.data, "anchor: mapped != unmapped");
    }

    #[test]
    fn micro_batch_size_does_not_change_results() {
        let mapped = {
            let m = small_model(7);
            let chip = ChipSpec::single_tile(m.mapped_planes(), (64, 64));
            m.compile(&chip).unwrap()
        };
        let x = batch(7);
        let full = mapped.infer(&x);
        for mb in [1usize, 2, 3, 7, 64] {
            assert_eq!(mapped.infer_batched(&x, mb).data, full.data, "micro_batch={mb}");
        }
    }

    #[test]
    fn spill_to_second_tile_resamples_noise() {
        // The same model on a chip whose tiles force a spill lands some
        // blocks on different global slots → different programming noise.
        let anchor = {
            let m = small_model(9);
            let chip = ChipSpec::single_tile(m.mapped_planes(), (64, 64));
            m.compile(&chip).unwrap()
        };
        let spilled = {
            let m = small_model(9);
            // Tiles of 10 arrays: int8 groups are 4 planes, so every tile
            // wastes 2 slots and later layers shift to higher slot ids.
            let chip = ChipSpec::new(16, 10, (64, 64));
            m.compile(&chip).unwrap()
        };
        assert!(spilled.placement().tiles_used() > 1);
        let x = batch(2);
        assert_ne!(
            anchor.infer(&x).data,
            spilled.infer(&x).data,
            "remapped slots must resample programming noise"
        );
    }

    #[test]
    fn two_layers_on_one_tile_draw_independent_streams() {
        // Two identical LinearMem layers (same weights, same engine seed):
        // before the chip refactor both drew the layer-local streams and
        // produced identical outputs on the same input; placed on one chip
        // they occupy different slots and must differ.
        let mut rng = Pcg64::new(3, 3);
        let l0 = LinearMem::new(16, 16, Some(hw(21)), &mut rng);
        let mut l1 = LinearMem::new(16, 16, Some(hw(21)), &mut rng);
        l1.w.value.copy_from_slice(&l0.w.value);
        l1.b.value.copy_from_slice(&l0.b.value);
        let model = Sequential::new(vec![Box::new(l0), Box::new(l1)]);
        let x = Tensor::from_vec(&[2, 16], (0..32).map(|i| ((i % 7) as f64) / 3.5 - 1.0).collect());
        {
            // Standalone twins (slot base 0 each) still agree…
            let mut s0 = LinearMem::new(16, 16, Some(hw(21)), &mut rng);
            let mut s1 = LinearMem::new(16, 16, Some(hw(21)), &mut rng);
            s1.w.value.copy_from_slice(&s0.w.value);
            s1.b.value.copy_from_slice(&s0.b.value);
            s0.update_weight();
            s1.update_weight();
            assert_eq!(s0.forward(&x, false).data, s1.forward(&x, false).data);
        }
        // …but inside one model (one virtual tile) the streams are per
        // physical array: same input through either layer differs.
        let y0 = model.layers[0].forward_eval(&x);
        let y1 = model.layers[1].forward_eval(&x);
        assert_ne!(y0.data, y1.data, "co-located layers must not share noise streams");
    }

    #[test]
    fn capacity_error_propagates_from_compile() {
        let m = small_model(11);
        let planes = m.mapped_planes();
        let chip = ChipSpec::new(1, planes - 1, (64, 64));
        let err = m.compile(&chip).unwrap_err().to_string();
        assert!(err.contains("chip capacity exceeded"), "{err}");
    }

    #[test]
    fn array_shape_mismatch_is_an_error() {
        let m = small_model(13);
        let chip = ChipSpec::single_tile(1024, (32, 32));
        let err = m.compile(&chip).unwrap_err().to_string();
        assert!(err.contains("array"), "{err}");
    }
}

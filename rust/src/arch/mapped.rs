//! The compiled inference runtime: a [`crate::nn::Sequential`] whose
//! hardware cores have been placed on a [`super::ChipSpec`] and programmed
//! once, exposed as a forward-only executor.
//!
//! Produced by [`crate::nn::Sequential::compile`]. Two entry points:
//!
//! - [`MappedModel::infer`] — evaluate one batch through the layer
//!   pipeline (full-batch DPE calls, engine-internal parallelism);
//! - [`MappedModel::infer_batched`] — split the batch into micro-batches
//!   and run them through each layer with `par_map` (inference-traffic
//!   shape: many independent requests). Each DPE layer slices its input
//!   **once for the full batch** ([`crate::dpe::PreparedInputs`], row
//!   slices shared across micro-batches), so quantization scales are
//!   batch-global and the result is bit-identical to [`MappedModel::infer`]
//!   for every micro-batch size and thread count under the default
//!   fixed-range (worst-case) ADC with `read_var = 0`. (A calibrated ADC
//!   ranges on the readout peak of whatever rows it sees, so there — as in
//!   the unmapped path — batch composition is part of the model.)
//!
//! Neither entry point touches training state: no activation caches, no
//! gradients, no `update_weight`.
//!
//! Single-sample `infer` calls (the request-at-a-time serving shape) no
//! longer serialize on one GEMM row band: the DPE parallelizes over
//! (k-block, n-block) array pairs by *total* grid work, and a lone big
//! pair 2-D-schedules its stacked GEMM over (row-band × panel-group)
//! items — so an m = 1 forward through a wide layer still fills the
//! worker pool (see `dpe::engine` §Perf and `examples/README.md`).
//! On noise-free hardware the same forwards additionally ride the exact
//! integer-domain kernel (byte weight panels, `i32`/`i64` accumulators) —
//! bit-identical to the f64 path, so mapping, micro-batching, and the
//! kernel choice are all invisible in the output.
//!
//! Models too big for one chip shard across several: see
//! [`super::fleet::ShardedModel`], which chains per-chip `MappedModel`
//! stages behind simulated inter-chip links, keeps this module's
//! batch-global quantization contract (full-batch stage chaining in
//! `infer_batched`), and reuses [`MappedModel::condemn`] /
//! [`MappedModel::self_heal`] per stage for its chip-level fault
//! handling.

use super::repair::{DegradedReport, HealthReport, RepairOutcome, RepairPlan, SlotHealth};
use super::{BlockMove, Placement};
use crate::dpe::RepairSpec;
use crate::nn::Sequential;
use crate::tensor::Tensor;

/// A network compiled onto a chip: placement + programmed arrays + the
/// forward-only executor. See the module docs.
pub struct MappedModel {
    model: Sequential,
    placement: Placement,
    /// Set by [`MappedModel::self_heal`] when condemned block groups could
    /// not be remapped (spares exhausted) — the chip keeps serving.
    degraded: Option<DegradedReport>,
}

impl MappedModel {
    pub(crate) fn new(model: Sequential, placement: Placement) -> Self {
        MappedModel { model, placement, degraded: None }
    }

    /// Evaluate one batch (forward-only, full batch per DPE call).
    pub fn infer(&self, x: &Tensor) -> Tensor {
        let mut h = x.clone();
        for l in &self.model.layers {
            h = l.forward_eval(&h);
        }
        h
    }

    /// Evaluate a batch in micro-batches of `micro_batch` samples (see the
    /// module docs for the determinism contract).
    pub fn infer_batched(&self, x: &Tensor, micro_batch: usize) -> Tensor {
        let mb = micro_batch.max(1);
        let mut h = x.clone();
        for l in &self.model.layers {
            h = l.forward_batched(&h, mb);
        }
        h
    }

    /// The chip placement this model was compiled with.
    pub fn placement(&self) -> &Placement {
        &self.placement
    }

    /// Condemned-block count per placed core, aligned with
    /// [`Self::placement`]'s `layers` order — the per-layer degraded-mode
    /// figure the chip reports surface.
    pub fn condemned_per_layer(&self) -> Vec<usize> {
        let mut counts = Vec::new();
        for l in &self.model.layers {
            for core in l.cores() {
                if core.placement().is_some() {
                    counts.push(core.condemned_blocks().len());
                }
            }
        }
        counts
    }

    /// The graceful-degradation record of the last [`MappedModel::self_heal`]
    /// round, if any condemned groups could not be repaired.
    pub fn degraded(&self) -> Option<&DegradedReport> {
        self.degraded.as_ref()
    }

    /// Probe-only health pass: run the ABFT checksum probes
    /// ([`crate::nn::MemCore::probe_block_scores`]) over every placed
    /// block group **without mutating any programmed state** — the
    /// background scan the serving runtime
    /// ([`super::serve::ServingRuntime`]) uses to decide whether a replica
    /// needs to leave rotation for a [`MappedModel::self_heal`] round.
    /// Deterministic for a fixed engine seed and spec.
    pub fn health_probe(&self, spec: &RepairSpec) -> anyhow::Result<HealthReport> {
        let mut health = HealthReport::default();
        let mut missing: Option<usize> = None;
        let mut ci = 0usize;
        for l in &self.model.layers {
            for core in l.cores() {
                if core.placement().is_none() {
                    continue;
                }
                let lp = core.placement().unwrap();
                let (slices, slots) = (lp.slices, lp.slots.clone());
                match core.probe_block_scores(spec) {
                    Some((scores, calls)) => {
                        health.probe_matmuls += calls;
                        for (b, &score) in scores.iter().enumerate() {
                            health.slots.push(SlotHealth {
                                slot: slots[b * slices],
                                layer: ci,
                                block: b,
                                score,
                                healthy: score <= spec.probe_re_bound,
                            });
                        }
                    }
                    None => missing = missing.or(Some(ci)),
                }
                ci += 1;
            }
        }
        if let Some(ci) = missing {
            anyhow::bail!("health probe: placed core {ci} has no programmed state");
        }
        Ok(health)
    }

    /// Fence off `(layer, block)` groups in place: each group's
    /// recombination scale is zeroed
    /// ([`crate::nn::MemCore::condemn_blocks`]), so it contributes
    /// **exactly zero** to every forward — a bounded missing-contribution
    /// error instead of whatever stale digits sit on its arrays. Layer
    /// indices count placed cores in compile order (the same indexing as
    /// [`HealthReport`] / [`RepairPlan`]). Purely mechanical: the
    /// degraded report is managed by [`MappedModel::self_heal`].
    pub fn condemn(&mut self, groups: &[(usize, usize)]) {
        let mut ci = 0usize;
        for l in &mut self.model.layers {
            l.visit_cores(&mut |core| {
                if core.placement().is_none() {
                    return;
                }
                let mine: Vec<usize> =
                    groups.iter().filter(|g| g.0 == ci).map(|g| g.1).collect();
                if !mine.is_empty() {
                    core.condemn_blocks(&mine);
                }
                ci += 1;
            });
        }
    }

    /// One closed-loop repair round over the whole chip (see
    /// [`super::repair`]):
    ///
    /// 1. **program-and-verify** every placed core at its current streams
    ///    (when `spec.verify` is on), collecting per-block retry counts —
    ///    block groups with unconverged planes are condemned;
    /// 2. **probe** every placed block group with checksum vectors through
    ///    the real GEMM path and condemn groups whose relative error
    ///    exceeds `spec.probe_re_bound`;
    /// 3. **remap** condemned groups onto spare arrays
    ///    ([`RepairPlan::plan`]) and reprogram only the moved blocks at
    ///    their new physical streams; groups that found no spare are
    ///    recorded in a [`DegradedReport`] — inference keeps serving.
    ///
    /// Deterministic for a fixed engine seed and spec. Errors only on
    /// internal inconsistency (a placed core without programmed state).
    pub fn self_heal(&mut self, spec: &RepairSpec) -> anyhow::Result<RepairOutcome> {
        let mut outcome = RepairOutcome::default();

        // Stage 1: program-and-verify. Unconverged block groups are
        // condemned even if their probe later sneaks under the bound.
        let mut condemned: Vec<(usize, usize)> = Vec::new();
        if spec.verify {
            let mut ci = 0usize;
            for l in &mut self.model.layers {
                l.visit_cores(&mut |core| {
                    if core.placement().is_none() {
                        return;
                    }
                    if let Some(rep) = core.program_verified(spec) {
                        condemned.extend(rep.unconverged_blocks().into_iter().map(|b| (ci, b)));
                        outcome.program_reports.push(rep);
                    }
                    ci += 1;
                });
            }
        }

        // Stage 2: online health probes, scored per placed block group.
        let health = self.health_probe(spec)?;

        // Stage 3: condemn (verify ∪ probe), plan, remap, degrade.
        condemned.extend(health.condemned());
        condemned.sort_unstable();
        condemned.dedup();
        let plan = RepairPlan::plan(&self.placement, &condemned);
        let mut ci = 0usize;
        for l in &mut self.model.layers {
            l.visit_cores(&mut |core| {
                if core.placement().is_none() {
                    return;
                }
                let mine: Vec<&BlockMove> =
                    plan.moves.iter().filter(|m| m.layer == ci).collect();
                core.remap_blocks(&mine);
                ci += 1;
            });
        }
        for m in &plan.moves {
            let lp = &mut self.placement.layers[m.layer];
            lp.block_streams[m.block] = m.new_stream;
            lp.slots[m.block * lp.slices..(m.block + 1) * lp.slices].copy_from_slice(&m.to);
            lp.tile_first = lp.tile_first.min(m.to[0].tile);
            lp.tile_last = lp.tile_last.max(m.to[0].tile);
        }
        // Stage 4: fence off what repair could not fix. Groups the plan
        // left unplaced are zeroed in place (exact-zero contribution beats
        // unbounded stuck-at garbage), and moved groups are re-probed at
        // their new slots — a spare that is itself faulty gets condemned
        // and zeroed too, extending the degraded report. The re-probe is
        // deterministic, so groups untouched by the plan keep their
        // stage-2 verdicts.
        let mut fenced: Vec<((usize, usize), f64)> =
            plan.unplaced.iter().map(|&g| (g, health.score_of(g.0, g.1).unwrap_or(0.0))).collect();
        if !plan.moves.is_empty() {
            let recheck = self.health_probe(spec)?;
            outcome.health.probe_matmuls = recheck.probe_matmuls;
            for m in &plan.moves {
                if let Some(score) = recheck.score_of(m.layer, m.block) {
                    if score > spec.probe_re_bound {
                        fenced.push(((m.layer, m.block), score));
                    }
                }
            }
            fenced.sort_by(|a, b| a.0.cmp(&b.0));
            fenced.dedup_by_key(|e| e.0);
        }
        self.degraded = if fenced.is_empty() {
            None
        } else {
            let groups: Vec<(usize, usize)> = fenced.iter().map(|e| e.0).collect();
            self.condemn(&groups);
            let mut deg = DegradedReport::default();
            for &((layer, block), score) in &fenced {
                let lp = &self.placement.layers[layer];
                deg.condemned.push((layer, block));
                deg.slots.push(lp.slots[block * lp.slices]);
                deg.estimated_re_impact = deg.estimated_re_impact.max(score);
            }
            Some(deg)
        };
        outcome.health.slots = health.slots;
        outcome.health.probe_matmuls += health.probe_matmuls;
        outcome.plan = plan;
        outcome.degraded = self.degraded.clone();
        Ok(outcome)
    }

    /// Per-layer summary including the arrays/tiles columns (delegates to
    /// [`Sequential::summary`], which reads each core's placement).
    pub fn summary(&self, in_shape: Vec<usize>) -> String {
        self.model.summary(in_shape)
    }

    /// Hardware-in-the-loop training on the compiled model (Fig 16 fast
    /// path): runs [`crate::nn::train::train_fast`] over the inner
    /// [`Sequential`]. The mapped per-slot streams stay in place — delta
    /// reprogramming redraws dirty cells at each core's existing physical
    /// slot streams — so training a mapped model is bit-reproducible under
    /// any thread count and the placement remains valid afterwards.
    pub fn train_fast(
        &mut self,
        data: &crate::data::Dataset,
        cfg: &crate::nn::train::TrainConfig,
    ) -> crate::nn::train::FastTrainReport {
        crate::nn::train::train_fast(&mut self.model, data, cfg)
    }

    /// Borrow the underlying (programmed) model.
    pub fn model(&self) -> &Sequential {
        &self.model
    }

    /// Mutably borrow the underlying model (custom training loops).
    pub fn model_mut(&mut self) -> &mut Sequential {
        &mut self.model
    }

    /// Unwrap back into the [`Sequential`] (arrays stay programmed with
    /// their mapped streams until the next slot assignment).
    pub fn into_model(self) -> Sequential {
        self.model
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ChipSpec;
    use crate::dpe::{DotProductEngine, DpeConfig, SliceMethod, SliceSpec};
    use crate::nn::layers::{Conv2dMem, Flatten, LinearMem, Relu};
    use crate::nn::{HwSpec, Layer};
    use crate::util::rng::Pcg64;

    fn hw(seed: u64) -> HwSpec {
        HwSpec::uniform(
            DotProductEngine::new(DpeConfig::default(), seed),
            SliceMethod::int(SliceSpec::int8()),
        )
    }

    /// A small conv+fc model exercising both DPE layer kinds.
    fn small_model(seed: u64) -> Sequential {
        let mut rng = Pcg64::new(seed, 0xA11C);
        Sequential::new(vec![
            Box::new(Conv2dMem::new(2, 6, 6, 3, 3, 1, 1, Some(hw(seed)), &mut rng)),
            Box::new(Relu::new()),
            Box::new(Flatten::new()),
            Box::new(LinearMem::new(3 * 6 * 6, 10, Some(hw(seed)), &mut rng)),
        ])
    }

    fn batch(n: usize) -> Tensor {
        Tensor::from_vec(
            &[n, 2, 6, 6],
            (0..n * 72).map(|i| ((i * 13 % 19) as f64) / 9.0 - 1.0).collect(),
        )
    }

    #[test]
    fn single_tile_mapping_bit_identical_to_unmapped_sequential() {
        // The bit-identity anchor: one tile large enough for the whole
        // model, layer-order assignment, reproduces the unmapped hardware
        // path exactly — noise and all.
        let mut unmapped = small_model(5);
        let model = small_model(5);
        let planes = model.mapped_planes();
        assert!(planes > 0);
        let chip = ChipSpec::single_tile(planes, (64, 64));
        let mapped = model.compile(&chip).expect("single-tile compile");
        assert_eq!(mapped.placement().total_planes(), planes);
        let x = batch(3);
        let y_seq = unmapped.forward(&x, false);
        let y_map = mapped.infer(&x);
        assert_eq!(y_seq.data, y_map.data, "anchor: mapped != unmapped");
    }

    #[test]
    fn micro_batch_size_does_not_change_results() {
        let mapped = {
            let m = small_model(7);
            let chip = ChipSpec::single_tile(m.mapped_planes(), (64, 64));
            m.compile(&chip).unwrap()
        };
        let x = batch(7);
        let full = mapped.infer(&x);
        for mb in [1usize, 2, 3, 7, 64] {
            assert_eq!(mapped.infer_batched(&x, mb).data, full.data, "micro_batch={mb}");
        }
    }

    #[test]
    fn mapped_training_keeps_slot_streams_and_stays_servable() {
        // Train a compiled model in place: the fast loop must run on the
        // mapped streams (delta path engaged, placement untouched) and the
        // model must keep serving afterwards.
        use crate::data::Dataset;
        use crate::nn::train::TrainConfig;
        let model = small_model(19);
        let planes = model.mapped_planes();
        let chip = ChipSpec::single_tile(planes, (64, 64));
        let mut mapped = model.compile(&chip).unwrap();
        let n = 24;
        let data = Dataset {
            sample_shape: vec![2, 6, 6],
            features: (0..n * 72).map(|i| ((i * 7 % 23) as f64) / 11.5 - 1.0).collect(),
            labels: (0..n).map(|i| i % 10).collect(),
            num_classes: 10,
        };
        let cfg = TrainConfig { steps: 3, batch_size: 8, lr: 0.02, log_every: 1, ..Default::default() };
        let rep = mapped.train_fast(&data, &cfg);
        assert_eq!(rep.logs.len(), 3);
        assert!(rep.delta.blocks > 0, "delta reprogramming ran on the mapped cores");
        assert_eq!(mapped.placement().total_planes(), planes, "placement survives training");
        let y = mapped.infer(&batch(2));
        assert_eq!(y.shape, vec![2, 10]);
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn spill_to_second_tile_resamples_noise() {
        // The same model on a chip whose tiles force a spill lands some
        // blocks on different global slots → different programming noise.
        let anchor = {
            let m = small_model(9);
            let chip = ChipSpec::single_tile(m.mapped_planes(), (64, 64));
            m.compile(&chip).unwrap()
        };
        let spilled = {
            let m = small_model(9);
            // Tiles of 10 arrays: int8 groups are 4 planes, so every tile
            // wastes 2 slots and later layers shift to higher slot ids.
            let chip = ChipSpec::new(16, 10, (64, 64));
            m.compile(&chip).unwrap()
        };
        assert!(spilled.placement().tiles_used() > 1);
        let x = batch(2);
        assert_ne!(
            anchor.infer(&x).data,
            spilled.infer(&x).data,
            "remapped slots must resample programming noise"
        );
    }

    #[test]
    fn two_layers_on_one_tile_draw_independent_streams() {
        // Two identical LinearMem layers (same weights, same engine seed):
        // before the chip refactor both drew the layer-local streams and
        // produced identical outputs on the same input; placed on one chip
        // they occupy different slots and must differ.
        let mut rng = Pcg64::new(3, 3);
        let l0 = LinearMem::new(16, 16, Some(hw(21)), &mut rng);
        let mut l1 = LinearMem::new(16, 16, Some(hw(21)), &mut rng);
        l1.w.value.copy_from_slice(&l0.w.value);
        l1.b.value.copy_from_slice(&l0.b.value);
        let model = Sequential::new(vec![Box::new(l0), Box::new(l1)]);
        let x = Tensor::from_vec(&[2, 16], (0..32).map(|i| ((i % 7) as f64) / 3.5 - 1.0).collect());
        {
            // Standalone twins (slot base 0 each) still agree…
            let mut s0 = LinearMem::new(16, 16, Some(hw(21)), &mut rng);
            let mut s1 = LinearMem::new(16, 16, Some(hw(21)), &mut rng);
            s1.w.value.copy_from_slice(&s0.w.value);
            s1.b.value.copy_from_slice(&s0.b.value);
            s0.update_weight();
            s1.update_weight();
            assert_eq!(s0.forward(&x, false).data, s1.forward(&x, false).data);
        }
        // …but inside one model (one virtual tile) the streams are per
        // physical array: same input through either layer differs.
        let y0 = model.layers[0].forward_eval(&x);
        let y1 = model.layers[1].forward_eval(&x);
        assert_ne!(y0.data, y1.data, "co-located layers must not share noise streams");
    }

    /// Engine with stuck cells on every slot's fault stream (both
    /// polarities) — SA1 pins digits to the device max, so verify-mode
    /// programming reliably condemns every hit block group.
    fn faulty_hw(seed: u64, rate: f64) -> HwSpec {
        use crate::device::faults::{FaultSpec, NonIdealitySpec};
        HwSpec::uniform(
            DotProductEngine::new(
                DpeConfig {
                    nonideal: NonIdealitySpec {
                        faults: FaultSpec::cells(rate),
                        ..NonIdealitySpec::none()
                    },
                    ..DpeConfig::default()
                },
                seed,
            ),
            SliceMethod::int(SliceSpec::int8()),
        )
    }

    /// One LinearMem(128, 64): a 2-block × 4-slice grid (8 digit planes).
    fn linear_model(hw: HwSpec, seed: u64) -> Sequential {
        let mut rng = Pcg64::new(seed, 0xF00D);
        Sequential::new(vec![Box::new(LinearMem::new(128, 64, Some(hw), &mut rng))])
    }

    fn lin_batch(n: usize) -> Tensor {
        Tensor::from_vec(
            &[n, 128],
            (0..n * 128).map(|i| ((i * 7 % 23) as f64) / 11.0 - 1.0).collect(),
        )
    }

    #[test]
    fn self_heal_on_healthy_chip_is_a_no_op() {
        let m = small_model(17);
        let chip = ChipSpec::single_tile(m.mapped_planes(), (64, 64));
        let mut mapped = m.compile(&chip).unwrap();
        let x = batch(2);
        let before = mapped.infer(&x);
        let out = mapped.self_heal(&crate::dpe::RepairSpec::enabled()).unwrap();
        assert!(out.plan.moves.is_empty(), "healthy chip must not move blocks");
        assert!(out.plan.unplaced.is_empty());
        assert!(out.degraded.is_none());
        assert!(mapped.degraded().is_none());
        assert_eq!(out.total_retries(), 0, "clean default engine must converge first try");
        assert!(out.health.probe_matmuls > 0, "probes must have run");
        assert!(!out.health.slots.is_empty());
        for s in &out.health.slots {
            assert!(s.healthy, "healthy group flagged: {s:?}");
        }
        assert_eq!(
            mapped.infer(&x).data,
            before.data,
            "a no-op heal must leave the programmed bits untouched"
        );
    }

    #[test]
    fn self_heal_remaps_condemned_groups_onto_spares() {
        // 1 tile x (8 data + 8 spare): both 4-plane groups fit the data
        // region exactly, with two whole spare groups in reserve. A 5%
        // stuck-cell rate guarantees unconverged planes in every group, so
        // verification condemns both; the probe bound is +inf to pin the
        // condemnation path under test.
        let spec = crate::dpe::RepairSpec {
            probe_re_bound: f64::INFINITY,
            ..crate::dpe::RepairSpec::enabled()
        };
        let chip = ChipSpec::new(1, 16, (64, 64)).with_spares(8);
        let mut mapped = linear_model(faulty_hw(41, 0.05), 41).compile(&chip).unwrap();
        let x = lin_batch(3);
        let before = mapped.infer(&x);
        let out = mapped.self_heal(&spec).unwrap();
        assert!(out.total_retries() > 0, "stuck cells must trigger verify retries");
        assert_eq!(out.plan.moves.len(), 2, "both condemned groups must move");
        assert!(out.plan.unplaced.is_empty());
        assert!(out.degraded.is_none());
        let lp = &mapped.placement().layers[0];
        assert_eq!(lp.block_streams, vec![8, 12], "groups must land on the spare tail");
        assert!(lp.slots.iter().all(|s| s.index >= 8), "all planes must sit on spares now");
        assert_ne!(
            mapped.infer(&x).data,
            before.data,
            "remapped blocks draw from new physical streams"
        );
        // The whole loop is deterministic: an identically-built chip heals
        // to bit-identical state.
        let mut twin = linear_model(faulty_hw(41, 0.05), 41).compile(&chip).unwrap();
        let out2 = twin.self_heal(&spec).unwrap();
        assert_eq!(out2.plan, out.plan);
        assert_eq!(twin.infer(&x).data, mapped.infer(&x).data);
    }

    #[test]
    fn exhausted_spares_keep_serving_with_degraded_report() {
        // Same model, but only one spare group: the second condemned group
        // has nowhere to go — inference must keep working and the model
        // must carry a DegradedReport instead of erroring.
        let spec = crate::dpe::RepairSpec {
            probe_re_bound: f64::INFINITY,
            ..crate::dpe::RepairSpec::enabled()
        };
        let chip = ChipSpec::new(1, 12, (64, 64)).with_spares(4);
        let mut mapped = linear_model(faulty_hw(43, 0.05), 43).compile(&chip).unwrap();
        let out = mapped.self_heal(&spec).unwrap();
        assert_eq!(out.plan.moves.len(), 1);
        assert_eq!(out.plan.unplaced.len(), 1);
        let deg = mapped.degraded().expect("spare exhaustion must degrade, not error");
        assert_eq!(deg.condemned, out.plan.unplaced);
        assert_eq!(out.degraded.as_ref(), Some(deg));
        let y = mapped.infer(&lin_batch(2));
        assert_eq!(y.shape, vec![2, 64], "degraded chip must keep serving");
    }

    #[test]
    fn condemned_group_contributes_exactly_zero() {
        // Degraded-mode semantics: a condemned group must contribute
        // exactly zero — not the stale digits on its arrays. Oracle: a twin
        // whose second k-block weights are zeroed *pre-quantization*. That
        // block quantizes to scale 0, the same skip path condemnation
        // takes, and block 0 programs identically (same seed, same slots),
        // so the two chips must agree bit for bit.
        let chip = ChipSpec::single_tile(8, (64, 64));
        let lin_with = |zero_tail: bool| {
            let mut rng = Pcg64::new(9, 0xBEEF);
            let mut l = LinearMem::new(128, 64, Some(hw(77)), &mut rng);
            if zero_tail {
                // w is row-major in_features × out_features; rows 64..128
                // are the second k-block group.
                for v in &mut l.w.value[64 * 64..] {
                    *v = 0.0;
                }
            }
            Sequential::new(vec![Box::new(l)]).compile(&chip).unwrap()
        };
        let mut fenced = lin_with(false);
        fenced.condemn(&[(0, 1)]);
        assert_eq!(fenced.condemned_per_layer(), vec![1]);
        let zeroed = lin_with(true);
        let x = lin_batch(3);
        let ya = fenced.infer(&x);
        let yb = zeroed.infer(&x);
        assert_eq!(ya.data.len(), yb.data.len());
        for (a, b) in ya.data.iter().zip(&yb.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "condemned group leaked stale digits");
        }
        // And the fence actually removed a live contribution.
        let full = lin_with(false);
        assert_ne!(full.infer(&x).data, ya.data, "block 1 must have contributed before");
    }

    #[test]
    fn summary_reports_condemned_group_counts() {
        let chip = ChipSpec::single_tile(8, (64, 64));
        let mut mapped = linear_model(hw(23), 23).compile(&chip).unwrap();
        assert!(
            !mapped.summary(vec![1, 128]).contains("condemned="),
            "healthy chip must not report condemned groups"
        );
        mapped.condemn(&[(0, 1)]);
        let s = mapped.summary(vec![1, 128]);
        assert!(s.contains("condemned=1"), "summary must surface the fenced group:\n{s}");
    }

    #[test]
    fn degraded_serving_is_deterministic() {
        // Two identically-built chips that exhaust their spares must fence
        // the same groups and keep serving bit-identical outputs — the
        // serving runtime relies on this to keep a degraded replica in
        // rotation without breaking pool determinism.
        let spec = crate::dpe::RepairSpec {
            probe_re_bound: f64::INFINITY,
            ..crate::dpe::RepairSpec::enabled()
        };
        let chip = ChipSpec::new(1, 12, (64, 64)).with_spares(4);
        let mut a = linear_model(faulty_hw(43, 0.05), 43).compile(&chip).unwrap();
        let mut b = linear_model(faulty_hw(43, 0.05), 43).compile(&chip).unwrap();
        let out_a = a.self_heal(&spec).unwrap();
        let out_b = b.self_heal(&spec).unwrap();
        assert_eq!(out_a.plan, out_b.plan);
        let deg_a = a.degraded().expect("spares must exhaust").clone();
        assert_eq!(Some(&deg_a), b.degraded());
        assert_eq!(a.condemned_per_layer(), b.condemned_per_layer());
        assert_eq!(a.condemned_per_layer().iter().sum::<usize>(), deg_a.condemned.len());
        let x = lin_batch(4);
        let ya = a.infer(&x);
        let yb = b.infer(&x);
        for (p, q) in ya.data.iter().zip(&yb.data) {
            assert_eq!(p.to_bits(), q.to_bits(), "degraded twins diverged");
        }
    }

    #[test]
    fn capacity_error_propagates_from_compile() {
        let m = small_model(11);
        let planes = m.mapped_planes();
        let chip = ChipSpec::new(1, planes - 1, (64, 64));
        let err = m.compile(&chip).unwrap_err().to_string();
        assert!(err.contains("chip capacity exceeded"), "{err}");
    }

    #[test]
    fn array_shape_mismatch_is_an_error() {
        let m = small_model(13);
        let chip = ChipSpec::single_tile(1024, (32, 32));
        let err = m.compile(&chip).unwrap_err().to_string();
        assert!(err.contains("array"), "{err}");
    }
}

//! Multi-chip sharded execution: chip-level fault domains, pipeline
//! parallelism, failover, and re-replication.
//!
//! One [`super::ChipSpec`] caps how large a model the single-chip
//! [`super::MappedModel`] path can serve. This module shards a
//! [`crate::nn::Sequential`] across an **ordered fleet** of chips:
//!
//! - [`ShardPlan`] — the partition: contiguous layer runs become
//!   *stages*, each owning one chip; a single layer too big for any one
//!   chip is **block-split** across a run of homogeneous chips (the
//!   stage's chip is their [`union_chip`], whose tile boundaries include
//!   the chip boundaries — so no weight block group ever straddles a
//!   chip, by the same invariant [`super::TileAllocator`] enforces for
//!   tiles). Chips left over become the fleet's spare pool.
//! - [`ShardedModel`] — the compiled result: one per-stage
//!   [`super::MappedModel`] each programmed on its own chip, chained by
//!   simulated inter-chip links. [`ShardedModel::infer_batched`] passes
//!   the full batch stage to stage, so quantization stays batch-global
//!   and the output is **bit-identical** to the single-chip
//!   `MappedModel::infer_batched` on noise-free engines (each stage
//!   reprograms at chip-local streams, so on *noisy* engines the
//!   sharded model draws different programming noise — same trade as a
//!   replica pool; the noise-free contract is exact and hard-asserted).
//! - [`ShardedModel::run`] — the pipeline executor: micro-batches flow
//!   through the stages under a deterministic simulated clock (compute
//!   is real, only duration is modeled — the same philosophy as
//!   [`super::serve`]); successive micro-batches overlap across stages,
//!   so fleet throughput beats the equivalent single chip.
//!
//! **Fault domains.** [`ChipFaultSpec`] kills a whole chip mid-run;
//! [`LinkSpec`] injects per-hop timeouts and transfer corruption.
//! Corrupted transfers are *detected* (a column-checksum over the
//! payload, the same ABFT idea the repair probes use) and retransmitted
//! under bounded retry/backoff; exhausting the hop budget fails the
//! micro-batch with a typed [`FleetError`] — conserved, never silently
//! dropped. On chip loss, a stage **fails over**: its layers re-compile
//! onto spare chips (reprogramming from the cached `WeightTemplate`s —
//! the delta path reuses clean digits and redraws only the new slots'
//! streams), paying `failover_us` of downtime; when no spare fits, the
//! dead chip's block groups are condemned in place (exact-zero
//! contribution, [`super::repair::DegradedReport`]) and the fleet keeps
//! serving degraded — which is why failover-on accuracy strictly beats
//! failover-off under the same faults.

use super::repair::DegradedReport;
use super::{ChipSpec, CoreDemand, MappedModel, Placement, TileAllocator};
use crate::dpe::RepairSpec;
use crate::nn::Sequential;
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;
use std::fmt::Write as _;

/// Inter-chip link model: transfer cost, hop deadline, bounded
/// retry/backoff, and the injected failure rates (TOML `[fleet]`).
#[derive(Debug, Clone, PartialEq)]
pub struct LinkSpec {
    /// Fixed per-transfer latency (µs).
    pub base_us: u64,
    /// Additional latency per sample in the micro-batch (µs).
    pub per_sample_us: u64,
    /// A hop that has not completed by this deadline counts as timed out.
    pub hop_deadline_us: u64,
    /// Retransmissions allowed per hop after the first attempt.
    pub max_retries: usize,
    /// Backoff before retry `k` is `retry_backoff_us << (k-1)` (µs).
    pub retry_backoff_us: u64,
    /// Probability a hop attempt times out (drops the transfer).
    pub drop_rate: f64,
    /// Probability a hop attempt corrupts the payload in flight (the
    /// checksum detects it and the receiver requests a retransmit).
    pub corrupt_rate: f64,
}

impl Default for LinkSpec {
    fn default() -> Self {
        LinkSpec {
            base_us: 50,
            per_sample_us: 5,
            hop_deadline_us: 10_000,
            max_retries: 2,
            retry_backoff_us: 200,
            drop_rate: 0.0,
            corrupt_rate: 0.0,
        }
    }
}

/// Fleet execution parameters (TOML `[fleet]`).
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSpec {
    /// Samples per micro-batch flowing through the pipeline.
    pub micro_batch: usize,
    /// Fixed per-stage dispatch cost (µs).
    pub service_base_us: u64,
    /// Per-sample compute cost of the *whole* model (µs); each stage
    /// charges its share, proportional to the digit planes it holds.
    pub service_per_sample_us: u64,
    pub link: LinkSpec,
    /// Re-replicate lost stages onto spare chips; `false` degrades only.
    pub failover: bool,
    /// Downtime to reprogram a stage onto spares (µs).
    pub failover_us: u64,
    /// Seed for the link fault draws (per-attempt streams).
    pub seed: u64,
}

impl Default for FleetSpec {
    fn default() -> Self {
        FleetSpec {
            micro_batch: 8,
            service_base_us: 200,
            service_per_sample_us: 50,
            link: LinkSpec::default(),
            failover: true,
            failover_us: 20_000,
            seed: 0x0F1E_E7,
        }
    }
}

/// A whole-chip failure injected at an absolute simulation time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChipFaultSpec {
    pub at_us: u64,
    /// Fleet chip index (a stage member or a spare).
    pub chip: usize,
}

/// Typed micro-batch failure — the only way a batch can fail.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FleetError {
    /// Every allowed attempt of an inter-stage hop timed out or was
    /// corrupted: the micro-batch never reached stage `stage`.
    LinkFailed { batch: usize, stage: usize, attempts: usize },
}

impl std::fmt::Display for FleetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FleetError::LinkFailed { batch, stage, attempts } => write!(
                f,
                "micro-batch {batch}: link into stage {stage} failed after {attempts} attempts"
            ),
        }
    }
}

impl std::error::Error for FleetError {}

/// Timeline entry of a fleet run.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetEvent {
    pub at_us: u64,
    pub kind: FleetEventKind,
}

#[derive(Debug, Clone, PartialEq)]
pub enum FleetEventKind {
    /// A chip died (fault applied at its injection time).
    ChipFault { chip: usize },
    /// A stage re-replicated onto spare chips.
    Failover { stage: usize, to_chips: Vec<usize> },
    /// No spare fit: the dead chip's groups were condemned in place.
    Degraded { stage: usize, condemned: usize },
    /// A chip died mid-execution; the micro-batch re-runs on the
    /// post-transition stage.
    Rerun { stage: usize, batch: usize },
    /// A hop attempt timed out.
    LinkTimeout { stage: usize, batch: usize, attempt: usize },
    /// A hop attempt delivered a corrupted payload; the checksum caught
    /// it and a retransmit was requested.
    CorruptDetected { stage: usize, batch: usize, attempt: usize },
    /// A micro-batch exhausted its hop budget and failed.
    BatchFailed { batch: usize, stage: usize },
}

/// Outcome of one micro-batch: every batch ends in exactly one of these.
#[derive(Debug, Clone, PartialEq)]
pub enum BatchOutcome {
    Done { completed_us: u64, degraded: bool },
    Failed { error: FleetError, at_us: u64 },
}

/// One pipeline stage of the plan: a contiguous layer run on one chip
/// (or, for a block-split layer, on a run of homogeneous chips fused
/// into one union chip).
#[derive(Debug, Clone, PartialEq)]
pub struct StagePlan {
    /// Fleet chip indices this stage occupies (ascending, contiguous).
    pub chips: Vec<usize>,
    /// The chip the stage compiles onto ([`union_chip`] of `chips`).
    pub chip: ChipSpec,
    /// Model layer range `[start, end)` (digital layers ride with the
    /// preceding hardware layer's stage).
    pub layers: (usize, usize),
    /// The stage's core demands (global model layer indices).
    pub demands: Vec<CoreDemand>,
    /// The allocation of `demands` on `chip`.
    pub placement: Placement,
}

/// The fleet partition: ordered stages plus the spare-chip pool.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardPlan {
    pub stages: Vec<StagePlan>,
    /// Fleet chips not owned by any stage, in ascending order.
    pub spares: Vec<usize>,
    pub fleet: Vec<ChipSpec>,
    pub n_layers: usize,
}

/// Fuse a run of fleet chips into one chip whose tiles are the members'
/// tiles concatenated in order. Members must agree on tile geometry
/// (`arrays_per_tile`, `array`, `spares_per_tile`): only then do the
/// union's tile boundaries include every chip boundary, which is what
/// keeps a block group from straddling chips.
pub fn union_chip(fleet: &[ChipSpec], members: &[usize]) -> anyhow::Result<ChipSpec> {
    if members.is_empty() {
        anyhow::bail!("a stage needs at least one chip");
    }
    let first = &fleet[members[0]];
    let mut tiles = 0usize;
    for &m in members {
        let c = &fleet[m];
        if c.arrays_per_tile != first.arrays_per_tile
            || c.array != first.array
            || c.spares_per_tile != first.spares_per_tile
        {
            anyhow::bail!(
                "cannot split a layer across heterogeneous chips: chip {m} \
                 ({} arrays/tile of {:?}, {} spares) differs from chip {} \
                 ({} arrays/tile of {:?}, {} spares)",
                c.arrays_per_tile,
                c.array,
                c.spares_per_tile,
                members[0],
                first.arrays_per_tile,
                first.array,
                first.spares_per_tile
            );
        }
        tiles += c.tiles;
    }
    let mut u = ChipSpec::new(tiles, first.arrays_per_tile, first.array);
    u.spares_per_tile = first.spares_per_tile;
    Ok(u)
}

/// A homogeneous fleet of `chips` single-tile chips of
/// `arrays_per_chip` arrays each — the simplest fleet shape (and the
/// one the TOML `[fleet]` section builds).
pub fn uniform_fleet(
    chips: usize,
    arrays_per_chip: usize,
    array: (usize, usize),
) -> Vec<ChipSpec> {
    (0..chips).map(|_| ChipSpec::single_tile(arrays_per_chip, array)).collect()
}

impl ShardPlan {
    /// Partition `demands` (model order, global layer indices) onto the
    /// ordered fleet. Greedy: extend the current stage while its chip
    /// still fits the next layer; close it and move to the next chip
    /// otherwise. A layer that does not fit alone on an empty chip is
    /// block-split across a widening run of homogeneous chips.
    /// Deterministic — no RNG anywhere in planning.
    pub fn plan(
        fleet: &[ChipSpec],
        demands: &[CoreDemand],
        n_layers: usize,
    ) -> anyhow::Result<ShardPlan> {
        if fleet.is_empty() {
            anyhow::bail!("cannot shard onto an empty fleet");
        }
        // Group demands by model layer (a layer's cores stay together).
        let mut layer_demands: Vec<(usize, Vec<CoreDemand>)> = Vec::new();
        for d in demands {
            match layer_demands.last_mut() {
                Some((li, v)) if *li == d.layer => v.push(d.clone()),
                _ => layer_demands.push((d.layer, vec![d.clone()])),
            }
        }
        let mut stages: Vec<StagePlan> = Vec::new();
        if layer_demands.is_empty() {
            // Purely digital model: one stage on chip 0, nothing placed.
            let placement = TileAllocator::allocate(&fleet[0], &[])?;
            stages.push(StagePlan {
                chips: vec![0],
                chip: fleet[0].clone(),
                layers: (0, n_layers),
                demands: Vec::new(),
                placement,
            });
            return Ok(ShardPlan {
                stages,
                spares: (1..fleet.len()).collect(),
                fleet: fleet.to_vec(),
                n_layers,
            });
        }
        let mut c = 0usize; // next free fleet chip
        let mut cur: Vec<CoreDemand> = Vec::new();
        let mut cur_first_layer = 0usize;
        let mut cur_placement: Option<Placement> = None;
        let mut i = 0usize;
        while i < layer_demands.len() {
            let (li, lds) = &layer_demands[i];
            if c >= fleet.len() {
                anyhow::bail!(
                    "fleet exhausted: {} chips hold layers up to {} but layer {} ({}) \
                     still needs {} digit planes",
                    fleet.len(),
                    cur_first_layer,
                    li,
                    lds[0].name,
                    lds.iter().map(CoreDemand::planes).sum::<usize>()
                );
            }
            let mut trial = cur.clone();
            trial.extend(lds.iter().cloned());
            match TileAllocator::allocate(&fleet[c], &trial) {
                Ok(p) => {
                    if cur.is_empty() {
                        cur_first_layer = *li;
                    }
                    cur = trial;
                    cur_placement = Some(p);
                    i += 1;
                }
                Err(alloc_err) => {
                    if !cur.is_empty() {
                        // Close the stage on chip c; retry this layer on
                        // the next chip.
                        stages.push(StagePlan {
                            chips: vec![c],
                            chip: fleet[c].clone(),
                            layers: (cur_first_layer, 0), // end fixed below
                            demands: std::mem::take(&mut cur),
                            placement: cur_placement.take().expect("stage had a placement"),
                        });
                        c += 1;
                    } else {
                        // Block-split: widen a union of chips until the
                        // lone layer fits.
                        let mut width = 2usize;
                        loop {
                            if c + width > fleet.len() {
                                anyhow::bail!(
                                    "fleet exhausted splitting layer {} ({}) across chips \
                                     {c}..{}: {alloc_err:#}",
                                    li,
                                    lds[0].name,
                                    fleet.len()
                                );
                            }
                            let members: Vec<usize> = (c..c + width).collect();
                            let u = union_chip(fleet, &members)?;
                            if let Ok(p) = TileAllocator::allocate(&u, lds) {
                                stages.push(StagePlan {
                                    chips: members,
                                    chip: u,
                                    layers: (*li, 0),
                                    demands: lds.clone(),
                                    placement: p,
                                });
                                c += width;
                                i += 1;
                                break;
                            }
                            width += 1;
                        }
                    }
                }
            }
        }
        if !cur.is_empty() {
            stages.push(StagePlan {
                chips: vec![c],
                chip: fleet[c].clone(),
                layers: (cur_first_layer, 0),
                demands: cur,
                placement: cur_placement.take().expect("stage had a placement"),
            });
            c += 1;
        }
        // Fix layer ranges: stage 0 absorbs any leading digital layers;
        // each stage ends where the next begins; the last takes the tail.
        let mut starts: Vec<usize> = stages.iter().map(|s| s.layers.0).collect();
        starts[0] = 0;
        let n_stages = stages.len();
        for (si, stage) in stages.iter_mut().enumerate() {
            let end = if si + 1 < n_stages { starts[si + 1] } else { n_layers };
            stage.layers = (starts[si], end);
        }
        Ok(ShardPlan {
            stages,
            spares: (c..fleet.len()).collect(),
            fleet: fleet.to_vec(),
            n_layers,
        })
    }

    /// Re-place stage `stage`'s demands onto `replacement` chips (the
    /// failover planner's bookkeeping): validates the union and the
    /// allocation, swaps the stage's chips, and removes the used chips
    /// from the spare pool. The old member chips are *not* returned to
    /// the pool here — the caller knows which of them are still alive.
    pub fn substitute(&self, stage: usize, replacement: &[usize]) -> anyhow::Result<ShardPlan> {
        let u = union_chip(&self.fleet, replacement)?;
        let placement = TileAllocator::allocate(&u, &self.stages[stage].demands)?;
        let mut plan = self.clone();
        plan.stages[stage].chips = replacement.to_vec();
        plan.stages[stage].chip = u;
        plan.stages[stage].placement = placement;
        plan.spares.retain(|s| !replacement.contains(s));
        Ok(plan)
    }

    /// Tile range `[start, end)` of each member chip within stage
    /// `stage`'s union chip — the chip-boundary map used to decide which
    /// block groups die with a member.
    pub fn member_tiles(&self, stage: usize) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        let mut off = 0usize;
        for &c in &self.stages[stage].chips {
            let t = self.fleet[c].tiles;
            out.push((off, off + t));
            off += t;
        }
        out
    }

    /// Human-readable plan summary (the CLI/example view).
    pub fn report(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "fleet: {} chips, {} stage(s), {} spare(s)",
            self.fleet.len(),
            self.stages.len(),
            self.spares.len()
        );
        for (si, st) in self.stages.iter().enumerate() {
            let _ = writeln!(
                s,
                "  stage {si}: chips {:?}  layers {}..{}  {} groups / {} planes{}",
                st.chips,
                st.layers.0,
                st.layers.1,
                st.demands.iter().map(|d| d.blocks).sum::<usize>(),
                st.placement.total_planes(),
                if st.chips.len() > 1 { "  (block-split)" } else { "" }
            );
        }
        if !self.spares.is_empty() {
            let _ = writeln!(s, "  spares: {:?}", self.spares);
        }
        s
    }
}

/// One compiled pipeline stage.
struct Stage {
    /// `None` only transiently inside a failed failover (the run aborts
    /// with the error in that case).
    model: Option<MappedModel>,
    /// Set when chip loss condemned groups in place (no spare fit).
    degraded: bool,
}

/// The result of one [`ShardedModel::run`]: per-micro-batch outcomes and
/// outputs, the event timeline, and the throughput accounting. Every
/// input sample is in exactly one batch; every batch is `Done` or
/// `Failed` — conservation is checkable, and checked, after every run.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetReport {
    pub outcomes: Vec<BatchOutcome>,
    /// Per-batch flattened output rows; `None` iff the batch failed.
    pub outputs: Vec<Option<Vec<f64>>>,
    /// Per-sample output shape (without the leading batch dim).
    pub out_shape: Vec<usize>,
    pub micro_batch: usize,
    pub samples: usize,
    pub events: Vec<FleetEvent>,
    pub makespan_us: u64,
}

impl FleetReport {
    pub fn completed(&self) -> usize {
        self.outcomes.iter().filter(|o| matches!(o, BatchOutcome::Done { .. })).count()
    }

    pub fn failed(&self) -> usize {
        self.outcomes.len() - self.completed()
    }

    /// Samples in completed batches.
    pub fn completed_samples(&self) -> usize {
        self.outcomes
            .iter()
            .enumerate()
            .filter(|(_, o)| matches!(o, BatchOutcome::Done { .. }))
            .map(|(b, _)| self.batch_size(b))
            .sum()
    }

    fn batch_size(&self, b: usize) -> usize {
        (self.samples - b * self.micro_batch).min(self.micro_batch)
    }

    /// Batches that completed on a degraded stage.
    pub fn degraded_batches(&self) -> usize {
        self.outcomes
            .iter()
            .filter(|o| matches!(o, BatchOutcome::Done { degraded: true, .. }))
            .count()
    }

    pub fn failovers(&self) -> usize {
        self.events.iter().filter(|e| matches!(e.kind, FleetEventKind::Failover { .. })).count()
    }

    pub fn link_retries(&self) -> usize {
        self.events
            .iter()
            .filter(|e| {
                matches!(
                    e.kind,
                    FleetEventKind::LinkTimeout { .. } | FleetEventKind::CorruptDetected { .. }
                )
            })
            .count()
    }

    pub fn corrupt_detected(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e.kind, FleetEventKind::CorruptDetected { .. }))
            .count()
    }

    /// Completed samples per second of simulated wall-clock.
    pub fn images_per_sec(&self) -> f64 {
        if self.makespan_us == 0 {
            return 0.0;
        }
        self.completed_samples() as f64 * 1e6 / self.makespan_us as f64
    }

    /// Request conservation: every sample sits in exactly one batch,
    /// every batch has exactly one outcome, and outputs are present
    /// exactly for the completed ones.
    pub fn conserved(&self) -> bool {
        let n_batches = if self.micro_batch == 0 {
            0
        } else {
            self.samples.div_ceil(self.micro_batch)
        };
        self.outcomes.len() == n_batches
            && self.outputs.len() == n_batches
            && self.outcomes.iter().zip(&self.outputs).enumerate().all(|(b, (o, out))| {
                let sample_len: usize = self.out_shape.iter().product();
                match (o, out) {
                    (BatchOutcome::Done { .. }, Some(rows)) => {
                        rows.len() == self.batch_size(b) * sample_len
                    }
                    (BatchOutcome::Failed { .. }, None) => true,
                    _ => false,
                }
            })
    }

    /// Assemble the full output tensor — `None` unless every batch
    /// completed.
    pub fn output_tensor(&self) -> Option<Tensor> {
        let mut data = Vec::new();
        for out in &self.outputs {
            data.extend_from_slice(out.as_deref()?);
        }
        let mut shape = vec![self.samples];
        shape.extend_from_slice(&self.out_shape);
        Some(Tensor::from_vec(&shape, data))
    }
}

/// A model compiled across a chip fleet. See the module docs.
pub struct ShardedModel {
    stages: Vec<Stage>,
    plan: ShardPlan,
    /// Per-fleet-chip liveness (faults applied so far).
    chip_down: Vec<bool>,
    /// Chip-loss condemnations (global core indices), merged with the
    /// per-stage self-heal reports into [`ShardedModel::degraded`].
    fleet_degraded: Option<DegradedReport>,
    merged_degraded: Option<DegradedReport>,
}

impl ShardedModel {
    /// Shard `model` across `fleet`: plan the partition, split the layer
    /// list by stage, and compile each stage onto its chip (programming
    /// it at chip-local streams). Errors on array-shape mismatch, an
    /// empty fleet, or a fleet too small for the model.
    pub fn compile(model: Sequential, fleet: &[ChipSpec]) -> anyhow::Result<ShardedModel> {
        let n_layers = model.layers.len();
        // Collect demands (global layer indices) and check array shapes
        // up front — the per-stage compiles repeat the check, but failing
        // here names the offending layer before any chip is programmed.
        let mut demands: Vec<CoreDemand> = Vec::new();
        for (li, l) in model.layers.iter().enumerate() {
            let name = l.name();
            for core in l.cores() {
                if let Some((blocks, slices)) = core.demand() {
                    if let Some(hw) = core.hw() {
                        if !fleet.is_empty() && hw.engine.cfg.array != fleet[0].array {
                            anyhow::bail!(
                                "cannot shard model onto fleet: layer {li} ({name}) engine \
                                 array {:?} != fleet array {:?}",
                                hw.engine.cfg.array,
                                fleet[0].array
                            );
                        }
                    }
                    demands.push(CoreDemand { layer: li, name, blocks, slices });
                }
            }
        }
        for (ci, chip) in fleet.iter().enumerate() {
            if chip.array != fleet[0].array {
                anyhow::bail!(
                    "fleet chips disagree on array shape: chip {ci} is {:?}, chip 0 is {:?}",
                    chip.array,
                    fleet[0].array
                );
            }
        }
        let plan = ShardPlan::plan(fleet, &demands, n_layers)?;
        // Split the layer list by stage and compile each run onto its
        // chip. The struct literal (not `Sequential::new`) keeps the
        // cores' current streams until `compile` assigns the real ones —
        // avoiding a pointless reprogram at virtual streams in between.
        let mut layers = model.layers;
        let mut stages = Vec::with_capacity(plan.stages.len());
        for st in &plan.stages {
            let count = st.layers.1 - st.layers.0;
            let tail = layers.split_off(count.min(layers.len()));
            let stage_layers = std::mem::replace(&mut layers, tail);
            let stage_model = Sequential { layers: stage_layers };
            let mapped = stage_model.compile(&st.chip)?;
            stages.push(Stage { model: Some(mapped), degraded: false });
        }
        debug_assert!(layers.is_empty(), "every layer belongs to a stage");
        let n_chips = plan.fleet.len();
        Ok(ShardedModel {
            stages,
            plan,
            chip_down: vec![false; n_chips],
            fleet_degraded: None,
            merged_degraded: None,
        })
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    pub fn stage_count(&self) -> usize {
        self.stages.len()
    }

    /// The compiled model of one stage.
    pub fn stage_model(&self, stage: usize) -> &MappedModel {
        self.stages[stage].model.as_ref().expect("stage model present")
    }

    /// Per-chip liveness after the faults applied so far.
    pub fn chip_down(&self) -> &[bool] {
        &self.chip_down
    }

    /// Spare chips still alive.
    pub fn spares_left(&self) -> usize {
        self.plan.spares.iter().filter(|&&c| !self.chip_down[c]).count()
    }

    /// Full-batch inference through the stage chain (each stage sees the
    /// whole batch, so quantization stays batch-global): bit-identical
    /// to the single-chip `MappedModel::infer` on noise-free engines.
    pub fn infer(&self, x: &Tensor) -> Tensor {
        let mut h = x.clone();
        for st in &self.stages {
            h = st.model.as_ref().expect("stage model present").infer(&h);
        }
        h
    }

    /// Micro-batched inference through the stage chain — the exact
    /// counterpart of [`MappedModel::infer_batched`] (see module docs
    /// for the bit-identity contract).
    pub fn infer_batched(&self, x: &Tensor, micro_batch: usize) -> Tensor {
        let mut h = x.clone();
        for st in &self.stages {
            h = st.model.as_ref().expect("stage model present").infer_batched(&h, micro_batch);
        }
        h
    }

    /// Condemned-group count per placed core across all stages (global
    /// core order).
    pub fn condemned_per_layer(&self) -> Vec<usize> {
        let mut out = Vec::new();
        for st in &self.stages {
            out.extend(st.model.as_ref().expect("stage model present").condemned_per_layer());
        }
        out
    }

    /// The merged degraded report (chip-loss condemnations plus the
    /// stages' own self-heal leftovers), if any.
    pub fn degraded(&self) -> Option<&DegradedReport> {
        self.merged_degraded.as_ref()
    }

    /// Probe every stage without mutating programmed state; core indices
    /// in the merged report are global (stage offsets applied).
    pub fn health_probe(&self, spec: &RepairSpec) -> anyhow::Result<super::HealthReport> {
        let mut health = super::HealthReport::default();
        let mut off = 0usize;
        for st in &self.stages {
            let m = st.model.as_ref().expect("stage model present");
            let h = m.health_probe(spec)?;
            health.probe_matmuls += h.probe_matmuls;
            for mut sh in h.slots {
                sh.layer += off;
                health.slots.push(sh);
            }
            off += m.placement().layers.len();
        }
        Ok(health)
    }

    /// One self-heal round per stage (program-and-verify, probe, remap
    /// to spares, degrade), merged into one outcome with global core
    /// indices.
    pub fn self_heal(&mut self, spec: &RepairSpec) -> anyhow::Result<super::RepairOutcome> {
        let mut out = super::RepairOutcome::default();
        let mut off = 0usize;
        for st in self.stages.iter_mut() {
            let m = st.model.as_mut().expect("stage model present");
            let o = m.self_heal(spec)?;
            out.program_reports.extend(o.program_reports);
            out.health.probe_matmuls += o.health.probe_matmuls;
            for mut sh in o.health.slots {
                sh.layer += off;
                out.health.slots.push(sh);
            }
            for mut mv in o.plan.moves {
                mv.layer += off;
                out.plan.moves.push(mv);
            }
            out.plan.unplaced.extend(o.plan.unplaced.into_iter().map(|(l, b)| (l + off, b)));
            off += m.placement().layers.len();
        }
        self.refresh_degraded();
        out.degraded = self.merged_degraded.clone();
        Ok(out)
    }

    fn refresh_degraded(&mut self) {
        let mut merged = DegradedReport::default();
        let mut any = false;
        if let Some(fd) = &self.fleet_degraded {
            merged.merge(fd, 0);
            any = true;
        }
        let mut off = 0usize;
        for st in &self.stages {
            let m = st.model.as_ref().expect("stage model present");
            if let Some(d) = m.degraded() {
                merged.merge(d, off);
                any = true;
            }
            off += m.placement().layers.len();
        }
        self.merged_degraded = if any { Some(merged) } else { None };
    }

    /// Simulated per-stage service time for a `bs`-sample micro-batch:
    /// the stage charges its plane share of the whole model's per-sample
    /// cost; a block-split stage divides the work across its member
    /// chips and pays a reduce term per extra member.
    fn service_us(&self, stage: usize, bs: usize, spec: &FleetSpec) -> u64 {
        let stage_planes = self.plan.stages[stage].placement.total_planes() as u64;
        let total: u64 =
            self.plan.stages.iter().map(|st| st.placement.total_planes() as u64).sum();
        let total = total.max(1);
        let mut svc =
            spec.service_base_us + (bs as u64 * spec.service_per_sample_us * stage_planes) / total;
        let width = self.plan.stages[stage].chips.len() as u64;
        if width > 1 {
            svc = svc / width + spec.link.base_us * (width - 1);
        }
        svc.max(1)
    }

    /// Alive spare chips at `at_us`: never faulted so far, and no
    /// injected fault at or before `at_us`.
    fn find_spares(
        &self,
        stage: usize,
        at_us: u64,
        faults: &[ChipFaultSpec],
    ) -> Option<Vec<usize>> {
        let alive: Vec<usize> = self
            .plan
            .spares
            .iter()
            .copied()
            .filter(|&c| {
                !self.chip_down[c] && !faults.iter().any(|f| f.chip == c && f.at_us <= at_us)
            })
            .collect();
        let demands = &self.plan.stages[stage].demands;
        for width in 1..=alive.len() {
            for start in 0..=alive.len() - width {
                let members: Vec<usize> = alive[start..start + width].to_vec();
                let Ok(u) = union_chip(&self.plan.fleet, &members) else { continue };
                if TileAllocator::allocate(&u, demands).is_ok() {
                    return Some(members);
                }
            }
        }
        None
    }

    /// Condemn the block groups whose home tiles belong to the dead
    /// member chip — exact-zero contribution, fleet keeps serving.
    fn degrade_stage(
        &mut self,
        stage: usize,
        dead_chip: usize,
        at_us: u64,
        events: &mut Vec<FleetEvent>,
    ) {
        let ranges = self.plan.member_tiles(stage);
        let pos = self.plan.stages[stage]
            .chips
            .iter()
            .position(|&c| c == dead_chip)
            .expect("dead chip is a stage member");
        let (t0, t1) = ranges[pos];
        let core_off: usize = self
            .plan
            .stages
            .iter()
            .take(stage)
            .map(|st| st.placement.layers.len())
            .sum();
        let mut groups: Vec<(usize, usize)> = Vec::new();
        let mut deg = self.fleet_degraded.take().unwrap_or_default();
        for (ci, lp) in self.plan.stages[stage].placement.layers.iter().enumerate() {
            for b in 0..lp.blocks {
                let home = lp.slots[b * lp.slices];
                if home.tile >= t0 && home.tile < t1 {
                    groups.push((ci, b));
                    deg.condemned.push((ci + core_off, b));
                    deg.slots.push(home);
                }
            }
        }
        // A whole-chip loss is a full-scale miss for the dead groups.
        deg.estimated_re_impact = deg.estimated_re_impact.max(1.0);
        self.fleet_degraded = Some(deg);
        self.stages[stage]
            .model
            .as_mut()
            .expect("stage model present")
            .condemn(&groups);
        self.stages[stage].degraded = true;
        self.refresh_degraded();
        events.push(FleetEvent {
            at_us,
            kind: FleetEventKind::Degraded { stage, condemned: groups.len() },
        });
    }

    /// Apply, in injection order, every not-yet-applied fault on this
    /// stage's chips with `at_us <= up_to`: mark the chip dead, then
    /// fail the stage over onto spares (re-replication) or condemn the
    /// dead chip's groups in place.
    #[allow(clippy::too_many_arguments)]
    fn absorb_stage_faults(
        &mut self,
        stage: usize,
        up_to: u64,
        faults: &[ChipFaultSpec],
        applied: &mut [bool],
        spec: &FleetSpec,
        stage_free: &mut [u64],
        events: &mut Vec<FleetEvent>,
    ) -> anyhow::Result<()> {
        loop {
            let next = faults
                .iter()
                .enumerate()
                .filter(|(k, f)| {
                    !applied[*k]
                        && f.at_us <= up_to
                        && self.plan.stages[stage].chips.contains(&f.chip)
                })
                .min_by_key(|(_, f)| (f.at_us, f.chip));
            let Some((k, f)) = next else { return Ok(()) };
            let f = *f;
            applied[k] = true;
            if self.chip_down[f.chip] {
                continue; // duplicate injection on an already-dead chip
            }
            self.chip_down[f.chip] = true;
            events.push(FleetEvent {
                at_us: f.at_us,
                kind: FleetEventKind::ChipFault { chip: f.chip },
            });
            let mut failed_over = false;
            if spec.failover {
                if let Some(members) = self.find_spares(stage, f.at_us, faults) {
                    let new_plan = self.plan.substitute(stage, &members)?;
                    let old = self.stages[stage].model.take().expect("stage model present");
                    let mapped = old.into_model().compile(&new_plan.stages[stage].chip)?;
                    debug_assert_eq!(
                        *mapped.placement(),
                        new_plan.stages[stage].placement,
                        "substitute and compile disagree on the stage placement"
                    );
                    let old_chips = self.plan.stages[stage].chips.clone();
                    self.plan = new_plan;
                    // Surviving old members go back to the spare pool.
                    for ch in old_chips {
                        if !self.chip_down[ch] {
                            self.plan.spares.push(ch);
                        }
                    }
                    self.plan.spares.sort_unstable();
                    self.stages[stage].model = Some(mapped);
                    self.stages[stage].degraded = false;
                    stage_free[stage] = stage_free[stage].max(f.at_us) + spec.failover_us;
                    events.push(FleetEvent {
                        at_us: f.at_us,
                        kind: FleetEventKind::Failover { stage, to_chips: members },
                    });
                    failed_over = true;
                }
            }
            if !failed_over {
                self.degrade_stage(stage, f.chip, f.at_us, events);
            }
        }
    }

    /// One inter-stage hop under the link model: per-attempt fault draws
    /// keyed by `(batch, stage, attempt)` — worker-count invariant.
    /// Returns the arrival time at the next stage, or the typed failure
    /// after the retry budget is spent.
    #[allow(clippy::too_many_arguments)]
    fn link_hop(
        &self,
        t: u64,
        batch: usize,
        stage: usize,
        bs: usize,
        payload: &[f64],
        spec: &FleetSpec,
        events: &mut Vec<FleetEvent>,
    ) -> Result<u64, (FleetError, u64)> {
        let link = &spec.link;
        let transfer = link.base_us + bs as u64 * link.per_sample_us;
        let mut t = t;
        let attempts = link.max_retries + 1;
        for attempt in 1..=attempts {
            let mut rng = Pcg64::new(
                spec.seed ^ 0x119C_C0DE,
                ((batch as u64) << 24) | ((stage as u64) << 8) | attempt as u64,
            );
            let backoff = link.retry_backoff_us << ((attempt - 1).min(20) as u32);
            if rng.uniform() < link.drop_rate {
                t += link.hop_deadline_us;
                events.push(FleetEvent {
                    at_us: t,
                    kind: FleetEventKind::LinkTimeout { stage, batch, attempt },
                });
                if attempt == attempts {
                    return Err((FleetError::LinkFailed { batch, stage, attempts }, t));
                }
                t += backoff;
                continue;
            }
            if rng.uniform() < link.corrupt_rate {
                // Corrupt one word of a copy in flight; the receiver's
                // column checksum over the payload catches the mismatch
                // and requests a retransmit — the corrupted data never
                // reaches compute.
                let mut corrupted = payload.to_vec();
                if !corrupted.is_empty() {
                    let i = rng.below(corrupted.len());
                    corrupted[i] = f64::from_bits(corrupted[i].to_bits() ^ (1u64 << 62));
                }
                let clean: f64 = payload.iter().sum();
                let got: f64 = corrupted.iter().sum();
                let detected = got.to_bits() != clean.to_bits();
                debug_assert!(
                    payload.is_empty() || detected,
                    "checksum failed to detect a corrupted transfer"
                );
                let _ = detected;
                t += transfer;
                events.push(FleetEvent {
                    at_us: t,
                    kind: FleetEventKind::CorruptDetected { stage, batch, attempt },
                });
                if attempt == attempts {
                    return Err((FleetError::LinkFailed { batch, stage, attempts }, t));
                }
                t += backoff;
                continue;
            }
            return Ok(t + transfer);
        }
        unreachable!("the retry loop always returns")
    }

    /// Pipeline-parallel execution of `x` through the fleet under the
    /// simulated clock, with chip faults and link faults injected. See
    /// the module docs; every micro-batch ends `Done` or `Failed` and
    /// the report's conservation check covers them all.
    pub fn run(
        &mut self,
        x: &Tensor,
        spec: &FleetSpec,
        faults: &[ChipFaultSpec],
    ) -> anyhow::Result<FleetReport> {
        let samples = x.shape.first().copied().unwrap_or(0);
        if samples == 0 {
            anyhow::bail!("fleet run needs at least one sample");
        }
        let mb = spec.micro_batch.max(1);
        let sample_len = x.numel() / samples;
        let n_batches = samples.div_ceil(mb);
        let n_stages = self.stages.len();
        let mut stage_free = vec![0u64; n_stages];
        let mut applied = vec![false; faults.len()];
        let mut events: Vec<FleetEvent> = Vec::new();
        let mut outcomes: Vec<BatchOutcome> = Vec::with_capacity(n_batches);
        let mut outputs: Vec<Option<Vec<f64>>> = Vec::with_capacity(n_batches);
        let mut out_shape: Vec<usize> = Vec::new();
        for b in 0..n_batches {
            let r0 = b * mb;
            let r1 = (r0 + mb).min(samples);
            let bs = r1 - r0;
            let mut shape = vec![bs];
            shape.extend_from_slice(&x.shape[1..]);
            let mut h =
                Tensor::from_vec(&shape, x.data[r0 * sample_len..r1 * sample_len].to_vec());
            let mut t = 0u64;
            let mut degraded = false;
            let mut failure: Option<(FleetError, u64, usize)> = None;
            for s in 0..n_stages {
                if s > 0 {
                    match self.link_hop(t, b, s, bs, &h.data, spec, &mut events) {
                        Ok(tt) => t = tt,
                        Err((e, at)) => {
                            failure = Some((e, at, s));
                            break;
                        }
                    }
                }
                // Dispatch under the fault clock: absorb everything due,
                // then check the planned execution window for a chip
                // death that would interrupt it — the batch re-runs on
                // the post-transition stage.
                loop {
                    self.absorb_stage_faults(
                        s, t, faults, &mut applied, spec, &mut stage_free, &mut events,
                    )?;
                    let service = self.service_us(s, bs, spec);
                    let start = t.max(stage_free[s]);
                    let done = start + service;
                    let interrupt = faults
                        .iter()
                        .enumerate()
                        .filter(|(k, f)| {
                            !applied[*k]
                                && f.at_us > start
                                && f.at_us < done
                                && self.plan.stages[s].chips.contains(&f.chip)
                        })
                        .map(|(_, f)| f.at_us)
                        .min();
                    if let Some(kill_at) = interrupt {
                        events.push(FleetEvent {
                            at_us: kill_at,
                            kind: FleetEventKind::Rerun { stage: s, batch: b },
                        });
                        t = kill_at;
                        continue;
                    }
                    stage_free[s] = done;
                    t = done;
                    break;
                }
                // Timing settled: now the real compute.
                let model = self.stages[s].model.as_ref().expect("stage model present");
                h = model.infer_batched(&h, bs);
                if self.stages[s].degraded {
                    degraded = true;
                }
            }
            match failure {
                Some((e, at, s)) => {
                    events.push(FleetEvent {
                        at_us: at,
                        kind: FleetEventKind::BatchFailed { batch: b, stage: s },
                    });
                    outcomes.push(BatchOutcome::Failed { error: e, at_us: at });
                    outputs.push(None);
                }
                None => {
                    if out_shape.is_empty() {
                        out_shape = h.shape[1..].to_vec();
                    }
                    outcomes.push(BatchOutcome::Done { completed_us: t, degraded });
                    outputs.push(Some(h.data));
                }
            }
        }
        let makespan_us = outcomes
            .iter()
            .map(|o| match o {
                BatchOutcome::Done { completed_us, .. } => *completed_us,
                BatchOutcome::Failed { at_us, .. } => *at_us,
            })
            .max()
            .unwrap_or(0);
        events.sort_by_key(|e| e.at_us);
        let report = FleetReport {
            outcomes,
            outputs,
            out_shape,
            micro_batch: mb,
            samples,
            events,
            makespan_us,
        };
        debug_assert!(report.conserved(), "fleet run lost or duplicated a micro-batch");
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpe::{DotProductEngine, SliceMethod, SliceSpec};
    use crate::nn::layers::LinearMem;
    use crate::nn::models::mlp;
    use crate::nn::HwSpec;
    use crate::util::prop::prop_check;

    fn ideal_hw() -> HwSpec {
        HwSpec::uniform(DotProductEngine::ideal((64, 64)), SliceMethod::int(SliceSpec::int8()))
    }

    /// mlp(96, 32, 8): layer 1 is 2 int8 groups (8 planes), layer 3 is 1
    /// group (4 planes) — 12 planes total.
    fn tiny_mlp() -> Sequential {
        mlp(96, 32, 8, Some(ideal_hw()), 7)
    }

    fn single_chip() -> MappedModel {
        tiny_mlp().compile(&ChipSpec::single_tile(12, (64, 64))).unwrap()
    }

    fn batch(n: usize) -> Tensor {
        Tensor::from_vec(
            &[n, 96],
            (0..n * 96).map(|i| (((i * 7) % 23) as f64) / 11.5 - 1.0).collect(),
        )
    }

    #[test]
    fn sharded_inference_bit_identical_to_single_chip_on_noise_free_engines() {
        let single = single_chip();
        let fleet = uniform_fleet(3, 8, (64, 64));
        let sharded = ShardedModel::compile(tiny_mlp(), &fleet).unwrap();
        assert_eq!(sharded.stage_count(), 2, "12 planes on 8-array chips is two stages");
        assert_eq!(sharded.plan().spares, vec![2]);
        assert_eq!(sharded.plan().stages[0].layers, (0, 3), "digital layers ride along");
        assert_eq!(sharded.plan().stages[1].layers, (3, 4));
        let x = batch(11);
        assert_eq!(sharded.infer(&x).data, single.infer(&x).data, "infer diverged");
        for mb in [1usize, 2, 4, 11, 64] {
            assert_eq!(
                sharded.infer_batched(&x, mb).data,
                single.infer_batched(&x, mb).data,
                "infer_batched diverged at micro_batch={mb}"
            );
        }
    }

    #[test]
    fn clean_pipeline_run_matches_direct_inference_and_conserves() {
        let single = single_chip();
        let fleet = uniform_fleet(3, 8, (64, 64));
        let mut sharded = ShardedModel::compile(tiny_mlp(), &fleet).unwrap();
        let spec = FleetSpec::default();
        let x = batch(20);
        let rep = sharded.run(&x, &spec, &[]).unwrap();
        assert!(rep.conserved(), "clean run must conserve every micro-batch");
        assert_eq!(rep.completed(), 3, "20 samples at micro_batch 8 is 3 batches");
        assert_eq!(rep.failed(), 0);
        let y = rep.output_tensor().expect("all batches completed");
        assert_eq!(
            y.data,
            single.infer_batched(&x, spec.micro_batch).data,
            "pipeline outputs diverged from the single chip"
        );
        assert!(rep.makespan_us > 0);
        assert!(rep.images_per_sec() > 0.0);
    }

    #[test]
    fn oversized_layer_block_splits_across_chips_bit_identically() {
        // LinearMem(256, 64): 4 int8 groups = 16 planes — too big for one
        // 8-array chip, so the layer splits across a 2-chip union.
        let lin = |seed: u64| {
            let mut rng = Pcg64::new(seed, 0xF1EE);
            Sequential::new(vec![Box::new(LinearMem::new(256, 64, Some(ideal_hw()), &mut rng))
                as Box<dyn crate::nn::Layer>])
        };
        let single = lin(3).compile(&ChipSpec::single_tile(16, (64, 64))).unwrap();
        let fleet = uniform_fleet(3, 8, (64, 64));
        let sharded = ShardedModel::compile(lin(3), &fleet).unwrap();
        assert_eq!(sharded.stage_count(), 1);
        assert_eq!(sharded.plan().stages[0].chips, vec![0, 1], "layer split across two chips");
        assert_eq!(sharded.plan().spares, vec![2]);
        // No group straddles a chip: each 4-plane group sits in one tile,
        // and each single-tile member chip is one union tile.
        let lp = &sharded.plan().stages[0].placement.layers[0];
        for chunk in lp.slots.chunks(lp.slices) {
            assert!(chunk.iter().all(|s| s.tile == chunk[0].tile), "group straddles a chip");
        }
        let x = Tensor::from_vec(
            &[5, 256],
            (0..5 * 256).map(|i| (((i * 11) % 29) as f64) / 14.5 - 1.0).collect(),
        );
        assert_eq!(sharded.infer_batched(&x, 2).data, single.infer_batched(&x, 2).data);
    }

    #[test]
    fn chip_loss_fails_over_to_spare_and_stays_bit_identical() {
        let single = single_chip();
        let fleet = uniform_fleet(4, 8, (64, 64));
        let mut sharded = ShardedModel::compile(tiny_mlp(), &fleet).unwrap();
        assert_eq!(sharded.plan().spares, vec![2, 3]);
        let spec = FleetSpec::default();
        let x = batch(32);
        let faults = [ChipFaultSpec { at_us: 700, chip: 0 }];
        let rep = sharded.run(&x, &spec, &faults).unwrap();
        assert!(rep.conserved());
        assert_eq!(rep.failed(), 0, "failover must not lose a batch");
        assert_eq!(rep.failovers(), 1);
        assert!(rep.events.iter().any(|e| matches!(e.kind, FleetEventKind::ChipFault { chip: 0 })));
        assert!(rep
            .events
            .iter()
            .any(|e| matches!(e.kind, FleetEventKind::Rerun { stage: 0, .. })));
        // The re-replicated stage reprograms from the cached templates on
        // a noise-free engine — outputs stay exact.
        let y = rep.output_tensor().expect("all batches completed");
        assert_eq!(
            y.data,
            single.infer_batched(&x, spec.micro_batch).data,
            "failover must reproduce the lost stage exactly on noise-free engines"
        );
        assert_eq!(sharded.plan().stages[0].chips, vec![2], "stage 0 moved to the spare");
        assert_eq!(sharded.plan().spares, vec![3], "one spare consumed, dead chip not returned");
        assert!(sharded.chip_down()[0]);
        assert_eq!(sharded.spares_left(), 1);
        assert!(sharded.degraded().is_none(), "failover leaves nothing condemned");
        // Failover downtime is visible in the clock.
        assert!(rep.makespan_us > spec.failover_us);
    }

    #[test]
    fn chip_loss_without_spare_serves_degraded() {
        let single = single_chip();
        let fleet = uniform_fleet(2, 8, (64, 64));
        let mut sharded = ShardedModel::compile(tiny_mlp(), &fleet).unwrap();
        assert!(sharded.plan().spares.is_empty());
        let spec = FleetSpec::default();
        let x = batch(32);
        let faults = [ChipFaultSpec { at_us: 700, chip: 0 }];
        let rep = sharded.run(&x, &spec, &faults).unwrap();
        assert!(rep.conserved());
        assert_eq!(rep.failed(), 0, "degraded serving must not lose a batch");
        assert_eq!(rep.failovers(), 0);
        assert!(rep
            .events
            .iter()
            .any(|e| matches!(e.kind, FleetEventKind::Degraded { stage: 0, condemned: 2 })));
        let deg = sharded.degraded().expect("chip loss without spares must degrade");
        assert_eq!(deg.condemned, vec![(0, 0), (0, 1)], "both layer-0 groups died with chip 0");
        assert_eq!(sharded.condemned_per_layer(), vec![2, 0]);
        assert!(rep.degraded_batches() > 0, "post-fault batches are degraded");
        // Batch 0 completed before the fault: still exact. Later batches
        // lost layer 0's contribution and must differ.
        let clean = single.infer_batched(&x, spec.micro_batch);
        let sample_len = 8usize;
        let mb = spec.micro_batch;
        assert_eq!(
            rep.outputs[0].as_deref().unwrap(),
            &clean.data[..mb * sample_len],
            "pre-fault batch must be exact"
        );
        assert_ne!(
            rep.outputs[3].as_deref().unwrap(),
            &clean.data[3 * mb * sample_len..4 * mb * sample_len],
            "post-fault batches must show the condemned groups"
        );
    }

    #[test]
    fn failover_off_degrades_even_with_spares_available() {
        let fleet = uniform_fleet(4, 8, (64, 64));
        let mut sharded = ShardedModel::compile(tiny_mlp(), &fleet).unwrap();
        let spec = FleetSpec { failover: false, ..FleetSpec::default() };
        let x = batch(32);
        let faults = [ChipFaultSpec { at_us: 700, chip: 0 }];
        let rep = sharded.run(&x, &spec, &faults).unwrap();
        assert!(rep.conserved());
        assert_eq!(rep.failovers(), 0);
        assert!(sharded.degraded().is_some());
        assert_eq!(sharded.spares_left(), 2, "spares untouched with failover off");
    }

    #[test]
    fn link_timeout_exhaustion_fails_the_batch_typed() {
        let fleet = uniform_fleet(3, 8, (64, 64));
        let mut sharded = ShardedModel::compile(tiny_mlp(), &fleet).unwrap();
        let spec = FleetSpec {
            link: LinkSpec { drop_rate: 1.0, max_retries: 1, ..LinkSpec::default() },
            ..FleetSpec::default()
        };
        let x = batch(20);
        let rep = sharded.run(&x, &spec, &[]).unwrap();
        assert!(rep.conserved(), "typed link failures must still conserve");
        assert_eq!(rep.completed(), 0, "every batch dies at the stage-1 hop");
        assert_eq!(rep.failed(), 3);
        for (b, o) in rep.outcomes.iter().enumerate() {
            match o {
                BatchOutcome::Failed { error, .. } => assert_eq!(
                    *error,
                    FleetError::LinkFailed { batch: b, stage: 1, attempts: 2 }
                ),
                BatchOutcome::Done { .. } => panic!("batch {b} should have failed"),
            }
        }
        assert_eq!(rep.link_retries(), 6, "two timed-out attempts per batch");
        assert!(rep.events.iter().any(|e| matches!(
            e.kind,
            FleetEventKind::BatchFailed { stage: 1, .. }
        )));
    }

    #[test]
    fn corrupted_transfers_are_detected_and_retransmitted() {
        let single = single_chip();
        let fleet = uniform_fleet(3, 8, (64, 64));
        let mut sharded = ShardedModel::compile(tiny_mlp(), &fleet).unwrap();
        // Heavy in-flight corruption, deep retry budget: essentially every
        // batch gets through on a clean retransmit, and the checksum
        // catches every corrupted copy before it reaches compute.
        let spec = FleetSpec {
            micro_batch: 2,
            link: LinkSpec { corrupt_rate: 0.5, max_retries: 19, ..LinkSpec::default() },
            ..FleetSpec::default()
        };
        let x = batch(24);
        let rep = sharded.run(&x, &spec, &[]).unwrap();
        assert!(rep.conserved());
        assert!(rep.corrupt_detected() > 0, "half the attempts corrupt — some must be seen");
        let clean = single.infer_batched(&x, spec.micro_batch);
        let sample_len = 8usize;
        for (b, out) in rep.outputs.iter().enumerate() {
            if let Some(rows) = out {
                let lo = b * spec.micro_batch * sample_len;
                assert_eq!(
                    rows.as_slice(),
                    &clean.data[lo..lo + rows.len()],
                    "a corrupted payload leaked into compute at batch {b}"
                );
            }
        }
    }

    #[test]
    fn pipeline_throughput_beats_the_single_chip() {
        // The same model, the same service model: one chip serializes the
        // whole per-batch cost; two stages overlap successive batches.
        let spec = FleetSpec::default();
        let x = batch(192);
        let mut single = ShardedModel::compile(
            tiny_mlp(),
            &uniform_fleet(1, 12, (64, 64)),
        )
        .unwrap();
        assert_eq!(single.stage_count(), 1);
        let rep_single = single.run(&x, &spec, &[]).unwrap();
        let mut sharded =
            ShardedModel::compile(tiny_mlp(), &uniform_fleet(2, 8, (64, 64))).unwrap();
        assert_eq!(sharded.stage_count(), 2);
        let rep_fleet = sharded.run(&x, &spec, &[]).unwrap();
        assert!(rep_single.conserved() && rep_fleet.conserved());
        assert!(
            rep_fleet.makespan_us < rep_single.makespan_us,
            "pipeline {} µs must beat single chip {} µs",
            rep_fleet.makespan_us,
            rep_single.makespan_us
        );
        assert!(rep_fleet.images_per_sec() > rep_single.images_per_sec());
        // And both agree bit-for-bit on the outputs.
        let y_fleet = rep_fleet.output_tensor().unwrap().data;
        let y_single = rep_single.output_tensor().unwrap().data;
        assert_eq!(y_fleet, y_single);
    }

    #[test]
    fn fleet_runs_are_deterministic() {
        let fleet = uniform_fleet(4, 8, (64, 64));
        let spec = FleetSpec {
            link: LinkSpec { drop_rate: 0.2, corrupt_rate: 0.2, ..LinkSpec::default() },
            ..FleetSpec::default()
        };
        let x = batch(40);
        let faults = [ChipFaultSpec { at_us: 900, chip: 1 }];
        let mut a = ShardedModel::compile(tiny_mlp(), &fleet).unwrap();
        let mut b = ShardedModel::compile(tiny_mlp(), &fleet).unwrap();
        let ra = a.run(&x, &spec, &faults).unwrap();
        let rb = b.run(&x, &spec, &faults).unwrap();
        assert_eq!(ra, rb, "identical fleets and faults must replay identically");
    }

    #[test]
    fn heterogeneous_split_is_a_clear_error() {
        let mut fleet = uniform_fleet(2, 8, (64, 64));
        fleet[1] = ChipSpec::new(2, 4, (64, 64));
        // A 16-plane layer fits neither chip alone, and the union is
        // heterogeneous — planning must explain, not mangle.
        let mut rng = Pcg64::new(5, 0xF1EE);
        let model =
            Sequential::new(vec![Box::new(LinearMem::new(256, 64, Some(ideal_hw()), &mut rng))
                as Box<dyn crate::nn::Layer>]);
        let err = ShardedModel::compile(model, &fleet).unwrap_err().to_string();
        assert!(err.contains("heterogeneous"), "{err}");
    }

    /// Random layer demands for the planning property tests.
    fn gen_demands(g: &mut crate::util::prop::Gen, apt: usize) -> (Vec<CoreDemand>, usize) {
        let n_layers = g.usize_in(1..=4);
        let mut demands = Vec::new();
        for li in 0..n_layers {
            let slices = g.usize_in(1..=apt.min(4));
            let blocks = g.usize_in(1..=5);
            demands.push(CoreDemand { layer: li, name: "TestCore", blocks, slices });
        }
        (demands, n_layers)
    }

    #[test]
    fn prop_shard_plan_partitions_groups_onto_chips() {
        prop_check("shard plan is a no-straddle partition in layer order", 200, |g| {
            let apt = g.usize_in(4..=16);
            let (demands, n_layers) = gen_demands(g, apt);
            let total_groups: usize = demands.iter().map(|d| d.blocks).sum();
            // Each single-tile chip holds at least one group (slices <=
            // apt), and each closed stage wastes less than one chip, so
            // groups + layers + 2 chips always suffice — with spares.
            let fleet = uniform_fleet(total_groups + n_layers + 2, apt, (64, 64));
            let plan = ShardPlan::plan(&fleet, &demands, n_layers)
                .map_err(|e| format!("plan failed: {e}"))?;
            // Stage layer ranges partition 0..n_layers in order.
            if plan.stages[0].layers.0 != 0 {
                return Err("stage 0 must start at layer 0".into());
            }
            for w in plan.stages.windows(2) {
                if w[0].layers.1 != w[1].layers.0 {
                    return Err("stage layer ranges must be contiguous".into());
                }
            }
            if plan.stages.last().unwrap().layers.1 != n_layers {
                return Err("last stage must end at n_layers".into());
            }
            // Stage chips are disjoint, ascending, and together with the
            // spares cover the fleet exactly.
            let mut seen: Vec<usize> = Vec::new();
            for st in &plan.stages {
                if st.chips.windows(2).any(|w| w[1] != w[0] + 1) {
                    return Err("stage chips must be a contiguous run".into());
                }
                seen.extend(&st.chips);
            }
            seen.extend(&plan.spares);
            let mut sorted = seen.clone();
            sorted.sort_unstable();
            sorted.dedup();
            if sorted.len() != seen.len() || sorted.len() != fleet.len() {
                return Err("stages + spares must cover the fleet exactly once".into());
            }
            // Every demand group lands in exactly one stage, and no group
            // straddles a member chip boundary.
            let placed: Vec<CoreDemand> =
                plan.stages.iter().flat_map(|s| s.demands.clone()).collect();
            if placed != demands {
                return Err("stage demands must concatenate to the model's demands".into());
            }
            for (si, st) in plan.stages.iter().enumerate() {
                let ranges = plan.member_tiles(si);
                for lp in &st.placement.layers {
                    for chunk in lp.slots.chunks(lp.slices) {
                        let tile = chunk[0].tile;
                        if chunk.iter().any(|s| s.tile != tile) {
                            return Err("group straddles a tile".into());
                        }
                        if !ranges.iter().any(|&(a, b)| tile >= a && tile < b) {
                            return Err("group tile outside every member chip".into());
                        }
                    }
                    if lp.layer < st.layers.0 || lp.layer >= st.layers.1 {
                        return Err("placed core outside its stage's layer range".into());
                    }
                }
            }
            // Deterministic: replanning reproduces the plan exactly.
            let plan2 = ShardPlan::plan(&fleet, &demands, n_layers).unwrap();
            if plan2 != plan {
                return Err("planning is not deterministic".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_spare_substitution_preserves_the_bijection() {
        prop_check("substitute re-places a stage without losing a group", 200, |g| {
            let apt = g.usize_in(4..=16);
            let (demands, n_layers) = gen_demands(g, apt);
            let total_groups: usize = demands.iter().map(|d| d.blocks).sum();
            // Enough spares to host any single stage again.
            let fleet =
                uniform_fleet(2 * (total_groups + n_layers + 2), apt, (64, 64));
            let plan = ShardPlan::plan(&fleet, &demands, n_layers)
                .map_err(|e| format!("plan failed: {e}"))?;
            let stage = g.usize_in(0..=plan.stages.len() - 1);
            let mut replaced = None;
            for width in 1..=plan.spares.len() {
                if let Ok(p) = plan.substitute(stage, &plan.spares[..width]) {
                    replaced = Some((p, width));
                    break;
                }
            }
            let Some((p2, width)) = replaced else {
                return Err("ample spares must host the stage".into());
            };
            if p2.stages[stage].chips != plan.spares[..width] {
                return Err("substituted stage must own exactly the used spares".into());
            }
            if p2.stages[stage].placement.total_planes()
                != plan.stages[stage].placement.total_planes()
            {
                return Err("substitution changed the stage's plane count".into());
            }
            if p2.spares != plan.spares[width..] {
                return Err("used spares must leave the pool".into());
            }
            for (si, st) in p2.stages.iter().enumerate() {
                if si != stage && *st != plan.stages[si] {
                    return Err("substitution must not touch other stages".into());
                }
                let ranges = p2.member_tiles(si);
                for lp in &st.placement.layers {
                    for chunk in lp.slots.chunks(lp.slices) {
                        let tile = chunk[0].tile;
                        if chunk.iter().any(|s| s.tile != tile)
                            || !ranges.iter().any(|&(a, b)| tile >= a && tile < b)
                        {
                            return Err("substituted group straddles a chip".into());
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn empty_fleet_and_undersized_fleet_are_clear_errors() {
        let err = ShardedModel::compile(tiny_mlp(), &[]).unwrap_err().to_string();
        assert!(err.contains("empty fleet"), "{err}");
        let err = ShardedModel::compile(tiny_mlp(), &uniform_fleet(1, 4, (64, 64)))
            .unwrap_err()
            .to_string();
        assert!(err.contains("fleet exhausted"), "{err}");
    }
}

//! Chip-level architecture: tiles of physical crossbar arrays and the
//! placement of whole networks onto them.
//!
//! The DPE ([`crate::dpe`]) models one array pair at a time; hierarchical
//! simulators (IMAC-Sim's partitioned banks, per-array noise statistics in
//! crossbar-emulation work) show why *placement* must be first-class:
//! which physical array a weight block lands on determines its noise,
//! fault, and ADC-mismatch streams. This module provides that layer:
//!
//! - [`ChipSpec`] — the physical hierarchy: `tiles × arrays_per_tile`
//!   arrays of a fixed shape (TOML `[chip]` section, see
//!   [`crate::coordinator::SimConfig`]);
//! - [`ArraySlot`] — one physical array position `(tile, index)`;
//! - [`TileAllocator`] — greedy bin-packing of each layer's weight block
//!   grid onto tiles: every `(k-block, n-block, slice)` digit plane gets a
//!   concrete slot; a block's `S_w` planes stay within one tile (they
//!   share input drivers), spilling the whole group to the next tile when
//!   the current one is full; exhausting the chip is an [`anyhow`] error
//!   carrying a capacity report;
//! - [`Placement`] — the allocation result: per-layer slot lists, the
//!   per-block *stream ids* that key the engine's programming-noise /
//!   fault / ADC-chain draws to physical arrays
//!   ([`crate::dpe::DotProductEngine::prepare_weights_mapped`]), and
//!   per-tile utilization;
//! - [`MappedModel`] ([`mapped`]) — a compiled, forward-only inference
//!   runtime produced by [`crate::nn::Sequential::compile`].
//!
//! **Stream semantics.** A slot's global id
//! (`tile · arrays_per_tile + index`) is the RNG stream of the array that
//! occupies it. An unmapped [`crate::nn::Sequential`] uses the same
//! derivation on a *virtual* unbounded tile packed in layer order, so a
//! chip with a single tile large enough for the whole model — where the
//! greedy allocator reproduces exactly that packing — programs every
//! array bit-identically to the unmapped path (the bit-identity anchor,
//! asserted in `benches/fig17_inference.rs`). Any placement that differs
//! (spill to another tile, different layer order) resamples the affected
//! arrays' noise.

pub mod fleet;
pub mod mapped;
pub mod repair;
pub mod serve;

pub use fleet::{
    uniform_fleet, union_chip, BatchOutcome, ChipFaultSpec, FleetError, FleetEvent,
    FleetEventKind, FleetReport, FleetSpec, LinkSpec, ShardPlan, ShardedModel, StagePlan,
};
pub use mapped::MappedModel;
pub use repair::{BlockMove, DegradedReport, HealthReport, RepairOutcome, RepairPlan, SlotHealth};
pub use serve::{
    BatchRecord, Completion, Event, EventKind, FaultEvent, HealRecord, MixedFactory, Outcome,
    ReplicaFactory, ReplicaModel, ReplicaSpec, Request, ServeError, ServeReport, ServingRuntime,
    ServingSpec, SimClock,
};

use std::fmt::Write as _;

/// Physical chip geometry: `tiles × arrays_per_tile` crossbar arrays, all
/// of shape `array` (rows × cols of devices).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChipSpec {
    pub tiles: usize,
    pub arrays_per_tile: usize,
    /// Array shape `(rows, cols)`; every engine bound to mapped layers
    /// must use the same shape.
    pub array: (usize, usize),
    /// Arrays at the *tail* of each tile reserved as repair spares (TOML
    /// `[chip] spares_per_tile`): the allocator never places data planes
    /// there, so slot ids of data placements are unchanged by the spare
    /// budget, and [`repair::RepairPlan`] can migrate condemned block
    /// groups into them. 0 (the default) reproduces the pre-spare chip
    /// bit-identically.
    pub spares_per_tile: usize,
}

impl ChipSpec {
    pub fn new(tiles: usize, arrays_per_tile: usize, array: (usize, usize)) -> Self {
        assert!(tiles > 0 && arrays_per_tile > 0, "chip needs at least one array");
        assert!(array.0 > 0 && array.1 > 0, "array shape must be positive");
        ChipSpec { tiles, arrays_per_tile, array, spares_per_tile: 0 }
    }

    /// Reserve `spares` tail arrays per tile as repair spares.
    pub fn with_spares(mut self, spares: usize) -> Self {
        assert!(
            spares < self.arrays_per_tile,
            "spares_per_tile = {spares} leaves no data arrays in a {}-array tile",
            self.arrays_per_tile
        );
        self.spares_per_tile = spares;
        self
    }

    /// One tile holding `capacity` arrays — the whole-model anchor chip.
    pub fn single_tile(capacity: usize, array: (usize, usize)) -> Self {
        ChipSpec::new(1, capacity.max(1), array)
    }

    /// A chip of `arrays_per_tile`-array tiles sized to hold at least
    /// `total_planes` arrays.
    pub fn fit(total_planes: usize, arrays_per_tile: usize, array: (usize, usize)) -> Self {
        let tiles = total_planes.div_ceil(arrays_per_tile.max(1)).max(1);
        ChipSpec::new(tiles, arrays_per_tile.max(1), array)
    }

    pub fn total_arrays(&self) -> usize {
        self.tiles * self.arrays_per_tile
    }

    /// Arrays per tile available to data placements (capacity minus the
    /// spare reservation).
    pub fn data_arrays_per_tile(&self) -> usize {
        self.arrays_per_tile - self.spares_per_tile
    }

    /// The spare slots of one tile: the reserved tail indices
    /// `[data_arrays_per_tile, arrays_per_tile)`.
    pub fn spare_slots(&self, tile: usize) -> impl Iterator<Item = ArraySlot> + '_ {
        (self.data_arrays_per_tile()..self.arrays_per_tile)
            .map(move |index| ArraySlot { tile, index })
    }

    /// Global id of a slot — also the RNG stream of the array occupying it.
    pub fn slot_id(&self, slot: ArraySlot) -> u64 {
        (slot.tile * self.arrays_per_tile + slot.index) as u64
    }
}

/// One physical array position on the chip.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArraySlot {
    pub tile: usize,
    pub index: usize,
}

/// One hardware core's placement demand: the layer's weight block grid
/// (`blocks` array pairs of `slices` digit planes each), in model order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CoreDemand {
    pub layer: usize,
    pub name: &'static str,
    /// `(k-block, n-block)` pairs in the weight grid.
    pub blocks: usize,
    /// Digit planes per block — the weight slice method's slice count.
    pub slices: usize,
}

impl CoreDemand {
    pub fn planes(&self) -> usize {
        self.blocks * self.slices
    }
}

/// One core's resolved placement.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerPlacement {
    pub layer: usize,
    pub name: &'static str,
    pub blocks: usize,
    pub slices: usize,
    /// Global slot id of each block's first plane — the per-block
    /// programming streams handed to
    /// [`crate::dpe::DotProductEngine::prepare_weights_mapped`].
    pub block_streams: Vec<u64>,
    /// Every digit plane's slot, block-major then slice-major — the order
    /// the engine programs them in.
    pub slots: Vec<ArraySlot>,
    pub tile_first: usize,
    pub tile_last: usize,
}

impl LayerPlacement {
    pub fn planes(&self) -> usize {
        self.blocks * self.slices
    }
}

/// The full chip allocation: per-core placements (model order) plus
/// per-tile occupancy.
#[derive(Debug, Clone, PartialEq)]
pub struct Placement {
    pub chip: ChipSpec,
    pub layers: Vec<LayerPlacement>,
    /// Arrays allocated per tile (may fall short of `arrays_per_tile` when
    /// a block group spilled past the tile's tail).
    pub used_per_tile: Vec<usize>,
}

impl Placement {
    pub fn total_planes(&self) -> usize {
        self.layers.iter().map(LayerPlacement::planes).sum()
    }

    pub fn tiles_used(&self) -> usize {
        self.used_per_tile.iter().filter(|&&u| u > 0).count()
    }

    /// Human-readable placement + utilization report (the CLI/example
    /// view; experiments emit the same data as `Table`s).
    pub fn report(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "chip: {} tiles x {} arrays of {}x{} ({} slots), {} used",
            self.chip.tiles,
            self.chip.arrays_per_tile,
            self.chip.array.0,
            self.chip.array.1,
            self.chip.total_arrays(),
            self.total_planes(),
        );
        for (t, &used) in self.used_per_tile.iter().enumerate() {
            let cap = self.chip.arrays_per_tile;
            let _ = writeln!(
                s,
                "  tile {t:>3}: {used:>4}/{cap} arrays ({:>5.1}%)",
                100.0 * used as f64 / cap as f64
            );
        }
        for lp in &self.layers {
            let _ = writeln!(
                s,
                "  layer {:>3} {:<12} {:>3} blocks x {} slices = {:>4} arrays  tiles {}..={}",
                lp.layer,
                lp.name,
                lp.blocks,
                lp.slices,
                lp.planes(),
                lp.tile_first,
                lp.tile_last,
            );
        }
        s
    }
}

/// Greedy layer-order tile allocator (see module docs).
pub struct TileAllocator {
    chip: ChipSpec,
    next_tile: usize,
    next_index: usize,
    used_per_tile: Vec<usize>,
}

impl TileAllocator {
    pub fn new(chip: ChipSpec) -> Self {
        let used_per_tile = vec![0; chip.tiles];
        TileAllocator { chip, next_tile: 0, next_index: 0, used_per_tile }
    }

    /// Allocate one block group of `slices` consecutive planes within a
    /// single tile, spilling the whole group to the next tile when the
    /// current one cannot hold it. `Err` carries the failure site; the
    /// driver ([`TileAllocator::allocate`]) wraps it in a capacity report.
    fn alloc_group(&mut self, slices: usize) -> Result<Vec<ArraySlot>, String> {
        assert!(slices > 0, "a block group has at least one plane");
        // Data placements only see the tile capacity left after the spare
        // reservation; the reserved tail indices belong to `arch::repair`.
        let data_cap = self.chip.data_arrays_per_tile();
        if slices > data_cap {
            return Err(format!(
                "a block group of {slices} digit planes cannot fit any tile \
                 (arrays_per_tile = {}, spares_per_tile = {})",
                self.chip.arrays_per_tile, self.chip.spares_per_tile
            ));
        }
        if data_cap - self.next_index < slices {
            // Spill: the group does not straddle tiles.
            self.next_tile += 1;
            self.next_index = 0;
        }
        if self.next_tile >= self.chip.tiles {
            return Err(format!(
                "no tile left for a group of {slices} planes (chip has {} tiles x {} arrays)",
                self.chip.tiles, self.chip.arrays_per_tile
            ));
        }
        let tile = self.next_tile;
        let group: Vec<ArraySlot> =
            (0..slices).map(|s| ArraySlot { tile, index: self.next_index + s }).collect();
        self.next_index += slices;
        self.used_per_tile[tile] += slices;
        if self.next_index == data_cap {
            self.next_tile += 1;
            self.next_index = 0;
        }
        Ok(group)
    }

    /// Place every demand (model order) onto the chip. Deterministic: the
    /// same demands on the same chip always yield the same placement.
    pub fn allocate(chip: &ChipSpec, demands: &[CoreDemand]) -> anyhow::Result<Placement> {
        let mut alloc = TileAllocator::new(chip.clone());
        let mut layers = Vec::with_capacity(demands.len());
        for d in demands {
            let mut block_streams = Vec::with_capacity(d.blocks);
            let mut slots = Vec::with_capacity(d.planes());
            let (mut tile_first, mut tile_last) = (usize::MAX, 0usize);
            for _ in 0..d.blocks {
                let group = alloc.alloc_group(d.slices).map_err(|site| {
                    anyhow::anyhow!(
                        "chip capacity exceeded at layer {} ({}): {site}\n{}",
                        d.layer,
                        d.name,
                        capacity_report(chip, demands, alloc.used_per_tile.iter().sum())
                    )
                })?;
                tile_first = tile_first.min(group[0].tile);
                tile_last = tile_last.max(group[group.len() - 1].tile);
                block_streams.push(chip.slot_id(group[0]));
                slots.extend(group);
            }
            layers.push(LayerPlacement {
                layer: d.layer,
                name: d.name,
                blocks: d.blocks,
                slices: d.slices,
                block_streams,
                slots,
                tile_first,
                tile_last,
            });
        }
        Ok(Placement { chip: chip.clone(), layers, used_per_tile: alloc.used_per_tile })
    }
}

/// The capacity report attached to allocation failures: total demand vs
/// chip size, broken down per layer.
fn capacity_report(chip: &ChipSpec, demands: &[CoreDemand], allocated: usize) -> String {
    let total: usize = demands.iter().map(CoreDemand::planes).sum();
    let mut s = format!(
        "  chip: {} tiles x {} arrays = {} slots; demand {} arrays ({} placed before failing)\n",
        chip.tiles,
        chip.arrays_per_tile,
        chip.total_arrays(),
        total,
        allocated,
    );
    for d in demands {
        let _ = writeln!(
            s,
            "  layer {:>3} ({}): {} blocks x {} slices = {} arrays",
            d.layer,
            d.name,
            d.blocks,
            d.slices,
            d.planes()
        );
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    fn demand(layer: usize, blocks: usize, slices: usize) -> CoreDemand {
        CoreDemand { layer, name: "TestCore", blocks, slices }
    }

    #[test]
    fn single_tile_packs_contiguously_in_layer_order() {
        // The anchor property: one sufficient tile yields global slot ids
        // 0..N in demand order — the virtual packing the unmapped
        // Sequential path derives its streams from.
        let chip = ChipSpec::single_tile(64, (64, 64));
        let demands = vec![demand(0, 3, 4), demand(1, 2, 5), demand(2, 1, 2)];
        let p = TileAllocator::allocate(&chip, &demands).unwrap();
        let mut next = 0u64;
        for lp in &p.layers {
            for (b, &stream) in lp.block_streams.iter().enumerate() {
                assert_eq!(stream, next + (b * lp.slices) as u64);
            }
            for (i, &slot) in lp.slots.iter().enumerate() {
                assert_eq!(chip.slot_id(slot), next + i as u64);
            }
            next += lp.planes() as u64;
        }
        assert_eq!(p.total_planes(), 24);
        assert_eq!(p.used_per_tile, vec![24]);
        assert!(p.report().contains("tile   0"));
    }

    #[test]
    fn groups_never_straddle_tiles() {
        // 10-array tiles, 4-plane groups: each tile takes 2 groups (8
        // slots) and wastes 2.
        let chip = ChipSpec::new(3, 10, (64, 64));
        let p = TileAllocator::allocate(&chip, &[demand(0, 5, 4)]).unwrap();
        for chunk in p.layers[0].slots.chunks(4) {
            let tile = chunk[0].tile;
            assert!(chunk.iter().all(|s| s.tile == tile), "group split across tiles");
        }
        assert_eq!(p.used_per_tile, vec![8, 8, 4]);
        assert_eq!(p.layers[0].tile_first, 0);
        assert_eq!(p.layers[0].tile_last, 2);
    }

    #[test]
    fn capacity_error_carries_report() {
        let chip = ChipSpec::new(1, 6, (64, 64));
        let err = TileAllocator::allocate(&chip, &[demand(0, 1, 4), demand(1, 1, 4)])
            .unwrap_err()
            .to_string();
        assert!(err.contains("chip capacity exceeded"), "{err}");
        assert!(err.contains("layer 1"), "{err}");
        let dbg = format!(
            "{:?}",
            TileAllocator::allocate(&chip, &[demand(0, 1, 4), demand(1, 1, 4)]).unwrap_err()
        );
        assert!(dbg.contains("demand 8 arrays"), "{dbg}");
    }

    #[test]
    fn oversized_group_is_an_error() {
        let chip = ChipSpec::new(4, 3, (64, 64));
        let err =
            TileAllocator::allocate(&chip, &[demand(0, 1, 4)]).unwrap_err().to_string();
        assert!(err.contains("cannot fit any tile"), "{err}");
    }

    #[test]
    fn allocator_properties() {
        prop_check("tile allocation is a bijection planes -> slots", 300, |g| {
            let apt = g.usize_in(4..=32);
            let n_layers = g.usize_in(1..=6);
            let demands: Vec<CoreDemand> = (0..n_layers)
                .map(|li| demand(li, g.usize_in(1..=5), g.usize_in(1..=apt.min(6))))
                .collect();
            let total: usize = demands.iter().map(CoreDemand::planes).sum();
            // Worst case wastes < slices per group; 2x slack always fits.
            let chip = ChipSpec::fit(2 * total, apt, (64, 64));
            let p = TileAllocator::allocate(&chip, &demands)
                .map_err(|e| format!("unexpected capacity error: {e}"))?;
            // Every plane got exactly one slot; ids are unique and strictly
            // increasing (deterministic greedy spill order).
            let mut ids: Vec<u64> = Vec::new();
            for (lp, d) in p.layers.iter().zip(&demands) {
                if lp.planes() != d.planes() || lp.slots.len() != d.planes() {
                    return Err(format!("layer {} plane/slot count mismatch", d.layer));
                }
                for (b, chunk) in lp.slots.chunks(d.slices).enumerate() {
                    if chunk.iter().any(|s| s.tile != chunk[0].tile) {
                        return Err("group straddles tiles".into());
                    }
                    if p.chip.slot_id(chunk[0]) != lp.block_streams[b] {
                        return Err("block stream != first plane slot id".into());
                    }
                }
                ids.extend(lp.slots.iter().map(|&s| p.chip.slot_id(s)));
            }
            if ids.len() != total {
                return Err(format!("{} slots for {} planes", ids.len(), total));
            }
            if !ids.windows(2).all(|w| w[0] < w[1]) {
                return Err("slot ids not strictly increasing".into());
            }
            // Tile occupancy is consistent and bounded.
            if p.used_per_tile.iter().sum::<usize>() != total {
                return Err("per-tile usage does not sum to demand".into());
            }
            if p.used_per_tile.iter().any(|&u| u > apt) {
                return Err("tile over capacity".into());
            }
            // Determinism: a second run reproduces the placement exactly.
            let p2 = TileAllocator::allocate(&chip, &demands).unwrap();
            if p2 != p {
                return Err("allocation not deterministic".into());
            }
            Ok(())
        });
    }

    #[test]
    fn fit_sizes_chip_to_demand() {
        let c = ChipSpec::fit(130, 64, (64, 64));
        assert_eq!(c.tiles, 3);
        assert_eq!(c.total_arrays(), 192);
        assert_eq!(ChipSpec::fit(0, 64, (64, 64)).tiles, 1);
    }

    #[test]
    fn spares_reserve_tail_slots_and_keep_data_ids_stable() {
        // 10-array tiles with 2 spares: data placements only use indices
        // 0..8 of each tile; the same demands on a spare-free chip land on
        // identical slots (so enabling spares never perturbs placements
        // that fit either way), and the reserved tail is enumerable.
        let base = ChipSpec::new(3, 10, (64, 64));
        let spared = base.clone().with_spares(2);
        assert_eq!(spared.data_arrays_per_tile(), 8);
        let demands = [demand(0, 3, 4), demand(1, 2, 4)];
        let p = TileAllocator::allocate(&spared, &demands).unwrap();
        for lp in &p.layers {
            for slot in &lp.slots {
                assert!(slot.index < 8, "data plane placed on a spare slot: {slot:?}");
            }
        }
        let p_base = TileAllocator::allocate(&base, &demands).unwrap();
        for (lp, lp_base) in p.layers.iter().zip(&p_base.layers) {
            assert_eq!(lp.slots, lp_base.slots, "spare budget perturbed data placement");
            assert_eq!(lp.block_streams, lp_base.block_streams);
        }
        let tail: Vec<ArraySlot> = spared.spare_slots(1).collect();
        assert_eq!(tail, vec![ArraySlot { tile: 1, index: 8 }, ArraySlot { tile: 1, index: 9 }]);
        assert_eq!(spared.slot_id(tail[0]), 18);
    }

    #[test]
    fn spares_shrink_effective_capacity() {
        // 6-array tile, 3 spares: a 4-plane group no longer fits any tile.
        let chip = ChipSpec::new(2, 6, (64, 64)).with_spares(3);
        let err =
            TileAllocator::allocate(&chip, &[demand(0, 1, 4)]).unwrap_err().to_string();
        assert!(err.contains("cannot fit any tile"), "{err}");
        assert!(err.contains("spares_per_tile = 3"), "{err}");
        // 3-plane groups fit exactly, one per tile.
        let p = TileAllocator::allocate(&chip, &[demand(0, 2, 3)]).unwrap();
        assert_eq!(p.used_per_tile, vec![3, 3]);
        assert_eq!(p.layers[0].slots[3].tile, 1);
    }

    #[test]
    #[should_panic(expected = "leaves no data arrays")]
    fn all_spare_tile_panics() {
        let _ = ChipSpec::new(1, 4, (64, 64)).with_spares(4);
    }
}

//! PJRT runtime: loads AOT-compiled HLO-text artifacts (produced by
//! `python/compile/aot.py`, L2/L1) and executes them from the Rust hot path.
//!
//! Interchange format is HLO **text** — jax ≥ 0.5 emits serialized protos
//! with 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Executables are compiled once per artifact and cached; Python never runs
//! at request time.
//!
//! # Feature gate
//!
//! The real PJRT client lives behind the `xla` cargo feature because the
//! external `xla` crate (and its libxla runtime) is not available in
//! offline build environments. Without the feature this module compiles a
//! **stub** whose constructor returns an error and whose surface matches
//! the real one **except `Runtime::load`**, which only exists with the
//! feature (its `Arc<xla::PjRtLoadedExecutable>` return type cannot be
//! mirrored without the crate) — write feature-portable callers against
//! `execute_f32`/`execute_matrices` instead. Every caller already handles
//! `Runtime::cpu` failing (the CLI prints "PJRT unavailable", the
//! coordinator and benches fall back to the native engine), so the rest
//! of the framework is unaffected.

pub mod xla_dpe;

pub use xla_dpe::XlaDpe;

use anyhow::Result;
use std::path::{Path, PathBuf};

/// `<name>.hlo.txt` under the artifacts dir — the single source of truth
/// for the artifact naming scheme, shared by the real and stub runtimes.
fn artifact_file(dir: &Path, name: &str) -> PathBuf {
    dir.join(format!("{name}.hlo.txt"))
}

#[cfg(feature = "xla")]
mod pjrt {
    use super::*;
    use crate::tensor::Matrix;
    use anyhow::Context;
    use std::collections::HashMap;
    use std::sync::{Arc, Mutex};

    /// A PJRT CPU client plus a cache of compiled executables keyed by
    /// artifact name.
    pub struct Runtime {
        client: xla::PjRtClient,
        artifacts_dir: PathBuf,
        cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    }

    impl std::fmt::Debug for Runtime {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "Runtime(dir={:?})", self.artifacts_dir)
        }
    }

    impl Runtime {
        /// Create a CPU PJRT client rooted at an artifacts directory.
        pub fn cpu(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Runtime {
                client,
                artifacts_dir: artifacts_dir.as_ref().to_path_buf(),
                cache: Mutex::new(HashMap::new()),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Path of a named artifact (see [`super::artifact_file`]).
        pub fn artifact_path(&self, name: &str) -> PathBuf {
            super::artifact_file(&self.artifacts_dir, name)
        }

        /// Whether the artifact exists on disk.
        pub fn has_artifact(&self, name: &str) -> bool {
            self.artifact_path(name).exists()
        }

        /// Load + compile (cached) an artifact.
        pub fn load(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
            if let Some(exe) = self.cache.lock().unwrap().get(name) {
                return Ok(exe.clone());
            }
            let path = self.artifact_path(name);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = Arc::new(
                self.client
                    .compile(&comp)
                    .with_context(|| format!("compiling artifact '{name}'"))?,
            );
            self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
            Ok(exe)
        }

        /// Execute an artifact on f32 buffers. Each input is `(shape, data)`;
        /// returns every output as `(shape, data)`. The artifact must have
        /// been lowered with `return_tuple=True`.
        pub fn execute_f32(
            &self,
            name: &str,
            inputs: &[(&[usize], &[f32])],
        ) -> Result<Vec<Vec<f32>>> {
            let exe = self.load(name)?;
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|(shape, data)| {
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(data)
                        .reshape(&dims)
                        .with_context(|| format!("reshaping input to {dims:?}"))
                })
                .collect::<Result<_>>()?;
            let mut result = exe
                .execute::<xla::Literal>(&literals)
                .with_context(|| format!("executing '{name}'"))?[0][0]
                .to_literal_sync()?;
            let tuple = result.decompose_tuple()?;
            tuple
                .into_iter()
                .map(|lit| lit.to_vec::<f32>().context("reading f32 output"))
                .collect()
        }

        /// Execute with `Matrix` (f64) operands, converting to f32 at the
        /// boundary (the artifacts are compiled for f32).
        pub fn execute_matrices(&self, name: &str, inputs: &[&Matrix]) -> Result<Vec<Vec<f32>>> {
            let f32_bufs: Vec<(Vec<usize>, Vec<f32>)> = inputs
                .iter()
                .map(|m| {
                    (vec![m.rows, m.cols], m.data.iter().map(|&x| x as f32).collect::<Vec<f32>>())
                })
                .collect();
            let refs: Vec<(&[usize], &[f32])> =
                f32_bufs.iter().map(|(s, d)| (s.as_slice(), d.as_slice())).collect();
            self.execute_f32(name, &refs)
        }

        /// Number of cached executables (for tests/metrics).
        pub fn cached_count(&self) -> usize {
            self.cache.lock().unwrap().len()
        }
    }
}

#[cfg(not(feature = "xla"))]
mod pjrt {
    use super::*;
    use crate::tensor::Matrix;

    /// Stub runtime compiled when the `xla` feature is off: the constructor
    /// fails, so every caller takes its native-engine fallback path.
    #[derive(Debug)]
    pub struct Runtime {
        artifacts_dir: PathBuf,
    }

    impl Runtime {
        /// Always fails: the crate was built without the `xla` feature.
        pub fn cpu(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
            let _ = artifacts_dir.as_ref();
            anyhow::bail!(
                "PJRT runtime unavailable: memintelli was built without the `xla` feature"
            )
        }

        pub fn platform(&self) -> String {
            "unavailable (built without `xla` feature)".to_string()
        }

        /// Path of a named artifact (see [`super::artifact_file`]).
        pub fn artifact_path(&self, name: &str) -> PathBuf {
            super::artifact_file(&self.artifacts_dir, name)
        }

        /// Whether the artifact exists on disk.
        pub fn has_artifact(&self, name: &str) -> bool {
            self.artifact_path(name).exists()
        }

        /// Always fails (stub).
        pub fn execute_f32(
            &self,
            name: &str,
            inputs: &[(&[usize], &[f32])],
        ) -> Result<Vec<Vec<f32>>> {
            let _ = inputs;
            anyhow::bail!("cannot execute '{name}': built without the `xla` feature")
        }

        /// Always fails (stub).
        pub fn execute_matrices(&self, name: &str, inputs: &[&Matrix]) -> Result<Vec<Vec<f32>>> {
            let _ = inputs;
            anyhow::bail!("cannot execute '{name}': built without the `xla` feature")
        }

        /// Number of cached executables (always zero for the stub).
        pub fn cached_count(&self) -> usize {
            0
        }
    }
}

pub use pjrt::Runtime;

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        // Tests run from the workspace root.
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[cfg(feature = "xla")]
    #[test]
    fn smoke_artifact_roundtrip() {
        use crate::tensor::Matrix;
        let dir = artifacts_dir();
        if !dir.join("_smoke.hlo.txt").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let rt = Runtime::cpu(&dir).unwrap();
        assert!(rt.has_artifact("_smoke"));
        let x = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let y = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let out = rt.execute_matrices("_smoke", &[&x, &y]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0], vec![5.0, 5.0, 9.0, 9.0]);
        // Second call hits the cache.
        let _ = rt.execute_matrices("_smoke", &[&x, &y]).unwrap();
        assert_eq!(rt.cached_count(), 1);
    }

    #[cfg(feature = "xla")]
    #[test]
    fn missing_artifact_is_error() {
        let rt = Runtime::cpu(artifacts_dir()).unwrap();
        assert!(!rt.has_artifact("definitely_missing"));
        assert!(rt.load("definitely_missing").is_err());
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_constructor_reports_unavailable() {
        let err = Runtime::cpu(artifacts_dir()).unwrap_err();
        assert!(format!("{err}").contains("xla"), "unexpected error: {err}");
    }
}

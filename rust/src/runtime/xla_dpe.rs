//! XLA backend for the DPE: executes the AOT-compiled Pallas/JAX DPE
//! matmul artifacts (`dpe_mm_*.hlo.txt`) and the fused LeNet-5 forward
//! (`lenet_fwd_*.hlo.txt`) from the Rust hot path.
//!
//! The artifact set is shape-specialized (HLO is static-shape); callers ask
//! [`XlaDpe::supports`] first and fall back to the native engine otherwise —
//! the coordinator's routing policy.
//!
//! Like [`super::Runtime`], the execution methods are real only with the
//! `xla` cargo feature; the stub build keeps the same signatures but can
//! never be reached because the stub `Runtime::cpu` constructor fails.

use super::Runtime;
use crate::tensor::Matrix;
use anyhow::Result;

/// Named DPE artifact formats (must match `python/compile/aot.py`).
pub const FORMATS: &[&str] = &["int4", "int8", "fp16", "bf16", "fp32", "flex16"];

/// XLA-backed DPE matmul executor.
#[derive(Debug)]
pub struct XlaDpe {
    rt: Runtime,
}

impl XlaDpe {
    pub fn new(rt: Runtime) -> Self {
        XlaDpe { rt }
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// Artifact name for a matmul shape + format.
    pub fn mm_name(m: usize, k: usize, n: usize, fmt: &str, ideal: bool) -> String {
        let suffix = if ideal { "_ideal" } else { "" };
        format!("dpe_mm_{m}x{k}x{n}_{fmt}{suffix}")
    }

    /// Does a compiled artifact exist for this shape/format?
    pub fn supports(&self, m: usize, k: usize, n: usize, fmt: &str, ideal: bool) -> bool {
        self.rt.has_artifact(&Self::mm_name(m, k, n, fmt, ideal))
    }

    /// Execute the DPE matmul artifact. `seed` drives the in-graph
    /// threefry programming-noise sampling (ignored by `_ideal` variants).
    #[cfg(feature = "xla")]
    pub fn matmul(
        &self,
        a: &Matrix,
        b: &Matrix,
        fmt: &str,
        ideal: bool,
        seed: u32,
    ) -> Result<Matrix> {
        let (m, k, n) = (a.rows, a.cols, b.cols);
        anyhow::ensure!(a.cols == b.rows, "matmul dim mismatch");
        let name = Self::mm_name(m, k, n, fmt, ideal);
        let a32: Vec<f32> = a.data.iter().map(|&x| x as f32).collect();
        let b32: Vec<f32> = b.data.iter().map(|&x| x as f32).collect();
        let key = [0u32, seed];
        let exe = self.rt.load(&name)?;
        let lit_a = xla::Literal::vec1(&a32).reshape(&[m as i64, k as i64])?;
        let lit_b = xla::Literal::vec1(&b32).reshape(&[k as i64, n as i64])?;
        let lit_key = xla::Literal::vec1(&key);
        let mut result = exe.execute::<xla::Literal>(&[lit_a, lit_b, lit_key])?[0][0]
            .to_literal_sync()?;
        let out = result.decompose_tuple()?;
        anyhow::ensure!(out.len() == 1, "expected 1 output, got {}", out.len());
        let data32 = out.into_iter().next().unwrap().to_vec::<f32>()?;
        Ok(Matrix::from_vec(m, n, data32.into_iter().map(|x| x as f64).collect()))
    }

    /// Stub: unreachable because the stub `Runtime` cannot be constructed.
    #[cfg(not(feature = "xla"))]
    pub fn matmul(
        &self,
        a: &Matrix,
        b: &Matrix,
        fmt: &str,
        ideal: bool,
        seed: u32,
    ) -> Result<Matrix> {
        let _ = (a, b, ideal, seed);
        anyhow::bail!("cannot run '{fmt}' artifact: built without the `xla` feature")
    }

    /// Execute a fused LeNet-5 forward artifact: `x` is `(batch, 784)`
    /// row-major, `params` are the 10 parameter buffers in `lenet_fwd`
    /// order. Returns `(batch, 10)` logits.
    #[cfg(feature = "xla")]
    pub fn lenet_forward(
        &self,
        batch: usize,
        fmt: &str,
        ideal: bool,
        x: &[f32],
        params: &[(Vec<usize>, Vec<f32>)],
        seed: u32,
    ) -> Result<Matrix> {
        anyhow::ensure!(x.len() == batch * 784, "bad input length");
        anyhow::ensure!(params.len() == 10, "lenet has 10 parameter buffers");
        let suffix = if ideal { "_ideal" } else { "" };
        let name = format!("lenet_fwd_b{batch}_{fmt}{suffix}");
        let exe = self.rt.load(&name)?;
        let mut literals = Vec::with_capacity(12);
        literals.push(
            xla::Literal::vec1(x).reshape(&[batch as i64, 1, 28, 28])?,
        );
        literals.push(xla::Literal::vec1(&[0u32, seed]));
        for (shape, data) in params {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(data).reshape(&dims)?);
        }
        let mut result =
            exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let out = result.decompose_tuple()?;
        let logits = out.into_iter().next().unwrap().to_vec::<f32>()?;
        Ok(Matrix::from_vec(batch, 10, logits.into_iter().map(|v| v as f64).collect()))
    }

    /// Stub: unreachable because the stub `Runtime` cannot be constructed.
    #[cfg(not(feature = "xla"))]
    pub fn lenet_forward(
        &self,
        batch: usize,
        fmt: &str,
        ideal: bool,
        x: &[f32],
        params: &[(Vec<usize>, Vec<f32>)],
        seed: u32,
    ) -> Result<Matrix> {
        let _ = (batch, ideal, x, params, seed);
        anyhow::bail!("cannot run '{fmt}' artifact: built without the `xla` feature")
    }
}

#[cfg(all(test, feature = "xla"))]
mod tests {
    use super::*;
    use crate::dpe::{DotProductEngine, SliceMethod, SliceSpec};
    use crate::util::rng::Pcg64;
    use std::path::PathBuf;

    fn xla_dpe() -> Option<XlaDpe> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("dpe_mm_128x128x128_int8_ideal.hlo.txt").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(XlaDpe::new(Runtime::cpu(dir).unwrap()))
    }

    #[test]
    fn xla_ideal_matches_native_ideal() {
        // Backend cross-validation: the AOT Pallas path and the native Rust
        // path implement the same noise-free sliced arithmetic.
        let Some(dpe) = xla_dpe() else { return };
        let mut rng = Pcg64::seeded(101);
        let a = Matrix::random_normal(128, 128, 0.0, 1.0, &mut rng);
        let b = Matrix::random_normal(128, 128, 0.0, 1.0, &mut rng);
        let xla_out = dpe.matmul(&a, &b, "int8", true, 0).unwrap();
        let native = DotProductEngine::ideal((64, 64)).matmul(
            &a,
            &b,
            &SliceMethod::int(SliceSpec::int8()),
            &SliceMethod::int(SliceSpec::int8()),
        );
        let ideal = a.matmul(&b);
        let re_x = xla_out.relative_error(&ideal);
        let re_n = native.relative_error(&ideal);
        // Both are INT8-quantized products of the same data.
        assert!(re_x < 0.02, "xla re={re_x}");
        assert!(re_n < 0.02, "native re={re_n}");
        // And they agree with each other far more closely than with ideal
        // (identical algorithm, f32-vs-f64 rounding differences only).
        let cross = xla_out.relative_error(&native);
        assert!(cross < re_x.max(re_n) * 0.5, "cross={cross} re_x={re_x}");
    }

    #[test]
    fn xla_noisy_differs_by_seed() {
        let Some(dpe) = xla_dpe() else { return };
        let mut rng = Pcg64::seeded(102);
        let a = Matrix::random_normal(128, 128, 0.0, 1.0, &mut rng);
        let b = Matrix::random_normal(128, 128, 0.0, 1.0, &mut rng);
        let o1 = dpe.matmul(&a, &b, "int8", false, 1).unwrap();
        let o2 = dpe.matmul(&a, &b, "int8", false, 2).unwrap();
        let o1b = dpe.matmul(&a, &b, "int8", false, 1).unwrap();
        assert_ne!(o1.data, o2.data, "different seeds must differ");
        assert_eq!(o1.data, o1b.data, "same seed must reproduce");
        let ideal = a.matmul(&b);
        assert!(o1.relative_error(&ideal) < 0.2);
    }

    #[test]
    fn supports_reports_artifact_presence() {
        let Some(dpe) = xla_dpe() else { return };
        assert!(dpe.supports(128, 128, 128, "int8", true));
        assert!(!dpe.supports(64, 64, 64, "int8", true));
    }
}

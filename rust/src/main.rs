//! memintelli — CLI for the MemIntelli-RS simulation framework.
//!
//! ```text
//! memintelli list | --list                list experiments (paper figures/tables)
//! memintelli run <id> [--full] [--config memintelli.toml]
//! memintelli run all [--full]
//! memintelli <id> [--quick|--full]        shortcut: run one experiment directly
//!                                         (e.g. `memintelli fig_faults --quick`)
//! memintelli info                         environment + artifact status
//! memintelli matmul --size N --method int8   one-off DPE matmul RE check
//! ```
//!
//! (Hand-rolled argument parsing: the offline registry has no clap.)

use memintelli::coordinator::{run_experiment, Scale, SimConfig, EXPERIMENTS};
use memintelli::dpe::{DotProductEngine, SliceMethod};
use memintelli::tensor::Matrix;
use memintelli::util::rng::Pcg64;
use std::path::Path;

fn usage() -> ! {
    eprintln!(
        "usage: memintelli <command>\n\
         \n\
         commands:\n\
         \x20 list | --list                list all experiments\n\
         \x20 run <id>|all [--full] [--config FILE]   run experiment(s)\n\
         \x20 <id> [--quick|--full]        shortcut for `run <id>` (quick is the default)\n\
         \x20 info                         show environment + artifacts\n\
         \x20 matmul [--size N] [--method M] [--config FILE]\n\
         \x20                              one-off DPE matmul accuracy check\n\
         \x20 serve [--quick|--full] [--config FILE] [--shards N]\n\
         \x20                              fault-tolerant serving runtime demo\n\
         \x20                              ([serving] section configures the pool;\n\
         \x20                              --shards N serves sharded replicas across\n\
         \x20                              N-chip fleets, overriding\n\
         \x20                              serving.shards_per_replica)"
    );
    std::process::exit(2);
}

struct Args {
    positional: Vec<String>,
    flags: std::collections::BTreeMap<String, String>,
}

fn parse_args(argv: &[String]) -> Args {
    let mut positional = Vec::new();
    let mut flags = std::collections::BTreeMap::new();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(name) = a.strip_prefix("--") {
            // Boolean flag when no value follows.
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.insert(name.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            positional.push(a.clone());
            i += 1;
        }
    }
    Args { positional, flags }
}

fn load_config(args: &Args) -> anyhow::Result<SimConfig> {
    match args.flags.get("config") {
        Some(path) => SimConfig::load(Path::new(path)),
        None => {
            let default = Path::new("memintelli.toml");
            if default.exists() {
                SimConfig::load(default)
            } else {
                Ok(SimConfig::default())
            }
        }
    }
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
    }
    let cmd = argv[0].as_str();
    let args = parse_args(&argv[1..]);
    match cmd {
        "list" | "--list" => {
            println!("experiments (paper artifact → id):\n");
            for (id, desc) in EXPERIMENTS {
                println!("  {id:<20} {desc}");
            }
        }
        "run" => {
            let Some(id) = args.positional.first() else { usage() };
            let cfg = load_config(&args)?;
            let scale = if args.flags.contains_key("full") { Scale::Full } else { Scale::Quick };
            if id == "all" {
                for (eid, _) in EXPERIMENTS {
                    println!("\n===== {eid} =====");
                    run_experiment(eid, &cfg, scale)?;
                }
            } else {
                run_experiment(id, &cfg, scale)?;
            }
        }
        "info" => {
            let cfg = load_config(&args)?;
            println!("MemIntelli-RS — memristive IMC simulation framework");
            println!("engine defaults : {:?}", cfg.dpe);
            println!("seed            : {}", cfg.seed);
            println!("workers         : {}", memintelli::util::parallel::worker_count());
            match memintelli::runtime::Runtime::cpu(&cfg.artifacts_dir) {
                Ok(rt) => {
                    println!("PJRT platform   : {}", rt.platform());
                    let names = [
                        "dpe_mm_128x128x128_int8",
                        "dpe_mm_128x128x128_int8_ideal",
                        "dpe_mm_128x128x128_fp16",
                        "dpe_mm_256x256x256_int8",
                        "lenet_fwd_b32_int8",
                        "lenet_fwd_b128_fp16",
                    ];
                    for n in names {
                        println!(
                            "artifact {n:<32} {}",
                            if rt.has_artifact(n) { "present" } else { "MISSING (run `make artifacts`)" }
                        );
                    }
                }
                Err(e) => println!("PJRT            : unavailable ({e})"),
            }
        }
        "matmul" => {
            let cfg = load_config(&args)?;
            let size: usize = args.flags.get("size").map(|s| s.parse()).transpose()?.unwrap_or(128);
            let method_name = args.flags.get("method").cloned().unwrap_or_else(|| cfg.method.clone());
            let method = SliceMethod::parse(&method_name)?;
            let mut rng = Pcg64::seeded(cfg.seed);
            let a = Matrix::random_normal(size, size, 0.0, 1.0, &mut rng);
            let b = Matrix::random_normal(size, size, 0.0, 1.0, &mut rng);
            let engine = DotProductEngine::new(cfg.dpe.clone(), cfg.seed);
            let t0 = std::time::Instant::now();
            let re = engine.relative_error(&a, &b, &method, &method);
            println!(
                "{size}x{size} {method_name}: relative error {re:.4e} ({} ms)",
                t0.elapsed().as_millis()
            );
        }
        // Replicated serving runtime under open-loop load with fault
        // injection and drift-triggered healing: `memintelli serve`
        // ≡ `memintelli run fig_serving`, with the `[serving]` section
        // (strictly validated at load) configuring the pool.
        "serve" => {
            let mut cfg = load_config(&args)?;
            if let Some(s) = args.flags.get("shards") {
                let shards: usize = s.parse().map_err(|_| {
                    anyhow::anyhow!("--shards expects a positive integer, got '{s}'")
                })?;
                anyhow::ensure!(shards >= 1, "--shards must be >= 1, got {shards}");
                cfg.serving.shards_per_replica = shards;
            }
            let scale = if args.flags.contains_key("full") { Scale::Full } else { Scale::Quick };
            run_experiment("fig_serving", &cfg, scale)?;
        }
        // Shortcut: a bare experiment id runs it directly, so
        // `memintelli fig_faults --quick` ≡ `memintelli run fig_faults`
        // (`--quick` is the default scale; `--full` selects full scale).
        id if EXPERIMENTS.iter().any(|(eid, _)| *eid == id) => {
            let cfg = load_config(&args)?;
            let scale = if args.flags.contains_key("full") { Scale::Full } else { Scale::Quick };
            run_experiment(id, &cfg, scale)?;
        }
        other if !other.starts_with("--") => {
            eprintln!(
                "unknown command or experiment '{other}' — did you mean '{}'? \
                 (see `memintelli list`)",
                memintelli::coordinator::closest_experiment(other)
            );
            std::process::exit(2);
        }
        _ => usage(),
    }
    Ok(())
}

//! im2col / col2im: flatten 2-d convolutions into the dot products the
//! crossbar arrays execute (paper Fig 8(c)).
//!
//! Layout conventions (PyTorch-like, NCHW):
//! - input feature map: `[C, H, W]` flattened row-major;
//! - im2col output: matrix of shape `[C*kh*kw, out_h*out_w]` — each column
//!   is one receptive field, so `weights(out_c × C*kh*kw) · cols` yields the
//!   convolution as a single matmul per sample.

use super::Matrix;

/// Convolution geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Conv2dDims {
    pub in_c: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
}

impl Conv2dDims {
    pub fn out_h(&self) -> usize {
        (self.in_h + 2 * self.pad - self.kh) / self.stride + 1
    }
    pub fn out_w(&self) -> usize {
        (self.in_w + 2 * self.pad - self.kw) / self.stride + 1
    }
    /// Rows of the im2col matrix (= columns of the weight matrix).
    pub fn patch_len(&self) -> usize {
        self.in_c * self.kh * self.kw
    }
}

/// im2col for one sample. `input` is `[C, H, W]` flattened; returns
/// `[C*kh*kw, out_h*out_w]`.
pub fn im2col(input: &[f64], d: Conv2dDims) -> Matrix {
    assert_eq!(input.len(), d.in_c * d.in_h * d.in_w, "input shape mismatch");
    let (oh, ow) = (d.out_h(), d.out_w());
    let mut out = Matrix::zeros(d.patch_len(), oh * ow);
    for c in 0..d.in_c {
        for ki in 0..d.kh {
            for kj in 0..d.kw {
                let row = (c * d.kh + ki) * d.kw + kj;
                let dst_row = &mut out.data[row * oh * ow..(row + 1) * oh * ow];
                for oi in 0..oh {
                    let ii = (oi * d.stride + ki) as isize - d.pad as isize;
                    if ii < 0 || ii as usize >= d.in_h {
                        continue; // zero padding: leave zeros
                    }
                    let src_base = c * d.in_h * d.in_w + ii as usize * d.in_w;
                    for oj in 0..ow {
                        let jj = (oj * d.stride + kj) as isize - d.pad as isize;
                        if jj < 0 || jj as usize >= d.in_w {
                            continue;
                        }
                        dst_row[oi * ow + oj] = input[src_base + jj as usize];
                    }
                }
            }
        }
    }
    out
}

/// col2im accumulation (the backward of im2col): scatter-add a
/// `[C*kh*kw, out_h*out_w]` matrix of patch gradients back into a
/// `[C, H, W]` gradient buffer.
pub fn col2im_accumulate(cols: &Matrix, d: Conv2dDims, grad_input: &mut [f64]) {
    assert_eq!(grad_input.len(), d.in_c * d.in_h * d.in_w);
    let (oh, ow) = (d.out_h(), d.out_w());
    assert_eq!((cols.rows, cols.cols), (d.patch_len(), oh * ow), "cols shape mismatch");
    for c in 0..d.in_c {
        for ki in 0..d.kh {
            for kj in 0..d.kw {
                let row = (c * d.kh + ki) * d.kw + kj;
                let src_row = &cols.data[row * oh * ow..(row + 1) * oh * ow];
                for oi in 0..oh {
                    let ii = (oi * d.stride + ki) as isize - d.pad as isize;
                    if ii < 0 || ii as usize >= d.in_h {
                        continue;
                    }
                    let dst_base = c * d.in_h * d.in_w + ii as usize * d.in_w;
                    for oj in 0..ow {
                        let jj = (oj * d.stride + kj) as isize - d.pad as isize;
                        if jj < 0 || jj as usize >= d.in_w {
                            continue;
                        }
                        grad_input[dst_base + jj as usize] += src_row[oi * ow + oj];
                    }
                }
            }
        }
    }
}

/// Direct (reference) convolution for testing: weights `[out_c, C*kh*kw]`,
/// returns `[out_c, out_h*out_w]`.
pub fn conv2d_direct(input: &[f64], weights: &Matrix, d: Conv2dDims) -> Matrix {
    let (oh, ow) = (d.out_h(), d.out_w());
    assert_eq!(weights.cols, d.patch_len());
    let mut out = Matrix::zeros(weights.rows, oh * ow);
    for oc in 0..weights.rows {
        for oi in 0..oh {
            for oj in 0..ow {
                let mut acc = 0.0;
                for c in 0..d.in_c {
                    for ki in 0..d.kh {
                        for kj in 0..d.kw {
                            let ii = (oi * d.stride + ki) as isize - d.pad as isize;
                            let jj = (oj * d.stride + kj) as isize - d.pad as isize;
                            if ii < 0 || jj < 0 || ii as usize >= d.in_h || jj as usize >= d.in_w {
                                continue;
                            }
                            let w = weights.at(oc, (c * d.kh + ki) * d.kw + kj);
                            acc += w * input[c * d.in_h * d.in_w + ii as usize * d.in_w + jj as usize];
                        }
                    }
                }
                *out.at_mut(oc, oi * ow + oj) = acc;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn im2col_matmul_equals_direct_conv() {
        let mut rng = Pcg64::seeded(11);
        for &(c, h, w, kh, stride, pad) in
            &[(1, 5, 5, 3, 1, 0), (3, 8, 8, 3, 1, 1), (2, 9, 7, 5, 2, 2), (4, 6, 6, 1, 1, 0)]
        {
            let d = Conv2dDims { in_c: c, in_h: h, in_w: w, kh, kw: kh, stride, pad };
            let input: Vec<f64> = (0..c * h * w).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
            let weights = Matrix::random_uniform(6, d.patch_len(), -1.0, 1.0, &mut rng);
            let via_cols = weights.matmul(&im2col(&input, d));
            let direct = conv2d_direct(&input, &weights, d);
            assert!(
                via_cols.relative_error(&direct) < 1e-12,
                "conv mismatch for {d:?}"
            );
        }
    }

    #[test]
    fn im2col_known_small_case() {
        // 1x3x3 input, 2x2 kernel, stride 1, no pad -> 4 patches of len 4.
        let d = Conv2dDims { in_c: 1, in_h: 3, in_w: 3, kh: 2, kw: 2, stride: 1, pad: 0 };
        let input: Vec<f64> = (1..=9).map(|i| i as f64).collect();
        let cols = im2col(&input, d);
        assert_eq!((cols.rows, cols.cols), (4, 4));
        // First column = top-left patch [1,2,4,5].
        let first: Vec<f64> = (0..4).map(|r| cols.at(r, 0)).collect();
        assert_eq!(first, vec![1.0, 2.0, 4.0, 5.0]);
    }

    #[test]
    fn output_dims() {
        let d = Conv2dDims { in_c: 3, in_h: 32, in_w: 32, kh: 3, kw: 3, stride: 1, pad: 1 };
        assert_eq!((d.out_h(), d.out_w()), (32, 32));
        let d2 = Conv2dDims { in_c: 1, in_h: 28, in_w: 28, kh: 5, kw: 5, stride: 1, pad: 0 };
        assert_eq!((d2.out_h(), d2.out_w()), (24, 24));
    }

    #[test]
    fn col2im_is_adjoint_of_im2col() {
        // <im2col(x), y> == <x, col2im(y)> — adjointness makes conv backward
        // correct by construction.
        let mut rng = Pcg64::seeded(12);
        let d = Conv2dDims { in_c: 2, in_h: 6, in_w: 5, kh: 3, kw: 3, stride: 2, pad: 1 };
        let x: Vec<f64> = (0..d.in_c * d.in_h * d.in_w).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
        let cols = im2col(&x, d);
        let y = Matrix::random_uniform(cols.rows, cols.cols, -1.0, 1.0, &mut rng);
        let lhs: f64 = cols.data.iter().zip(&y.data).map(|(a, b)| a * b).sum();
        let mut back = vec![0.0; x.len()];
        col2im_accumulate(&y, d, &mut back);
        let rhs: f64 = x.iter().zip(&back).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-9, "lhs={lhs} rhs={rhs}");
    }
}

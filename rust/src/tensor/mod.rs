//! Dense linear-algebra substrate: a row-major `f64` matrix with the
//! operations the simulator needs (blocked matmul, transpose, padding,
//! block views, norms), byte-packed digit planes + a packed-panel GEMM
//! micro-kernel for the DPE's stacked slice-plane pipeline, plus an N-d
//! `Tensor` used by the NN layers.
//!
//! Built from scratch — the offline registry has no ndarray/nalgebra.
//!
//! # §Perf — the three digit-domain GEMM kernels
//!
//! The DPE datapath compressed in three steps, and all three kernels
//! coexist (each newer one is hard-asserted bit-identical to the one
//! before it):
//!
//! 1. **Per-slice f64** — [`PackedB`] + [`matmul_packed_into`], the
//!    packed-panel micro-kernel. B is packed **once per prepared-weight
//!    lifetime** into column panels of [`GEMM_NR`] (k-major inside each
//!    panel, zero-padded edge panel), and the kernel computes register
//!    tiles of `GEMM_MR × GEMM_NR` accumulators with the packed panel
//!    streamed contiguously. One call per input digit plane: B is
//!    streamed `S_a` times per block.
//! 2. **Stacked f64** — [`DigitPlanes`] + [`matmul_packed_stacked_into`].
//!    All `S_a` input digit planes of one k-block live in a single
//!    byte-packed buffer (slice-major u8 rows — digits are `< 2^8` by
//!    construction, so the f64 planes were an 8× memory tax), and one
//!    call multiplies **every** plane against the packed weight block:
//!    the loop order is panel-outer / slice-inner, so each B panel is
//!    loaded once per block instead of once per (slice, block) — the
//!    `S_a`× cache-reuse win of the stacked layout. Digits convert
//!    u8 → f64 in-register, which is exact. Plane 0 — the 1-bit,
//!    mostly-zero sign slice of signed specs — additionally carries a
//!    per-row nonzero bitmask; its zero-skip is a set-bit iteration over
//!    mask words instead of per-digit compares.
//! 3. **Stacked int** — [`PackedU8`] + [`matmul_packed_stacked_int_into`],
//!    the integer-domain endpoint. When a programmed weight block's
//!    packed values are all exact integers in `[0, 255]` (always true for
//!    noise-free programming; checked value-by-value at program time),
//!    the weight panels are mirrored into u8 — the same [`GEMM_NR`]
//!    panel layout, 1 byte per digit instead of 8 — and the partial sums
//!    accumulate as `u8×u8 → i32` (or i64) integer dot products,
//!    converting to f64 exactly **once** per output element. Weight-side
//!    bytes moved drop another 8×, the register tiles hold 32-bit lanes
//!    instead of 64-bit ones, and the fixed-width [`GEMM_NR`]-lane inner
//!    loop over u8 panels is the shape LLVM autovectorizes into wide
//!    integer multiply-adds.
//!
//! (The general-purpose [`Matrix::matmul`] — i-k-j, unit-stride inner
//! loops, parallel over row bands only when the work amortizes thread
//! spawn — remains for non-digit operands and cold paths.)
//!
//! **Why the int kernel is bit-identical, not just close.** Digits are
//! non-negative integers: every product term is an integer ≤
//! `max_a·max_w ≤ 255² `, and every prefix sum along `k` is an integer
//! bounded by `k·max_a·max_w`. [`int_accum_for`] proves that bound from
//! the slice tables at prepare time and picks i32 (`bound ≤ i32::MAX`)
//! or i64 (`bound < 2^53`), refusing the int path otherwise. Whenever
//! the bound is `< 2^53`, **every** prefix sum is exactly representable
//! in f64, so the f64 kernels' ascending-`k` accumulation commits no
//! rounding at any step — their "floating-point" result *is* the exact
//! integer sum. The integer kernel computes the same exact sum in
//! i32/i64 and converts once (`≤ 2^53` → exact again), so the three
//! kernels agree bit for bit, zero-skips and all (a skipped integer
//! term adds exactly 0).
//!
//! For large or wide operands, [`matmul_packed_stacked_2d`] /
//! [`matmul_packed_stacked_int_2d`] run the same kernels as 2-D
//! (row-band × panel-group) work items on the lock-free atomic-counter
//! scheduler: a band-only split starves the pool when `m` is small
//! (single-sample inference has exactly one band), while the 2-D grid
//! still has `S_a × panel-groups` items at `m = 1`.
//!
//! All kernels accumulate each output element along ascending `k` with
//! one multiply-add per step and no FMA contraction, so their results are
//! bit-identical to each other — the property the DPE's stacked-vs-
//! reference oracle tests rely on. (The zero-skips differ between the
//! kernels — all-zero tile columns, per-digit skips, mask-driven skips —
//! but a skipped term contributes `a·b` with `a = 0`, i.e. `±0.0`, and
//! adding `±0.0` to an accumulator that is never `-0.0` cannot change its
//! bits. Accumulators start at `+0.0` and IEEE round-to-nearest never
//! produces `-0.0` from a sum of a finite value and its negation, so the
//! accumulator indeed never holds `-0.0`. The integer kernel sidesteps
//! the question entirely: its accumulators are integers, and `0 as f64`
//! is `+0.0`.)

mod conv;

pub use conv::{col2im_accumulate, conv2d_direct, im2col, Conv2dDims};

use crate::util::parallel::{par_chunks_mut, par_for};
use crate::util::rng::Pcg64;
use std::fmt;

/// Row-major dense matrix of f64.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)?;
        if self.rows * self.cols <= 36 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    pub fn identity(n: usize) -> Self {
        Matrix::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// Uniform random entries in [lo, hi).
    pub fn random_uniform(rows: usize, cols: usize, lo: f64, hi: f64, rng: &mut Pcg64) -> Self {
        let data = (0..rows * cols).map(|_| rng.uniform_range(lo, hi)).collect();
        Matrix { rows, cols, data }
    }

    /// Normal random entries.
    pub fn random_normal(rows: usize, cols: usize, mean: f64, std: f64, rng: &mut Pcg64) -> Self {
        let data = (0..rows * cols).map(|_| rng.normal_ms(mean, std)).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Matrix multiply `self (m×k) * other (k×n)`: i-k-j loop order
    /// (unit-stride inner loops over both B and C rows), parallel over row
    /// bands only when the work amortizes thread spawn (see module §Perf;
    /// the DPE hot path uses [`matmul_packed_into`] instead).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch {}x{} * {}x{}", self.rows, self.cols, other.rows, other.cols);
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        let kernel = |i0: usize, rows_here: usize, chunk: &mut [f64]| {
            for di in 0..rows_here {
                let i = i0 + di;
                let a_row = &self.data[i * k..(i + 1) * k];
                let c_row = &mut chunk[di * n..(di + 1) * n];
                for (kk, &a) in a_row.iter().enumerate() {
                    if a == 0.0 {
                        continue;
                    }
                    let b_row = &other.data[kk * n..(kk + 1) * n];
                    for (c, &b) in c_row.iter_mut().zip(b_row) {
                        *c += a * b;
                    }
                }
            }
        };
        if m * k * n < (1 << 21) {
            kernel(0, m, &mut out.data);
        } else {
            let band = 32usize.max(1);
            par_chunks_mut(&mut out.data, band * n, |band_idx, chunk| {
                kernel(band_idx * band, chunk.len() / n, chunk);
            });
        }
        out
    }

    /// Matrix–vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len(), "matvec dim mismatch");
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }

    pub fn map(&self, f: impl Fn(f64) -> f64 + Sync) -> Matrix {
        Matrix { rows: self.rows, cols: self.cols, data: self.data.iter().map(|&x| f(x)).collect() }
    }

    pub fn zip_map(&self, other: &Matrix, f: impl Fn(f64, f64) -> f64) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        self.zip_map(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        self.zip_map(other, |a, b| a - b)
    }

    pub fn scale(&self, s: f64) -> Matrix {
        self.map(|x| x * s)
    }

    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn abs_max(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }

    /// Relative error `‖self − other‖₂ / ‖other‖₂` — the paper's RE metric
    /// (Fig 11) with `other` as the ideal result.
    pub fn relative_error(&self, ideal: &Matrix) -> f64 {
        let denom = ideal.frobenius();
        if denom == 0.0 {
            return self.frobenius();
        }
        self.sub(ideal).frobenius() / denom
    }

    /// Zero-pad to `(rows, cols)` (paper Fig 7: pad to a multiple of the
    /// array size).
    pub fn pad_to(&self, rows: usize, cols: usize) -> Matrix {
        assert!(rows >= self.rows && cols >= self.cols, "pad_to must grow");
        let mut out = Matrix::zeros(rows, cols);
        for i in 0..self.rows {
            out.data[i * cols..i * cols + self.cols].copy_from_slice(self.row(i));
        }
        out
    }

    /// Extract the `r0..r0+h, c0..c0+w` submatrix.
    pub fn block(&self, r0: usize, c0: usize, h: usize, w: usize) -> Matrix {
        assert!(r0 + h <= self.rows && c0 + w <= self.cols, "block out of range");
        let mut out = Matrix::zeros(h, w);
        for i in 0..h {
            let src = (r0 + i) * self.cols + c0;
            out.data[i * w..(i + 1) * w].copy_from_slice(&self.data[src..src + w]);
        }
        out
    }

    /// Write `blockm` into position `(r0, c0)`, clipping to bounds (used to
    /// un-pad block results).
    pub fn set_block_clipped(&mut self, r0: usize, c0: usize, blockm: &Matrix) {
        let h = blockm.rows.min(self.rows.saturating_sub(r0));
        let w = blockm.cols.min(self.cols.saturating_sub(c0));
        for i in 0..h {
            let dst = (r0 + i) * self.cols + c0;
            self.data[dst..dst + w].copy_from_slice(&blockm.data[i * blockm.cols..i * blockm.cols + w]);
        }
    }

    /// Accumulate (`+=`) `blockm` into position `(r0, c0)` with clipping.
    pub fn add_block_clipped(&mut self, r0: usize, c0: usize, blockm: &Matrix) {
        let h = blockm.rows.min(self.rows.saturating_sub(r0));
        let w = blockm.cols.min(self.cols.saturating_sub(c0));
        for i in 0..h {
            let dst = (r0 + i) * self.cols + c0;
            for j in 0..w {
                self.data[dst + j] += blockm.data[i * blockm.cols + j];
            }
        }
    }

    /// Mean of all entries.
    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f64>() / self.data.len() as f64
    }

    /// Convenience wrapper over [`matmul_packed_into`] allocating the
    /// output (tests / cold paths; the DPE reuses a scratch buffer).
    pub fn matmul_packed(&self, packed: &PackedB) -> Matrix {
        let mut out = Matrix::zeros(self.rows, packed.n);
        matmul_packed_into(self, packed, &mut out.data);
        out
    }
}

/// GEMM panel width (columns per packed B panel / register-tile width).
pub const GEMM_NR: usize = 8;
/// GEMM register-tile height (rows of A per micro-kernel iteration).
pub const GEMM_MR: usize = 4;

/// A `k × n` matrix re-laid-out for the packed GEMM micro-kernel: column
/// panels of [`GEMM_NR`], k-major within each panel, the last panel
/// zero-padded to full width. Pack once (per prepared-weight lifetime),
/// multiply many times.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedB {
    /// Contraction length (rows of the original B).
    pub k: usize,
    /// Logical column count (padding excluded).
    pub n: usize,
    data: Vec<f64>,
}

impl PackedB {
    /// Pack `b` into [`GEMM_NR`]-wide column panels.
    pub fn pack(b: &Matrix) -> PackedB {
        let (k, n) = (b.rows, b.cols);
        let panels = n.div_ceil(GEMM_NR).max(1);
        let mut data = vec![0.0; panels * k * GEMM_NR];
        for p in 0..n.div_ceil(GEMM_NR) {
            let j0 = p * GEMM_NR;
            let w = GEMM_NR.min(n - j0);
            let base = p * k * GEMM_NR;
            for kk in 0..k {
                let dst = base + kk * GEMM_NR;
                let src = kk * n + j0;
                data[dst..dst + w].copy_from_slice(&b.data[src..src + w]);
            }
        }
        PackedB { k, n, data }
    }

    /// An all-zero packed buffer with the exact layout [`PackedB::pack`]
    /// would produce for a `k × n` matrix. Writers that generate values
    /// element-by-element ([`PackedB::write`]) can fill the panels
    /// directly instead of materializing a dense matrix and packing it —
    /// the DPE programs noisy weight digits straight into panel form this
    /// way, skipping one full allocation + copy per programmed block.
    pub fn zeros(k: usize, n: usize) -> PackedB {
        let panels = n.div_ceil(GEMM_NR).max(1);
        PackedB { k, n, data: vec![0.0; panels * k * GEMM_NR] }
    }

    /// Write element `(kk, col)` of the logical `k × n` matrix into its
    /// packed slot. `PackedB::zeros` followed by `write` over every
    /// element yields the same buffer as [`PackedB::pack`].
    #[inline]
    pub fn write(&mut self, kk: usize, col: usize, v: f64) {
        debug_assert!(kk < self.k && col < self.n, "write out of packed bounds");
        let (p, jj) = (col / GEMM_NR, col % GEMM_NR);
        self.data[p * self.k * GEMM_NR + kk * GEMM_NR + jj] = v;
    }

    /// Materialize columns `c0..c0 + w` as a dense `k × w` matrix — the
    /// exact inverse of [`PackedB::pack`] over that column range. Lets the
    /// packed form be the *only* retained copy of a prepared weight block
    /// (cold paths unpack the stripe they need instead of keeping a second
    /// dense copy alive).
    pub fn unpack_cols(&self, c0: usize, w: usize) -> Matrix {
        assert!(c0 + w <= self.n, "column range out of packed bounds");
        let mut out = Matrix::zeros(self.k, w);
        for j in 0..w {
            let (p, jj) = ((c0 + j) / GEMM_NR, (c0 + j) % GEMM_NR);
            let base = p * self.k * GEMM_NR + jj;
            for kk in 0..self.k {
                out.data[kk * w + j] = self.data[base + kk * GEMM_NR];
            }
        }
        out
    }
}

/// Byte mirror of a [`PackedB`]: the identical [`GEMM_NR`] column-panel,
/// k-major layout, with each value stored as a `u8` digit — 1 byte per
/// weight digit instead of 8. Built from a packed f64 block whose values
/// are all exact integers in `[0, 255]` ([`PackedU8::from_packed`]),
/// which is the program-time invariant of noise-free weight programming;
/// the integer stacked GEMM ([`matmul_packed_stacked_int_into`]) streams
/// these panels instead of the f64 ones (§Perf).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedU8 {
    /// Contraction length (rows of the original B).
    pub k: usize,
    /// Logical column count (padding excluded).
    pub n: usize,
    data: Vec<u8>,
    /// Largest digit actually stored — lets the dispatcher re-check the
    /// proved range bound against the *programmed* values (fault
    /// injection can pin a cell above the slice-spec maximum).
    max_digit: u8,
}

impl PackedU8 {
    /// Mirror `p` into byte panels, or `None` if any packed value
    /// (padding included) is not an exact integer in `[0, 255]` — the
    /// caller then keeps the f64 kernel. Noisy analog values fail on the
    /// first element, so the scan is O(1) for noisy blocks and one cheap
    /// program-time pass for exact ones.
    pub fn from_packed(p: &PackedB) -> Option<PackedU8> {
        if !p.data.iter().all(|&v| (0.0..=255.0).contains(&v) && v.fract() == 0.0) {
            return None;
        }
        let data: Vec<u8> = p.data.iter().map(|&v| v as u8).collect();
        let max_digit = data.iter().copied().max().unwrap_or(0);
        Some(PackedU8 { k: p.k, n: p.n, data, max_digit })
    }

    /// Largest digit stored in any panel (padding is 0).
    pub fn max_digit(&self) -> u8 {
        self.max_digit
    }
}

/// Accumulator width for the integer stacked GEMM, selected by
/// [`int_accum_for`] from the proved partial-sum bound.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IntAccum {
    /// Bound fits i32 — the common case (e.g. INT8 on 64-row arrays:
    /// `64 · 15 · 15 = 14 400`).
    I32,
    /// Bound fits the f64-exact integer range `< 2^53` but not i32 —
    /// extreme specs / very long k-blocks.
    I64,
}

/// Prove the integer-kernel range bound `k · max_a · max_w` (k-block
/// length × largest input digit × largest weight digit) and select the
/// narrowest safe accumulator: i32 when the bound fits `i32::MAX`, i64
/// when it stays below `2^53` (the f64-exact integer range — also the
/// bound under which the f64 kernels are exact, see §Perf), and `None`
/// beyond that (the caller must keep the f64 kernel). Every prefix sum
/// of non-negative integer terms is bounded by the full sum, so the
/// selected accumulator can never overflow mid-loop.
pub fn int_accum_for(k: usize, max_a: u64, max_w: u64) -> Option<IntAccum> {
    let bound = (k as u128) * (max_a as u128) * (max_w as u128);
    if bound <= i32::MAX as u128 {
        Some(IntAccum::I32)
    } else if bound < (1u128 << 53) {
        Some(IntAccum::I64)
    } else {
        None
    }
}

/// `out = a · B` where `B` was packed with [`PackedB::pack`]. `out` must
/// hold exactly `a.rows × packed.n` elements and is fully overwritten —
/// callers reuse one scratch buffer across calls. Bit-identical to
/// [`Matrix::matmul`] (see module §Perf).
pub fn matmul_packed_into(a: &Matrix, packed: &PackedB, out: &mut [f64]) {
    assert_eq!(
        a.cols, packed.k,
        "matmul_packed dim mismatch: a is {}x{}, packed b is {}x{}",
        a.rows, a.cols, packed.k, packed.n
    );
    assert_eq!(out.len(), a.rows * packed.n, "matmul_packed output buffer size mismatch");
    matmul_packed_rows_into(a, 0, a.rows, packed, out);
}

/// Band variant of [`matmul_packed_into`]: compute output rows
/// `i0..i0 + rows` into `out` (which holds exactly those rows). Disjoint
/// bands are independent, so callers can parallelize over row chunks.
pub fn matmul_packed_rows_into(
    a: &Matrix,
    i0: usize,
    rows: usize,
    packed: &PackedB,
    out: &mut [f64],
) {
    debug_assert!(i0 + rows <= a.rows, "row band out of range");
    debug_assert_eq!(out.len(), rows * packed.n, "band buffer size mismatch");
    let (k, n) = (packed.k, packed.n);
    for p in 0..n.div_ceil(GEMM_NR) {
        let j0 = p * GEMM_NR;
        let w = GEMM_NR.min(n - j0);
        let bp = &packed.data[p * k * GEMM_NR..(p + 1) * k * GEMM_NR];
        let mut i = 0usize;
        // MR×NR register tiles: each accumulator runs ascending k with one
        // multiply-add per step (no FMA, no reassociation) — the
        // bit-identity contract with `Matrix::matmul`.
        while i + GEMM_MR <= rows {
            let a0 = a.row(i0 + i);
            let a1 = a.row(i0 + i + 1);
            let a2 = a.row(i0 + i + 2);
            let a3 = a.row(i0 + i + 3);
            let mut c0 = [0.0f64; GEMM_NR];
            let mut c1 = [0.0f64; GEMM_NR];
            let mut c2 = [0.0f64; GEMM_NR];
            let mut c3 = [0.0f64; GEMM_NR];
            for kk in 0..k {
                let (x0, x1, x2, x3) = (a0[kk], a1[kk], a2[kk], a3[kk]);
                // Digit planes (especially 1-bit sign slices) are mostly
                // zeros; skipping a fully-zero A column of the tile keeps
                // the sparse win of the i-k-j kernel.
                if x0 == 0.0 && x1 == 0.0 && x2 == 0.0 && x3 == 0.0 {
                    continue;
                }
                let brow = &bp[kk * GEMM_NR..kk * GEMM_NR + GEMM_NR];
                for jj in 0..GEMM_NR {
                    let bv = brow[jj];
                    c0[jj] += x0 * bv;
                    c1[jj] += x1 * bv;
                    c2[jj] += x2 * bv;
                    c3[jj] += x3 * bv;
                }
            }
            out[i * n + j0..i * n + j0 + w].copy_from_slice(&c0[..w]);
            out[(i + 1) * n + j0..(i + 1) * n + j0 + w].copy_from_slice(&c1[..w]);
            out[(i + 2) * n + j0..(i + 2) * n + j0 + w].copy_from_slice(&c2[..w]);
            out[(i + 3) * n + j0..(i + 3) * n + j0 + w].copy_from_slice(&c3[..w]);
            i += GEMM_MR;
        }
        // Remainder rows one at a time (same ascending-k accumulation).
        while i < rows {
            let ar = a.row(i0 + i);
            let mut c = [0.0f64; GEMM_NR];
            for (kk, &x) in ar.iter().enumerate() {
                if x == 0.0 {
                    continue;
                }
                let brow = &bp[kk * GEMM_NR..kk * GEMM_NR + GEMM_NR];
                for jj in 0..GEMM_NR {
                    c[jj] += x * brow[jj];
                }
            }
            out[i * n + j0..i * n + j0 + w].copy_from_slice(&c[..w]);
            i += 1;
        }
    }
}

/// The training-path GEMM (backward passes of
/// [`crate::nn::LinearMem`]/[`crate::nn::Conv2dMem`], §Perf): `a · b`
/// through the packed register-tiled kernels instead of the naive dense
/// loop, bit-identical to [`Matrix::matmul`] on the same operands.
/// Dispatch mirrors `Matrix::matmul` — serial under the same work
/// threshold, band-parallel above it — with one extra rung: when both
/// operands are exact byte-valued integers and the `k · max_a · max_w`
/// bound holds ([`int_accum_for`]), the multiply runs on the integer
/// slice-stacked kernel under the 2-D scheduler (a single-plane
/// [`DigitPlanes`] stack, whose output layout equals the plain `m × n`
/// result). Gradients are generic f64 so the integer rung engages only
/// for digit-valued operands; the scan for it fails on the first
/// non-integer value.
pub fn matmul_train(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(
        b.rows, a.cols,
        "matmul_train dim mismatch {}x{} * {}x{}",
        a.rows, a.cols, b.rows, b.cols
    );
    matmul_train_packed(a, &PackedB::pack(b))
}

/// [`matmul_train`] with `b` already packed — callers multiplying several
/// operands against the same matrix (forward + weight-grad sharing one
/// pack) pay the packing once.
pub fn matmul_train_packed(a: &Matrix, packed: &PackedB) -> Matrix {
    assert_eq!(
        a.cols, packed.k,
        "matmul_train dim mismatch: a is {}x{}, packed b is {}x{}",
        a.rows, a.cols, packed.k, packed.n
    );
    let (m, k, n) = (a.rows, a.cols, packed.n);
    let mut out = Matrix::zeros(m, n);
    let serial = m * k * n < (1 << 21);
    if let Some(pb) = PackedU8::from_packed(packed) {
        if let Some((planes, max_a)) = byte_plane_of(a) {
            if let Some(acc) = int_accum_for(k, max_a as u64, pb.max_digit() as u64) {
                if serial {
                    matmul_packed_stacked_int_into(&planes, &pb, acc, &mut out.data);
                } else {
                    matmul_packed_stacked_int_2d(&planes, &pb, acc, &mut out.data);
                }
                return out;
            }
        }
    }
    if serial {
        matmul_packed_into(a, packed, &mut out.data);
    } else {
        par_chunks_mut(&mut out.data, STACK_BAND * n, |band_idx, chunk| {
            matmul_packed_rows_into(a, band_idx * STACK_BAND, chunk.len() / n, packed, chunk);
        });
    }
    out
}

/// `a` as a single byte-valued digit plane (plus its max digit), or
/// `None` if any entry is not an exact integer in `[0, 255]` — the
/// integer-rung precondition of [`matmul_train_packed`]. Fails on the
/// first non-integer value, so the scan is O(1) for generic f64 data.
fn byte_plane_of(a: &Matrix) -> Option<(DigitPlanes, u8)> {
    if !a.data.iter().all(|&v| (0.0..=255.0).contains(&v) && v.fract() == 0.0) {
        return None;
    }
    let mut planes = DigitPlanes::zeroed(1, a.rows, a.cols);
    let mut max_a = 0u8;
    for i in 0..a.rows {
        for (kk, &v) in a.row(i).iter().enumerate() {
            let d = v as u8;
            max_a = max_a.max(d);
            planes.set(0, i, kk, d);
        }
    }
    Some((planes, max_a))
}

/// All digit planes of one quantized operand block in byte-packed,
/// slice-major form: digit `(s, i, kk)` of plane `s` lives at
/// `data[(s·rows + i)·cols + kk]` as a `u8` (slice digits are `< 2^8` by
/// construction — slice widths are 1..=8 bits). This is the only retained
/// form of a prepared input's digit planes: the old `Vec<Matrix>` of f64
/// planes cost 8× the memory bandwidth on the GEMM hot path (§Perf).
///
/// Plane 0 — the 1-bit sign slice of signed specs — additionally carries a
/// per-row nonzero bitmask so the stacked kernel's zero-skip over the
/// mostly-zero sign plane is a set-bit iteration instead of per-digit
/// compares. The mask may over-approximate (a set bit for a zero digit
/// only adds an exact `±0.0` term) but never under-approximates: builders
/// start from [`DigitPlanes::zeroed`] and [`DigitPlanes::set`] only sets
/// bits.
#[derive(Debug, Clone, PartialEq)]
pub struct DigitPlanes {
    /// Logical rows per plane (the batch dimension `m`).
    pub rows: usize,
    /// Columns per plane (the padded contraction width of the k-block).
    pub cols: usize,
    /// Number of slice planes (`S_a`).
    n_planes: usize,
    data: Vec<u8>,
    /// Bit `kk & 63` of word `mask[i·mask_words + (kk >> 6)]` is set iff
    /// digit `(0, i, kk)` was written nonzero.
    mask: Vec<u64>,
    mask_words: usize,
}

impl DigitPlanes {
    /// An all-zero plane set (every digit 0, every mask bit clear).
    pub fn zeroed(n_planes: usize, rows: usize, cols: usize) -> Self {
        assert!(n_planes > 0, "need at least one digit plane");
        let mask_words = cols.div_ceil(64).max(1);
        DigitPlanes {
            rows,
            cols,
            n_planes,
            data: vec![0; n_planes * rows * cols],
            mask: vec![0; rows * mask_words],
            mask_words,
        }
    }

    pub fn num_planes(&self) -> usize {
        self.n_planes
    }

    /// Write digit `(s, i, kk)`. Builders write each position at most
    /// once starting from [`DigitPlanes::zeroed`]; rewriting a nonzero
    /// position to zero would leave a stale (but harmless, see the type
    /// docs) mask bit.
    #[inline]
    pub fn set(&mut self, s: usize, i: usize, kk: usize, d: u8) {
        debug_assert!(s < self.n_planes && i < self.rows && kk < self.cols);
        self.data[(s * self.rows + i) * self.cols + kk] = d;
        if s == 0 && d != 0 {
            self.mask[i * self.mask_words + (kk >> 6)] |= 1u64 << (kk & 63);
        }
    }

    #[inline]
    pub fn digit(&self, s: usize, i: usize, kk: usize) -> u8 {
        debug_assert!(s < self.n_planes && i < self.rows && kk < self.cols);
        self.data[(s * self.rows + i) * self.cols + kk]
    }

    /// Row `i` of plane `s` as raw digits.
    #[inline]
    pub fn plane_row(&self, s: usize, i: usize) -> &[u8] {
        let base = (s * self.rows + i) * self.cols;
        &self.data[base..base + self.cols]
    }

    /// The nonzero bitmask of row `i` of plane 0 (`cols.div_ceil(64)`
    /// words, ascending-`kk` bit order).
    #[inline]
    pub(crate) fn sign_row_mask(&self, i: usize) -> &[u64] {
        &self.mask[i * self.mask_words..(i + 1) * self.mask_words]
    }

    /// Build from f64 digit planes (the `slice_digits` layout) — tests and
    /// conversion cold paths. Every value must be an integer in `[0, 256)`.
    pub fn from_slices(slices: &[Matrix]) -> Self {
        assert!(!slices.is_empty(), "need at least one digit plane");
        let (rows, cols) = (slices[0].rows, slices[0].cols);
        assert!(
            slices.iter().all(|p| p.rows == rows && p.cols == cols),
            "digit planes must share one shape"
        );
        let mut out = DigitPlanes::zeroed(slices.len(), rows, cols);
        for (s, plane) in slices.iter().enumerate() {
            for i in 0..rows {
                for (kk, &v) in plane.row(i).iter().enumerate() {
                    // Hard assert (cold path): `v as u8` would silently
                    // truncate an out-of-range digit in release builds.
                    assert!(
                        v >= 0.0 && v < 256.0 && v.fract() == 0.0,
                        "digit {v} not a byte"
                    );
                    out.set(s, i, kk, v as u8);
                }
            }
        }
        out
    }

    /// Materialize plane `s` as an f64 matrix — cold paths only (the
    /// circuit solver and the reference oracle). `d as f64` is exact for
    /// every byte value.
    pub fn plane(&self, s: usize) -> Matrix {
        assert!(s < self.n_planes, "plane index out of range");
        let base = s * self.rows * self.cols;
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data[base..base + self.rows * self.cols]
                .iter()
                .map(|&d| d as f64)
                .collect(),
        }
    }

    /// Rows `[r0, r0 + len)` of every plane (digits and sign masks copied
    /// verbatim) — the batched-inference row slice.
    pub fn row_slice(&self, r0: usize, len: usize) -> DigitPlanes {
        assert!(r0 + len <= self.rows, "row slice {r0}+{len} out of {} rows", self.rows);
        let mut data = Vec::with_capacity(self.n_planes * len * self.cols);
        for s in 0..self.n_planes {
            let base = (s * self.rows + r0) * self.cols;
            data.extend_from_slice(&self.data[base..base + len * self.cols]);
        }
        let mask = self.mask[r0 * self.mask_words..(r0 + len) * self.mask_words].to_vec();
        DigitPlanes {
            rows: len,
            cols: self.cols,
            n_planes: self.n_planes,
            data,
            mask,
            mask_words: self.mask_words,
        }
    }
}

/// Row-band height of one 2-D stacked-GEMM work item (a multiple of
/// [`GEMM_MR`] so bands never split a register tile).
const STACK_BAND: usize = 32;
/// Packed panels per 2-D stacked-GEMM work item.
const STACK_PANEL_GROUP: usize = 8;

/// `out = [plane 0; plane 1; …] · B` for every digit plane of `a` in one
/// pass: output row `s·a.rows + i` is plane `s` row `i` times `B`. `out`
/// must hold exactly `a.num_planes() · a.rows · packed.n` elements and is
/// fully overwritten. The loop is panel-outer / slice-inner, so each
/// packed panel is consumed by every plane's row tiles while L1-hot
/// (§Perf). Bit-identical to `a.plane(s).matmul_packed(&packed)` per
/// plane.
pub fn matmul_packed_stacked_into(a: &DigitPlanes, packed: &PackedB, out: &mut [f64]) {
    stacked_dims_check(a, packed.k, packed.n, out);
    let panels = packed.n.div_ceil(GEMM_NR);
    let base = out.as_mut_ptr();
    for p in 0..panels {
        for s in 0..a.num_planes() {
            // SAFETY: out sizing checked above; (s, p) regions are
            // pairwise disjoint and visited once, serially.
            unsafe { stacked_region(a, packed, s, 0, a.rows, p, p + 1, base) };
        }
    }
}

/// 2-D scheduled variant of [`matmul_packed_stacked_into`]: the same
/// kernel, dispatched as (slice × row-band × panel-group) work items on
/// the lock-free atomic-counter scheduler (`util::parallel::par_for`).
/// Every output element is computed by exactly one item with the same
/// ascending-`k` kernel, so the result is bit-identical to the serial
/// variant regardless of thread count or claim order.
pub fn matmul_packed_stacked_2d(a: &DigitPlanes, packed: &PackedB, out: &mut [f64]) {
    stacked_dims_check(a, packed.k, packed.n, out);
    let panels = packed.n.div_ceil(GEMM_NR).max(1);
    let base = SendPtr(out.as_mut_ptr());
    stacked_grid(a.num_planes(), a.rows, panels, |s, i0, rh, p0, p1| {
        // SAFETY: out sizing checked above; distinct items cover pairwise
        // disjoint (plane-row-band × panel-group) regions, and par_for
        // hands each item index to exactly one worker.
        unsafe { stacked_region(a, packed, s, i0, rh, p0, p1, base.0) };
    });
}

/// Integer-domain variant of [`matmul_packed_stacked_into`]: the same
/// panel-outer / slice-inner pass, but streaming the u8 weight panels and
/// accumulating each output element as an integer dot product in the
/// accumulator width the caller proved safe with [`int_accum_for`],
/// converted to f64 exactly once per element. Bit-identical to the f64
/// stacked kernel whenever the bound holds (§Perf).
pub fn matmul_packed_stacked_int_into(
    a: &DigitPlanes,
    packed: &PackedU8,
    acc: IntAccum,
    out: &mut [f64],
) {
    stacked_dims_check(a, packed.k, packed.n, out);
    let panels = packed.n.div_ceil(GEMM_NR);
    let base = out.as_mut_ptr();
    for p in 0..panels {
        for s in 0..a.num_planes() {
            // SAFETY: out sizing checked above; (s, p) regions are
            // pairwise disjoint and visited once, serially.
            unsafe {
                match acc {
                    IntAccum::I32 => {
                        stacked_int_region::<i32>(a, packed, s, 0, a.rows, p, p + 1, base)
                    }
                    IntAccum::I64 => {
                        stacked_int_region::<i64>(a, packed, s, 0, a.rows, p, p + 1, base)
                    }
                }
            }
        }
    }
}

/// 2-D scheduled variant of [`matmul_packed_stacked_int_into`]: the same
/// (slice × row-band × panel-group) work-item grid as
/// [`matmul_packed_stacked_2d`], bit-identical to the serial integer
/// kernel regardless of thread count or claim order.
pub fn matmul_packed_stacked_int_2d(
    a: &DigitPlanes,
    packed: &PackedU8,
    acc: IntAccum,
    out: &mut [f64],
) {
    stacked_dims_check(a, packed.k, packed.n, out);
    let panels = packed.n.div_ceil(GEMM_NR).max(1);
    let base = SendPtr(out.as_mut_ptr());
    stacked_grid(a.num_planes(), a.rows, panels, |s, i0, rh, p0, p1| {
        // SAFETY: as in `matmul_packed_stacked_2d` — disjoint regions,
        // each item claimed by exactly one worker.
        unsafe {
            match acc {
                IntAccum::I32 => stacked_int_region::<i32>(a, packed, s, i0, rh, p0, p1, base.0),
                IntAccum::I64 => stacked_int_region::<i64>(a, packed, s, i0, rh, p0, p1, base.0),
            }
        }
    });
}

/// Decompose a stacked GEMM into (slice × row-band × panel-group) work
/// items and run `f(s, i0, rh, p0, p1)` for each on the lock-free
/// atomic-counter scheduler — the shared schedule of the f64 and integer
/// 2-D variants. Every output element belongs to exactly one item.
fn stacked_grid(
    n_planes: usize,
    rows: usize,
    panels: usize,
    f: impl Fn(usize, usize, usize, usize, usize) + Sync,
) {
    let bands = rows.div_ceil(STACK_BAND).max(1);
    let pgroups = panels.div_ceil(STACK_PANEL_GROUP);
    let items = n_planes * bands * pgroups;
    par_for(items, |it| {
        let s = it / (bands * pgroups);
        let rem = it % (bands * pgroups);
        let i0 = (rem / pgroups) * STACK_BAND;
        let p0 = (rem % pgroups) * STACK_PANEL_GROUP;
        let rh = STACK_BAND.min(rows.saturating_sub(i0));
        let p1 = panels.min(p0 + STACK_PANEL_GROUP);
        f(s, i0, rh, p0, p1);
    });
}

fn stacked_dims_check(a: &DigitPlanes, k: usize, n: usize, out: &[f64]) {
    assert_eq!(
        a.cols, k,
        "stacked matmul dim mismatch: planes are {}x{}, packed b is {}x{}",
        a.rows, a.cols, k, n
    );
    assert_eq!(
        out.len(),
        a.num_planes() * a.rows * n,
        "stacked matmul output buffer size mismatch"
    );
}

/// Raw-pointer wrapper for the disjoint-region writes of the 2-D stacked
/// GEMM (same pattern as `util::parallel`'s internal scheduler).
struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Micro-kernel of the stacked digit-plane GEMM: plane `s`, rows
/// `[i0, i0 + rh)` of that plane, packed panels `[p0, p1)`, written into
/// the full `(num_planes·rows) × n` row-major output at `out` (plane `s`
/// row `i` is output row `s·rows + i`). Digits convert u8 → f64
/// in-register (exact), and each accumulator runs ascending `k` with one
/// multiply-add per step and no FMA — the bit-identity contract with
/// [`matmul_packed_rows_into`]. Plane 0 iterates the set bits of the
/// per-row nonzero masks (still ascending `k`; skipped terms are `±0.0`,
/// see module §Perf); other planes skip all-zero tile columns like the
/// f64 kernel.
///
/// # Safety
/// `out` must point to a buffer of `a.num_planes() · a.rows · packed.n`
/// f64s, and no other thread may concurrently touch the (row, panel)
/// region this call writes.
#[allow(clippy::too_many_arguments)]
unsafe fn stacked_region(
    a: &DigitPlanes,
    packed: &PackedB,
    s: usize,
    i0: usize,
    rh: usize,
    p0: usize,
    p1: usize,
    out: *mut f64,
) {
    let (k, n) = (packed.k, packed.n);
    let row_base = s * a.rows;
    for p in p0..p1 {
        let j0 = p * GEMM_NR;
        let w = GEMM_NR.min(n - j0);
        let bp = &packed.data[p * k * GEMM_NR..(p + 1) * k * GEMM_NR];
        let mut i = 0usize;
        while i + GEMM_MR <= rh {
            let a0 = a.plane_row(s, i0 + i);
            let a1 = a.plane_row(s, i0 + i + 1);
            let a2 = a.plane_row(s, i0 + i + 2);
            let a3 = a.plane_row(s, i0 + i + 3);
            let mut c0 = [0.0f64; GEMM_NR];
            let mut c1 = [0.0f64; GEMM_NR];
            let mut c2 = [0.0f64; GEMM_NR];
            let mut c3 = [0.0f64; GEMM_NR];
            if s == 0 {
                // Sign plane: walk each tile row's own set bits (ascending
                // kk — trailing_zeros order), so a row contributes nothing
                // at its zero digits instead of a `±0.0` add per lane. The
                // mostly-zero sign plane drops most of its multiply-adds
                // this way; each output element still accumulates its
                // nonzero terms along ascending `k`, so bits don't change.
                for (r, (ar, c)) in
                    [(a0, &mut c0), (a1, &mut c1), (a2, &mut c2), (a3, &mut c3)]
                        .into_iter()
                        .enumerate()
                {
                    let mrow = a.sign_row_mask(i0 + i + r);
                    for (wi, &wd) in mrow.iter().enumerate() {
                        let mut word = wd;
                        while word != 0 {
                            let kk = (wi << 6) + word.trailing_zeros() as usize;
                            word &= word - 1;
                            let brow = &bp[kk * GEMM_NR..kk * GEMM_NR + GEMM_NR];
                            let x = ar[kk] as f64;
                            for jj in 0..GEMM_NR {
                                c[jj] += x * brow[jj];
                            }
                        }
                    }
                }
            } else {
                for kk in 0..k {
                    let (d0, d1, d2, d3) = (a0[kk], a1[kk], a2[kk], a3[kk]);
                    if (d0 | d1 | d2 | d3) == 0 {
                        continue;
                    }
                    let brow = &bp[kk * GEMM_NR..kk * GEMM_NR + GEMM_NR];
                    let (x0, x1, x2, x3) = (d0 as f64, d1 as f64, d2 as f64, d3 as f64);
                    for jj in 0..GEMM_NR {
                        let bv = brow[jj];
                        c0[jj] += x0 * bv;
                        c1[jj] += x1 * bv;
                        c2[jj] += x2 * bv;
                        c3[jj] += x3 * bv;
                    }
                }
            }
            for (r, c) in [(0usize, &c0), (1, &c1), (2, &c2), (3, &c3)] {
                let dst = out.add((row_base + i0 + i + r) * n + j0);
                std::ptr::copy_nonoverlapping(c.as_ptr(), dst, w);
            }
            i += GEMM_MR;
        }
        // Remainder rows one at a time (same ascending-k accumulation).
        while i < rh {
            let ar = a.plane_row(s, i0 + i);
            let mut c = [0.0f64; GEMM_NR];
            if s == 0 {
                let mrow = a.sign_row_mask(i0 + i);
                for (wi, &wd) in mrow.iter().enumerate() {
                    let mut word = wd;
                    while word != 0 {
                        let kk = (wi << 6) + word.trailing_zeros() as usize;
                        word &= word - 1;
                        let brow = &bp[kk * GEMM_NR..kk * GEMM_NR + GEMM_NR];
                        let x = ar[kk] as f64;
                        for jj in 0..GEMM_NR {
                            c[jj] += x * brow[jj];
                        }
                    }
                }
            } else {
                for (kk, &d) in ar.iter().enumerate() {
                    if d == 0 {
                        continue;
                    }
                    let brow = &bp[kk * GEMM_NR..kk * GEMM_NR + GEMM_NR];
                    let x = d as f64;
                    for jj in 0..GEMM_NR {
                        c[jj] += x * brow[jj];
                    }
                }
            }
            let dst = out.add((row_base + i0 + i) * n + j0);
            std::ptr::copy_nonoverlapping(c.as_ptr(), dst, w);
            i += 1;
        }
    }
}

/// Integer accumulator of the int stacked GEMM — i32 or i64, selected per
/// block by [`int_accum_for`]'s proved bound (monomorphized, so each width
/// gets its own straight-line kernel).
trait DigitAcc:
    Copy + std::ops::Add<Output = Self> + std::ops::Mul<Output = Self> + 'static
{
    const ZERO: Self;
    fn from_u8(d: u8) -> Self;
    fn to_f64(self) -> f64;
}

impl DigitAcc for i32 {
    const ZERO: Self = 0;
    #[inline(always)]
    fn from_u8(d: u8) -> i32 {
        d as i32
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }
}

impl DigitAcc for i64 {
    const ZERO: Self = 0;
    #[inline(always)]
    fn from_u8(d: u8) -> i64 {
        d as i64
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }
}

/// Integer-domain micro-kernel: the exact loop structure of
/// [`stacked_region`] — same register tiles, same zero-skips, same
/// sign-plane mask walk — but streaming u8 weight panels and accumulating
/// in `A` (i32/i64). The inner loop is a fixed [`GEMM_NR`]-wide lane
/// array over contiguous u8 bytes, which LLVM autovectorizes into wide
/// integer multiply-adds (widen + `pmulld`/`paddd` on x86, `smlal`-class
/// ops on aarch64). The caller proved via [`int_accum_for`] that no
/// prefix sum can overflow `A`; the single `to_f64` per output element is
/// exact for the same reason (§Perf).
///
/// # Safety
/// As [`stacked_region`]: `out` must point to a buffer of
/// `a.num_planes() · a.rows · packed.n` f64s, and no other thread may
/// concurrently touch the (row, panel) region this call writes.
#[allow(clippy::too_many_arguments)]
unsafe fn stacked_int_region<A: DigitAcc>(
    a: &DigitPlanes,
    packed: &PackedU8,
    s: usize,
    i0: usize,
    rh: usize,
    p0: usize,
    p1: usize,
    out: *mut f64,
) {
    let (k, n) = (packed.k, packed.n);
    let row_base = s * a.rows;
    for p in p0..p1 {
        let j0 = p * GEMM_NR;
        let w = GEMM_NR.min(n - j0);
        let bp = &packed.data[p * k * GEMM_NR..(p + 1) * k * GEMM_NR];
        let mut i = 0usize;
        while i + GEMM_MR <= rh {
            let a0 = a.plane_row(s, i0 + i);
            let a1 = a.plane_row(s, i0 + i + 1);
            let a2 = a.plane_row(s, i0 + i + 2);
            let a3 = a.plane_row(s, i0 + i + 3);
            let mut c0 = [A::ZERO; GEMM_NR];
            let mut c1 = [A::ZERO; GEMM_NR];
            let mut c2 = [A::ZERO; GEMM_NR];
            let mut c3 = [A::ZERO; GEMM_NR];
            if s == 0 {
                // Sign plane: walk each tile row's own set bits (integer
                // arithmetic is exact, so skipped zero terms change
                // nothing at all).
                for (r, (ar, c)) in
                    [(a0, &mut c0), (a1, &mut c1), (a2, &mut c2), (a3, &mut c3)]
                        .into_iter()
                        .enumerate()
                {
                    let mrow = a.sign_row_mask(i0 + i + r);
                    for (wi, &wd) in mrow.iter().enumerate() {
                        let mut word = wd;
                        while word != 0 {
                            let kk = (wi << 6) + word.trailing_zeros() as usize;
                            word &= word - 1;
                            let brow = &bp[kk * GEMM_NR..kk * GEMM_NR + GEMM_NR];
                            let x = A::from_u8(ar[kk]);
                            for jj in 0..GEMM_NR {
                                c[jj] = c[jj] + x * A::from_u8(brow[jj]);
                            }
                        }
                    }
                }
            } else {
                for kk in 0..k {
                    let (d0, d1, d2, d3) = (a0[kk], a1[kk], a2[kk], a3[kk]);
                    if (d0 | d1 | d2 | d3) == 0 {
                        continue;
                    }
                    let brow = &bp[kk * GEMM_NR..kk * GEMM_NR + GEMM_NR];
                    let (x0, x1, x2, x3) =
                        (A::from_u8(d0), A::from_u8(d1), A::from_u8(d2), A::from_u8(d3));
                    for jj in 0..GEMM_NR {
                        let bv = A::from_u8(brow[jj]);
                        c0[jj] = c0[jj] + x0 * bv;
                        c1[jj] = c1[jj] + x1 * bv;
                        c2[jj] = c2[jj] + x2 * bv;
                        c3[jj] = c3[jj] + x3 * bv;
                    }
                }
            }
            for (r, c) in [(0usize, &c0), (1, &c1), (2, &c2), (3, &c3)] {
                let dst = out.add((row_base + i0 + i + r) * n + j0);
                for (jj, &v) in c.iter().enumerate().take(w) {
                    *dst.add(jj) = v.to_f64();
                }
            }
            i += GEMM_MR;
        }
        // Remainder rows one at a time (same integer accumulation).
        while i < rh {
            let ar = a.plane_row(s, i0 + i);
            let mut c = [A::ZERO; GEMM_NR];
            if s == 0 {
                let mrow = a.sign_row_mask(i0 + i);
                for (wi, &wd) in mrow.iter().enumerate() {
                    let mut word = wd;
                    while word != 0 {
                        let kk = (wi << 6) + word.trailing_zeros() as usize;
                        word &= word - 1;
                        let brow = &bp[kk * GEMM_NR..kk * GEMM_NR + GEMM_NR];
                        let x = A::from_u8(ar[kk]);
                        for jj in 0..GEMM_NR {
                            c[jj] = c[jj] + x * A::from_u8(brow[jj]);
                        }
                    }
                }
            } else {
                for (kk, &d) in ar.iter().enumerate() {
                    if d == 0 {
                        continue;
                    }
                    let brow = &bp[kk * GEMM_NR..kk * GEMM_NR + GEMM_NR];
                    let x = A::from_u8(d);
                    for jj in 0..GEMM_NR {
                        c[jj] = c[jj] + x * A::from_u8(brow[jj]);
                    }
                }
            }
            let dst = out.add((row_base + i0 + i) * n + j0);
            for (jj, &v) in c.iter().enumerate().take(w) {
                *dst.add(jj) = v.to_f64();
            }
            i += 1;
        }
    }
}

/// N-d tensor (row-major) for NN activations; thin wrapper sharing the
/// `Matrix` storage conventions.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f64>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f64>) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(self.numel(), shape.iter().product::<usize>(), "reshape numel mismatch");
        self.shape = shape.to_vec();
        self
    }

    /// View a 2-d tensor as a Matrix (copies).
    pub fn to_matrix(&self) -> Matrix {
        assert_eq!(self.shape.len(), 2, "to_matrix needs 2-d");
        Matrix::from_vec(self.shape[0], self.shape[1], self.data.clone())
    }

    pub fn from_matrix(m: &Matrix) -> Self {
        Tensor { shape: vec![m.rows, m.cols], data: m.data.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn prop_pack_unpack_roundtrip_on_ragged_shapes() {
        // pack → unpack_cols is the identity on any column stripe of any
        // (ragged) shape: edge panels, stripes straddling panel
        // boundaries, single columns.
        prop_check("pack/unpack_cols roundtrip", 150, |g| {
            let k = g.usize_in(1..=80);
            let n = g.usize_in(1..=120);
            let b = Matrix::from_vec(k, n, g.vec_f64(k * n, -10.0..10.0));
            let packed = PackedB::pack(&b);
            if packed.unpack_cols(0, n) != b {
                return Err(format!("{k}x{n}: full unpack differs"));
            }
            let c0 = g.usize_in(0..=n - 1);
            let w = g.usize_in(1..=n - c0);
            if packed.unpack_cols(c0, w) != b.block(0, c0, k, w) {
                return Err(format!("{k}x{n}: stripe [{c0}, {c0}+{w}) differs"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_packed_gemm_bit_identical_on_ragged_shapes() {
        // The DPE's bit-identity contract, swept over random ragged shapes
        // and multiscale values (subnormal-ish exponent spread included).
        prop_check("packed GEMM == matmul bitwise", 60, |g| {
            let m = g.usize_in(1..=24);
            let k = g.usize_in(1..=48);
            let n = g.usize_in(1..=64);
            let a = Matrix::from_vec(m, k, g.vec_f64_multiscale(m * k));
            let b = Matrix::from_vec(k, n, g.vec_f64_multiscale(k * n));
            let packed = PackedB::pack(&b);
            if a.matmul_packed(&packed).data != a.matmul(&b).data {
                return Err(format!("{m}x{k}x{n}: packed GEMM diverged from matmul"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_matmul_train_bit_identical_to_matmul() {
        // The training-kernel contract: `matmul_train` must match the
        // reference `Matrix::matmul` bit for bit on any operands — both
        // the f64 packed rung and the exact integer rung (digit-valued
        // operands), which this sweep hits explicitly.
        prop_check("matmul_train == matmul bitwise", 60, |g| {
            let m = g.usize_in(1..=32);
            let k = g.usize_in(1..=48);
            let n = g.usize_in(1..=40);
            let int_case = g.bool();
            let (a, b) = if int_case {
                let a = Matrix::from_vec(
                    m,
                    k,
                    (0..m * k).map(|_| g.usize_in(0..=255) as f64).collect(),
                );
                let b = Matrix::from_vec(
                    k,
                    n,
                    (0..k * n).map(|_| g.usize_in(0..=15) as f64).collect(),
                );
                (a, b)
            } else {
                let a = Matrix::from_vec(m, k, g.vec_f64_multiscale(m * k));
                let b = Matrix::from_vec(k, n, g.vec_f64_multiscale(k * n));
                (a, b)
            };
            if matmul_train(&a, &b).data != a.matmul(&b).data {
                return Err(format!("{m}x{k}x{n} int={int_case}: matmul_train diverged"));
            }
            Ok(())
        });
    }

    #[test]
    fn matmul_train_parallel_rung_bit_identical() {
        // Above the serial threshold (m·k·n ≥ 2²¹) matmul_train takes the
        // banded-parallel / 2-D-scheduled rungs; both must still be
        // bit-identical to the reference — for f64 and integer operands.
        let (m, k, n) = (160, 160, 160);
        assert!(m * k * n >= 1 << 21, "dims must cross the parallel threshold");
        let af = Matrix::from_vec(m, k, (0..m * k).map(|i| ((i * 31 % 97) as f64) / 7.0 - 6.0).collect());
        let bf = Matrix::from_vec(k, n, (0..k * n).map(|i| ((i * 17 % 89) as f64) / 11.0 - 4.0).collect());
        assert_eq!(matmul_train(&af, &bf).data, af.matmul(&bf).data, "f64 parallel rung");
        let ai = Matrix::from_vec(m, k, (0..m * k).map(|i| ((i * 31) % 256) as f64).collect());
        let bi = Matrix::from_vec(k, n, (0..k * n).map(|i| ((i * 13) % 16) as f64).collect());
        assert_eq!(matmul_train(&ai, &bi).data, ai.matmul(&bi).data, "int parallel rung");
    }

    #[test]
    fn prop_transpose_and_pad_block_invariants() {
        // Matrix algebra invariants on ragged shapes: double transpose is
        // the identity, and pad_to → block recovers the original.
        prop_check("transpose/pad/block invariants", 100, |g| {
            let r = g.usize_in(1..=40);
            let c = g.usize_in(1..=40);
            let a = Matrix::from_vec(r, c, g.vec_f64(r * c, -100.0..100.0));
            if a.transpose().transpose() != a {
                return Err(format!("{r}x{c}: transpose involution broken"));
            }
            let pr = r + g.usize_in(0..=9);
            let pc = c + g.usize_in(0..=9);
            let p = a.pad_to(pr, pc);
            if p.block(0, 0, r, c) != a {
                return Err(format!("{r}x{c} -> {pr}x{pc}: pad/block roundtrip broken"));
            }
            Ok(())
        });
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Pcg64::seeded(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 33, 9), (64, 64, 64), (70, 65, 130)] {
            let a = Matrix::random_uniform(m, k, -1.0, 1.0, &mut rng);
            let b = Matrix::random_uniform(k, n, -1.0, 1.0, &mut rng);
            let c = a.matmul(&b);
            for i in 0..m {
                for j in 0..n {
                    let want: f64 = (0..k).map(|t| a.at(i, t) * b.at(t, j)).sum();
                    assert!((c.at(i, j) - want).abs() < 1e-9, "({i},{j})");
                }
            }
        }
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg64::seeded(2);
        let a = Matrix::random_uniform(13, 13, -5.0, 5.0, &mut rng);
        let c = a.matmul(&Matrix::identity(13));
        assert!(c.relative_error(&a) < 1e-15);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Pcg64::seeded(3);
        let a = Matrix::random_uniform(8, 5, -1.0, 1.0, &mut rng);
        let x: Vec<f64> = (0..5).map(|i| i as f64).collect();
        let xm = Matrix::from_vec(5, 1, x.clone());
        let y = a.matvec(&x);
        let ym = a.matmul(&xm);
        for i in 0..8 {
            assert!((y[i] - ym.at(i, 0)).abs() < 1e-12);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::seeded(4);
        let a = Matrix::random_uniform(7, 11, -1.0, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn pad_and_block_roundtrip() {
        let mut rng = Pcg64::seeded(5);
        let a = Matrix::random_uniform(5, 7, -1.0, 1.0, &mut rng);
        let p = a.pad_to(8, 8);
        assert_eq!(p.block(0, 0, 5, 7), a);
        assert_eq!(p.at(7, 7), 0.0);
    }

    #[test]
    fn set_and_add_block_clipped() {
        let mut m = Matrix::zeros(4, 4);
        let b = Matrix::from_fn(3, 3, |i, j| (i * 3 + j) as f64 + 1.0);
        m.set_block_clipped(2, 2, &b); // clips to 2x2
        assert_eq!(m.at(2, 2), 1.0);
        assert_eq!(m.at(3, 3), 5.0);
        m.add_block_clipped(2, 2, &b);
        assert_eq!(m.at(3, 3), 10.0);
    }

    #[test]
    fn relative_error_zero_for_equal() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.relative_error(&a), 0.0);
    }

    #[test]
    fn relative_error_scale_invariance() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = a.scale(1.1);
        let re = b.relative_error(&a);
        assert!((re - 0.1).abs() < 1e-12);
    }

    #[test]
    fn tensor_reshape_and_matrix_roundtrip() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|i| i as f64).collect());
        let m = t.to_matrix();
        assert_eq!(m.at(1, 2), 5.0);
        let t2 = Tensor::from_matrix(&m).reshape(&[3, 2]);
        assert_eq!(t2.shape, vec![3, 2]);
    }

    #[test]
    #[should_panic(expected = "matmul dim mismatch")]
    fn matmul_rejects_mismatch() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(4, 2);
        let _ = a.matmul(&b);
    }

    #[test]
    fn packed_gemm_bit_identical_to_matmul() {
        // The fused DPE pipeline depends on this being *exact*, not just
        // close: ragged shapes (edge panels, remainder row tiles), signed
        // values, and a shape big enough to trip matmul's parallel bands.
        let mut rng = Pcg64::seeded(11);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (4, 64, 8),
            (13, 64, 130),
            (70, 65, 9),
            (130, 130, 130),
        ] {
            let a = Matrix::random_uniform(m, k, -1.0, 1.0, &mut rng);
            let b = Matrix::random_uniform(k, n, -1.0, 1.0, &mut rng);
            let packed = PackedB::pack(&b);
            let via_packed = a.matmul_packed(&packed);
            let via_matmul = a.matmul(&b);
            assert_eq!(via_packed.data, via_matmul.data, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn packed_gemm_bit_identical_on_sparse_digit_planes() {
        // Digit-plane-shaped operands: small non-negative integers with
        // many zeros (exercises both kernels' zero-skip paths).
        let mut rng = Pcg64::seeded(12);
        let a = Matrix::from_fn(37, 64, |_, _| (rng.uniform_range(0.0, 4.0) as i64).max(0) as f64)
            .map(|v| if v < 2.0 { 0.0 } else { v });
        let b = Matrix::from_fn(64, 96, |_, _| (rng.uniform_range(-2.0, 4.0) as i64) as f64);
        let packed = PackedB::pack(&b);
        assert_eq!(a.matmul_packed(&packed).data, a.matmul(&b).data);
    }

    #[test]
    fn packed_gemm_band_variant_matches_full() {
        let mut rng = Pcg64::seeded(13);
        let a = Matrix::random_uniform(23, 40, -1.0, 1.0, &mut rng);
        let b = Matrix::random_uniform(40, 17, -1.0, 1.0, &mut rng);
        let packed = PackedB::pack(&b);
        let full = a.matmul_packed(&packed);
        let mut banded = vec![0.0; 23 * 17];
        for (i0, rows) in [(0usize, 9usize), (9, 4), (13, 10)] {
            matmul_packed_rows_into(&a, i0, rows, &packed, &mut banded[i0 * 17..(i0 + rows) * 17]);
        }
        assert_eq!(banded, full.data);
    }

    #[test]
    fn packed_buffer_is_overwritten_not_accumulated() {
        let a = Matrix::identity(4);
        let b = Matrix::from_fn(4, 4, |i, j| (i * 4 + j) as f64);
        let packed = PackedB::pack(&b);
        let mut out = vec![123.0; 16];
        matmul_packed_into(&a, &packed, &mut out);
        assert_eq!(out, b.data);
        // Second call over dirty scratch must give the same result.
        matmul_packed_into(&a, &packed, &mut out);
        assert_eq!(out, b.data);
    }

    #[test]
    fn packed_zeros_write_matches_pack() {
        // The DPE's direct-pack programming path depends on zeros + write
        // reproducing pack() exactly, including ragged edge panels.
        let mut rng = Pcg64::seeded(15);
        for &(k, n) in &[(1usize, 1usize), (5, 8), (7, 19), (64, 320), (3, 9)] {
            let b = Matrix::random_uniform(k, n, -1.0, 1.0, &mut rng);
            let packed = PackedB::pack(&b);
            let mut direct = PackedB::zeros(k, n);
            for kk in 0..k {
                for j in 0..n {
                    direct.write(kk, j, b.at(kk, j));
                }
            }
            assert_eq!(direct, packed, "{k}x{n}");
        }
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let mut rng = Pcg64::seeded(14);
        for &(k, n) in &[(1usize, 1usize), (5, 8), (7, 19), (64, 320)] {
            let b = Matrix::random_uniform(k, n, -1.0, 1.0, &mut rng);
            let packed = PackedB::pack(&b);
            assert_eq!(packed.unpack_cols(0, n), b, "{k}x{n} full");
            // Arbitrary interior stripe (may straddle panel boundaries).
            if n >= 3 {
                let (c0, w) = (1, n - 2);
                assert_eq!(packed.unpack_cols(c0, w), b.block(0, c0, k, w), "{k}x{n} stripe");
            }
        }
    }

    #[test]
    #[should_panic(expected = "matmul_packed dim mismatch")]
    fn packed_gemm_rejects_mismatch() {
        let a = Matrix::zeros(2, 3);
        let packed = PackedB::pack(&Matrix::zeros(4, 2));
        let mut out = vec![0.0; 4];
        matmul_packed_into(&a, &packed, &mut out);
    }

    /// Digit-plane-shaped random planes: plane 0 is a sparse 0/1 sign
    /// plane, later planes hold small digits with many zeros.
    fn random_digit_planes(
        n_planes: usize,
        rows: usize,
        cols: usize,
        rng: &mut Pcg64,
    ) -> Vec<Matrix> {
        (0..n_planes)
            .map(|s| {
                Matrix::from_fn(rows, cols, |_, _| {
                    if s == 0 {
                        if rng.uniform_range(0.0, 1.0) < 0.4 { 1.0 } else { 0.0 }
                    } else if rng.uniform_range(0.0, 1.0) < 0.5 {
                        0.0
                    } else {
                        rng.below(256) as f64
                    }
                })
            })
            .collect()
    }

    #[test]
    fn digit_planes_roundtrip_and_row_slice() {
        let mut rng = Pcg64::seeded(21);
        // cols > 64 exercises multi-word sign masks.
        for &(n_planes, rows, cols) in &[(1usize, 1usize, 1usize), (4, 7, 64), (5, 9, 130)] {
            let slices = random_digit_planes(n_planes, rows, cols, &mut rng);
            let dp = DigitPlanes::from_slices(&slices);
            assert_eq!((dp.num_planes(), dp.rows, dp.cols), (n_planes, rows, cols));
            for (s, sl) in slices.iter().enumerate() {
                assert_eq!(&dp.plane(s), sl, "plane {s}");
            }
            // Sign mask exactly mirrors plane-0 nonzeros (write-once build).
            for i in 0..rows {
                let mrow = dp.sign_row_mask(i);
                for kk in 0..cols {
                    let bit = (mrow[kk >> 6] >> (kk & 63)) & 1 == 1;
                    assert_eq!(bit, slices[0].at(i, kk) != 0.0, "mask ({i},{kk})");
                }
            }
            if rows >= 3 {
                let (r0, len) = (1, rows - 2);
                let sub = dp.row_slice(r0, len);
                for (s, sl) in slices.iter().enumerate() {
                    assert_eq!(sub.plane(s), sl.block(r0, 0, len, cols), "row_slice plane {s}");
                }
                assert_eq!(sub.sign_row_mask(0), dp.sign_row_mask(r0));
            }
        }
    }

    #[test]
    fn stacked_gemm_bit_identical_to_per_slice_kernel() {
        // The tentpole contract at the kernel level: one stacked pass over
        // byte planes == S_a separate packed GEMMs over the f64 planes,
        // bit for bit — ragged shapes, multi-word masks, remainder rows.
        let mut rng = Pcg64::seeded(22);
        for &(n_planes, m, k, n) in &[
            (4usize, 1usize, 64usize, 256usize),
            (4, 3, 70, 33),
            (5, 33, 130, 64),
            (1, 4, 64, 8),
            (2, 9, 1, 1),
        ] {
            let slices = random_digit_planes(n_planes, m, k, &mut rng);
            let dp = DigitPlanes::from_slices(&slices);
            let b = Matrix::random_uniform(k, n, -3.0, 3.0, &mut rng);
            let packed = PackedB::pack(&b);
            let mut stacked = vec![f64::NAN; n_planes * m * n];
            matmul_packed_stacked_into(&dp, &packed, &mut stacked);
            for (s, sl) in slices.iter().enumerate() {
                let per_slice = sl.matmul_packed(&packed);
                assert_eq!(
                    &stacked[s * m * n..(s + 1) * m * n],
                    &per_slice.data[..],
                    "{n_planes} planes {m}x{k}x{n}, plane {s}"
                );
            }
            // The 2-D scheduled variant must agree exactly, dirty scratch
            // and all.
            let mut grid = vec![123.0; n_planes * m * n];
            matmul_packed_stacked_2d(&dp, &packed, &mut grid);
            assert_eq!(grid, stacked, "{n_planes} planes {m}x{k}x{n} 2-D grid");
        }
    }

    #[test]
    fn prop_stacked_gemm_matches_per_slice_on_random_shapes() {
        prop_check("stacked GEMM == per-slice packed GEMM", 60, |g| {
            let n_planes = g.usize_in(1..=5);
            let m = *g.choose(&[1usize, GEMM_MR - 1, GEMM_MR, 9, 33]);
            let k = g.usize_in(1..=140);
            let n = g.usize_in(1..=100);
            let slices: Vec<Matrix> = (0..n_planes)
                .map(|s| {
                    Matrix::from_fn(m, k, |_, _| {
                        if g.bool() {
                            0.0
                        } else if s == 0 {
                            1.0
                        } else {
                            g.usize_in(0..=255) as f64
                        }
                    })
                })
                .collect();
            let dp = DigitPlanes::from_slices(&slices);
            let b = Matrix::from_vec(k, n, g.vec_f64(k * n, -4.0..4.0));
            let packed = PackedB::pack(&b);
            let mut stacked = vec![0.0; n_planes * m * n];
            matmul_packed_stacked_into(&dp, &packed, &mut stacked);
            for (s, sl) in slices.iter().enumerate() {
                if stacked[s * m * n..(s + 1) * m * n] != sl.matmul_packed(&packed).data[..] {
                    return Err(format!("{n_planes}p {m}x{k}x{n}: plane {s} diverged"));
                }
            }
            let mut grid = vec![7.0; n_planes * m * n];
            matmul_packed_stacked_2d(&dp, &packed, &mut grid);
            if grid != stacked {
                return Err(format!("{n_planes}p {m}x{k}x{n}: 2-D grid diverged"));
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "stacked matmul dim mismatch")]
    fn stacked_gemm_rejects_mismatch() {
        let dp = DigitPlanes::zeroed(2, 3, 5);
        let packed = PackedB::pack(&Matrix::zeros(4, 2));
        let mut out = vec![0.0; 2 * 3 * 2];
        matmul_packed_stacked_into(&dp, &packed, &mut out);
    }

    /// Integer digit matrix 0..=max_digit with many zeros (weight-plane
    /// shaped).
    fn random_digit_matrix(k: usize, n: usize, max_digit: usize, rng: &mut Pcg64) -> Matrix {
        Matrix::from_fn(k, n, |_, _| {
            if rng.uniform_range(0.0, 1.0) < 0.4 { 0.0 } else { rng.below(max_digit + 1) as f64 }
        })
    }

    #[test]
    fn int_stacked_gemm_bit_identical_to_f64_stacked() {
        // The tentpole contract: with an integer B, the u8 mirror exists
        // and both integer variants reproduce the f64 stacked kernel bit
        // for bit — in BOTH accumulator widths (the bound only needs the
        // narrower one; the wider is always also safe).
        let mut rng = Pcg64::seeded(23);
        for &(n_planes, m, k, n) in &[
            (4usize, 1usize, 64usize, 256usize),
            (4, 3, 70, 33),
            (5, 33, 130, 64),
            (1, 4, 64, 8),
            (2, 9, 1, 1),
        ] {
            let dp = DigitPlanes::from_slices(&random_digit_planes(n_planes, m, k, &mut rng));
            let b = random_digit_matrix(k, n, 15, &mut rng);
            let packed = PackedB::pack(&b);
            let pu8 = PackedU8::from_packed(&packed).expect("integer B must mirror");
            assert!(int_accum_for(k, 255, pu8.max_digit() as u64).is_some());
            let mut f64_out = vec![f64::NAN; n_planes * m * n];
            matmul_packed_stacked_into(&dp, &packed, &mut f64_out);
            for acc in [IntAccum::I32, IntAccum::I64] {
                let mut int_out = vec![f64::NAN; n_planes * m * n];
                matmul_packed_stacked_int_into(&dp, &pu8, acc, &mut int_out);
                assert_eq!(int_out, f64_out, "{n_planes}p {m}x{k}x{n} serial {acc:?}");
                let mut grid = vec![123.0; n_planes * m * n];
                matmul_packed_stacked_int_2d(&dp, &pu8, acc, &mut grid);
                assert_eq!(grid, f64_out, "{n_planes}p {m}x{k}x{n} 2-D {acc:?}");
            }
        }
    }

    #[test]
    fn prop_int_stacked_gemm_matches_f64_on_random_shapes() {
        prop_check("int stacked GEMM == f64 stacked GEMM", 60, |g| {
            let n_planes = g.usize_in(1..=5);
            let m = *g.choose(&[1usize, GEMM_MR - 1, GEMM_MR, 9, 33]);
            let k = g.usize_in(1..=140);
            let n = g.usize_in(1..=100);
            let max_w = g.usize_in(1..=255);
            let slices: Vec<Matrix> = (0..n_planes)
                .map(|s| {
                    Matrix::from_fn(m, k, |_, _| {
                        if g.bool() {
                            0.0
                        } else if s == 0 {
                            1.0
                        } else {
                            g.usize_in(0..=255) as f64
                        }
                    })
                })
                .collect();
            let dp = DigitPlanes::from_slices(&slices);
            let b = Matrix::from_fn(k, n, |_, _| g.usize_in(0..=max_w) as f64);
            let packed = PackedB::pack(&b);
            let pu8 = PackedU8::from_packed(&packed)
                .ok_or_else(|| format!("{k}x{n}: integer B rejected"))?;
            let acc = int_accum_for(k, 255, pu8.max_digit() as u64)
                .ok_or_else(|| format!("k={k}: bound unexpectedly above 2^53"))?;
            let mut f64_out = vec![0.0; n_planes * m * n];
            matmul_packed_stacked_into(&dp, &packed, &mut f64_out);
            let mut int_out = vec![0.0; n_planes * m * n];
            matmul_packed_stacked_int_into(&dp, &pu8, acc, &mut int_out);
            if int_out != f64_out {
                return Err(format!("{n_planes}p {m}x{k}x{n} {acc:?}: serial int diverged"));
            }
            let mut grid = vec![7.0; n_planes * m * n];
            matmul_packed_stacked_int_2d(&dp, &pu8, acc, &mut grid);
            if grid != f64_out {
                return Err(format!("{n_planes}p {m}x{k}x{n} {acc:?}: 2-D int diverged"));
            }
            Ok(())
        });
    }

    #[test]
    fn int_kernel_i64_path_exact_at_extreme_bound() {
        // Worst case the spec tables can pose: every digit maxed at 255
        // over a k too long for i32. 40 000 · 255 · 255 = 2 601 000 000
        // overflows i32 but is far below 2^53, so the i64 path must
        // reproduce the exact sum (and the f64 kernel, still exact by the
        // §Perf argument, must agree bit for bit).
        let (k, sum) = (40_000usize, 40_000f64 * 255.0 * 255.0);
        assert_eq!(int_accum_for(k, 255, 255), Some(IntAccum::I64));
        let slices =
            vec![Matrix::from_fn(1, k, |_, _| 1.0), Matrix::from_fn(1, k, |_, _| 255.0)];
        let dp = DigitPlanes::from_slices(&slices);
        let packed = PackedB::pack(&Matrix::from_fn(k, 1, |_, _| 255.0));
        let pu8 = PackedU8::from_packed(&packed).unwrap();
        assert_eq!(pu8.max_digit(), 255);
        let mut f64_out = vec![0.0; 2];
        matmul_packed_stacked_into(&dp, &packed, &mut f64_out);
        let mut int_out = vec![0.0; 2];
        matmul_packed_stacked_int_into(&dp, &pu8, IntAccum::I64, &mut int_out);
        assert_eq!(int_out, f64_out);
        assert_eq!(int_out, vec![k as f64 * 255.0, sum]);
    }

    #[test]
    fn int_accum_bound_selection() {
        // i32::MAX itself still fits i32; one past needs i64; the f64
        // exactness frontier 2^53 is exclusive.
        assert_eq!(int_accum_for(i32::MAX as usize, 1, 1), Some(IntAccum::I32));
        assert_eq!(int_accum_for(i32::MAX as usize + 1, 1, 1), Some(IntAccum::I64));
        assert_eq!(int_accum_for((1usize << 53) - 1, 1, 1), Some(IntAccum::I64));
        assert_eq!(int_accum_for(1usize << 53, 1, 1), None);
        assert_eq!(int_accum_for(0, 255, 255), Some(IntAccum::I32));
        // Typical engine case: 64-row k-blocks of INT8 digit pairs.
        assert_eq!(int_accum_for(64, 15, 15), Some(IntAccum::I32));
    }

    #[test]
    fn packed_u8_mirror_rejects_non_integer_values() {
        // Noisy analog conductances must keep the f64 kernel; exact
        // integer programming must engage the byte mirror.
        let exact = PackedB::pack(&Matrix::from_fn(5, 9, |i, j| ((i * j) % 16) as f64));
        let pu8 = PackedU8::from_packed(&exact).expect("exact integers must mirror");
        assert_eq!(pu8.max_digit(), 15);
        for bad in [
            Matrix::from_fn(5, 9, |i, j| ((i * j) % 16) as f64 + 1e-9), // fractional
            Matrix::from_fn(5, 9, |_, _| -1.0),                         // negative
            Matrix::from_fn(5, 9, |_, _| 256.0),                        // too wide
        ] {
            assert!(PackedU8::from_packed(&PackedB::pack(&bad)).is_none());
        }
    }
}

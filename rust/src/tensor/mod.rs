//! Dense linear-algebra substrate: a row-major `f64` matrix with the
//! operations the simulator needs (blocked matmul, transpose, padding,
//! block views, norms) plus an N-d `Tensor` used by the NN layers.
//!
//! Built from scratch — the offline registry has no ndarray/nalgebra.

mod conv;

pub use conv::{col2im_accumulate, conv2d_direct, im2col, Conv2dDims};

use crate::util::parallel::par_chunks_mut;
use crate::util::rng::Pcg64;
use std::fmt;

/// Row-major dense matrix of f64.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)?;
        if self.rows * self.cols <= 36 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    pub fn identity(n: usize) -> Self {
        Matrix::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// Uniform random entries in [lo, hi).
    pub fn random_uniform(rows: usize, cols: usize, lo: f64, hi: f64, rng: &mut Pcg64) -> Self {
        let data = (0..rows * cols).map(|_| rng.uniform_range(lo, hi)).collect();
        Matrix { rows, cols, data }
    }

    /// Normal random entries.
    pub fn random_normal(rows: usize, cols: usize, mean: f64, std: f64, rng: &mut Pcg64) -> Self {
        let data = (0..rows * cols).map(|_| rng.normal_ms(mean, std)).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Matrix multiply `self (m×k) * other (k×n)`: i-k-j loop order
    /// (unit-stride inner loops over both B and C rows), parallel over row
    /// bands only when the work amortizes thread spawn (§Perf: nested
    /// sub-millisecond parallelism was a 1.7× end-to-end regression).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch {}x{} * {}x{}", self.rows, self.cols, other.rows, other.cols);
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        let kernel = |i0: usize, rows_here: usize, chunk: &mut [f64]| {
            for di in 0..rows_here {
                let i = i0 + di;
                let a_row = &self.data[i * k..(i + 1) * k];
                let c_row = &mut chunk[di * n..(di + 1) * n];
                for (kk, &a) in a_row.iter().enumerate() {
                    if a == 0.0 {
                        continue;
                    }
                    let b_row = &other.data[kk * n..(kk + 1) * n];
                    for (c, &b) in c_row.iter_mut().zip(b_row) {
                        *c += a * b;
                    }
                }
            }
        };
        if m * k * n < (1 << 21) {
            kernel(0, m, &mut out.data);
        } else {
            let band = 32usize.max(1);
            par_chunks_mut(&mut out.data, band * n, |band_idx, chunk| {
                kernel(band_idx * band, chunk.len() / n, chunk);
            });
        }
        out
    }

    /// Matrix–vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len(), "matvec dim mismatch");
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }

    pub fn map(&self, f: impl Fn(f64) -> f64 + Sync) -> Matrix {
        Matrix { rows: self.rows, cols: self.cols, data: self.data.iter().map(|&x| f(x)).collect() }
    }

    pub fn zip_map(&self, other: &Matrix, f: impl Fn(f64, f64) -> f64) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        self.zip_map(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        self.zip_map(other, |a, b| a - b)
    }

    pub fn scale(&self, s: f64) -> Matrix {
        self.map(|x| x * s)
    }

    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn abs_max(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }

    /// Relative error `‖self − other‖₂ / ‖other‖₂` — the paper's RE metric
    /// (Fig 11) with `other` as the ideal result.
    pub fn relative_error(&self, ideal: &Matrix) -> f64 {
        let denom = ideal.frobenius();
        if denom == 0.0 {
            return self.frobenius();
        }
        self.sub(ideal).frobenius() / denom
    }

    /// Zero-pad to `(rows, cols)` (paper Fig 7: pad to a multiple of the
    /// array size).
    pub fn pad_to(&self, rows: usize, cols: usize) -> Matrix {
        assert!(rows >= self.rows && cols >= self.cols, "pad_to must grow");
        let mut out = Matrix::zeros(rows, cols);
        for i in 0..self.rows {
            out.data[i * cols..i * cols + self.cols].copy_from_slice(self.row(i));
        }
        out
    }

    /// Extract the `r0..r0+h, c0..c0+w` submatrix.
    pub fn block(&self, r0: usize, c0: usize, h: usize, w: usize) -> Matrix {
        assert!(r0 + h <= self.rows && c0 + w <= self.cols, "block out of range");
        let mut out = Matrix::zeros(h, w);
        for i in 0..h {
            let src = (r0 + i) * self.cols + c0;
            out.data[i * w..(i + 1) * w].copy_from_slice(&self.data[src..src + w]);
        }
        out
    }

    /// Write `blockm` into position `(r0, c0)`, clipping to bounds (used to
    /// un-pad block results).
    pub fn set_block_clipped(&mut self, r0: usize, c0: usize, blockm: &Matrix) {
        let h = blockm.rows.min(self.rows.saturating_sub(r0));
        let w = blockm.cols.min(self.cols.saturating_sub(c0));
        for i in 0..h {
            let dst = (r0 + i) * self.cols + c0;
            self.data[dst..dst + w].copy_from_slice(&blockm.data[i * blockm.cols..i * blockm.cols + w]);
        }
    }

    /// Accumulate (`+=`) `blockm` into position `(r0, c0)` with clipping.
    pub fn add_block_clipped(&mut self, r0: usize, c0: usize, blockm: &Matrix) {
        let h = blockm.rows.min(self.rows.saturating_sub(r0));
        let w = blockm.cols.min(self.cols.saturating_sub(c0));
        for i in 0..h {
            let dst = (r0 + i) * self.cols + c0;
            for j in 0..w {
                self.data[dst + j] += blockm.data[i * blockm.cols + j];
            }
        }
    }

    /// Mean of all entries.
    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f64>() / self.data.len() as f64
    }
}

/// N-d tensor (row-major) for NN activations; thin wrapper sharing the
/// `Matrix` storage conventions.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f64>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f64>) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(self.numel(), shape.iter().product::<usize>(), "reshape numel mismatch");
        self.shape = shape.to_vec();
        self
    }

    /// View a 2-d tensor as a Matrix (copies).
    pub fn to_matrix(&self) -> Matrix {
        assert_eq!(self.shape.len(), 2, "to_matrix needs 2-d");
        Matrix::from_vec(self.shape[0], self.shape[1], self.data.clone())
    }

    pub fn from_matrix(m: &Matrix) -> Self {
        Tensor { shape: vec![m.rows, m.cols], data: m.data.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Pcg64::seeded(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 33, 9), (64, 64, 64), (70, 65, 130)] {
            let a = Matrix::random_uniform(m, k, -1.0, 1.0, &mut rng);
            let b = Matrix::random_uniform(k, n, -1.0, 1.0, &mut rng);
            let c = a.matmul(&b);
            for i in 0..m {
                for j in 0..n {
                    let want: f64 = (0..k).map(|t| a.at(i, t) * b.at(t, j)).sum();
                    assert!((c.at(i, j) - want).abs() < 1e-9, "({i},{j})");
                }
            }
        }
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg64::seeded(2);
        let a = Matrix::random_uniform(13, 13, -5.0, 5.0, &mut rng);
        let c = a.matmul(&Matrix::identity(13));
        assert!(c.relative_error(&a) < 1e-15);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Pcg64::seeded(3);
        let a = Matrix::random_uniform(8, 5, -1.0, 1.0, &mut rng);
        let x: Vec<f64> = (0..5).map(|i| i as f64).collect();
        let xm = Matrix::from_vec(5, 1, x.clone());
        let y = a.matvec(&x);
        let ym = a.matmul(&xm);
        for i in 0..8 {
            assert!((y[i] - ym.at(i, 0)).abs() < 1e-12);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::seeded(4);
        let a = Matrix::random_uniform(7, 11, -1.0, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn pad_and_block_roundtrip() {
        let mut rng = Pcg64::seeded(5);
        let a = Matrix::random_uniform(5, 7, -1.0, 1.0, &mut rng);
        let p = a.pad_to(8, 8);
        assert_eq!(p.block(0, 0, 5, 7), a);
        assert_eq!(p.at(7, 7), 0.0);
    }

    #[test]
    fn set_and_add_block_clipped() {
        let mut m = Matrix::zeros(4, 4);
        let b = Matrix::from_fn(3, 3, |i, j| (i * 3 + j) as f64 + 1.0);
        m.set_block_clipped(2, 2, &b); // clips to 2x2
        assert_eq!(m.at(2, 2), 1.0);
        assert_eq!(m.at(3, 3), 5.0);
        m.add_block_clipped(2, 2, &b);
        assert_eq!(m.at(3, 3), 10.0);
    }

    #[test]
    fn relative_error_zero_for_equal() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.relative_error(&a), 0.0);
    }

    #[test]
    fn relative_error_scale_invariance() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = a.scale(1.1);
        let re = b.relative_error(&a);
        assert!((re - 0.1).abs() < 1e-12);
    }

    #[test]
    fn tensor_reshape_and_matrix_roundtrip() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|i| i as f64).collect());
        let m = t.to_matrix();
        assert_eq!(m.at(1, 2), 5.0);
        let t2 = Tensor::from_matrix(&m).reshape(&[3, 2]);
        assert_eq!(t2.shape, vec![3, 2]);
    }

    #[test]
    #[should_panic(expected = "matmul dim mismatch")]
    fn matmul_rejects_mismatch() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(4, 2);
        let _ = a.matmul(&b);
    }
}

//! Continuous wavelet transform on the DPE (paper §5, Fig 14).
//!
//! The Morlet CWT is organized as a matrix multiplication: each row of the
//! kernel matrix is one scaled/shifted wavelet, so the transform of a
//! windowed signal is `K · s`. The complex Morlet is split into real and
//! imaginary kernel matrices, each quantized to signed INT4 and mapped on
//! separate arrays (Fig 14(c)); the power spectrum recombines the two
//! convolution results.

use crate::dpe::{DotProductEngine, SliceMethod, SliceSpec};
use crate::tensor::Matrix;

/// Morlet wavelet (ω₀ = 6): `ψ(t) = π^(−1/4)·exp(iω₀t)·exp(−t²/2)`.
/// Returns (real, imag) at time `t`.
pub fn morlet(t: f64) -> (f64, f64) {
    let envelope = (-t * t / 2.0).exp() * std::f64::consts::PI.powf(-0.25);
    let omega0 = 6.0;
    ((omega0 * t).cos() * envelope, (omega0 * t).sin() * envelope)
}

/// Build the Morlet kernel matrices for a window of length `n` and the
/// given scales (in samples). Row `s` of each matrix is the wavelet at
/// scale `scales[s]` centered in the window, normalized by 1/√scale.
pub fn morlet_kernels(n: usize, scales: &[f64]) -> (Matrix, Matrix) {
    let mut real = Matrix::zeros(scales.len(), n);
    let mut imag = Matrix::zeros(scales.len(), n);
    for (si, &scale) in scales.iter().enumerate() {
        assert!(scale > 0.0);
        let norm = 1.0 / scale.sqrt();
        for j in 0..n {
            let t = (j as f64 - n as f64 / 2.0) / scale;
            let (re, im) = morlet(t);
            *real.at_mut(si, j) = norm * re;
            *imag.at_mut(si, j) = norm * im;
        }
    }
    (real, imag)
}

/// Dyadic-ish scale ladder from `min` to `max` (samples), `per_octave`
/// voices per octave — the standard CWT scale axis.
pub fn scale_ladder(min: f64, max: f64, per_octave: usize) -> Vec<f64> {
    let mut scales = Vec::new();
    let step = (2f64).powf(1.0 / per_octave as f64);
    let mut s = min;
    while s <= max {
        scales.push(s);
        s *= step;
    }
    scales
}

/// CWT power spectrum computed on hardware.
///
/// The signal is processed in sliding windows of the kernel length with
/// stride 1 (each window = one DPE matvec batch); output is
/// `(scales, time)` power. `engine = None` computes the digital reference.
pub struct CwtProcessor {
    pub real: Matrix,
    pub imag: Matrix,
    pub scales: Vec<f64>,
}

impl CwtProcessor {
    pub fn new(window: usize, scales: Vec<f64>) -> Self {
        let (real, imag) = morlet_kernels(window, &scales);
        CwtProcessor { real, imag, scales }
    }

    /// Power spectrum |W|² of `signal`. With `Some((engine, method))` the
    /// two kernel matmuls run on the DPE (real/imag mapped separately).
    pub fn power(
        &self,
        signal: &[f64],
        hw: Option<(&DotProductEngine, &SliceMethod)>,
    ) -> Matrix {
        let n = self.real.cols;
        assert!(signal.len() >= n, "signal shorter than window");
        let t_out = signal.len() - n + 1;
        // Window matrix: (t_out, n) — each row one signal window.
        let mut windows = Matrix::zeros(t_out, n);
        for t in 0..t_out {
            windows.row_mut(t).copy_from_slice(&signal[t..t + n]);
        }
        // (t_out, n) · (n, scales) for both parts.
        let (re, im) = match hw {
            Some((engine, method)) => {
                // The window matrix feeds both kernel matmuls: quantize +
                // slice it once and share the prepared inputs across the
                // real and imaginary arrays (bit-identical to slicing it
                // per matmul).
                let win = engine.prepare_inputs(&windows, method);
                let wr = engine.prepare_weights(&self.real.transpose(), method, 0);
                let wi = engine.prepare_weights(&self.imag.transpose(), method, 1);
                (
                    engine.matmul_prepared_inputs(&win, &wr, 0),
                    engine.matmul_prepared_inputs(&win, &wi, 1),
                )
            }
            None => (
                windows.matmul(&self.real.transpose()),
                windows.matmul(&self.imag.transpose()),
            ),
        };
        // Power = re² + im², transposed to (scales, time).
        let mut out = Matrix::zeros(self.scales.len(), t_out);
        for t in 0..t_out {
            for s in 0..self.scales.len() {
                let r = re.at(t, s);
                let i = im.at(t, s);
                *out.at_mut(s, t) = r * r + i * i;
            }
        }
        out
    }
}

/// The paper's INT4 mapping for the wavelet matrices.
pub fn int4_method() -> SliceMethod {
    SliceMethod::int(SliceSpec::int4())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpe::DpeConfig;

    #[test]
    fn morlet_is_normalized_gaussian_envelope() {
        let (re0, im0) = morlet(0.0);
        assert!(re0 > 0.7 && re0 < 0.8); // π^-1/4 ≈ 0.7511
        assert!(im0.abs() < 1e-12);
        let (re_far, im_far) = morlet(6.0);
        assert!(re_far.abs() < 1e-6 && im_far.abs() < 1e-6);
    }

    #[test]
    fn kernels_shape_and_symmetry() {
        let scales = vec![2.0, 4.0, 8.0];
        let (re, im) = morlet_kernels(64, &scales);
        assert_eq!((re.rows, re.cols), (3, 64));
        assert_eq!((im.rows, im.cols), (3, 64));
        // Real part symmetric, imaginary antisymmetric around center.
        for j in 0..31 {
            assert!((re.at(1, 32 + j) - re.at(1, 32 - j)).abs() < 1e-9);
            assert!((im.at(1, 32 + j) + im.at(1, 32 - j)).abs() < 1e-9);
        }
    }

    #[test]
    fn scale_ladder_is_geometric() {
        let s = scale_ladder(2.0, 64.0, 4);
        assert!(s.len() > 10);
        for w in s.windows(2) {
            assert!((w[1] / w[0] - 2f64.powf(0.25)).abs() < 1e-12);
        }
    }

    #[test]
    fn cwt_peaks_at_matching_scale() {
        // Pure sinusoid of period P: power should peak at scale ≈ ω₀·P/2π.
        let period = 16.0;
        let n_sig = 512;
        let signal: Vec<f64> = (0..n_sig)
            .map(|t| (std::f64::consts::TAU * t as f64 / period).sin())
            .collect();
        let scales = scale_ladder(2.0, 64.0, 8);
        let proc = CwtProcessor::new(128, scales.clone());
        let power = proc.power(&signal, None);
        // Average power over time per scale; find argmax.
        let mean_p: Vec<f64> = (0..scales.len())
            .map(|s| power.row(s).iter().sum::<f64>() / power.cols as f64)
            .collect();
        let argmax = mean_p
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        let expected_scale = 6.0 * period / std::f64::consts::TAU;
        let ratio = scales[argmax] / expected_scale;
        assert!((0.8..1.25).contains(&ratio), "peak scale {} vs expected {expected_scale}", scales[argmax]);
    }

    #[test]
    fn shared_window_slicing_bit_identical_to_per_call() {
        // `power` slices the window matrix once for both kernels — must
        // match the pre-split behavior of slicing per matmul exactly.
        let signal: Vec<f64> = (0..150)
            .map(|t| (std::f64::consts::TAU * t as f64 / 12.0).sin())
            .collect();
        let scales = scale_ladder(4.0, 16.0, 2);
        let proc = CwtProcessor::new(64, scales);
        let mut cfg = DpeConfig::default();
        cfg.device.cv = 0.02;
        let engine = DotProductEngine::new(cfg, 5);
        let method = int4_method();
        let cached = proc.power(&signal, Some((&engine, &method)));
        // Pre-split emulation.
        let n = proc.real.cols;
        let t_out = signal.len() - n + 1;
        let mut windows = Matrix::zeros(t_out, n);
        for t in 0..t_out {
            windows.row_mut(t).copy_from_slice(&signal[t..t + n]);
        }
        let wr = engine.prepare_weights(&proc.real.transpose(), &method, 0);
        let wi = engine.prepare_weights(&proc.imag.transpose(), &method, 1);
        let re = engine.matmul_prepared(&windows, &wr, &method, 0);
        let im = engine.matmul_prepared(&windows, &wi, &method, 1);
        let mut want = Matrix::zeros(proc.scales.len(), t_out);
        for t in 0..t_out {
            for s in 0..proc.scales.len() {
                let r = re.at(t, s);
                let i = im.at(t, s);
                *want.at_mut(s, t) = r * r + i * i;
            }
        }
        assert_eq!(cached.data, want.data);
    }

    #[test]
    fn hardware_cwt_close_to_digital() {
        // Fig 14: INT4-mapped kernels still resolve the spectrum.
        let signal: Vec<f64> = (0..300)
            .map(|t| (std::f64::consts::TAU * t as f64 / 20.0).sin())
            .collect();
        let scales = scale_ladder(4.0, 32.0, 4);
        let proc = CwtProcessor::new(96, scales);
        let digital = proc.power(&signal, None);
        let mut cfg = DpeConfig::default();
        cfg.device.cv = 0.02;
        let engine = DotProductEngine::new(cfg, 5);
        let method = int4_method();
        let hw = proc.power(&signal, Some((&engine, &method)));
        // Power spectra correlate strongly even at INT4.
        let corr = pearson(&digital.data, &hw.data);
        assert!(corr > 0.95, "spectrum correlation {corr}");
    }

    fn pearson(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len() as f64;
        let ma = a.iter().sum::<f64>() / n;
        let mb = b.iter().sum::<f64>() / n;
        let cov: f64 = a.iter().zip(b).map(|(x, y)| (x - ma) * (y - mb)).sum();
        let va: f64 = a.iter().map(|x| (x - ma) * (x - ma)).sum();
        let vb: f64 = b.iter().map(|y| (y - mb) * (y - mb)).sum();
        cov / (va.sqrt() * vb.sqrt()).max(1e-300)
    }
}

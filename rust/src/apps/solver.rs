//! Linear equation solving on the DPE (paper §5, Fig 13).
//!
//! The showcase problem is the paper's own word-line circuit equation: a
//! banded symmetric positive-definite system from Ohm/Kirchhoff analysis of
//! a resistive word line loaded by memristors, solved by conjugate
//! gradients whose matvec runs on the (noisy, pre-aligned FP32) DPE.

use crate::dpe::{DotProductEngine, SliceMethod};
#[cfg(test)]
use crate::dpe::SliceSpec;
use crate::tensor::Matrix;

/// Build the word-line circuit equation `A·v = b` (Fig 13(a)): `n` nodes
/// chained by wire conductance `g_w = 1/r_wire`, each node loaded to ground
/// through a memristor of conductance `g_load[i]`, driven by `v_in` through
/// the first wire segment. The matrix is tridiagonal SPD.
pub fn wordline_equation(g_load: &[f64], r_wire: f64, v_in: f64) -> (Matrix, Vec<f64>) {
    let n = g_load.len();
    assert!(n > 0 && r_wire > 0.0);
    let gw = 1.0 / r_wire;
    let mut a = Matrix::zeros(n, n);
    let mut b = vec![0.0; n];
    for i in 0..n {
        let mut diag = g_load[i];
        if i == 0 {
            diag += gw;
            b[0] = gw * v_in;
        } else {
            diag += gw;
            *a.at_mut(i, i - 1) = -gw;
        }
        if i + 1 < n {
            diag += gw;
            *a.at_mut(i, i + 1) = -gw;
        }
        *a.at_mut(i, i) = diag;
    }
    (a, b)
}

/// Matvec backend for CG: software (exact) or the hardware DPE.
///
/// The hardware backend programs the coefficient matrix onto the arrays
/// **once** (as real deployments do — `A` does not change between
/// iterations); every matvec then reads the same programmed conductances.
pub enum MatvecBackend<'a> {
    Software,
    Hardware {
        engine: &'a DotProductEngine,
        method: SliceMethod,
        prepared: crate::dpe::PreparedWeights,
    },
}

impl<'a> MatvecBackend<'a> {
    /// Program `a` for hardware solving (Fig 13: pre-aligned fine slices + IntegerSnap ADC).
    pub fn hardware(engine: &'a DotProductEngine, method: SliceMethod, a: &Matrix) -> Self {
        let prepared = engine.prepare_weights(a, &method, 0);
        MatvecBackend::Hardware { engine, method, prepared }
    }

    fn matvec(&self, a: &Matrix, x: &[f64], iter: u64) -> Vec<f64> {
        match self {
            MatvecBackend::Software => a.matvec(x),
            MatvecBackend::Hardware { engine, method, prepared } => {
                // x as a row vector: (1×n)·(n×n).
                let xm = Matrix::from_vec(1, x.len(), x.to_vec());
                engine.matmul_prepared(&xm, prepared, method, iter).data
            }
        }
    }
}

/// Convergence log of one CG run.
#[derive(Debug, Clone)]
pub struct CgResult {
    pub x: Vec<f64>,
    /// Residual norm ‖b − A·x‖₂ per iteration (Fig 13(b) plots these).
    pub residuals: Vec<f64>,
    pub converged: bool,
}

/// Conjugate gradients with the matvec routed through `backend`.
///
/// With a noisy hardware backend the recurrence residual drifts from the
/// true residual, so the true residual is recomputed (in software, as the
/// digital host would) every iteration for the convergence log.
pub fn conjugate_gradient(
    a: &Matrix,
    b: &[f64],
    backend: &MatvecBackend,
    tol: f64,
    max_iter: usize,
) -> CgResult {
    let n = b.len();
    let mut x = vec![0.0; n];
    let mut r: Vec<f64> = b.to_vec();
    let mut p = r.clone();
    let mut rs_old: f64 = r.iter().map(|v| v * v).sum();
    let b_norm = rs_old.sqrt().max(1e-300);
    let mut residuals = Vec::with_capacity(max_iter);
    let mut converged = false;
    let mut best_x = x.clone();
    let mut best_res = f64::INFINITY;
    for it in 0..max_iter {
        let ap = backend.matvec(a, &p, it as u64);
        let p_ap: f64 = p.iter().zip(&ap).map(|(a, b)| a * b).sum();
        if p_ap <= 0.0 {
            // Hardware noise broke conjugacy (ascent direction): restart
            // from the current residual (steepest descent).
            p = r.clone();
            rs_old = r.iter().map(|v| v * v).sum();
            continue;
        }
        let alpha = rs_old / p_ap;
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        let rs_new: f64 = r.iter().map(|v| v * v).sum();
        // True residual for the log (recomputed digitally).
        let true_r = {
            let ax = a.matvec(&x);
            (b.iter().zip(&ax).map(|(bi, ai)| (bi - ai) * (bi - ai)).sum::<f64>()).sqrt() / b_norm
        };
        residuals.push(true_r);
        if true_r < best_res {
            best_res = true_r;
            best_x.copy_from_slice(&x);
        }
        if true_r < tol {
            converged = true;
            break;
        }
        let beta = rs_new / rs_old;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rs_old = rs_new;
    }
    // Return the best iterate seen (noisy matvecs are not monotone).
    CgResult { x: best_x, residuals, converged }
}

/// Exact dense solve (Gaussian elimination with partial pivoting) — the
/// digital reference for Fig 13(c).
pub fn solve_dense(a: &Matrix, b: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut m = a.clone();
    let mut rhs = b.to_vec();
    for k in 0..n {
        let piv = (k..n)
            .max_by(|&p, &q| m.at(p, k).abs().total_cmp(&m.at(q, k).abs()))
            .unwrap();
        if piv != k {
            for j in 0..n {
                let tmp = m.at(k, j);
                *m.at_mut(k, j) = m.at(piv, j);
                *m.at_mut(piv, j) = tmp;
            }
            rhs.swap(k, piv);
        }
        let pk = m.at(k, k);
        assert!(pk.abs() > 1e-300, "singular system");
        for i in (k + 1)..n {
            let f = m.at(i, k) / pk;
            if f != 0.0 {
                for j in k..n {
                    let v = m.at(i, j) - f * m.at(k, j);
                    *m.at_mut(i, j) = v;
                }
                rhs[i] -= f * rhs[k];
            }
        }
    }
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut acc = rhs[i];
        for j in (i + 1)..n {
            acc -= m.at(i, j) * x[j];
        }
        x[i] = acc / m.at(i, i);
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpe::DpeConfig;
    use crate::util::rng::Pcg64;

    fn test_system(n: usize, seed: u64) -> (Matrix, Vec<f64>) {
        let mut rng = Pcg64::seeded(seed);
        let g_load: Vec<f64> = (0..n).map(|_| rng.uniform_range(1e-6, 1e-5)).collect();
        wordline_equation(&g_load, 2.93, 0.2)
    }

    #[test]
    fn wordline_matrix_is_spd_tridiagonal() {
        let (a, b) = test_system(16, 1);
        for i in 0..16 {
            for j in 0..16 {
                assert!((a.at(i, j) - a.at(j, i)).abs() < 1e-18, "symmetric");
                if (i as isize - j as isize).abs() > 1 {
                    assert_eq!(a.at(i, j), 0.0, "tridiagonal");
                }
            }
            assert!(a.at(i, i) > 0.0);
        }
        assert!(b[0] > 0.0);
        assert!(b[1..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn software_cg_matches_dense() {
        let (a, b) = test_system(32, 2);
        let dense = solve_dense(&a, &b);
        let cg = conjugate_gradient(&a, &b, &MatvecBackend::Software, 1e-12, 500);
        assert!(cg.converged);
        for (x, y) in cg.x.iter().zip(&dense) {
            assert!((x - y).abs() < 1e-8 * y.abs().max(1e-3), "{x} vs {y}");
        }
    }

    #[test]
    fn voltages_decay_along_wordline() {
        // Physics sanity: IR drop means monotone non-increasing node
        // voltages away from the source.
        let (a, b) = test_system(24, 3);
        let v = solve_dense(&a, &b);
        for w in v.windows(2) {
            assert!(w[1] <= w[0] + 1e-15);
        }
        assert!(v[0] < 0.2);
    }

    #[test]
    fn hardware_cg_converges_close_to_software() {
        // Fig 13(b)(c): the hardware solver needs more iterations but lands
        // on a solution consistent with software. Solver method: 24-bit
        // pre-aligned fine slices + calibrated ADC (see SliceSpec::solver26),
        // device variation 2%.
        let (a, b) = test_system(32, 4);
        let mut cfg = DpeConfig::default();
        cfg.array = (32, 32);
        cfg.device.cv = 0.02;
        cfg.adc_policy = crate::dpe::engine::AdcPolicy::IntegerSnap;
        let engine = DotProductEngine::new(cfg, 11);
        let method = SliceMethod::fp(SliceSpec::solver26());
        let hw = MatvecBackend::hardware(&engine, method, &a);
        let sw = conjugate_gradient(&a, &b, &MatvecBackend::Software, 1e-10, 300);
        let hwr = conjugate_gradient(&a, &b, &hw, 1e-6, 300);
        assert!(hwr.converged, "hardware CG did not reach 1e-6");
        let rel_diff: f64 = hwr
            .x
            .iter()
            .zip(&sw.x)
            .map(|(h, s)| (h - s) * (h - s))
            .sum::<f64>()
            .sqrt()
            / sw.x.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(rel_diff < 1e-3, "hardware vs software solution diff {rel_diff}");
        // Hardware needs at least as many iterations as software.
        let sw_iters = sw.residuals.iter().position(|&r| r < 1e-6).unwrap();
        assert!(
            hwr.residuals.len() >= sw_iters,
            "hw {} vs sw {}",
            hwr.residuals.len(),
            sw_iters
        );
        // Voltages consistent: max deviation far below the 0.2 V drive.
        let maxdv = hwr.x.iter().zip(&sw.x).map(|(h, s)| (h - s).abs()).fold(0.0, f64::max);
        assert!(maxdv < 0.002, "max voltage deviation {maxdv}");
    }

    #[test]
    fn hardware_cg_breaks_down_at_high_variation() {
        // The flip side (feeds the Fig 13 bench's cv sweep): at Table-2
        // cv = 0.05 the ill-conditioned word-line system can no longer be
        // solved to software precision.
        let (a, b) = test_system(32, 4);
        let mut cfg = DpeConfig::default();
        cfg.array = (32, 32);
        cfg.device.cv = 0.1;
        cfg.adc_policy = crate::dpe::engine::AdcPolicy::IntegerSnap;
        let engine = DotProductEngine::new(cfg, 11);
        let method = SliceMethod::fp(SliceSpec::solver26());
        let hw = MatvecBackend::hardware(&engine, method, &a);
        let hwr = conjugate_gradient(&a, &b, &hw, 1e-6, 100);
        assert!(!hwr.converged, "10% variation should not reach 1e-6");
    }

    #[test]
    fn cg_residuals_decrease_software() {
        let (a, b) = test_system(48, 5);
        let cg = conjugate_gradient(&a, &b, &MatvecBackend::Software, 1e-12, 300);
        let first = cg.residuals[0];
        let last = *cg.residuals.last().unwrap();
        assert!(last < first * 1e-6);
    }

    #[test]
    #[should_panic(expected = "singular")]
    fn dense_rejects_singular() {
        let a = Matrix::zeros(3, 3);
        solve_dense(&a, &[1.0, 2.0, 3.0]);
    }
}

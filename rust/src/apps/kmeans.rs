//! K-means clustering on the DPE (paper §5, Fig 15).
//!
//! Squared items are unsupported on a crossbar, so Euclidean distance uses
//! the paper's dot-product trick (after [21], Wang et al.): with the
//! augmented vectors `x̃ = [x, −1/2, …, −1/2]` (n tail entries) and
//! `ỹ = [y, y²/n, …, y²/n]`,
//! `x̃·ỹ = x·y − y²/2 = (‖x‖² − ‖x − y‖²)/2`, so for a fixed input the
//! similarity is maximal exactly where the Euclidean distance is minimal
//! (the `‖x‖²` term is shared by all centers). Center similarity is
//! therefore one DPE matmul per assignment pass — the paper's
//! "similarity layer".

use crate::dpe::{DotProductEngine, SliceMethod, SliceSpec};
use crate::tensor::Matrix;
use crate::util::rng::Pcg64;

/// K-means configuration (paper: IRIS, INT8 (1,1,2,4), n = 10 tail).
#[derive(Debug, Clone)]
pub struct KmeansConfig {
    pub k: usize,
    pub tail: usize,
    pub max_iter: usize,
    pub seed: u64,
}

impl Default for KmeansConfig {
    fn default() -> Self {
        KmeansConfig { k: 3, tail: 10, max_iter: 25, seed: 2024 }
    }
}

/// Result of a clustering run.
#[derive(Debug, Clone)]
pub struct KmeansResult {
    /// Final centers, `k × d`.
    pub centers: Matrix,
    pub assignments: Vec<usize>,
    pub iterations: usize,
    /// Center trajectory per iteration (Fig 15(a) plots the evolution).
    pub center_history: Vec<Matrix>,
}

/// Augment data rows: `[x, −1/2 × tail]`.
fn augment_data(x: &Matrix, tail: usize) -> Matrix {
    let mut out = Matrix::zeros(x.rows, x.cols + tail);
    for i in 0..x.rows {
        out.row_mut(i)[..x.cols].copy_from_slice(x.row(i));
        for t in 0..tail {
            out.row_mut(i)[x.cols + t] = -0.5;
        }
    }
    out
}

/// Augment centers: `[y, y²/n × tail]` (transposed for the matmul).
fn augment_centers(centers: &Matrix, tail: usize) -> Matrix {
    let mut out = Matrix::zeros(centers.cols + tail, centers.rows);
    for c in 0..centers.rows {
        let y = centers.row(c);
        let y2: f64 = y.iter().map(|v| v * v).sum();
        for (j, &v) in y.iter().enumerate() {
            *out.at_mut(j, c) = v;
        }
        for t in 0..tail {
            *out.at_mut(centers.cols + t, c) = y2 / tail as f64;
        }
    }
    out
}

/// One assignment pass: similarity matmul (on DPE when provided), argmax.
/// One-shot convenience — the [`kmeans`] loop itself slices the augmented
/// data once via [`crate::dpe::PreparedInputs`] and reuses it across every
/// pass instead of re-quantizing here each iteration.
pub fn assign(
    x: &Matrix,
    centers: &Matrix,
    tail: usize,
    hw: Option<(&DotProductEngine, &SliceMethod)>,
    tag: u64,
) -> Vec<usize> {
    let xa = augment_data(x, tail);
    let ca = augment_centers(centers, tail);
    let sim = match hw {
        Some((engine, method)) => {
            let w = engine.prepare_weights(&ca, method, tag);
            engine.matmul_prepared(&xa, &w, method, tag)
        }
        None => xa.matmul(&ca),
    };
    argmax_rows(&sim)
}

/// Row-wise argmax of the similarity matrix → cluster ids.
fn argmax_rows(sim: &Matrix) -> Vec<usize> {
    (0..sim.rows)
        .map(|i| {
            sim.row(i)
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0
        })
        .collect()
}

/// Full K-means loop with hardware-assigned similarity.
pub fn kmeans(
    x: &Matrix,
    cfg: &KmeansConfig,
    hw: Option<(&DotProductEngine, &SliceMethod)>,
) -> KmeansResult {
    assert!(cfg.k >= 1 && x.rows >= cfg.k);
    let mut rng = Pcg64::new(cfg.seed, 0x4B4D);
    // k-means++-lite init: random distinct samples.
    let mut chosen: Vec<usize> = Vec::new();
    while chosen.len() < cfg.k {
        let c = rng.below(x.rows);
        if !chosen.contains(&c) {
            chosen.push(c);
        }
    }
    let mut centers = Matrix::zeros(cfg.k, x.cols);
    for (c, &i) in chosen.iter().enumerate() {
        centers.row_mut(c).copy_from_slice(x.row(i));
    }
    let mut history = vec![centers.clone()];
    let mut assignments = vec![0usize; x.rows];
    let mut iterations = 0;
    // The augmented data matrix is fixed for the whole run: build and
    // (on hardware) quantize + slice it once, then reuse the prepared
    // inputs across every assignment pass — only the centers (the weight
    // side) change per iteration. Bit-identical to re-slicing per pass.
    let xa = augment_data(x, cfg.tail);
    let xa_prepared = hw.map(|(engine, method)| engine.prepare_inputs(&xa, method));
    for it in 0..cfg.max_iter {
        iterations = it + 1;
        let ca = augment_centers(&centers, cfg.tail);
        let sim = match (hw, &xa_prepared) {
            (Some((engine, method)), Some(ai)) => {
                let w = engine.prepare_weights(&ca, method, it as u64);
                engine.matmul_prepared_inputs(ai, &w, it as u64)
            }
            _ => xa.matmul(&ca),
        };
        let new_assign = argmax_rows(&sim);
        // Update centers (digital averaging, as in the paper's host loop).
        let mut sums = Matrix::zeros(cfg.k, x.cols);
        let mut counts = vec![0usize; cfg.k];
        for (i, &c) in new_assign.iter().enumerate() {
            counts[c] += 1;
            for (s, &v) in sums.row_mut(c).iter_mut().zip(x.row(i)) {
                *s += v;
            }
        }
        let mut moved = 0.0f64;
        for c in 0..cfg.k {
            if counts[c] == 0 {
                continue; // keep empty cluster's center
            }
            for j in 0..x.cols {
                let nv = sums.at(c, j) / counts[c] as f64;
                moved = moved.max((nv - centers.at(c, j)).abs());
                *centers.at_mut(c, j) = nv;
            }
        }
        history.push(centers.clone());
        let stable = new_assign == assignments;
        assignments = new_assign;
        if stable || moved < 1e-12 {
            break;
        }
    }
    KmeansResult { centers, assignments, iterations, center_history: history }
}

/// Clustering agreement vs ground-truth labels: best-permutation accuracy
/// over ≤4 clusters (exhaustive permutation search).
pub fn clustering_accuracy(assignments: &[usize], labels: &[usize], k: usize) -> f64 {
    assert!(k <= 4, "permutation search limited to k ≤ 4");
    let perms = permutations(k);
    let mut best = 0.0f64;
    for perm in perms {
        let correct = assignments
            .iter()
            .zip(labels)
            .filter(|(&a, &l)| perm[a] == l)
            .count();
        best = best.max(correct as f64 / labels.len() as f64);
    }
    best
}

fn permutations(k: usize) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    let mut items: Vec<usize> = (0..k).collect();
    permute(&mut items, 0, &mut out);
    out
}

fn permute(items: &mut Vec<usize>, start: usize, out: &mut Vec<Vec<usize>>) {
    if start == items.len() {
        out.push(items.clone());
        return;
    }
    for i in start..items.len() {
        items.swap(start, i);
        permute(items, start + 1, out);
        items.swap(start, i);
    }
}

/// The paper's INT8 (1,1,2,4) method for Fig 15.
pub fn int8_method() -> SliceMethod {
    SliceMethod::int(SliceSpec::int8())
}

/// Min–max normalize each feature column to [0, 1] — balances the feature
/// and `y²/n` tail magnitudes so the INT8 quantization range is used
/// evenly (required for hardware clustering fidelity).
pub fn min_max_normalize(x: &mut Matrix) {
    for j in 0..x.cols {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for i in 0..x.rows {
            lo = lo.min(x.at(i, j));
            hi = hi.max(x.at(i, j));
        }
        let span = (hi - lo).max(1e-300);
        for i in 0..x.rows {
            *x.at_mut(i, j) = (x.at(i, j) - lo) / span;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::iris;
    use crate::dpe::DpeConfig;

    fn iris_matrix() -> (Matrix, Vec<usize>) {
        let ds = iris::load(50, 42);
        let mut m = Matrix::from_vec(ds.len(), 4, ds.features.clone());
        min_max_normalize(&mut m);
        (m, ds.labels.clone())
    }

    #[test]
    fn distance_trick_is_monotone_in_distance() {
        // x̃·ỹ = 2x·y − y²: for fixed x, larger similarity ⇔ smaller
        // (x−y)².
        let x = Matrix::from_vec(1, 3, vec![1.0, -0.5, 2.0]);
        let centers =
            Matrix::from_vec(3, 3, vec![1.0, -0.5, 2.0, 0.0, 0.0, 0.0, 2.0, 1.0, -1.0]);
        let xa = augment_data(&x, 10);
        let ca = augment_centers(&centers, 10);
        let sim = xa.matmul(&ca);
        let d2: Vec<f64> = (0..3)
            .map(|c| {
                x.row(0)
                    .iter()
                    .zip(centers.row(c))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum()
            })
            .collect();
        // Verify sim = x² − d² up to the shared x² offset: ordering reversed.
        for a in 0..3 {
            for b in 0..3 {
                if d2[a] < d2[b] {
                    assert!(sim.at(0, a) > sim.at(0, b), "similarity must invert distance order");
                }
            }
        }
    }

    #[test]
    fn digital_kmeans_clusters_iris() {
        let (x, labels) = iris_matrix();
        let res = kmeans(&x, &KmeansConfig::default(), None);
        let acc = clustering_accuracy(&res.assignments, &labels, 3);
        assert!(acc > 0.8, "digital clustering accuracy {acc}");
        assert!(res.iterations <= 25);
    }

    #[test]
    fn hardware_kmeans_matches_digital_clusters() {
        // Fig 15(b): hardware clustering results are counterparts of the
        // full-precision ones.
        let (x, labels) = iris_matrix();
        let digital = kmeans(&x, &KmeansConfig::default(), None);
        let mut cfg = DpeConfig::default();
        cfg.device.cv = 0.02;
        let engine = DotProductEngine::new(cfg, 3);
        let method = int8_method();
        let hw = kmeans(&x, &KmeansConfig::default(), Some((&engine, &method)));
        let acc_d = clustering_accuracy(&digital.assignments, &labels, 3);
        let acc_h = clustering_accuracy(&hw.assignments, &labels, 3);
        assert!(acc_h > acc_d - 0.1, "hw {acc_h} vs digital {acc_d}");
        // Centers land near each other (best permutation distance).
        let agree = clustering_accuracy(&hw.assignments, &digital.assignments, 3);
        assert!(agree > 0.85, "assignment agreement {agree}");
    }

    #[test]
    fn cached_input_loop_bit_identical_to_per_pass_slicing() {
        // The kmeans loop slices the augmented data once (PreparedInputs)
        // — it must stay bit-identical to the pre-split behavior of
        // re-slicing in every `assign` pass.
        let (x, _) = iris_matrix();
        let mut dcfg = DpeConfig::default();
        dcfg.device.cv = 0.02;
        let engine = DotProductEngine::new(dcfg, 3);
        let method = int8_method();
        let cfg = KmeansConfig::default();
        let res = kmeans(&x, &cfg, Some((&engine, &method)));
        // Pre-split emulation: identical init, per-pass `assign`.
        let mut rng = crate::util::rng::Pcg64::new(cfg.seed, 0x4B4D);
        let mut chosen: Vec<usize> = Vec::new();
        while chosen.len() < cfg.k {
            let c = rng.below(x.rows);
            if !chosen.contains(&c) {
                chosen.push(c);
            }
        }
        let mut centers = Matrix::zeros(cfg.k, x.cols);
        for (c, &i) in chosen.iter().enumerate() {
            centers.row_mut(c).copy_from_slice(x.row(i));
        }
        let mut assignments = vec![0usize; x.rows];
        for it in 0..cfg.max_iter {
            let new_assign = assign(&x, &centers, cfg.tail, Some((&engine, &method)), it as u64);
            let mut sums = Matrix::zeros(cfg.k, x.cols);
            let mut counts = vec![0usize; cfg.k];
            for (i, &c) in new_assign.iter().enumerate() {
                counts[c] += 1;
                for (s, &v) in sums.row_mut(c).iter_mut().zip(x.row(i)) {
                    *s += v;
                }
            }
            let mut moved = 0.0f64;
            for c in 0..cfg.k {
                if counts[c] == 0 {
                    continue;
                }
                for j in 0..x.cols {
                    let nv = sums.at(c, j) / counts[c] as f64;
                    moved = moved.max((nv - centers.at(c, j)).abs());
                    *centers.at_mut(c, j) = nv;
                }
            }
            let stable = new_assign == assignments;
            assignments = new_assign;
            if stable || moved < 1e-12 {
                break;
            }
        }
        assert_eq!(res.assignments, assignments);
        assert_eq!(res.centers.data, centers.data);
    }

    #[test]
    fn center_history_recorded() {
        let (x, _) = iris_matrix();
        let res = kmeans(&x, &KmeansConfig { max_iter: 5, ..Default::default() }, None);
        assert_eq!(res.center_history.len(), res.iterations + 1);
    }

    #[test]
    fn accuracy_permutation_invariant() {
        let labels = vec![0, 0, 1, 1, 2, 2];
        let assign = vec![2, 2, 0, 0, 1, 1]; // relabeled perfectly
        assert_eq!(clustering_accuracy(&assign, &labels, 3), 1.0);
    }

    #[test]
    fn single_cluster_trivial() {
        let x = Matrix::from_vec(4, 2, vec![0.0, 0.0, 0.1, 0.1, 0.2, 0.0, 0.1, 0.2]);
        let res = kmeans(&x, &KmeansConfig { k: 1, ..Default::default() }, None);
        assert!(res.assignments.iter().all(|&a| a == 0));
        // Center = mean of data.
        assert!((res.centers.at(0, 0) - 0.1).abs() < 1e-12);
    }
}

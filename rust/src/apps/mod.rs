//! Application substrates demonstrating the DPE (paper §5).
pub mod cwt;
pub mod kmeans;
pub mod solver;

//! # MemIntelli-RS
//!
//! A Rust + JAX + Pallas reproduction of **MemIntelli: A Generic End-to-End
//! Simulation Framework for Memristive Intelligent Computing** (Zhou et al.,
//! HUST, 2024/2025).
//!
//! MemIntelli simulates intelligent-computing workloads running on
//! memristive crossbar arrays: a lognormal device-variation model, a
//! crossbar circuit model with wire resistance / IR-drop, DAC–ADC
//! quantization, and a **variable-precision bit-slicing dot-product engine
//! (DPE)** supporting INT and shared-exponent FP data, composed into
//! hardware-aware neural-network layers and application substrates
//! (equation solving, wavelet transforms, clustering).
//!
//! Architecture (see `DESIGN.md`):
//! - **L3 (this crate)** — the full simulator + coordinator, pure Rust;
//! - **L2/L1 (`python/compile/`)** — JAX graph + Pallas kernel, AOT-lowered
//!   once to HLO text (`artifacts/`), executed from Rust via PJRT
//!   ([`runtime`]); Python is never on the request path. The PJRT client
//!   is gated behind the `xla` cargo feature (off by default for offline
//!   builds); without it the runtime compiles as a stub and everything
//!   routes through the native engine.
//!
//! The DPE hot path uses the stacked slice-plane GEMM pipeline — input
//! digits live in byte-packed [`tensor::DigitPlanes`] and **one** packed
//! GEMM per array block covers every (input slice, weight slice) pair,
//! 2-D (row-band × panel-group) scheduled when a single block must fill
//! the pool; see `dpe::engine` §Perf and `tensor` §Perf for the design
//! and `benches/table3_throughput.rs` (`BENCH_table3.json`) plus
//! `benches/gemm_kernel.rs` (`BENCH_gemm.json`) for the tracked
//! throughput numbers. On top of it, the datapath splits into cached
//! deterministic halves and a cheap stochastic tail
//! ([`dpe::WeightTemplate`], [`dpe::PreparedInputs`]): loops that
//! re-program or re-read the same matrices — Monte-Carlo sweeps, fault
//! yield studies, k-means passes, the CWT — pay only the noise-draw cost
//! per cycle (`benches/fig12_montecarlo.rs`, `BENCH_mc.json`).
//!
//! Beyond the paper, [`device::faults`] adds a unified fault-injection
//! subsystem (stuck-at cells, dead lines, retention at read time,
//! per-column ADC error) threaded through weight programming so faults
//! cost one mask application per prepared-weight lifetime; the
//! `fig_faults` experiment and `dpe::montecarlo::sweep_faults` report
//! accuracy/yield under it.
//!
//! The [`arch`] layer makes *placement* first-class: a [`arch::ChipSpec`]
//! (tiles × arrays-per-tile, TOML `[chip]`) plus a greedy
//! [`arch::TileAllocator`] map every weight digit plane of a network onto
//! a concrete physical array, whose global slot id keys the programming
//! noise / fault / ADC-chain streams. [`nn::Sequential::compile`] programs
//! the whole chip once and returns a forward-only [`arch::MappedModel`]
//! with micro-batched inference (`infer_batched`), tracked by
//! `benches/fig17_inference.rs` (`BENCH_fig17.json`).

pub mod apps;
pub mod arch;
pub mod circuit;
pub mod coordinator;
pub mod data;
pub mod device;
pub mod dpe;
pub mod nn;
pub mod runtime;
pub mod tensor;
pub mod util;

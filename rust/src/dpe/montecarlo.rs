//! Monte-Carlo nonideality analysis (paper Fig 12): repeated DPE matmuls
//! with freshly sampled programming noise, sweeping bit width, block size,
//! and conductance variation, reporting relative-error statistics — plus
//! the fault-injection extension ([`run_fault_point`] / [`sweep_faults`]):
//! each cycle re-programs with a fresh stuck-at/retention/ADC-error
//! pattern and the point additionally reports **yield**, the fraction of
//! programmed instances whose relative error stays within a target bound
//! (the chip-binning view of robustness).

use super::engine::{DotProductEngine, DpeConfig, SliceMethod};
use super::slicing::{DataMode, SliceSpec};
use crate::device::faults::NonIdealitySpec;
use crate::tensor::Matrix;
use crate::util::parallel::par_map;
use crate::util::rng::Pcg64;

/// One Monte-Carlo sweep point.
#[derive(Debug, Clone)]
pub struct McPoint {
    pub label: String,
    pub bits: usize,
    pub block: usize,
    pub cv: f64,
    pub mode: DataMode,
    /// Mean / std / min / max of the relative error over the cycles.
    pub re_mean: f64,
    pub re_std: f64,
    pub re_min: f64,
    pub re_max: f64,
}

/// Monte-Carlo experiment configuration.
#[derive(Debug, Clone)]
pub struct McConfig {
    /// Operand size (paper: 128×128).
    pub size: usize,
    /// Cycles per point (paper: 100).
    pub cycles: usize,
    pub base: DpeConfig,
    pub seed: u64,
}

impl Default for McConfig {
    fn default() -> Self {
        McConfig { size: 128, cycles: 100, base: DpeConfig::default(), seed: 2024 }
    }
}

/// Build a signed slice spec of `bits` total (1-bit sign slice, then 1, 2,
/// then 4-bit slices — the paper's dynamic pattern generalized).
pub fn spec_for_bits(bits: usize) -> SliceSpec {
    assert!(bits >= 2, "need at least sign + 1 bit");
    let mut widths = vec![1usize];
    let mut rest = bits - 1;
    for w in [1usize, 2] {
        if rest == 0 {
            break;
        }
        let take = w.min(rest);
        widths.push(take);
        rest -= take;
    }
    while rest > 0 {
        let take = rest.min(4);
        widths.push(take);
        rest -= take;
    }
    SliceSpec::new(&widths, true)
}

/// Run one sweep point: `cycles` independent programming cycles of the
/// same operands; each cycle re-programs with fresh noise.
pub fn run_point(cfg: &McConfig, bits: usize, block: usize, cv: f64, mode: DataMode) -> McPoint {
    let mut rng = Pcg64::new(cfg.seed, 0x4D43);
    run_point_with_operands(cfg, bits, block, cv, mode, &mut rng)
}

/// The operands [`run_point`] draws (fixed per `cfg.seed`) — public so
/// the perf bench (`benches/fig12_montecarlo.rs`) can drive the identical
/// workload through the uncached pre-split path and cross-check
/// bit-identity against the cached one.
pub fn point_operands(cfg: &McConfig) -> (Matrix, Matrix) {
    let mut rng = Pcg64::new(cfg.seed, 0x4D43);
    mc_operands(cfg, &mut rng)
}

/// The operands [`run_fault_point`] draws (fixed per `cfg.seed`).
pub fn fault_point_operands(cfg: &McConfig) -> (Matrix, Matrix) {
    let mut rng = Pcg64::new(cfg.seed, 0x4641);
    mc_operands(cfg, &mut rng)
}

/// `(mean, std, min, max)` of a non-empty relative-error sample
/// (population std, matching the paper's Fig-12 statistics).
fn re_stats(res: &[f64]) -> (f64, f64, f64, f64) {
    let n = res.len() as f64;
    let mean = res.iter().sum::<f64>() / n;
    let var = res.iter().map(|r| (r - mean) * (r - mean)).sum::<f64>() / n;
    let min = res.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = res.iter().cloned().fold(0.0, f64::max);
    (mean, var.sqrt(), min, max)
}

fn mc_operands(cfg: &McConfig, rng: &mut Pcg64) -> (Matrix, Matrix) {
    // Normal operands: per-block maxima land away from powers of two, so
    // the pre-alignment exponent rounding (vs full-precision quantization
    // coefficients) is exercised — the distinction Fig 12 plots.
    (
        Matrix::random_normal(cfg.size, cfg.size, 0.0, 1.0, rng),
        Matrix::random_normal(cfg.size, cfg.size, 0.0, 1.0, rng),
    )
}

/// The shared Monte-Carlo cycle loop: build the [`crate::dpe::WeightTemplate`]
/// and the [`crate::dpe::PreparedInputs`] **once**, then run `cfg.cycles`
/// independent programming cycles that pay only the noise/fault-draw,
/// pack, and matmul cost (engine §Perf) — bit-identical to the pre-split
/// per-cycle `prepare_weights` + `matmul_prepared` loop at the same seed.
/// Per-cycle state derives only from the cycle index, so results are
/// deterministic regardless of thread count; the per-cycle engine work
/// runs serially because the cycle-level `par_map` already saturates the
/// worker pool (no nested thread scopes).
fn mc_cycles(
    cfg: &McConfig,
    dpe_cfg: &DpeConfig,
    a: &Matrix,
    b: &Matrix,
    ideal: &Matrix,
    method: &SliceMethod,
) -> Vec<f64> {
    let setup = DotProductEngine::new(dpe_cfg.clone(), cfg.seed);
    let template = setup.weight_template(b, method);
    let inputs = setup.prepare_inputs(a, method);
    par_map(cfg.cycles, |cycle| {
        let engine = DotProductEngine::new(dpe_cfg.clone(), cfg.seed.wrapping_add(cycle as u64));
        let w = template.program_with(&engine, cycle as u64, false);
        engine
            .matmul_prepared_inputs_with(&inputs, &w, cycle as u64, false)
            .relative_error(ideal)
    })
}

fn run_point_with_operands(
    cfg: &McConfig,
    bits: usize,
    block: usize,
    cv: f64,
    mode: DataMode,
    rng: &mut Pcg64,
) -> McPoint {
    let (a, b) = mc_operands(cfg, rng);
    let ideal = a.matmul(&b);
    let spec = spec_for_bits(bits);
    let method = SliceMethod { spec, mode };
    let mut dpe_cfg = cfg.base.clone();
    dpe_cfg.array = (block, block);
    dpe_cfg.device.cv = cv;
    let res = mc_cycles(cfg, &dpe_cfg, &a, &b, &ideal, &method);
    let (re_mean, re_std, re_min, re_max) = re_stats(&res);
    McPoint {
        label: format!("{bits}b/{block}blk/cv{cv}/{mode:?}"),
        bits,
        block,
        cv,
        mode,
        re_mean,
        re_std,
        re_min,
        re_max,
    }
}

/// One fault-injection sweep point: RE statistics plus yield at a target
/// error bound.
#[derive(Debug, Clone)]
pub struct FaultPoint {
    pub label: String,
    pub bits: usize,
    pub cv: f64,
    /// Combined per-cell stuck-at rate of the swept spec (reporting key).
    pub fault_rate: f64,
    pub re_mean: f64,
    pub re_std: f64,
    pub re_max: f64,
    /// Fraction of Monte-Carlo cycles (independently programmed array
    /// instances) with relative error ≤ the point's yield bound.
    pub yield_frac: f64,
    /// The RE bound used for `yield_frac`.
    pub yield_re: f64,
}

/// Run one fault point: `cfg.cycles` independent programming cycles of
/// the same operands under `ni`, each with a fresh fault pattern (the
/// engine seed varies per cycle, which reseeds both the programming noise
/// and the injection streams). Deterministic in `cfg.seed` regardless of
/// thread count: per-cycle state derives only from the cycle index. The
/// deterministic quantize/slice work is cached across cycles via the
/// weight template and prepared inputs (engine §Perf) — only the noise,
/// fault, and ADC-chain draws differ between cycles, so only they are
/// re-done.
pub fn run_fault_point(
    cfg: &McConfig,
    bits: usize,
    cv: f64,
    ni: &NonIdealitySpec,
    yield_re: f64,
) -> FaultPoint {
    let mut rng = Pcg64::new(cfg.seed, 0x4641);
    let (a, b) = mc_operands(cfg, &mut rng);
    let ideal = a.matmul(&b);
    let method = SliceMethod { spec: spec_for_bits(bits), mode: DataMode::Quantize };
    let mut dpe_cfg = cfg.base.clone();
    dpe_cfg.device.cv = cv;
    dpe_cfg.nonideal = ni.clone();
    let res = mc_cycles(cfg, &dpe_cfg, &a, &b, &ideal, &method);
    let (re_mean, re_std, _, re_max) = re_stats(&res);
    let good = res.iter().filter(|&&r| r <= yield_re).count();
    let fault_rate = ni.faults.cell_rate();
    FaultPoint {
        label: format!("{bits}b/cv{cv}/fault{fault_rate}"),
        bits,
        cv,
        fault_rate,
        re_mean,
        re_std,
        re_max,
        yield_frac: good as f64 / res.len() as f64,
        yield_re,
    }
}

/// The fault-injection sweep grid: symmetric stuck-at cell rates
/// (`sa0 = sa1 = rate/2`) × conductance variation × bit width. Only the
/// cell rates of `base` are overridden — its dead-line rates,
/// retention/ADC knobs, and injection seed carry through to every point.
/// Yield is evaluated at `yield_re`.
pub fn sweep_faults(
    cfg: &McConfig,
    bits: &[usize],
    cvs: &[f64],
    rates: &[f64],
    base: &NonIdealitySpec,
    yield_re: f64,
) -> Vec<FaultPoint> {
    let mut out = Vec::new();
    for &b in bits {
        for &cv in cvs {
            for &rate in rates {
                let mut ni = base.clone();
                ni.faults.sa0 = rate / 2.0;
                ni.faults.sa1 = rate / 2.0;
                out.push(run_fault_point(cfg, b, cv, &ni, yield_re));
            }
        }
    }
    out
}

/// The full Fig-12-style sweep grid.
pub fn sweep(
    cfg: &McConfig,
    bits: &[usize],
    blocks: &[usize],
    cvs: &[f64],
    modes: &[DataMode],
) -> Vec<McPoint> {
    let mut rng = Pcg64::new(cfg.seed, 0x57EE9);
    let mut out = Vec::new();
    for &mode in modes {
        for &b in bits {
            for &blk in blocks {
                for &cv in cvs {
                    out.push(run_point_with_operands(cfg, b, blk, cv, mode, &mut rng));
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> McConfig {
        McConfig { size: 32, cycles: 8, ..McConfig::default() }
    }

    #[test]
    fn spec_for_bits_patterns() {
        assert_eq!(spec_for_bits(4).widths, vec![1, 1, 2]);
        assert_eq!(spec_for_bits(8).widths, vec![1, 1, 2, 4]);
        assert_eq!(spec_for_bits(12).widths, vec![1, 1, 2, 4, 4]);
        assert_eq!(spec_for_bits(2).widths, vec![1, 1]);
        for bits in 2..=24 {
            assert_eq!(spec_for_bits(bits).total_bits(), bits);
        }
    }

    #[test]
    fn more_bits_lower_error() {
        let cfg = small_cfg();
        let p4 = run_point(&cfg, 4, 32, 0.02, DataMode::Quantize);
        let p8 = run_point(&cfg, 8, 32, 0.02, DataMode::Quantize);
        assert!(p8.re_mean < p4.re_mean, "8b {} vs 4b {}", p8.re_mean, p4.re_mean);
    }

    #[test]
    fn more_variation_higher_error() {
        let cfg = small_cfg();
        let lo = run_point(&cfg, 8, 32, 0.01, DataMode::Quantize);
        let hi = run_point(&cfg, 8, 32, 0.2, DataMode::Quantize);
        assert!(hi.re_mean > lo.re_mean, "hi {} vs lo {}", hi.re_mean, lo.re_mean);
    }

    #[test]
    fn quantize_beats_prealign() {
        // Fig 12: at matched slice config the full-precision quantization
        // coefficient beats the power-of-two shared exponent. Needs enough
        // blocks for the per-block exponent rounding to average out.
        let cfg = McConfig { size: 64, cycles: 10, seed: 99, ..McConfig::default() };
        let q = run_point(&cfg, 6, 32, 0.01, DataMode::Quantize);
        let p = run_point(&cfg, 6, 32, 0.01, DataMode::PreAlign);
        assert!(q.re_mean < p.re_mean, "q {} vs p {}", q.re_mean, p.re_mean);
    }

    #[test]
    fn cached_cycles_bit_identical_to_presplit_loop() {
        // The acceptance invariant of the template split: `run_point` and
        // `run_fault_point` must be bit-identical at the same seed to the
        // pre-split implementation, i.e. a per-cycle
        // `prepare_weights` + `matmul_prepared` loop over the same
        // operands.
        let cfg = McConfig { size: 24, cycles: 5, ..McConfig::default() };
        let presplit = |dpe_cfg: &DpeConfig, a: &Matrix, b: &Matrix, method: &SliceMethod| {
            let ideal = a.matmul(b);
            let res: Vec<f64> = (0..cfg.cycles)
                .map(|cycle| {
                    let engine = DotProductEngine::new(
                        dpe_cfg.clone(),
                        cfg.seed.wrapping_add(cycle as u64),
                    );
                    let w = engine.prepare_weights(b, method, cycle as u64);
                    engine
                        .matmul_prepared(a, &w, method, cycle as u64)
                        .relative_error(&ideal)
                })
                .collect();
            re_stats(&res)
        };
        for mode in [DataMode::Quantize, DataMode::PreAlign] {
            let p = run_point(&cfg, 8, 16, 0.05, mode);
            let (a, b) = point_operands(&cfg);
            let method = SliceMethod { spec: spec_for_bits(8), mode };
            let mut dpe_cfg = cfg.base.clone();
            dpe_cfg.array = (16, 16);
            dpe_cfg.device.cv = 0.05;
            let (mean, std, min, max) = presplit(&dpe_cfg, &a, &b, &method);
            assert_eq!(p.re_mean.to_bits(), mean.to_bits(), "{mode:?} mean");
            assert_eq!(p.re_std.to_bits(), std.to_bits(), "{mode:?} std");
            assert_eq!(p.re_min.to_bits(), min.to_bits(), "{mode:?} min");
            assert_eq!(p.re_max.to_bits(), max.to_bits(), "{mode:?} max");
        }
        // Fault path: stuck-at cells + per-column ADC error active.
        let mut ni = NonIdealitySpec::none();
        ni.faults = crate::device::faults::FaultSpec::cells(0.05);
        ni.adc.offset_std_lsb = 0.3;
        let fp = run_fault_point(&cfg, 8, 0.05, &ni, 0.1);
        let (a, b) = fault_point_operands(&cfg);
        let method = SliceMethod { spec: spec_for_bits(8), mode: DataMode::Quantize };
        let mut dpe_cfg = cfg.base.clone();
        dpe_cfg.device.cv = 0.05;
        dpe_cfg.nonideal = ni;
        let (mean, std, _, max) = presplit(&dpe_cfg, &a, &b, &method);
        assert_eq!(fp.re_mean.to_bits(), mean.to_bits(), "fault mean");
        assert_eq!(fp.re_std.to_bits(), std.to_bits(), "fault std");
        assert_eq!(fp.re_max.to_bits(), max.to_bits(), "fault max");
    }

    #[test]
    fn fault_point_degrades_with_rate() {
        let cfg = small_cfg();
        let clean = run_fault_point(&cfg, 8, 0.02, &NonIdealitySpec::none(), 0.05);
        let mut ni = NonIdealitySpec::none();
        ni.faults = crate::device::faults::FaultSpec::cells(0.2);
        let faulty = run_fault_point(&cfg, 8, 0.02, &ni, 0.05);
        assert!(
            faulty.re_mean > clean.re_mean,
            "20% stuck cells must raise RE: {} vs {}",
            faulty.re_mean,
            clean.re_mean
        );
        assert!(faulty.yield_frac <= clean.yield_frac);
        for p in [&clean, &faulty] {
            assert!((0.0..=1.0).contains(&p.yield_frac));
            assert!(p.re_mean.is_finite() && p.re_mean >= 0.0);
        }
    }

    #[test]
    fn fault_sweep_grid_size_and_labels() {
        let cfg = McConfig { size: 16, cycles: 3, ..McConfig::default() };
        let pts = sweep_faults(
            &cfg,
            &[4, 8],
            &[0.0, 0.05],
            &[0.0, 0.05],
            &NonIdealitySpec::none(),
            0.1,
        );
        assert_eq!(pts.len(), 8);
        assert!(pts.iter().all(|p| p.yield_re == 0.1));
        assert!(pts.iter().any(|p| p.fault_rate == 0.0) && pts.iter().any(|p| p.fault_rate > 0.0));
    }

    #[test]
    fn sweep_grid_size() {
        let cfg = McConfig { size: 16, cycles: 3, ..McConfig::default() };
        let pts = sweep(&cfg, &[4, 8], &[16, 32], &[0.05], &[DataMode::Quantize, DataMode::PreAlign]);
        assert_eq!(pts.len(), 8);
        assert!(pts.iter().all(|p| p.re_mean.is_finite() && p.re_mean >= 0.0));
        assert!(pts.iter().all(|p| p.re_min <= p.re_mean && p.re_mean <= p.re_max));
    }
}

//! Dynamic bit-slicing (paper §2.2, Fig 1) and block quantization /
//! pre-alignment (§3.3, Fig 5).
//!
//! A [`SliceSpec`] lists slice widths **from MSB to LSB**, e.g. the paper's
//! INT8 method `(1, 1, 2, 4)`. For signed data the first slice must be the
//! 1-bit sign slice; its contribution carries weight `−2^(B−1)` in the
//! digital shift-and-add recombination, which keeps every stored digit
//! non-negative (required: conductances are non-negative) while staying
//! linear in the digits — exactly two's complement.
//!
//! Continuous data enters the integer domain one of two ways (Fig 5):
//! - **Quantization** (INT path): per-block scale `s = max|x| / (2^(B−1)−1)`,
//!   stored as a full-precision coefficient in the digital periphery;
//! - **Pre-alignment** (FP path): the block shares one exponent
//!   `e = ⌈log₂ max|x|⌉`, so the scale is constrained to a power of two
//!   (`s = 2^e / (2^(B−1))`) — cheaper hardware, up to one bit worse, which
//!   is precisely the quantization-vs-pre-alignment gap of Fig 12.

use crate::tensor::{DigitPlanes, Matrix};
use anyhow::{bail, Result};

/// Largest allowed [`SliceSpec::total_bits`]. Two ceilings meet here:
/// `slice_digits`' two's-complement modulus is `1i64 << total` (UB at 63+),
/// and the integer-GEMM exactness argument (`tensor` §Perf) needs digit
/// partial sums below `2^53` — a 52-bit integer range keeps every
/// representable value itself f64-exact with room for the sign bit.
pub const MAX_TOTAL_BITS: usize = 52;

/// How continuous values map to integers before slicing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataMode {
    /// Full-precision per-block scale coefficient (INT path).
    Quantize,
    /// Power-of-two shared exponent per block (FP path).
    PreAlign,
}

/// Slice widths, MSB first. `signed` data requires `widths[0] == 1`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SliceSpec {
    pub widths: Vec<usize>,
    pub signed: bool,
}

impl SliceSpec {
    /// Validating constructor: every failure names the offending slice, so
    /// TOML / CLI method strings get an actionable error instead of a
    /// release-mode silent digit truncation (digits are stored as `u8`, so
    /// a slice wider than 8 bits would corrupt data downstream).
    pub fn try_new(widths: &[usize], signed: bool) -> Result<Self> {
        if widths.is_empty() {
            bail!("need at least one slice");
        }
        for (k, &w) in widths.iter().enumerate() {
            if !(1..=8).contains(&w) {
                bail!(
                    "slice widths must be 1..=8 bits: slice {k} (MSB-first) of {widths:?} \
                     is {w} bits — digits are stored as bytes"
                );
            }
        }
        if signed && widths[0] != 1 {
            bail!(
                "signed data needs a 1-bit sign slice first: slice 0 of {widths:?} is {} bits",
                widths[0]
            );
        }
        let total: usize = widths.iter().sum();
        if total > MAX_TOTAL_BITS {
            bail!(
                "slice widths {widths:?} sum to {total} bits, above the {MAX_TOTAL_BITS}-bit \
                 limit (two's-complement modulus and f64-exact digit arithmetic)"
            );
        }
        Ok(SliceSpec { widths: widths.to_vec(), signed })
    }

    /// Panicking form of [`SliceSpec::try_new`] for the hard-coded named
    /// methods and tests.
    pub fn new(widths: &[usize], signed: bool) -> Self {
        match Self::try_new(widths, signed) {
            Ok(spec) => spec,
            Err(e) => panic!("{e}"),
        }
    }

    /// Total bits across slices.
    pub fn total_bits(&self) -> usize {
        self.widths.iter().sum()
    }

    pub fn num_slices(&self) -> usize {
        self.widths.len()
    }

    /// Bit position (shift) of the LSB of each slice, MSB-first order.
    pub fn shifts(&self) -> Vec<u32> {
        let total: usize = self.total_bits();
        let mut shifts = Vec::with_capacity(self.widths.len());
        let mut used = 0usize;
        for &w in &self.widths {
            used += w;
            shifts.push((total - used) as u32);
        }
        shifts
    }

    /// Signed weight of slice `k` in the recombination:
    /// sign slice → `−2^shift`, others → `+2^shift`. Rebuilds the shift
    /// list per call — loops over every slice should use
    /// [`SliceSpec::tables`] instead.
    pub fn weight(&self, k: usize) -> f64 {
        let shift = self.shifts()[k];
        let w = (shift as f64).exp2();
        if self.signed && k == 0 {
            -w
        } else {
            w
        }
    }

    /// Largest representable integer.
    pub fn max_int(&self) -> i64 {
        if self.signed {
            (1i64 << (self.total_bits() - 1)) - 1
        } else {
            (1i64 << self.total_bits()) - 1
        }
    }

    /// Smallest representable integer.
    pub fn min_int(&self) -> i64 {
        if self.signed {
            -(1i64 << (self.total_bits() - 1))
        } else {
            0
        }
    }

    // ---- paper's named slice methods ----

    /// INT4 (1,1,2) — Fig 16.
    pub fn int4() -> Self {
        SliceSpec::new(&[1, 1, 2], true)
    }
    /// INT8 (1,1,2,4) — Fig 15/16.
    pub fn int8() -> Self {
        SliceSpec::new(&[1, 1, 2, 4], true)
    }
    /// FP16-effective (1,1,2,4,4) — Fig 16 (sign + 11 mantissa bits).
    pub fn fp16() -> Self {
        SliceSpec::new(&[1, 1, 2, 4, 4], true)
    }
    /// BF16-effective (1,1,2,4) — 8 mantissa bits incl. sign.
    pub fn bf16() -> Self {
        SliceSpec::new(&[1, 1, 2, 4], true)
    }
    /// FP32-effective (1,1,2,4,4,4,4,4) — 24 mantissa bits incl. sign.
    pub fn fp32() -> Self {
        SliceSpec::new(&[1, 1, 2, 4, 4, 4, 4, 4], true)
    }
    /// FlexPoint16+5 (1,1,2,4,4,4) — 16-bit mantissa, 5-bit shared exponent.
    pub fn flex16() -> Self {
        SliceSpec::new(&[1, 1, 2, 4, 4, 4], true)
    }
    /// Uniform 1-bit slices (Fig 17's INTn = (1,)*n).
    pub fn ones(n: usize) -> Self {
        SliceSpec::new(&vec![1; n], true)
    }
    /// 26-bit solver method with ≤2-bit slices: keeps every slice-pair
    /// readout within the ADC's integer-exact range (used with
    /// `AdcPolicy::Calibrated` for the Fig 13 equation solver).
    pub fn solver26() -> Self {
        SliceSpec::new(&[1, 1, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2], true)
    }

    /// Precompute the per-slice lookup tables the matmul hot paths need
    /// (signed shift-add weights and per-slice digit maxima), instead of
    /// re-deriving them per call site. The shift list is computed **once**
    /// here — per-slice [`SliceSpec::weight`] calls would rebuild it per
    /// slice, making every table O(S²) allocations.
    pub fn tables(&self) -> SliceTables {
        let shifts = self.shifts();
        SliceTables {
            weights: shifts
                .iter()
                .enumerate()
                .map(|(k, &sh)| {
                    let w = (sh as f64).exp2();
                    if self.signed && k == 0 { -w } else { w }
                })
                .collect(),
            max_digit: self.widths.iter().map(|&w| ((1u64 << w) - 1) as f64).collect(),
        }
    }
}

/// Precomputed per-slice tables shared by the DPE matmul entry points
/// (stacked pipeline, circuit path, and weight preparation): the signed
/// recombination weight and the largest digit value of each slice.
#[derive(Debug, Clone, PartialEq)]
pub struct SliceTables {
    /// Signed shift-add weight per slice (`−2^shift` for the sign slice).
    pub weights: Vec<f64>,
    /// Largest representable digit per slice: `2^width − 1`.
    pub max_digit: Vec<f64>,
}

impl SliceTables {
    pub fn num_slices(&self) -> usize {
        self.weights.len()
    }
}

/// A quantized block: integer values (stored as f64) plus the scale that
/// recovers the original data (`x ≈ q · scale`).
#[derive(Debug, Clone)]
pub struct QuantizedBlock {
    pub q: Matrix,
    pub scale: f64,
}

/// The per-block scale for `mode` given the block's abs-max — the single
/// source of truth shared by [`quantize_block`] and
/// [`quantize_slice_block`]. `max_abs` must be nonzero.
fn block_scale(max_abs: f64, max_int: f64, mode: DataMode) -> f64 {
    match mode {
        DataMode::Quantize => max_abs / max_int,
        DataMode::PreAlign => {
            // Shared exponent: smallest power of two ≥ max_abs, then the
            // mantissa uses total_bits−1 magnitude bits.
            let e = max_abs.log2().ceil();
            e.exp2() / (max_int + 1.0)
        }
    }
}

/// Map one continuous value to its block integer (round, then clamp to the
/// spec range) — shared by both quantize paths so they cannot drift.
#[inline]
fn quantize_value(v: f64, scale: f64, min_int: f64, max_int: f64) -> f64 {
    (v / scale).round().clamp(min_int, max_int)
}

/// Quantize a block to the spec's integer range using `mode`.
pub fn quantize_block(x: &Matrix, spec: &SliceSpec, mode: DataMode) -> QuantizedBlock {
    let max_abs = x.abs_max();
    if max_abs == 0.0 {
        return QuantizedBlock { q: Matrix::zeros(x.rows, x.cols), scale: 0.0 };
    }
    let max_int = spec.max_int() as f64;
    let scale = block_scale(max_abs, max_int, mode);
    let min_int = spec.min_int() as f64;
    let q = x.map(|v| quantize_value(v, scale, min_int, max_int));
    QuantizedBlock { q, scale }
}

/// Slice an integer matrix (two's complement) into per-slice digit
/// matrices, MSB first. Every digit is in `[0, 2^width_k)`. Cold-path /
/// test form — the matmul pipeline uses [`quantize_slice_block`], which
/// fills byte-packed [`DigitPlanes`] in the same pass as quantization.
pub fn slice_digits(q: &Matrix, spec: &SliceSpec) -> Vec<Matrix> {
    let total = spec.total_bits() as u32;
    let modulus = 1i64 << total;
    let shifts = spec.shifts();
    let masks: Vec<u64> = spec.widths.iter().map(|&w| (1u64 << w) - 1).collect();
    let mut out: Vec<Matrix> =
        spec.widths.iter().map(|_| Matrix::zeros(q.rows, q.cols)).collect();
    for (idx, &v) in q.data.iter().enumerate() {
        let vi = v as i64;
        debug_assert!(
            vi >= spec.min_int() && vi <= spec.max_int(),
            "value {vi} outside spec range"
        );
        // Two's complement representation.
        let u = vi.rem_euclid(modulus) as u64;
        for (k, plane) in out.iter_mut().enumerate() {
            plane.data[idx] = ((u >> shifts[k]) & masks[k]) as f64;
        }
    }
    out
}

/// A quantized block already sliced into byte-packed digit planes plus the
/// scale recovering the original data — the fused output of
/// [`quantize_slice_block`].
#[derive(Debug, Clone)]
pub struct SlicedBlock {
    pub planes: DigitPlanes,
    pub scale: f64,
}

/// Fused quantize + slice: one pass over the data maps each element to its
/// integer value and writes all of its digits straight into byte-packed
/// [`DigitPlanes`] — no intermediate integer matrix, no per-element
/// re-derivation of shifts and masks. Digit-for-digit (and
/// scale-for-scale) identical to
/// `slice_digits(&quantize_block(x, spec, mode).q, spec)`: the per-element
/// arithmetic is the same `round → clamp → two's complement → shift/mask`
/// sequence. The standalone functions remain for cold paths and tests.
pub fn quantize_slice_block(x: &Matrix, spec: &SliceSpec, mode: DataMode) -> SlicedBlock {
    let n_slices = spec.num_slices();
    let max_abs = x.abs_max();
    if max_abs == 0.0 {
        return SlicedBlock { planes: DigitPlanes::zeroed(n_slices, x.rows, x.cols), scale: 0.0 };
    }
    let max_int = spec.max_int() as f64;
    let scale = block_scale(max_abs, max_int, mode);
    let min_int = spec.min_int() as f64;
    let total = spec.total_bits() as u32;
    let modulus = 1i64 << total;
    let shifts = spec.shifts();
    let masks: Vec<u64> = spec.widths.iter().map(|&w| (1u64 << w) - 1).collect();
    // Hard (release-mode) guard for the `as u8` narrowing below: a mask
    // wider than a byte would silently corrupt digits. `try_new` already
    // enforces widths ≤ 8, so this can only fire on a hand-built spec.
    assert!(masks.iter().all(|&m| m <= 0xFF), "slice mask wider than a byte");
    let mut planes = DigitPlanes::zeroed(n_slices, x.rows, x.cols);
    for i in 0..x.rows {
        for (kk, &v) in x.row(i).iter().enumerate() {
            let q = quantize_value(v, scale, min_int, max_int);
            let u = (q as i64).rem_euclid(modulus) as u64;
            for s in 0..n_slices {
                // Masked to ≤ 8 bits (asserted above), so the narrowing is
                // lossless.
                planes.set(s, i, kk, ((u >> shifts[s]) & masks[s]) as u8);
            }
        }
    }
    SlicedBlock { planes, scale }
}

/// Recombine digit matrices back to the integer matrix (shift-and-add with
/// the sign-slice weight). Inverse of [`slice_digits`].
pub fn reconstruct(digits: &[Matrix], spec: &SliceSpec) -> Matrix {
    assert_eq!(digits.len(), spec.num_slices());
    let tables = spec.tables();
    let mut out = Matrix::zeros(digits[0].rows, digits[0].cols);
    for (k, d) in digits.iter().enumerate() {
        let w = tables.weights[k];
        for (o, &v) in out.data.iter_mut().zip(&d.data) {
            *o += w * v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;
    use crate::util::rng::Pcg64;

    #[test]
    fn shifts_and_weights_int8() {
        let s = SliceSpec::int8();
        assert_eq!(s.total_bits(), 8);
        assert_eq!(s.shifts(), vec![7, 6, 4, 0]);
        assert_eq!(s.weight(0), -128.0);
        assert_eq!(s.weight(1), 64.0);
        assert_eq!(s.weight(2), 16.0);
        assert_eq!(s.weight(3), 1.0);
        assert_eq!(s.max_int(), 127);
        assert_eq!(s.min_int(), -128);
    }

    #[test]
    fn slice_reconstruct_exhaustive_int8() {
        let s = SliceSpec::int8();
        let vals: Vec<f64> = (-128..=127).map(|v| v as f64).collect();
        let q = Matrix::from_vec(16, 16, vals.clone());
        let digits = slice_digits(&q, &s);
        // All digits within width range.
        for (k, d) in digits.iter().enumerate() {
            let max = (1u64 << s.widths[k]) as f64;
            assert!(d.data.iter().all(|&x| x >= 0.0 && x < max));
        }
        let r = reconstruct(&digits, &s);
        assert_eq!(r.data, vals);
    }

    #[test]
    fn slice_reconstruct_roundtrip_property() {
        prop_check("slice/reconstruct roundtrip", 300, |g| {
            // Random spec: signed, 1-bit first slice, 1..5 more slices.
            let n_extra = g.usize_in(1..=4);
            let mut widths = vec![1usize];
            for _ in 0..n_extra {
                widths.push(g.usize_in(1..=4));
            }
            let spec = SliceSpec::new(&widths, true);
            let rows = g.usize_in(1..=8);
            let cols = g.usize_in(1..=8);
            let vals: Vec<f64> = (0..rows * cols)
                .map(|_| g.i64_in(spec.min_int()..=spec.max_int()) as f64)
                .collect();
            let q = Matrix::from_vec(rows, cols, vals.clone());
            let r = reconstruct(&slice_digits(&q, &spec), &spec);
            if r.data != vals {
                return Err(format!("roundtrip failed for widths {widths:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn unsigned_slicing_roundtrip() {
        let spec = SliceSpec::new(&[2, 2], false);
        let vals: Vec<f64> = (0..16).map(|v| v as f64).collect();
        let q = Matrix::from_vec(4, 4, vals.clone());
        let r = reconstruct(&slice_digits(&q, &spec), &spec);
        assert_eq!(r.data, vals);
    }

    #[test]
    fn quantize_block_error_bound() {
        let mut rng = Pcg64::seeded(51);
        let spec = SliceSpec::int8();
        let x = Matrix::random_uniform(32, 32, -3.0, 3.0, &mut rng);
        for mode in [DataMode::Quantize, DataMode::PreAlign] {
            let qb = quantize_block(&x, &spec, mode);
            let recon = qb.q.scale(qb.scale);
            let max_err = recon.sub(&x).abs_max();
            // Error ≤ scale/2 per element.
            assert!(max_err <= qb.scale / 2.0 + 1e-12, "{mode:?}: {max_err}");
        }
    }

    #[test]
    fn quantize_beats_prealign_scale() {
        // Quantization uses the full integer range; pre-alignment rounds the
        // scale up to a power of two, so its step can be up to 2× coarser.
        let mut rng = Pcg64::seeded(52);
        let x = Matrix::random_uniform(16, 16, -1.3, 1.3, &mut rng);
        let spec = SliceSpec::int8();
        let q = quantize_block(&x, &spec, DataMode::Quantize);
        let p = quantize_block(&x, &spec, DataMode::PreAlign);
        assert!(q.scale <= p.scale + 1e-18);
        assert!(p.scale / q.scale <= 2.0 + 1e-9);
    }

    #[test]
    fn prealign_scale_is_power_of_two_multiple() {
        let mut rng = Pcg64::seeded(53);
        let spec = SliceSpec::int8();
        let x = Matrix::random_uniform(8, 8, -5.0, 5.0, &mut rng);
        let p = quantize_block(&x, &spec, DataMode::PreAlign);
        // scale * 2^(B-1) must be a power of two.
        let v = p.scale * (spec.max_int() as f64 + 1.0);
        let l = v.log2();
        assert!((l - l.round()).abs() < 1e-9, "scale={}", p.scale);
    }

    #[test]
    fn zero_block_quantizes_to_zero() {
        let spec = SliceSpec::int8();
        let x = Matrix::zeros(4, 4);
        let qb = quantize_block(&x, &spec, DataMode::Quantize);
        assert_eq!(qb.scale, 0.0);
        assert!(qb.q.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn quantized_dot_product_accuracy_property() {
        // End-to-end digit-domain check: quantize → slice → exact digit
        // matmul with recombination == matmul of quantized values.
        prop_check("sliced matmul equals quantized matmul", 50, |g| {
            let spec = SliceSpec::int8();
            let m = g.usize_in(1..=6);
            let k = g.usize_in(1..=6);
            let n = g.usize_in(1..=6);
            let mut mk_int = |rows: usize, cols: usize, g: &mut crate::util::prop::Gen| {
                let vals: Vec<f64> = (0..rows * cols)
                    .map(|_| g.i64_in(-128..=127) as f64)
                    .collect();
                Matrix::from_vec(rows, cols, vals)
            };
            let a = mk_int(m, k, g);
            let b = mk_int(k, n, g);
            let a_sl = slice_digits(&a, &spec);
            let b_sl = slice_digits(&b, &spec);
            let mut acc = Matrix::zeros(m, n);
            for (ka, da) in a_sl.iter().enumerate() {
                for (kb, db) in b_sl.iter().enumerate() {
                    let part = da.matmul(db);
                    let w = spec.weight(ka) * spec.weight(kb);
                    acc = acc.add(&part.scale(w));
                }
            }
            let ideal = a.matmul(&b);
            if acc.relative_error(&ideal) > 1e-12 {
                return Err(format!("re={}", acc.relative_error(&ideal)));
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "sign slice")]
    fn signed_spec_requires_sign_slice() {
        SliceSpec::new(&[2, 2], true);
    }

    #[test]
    fn try_new_errors_name_the_offending_slice() {
        let e = SliceSpec::try_new(&[1, 9, 2], true).unwrap_err().to_string();
        assert!(e.contains("slice 1") && e.contains("9 bits"), "{e}");
        let e = SliceSpec::try_new(&[], true).unwrap_err().to_string();
        assert!(e.contains("at least one slice"), "{e}");
        let e = SliceSpec::try_new(&[2, 2], true).unwrap_err().to_string();
        assert!(e.contains("sign slice") && e.contains("2 bits"), "{e}");
        // 7×8 = 56 bits blows the 52-bit total cap even though every
        // individual width is legal.
        let e = SliceSpec::try_new(&[8; 7], false).unwrap_err().to_string();
        assert!(e.contains("56 bits") && e.contains("52"), "{e}");
        assert!(SliceSpec::try_new(&[1, 1, 2, 4], true).is_ok());
        assert!(SliceSpec::try_new(&[8; 6], false).is_ok(), "48 bits is within the cap");
    }

    #[test]
    #[should_panic(expected = "slice widths must be 1..=8")]
    fn new_panics_on_wide_slice() {
        // The release-build silent-truncation path this guards: a 12-bit
        // slice's digits don't fit the u8 planes.
        SliceSpec::new(&[1, 12], true);
    }

    /// A random slice spec: signed (1-bit sign slice first) or unsigned,
    /// 1–5 further slices of 1..=8 bits.
    fn random_spec(g: &mut crate::util::prop::Gen) -> SliceSpec {
        let signed = g.bool();
        let mut widths = vec![if signed { 1 } else { g.usize_in(1..=8) }];
        for _ in 0..g.usize_in(1..=4) {
            widths.push(g.usize_in(1..=8));
        }
        SliceSpec::new(&widths, signed)
    }

    #[test]
    fn prop_digit_planes_roundtrip_against_slice_digits() {
        // Byte-packed DigitPlanes must reproduce the f64 slice_digits
        // planes exactly for random specs × ragged shapes, and the sign
        // mask must mirror plane-0 nonzeros exactly (write-once build).
        prop_check("DigitPlanes round-trips slice_digits", 120, |g| {
            let spec = random_spec(g);
            let rows = g.usize_in(1..=9);
            let cols = g.usize_in(1..=130);
            let vals: Vec<f64> = (0..rows * cols)
                .map(|_| g.i64_in(spec.min_int()..=spec.max_int()) as f64)
                .collect();
            let q = Matrix::from_vec(rows, cols, vals);
            let slices = slice_digits(&q, &spec);
            let dp = DigitPlanes::from_slices(&slices);
            for (s, sl) in slices.iter().enumerate() {
                if &dp.plane(s) != sl {
                    return Err(format!("widths {:?}: plane {s} differs", spec.widths));
                }
            }
            // The sign mask must mirror plane-0 nonzeros exactly (the
            // kernel's zero-skip correctness bound: no missing bits).
            for i in 0..rows {
                let mrow = dp.sign_row_mask(i);
                for kk in 0..cols {
                    let bit = (mrow[kk >> 6] >> (kk & 63)) & 1 == 1;
                    if bit != (slices[0].at(i, kk) != 0.0) {
                        return Err(format!("widths {:?}: mask bit ({i},{kk})", spec.widths));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_fused_quantize_slice_matches_two_pass() {
        // The fused single-pass quantize+slice must be scale- and
        // digit-identical to quantize_block followed by slice_digits, for
        // both data modes and random specs × ragged shapes.
        prop_check("quantize_slice_block == quantize_block + slice_digits", 120, |g| {
            let spec = random_spec(g);
            let mode = *g.choose(&[DataMode::Quantize, DataMode::PreAlign]);
            let rows = g.usize_in(1..=8);
            let cols = g.usize_in(1..=90);
            // Mix in an occasional all-zero block (scale-0 path).
            let x = if g.usize_in(0..=19) == 0 {
                Matrix::zeros(rows, cols)
            } else {
                Matrix::from_vec(rows, cols, g.vec_f64_multiscale(rows * cols))
            };
            let fused = quantize_slice_block(&x, &spec, mode);
            let qb = quantize_block(&x, &spec, mode);
            if fused.scale.to_bits() != qb.scale.to_bits() {
                return Err(format!(
                    "widths {:?} {mode:?}: scale {} vs {}",
                    spec.widths, fused.scale, qb.scale
                ));
            }
            let slices = slice_digits(&qb.q, &spec);
            for (s, sl) in slices.iter().enumerate() {
                if &fused.planes.plane(s) != sl {
                    return Err(format!("widths {:?} {mode:?}: plane {s} differs", spec.widths));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn tables_match_per_slice_queries() {
        for spec in [SliceSpec::int4(), SliceSpec::int8(), SliceSpec::fp16(), SliceSpec::ones(5)] {
            let t = spec.tables();
            assert_eq!(t.num_slices(), spec.num_slices());
            for k in 0..spec.num_slices() {
                assert_eq!(t.weights[k], spec.weight(k));
                assert_eq!(t.max_digit[k], ((1u64 << spec.widths[k]) - 1) as f64);
            }
        }
    }

    #[test]
    fn named_formats_bits() {
        assert_eq!(SliceSpec::int4().total_bits(), 4);
        assert_eq!(SliceSpec::int8().total_bits(), 8);
        assert_eq!(SliceSpec::fp16().total_bits(), 12);
        assert_eq!(SliceSpec::bf16().total_bits(), 8);
        assert_eq!(SliceSpec::fp32().total_bits(), 24);
        assert_eq!(SliceSpec::flex16().total_bits(), 16);
        assert_eq!(SliceSpec::ones(5).total_bits(), 5);
    }
}

//! Block matrix mapping (paper §3.3, Fig 7).
//!
//! Matrices larger than the physical array are decomposed into
//! `l_blk_m × l_blk_n` submatrices; each block gets its own quantization
//! coefficient or shared exponent (shrinking the pre-processing error with
//! the block size), and matrices whose dimensions are not divisible by the
//! array size are zero-padded.

/// A block partition of one matrix dimension.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockDim {
    pub total: usize,
    pub block: usize,
}

impl BlockDim {
    pub fn new(total: usize, block: usize) -> Self {
        assert!(block > 0, "block size must be positive");
        BlockDim { total, block }
    }

    /// Number of blocks (ceil division).
    pub fn count(&self) -> usize {
        self.total.div_ceil(self.block)
    }

    /// Padded total length.
    pub fn padded(&self) -> usize {
        self.count() * self.block
    }

    /// (start, len) of block `i` in the *unpadded* matrix; the last block
    /// may be short (the remainder is the zero padding).
    pub fn range(&self, i: usize) -> (usize, usize) {
        assert!(i < self.count());
        let start = i * self.block;
        (start, self.block.min(self.total - start))
    }
}

/// Block grid for a matmul `A(m×k) · B(k×n)` on arrays of `l_m × l_n`
/// devices: the contraction dimension `k` is split by the array's row count
/// `l_m` and the output dimension `n` by the array's column count `l_n`.
#[derive(Debug, Clone, Copy)]
pub struct MatmulBlocks {
    pub k: BlockDim,
    pub n: BlockDim,
}

impl MatmulBlocks {
    pub fn new(k_total: usize, n_total: usize, array: (usize, usize)) -> Self {
        MatmulBlocks {
            k: BlockDim::new(k_total, array.0),
            n: BlockDim::new(n_total, array.1),
        }
    }

    /// Number of physical arrays per weight slice.
    pub fn arrays_per_slice(&self) -> usize {
        self.k.count() * self.n.count()
    }

    /// Total `(k-block, n-block)` array pairs — the flat task count of the
    /// fused matmul pipeline (alias of [`Self::arrays_per_slice`], named
    /// for the scheduling view).
    pub fn pair_count(&self) -> usize {
        self.arrays_per_slice()
    }

    /// Decompose a flat pair index into `(kb, nb)`; pairs are laid out
    /// row-major over n-blocks, matching the `kb * n_count + nb` block
    /// storage order of `PreparedWeights`.
    pub fn pair(&self, idx: usize) -> (usize, usize) {
        let nc = self.n.count();
        (idx / nc, idx % nc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_division() {
        let d = BlockDim::new(128, 64);
        assert_eq!(d.count(), 2);
        assert_eq!(d.padded(), 128);
        assert_eq!(d.range(0), (0, 64));
        assert_eq!(d.range(1), (64, 64));
    }

    #[test]
    fn remainder_padding() {
        let d = BlockDim::new(100, 64);
        assert_eq!(d.count(), 2);
        assert_eq!(d.padded(), 128);
        assert_eq!(d.range(1), (64, 36)); // short last block
    }

    #[test]
    fn small_matrix_single_block() {
        let d = BlockDim::new(10, 64);
        assert_eq!(d.count(), 1);
        assert_eq!(d.range(0), (0, 10));
    }

    #[test]
    fn matmul_blocks_array_count() {
        let b = MatmulBlocks::new(128, 128, (64, 64));
        assert_eq!(b.arrays_per_slice(), 4);
        let b = MatmulBlocks::new(130, 64, (64, 64));
        assert_eq!(b.arrays_per_slice(), 3);
    }

    #[test]
    #[should_panic(expected = "block size")]
    fn zero_block_rejected() {
        BlockDim::new(10, 0);
    }

    #[test]
    fn pair_indexing_roundtrip() {
        let b = MatmulBlocks::new(130, 200, (64, 64));
        assert_eq!(b.pair_count(), 3 * 4);
        for idx in 0..b.pair_count() {
            let (kb, nb) = b.pair(idx);
            assert!(kb < b.k.count() && nb < b.n.count());
            assert_eq!(kb * b.n.count() + nb, idx);
        }
    }

    #[test]
    fn blockdim_properties() {
        use crate::util::prop::prop_check;
        prop_check("BlockDim ranges tile 0..total exactly, padded is a block multiple", 400, |g| {
            let total = g.usize_in(1..=600);
            let block = g.usize_in(1..=130);
            let d = BlockDim::new(total, block);
            // Ranges concatenate to exactly 0..total: no gap, no overlap.
            let mut pos = 0usize;
            for i in 0..d.count() {
                let (start, len) = d.range(i);
                if start != pos {
                    return Err(format!("block {i} starts at {start}, expected {pos}"));
                }
                if len == 0 || len > block {
                    return Err(format!("block {i} has length {len} (block size {block})"));
                }
                if i + 1 < d.count() && len != block {
                    return Err(format!("only the last block may be short, block {i} is {len}"));
                }
                pos += len;
            }
            if pos != total {
                return Err(format!("ranges cover {pos} of {total}"));
            }
            // Padded size: smallest block multiple >= total.
            let padded = d.padded();
            if padded % block != 0 {
                return Err(format!("padded {padded} not a multiple of {block}"));
            }
            if padded < total || padded - total >= block {
                return Err(format!("padded {padded} not minimal for total {total}"));
            }
            Ok(())
        });
    }

    #[test]
    fn matmul_blocks_pair_properties() {
        use crate::util::prop::prop_check;
        prop_check("MatmulBlocks pair index is a bijection over the grid", 400, |g| {
            let k = g.usize_in(1..=500);
            let n = g.usize_in(1..=500);
            let array = (g.usize_in(1..=96), g.usize_in(1..=96));
            let b = MatmulBlocks::new(k, n, array);
            if b.pair_count() != b.k.count() * b.n.count() {
                return Err("pair_count != k blocks x n blocks".into());
            }
            let mut seen = vec![false; b.pair_count()];
            for idx in 0..b.pair_count() {
                let (kb, nb) = b.pair(idx);
                if kb >= b.k.count() || nb >= b.n.count() {
                    return Err(format!("pair {idx} -> ({kb}, {nb}) out of grid"));
                }
                let back = kb * b.n.count() + nb;
                if back != idx {
                    return Err(format!("pair {idx} round-trips to {back}"));
                }
                if seen[idx] {
                    return Err(format!("pair index {idx} visited twice"));
                }
                seen[idx] = true;
            }
            Ok(())
        });
    }
}

//! The variable-precision dot-product engine (paper §3.3, Figs 5–7).
//!
//! Computes `C = A·B` on simulated crossbar hardware:
//! 1. split the contraction/output dimensions into array-sized blocks
//!    (Fig 7), each block sharing one quantization coefficient (INT path)
//!    or one exponent (FP pre-alignment path);
//! 2. slice the block integers into the spec's digit planes (Fig 1);
//! 3. program every weight digit plane onto a (noisy) crossbar array via
//!    the device model — lognormal conductance variation, `g_levels`
//!    discrete states;
//! 4. for each input slice run the analog MVM against **all** weight digit
//!    planes at once (stacked slice-plane GEMM, see §Perf) — or the full
//!    IR-drop circuit solve per plane when `use_circuit` is set — and
//!    quantize each plane's readout with the ADC;
//! 5. recombine partials with signed shift-and-add weights and the block
//!    scales.
//!
//! Weight preparation (steps 1–3) is separated into [`PreparedWeights`] so
//! NN layers can slice+program once per weight update and reuse across
//! batches, matching the paper's "sliced copy of the weight saved as an
//! attribute in the computing graph".
//!
//! # §Perf — the stacked slice-plane GEMM pipeline
//!
//! The hot path of every workload (NN training/inference, the solver, CWT,
//! k-means) bottoms out in [`DotProductEngine::matmul_prepared`]. The
//! original implementation issued one small `Matrix::matmul` per
//! (input-slice × weight-slice × array-block) triple with a fresh heap
//! allocation per partial — `S_a · S_w` malloc-heavy micro-GEMMs per block,
//! which for int8/fp16 specs (4–5 slices per operand) meant 16–25 dispatches
//! where one suffices.
//!
//! The stacked pipeline restructures this:
//!
//! - **Prepare time** ([`DotProductEngine::prepare_weights`]): each block's
//!   `S_w` programmed digit planes are column-stacked into one contiguous
//!   `l_m × (S_w·l_n)` matrix and packed into GEMM panels
//!   ([`crate::tensor::PackedB`]) **once per prepared-weight lifetime** —
//!   the packing is amortized over every batch/epoch that reuses the
//!   weights, and only the packed form is retained (cold paths unpack the
//!   stripe they need). On the input side, each k-block's `S_a` digit
//!   planes are quantized + sliced in one pass straight into byte-packed
//!   [`crate::tensor::DigitPlanes`] (u8 digits, slice-major — 8× less
//!   memory than the old f64 planes, which are never materialized).
//! - **Matmul time**: **one** stacked GEMM per (k-block, n-block) pair
//!   ([`crate::tensor::matmul_packed_stacked_into`]) multiplies all `S_a`
//!   input planes against the packed block, producing every `(sa, sw)`
//!   partial as a (plane-row-block × column-stripe) region of one stacked
//!   output buffer — each B panel is loaded once per block instead of once
//!   per (input slice, block). u8 → f64 conversion happens in-register and
//!   is exact, and each logical output row still accumulates along
//!   ascending `k`, so nothing about the arithmetic changes. ADC
//!   quantization and signed shift-add recombination then consume the
//!   stripes exactly as before, in the same (sa, sw) order.
//! - **Scheduling**: when the block grid has ≥ 2 array pairs carrying
//!   enough total work, the pairs are the work items on the lock-free
//!   `par_map` pool (GEMMs serial inside). Otherwise a big lone pair 2-D
//!   schedules its stacked GEMM over (row-band × panel-group) items
//!   ([`crate::tensor::matmul_packed_stacked_2d`]) — row bands alone
//!   starve the pool when `m` is small (an m = 1 single-sample inference
//!   has one band), while the 2-D grid still has `S_a × panel-groups`
//!   items. One level of parallelism either way, no nested spawn.
//! - **Integer kernel**: when programming leaves digits exact (noise-free
//!   engines — programming noise and fault injection both produce
//!   non-integer or out-of-spec analog values otherwise), each prepared
//!   block additionally keeps a byte mirror of its packed panels
//!   ([`crate::tensor::PackedU8`], detected value-wise at program time),
//!   and the matmul dispatches the integer stacked kernel
//!   ([`crate::tensor::matmul_packed_stacked_int_into`] / `_int_2d`
//!   under the same 2-D threshold): u8×u8 digit products in an i32/i64
//!   accumulator proved safe from the slice tables at plan time
//!   ([`crate::tensor::int_accum_for`], re-checked against each block's
//!   *programmed* max digit), converted to f64 once per output element.
//!   Every digit partial sum stays below 2^53, so the integer kernel is
//!   **bit-identical** to the f64 stacked kernel (`tensor` §Perf) — the
//!   dispatch is invisible to results and asserted against the oracle in
//!   tests and benches, while moving 8× fewer weight-side bytes.
//!
//! The retained per-slice-pair implementation
//! (`matmul_prepared_reference`, `#[doc(hidden)]` so the gemm-kernel bench
//! can call it too) is the correctness oracle: both paths accumulate every
//! output element along ascending `k` in the same (sa, sw) order with the
//! same ADC arithmetic, so the stacked pipeline is asserted
//! **bit-identical** across slice specs, ADC policies, and ragged shapes.
//! The win is purely architectural: one well-shaped GEMM per block instead
//! of `S_a · S_w` tiny ones, measured by `benches/table3_throughput.rs`
//! (`BENCH_table3.json`) and `benches/gemm_kernel.rs` (`BENCH_gemm.json`).
//!
//! # §Perf — prepared-input caching and the program-template split
//!
//! Both halves of the datapath split into a **cached deterministic part**
//! and a **cheap stochastic tail**:
//!
//! - **Weight side**: [`DotProductEngine::weight_template`] runs the
//!   deterministic steps 1–2 (block grid, per-block quantization, digit
//!   slicing) once per matrix into a [`WeightTemplate`];
//!   [`WeightTemplate::program`] then runs only step 3 per programming
//!   cycle — the programming-noise / fault / ADC-chain draws, written
//!   **directly into the packed GEMM panels** (the fused `l_m × (S_w·l_n)`
//!   matrix is never materialized). `prepare_weights` itself fuses the two
//!   stages per block, so `template.program(&engine, tag)` is bit-identical
//!   to `engine.prepare_weights(&b, &method, tag)` by construction.
//! - **Input side**: [`DotProductEngine::prepare_inputs`] promotes the
//!   per-k-block quantize + slice of the `A` operand to a reusable
//!   [`PreparedInputs`]; [`DotProductEngine::matmul_prepared_inputs`]
//!   consumes it. A fixed input sliced once is shared across Monte-Carlo
//!   cycles (`dpe::montecarlo`), k-means assignment passes
//!   (`apps::kmeans`), and the CWT's real/imaginary kernels (`apps::cwt`).
//!
//! **When to cache**: any loop that re-reads or re-programs the *same*
//! matrix — Monte-Carlo re-programming, fault-yield sweeps, repeated
//! evaluation of a fixed batch. Inputs that never repeat (fresh training
//! batches) only pay the cache bookkeeping, so the input cache stays
//! eval-only.
//!
//! **Training path** (hardware-in-the-loop, Fig 16): weights change every
//! optimizer step, but an SGD step moves most digits by *zero or one
//! quantization level* — so instead of a full `prepare_weights` per step,
//! [`DotProductEngine::program_delta`] diffs the fresh quantization
//! against the cached [`WeightTemplate`] per block and rewrites **only
//! the cells whose digits changed**, drawing replacement programming
//! noise from a fresh per-step generator keyed by the block's existing
//! per-slot stream and the new programming `tag`. A block whose digits
//! are unchanged is skipped outright (scale-only changes update the
//! recombination scale without touching the panels); cells untouched by
//! the step keep the analog noise of their previous programming — the
//! physical behaviour of not pulsing a cell. What the delta update
//! *skips*: re-blocking, re-quantization packing, noise redraws for
//! clean cells, and the ADC-chain draw (the chain keys off the slot
//! stream only, so it is generation-independent). A **full reprogram is
//! still forced** when program-time fault/retention injection is active
//! (fault masks are sampled plane-wise and cannot be replayed cell-wise),
//! when no template is cached yet, or when the weight shape or slice
//! method changed ([`crate::nn::MemCore`] handles the fallback). On
//! noise-free engines the delta path is bit-identical to the full
//! reprogram; `benches/fig16_training.rs` (`BENCH_fig16.json`) tracks
//! the per-step reprogram / forward / backward / optim breakdown.
//!
//! Monte-Carlo hot loops additionally run the per-cycle program + matmul
//! **serially inside each cycle** (the cycle-level `par_map` already
//! saturates the worker pool; the pre-split path nested thread scopes
//! inside every cycle, oversubscribing the machine). The perf trajectory
//! for this is `benches/fig12_montecarlo.rs` (`BENCH_mc.json`).

use super::blocks::{BlockDim, MatmulBlocks};
use super::quant::Adc;
use super::slicing::{
    quantize_block, quantize_slice_block, slice_digits, DataMode, SliceSpec, SliceTables,
};
use crate::circuit::CrossbarCircuit;
use crate::device::faults::{AdcChain, FaultSpec, NonIdealitySpec};
use crate::device::DeviceSpec;
use crate::tensor::{
    int_accum_for, matmul_packed_stacked_2d, matmul_packed_stacked_int_2d,
    matmul_packed_stacked_int_into, matmul_packed_stacked_into, DigitPlanes, IntAccum, Matrix,
    PackedB, PackedU8,
};
use crate::util::parallel::par_map;
use crate::util::rng::Pcg64;

/// A slice method: spec + how continuous data becomes integers.
#[derive(Debug, Clone, PartialEq)]
pub struct SliceMethod {
    pub spec: SliceSpec,
    pub mode: DataMode,
}

impl SliceMethod {
    pub fn int(spec: SliceSpec) -> Self {
        SliceMethod { spec, mode: DataMode::Quantize }
    }
    pub fn fp(spec: SliceSpec) -> Self {
        SliceMethod { spec, mode: DataMode::PreAlign }
    }
    /// Parse a paper-style name: "int4", "int8", "fp16", "bf16", "fp32",
    /// "flex16", or "ones<N>"; "fp*" names select pre-alignment.
    pub fn parse(name: &str) -> anyhow::Result<Self> {
        let lower = name.to_ascii_lowercase();
        Ok(match lower.as_str() {
            "int4" => Self::int(SliceSpec::int4()),
            "int8" => Self::int(SliceSpec::int8()),
            "fp16" => Self::fp(SliceSpec::fp16()),
            "bf16" => Self::fp(SliceSpec::bf16()),
            "fp32" => Self::fp(SliceSpec::fp32()),
            "flex16" | "flexpoint16" => Self::fp(SliceSpec::flex16()),
            _ => {
                if let Some(n) = lower.strip_prefix("ones") {
                    // try_new (not the panicking `ones`) so a bad count —
                    // e.g. "ones0" — surfaces as a parse error.
                    Self::int(SliceSpec::try_new(&vec![1; n.parse()?], true)?)
                } else {
                    anyhow::bail!("unknown slice method '{name}'")
                }
            }
        })
    }
}

/// How the ADC full-scale range is chosen per slice-pair readout.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdcPolicy {
    /// Fixed worst-case range `rows·max_a·max_w` — conservative, matches
    /// the AOT artifacts (`python/compile/`), and is the paper's "hard to
    /// achieve software accuracy" regime.
    #[default]
    WorstCase,
    /// Per-readout calibrated range (programmable-gain amplifier): the
    /// gain maps the actual peak of each readout to full scale —
    /// amplifying small signals, attenuating large ones. Strictly finer
    /// than `WorstCase`. Models calibrated ADC ranges à la CrossSim.
    Calibrated,
    /// Count-mode readout: like `Calibrated` but the step never drops
    /// below one digit unit, so integer-valued partials below `radc` are
    /// converted **exactly** and sub-LSB analog noise is absorbed by the
    /// code boundary. Required for the high-precision FP32 solver
    /// workloads (Fig 13).
    IntegerSnap,
}

/// Engine configuration (defaults = Table 2).
#[derive(Debug, Clone)]
pub struct DpeConfig {
    pub device: DeviceSpec,
    /// Physical array size `(rows = contraction block, cols = output block)`.
    pub array: (usize, usize),
    /// DAC levels (input side). Table 2: 256.
    pub rdac: usize,
    /// ADC levels (readout side). Table 2: 1024.
    pub radc: usize,
    /// ADC range selection policy.
    pub adc_policy: AdcPolicy,
    /// Disable all analog noise/quantization (ideal sliced arithmetic).
    pub noise_free: bool,
    /// Route every block MVM through the IR-drop circuit solver.
    pub use_circuit: bool,
    /// Wire resistance for the circuit model (Ω).
    pub r_wire: f64,
    /// Read voltage at full input scale (V), used by the circuit path.
    pub v_read: f64,
    /// Unified fault/non-ideality injection (stuck-at + dead lines,
    /// retention at read time, per-column ADC error). The default all-off
    /// spec leaves the engine bit-identical to no injection; see
    /// [`crate::device::faults`] for the composition order.
    ///
    /// `noise_free = true` is the master kill-switch for **all** analog
    /// effects and disables this injection too — to study faults in
    /// isolation from programming noise, keep `noise_free = false` and
    /// set `device.cv = 0` instead.
    pub nonideal: NonIdealitySpec,
}

impl Default for DpeConfig {
    fn default() -> Self {
        DpeConfig {
            device: DeviceSpec::default(),
            array: (64, 64),
            rdac: 256,
            radc: 1024,
            adc_policy: AdcPolicy::default(),
            noise_free: false,
            use_circuit: false,
            r_wire: 2.93,
            v_read: 0.2,
            nonideal: NonIdealitySpec::none(),
        }
    }
}

/// One weight block programmed on hardware: the `S_w` analog digit planes
/// (noise applied) column-stacked into one fused `l_m × (S_w·l_n)` matrix
/// and kept **only** in packed-panel form (the dense fused matrix is a
/// packing-time temporary — retaining both would double prepared-weight
/// memory), plus the block's recovery scale.
#[derive(Debug, Clone)]
struct PreparedBlock {
    /// Column-panel packing of the fused digit planes (columns
    /// `[s·l_n, (s+1)·l_n)` hold weight slice `s`), built once per
    /// programming and reused by every `matmul_prepared` call.
    packed: PackedB,
    /// Byte mirror of `packed`, present iff every programmed value is an
    /// exact integer digit (noise-free programming) — lets the matmul
    /// dispatch the integer stacked kernel (§Perf). `None` for noisy
    /// analog values, which keep the f64 kernel.
    packed_int: Option<PackedU8>,
    scale: f64,
    /// This array's per-column ADC chain (ideal unless the non-ideality
    /// spec configures gain/offset error or floor rounding) — sampled
    /// once at program time so the ADC knob, like the fault masks, costs
    /// nothing per matmul.
    chain: AdcChain,
}

impl PreparedBlock {
    /// Materialize one weight-slice digit plane (a column stripe of the
    /// fused matrix, unpacked from the panels) — cold paths only: the
    /// circuit solver and the test oracle.
    fn plane(&self, s: usize, l_n: usize) -> Matrix {
        self.packed.unpack_cols(s * l_n, l_n)
    }
}

/// A weight matrix sliced, blocked, and programmed onto arrays.
#[derive(Debug, Clone)]
pub struct PreparedWeights {
    blocks: Vec<PreparedBlock>, // indexed kb * n_blocks + nb
    grid: MatmulBlocks,
    method: SliceMethod,
    k: usize,
    n: usize,
}

impl PreparedWeights {
    pub fn shape(&self) -> (usize, usize) {
        (self.k, self.n)
    }
    pub fn method(&self) -> &SliceMethod {
        &self.method
    }
    /// Number of physical arrays used (blocks × slices) — the paper's
    /// "array groups" resource count (Fig 6).
    pub fn arrays_used(&self) -> usize {
        self.blocks.len() * self.method.spec.num_slices()
    }
    /// Number of `(k-block, n-block)` array pairs — the block-group count
    /// the chip mapper places (each group is `num_slices` digit planes
    /// that share input drivers, so [`crate::arch::TileAllocator`] keeps a
    /// group within one tile).
    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }
    /// Number of blocks carrying an exact-integer byte mirror — how many
    /// the integer stacked GEMM can serve (§Perf). Equals
    /// [`PreparedWeights::num_blocks`] for noise-free engines, 0 for noisy
    /// analog programming.
    pub fn int_panel_blocks(&self) -> usize {
        self.blocks.iter().filter(|b| b.packed_int.is_some()).count()
    }
    /// Fence off one `(k-block, n-block)` group: zero its recombination
    /// scale so every matmul path (stacked, integer, circuit, oracle)
    /// skips the pair entirely and the group contributes **exactly
    /// zero** — not the stale digits sitting on a faulty array. This is
    /// the degraded-mode primitive behind
    /// [`crate::arch::DegradedReport`]: when spares are exhausted, an
    /// unrepairable group's bounded missing-contribution error replaces
    /// the unbounded stuck-at readout error. Irreversible until the
    /// block is reprogrammed.
    pub fn condemn_block(&mut self, block: usize) {
        assert!(
            block < self.blocks.len(),
            "condemn_block: block {} out of range ({} blocks)",
            block,
            self.blocks.len()
        );
        self.blocks[block].scale = 0.0;
    }
}

/// The deterministic half of one weight block: the quantized digit planes
/// (`S_w` matrices of `l_m × l_n`, plane-major — which is also the RNG
/// draw order of programming) plus the block's recovery scale. No noise
/// has been applied yet, so programming one is pure noise-draw + pack.
#[derive(Debug, Clone)]
struct TemplateBlock {
    planes: Vec<Matrix>,
    scale: f64,
}

/// The deterministic half of [`DotProductEngine::prepare_weights`]: block
/// grid, per-block quantized digit planes, and recovery scales —
/// everything that does **not** depend on the programming-noise / fault /
/// ADC draws. Build once per weight matrix with
/// [`DotProductEngine::weight_template`], then call
/// [`WeightTemplate::program`] per programming cycle: Monte-Carlo sweeps,
/// fault-yield studies, and any loop that re-programs the same matrix pay
/// only the stochastic-tail cost per cycle (§Perf).
///
/// `template.program(&engine, tag)` is bit-identical to
/// `engine.prepare_weights(&b, &method, tag)`: both run the same per-block
/// programming code on the same RNG streams.
#[derive(Debug, Clone)]
pub struct WeightTemplate {
    blocks: Vec<TemplateBlock>, // indexed kb * n_blocks + nb
    grid: MatmulBlocks,
    method: SliceMethod,
    k: usize,
    n: usize,
    /// Array geometry the template was blocked for; programming engines
    /// must match.
    array: (usize, usize),
}

impl WeightTemplate {
    pub fn shape(&self) -> (usize, usize) {
        (self.k, self.n)
    }

    pub fn method(&self) -> &SliceMethod {
        &self.method
    }

    /// Program the template onto (noisy) crossbar arrays: draw programming
    /// noise, fault/retention injection, and the per-column ADC chain for
    /// every block, packing the result — the cheap stochastic tail of
    /// [`DotProductEngine::prepare_weights`], bit-identical to it at the
    /// same engine seed and `tag`.
    pub fn program(&self, engine: &DotProductEngine, tag: u64) -> PreparedWeights {
        self.program_with(engine, tag, true)
    }

    /// `program` with explicit block-level parallelism control: hot loops
    /// already parallel at an outer level (Monte-Carlo cycles) pass
    /// `parallel = false` to avoid nested thread scopes (§Perf).
    pub(crate) fn program_with(
        &self,
        engine: &DotProductEngine,
        tag: u64,
        parallel: bool,
    ) -> PreparedWeights {
        assert_eq!(
            engine.cfg.array, self.array,
            "weight template was blocked for {:?} arrays, engine has {:?}",
            self.array, engine.cfg.array
        );
        engine.assert_method_fits(&self.method.spec);
        let body = |blk: usize| engine.program_block(&self.blocks[blk], blk as u64, tag);
        let blocks: Vec<PreparedBlock> = if parallel {
            par_map(self.blocks.len(), body)
        } else {
            (0..self.blocks.len()).map(body).collect()
        };
        PreparedWeights {
            blocks,
            grid: self.grid,
            method: self.method.clone(),
            k: self.k,
            n: self.n,
        }
    }

    /// Program-and-verify at the layer-local identity streams: like
    /// [`WeightTemplate::program`], but each digit plane is read back
    /// through the read-noise model and re-drawn while its worst per-cell
    /// digit error exceeds `spec.tolerance` (bounded by
    /// `spec.max_retries`). Returns the per-block retry/convergence
    /// accounting alongside the weights. With `spec.verify == false` this
    /// is the plain single-shot path, bit-identical to `program`.
    pub fn program_verified(
        &self,
        engine: &DotProductEngine,
        tag: u64,
        spec: &RepairSpec,
    ) -> (PreparedWeights, ProgramReport) {
        let identity: Vec<u64> = (0..self.blocks.len() as u64).collect();
        self.program_verified_mapped(engine, tag, spec, &identity)
    }

    /// [`WeightTemplate::program_verified`] with explicit per-block
    /// physical stream ids (the chip-mapped path, mirroring
    /// [`DotProductEngine::prepare_weights_mapped`]): every draw — the
    /// programming redraws of the verify loop included — keys off the
    /// physical slot id, and the stuck cells pinned on each retry are the
    /// *slot's* fault mask, so a plane that never converges condemns a
    /// physical array, not a logical block index.
    pub fn program_verified_mapped(
        &self,
        engine: &DotProductEngine,
        tag: u64,
        spec: &RepairSpec,
        block_streams: &[u64],
    ) -> (PreparedWeights, ProgramReport) {
        assert_eq!(
            engine.cfg.array, self.array,
            "weight template was blocked for {:?} arrays, engine has {:?}",
            self.array, engine.cfg.array
        );
        engine.assert_method_fits(&self.method.spec);
        assert_eq!(
            block_streams.len(),
            self.blocks.len(),
            "stream list covers {} blocks, weight grid has {}",
            block_streams.len(),
            self.blocks.len()
        );
        if !spec.verify {
            // Single-shot path: literally `program_block` per block, so a
            // disabled [repair] spec cannot drift from the existing
            // programming path by construction.
            let blocks: Vec<PreparedBlock> = par_map(self.blocks.len(), |blk| {
                engine.program_block(&self.blocks[blk], block_streams[blk], tag)
            });
            let w = PreparedWeights {
                blocks,
                grid: self.grid,
                method: self.method.clone(),
                k: self.k,
                n: self.n,
            };
            return (w, ProgramReport::default());
        }
        let results: Vec<(PreparedBlock, BlockProgramStats)> =
            par_map(self.blocks.len(), |blk| {
                let (pb, mut st) = engine.program_block_verified(
                    &self.blocks[blk],
                    block_streams[blk],
                    tag,
                    spec,
                );
                st.block = blk;
                (pb, st)
            });
        let (blocks, stats): (Vec<_>, Vec<_>) = results.into_iter().unzip();
        let w = PreparedWeights {
            blocks,
            grid: self.grid,
            method: self.method.clone(),
            k: self.k,
            n: self.n,
        };
        (w, ProgramReport { blocks: stats })
    }
}

/// Closed-loop reliability policy (the TOML `[repair]` section): the
/// program-and-verify loop of [`WeightTemplate::program_verified`] plus
/// the health-probe thresholds consumed by [`crate::arch::repair`].
#[derive(Debug, Clone, PartialEq)]
pub struct RepairSpec {
    /// Master switch. When false every programming path is the plain
    /// single-shot one (hard-asserted bit-identical to it).
    pub verify: bool,
    /// Per-plane acceptance bound on the worst per-cell digit error of
    /// the read-back (digit units; a 4-bit device spans 0..=15). Healthy
    /// planes under Table-2 programming noise stay well below ~5 digits;
    /// a stuck cell contributes up to `max_digit` and never improves.
    pub tolerance: f64,
    /// Extra programming attempts per plane before it counts as
    /// unconverged (the condemnation signal).
    pub max_retries: usize,
    /// Relative-error bound on a block group's checksum probe readout
    /// before the group's slots are condemned (see
    /// [`crate::arch::repair::HealthReport`]).
    pub probe_re_bound: f64,
    /// Deterministic probe vectors per k-block: 1 = the all-ones column
    /// checksum, 2 = additionally the alternating ±1 vector (catches
    /// sign-symmetric fault patterns the plain sum misses).
    pub probe_vectors: usize,
}

impl Default for RepairSpec {
    fn default() -> Self {
        RepairSpec {
            verify: false,
            tolerance: 6.0,
            max_retries: 3,
            probe_re_bound: 0.25,
            probe_vectors: 2,
        }
    }
}

impl RepairSpec {
    /// The all-off policy: no verify loop, no probes.
    pub fn none() -> Self {
        RepairSpec::default()
    }

    /// An enabled policy with the default thresholds.
    pub fn enabled() -> Self {
        RepairSpec { verify: true, ..RepairSpec::default() }
    }
}

/// Per-block accounting of one verified programming pass.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockProgramStats {
    /// Block index within the layer's weight grid (`kb * n_blocks + nb`).
    pub block: usize,
    /// Physical stream the block was programmed on (slot id when mapped).
    pub stream: u64,
    /// Total extra programming attempts across the block's digit planes.
    pub retries: usize,
    /// Planes still failing the tolerance after `max_retries` — stuck
    /// cells by construction never converge, so this is the per-slot
    /// fault detection signal.
    pub unconverged_planes: usize,
    /// Worst final per-cell digit error over the block's planes.
    pub worst_err: f64,
}

/// The per-block stats of one [`WeightTemplate::program_verified`] run
/// (empty when the spec disables verification).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ProgramReport {
    pub blocks: Vec<BlockProgramStats>,
}

impl ProgramReport {
    /// Total retries across all blocks.
    pub fn total_retries(&self) -> usize {
        self.blocks.iter().map(|b| b.retries).sum()
    }

    /// Indices of blocks with at least one unconverged plane.
    pub fn unconverged_blocks(&self) -> Vec<usize> {
        self.blocks
            .iter()
            .filter(|b| b.unconverged_planes > 0)
            .map(|b| b.block)
            .collect()
    }

    /// Retries-per-block histogram: `hist[r]` counts blocks that took
    /// exactly `r` retries, with the last bin absorbing `>= cap`.
    pub fn retry_histogram(&self, cap: usize) -> Vec<usize> {
        let mut hist = vec![0usize; cap + 1];
        for b in &self.blocks {
            hist[b.retries.min(cap)] += 1;
        }
        hist
    }
}

/// Accounting of one [`DotProductEngine::program_delta`] pass (or, via
/// [`crate::nn::MemCore::program_delta`], of a whole optimizer step):
/// how many blocks were untouched, scale-adjusted, or cell-rewritten, and
/// how many individual cells were actually re-pulsed. The training loop
/// sums these per step, and the fig16 bench asserts from them that a step
/// touching one layer redraws only that layer's dirty blocks (§Perf).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DeltaReport {
    /// Total `(k-block, n-block)` groups examined.
    pub blocks: usize,
    /// Blocks whose digits *and* scale were unchanged — zero work.
    pub blocks_clean: usize,
    /// Blocks whose digits were unchanged but whose recombination scale
    /// moved (quantization range shifted without flipping any digit) —
    /// scale updated, panels untouched, no RNG consumed.
    pub blocks_scale_only: usize,
    /// Blocks with at least one changed digit — dirty cells re-pulsed.
    pub blocks_redrawn: usize,
    /// Individual cells rewritten across all redrawn blocks.
    pub cells_redrawn: usize,
    /// Full `prepare_weights`-style reprograms forced instead of a delta
    /// (no cached template, shape/method change, or program-time fault
    /// injection active).
    pub full_reprograms: usize,
}

impl DeltaReport {
    /// The report of one forced full reprogram over `blocks` groups.
    pub fn full(blocks: usize) -> DeltaReport {
        DeltaReport {
            blocks,
            blocks_redrawn: blocks,
            full_reprograms: 1,
            ..DeltaReport::default()
        }
    }

    /// Blocks that needed any update at all (scale-only + redrawn).
    pub fn dirty_blocks(&self) -> usize {
        self.blocks_scale_only + self.blocks_redrawn
    }

    /// Accumulate another report (per-layer → per-step totals).
    pub fn merge(&mut self, other: &DeltaReport) {
        self.blocks += other.blocks;
        self.blocks_clean += other.blocks_clean;
        self.blocks_scale_only += other.blocks_scale_only;
        self.blocks_redrawn += other.blocks_redrawn;
        self.cells_redrawn += other.cells_redrawn;
        self.full_reprograms += other.full_reprograms;
    }
}

/// One k-block of the input, quantized and sliced once and shared across
/// all n-blocks of the weight.
#[derive(Debug, Clone)]
struct InputBlock {
    /// All `S_a` digit planes of `m × l_m`, byte-packed slice-major — the
    /// **only** retained copy of the input digits (no f64 planes; cold
    /// paths materialize a plane on demand via [`DigitPlanes::plane`]).
    planes: DigitPlanes,
    scale: f64,
}

/// A quantized + sliced input operand (the `A` of `A·B`): the per-k-block
/// digit planes the matmul pipeline needs, promoted to a reusable value.
/// Prepare once per input matrix with
/// [`DotProductEngine::prepare_inputs`] and feed to
/// [`DotProductEngine::matmul_prepared_inputs`] any number of times —
/// Monte-Carlo cycles over re-programmed weights, k-means assignment
/// passes, and the CWT's real/imaginary kernels all share one slicing of
/// their fixed input (§Perf). Slicing is fully deterministic, so the
/// cached path is bit-identical to per-call slicing.
#[derive(Debug, Clone)]
pub struct PreparedInputs {
    blocks: Vec<InputBlock>,
    method: SliceMethod,
    m: usize,
    k: usize,
    /// Array row count the k dimension was blocked by; must match the
    /// engine (and therefore the weights) at matmul time.
    l_m: usize,
}

impl PreparedInputs {
    pub fn shape(&self) -> (usize, usize) {
        (self.m, self.k)
    }

    pub fn method(&self) -> &SliceMethod {
        &self.method
    }

    /// The row slice `[r0, r0 + len)` of the prepared input: the same
    /// per-k-block quantization scales and digit planes, restricted to
    /// those rows. Because the scales stay batch-global, a matmul over the
    /// slice reproduces the corresponding rows of the full-batch matmul
    /// bit for bit under the fixed-range (worst-case) ADC — the invariant
    /// the micro-batched inference executor ([`crate::arch::MappedModel`])
    /// relies on. (Re-preparing only those rows would instead re-derive
    /// the scales from the sub-batch maxima.)
    pub fn rows(&self, r0: usize, len: usize) -> PreparedInputs {
        assert!(r0 + len <= self.m, "row slice {r0}+{len} out of {} rows", self.m);
        PreparedInputs {
            blocks: self
                .blocks
                .iter()
                .map(|b| InputBlock { planes: b.planes.row_slice(r0, len), scale: b.scale })
                .collect(),
            method: self.method.clone(),
            m: len,
            k: self.k,
            l_m: self.l_m,
        }
    }
}

/// Per-call precomputed tables shared by the fused, circuit, and (test)
/// reference matmul paths: the slice tables of both operands plus the
/// combined per-(sa, sw) recombination weights and worst-case ADC ranges —
/// hoisted out of the inner loops instead of being re-derived per pair.
struct SlicePairPlan {
    a: SliceTables,
    w: SliceTables,
    /// `pair_weight[sa·S_w + sw] = a.weights[sa] · w.weights[sw]`.
    pair_weight: Vec<f64>,
    /// `worst_scale[sa·S_w + sw] = rows · a_max[sa] · w_max[sw]`.
    worst_scale: Vec<f64>,
    /// Accumulator the integer stacked kernel may use, proved from the
    /// spec tables (`rows · max_a · max_w`, see
    /// [`crate::tensor::int_accum_for`]); `None` keeps the f64 kernel.
    int_acc: Option<IntAccum>,
    /// Largest weight digit the proof above assumed. The dispatcher
    /// re-checks each block's *programmed* max digit against it — fault
    /// injection can pin a cell to the device maximum, above a narrow
    /// slice's spec bound.
    max_w_digit: f64,
}

impl SlicePairPlan {
    fn new(rows: usize, a_spec: &SliceSpec, w_spec: &SliceSpec) -> Self {
        let a = a_spec.tables();
        let w = w_spec.tables();
        let (sa_n, sw_n) = (a.num_slices(), w.num_slices());
        let mut pair_weight = Vec::with_capacity(sa_n * sw_n);
        let mut worst_scale = Vec::with_capacity(sa_n * sw_n);
        for sa in 0..sa_n {
            for sw in 0..sw_n {
                pair_weight.push(a.weights[sa] * w.weights[sw]);
                worst_scale.push(rows as f64 * a.max_digit[sa] * w.max_digit[sw]);
            }
        }
        let max_a = a.max_digit.iter().cloned().fold(0.0, f64::max);
        let max_w = w.max_digit.iter().cloned().fold(0.0, f64::max);
        let int_acc = int_accum_for(rows, max_a as u64, max_w as u64);
        SlicePairPlan { a, w, pair_weight, worst_scale, int_acc, max_w_digit: max_w }
    }

    #[inline]
    fn idx(&self, sa: usize, sw: usize) -> usize {
        sa * self.w.num_slices() + sw
    }
}

/// Geometry of one weight-slice stripe inside a row-major scratch buffer:
/// `rows` rows of `width` values starting at column `c0` with `stride`
/// values per row.
#[derive(Clone, Copy)]
struct Stripe {
    rows: usize,
    stride: usize,
    c0: usize,
    width: usize,
}

impl Stripe {
    /// A stripe covering a whole contiguous `rows × width` buffer.
    fn contiguous(rows: usize, width: usize) -> Stripe {
        Stripe { rows, stride: width, c0: 0, width }
    }
}

/// The hardware dot-product engine.
#[derive(Debug, Clone)]
pub struct DotProductEngine {
    pub cfg: DpeConfig,
    seed: u64,
}

impl DotProductEngine {
    pub fn new(cfg: DpeConfig, seed: u64) -> Self {
        assert!(cfg.array.0 > 0 && cfg.array.1 > 0);
        DotProductEngine { cfg, seed }
    }

    /// An engine that performs exact sliced arithmetic (no noise, no ADC) —
    /// used for backend cross-validation.
    pub fn ideal(array: (usize, usize)) -> Self {
        DotProductEngine::new(
            DpeConfig { noise_free: true, array, ..DpeConfig::default() },
            0,
        )
    }

    /// Program `b` onto crossbar arrays with `method` (steps 1–3 above):
    /// quantize + slice each block, program every digit plane through the
    /// device model, and pack for the GEMM micro-kernel (§Perf). This is
    /// exactly [`DotProductEngine::weight_template`] +
    /// [`WeightTemplate::program`] fused per block; loops that re-program
    /// the same matrix should build the template once instead.
    pub fn prepare_weights(&self, b: &Matrix, method: &SliceMethod, tag: u64) -> PreparedWeights {
        let grid = MatmulBlocks::new(b.rows, b.cols, self.cfg.array);
        self.assert_method_fits(&method.spec);
        let blocks: Vec<PreparedBlock> = par_map(grid.pair_count(), |blk| {
            let tb = template_block(b, &grid, method, self.cfg.array, blk);
            self.program_block(&tb, blk as u64, tag)
        });
        PreparedWeights { blocks, grid, method: method.clone(), k: b.rows, n: b.cols }
    }

    /// [`DotProductEngine::prepare_weights`] with explicit per-block
    /// physical stream ids — the chip-mapping path. `block_streams[blk]`
    /// is the global slot id of the block's first digit plane on the chip
    /// ([`crate::arch`]): programming noise, fault masks, and the
    /// per-column ADC chain of each block derive from that id instead of
    /// the layer-local block index, so the draws belong to the *physical
    /// array* the block landed on — two layers sharing a tile get
    /// independent streams, and remapping a block to a different slot
    /// resamples its noise. With `block_streams[blk] == blk` this is
    /// bit-identical to `prepare_weights`.
    pub fn prepare_weights_mapped(
        &self,
        b: &Matrix,
        method: &SliceMethod,
        tag: u64,
        block_streams: &[u64],
    ) -> PreparedWeights {
        let grid = MatmulBlocks::new(b.rows, b.cols, self.cfg.array);
        self.assert_method_fits(&method.spec);
        assert_eq!(
            block_streams.len(),
            grid.pair_count(),
            "stream list covers {} blocks, weight grid has {}",
            block_streams.len(),
            grid.pair_count()
        );
        let blocks: Vec<PreparedBlock> = par_map(grid.pair_count(), |blk| {
            let tb = template_block(b, &grid, method, self.cfg.array, blk);
            self.program_block(&tb, block_streams[blk], tag)
        });
        PreparedWeights { blocks, grid, method: method.clone(), k: b.rows, n: b.cols }
    }

    /// The deterministic half of [`DotProductEngine::prepare_weights`]:
    /// block, pad, quantize, and slice `b` once into a reusable
    /// [`WeightTemplate`] (§Perf). No RNG is consumed.
    pub fn weight_template(&self, b: &Matrix, method: &SliceMethod) -> WeightTemplate {
        let grid = MatmulBlocks::new(b.rows, b.cols, self.cfg.array);
        self.assert_method_fits(&method.spec);
        let blocks: Vec<TemplateBlock> = par_map(grid.pair_count(), |blk| {
            template_block(b, &grid, method, self.cfg.array, blk)
        });
        WeightTemplate {
            blocks,
            grid,
            method: method.clone(),
            k: b.rows,
            n: b.cols,
            array: self.cfg.array,
        }
    }

    /// Every slice digit must be representable by the device's `g_levels`.
    fn assert_method_fits(&self, spec: &SliceSpec) {
        let w_tables = spec.tables();
        assert!(
            w_tables.max_digit.iter().all(|&d| d <= self.cfg.device.max_digit() as f64),
            "slice width exceeds device g_levels={}",
            self.cfg.device.g_levels
        );
    }

    /// The stochastic tail of weight preparation for one block (step 3):
    /// per-plane lognormal programming noise, optional fault/retention
    /// injection, and the block's ADC chain. Noisy digits are written
    /// **directly into the packed panel layout** — the fused
    /// `l_m × (S_w·l_n)` matrix is never materialized; values and RNG draw
    /// order are identical to programming each plane densely and packing
    /// afterwards.
    ///
    /// `stream` keys every RNG draw of the block: the layer-local block
    /// index on the unmapped path, the physical array slot id on the
    /// chip-mapped path (`prepare_weights_mapped`).
    ///
    /// Fault/retention injection is a program-time effect: it runs once
    /// per prepared-weight lifetime on its own RNG stream (so an all-off
    /// spec leaves the programming-noise stream — and every bit of the
    /// result — untouched), and costs nothing per matmul.
    fn program_block(&self, tb: &TemplateBlock, stream: u64, tag: u64) -> PreparedBlock {
        let (l_m, l_n) = self.cfg.array;
        let n_slices = tb.planes.len();
        let dev = &self.cfg.device;
        let step = dev.step();
        let mut rng = Pcg64::new(self.seed ^ (tag.wrapping_mul(0x9E37_79B9_7F4A_7C15)), stream);
        let ni = &self.cfg.nonideal;
        let inject = !self.cfg.noise_free && ni.injects_at_program();
        let mut fault_rng = inject.then(|| {
            Pcg64::new(
                self.seed ^ ni.seed ^ tag.wrapping_mul(0xD1B5_4A32_D192_ED03),
                0x4641_544C ^ stream,
            )
        });
        let mut packed = PackedB::zeros(l_m, n_slices * l_n);
        for (s, plane) in tb.planes.iter().enumerate() {
            let c0 = s * l_n;
            if let Some(frng) = fault_rng.as_mut() {
                // Injection path: materialize the programmed plane so the
                // fault masks see the same `l_m × l_n` view as the digits.
                let mut programmed = self.program_plane(plane, &mut rng);
                ni.inject_plane(&mut programmed, dev, frng);
                for r in 0..l_m {
                    for (c, &v) in programmed.row(r).iter().enumerate() {
                        packed.write(r, c0 + c, v);
                    }
                }
            } else if self.cfg.noise_free {
                for r in 0..l_m {
                    for (c, &d) in plane.row(r).iter().enumerate() {
                        packed.write(r, c0 + c, d);
                    }
                }
            } else {
                for r in 0..l_m {
                    for (c, &d) in plane.row(r).iter().enumerate() {
                        let g = dev.sample_level(d as u32, &mut rng);
                        packed.write(r, c0 + c, (g - dev.lgs) / step);
                    }
                }
            }
        }
        let packed_int = PackedU8::from_packed(&packed);
        PreparedBlock { packed, packed_int, scale: tb.scale, chain: self.adc_chain_for(stream) }
    }

    /// [`DotProductEngine::program_block`] with the closed verify loop
    /// (paper-adjacent iterative program-and-verify): after each plane is
    /// programmed, it is read back through the read-noise model on a
    /// dedicated RNG stream and re-drawn while its worst per-cell digit
    /// error exceeds `spec.tolerance`, up to `spec.max_retries` extra
    /// attempts.
    ///
    /// Invariants that keep the disabled/clean cases bit-identical to the
    /// plain path:
    /// - the programming and fault streams are the same generators in the
    ///   same order as `program_block`; a plane that passes on its first
    ///   attempt consumes exactly the plain path's draws;
    /// - read-back uses its **own** stream (never the programming or
    ///   fault generators), and is draw-free when `read_cv == 0`;
    /// - retries re-apply the plane's *captured* fault mask — stuck cells
    ///   belong to the physical array, so they are pinned identically on
    ///   every attempt and a plane hosting one above tolerance never
    ///   converges (the detection signal) — while drift is re-drawn from
    ///   the continuing fault stream (a reprogram decays afresh).
    fn program_block_verified(
        &self,
        tb: &TemplateBlock,
        stream: u64,
        tag: u64,
        spec: &RepairSpec,
    ) -> (PreparedBlock, BlockProgramStats) {
        let (l_m, l_n) = self.cfg.array;
        let n_slices = tb.planes.len();
        let dev = &self.cfg.device;
        let max_digit = dev.max_digit() as f64;
        let mut rng = Pcg64::new(self.seed ^ (tag.wrapping_mul(0x9E37_79B9_7F4A_7C15)), stream);
        let ni = &self.cfg.nonideal;
        let inject = !self.cfg.noise_free && ni.injects_at_program();
        let mut fault_rng = inject.then(|| {
            Pcg64::new(
                self.seed ^ ni.seed ^ tag.wrapping_mul(0xD1B5_4A32_D192_ED03),
                0x4641_544C ^ stream,
            )
        });
        let mut verify_rng =
            Pcg64::new(self.seed ^ tag.wrapping_mul(0x94D0_49BB_1331_11EB), 0x7E81_0000 ^ stream);
        let read_cv = if self.cfg.noise_free { 0.0 } else { dev.read_cv };
        let mut packed = PackedB::zeros(l_m, n_slices * l_n);
        let mut stats = BlockProgramStats {
            block: 0,
            stream,
            retries: 0,
            unconverged_planes: 0,
            worst_err: 0.0,
        };
        for (s, plane) in tb.planes.iter().enumerate() {
            let c0 = s * l_n;
            // First attempt: identical draws to `program_block`.
            let (mut programmed, mask) = if let Some(frng) = fault_rng.as_mut() {
                let mut p = self.program_plane(plane, &mut rng);
                let m = ni.inject_plane_masked(&mut p, dev, frng);
                (p, Some(m))
            } else if self.cfg.noise_free {
                (plane.clone(), None)
            } else {
                (self.program_plane(plane, &mut rng), None)
            };
            let mut err = plane_readback_error(&programmed, plane, read_cv, &mut verify_rng);
            let mut attempts = 0usize;
            while err > spec.tolerance && attempts < spec.max_retries {
                attempts += 1;
                programmed = self.program_plane(plane, &mut rng);
                if let Some(frng) = fault_rng.as_mut() {
                    // Drift decays afresh on a reprogram (new per-cell
                    // exponents from the continuing fault stream); the
                    // captured stuck-cell mask is then pinned unchanged.
                    let drift_only = NonIdealitySpec { faults: FaultSpec::none(), ..ni.clone() };
                    drift_only.inject_plane(&mut programmed, dev, frng);
                    if let Some(m) = mask.as_ref() {
                        m.apply(&mut programmed, max_digit);
                    }
                }
                err = plane_readback_error(&programmed, plane, read_cv, &mut verify_rng);
            }
            stats.retries += attempts;
            if err > spec.tolerance {
                stats.unconverged_planes += 1;
            }
            stats.worst_err = stats.worst_err.max(err);
            for r in 0..l_m {
                for (c, &v) in programmed.row(r).iter().enumerate() {
                    packed.write(r, c0 + c, v);
                }
            }
        }
        let packed_int = PackedU8::from_packed(&packed);
        let chain = self.adc_chain_for(stream);
        (PreparedBlock { packed, packed_int, scale: tb.scale, chain }, stats)
    }

    /// Reprogram only the listed `(block, new_stream)` pairs of an
    /// existing [`PreparedWeights`] in place — the remap-to-spare path
    /// ([`crate::arch::repair::RepairPlan`]). Each moved block re-derives
    /// its template slice deterministically and programs it at the *new*
    /// physical stream, so its programming noise, fault mask, and ADC
    /// chain all belong to the destination slot; untouched blocks keep
    /// their bits. `b` must be the matrix the weights were prepared from.
    pub fn reprogram_prepared_blocks(
        &self,
        w: &mut PreparedWeights,
        b: &Matrix,
        moves: &[(usize, u64)],
        tag: u64,
    ) {
        assert_eq!(
            (b.rows, b.cols),
            (w.k, w.n),
            "weight matrix is {}x{}, prepared weights are {}x{}",
            b.rows,
            b.cols,
            w.k,
            w.n
        );
        assert_eq!(
            (w.grid.k.block, w.grid.n.block),
            self.cfg.array,
            "weights were prepared for {:?} arrays, engine has {:?}",
            (w.grid.k.block, w.grid.n.block),
            self.cfg.array
        );
        let method = w.method.clone();
        for &(blk, stream) in moves {
            assert!(blk < w.blocks.len(), "block {blk} out of {} blocks", w.blocks.len());
            let tb = template_block(b, &w.grid, &method, self.cfg.array, blk);
            w.blocks[blk] = self.program_block(&tb, stream, tag);
        }
    }

    /// Delta-reprogram an existing [`PreparedWeights`] in place after an
    /// optimizer step (§Perf training path): re-derive each block's
    /// quantized template from the updated matrix `b`, diff it against the
    /// cached `template`, and rewrite **only the cells whose digits
    /// changed** — drawing their replacement programming noise from a
    /// fresh generator keyed by `tag` at the block's existing per-slot
    /// stream (`block_streams[blk]`), so the draws stay attached to the
    /// physical array and are deterministic under any thread count (each
    /// block is diffed and drawn by exactly one worker, planes ascending,
    /// row-major). Clean blocks cost one template diff; scale-only blocks
    /// additionally update the recombination scale; cells untouched by the
    /// step keep the analog noise of their previous programming — the
    /// physics of not pulsing a cell. The cached `template` is updated to
    /// the fresh digits so the next step diffs against this one.
    ///
    /// On noise-free engines the result is bit-identical to a full
    /// `prepare_weights_mapped` at the same streams (digits are written
    /// exactly and the ADC chain keys off the stream only). Program-time
    /// fault/retention injection cannot be replayed cell-wise, so this
    /// path refuses it — callers must fall back to a full reprogram
    /// ([`crate::nn::MemCore::program_delta`] does).
    pub fn program_delta(
        &self,
        template: &mut WeightTemplate,
        b: &Matrix,
        tag: u64,
        block_streams: &[u64],
        prev: &mut PreparedWeights,
    ) -> DeltaReport {
        assert_eq!(
            (b.rows, b.cols),
            (prev.k, prev.n),
            "weight matrix is {}x{}, prepared weights are {}x{}",
            b.rows,
            b.cols,
            prev.k,
            prev.n
        );
        assert_eq!(
            (template.k, template.n),
            (prev.k, prev.n),
            "template shape {:?} does not match prepared weights {:?}",
            (template.k, template.n),
            (prev.k, prev.n)
        );
        assert_eq!(
            template.array, self.cfg.array,
            "template was blocked for {:?} arrays, engine has {:?}",
            template.array, self.cfg.array
        );
        assert_eq!(template.method, prev.method, "template/prepared slice methods differ");
        assert_eq!(
            block_streams.len(),
            prev.blocks.len(),
            "stream list covers {} blocks, weight grid has {}",
            block_streams.len(),
            prev.blocks.len()
        );
        assert!(
            self.cfg.noise_free || !self.cfg.nonideal.injects_at_program(),
            "program_delta cannot replay program-time fault injection — full reprogram required"
        );
        let (l_m, l_n) = self.cfg.array;
        let dev = &self.cfg.device;
        let step = dev.step();
        let noise_free = self.cfg.noise_free;
        // Classification per block: 0 = clean, 1 = scale-only, 2 = redraw
        // (with the dirty-cell writes in packed-panel coordinates).
        type BlockDelta = (u8, Option<TemplateBlock>, Vec<(usize, usize, f64)>);
        let deltas: Vec<BlockDelta> = par_map(prev.blocks.len(), |blk| {
            let fresh = template_block(b, &prev.grid, &prev.method, self.cfg.array, blk);
            let old = &template.blocks[blk];
            if fresh.planes == old.planes {
                if fresh.scale == old.scale {
                    return (0, None, Vec::new());
                }
                return (1, Some(fresh), Vec::new());
            }
            let mut rng =
                Pcg64::new(self.seed ^ (tag.wrapping_mul(0x9E37_79B9_7F4A_7C15)), block_streams[blk]);
            let mut writes = Vec::new();
            for (s, plane) in fresh.planes.iter().enumerate() {
                let old_plane = &old.planes[s];
                let c0 = s * l_n;
                for r in 0..l_m {
                    let new_row = plane.row(r);
                    let old_row = old_plane.row(r);
                    for c in 0..l_n {
                        if new_row[c] != old_row[c] {
                            let v = if noise_free {
                                new_row[c]
                            } else {
                                let g = dev.sample_level(new_row[c] as u32, &mut rng);
                                (g - dev.lgs) / step
                            };
                            writes.push((r, c0 + c, v));
                        }
                    }
                }
            }
            (2, Some(fresh), writes)
        });
        let mut report = DeltaReport { blocks: prev.blocks.len(), ..DeltaReport::default() };
        for (blk, (class, fresh, writes)) in deltas.into_iter().enumerate() {
            match class {
                0 => report.blocks_clean += 1,
                1 => {
                    let fresh = fresh.expect("scale-only delta carries the fresh template");
                    prev.blocks[blk].scale = fresh.scale;
                    template.blocks[blk] = fresh;
                    report.blocks_scale_only += 1;
                }
                _ => {
                    let fresh = fresh.expect("redraw delta carries the fresh template");
                    report.blocks_redrawn += 1;
                    report.cells_redrawn += writes.len();
                    let pb = &mut prev.blocks[blk];
                    pb.scale = fresh.scale;
                    for (r, c, v) in writes {
                        pb.packed.write(r, c, v);
                    }
                    pb.packed_int = PackedU8::from_packed(&pb.packed);
                    template.blocks[blk] = fresh;
                }
            }
        }
        report
    }

    /// Program one digit plane through the device model: digit → target
    /// conductance → lognormal sample → effective analog digit
    /// (offset-corrected, i.e. `(G − LGS)/step`).
    fn program_plane(&self, plane: &Matrix, rng: &mut Pcg64) -> Matrix {
        let dev = &self.cfg.device;
        let step = dev.step();
        Matrix {
            rows: plane.rows,
            cols: plane.cols,
            data: plane
                .data
                .iter()
                .map(|&d| {
                    let g = dev.sample_level(d as u32, rng);
                    (g - dev.lgs) / step
                })
                .collect(),
        }
    }

    /// Full matmul `a (m×k) · b (k×n)` with per-call weight programming.
    pub fn matmul(&self, a: &Matrix, b: &Matrix, a_med: &SliceMethod, b_med: &SliceMethod) -> Matrix {
        let prepared = self.prepare_weights(b, b_med, 0);
        self.matmul_prepared(a, &prepared, a_med, 0)
    }

    /// INT-path convenience (both operands quantization-sliced).
    pub fn matmul_int(&self, a: &Matrix, b: &Matrix, a_spec: &SliceSpec, b_spec: &SliceSpec) -> Matrix {
        self.matmul(a, b, &SliceMethod::int(a_spec.clone()), &SliceMethod::int(b_spec.clone()))
    }

    /// FP-path convenience (both operands pre-aligned).
    pub fn matmul_fp(&self, a: &Matrix, b: &Matrix, a_spec: &SliceSpec, b_spec: &SliceSpec) -> Matrix {
        self.matmul(a, b, &SliceMethod::fp(a_spec.clone()), &SliceMethod::fp(b_spec.clone()))
    }

    /// Quantize + slice each k-block of the input once into a reusable
    /// [`PreparedInputs`] (the deterministic input half of the matmul —
    /// no RNG is consumed, so the cached path is bit-identical to per-call
    /// slicing; §Perf). The fused single-pass
    /// [`crate::dpe::slicing::quantize_slice_block`] writes the digits
    /// straight into byte-packed [`DigitPlanes`] — no intermediate integer
    /// matrix and no f64 digit planes.
    pub fn prepare_inputs(&self, a: &Matrix, method: &SliceMethod) -> PreparedInputs {
        let m = a.rows;
        let l_m = self.cfg.array.0;
        let kdim = BlockDim::new(a.cols, l_m);
        let blocks: Vec<InputBlock> = par_map(kdim.count(), |kb| {
            let (k0, kl) = kdim.range(kb);
            let sub = a.block(0, k0, m, kl).pad_to(m, l_m);
            let sb = quantize_slice_block(&sub, &method.spec, method.mode);
            InputBlock { planes: sb.planes, scale: sb.scale }
        });
        PreparedInputs { blocks, method: method.clone(), m, k: a.cols, l_m }
    }

    /// Matmul against pre-programmed weights (the NN hot path): slices `a`
    /// per call, then dispatches into the stacked slice-plane pipeline (see
    /// module §Perf). `tag` decorrelates per-read conductance fluctuation
    /// ([`crate::device::DeviceSpec::read_cv`]) between calls; with the
    /// default `read_cv = 0` reads are deterministic and the tag is inert.
    /// Loops that reuse the same `a` should slice it once with
    /// [`DotProductEngine::prepare_inputs`] instead.
    pub fn matmul_prepared(
        &self,
        a: &Matrix,
        w: &PreparedWeights,
        a_med: &SliceMethod,
        tag: u64,
    ) -> Matrix {
        assert_eq!(a.cols, w.k, "matmul dim mismatch: a is {}x{}, weights are {}x{}", a.rows, a.cols, w.k, w.n);
        let ai = self.prepare_inputs(a, a_med);
        self.matmul_prepared_inputs_with(&ai, w, tag, true)
    }

    /// [`DotProductEngine::matmul_prepared`] with the input already sliced
    /// — the fully-cached hot path: per call only the GEMMs, ADC, and
    /// shift-add recombination run (plus read-noise draws when
    /// `device.read_cv > 0`, decorrelated by `tag`).
    pub fn matmul_prepared_inputs(
        &self,
        a: &PreparedInputs,
        w: &PreparedWeights,
        tag: u64,
    ) -> Matrix {
        self.matmul_prepared_inputs_with(a, w, tag, true)
    }

    /// `matmul_prepared_inputs` with explicit parallelism control: hot
    /// loops already parallel at an outer level (Monte-Carlo cycles) pass
    /// `parallel = false` so neither the pair loop nor the in-pair 2-D
    /// GEMM grid spawns nested thread scopes (§Perf).
    pub(crate) fn matmul_prepared_inputs_with(
        &self,
        a: &PreparedInputs,
        w: &PreparedWeights,
        tag: u64,
        parallel: bool,
    ) -> Matrix {
        assert_eq!(
            a.k, w.k,
            "matmul dim mismatch: inputs are {}x{}, weights are {}x{}",
            a.m, a.k, w.k, w.n
        );
        assert_eq!(
            a.l_m, self.cfg.array.0,
            "inputs were sliced for array rows {}, engine has {}",
            a.l_m, self.cfg.array.0
        );
        assert_eq!(
            (w.grid.k.block, w.grid.n.block),
            self.cfg.array,
            "weights were prepared for {:?} arrays, engine has {:?}",
            (w.grid.k.block, w.grid.n.block),
            self.cfg.array
        );
        let grid = w.grid;
        let (m, n) = (a.m, w.n);
        let nc = grid.n.count();
        let (l_m, l_n) = self.cfg.array;
        let adc = Adc::new(self.cfg.radc);
        let plan = SlicePairPlan::new(l_m, &a.method.spec, &w.method.spec);
        let a_blocks = &a.blocks;

        // Parallelize across (kb, nb) array pairs when the grid carries
        // enough *total* work (the old per-pair threshold starved the pool
        // on small-m grids: an m = 1 matmul over many blocks has lots of
        // cheap pairs); a lone/tiny grid instead 2-D-schedules each pair's
        // stacked GEMM over (row-band × panel-group) items inside
        // `pair_contribution_stacked` — one level of parallelism either
        // way, no nested spawn (§Perf).
        let per_pair_work =
            m * l_m * l_n * plan.a.num_slices() * plan.w.num_slices();
        let tasks = grid.pair_count();
        let across_pairs =
            parallel && tasks >= 2 && per_pair_work.saturating_mul(tasks) >= (1 << 19);
        let grid_parallel = parallel && !across_pairs;

        // One task per (kb, nb) array pair: returns the scaled block
        // contribution, or `None` for zero-scale pairs (all-zero block of
        // either operand) — no allocation, and `assemble_output` skips
        // them; per-nb reduction afterwards is cheap.
        let pair_body = |task: usize| -> Option<Matrix> {
            let (kb, nb) = grid.pair(task);
            let ab = &a_blocks[kb];
            let wb = &w.blocks[kb * nc + nb];
            if ab.scale == 0.0 || wb.scale == 0.0 {
                return None;
            }
            Some(if self.cfg.use_circuit {
                self.pair_contribution_circuit(ab, wb, &plan, &adc, task, tag)
            } else {
                self.pair_contribution_stacked(ab, wb, &plan, &adc, task, tag, grid_parallel)
            })
        };
        let pair_results: Vec<Option<Matrix>> = if across_pairs {
            par_map(tasks, pair_body)
        } else {
            (0..tasks).map(pair_body).collect()
        };

        assemble_output(&grid, m, n, l_n, &pair_results)
    }

    /// The per-column ADC chain of one physical array pair: ideal (fast
    /// readout path) unless the non-ideality spec configures gain/offset
    /// error or floor rounding. Each block has its own periphery, so
    /// distinct arrays sample independent mismatch; the sampling is
    /// deterministic in (engine seed, injection seed, `stream` — the
    /// layer-local block id, or the physical slot id on the chip-mapped
    /// path) and happens once at `prepare_weights` time (the chain is
    /// stored in the [`PreparedBlock`], a static calibration error shared
    /// by every matmul — and by the `#[cfg(test)]` reference oracle).
    fn adc_chain_for(&self, stream: u64) -> AdcChain {
        let ni = &self.cfg.nonideal;
        if self.cfg.noise_free || ni.adc.is_ideal() {
            return AdcChain::ideal();
        }
        let mut rng = Pcg64::new(self.seed ^ ni.seed, 0xADC0_0000 ^ stream);
        AdcChain::sample(&ni.adc, self.cfg.array.1, &mut rng)
    }

    /// The stacked slice-plane contribution of one (k-block, n-block)
    /// array pair: **one** stacked GEMM over the byte-packed input planes
    /// produces every `(sa, sw)` partial — input slice `sa`'s row block of
    /// the stacked output, column stripe `sw` within it — then each stripe
    /// is read-noised (when configured), ADC'd, and recombined in the same
    /// ascending (sa, sw) order as the per-pair reference, so the
    /// accumulation is bit-identical (§Perf). When `grid_parallel` is set
    /// and the GEMM is big enough, it runs as 2-D (row-band ×
    /// panel-group) work items on the atomic-counter scheduler.
    #[allow(clippy::too_many_arguments)]
    fn pair_contribution_stacked(
        &self,
        ab: &InputBlock,
        wb: &PreparedBlock,
        plan: &SlicePairPlan,
        adc: &Adc,
        blk: usize,
        tag: u64,
        grid_parallel: bool,
    ) -> Matrix {
        let l_n = self.cfg.array.1;
        let m = ab.planes.rows;
        let l_m = ab.planes.cols;
        let sa_n = plan.a.num_slices();
        let sw_n = plan.w.num_slices();
        let wide = sw_n * l_n;
        let chain = &wb.chain;
        let read_noise = self.read_noise_active();
        let mut block_acc = Matrix::zeros(m, l_n);
        let mut stacked_out = vec![0.0f64; sa_n * m * wide];
        // Integer kernel: engages when the plan proved the accumulator
        // bound AND this block's programmed digits are exact integers no
        // wider than the proof assumed — bit-identical to the f64 kernel
        // either way (§Perf), so the dispatch is invisible to results.
        let int_panels = plan.int_acc.and_then(|acc| {
            wb.packed_int
                .as_ref()
                .filter(|ip| ip.max_digit() as f64 <= plan.max_w_digit)
                .map(|ip| (ip, acc))
        });
        let grid_2d = grid_parallel && sa_n * m * l_m * wide >= (1 << 21);
        match (int_panels, grid_2d) {
            (Some((ip, acc)), true) => {
                matmul_packed_stacked_int_2d(&ab.planes, ip, acc, &mut stacked_out)
            }
            (Some((ip, acc)), false) => {
                matmul_packed_stacked_int_into(&ab.planes, ip, acc, &mut stacked_out)
            }
            (None, true) => matmul_packed_stacked_2d(&ab.planes, &wb.packed, &mut stacked_out),
            (None, false) => matmul_packed_stacked_into(&ab.planes, &wb.packed, &mut stacked_out),
        }
        for sa in 0..sa_n {
            // Input slice sa's rows of the stacked output (slice-major).
            let sa_out = &mut stacked_out[sa * m * wide..(sa + 1) * m * wide];
            if !self.cfg.noise_free {
                for sw in 0..sw_n {
                    let stripe = Stripe { rows: m, stride: wide, c0: sw * l_n, width: l_n };
                    if read_noise {
                        self.apply_read_noise(sa_out, stripe, blk, sa, sw, tag);
                    }
                    self.adc_readout(
                        adc,
                        sa_out,
                        stripe,
                        plan.worst_scale[plan.idx(sa, sw)],
                        chain,
                    );
                }
            }
            // Shift-add recombination over the stripes, in the same
            // (sa, sw) order as the per-pair reference → bit-identical
            // accumulation.
            for sw in 0..sw_n {
                let wgt = plan.pair_weight[plan.idx(sa, sw)];
                for i in 0..m {
                    let src = &sa_out[i * wide + sw * l_n..i * wide + (sw + 1) * l_n];
                    let dst = &mut block_acc.data[i * l_n..(i + 1) * l_n];
                    for (o, &p) in dst.iter_mut().zip(src) {
                        *o += wgt * p;
                    }
                }
            }
        }
        let s = ab.scale * wb.scale;
        for v in block_acc.data.iter_mut() {
            *v *= s;
        }
        block_acc
    }

    /// Per-plane contribution of one array pair through the IR-drop
    /// circuit solver (the `use_circuit` path keeps the per-slice-pair
    /// structure: the circuit solve itself is the bottleneck there, not
    /// GEMM dispatch).
    fn pair_contribution_circuit(
        &self,
        ab: &InputBlock,
        wb: &PreparedBlock,
        plan: &SlicePairPlan,
        adc: &Adc,
        blk: usize,
        tag: u64,
    ) -> Matrix {
        let l_n = self.cfg.array.1;
        let m = ab.planes.rows;
        let sw_n = plan.w.num_slices();
        let chain = &wb.chain;
        let read_noise = self.read_noise_active();
        let mut block_acc = Matrix::zeros(m, l_n);
        // Unpack each weight plane once per pair (not once per slice pair);
        // input planes materialize f64 on demand (the circuit solve is the
        // bottleneck here, not the conversion).
        let w_planes: Vec<Matrix> = (0..sw_n).map(|sw| wb.plane(sw, l_n)).collect();
        for sa in 0..ab.planes.num_planes() {
            let a_plane = ab.planes.plane(sa);
            for (sw, w_plane) in w_planes.iter().enumerate() {
                let mut partial = self.circuit_mvm(&a_plane, w_plane, plan.a.max_digit[sa]);
                if !self.cfg.noise_free {
                    if read_noise {
                        self.apply_read_noise(
                            &mut partial.data,
                            Stripe::contiguous(m, l_n),
                            blk,
                            sa,
                            sw,
                            tag,
                        );
                    }
                    self.adc_readout(
                        adc,
                        &mut partial.data,
                        Stripe::contiguous(m, l_n),
                        plan.worst_scale[plan.idx(sa, sw)],
                        chain,
                    );
                }
                let wgt = plan.pair_weight[plan.idx(sa, sw)];
                for (o, &p) in block_acc.data.iter_mut().zip(&partial.data) {
                    *o += wgt * p;
                }
            }
        }
        let s = ab.scale * wb.scale;
        for v in block_acc.data.iter_mut() {
            *v *= s;
        }
        block_acc
    }

    /// True iff per-read conductance fluctuation is modeled — then (and
    /// only then) the `tag` of the prepared matmuls has draws to
    /// decorrelate.
    fn read_noise_active(&self) -> bool {
        !self.cfg.noise_free && self.cfg.device.read_cv > 0.0
    }

    /// Multiplicative per-read lognormal fluctuation
    /// ([`crate::device::DeviceSpec::read_cv`]) on one readout stripe,
    /// applied before the ADC. One RNG stream per (array pair, input
    /// slice, weight slice), seeded by the call `tag` and drawn row-major
    /// over the stripe — identical between the stacked pipeline, the circuit
    /// path, and the reference oracle, and independent of pair scheduling.
    fn apply_read_noise(
        &self,
        data: &mut [f64],
        stripe: Stripe,
        blk: usize,
        sa: usize,
        sw: usize,
        tag: u64,
    ) {
        let cv = self.cfg.device.read_cv;
        let mut rng = Pcg64::new(
            self.seed ^ tag.wrapping_mul(0xC2B2_AE3D_27D4_EB4F),
            0x5EAD_0000 ^ ((blk as u64) << 16) ^ ((sa as u64) << 8) ^ sw as u64,
        );
        for i in 0..stripe.rows {
            let s = i * stripe.stride + stripe.c0;
            for v in data[s..s + stripe.width].iter_mut() {
                *v *= rng.lognormal_cv(1.0, cv);
            }
        }
    }

    /// Apply the configured ADC policy to one readout stripe in place.
    /// With a non-ideal `chain`, each value passes through its column's
    /// gain/offset error and the configured rounding mode before code
    /// reconstruction; stripe column `j` is physical array column `j` in
    /// both the fused layout and the reference oracle's contiguous
    /// partials, so the two paths stay bit-identical under injection.
    fn adc_readout(&self, adc: &Adc, data: &mut [f64], stripe: Stripe, worst: f64, chain: &AdcChain) {
        match self.cfg.adc_policy {
            AdcPolicy::WorstCase => {
                let q = adc.for_full_scale(worst);
                if chain.is_ideal() {
                    for i in 0..stripe.rows {
                        let s = i * stripe.stride + stripe.c0;
                        q.quantize_slice(&mut data[s..s + stripe.width]);
                    }
                } else {
                    let step = q.step();
                    let max_code = self.cfg.radc as f64 - 1.0;
                    for i in 0..stripe.rows {
                        let s = i * stripe.stride + stripe.c0;
                        for (j, v) in data[s..s + stripe.width].iter_mut().enumerate() {
                            *v = chain.convert(*v, j, step, max_code);
                        }
                    }
                }
            }
            AdcPolicy::Calibrated | AdcPolicy::IntegerSnap => {
                // The PGA calibrates the range on the undistorted peak;
                // gain/offset mismatch then perturbs each conversion.
                let mut peak = 0.0f64;
                for i in 0..stripe.rows {
                    let s = i * stripe.stride + stripe.c0;
                    for &v in &data[s..s + stripe.width] {
                        peak = peak.max(v);
                    }
                }
                let mut step = peak / (self.cfg.radc as f64 - 1.0);
                if self.cfg.adc_policy == AdcPolicy::IntegerSnap {
                    step = step.max(1.0);
                }
                if step > 0.0 {
                    if chain.is_ideal() {
                        for i in 0..stripe.rows {
                            let s = i * stripe.stride + stripe.c0;
                            for v in data[s..s + stripe.width].iter_mut() {
                                *v = (*v / step).round().max(0.0) * step;
                            }
                        }
                    } else {
                        let max_code = self.cfg.radc as f64 - 1.0;
                        for i in 0..stripe.rows {
                            let s = i * stripe.stride + stripe.c0;
                            for (j, v) in data[s..s + stripe.width].iter_mut().enumerate() {
                                *v = chain.convert(*v, j, step, max_code);
                            }
                        }
                    }
                }
            }
        }
    }

    /// Reference per-slice-pair implementation — the pre-fusion pipeline,
    /// retained as the correctness oracle: the stacked path must be
    /// bit-identical to this for every spec/policy/shape. Hidden rather
    /// than `#[cfg(test)]` so `benches/gemm_kernel.rs` can hard-assert the
    /// bit-identity contract outside the test harness; not part of the
    /// public API.
    #[doc(hidden)]
    pub fn matmul_prepared_reference(
        &self,
        a: &Matrix,
        w: &PreparedWeights,
        a_med: &SliceMethod,
        tag: u64,
    ) -> Matrix {
        assert_eq!(a.cols, w.k, "matmul dim mismatch");
        let grid = w.grid;
        let (m, n) = (a.rows, w.n);
        let nc = grid.n.count();
        let (l_m, l_n) = self.cfg.array;
        let adc = Adc::new(self.cfg.radc);
        let plan = SlicePairPlan::new(l_m, &a_med.spec, &w.method.spec);
        let ai = self.prepare_inputs(a, a_med);
        let a_blocks = &ai.blocks;
        let read_noise = self.read_noise_active();
        let pair_results: Vec<Option<Matrix>> = (0..grid.pair_count())
            .map(|task| {
                let (kb, nb) = grid.pair(task);
                let ab = &a_blocks[kb];
                let wb = &w.blocks[kb * nc + nb];
                if ab.scale == 0.0 || wb.scale == 0.0 {
                    return None;
                }
                let chain = &wb.chain;
                let mut block_acc = Matrix::zeros(m, l_n);
                for sa in 0..ab.planes.num_planes() {
                    // The oracle runs on f64 materializations of the byte
                    // planes — `d as f64` is exact, so this is the same
                    // operand the stacked kernel sees.
                    let a_plane = ab.planes.plane(sa);
                    for sw in 0..plan.w.num_slices() {
                        let w_plane = wb.plane(sw, l_n);
                        let mut partial = if self.cfg.use_circuit {
                            self.circuit_mvm(&a_plane, &w_plane, plan.a.max_digit[sa])
                        } else {
                            a_plane.matmul(&w_plane)
                        };
                        if !self.cfg.noise_free {
                            if read_noise {
                                self.apply_read_noise(
                                    &mut partial.data,
                                    Stripe::contiguous(m, l_n),
                                    task,
                                    sa,
                                    sw,
                                    tag,
                                );
                            }
                            self.adc_readout(
                                &adc,
                                &mut partial.data,
                                Stripe::contiguous(m, l_n),
                                plan.worst_scale[plan.idx(sa, sw)],
                                chain,
                            );
                        }
                        let wgt = plan.pair_weight[plan.idx(sa, sw)];
                        for (o, &p) in block_acc.data.iter_mut().zip(&partial.data) {
                            *o += wgt * p;
                        }
                    }
                }
                let s = ab.scale * wb.scale;
                for v in block_acc.data.iter_mut() {
                    *v *= s;
                }
                Some(block_acc)
            })
            .collect();
        assemble_output(&grid, m, n, l_n, &pair_results)
    }

    /// Route one digit-plane MVM through the IR-drop circuit model: inputs
    /// become voltages (`digit/a_max · v_read`), digits become conductances,
    /// output currents convert back to digit units.
    fn circuit_mvm(&self, a_plane: &Matrix, w_plane: &Matrix, a_max: f64) -> Matrix {
        let dev = &self.cfg.device;
        let step = dev.step();
        // Conductance matrix for this plane: G = digit·step + LGS.
        let g = w_plane.map(|d| d * step + dev.lgs);
        let xb = CrossbarCircuit::new(g, self.cfg.r_wire);
        let mut out = Matrix::zeros(a_plane.rows, w_plane.cols);
        let scale_v = if a_max > 0.0 { self.cfg.v_read / a_max } else { 0.0 };
        for r in 0..a_plane.rows {
            let v: Vec<f64> = a_plane.row(r).iter().map(|&d| d * scale_v).collect();
            let (sol, _) = xb.solve_cross_iteration(&v, 1e-9, 40);
            // Subtract the LGS offset column contribution digitally and
            // convert current → digit units.
            let v_sum: f64 = v.iter().sum();
            for c in 0..w_plane.cols {
                let i_dev = sol.i_out[c];
                let digit_val = (i_dev - v_sum * dev.lgs) / (step * scale_v.max(f64::MIN_POSITIVE));
                *out.at_mut(r, c) = digit_val;
            }
        }
        out
    }

    /// Relative error of this engine vs the ideal matmul for given operands
    /// (the paper's RE metric).
    pub fn relative_error(&self, a: &Matrix, b: &Matrix, a_med: &SliceMethod, b_med: &SliceMethod) -> f64 {
        self.matmul(a, b, a_med, b_med).relative_error(&a.matmul(b))
    }
}

/// The deterministic per-block half of weight preparation (steps 1–2 of
/// the module pipeline): extract + pad the block, quantize, and slice into
/// digit planes. Shared verbatim by `prepare_weights` and
/// `weight_template`, so the fused and the cached path cannot drift apart.
fn template_block(
    b: &Matrix,
    grid: &MatmulBlocks,
    method: &SliceMethod,
    array: (usize, usize),
    blk: usize,
) -> TemplateBlock {
    let (l_m, l_n) = array;
    let (kb, nb) = grid.pair(blk);
    let (k0, kl) = grid.k.range(kb);
    let (n0, nl) = grid.n.range(nb);
    // Pad short edge blocks to the full array size with zeros.
    let sub = b.block(k0, n0, kl, nl).pad_to(l_m, l_n);
    let qb = quantize_block(&sub, &method.spec, method.mode);
    TemplateBlock { planes: slice_digits(&qb.q, &method.spec), scale: qb.scale }
}

/// Worst per-cell digit error of one programmed plane read back through
/// multiplicative per-read fluctuation (`read_cv`) against the template's
/// target digits — the verify metric of
/// [`DotProductEngine::program_block_verified`]. Draw-free when
/// `read_cv == 0` ([`Pcg64::lognormal_cv`] early-returns), so a
/// deterministic read-back costs no RNG state.
fn plane_readback_error(
    programmed: &Matrix,
    target: &Matrix,
    read_cv: f64,
    rng: &mut Pcg64,
) -> f64 {
    let mut worst = 0.0f64;
    for (&got, &want) in programmed.data.iter().zip(&target.data) {
        let read = got * rng.lognormal_cv(1.0, read_cv);
        worst = worst.max((read - want).abs());
    }
    worst
}

/// Reduce per-pair block contributions into the `m × n` output: sum over
/// k-blocks per column block, then un-pad into place. `None` entries are
/// zero-scale pairs that contributed nothing — they are skipped instead of
/// being materialized as zero matrices.
fn assemble_output(
    grid: &MatmulBlocks,
    m: usize,
    n: usize,
    l_n: usize,
    pair_results: &[Option<Matrix>],
) -> Matrix {
    let (kc, nc) = (grid.k.count(), grid.n.count());
    let mut out = Matrix::zeros(m, n);
    let mut acc = Matrix::zeros(m, l_n);
    for nb in 0..nc {
        let (n0, nl) = grid.n.range(nb);
        acc.data.fill(0.0);
        let mut any = false;
        for kb in 0..kc {
            if let Some(p) = &pair_results[kb * nc + nb] {
                any = true;
                for (o, &v) in acc.data.iter_mut().zip(&p.data) {
                    *o += v;
                }
            }
        }
        if any {
            out.set_block_clipped(0, n0, &acc.block(0, 0, m, nl));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_mat(m: usize, n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::seeded(seed);
        Matrix::random_uniform(m, n, -1.0, 1.0, &mut rng)
    }

    #[test]
    fn ideal_engine_int8_small_error() {
        // Noise-free sliced arithmetic: only quantization error remains,
        // which for INT8 on 64-blocks is well under 1%.
        let e = DotProductEngine::ideal((64, 64));
        let a = rand_mat(32, 50, 61);
        let b = rand_mat(50, 40, 62);
        let re = e.relative_error(&a, &b, &SliceMethod::int(SliceSpec::int8()), &SliceMethod::int(SliceSpec::int8()));
        assert!(re < 0.01, "re={re}");
    }

    #[test]
    fn ideal_engine_fp32_tiny_error() {
        let e = DotProductEngine::ideal((64, 64));
        let a = rand_mat(16, 16, 63);
        let b = rand_mat(16, 16, 64);
        let re = e.relative_error(&a, &b, &SliceMethod::fp(SliceSpec::fp32()), &SliceMethod::fp(SliceSpec::fp32()));
        assert!(re < 1e-5, "re={re}");
    }

    #[test]
    fn noisy_engine_error_ordering() {
        // More bits → lower error; noise → higher error than ideal.
        let a = rand_mat(64, 64, 65);
        let b = rand_mat(64, 64, 66);
        let noisy = DotProductEngine::new(DpeConfig::default(), 7);
        let re4 = noisy.relative_error(&a, &b, &SliceMethod::int(SliceSpec::int4()), &SliceMethod::int(SliceSpec::int4()));
        let re8 = noisy.relative_error(&a, &b, &SliceMethod::int(SliceSpec::int8()), &SliceMethod::int(SliceSpec::int8()));
        assert!(re8 < re4, "re8={re8} re4={re4}");
        let ideal = DotProductEngine::ideal((64, 64));
        let re8i = ideal.relative_error(&a, &b, &SliceMethod::int(SliceSpec::int8()), &SliceMethod::int(SliceSpec::int8()));
        assert!(re8i < re8, "ideal {re8i} vs noisy {re8}");
    }

    #[test]
    fn block_decomposition_matches_unblocked() {
        // Ideal engine: block size must not change the exact result when
        // scales are per-block exact (noise-free, generous bits).
        let a = rand_mat(20, 100, 67);
        let b = rand_mat(100, 30, 68);
        let big = DotProductEngine::ideal((128, 128));
        let small = DotProductEngine::ideal((32, 32));
        let med = SliceMethod::fp(SliceSpec::fp32());
        let r1 = big.matmul(&a, &b, &med, &med);
        let r2 = small.matmul(&a, &b, &med, &med);
        let ideal = a.matmul(&b);
        assert!(r1.relative_error(&ideal) < 1e-5);
        assert!(r2.relative_error(&ideal) < 1e-5);
    }

    #[test]
    fn smaller_blocks_reduce_quant_error() {
        // Fig 12: quantizing per smaller block tracks local dynamic range.
        // Construct a matrix with badly mismatched block magnitudes.
        let mut rng = Pcg64::seeded(69);
        let b = Matrix::from_fn(128, 128, |i, _| {
            let scale = if i < 64 { 1.0 } else { 1e-3 };
            scale * rng.uniform_range(-1.0, 1.0)
        });
        let a = rand_mat(32, 128, 70);
        let med = SliceMethod::int(SliceSpec::int8());
        let ideal = a.matmul(&b);
        let e_small = DotProductEngine::ideal((32, 32));
        let e_big = DotProductEngine::ideal((128, 128));
        let re_small = e_small.matmul(&a, &b, &med, &med).relative_error(&ideal);
        let re_big = e_big.matmul(&a, &b, &med, &med).relative_error(&ideal);
        assert!(re_small < re_big, "small={re_small} big={re_big}");
    }

    #[test]
    fn quantize_beats_prealign_same_bits() {
        // Fig 12's headline: quantization-based dot product beats the
        // pre-alignment method at the same effective bit width. The gap
        // shows when block maxima are away from powers of two (pre-align
        // rounds the scale up to 2^e): scale operands to ~0.7.
        let a = rand_mat(64, 64, 71).scale(0.7);
        let b = rand_mat(64, 64, 72).scale(0.7);
        let e = DotProductEngine::ideal((64, 64));
        let spec = SliceSpec::int8();
        let re_q = e.relative_error(&a, &b, &SliceMethod::int(spec.clone()), &SliceMethod::int(spec.clone()));
        let re_p = e.relative_error(&a, &b, &SliceMethod::fp(spec.clone()), &SliceMethod::fp(spec.clone()));
        assert!(re_q < re_p, "quant={re_q} prealign={re_p}");
    }

    #[test]
    fn prepared_weights_reused_across_inputs() {
        let e = DotProductEngine::new(DpeConfig::default(), 3);
        let b = rand_mat(64, 32, 73);
        let med = SliceMethod::int(SliceSpec::int8());
        let w = e.prepare_weights(&b, &med, 0);
        assert_eq!(w.shape(), (64, 32));
        assert_eq!(w.arrays_used(), 4); // 1 k-block × 1 n-block × 4 slices
        let a1 = rand_mat(8, 64, 74);
        let r1 = e.matmul_prepared(&a1, &w, &med, 0);
        let r1b = e.matmul_prepared(&a1, &w, &med, 0);
        // Same programmed weights → identical results.
        assert_eq!(r1.data, r1b.data);
        assert!(r1.relative_error(&a1.matmul(&b)) < 0.15);
    }

    #[test]
    fn programming_tag_decorrelates_noise() {
        let e = DotProductEngine::new(DpeConfig::default(), 3);
        let b = rand_mat(64, 64, 75);
        let med = SliceMethod::int(SliceSpec::int8());
        let a = rand_mat(8, 64, 76);
        let w0 = e.prepare_weights(&b, &med, 0);
        let w1 = e.prepare_weights(&b, &med, 1);
        let r0 = e.matmul_prepared(&a, &w0, &med, 0);
        let r1 = e.matmul_prepared(&a, &w1, &med, 0);
        assert_ne!(r0.data, r1.data);
    }

    #[test]
    fn nonsquare_and_padded_shapes() {
        let e = DotProductEngine::ideal((64, 64));
        let med = SliceMethod::int(SliceSpec::int8());
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 65, 7), (10, 100, 130), (128, 64, 1)] {
            let a = rand_mat(m, k, 80 + m as u64);
            let b = rand_mat(k, n, 90 + n as u64);
            let r = e.matmul(&a, &b, &med, &med);
            assert_eq!((r.rows, r.cols), (m, n));
            assert!(r.relative_error(&a.matmul(&b)) < 0.02, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn fused_pipeline_bit_identical_to_reference_oracle() {
        // Tentpole invariant: the fused slice-plane GEMM pipeline must
        // reproduce the retained per-slice-pair oracle bit for bit —
        // noise-free and seeded-noise, every ADC policy, INT and FP specs,
        // and ragged shapes that exercise edge-block padding.
        let shapes = [(5usize, 100usize, 37usize), (12, 64, 64), (3, 65, 130), (1, 1, 1)];
        let methods = [
            SliceMethod::int(SliceSpec::int4()),
            SliceMethod::int(SliceSpec::int8()),
            SliceMethod::fp(SliceSpec::fp16()),
            SliceMethod::fp(SliceSpec::bf16()),
        ];
        let policies = [AdcPolicy::WorstCase, AdcPolicy::Calibrated, AdcPolicy::IntegerSnap];
        for (si, &(m, k, n)) in shapes.iter().enumerate() {
            let a = rand_mat(m, k, 200 + si as u64);
            let b = rand_mat(k, n, 300 + si as u64);
            for method in &methods {
                for (pi, &adc_policy) in policies.iter().enumerate() {
                    for noise_free in [true, false] {
                        let cfg = DpeConfig {
                            array: (64, 64),
                            adc_policy,
                            noise_free,
                            ..DpeConfig::default()
                        };
                        let e = DotProductEngine::new(cfg, 7 + pi as u64);
                        let w = e.prepare_weights(&b, method, 1);
                        let fused = e.matmul_prepared(&a, &w, method, 0);
                        let oracle = e.matmul_prepared_reference(&a, &w, method, 0);
                        assert_eq!(
                            fused.data, oracle.data,
                            "{m}x{k}x{n} widths={:?} policy={adc_policy:?} noise_free={noise_free}",
                            method.spec.widths
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn fused_band_parallel_matches_reference() {
        // m large enough (with a single (kb, nb) task) to trip the in-pair
        // 2-D grid-scheduled GEMM: results must stay bit-identical.
        let e = DotProductEngine::new(DpeConfig::default(), 9);
        let med = SliceMethod::int(SliceSpec::int8());
        let a = rand_mat(300, 64, 501);
        let b = rand_mat(64, 64, 502);
        let w = e.prepare_weights(&b, &med, 0);
        let fused = e.matmul_prepared(&a, &w, &med, 0);
        let oracle = e.matmul_prepared_reference(&a, &w, &med, 0);
        assert_eq!(fused.data, oracle.data);
    }

    #[test]
    fn single_sample_wide_layer_matches_reference() {
        // The 2-D scheduling target shape: m = 1 over a wide layer (many
        // (kb, nb) pairs, each with trivial per-pair work). The total-work
        // dispatch must still be bit-identical to the serial oracle.
        let e = DotProductEngine::new(DpeConfig::default(), 15);
        let med = SliceMethod::int(SliceSpec::int8());
        let a = rand_mat(1, 512, 511);
        let b = rand_mat(512, 512, 512);
        let w = e.prepare_weights(&b, &med, 0);
        let fused = e.matmul_prepared(&a, &w, &med, 0);
        let oracle = e.matmul_prepared_reference(&a, &w, &med, 0);
        assert_eq!(fused.data, oracle.data);
    }

    #[test]
    fn prop_stacked_pipeline_matches_oracle_across_matrix() {
        // Satellite sweep: the stacked GEMM path must be bit-identical to
        // the per-slice-pair oracle across int4/int8/fp16 × all three ADC
        // policies × read-noise on/off × m ∈ {1, MR−1, MR, 33}, on random
        // ragged (k, n).
        use crate::tensor::GEMM_MR;
        let methods = [
            SliceMethod::int(SliceSpec::int4()),
            SliceMethod::int(SliceSpec::int8()),
            SliceMethod::fp(SliceSpec::fp16()),
        ];
        let policies = [AdcPolicy::WorstCase, AdcPolicy::Calibrated, AdcPolicy::IntegerSnap];
        let ms = [1usize, GEMM_MR - 1, GEMM_MR, 33];
        crate::util::prop::prop_check("stacked pipeline == per-slice oracle", 40, |g| {
            let method = g.choose(&methods).clone();
            let adc_policy = *g.choose(&policies);
            let read_noise = g.bool();
            let m = *g.choose(&ms);
            let k = g.usize_in(1..=100);
            let n = g.usize_in(1..=100);
            let mut cfg = DpeConfig { adc_policy, ..DpeConfig::default() };
            if read_noise {
                cfg.device.read_cv = 0.03;
            }
            let e = DotProductEngine::new(cfg, 41 + g.case as u64);
            let a = Matrix::from_vec(m, k, g.vec_f64(m * k, -1.0..1.0));
            let b = Matrix::from_vec(k, n, g.vec_f64(k * n, -1.0..1.0));
            let w = e.prepare_weights(&b, &method, 1);
            let fused = e.matmul_prepared(&a, &w, &method, 3);
            let oracle = e.matmul_prepared_reference(&a, &w, &method, 3);
            if fused.data != oracle.data {
                return Err(format!(
                    "{m}x{k}x{n} widths={:?} policy={adc_policy:?} read_noise={read_noise}",
                    method.spec.widths
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn int_kernel_engages_noise_free_and_matches_oracle() {
        // Noise-free programming writes digits verbatim, so every block
        // gets an exact-integer byte mirror and the integer kernel serves
        // the whole matmul; analog programming (default noisy config)
        // yields no mirror and keeps the f64 kernel. Both must be
        // bit-identical to the per-slice-pair oracle — the dispatch is
        // invisible to results.
        let med = SliceMethod::int(SliceSpec::int8());
        let a = rand_mat(9, 100, 601);
        let b = rand_mat(100, 70, 602);
        let ideal = DotProductEngine::ideal((64, 64));
        let w = ideal.prepare_weights(&b, &med, 0);
        assert_eq!(w.int_panel_blocks(), w.num_blocks(), "noise-free blocks must all mirror");
        let fused = ideal.matmul_prepared(&a, &w, &med, 0);
        assert_eq!(fused.data, ideal.matmul_prepared_reference(&a, &w, &med, 0).data);
        // Big single-block shape: trips the in-pair 2-D grid, so this
        // exercises the *parallel* integer kernel against the oracle.
        let a_big = rand_mat(300, 64, 603);
        let b_big = rand_mat(64, 64, 604);
        let wb = ideal.prepare_weights(&b_big, &med, 0);
        assert_eq!(wb.int_panel_blocks(), 1);
        let fused_big = ideal.matmul_prepared(&a_big, &wb, &med, 0);
        assert_eq!(fused_big.data, ideal.matmul_prepared_reference(&a_big, &wb, &med, 0).data);
        // Analog programming: lognormal conductance samples are not
        // integers, so no block carries a mirror.
        let noisy = DotProductEngine::new(DpeConfig::default(), 3);
        let wn = noisy.prepare_weights(&b, &med, 0);
        assert_eq!(wn.int_panel_blocks(), 0, "analog programming must keep the f64 kernel");
    }

    #[test]
    fn prop_int_kernel_dispatch_matches_oracle() {
        // Satellite sweep for the integer dispatch: random device-hostable
        // slice specs × noise-free (int kernel) vs noisy (f64 fallback) ×
        // read-noise on/off × m ∈ {1, MR−1, MR, 33} on ragged (k, n) —
        // always bit-identical to the oracle, and noise-free engines must
        // actually engage (every block mirrored).
        use crate::tensor::GEMM_MR;
        let ms = [1usize, GEMM_MR - 1, GEMM_MR, 33];
        crate::util::prop::prop_check("int-kernel dispatch == oracle", 40, |g| {
            // Signed spec the default device (g_levels = 16) can host:
            // 1-bit sign slice plus 1–4 slices of 1..=4 bits.
            let mut widths = vec![1usize];
            for _ in 0..g.usize_in(1..=4) {
                widths.push(g.usize_in(1..=4));
            }
            let method = SliceMethod::int(SliceSpec::new(&widths, true));
            let noise_free = g.bool();
            let m = *g.choose(&ms);
            let k = g.usize_in(1..=100);
            let n = g.usize_in(1..=100);
            let mut cfg = DpeConfig { noise_free, ..DpeConfig::default() };
            cfg.device.read_cv = if g.bool() { 0.03 } else { 0.0 };
            let e = DotProductEngine::new(cfg, 61 + g.case as u64);
            let a = Matrix::from_vec(m, k, g.vec_f64(m * k, -1.0..1.0));
            let b = Matrix::from_vec(k, n, g.vec_f64(k * n, -1.0..1.0));
            let w = e.prepare_weights(&b, &method, 1);
            if noise_free && w.int_panel_blocks() != w.num_blocks() {
                return Err(format!("widths {widths:?}: noise-free block lost its byte mirror"));
            }
            let fused = e.matmul_prepared(&a, &w, &method, 2);
            let oracle = e.matmul_prepared_reference(&a, &w, &method, 2);
            if fused.data != oracle.data {
                return Err(format!("{m}x{k}x{n} widths={widths:?} noise_free={noise_free}"));
            }
            Ok(())
        });
    }

    #[test]
    fn circuit_path_matches_reference_oracle() {
        let mut cfg = DpeConfig { use_circuit: true, r_wire: 0.5, array: (16, 16), ..DpeConfig::default() };
        cfg.device.cv = 0.0;
        let e = DotProductEngine::new(cfg, 5);
        let a = rand_mat(4, 20, 401);
        let b = rand_mat(20, 18, 402);
        let med = SliceMethod::int(SliceSpec::int8());
        let w = e.prepare_weights(&b, &med, 0);
        let fused = e.matmul_prepared(&a, &w, &med, 0);
        let oracle = e.matmul_prepared_reference(&a, &w, &med, 0);
        assert_eq!(fused.data, oracle.data);
    }

    #[test]
    fn circuit_path_close_to_ideal_for_tiny_rwire() {
        let mut cfg = DpeConfig { use_circuit: true, r_wire: 1e-6, array: (16, 16), ..DpeConfig::default() };
        cfg.device.cv = 0.0;
        cfg.noise_free = false;
        let e = DotProductEngine::new(cfg, 5);
        let a = rand_mat(4, 16, 77);
        let b = rand_mat(16, 8, 78);
        let med = SliceMethod::int(SliceSpec::int8());
        let re = e.matmul(&a, &b, &med, &med).relative_error(&a.matmul(&b));
        assert!(re < 0.02, "re={re}");
    }

    #[test]
    fn circuit_path_ir_drop_increases_error() {
        let mk = |r_wire: f64| {
            let mut cfg = DpeConfig { use_circuit: true, r_wire, array: (32, 32), ..DpeConfig::default() };
            cfg.device.cv = 0.0;
            DotProductEngine::new(cfg, 5)
        };
        let a = rand_mat(4, 32, 81).map(f64::abs);
        let b = rand_mat(32, 16, 82).map(f64::abs);
        let med = SliceMethod::int(SliceSpec::int8());
        let ideal = a.matmul(&b);
        let re_small = mk(0.1).matmul(&a, &b, &med, &med).relative_error(&ideal);
        let re_large = mk(10.0).matmul(&a, &b, &med, &med).relative_error(&ideal);
        assert!(re_large > re_small, "re_large={re_large} re_small={re_small}");
    }

    #[test]
    #[should_panic(expected = "dim mismatch")]
    fn shape_mismatch_panics() {
        let e = DotProductEngine::ideal((64, 64));
        let med = SliceMethod::int(SliceSpec::int8());
        let w = e.prepare_weights(&rand_mat(10, 10, 1), &med, 0);
        let _ = e.matmul_prepared(&rand_mat(2, 11, 2), &w, &med, 0);
    }

    #[test]
    fn parse_method_names() {
        assert_eq!(SliceMethod::parse("int8").unwrap().spec.total_bits(), 8);
        assert_eq!(SliceMethod::parse("FP16").unwrap().mode, DataMode::PreAlign);
        assert_eq!(SliceMethod::parse("ones6").unwrap().spec.num_slices(), 6);
        assert!(SliceMethod::parse("nope").is_err());
    }

    /// The fault-injection variants the equivalence tests sweep: each
    /// activates one non-ideality class, plus the all-on combination.
    fn nonideal_variants() -> Vec<(&'static str, NonIdealitySpec)> {
        use crate::device::drift::DriftSpec;
        use crate::device::faults::{AdcErrorSpec, AdcRounding, FaultSpec};
        let stuck = NonIdealitySpec {
            faults: FaultSpec { sa0: 0.03, sa1: 0.02, dead_row: 0.02, dead_col: 0.02 },
            ..NonIdealitySpec::none()
        };
        let drift = NonIdealitySpec {
            drift: DriftSpec { nu: 0.08, nu_std: 0.02, t0: 1.0 },
            t_read: 1e4,
            ..NonIdealitySpec::none()
        };
        let adc = NonIdealitySpec {
            adc: AdcErrorSpec { gain_std: 0.03, offset_std_lsb: 0.5, rounding: AdcRounding::Round },
            ..NonIdealitySpec::none()
        };
        let floor = NonIdealitySpec {
            adc: AdcErrorSpec { gain_std: 0.0, offset_std_lsb: 0.0, rounding: AdcRounding::Floor },
            ..NonIdealitySpec::none()
        };
        let all = NonIdealitySpec {
            faults: FaultSpec { sa0: 0.02, sa1: 0.02, dead_row: 0.01, dead_col: 0.01 },
            drift: DriftSpec { nu: 0.05, nu_std: 0.01, t0: 1.0 },
            t_read: 1e3,
            adc: AdcErrorSpec { gain_std: 0.02, offset_std_lsb: 0.3, rounding: AdcRounding::Floor },
            ..NonIdealitySpec::none()
        };
        vec![("stuck", stuck), ("drift", drift), ("adc", adc), ("floor", floor), ("all", all)]
    }

    #[test]
    fn fused_matches_oracle_under_every_fault_injection() {
        // Tentpole invariant extended: with stuck-at masks, retention at
        // read time, and per-column ADC error active — alone and combined
        // — the fused pipeline must still reproduce the per-slice-pair
        // oracle bit for bit, for INT and FP methods on ragged shapes.
        let shapes = [(5usize, 100usize, 37usize), (3, 65, 130), (12, 64, 64)];
        let methods =
            [SliceMethod::int(SliceSpec::int8()), SliceMethod::fp(SliceSpec::fp16())];
        let policies = [AdcPolicy::WorstCase, AdcPolicy::Calibrated, AdcPolicy::IntegerSnap];
        for (si, &(m, k, n)) in shapes.iter().enumerate() {
            let a = rand_mat(m, k, 600 + si as u64);
            let b = rand_mat(k, n, 700 + si as u64);
            for method in &methods {
                for &adc_policy in &policies {
                    for (tag, ni) in nonideal_variants() {
                        let cfg = DpeConfig {
                            array: (64, 64),
                            adc_policy,
                            nonideal: ni,
                            ..DpeConfig::default()
                        };
                        let e = DotProductEngine::new(cfg, 23);
                        let w = e.prepare_weights(&b, method, 1);
                        let fused = e.matmul_prepared(&a, &w, method, 0);
                        let oracle = e.matmul_prepared_reference(&a, &w, method, 0);
                        assert_eq!(
                            fused.data, oracle.data,
                            "{m}x{k}x{n} widths={:?} policy={adc_policy:?} nonideal={tag}",
                            method.spec.widths
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn zero_rate_nonideal_spec_is_bit_identical_to_none() {
        // An all-off NonIdealitySpec must leave the engine bit-identical
        // to the default one *even when its injection seed differs*: if
        // any gate were broken (fault RNG consulted, ADC chain sampled),
        // the differing seed would perturb the output and fail this test.
        let a = rand_mat(9, 80, 801);
        let b = rand_mat(80, 70, 802);
        let med = SliceMethod::int(SliceSpec::int8());
        let base = DotProductEngine::new(DpeConfig::default(), 5);
        let explicit = DotProductEngine::new(
            DpeConfig {
                nonideal: NonIdealitySpec { seed: 0xDEAD_BEEF, ..NonIdealitySpec::none() },
                ..DpeConfig::default()
            },
            5,
        );
        let wb = base.prepare_weights(&b, &med, 0);
        let we = explicit.prepare_weights(&b, &med, 0);
        assert_eq!(
            base.matmul_prepared(&a, &wb, &med, 0).data,
            explicit.matmul_prepared(&a, &we, &med, 0).data
        );
    }

    #[test]
    fn cached_template_and_inputs_bit_identical_across_injection_matrix() {
        // Tentpole invariant of the caching split: `weight_template` +
        // `program` must reproduce `prepare_weights` bit for bit, and the
        // `PreparedInputs` path must reproduce per-call slicing bit for
        // bit — across INT/FP methods, every ADC policy, every
        // fault-injection variant, and ragged shapes.
        let shapes = [(5usize, 100usize, 37usize), (3, 65, 130), (12, 64, 64)];
        let methods =
            [SliceMethod::int(SliceSpec::int8()), SliceMethod::fp(SliceSpec::fp16())];
        let policies = [AdcPolicy::WorstCase, AdcPolicy::Calibrated, AdcPolicy::IntegerSnap];
        let mut variants = nonideal_variants();
        variants.push(("none", NonIdealitySpec::none()));
        for (si, &(m, k, n)) in shapes.iter().enumerate() {
            let a = rand_mat(m, k, 900 + si as u64);
            let b = rand_mat(k, n, 950 + si as u64);
            for method in &methods {
                for &adc_policy in &policies {
                    for (vtag, ni) in &variants {
                        let cfg = DpeConfig {
                            array: (64, 64),
                            adc_policy,
                            nonideal: ni.clone(),
                            ..DpeConfig::default()
                        };
                        let e = DotProductEngine::new(cfg, 31);
                        let template = e.weight_template(&b, method);
                        assert_eq!(template.shape(), (k, n));
                        let ai = e.prepare_inputs(&a, method);
                        assert_eq!(ai.shape(), (m, k));
                        let direct_w = e.prepare_weights(&b, method, 2);
                        let direct = e.matmul_prepared(&a, &direct_w, method, 5);
                        let cached =
                            e.matmul_prepared_inputs(&ai, &template.program(&e, 2), 5);
                        assert_eq!(
                            cached.data, direct.data,
                            "{m}x{k}x{n} widths={:?} policy={adc_policy:?} nonideal={vtag}",
                            method.spec.widths
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn cached_paths_bit_identical_noise_free_and_serial() {
        // Noise-free engines and the serial (no nested parallelism) entry
        // points used by the Monte-Carlo driver must also match exactly.
        let a = rand_mat(7, 90, 981);
        let b = rand_mat(90, 70, 982);
        let med = SliceMethod::int(SliceSpec::int8());
        for noise_free in [true, false] {
            let cfg = DpeConfig { noise_free, ..DpeConfig::default() };
            let e = DotProductEngine::new(cfg, 17);
            let template = e.weight_template(&b, &med);
            let ai = e.prepare_inputs(&a, &med);
            let direct = e.matmul_prepared(&a, &e.prepare_weights(&b, &med, 3), &med, 4);
            let serial = e.matmul_prepared_inputs_with(
                &ai,
                &template.program_with(&e, 3, false),
                4,
                false,
            );
            assert_eq!(serial.data, direct.data, "noise_free={noise_free}");
        }
    }

    #[test]
    fn zero_scale_blocks_are_skipped_with_exact_zero_output() {
        // An all-zero n-block of the weights (and an all-zero k-block of
        // the input) quantizes to scale 0; those pairs must contribute
        // exactly zero columns without being materialized, and the fused
        // path must still match the oracle.
        let mut rng = Pcg64::seeded(877);
        let a = Matrix::from_fn(9, 130, |_, j| {
            if (64..128).contains(&j) { 0.0 } else { rng.uniform_range(-1.0, 1.0) }
        });
        let b = Matrix::from_fn(130, 100, |_, j| {
            if j < 64 { 0.0 } else { rng.uniform_range(-1.0, 1.0) }
        });
        let e = DotProductEngine::new(DpeConfig::default(), 7);
        let med = SliceMethod::int(SliceSpec::int8());
        let w = e.prepare_weights(&b, &med, 0);
        let out = e.matmul_prepared(&a, &w, &med, 0);
        let oracle = e.matmul_prepared_reference(&a, &w, &med, 0);
        assert_eq!(out.data, oracle.data);
        // Columns of the zero weight block are exactly zero.
        for i in 0..out.rows {
            for j in 0..64 {
                assert_eq!(out.at(i, j), 0.0, "({i},{j})");
            }
        }
        // Non-zero columns still track the ideal product.
        let ideal = a.matmul(&b);
        assert!(out.relative_error(&ideal) < 0.15);
    }

    #[test]
    fn read_noise_tag_decorrelates_reads() {
        let mut cfg = DpeConfig::default();
        cfg.device.read_cv = 0.05;
        let e = DotProductEngine::new(cfg, 3);
        let med = SliceMethod::int(SliceSpec::int8());
        let a = rand_mat(8, 64, 471);
        let b = rand_mat(64, 64, 472);
        let w = e.prepare_weights(&b, &med, 0);
        let r0 = e.matmul_prepared(&a, &w, &med, 0);
        let r0b = e.matmul_prepared(&a, &w, &med, 0);
        assert_eq!(r0.data, r0b.data, "same tag → identical read noise");
        let r1 = e.matmul_prepared(&a, &w, &med, 1);
        assert_ne!(r0.data, r1.data, "tag must decorrelate per-read noise");
        // Read fluctuation is a perturbation, not a blow-up.
        assert!(r1.relative_error(&a.matmul(&b)) < 0.2);
    }

    #[test]
    fn read_noise_fused_matches_reference_oracle() {
        // The per-(pair, sa, sw) read-noise streams must land on the same
        // elements in the fused stripes as in the oracle's contiguous
        // partials, for every ADC policy and on ragged shapes.
        let policies = [AdcPolicy::WorstCase, AdcPolicy::Calibrated, AdcPolicy::IntegerSnap];
        for &(m, k, n) in &[(5usize, 100usize, 37usize), (12, 64, 64)] {
            let a = rand_mat(m, k, 555);
            let b = rand_mat(k, n, 556);
            for &adc_policy in &policies {
                let mut cfg = DpeConfig { adc_policy, ..DpeConfig::default() };
                cfg.device.read_cv = 0.04;
                let e = DotProductEngine::new(cfg, 11);
                let med = SliceMethod::int(SliceSpec::int8());
                let w = e.prepare_weights(&b, &med, 1);
                let fused = e.matmul_prepared(&a, &w, &med, 9);
                let oracle = e.matmul_prepared_reference(&a, &w, &med, 9);
                assert_eq!(fused.data, oracle.data, "{m}x{k}x{n} policy={adc_policy:?}");
            }
        }
    }

    #[test]
    fn read_noise_circuit_path_matches_reference() {
        let mut cfg =
            DpeConfig { use_circuit: true, r_wire: 0.5, array: (16, 16), ..DpeConfig::default() };
        cfg.device.cv = 0.0;
        cfg.device.read_cv = 0.03;
        let e = DotProductEngine::new(cfg, 5);
        let a = rand_mat(4, 20, 403);
        let b = rand_mat(20, 18, 404);
        let med = SliceMethod::int(SliceSpec::int8());
        let w = e.prepare_weights(&b, &med, 0);
        let fused = e.matmul_prepared(&a, &w, &med, 2);
        let oracle = e.matmul_prepared_reference(&a, &w, &med, 2);
        assert_eq!(fused.data, oracle.data);
    }

    #[test]
    #[should_panic(expected = "sliced for array rows")]
    fn prepared_inputs_array_mismatch_panics() {
        let e32 = DotProductEngine::ideal((32, 32));
        let e64 = DotProductEngine::ideal((64, 64));
        let med = SliceMethod::int(SliceSpec::int8());
        let ai = e32.prepare_inputs(&rand_mat(4, 64, 1), &med);
        let w = e64.prepare_weights(&rand_mat(64, 8, 2), &med, 0);
        let _ = e64.matmul_prepared_inputs(&ai, &w, 0);
    }

    #[test]
    #[should_panic(expected = "weight template was blocked for")]
    fn template_array_mismatch_panics() {
        let e32 = DotProductEngine::ideal((32, 32));
        let e64 = DotProductEngine::ideal((64, 64));
        let med = SliceMethod::int(SliceSpec::int8());
        let template = e32.weight_template(&rand_mat(64, 8, 3), &med);
        let _ = template.program(&e64, 0);
    }

    #[test]
    fn mapped_streams_identity_bit_identical_and_slots_decorrelate() {
        // `prepare_weights_mapped` with the identity stream list must be
        // bit-identical to `prepare_weights`; moving the blocks to other
        // physical slots must resample programming noise (and, when
        // configured, fault masks and ADC chains).
        use crate::device::faults::{AdcErrorSpec, FaultSpec};
        let a = rand_mat(6, 130, 821);
        let b = rand_mat(130, 70, 822);
        let med = SliceMethod::int(SliceSpec::int8());
        let cfg = DpeConfig {
            nonideal: NonIdealitySpec {
                faults: FaultSpec::cells(0.02),
                adc: AdcErrorSpec { gain_std: 0.02, offset_std_lsb: 0.3, ..AdcErrorSpec::none() },
                ..NonIdealitySpec::none()
            },
            ..DpeConfig::default()
        };
        let e = DotProductEngine::new(cfg, 13);
        let w_legacy = e.prepare_weights(&b, &med, 1);
        let identity: Vec<u64> = (0..w_legacy.num_blocks() as u64).collect();
        let w_id = e.prepare_weights_mapped(&b, &med, 1, &identity);
        assert_eq!(
            e.matmul_prepared(&a, &w_legacy, &med, 0).data,
            e.matmul_prepared(&a, &w_id, &med, 0).data,
            "identity stream mapping must be bit-identical"
        );
        let shifted: Vec<u64> = identity.iter().map(|s| s + 1000).collect();
        let w_shift = e.prepare_weights_mapped(&b, &med, 1, &shifted);
        assert_ne!(
            e.matmul_prepared(&a, &w_id, &med, 0).data,
            e.matmul_prepared(&a, &w_shift, &med, 0).data,
            "different physical slots must draw different noise"
        );
    }

    #[test]
    fn prepared_input_row_slices_match_full_batch_rows() {
        // The executor invariant: matmul over a row slice of PreparedInputs
        // equals the corresponding rows of the full-batch matmul, bit for
        // bit, under the default worst-case ADC.
        let e = DotProductEngine::new(DpeConfig::default(), 6);
        let med = SliceMethod::int(SliceSpec::int8());
        let a = rand_mat(13, 100, 831);
        let b = rand_mat(100, 37, 832);
        let w = e.prepare_weights(&b, &med, 1);
        let ai = e.prepare_inputs(&a, &med);
        let full = e.matmul_prepared_inputs(&ai, &w, 0);
        for (r0, len) in [(0usize, 5usize), (5, 4), (9, 4), (0, 13)] {
            let part = e.matmul_prepared_inputs(&ai.rows(r0, len), &w, 0);
            assert_eq!((part.rows, part.cols), (len, 37));
            for i in 0..len {
                assert_eq!(part.row(i), full.row(r0 + i), "row {} of slice ({r0},{len})", i);
            }
        }
    }

    #[test]
    fn fault_injection_changes_results_and_degrades_accuracy() {
        use crate::device::faults::FaultSpec;
        let a = rand_mat(16, 128, 811);
        let b = rand_mat(128, 64, 812);
        let med = SliceMethod::int(SliceSpec::int8());
        let clean = DotProductEngine::new(DpeConfig::default(), 5);
        let faulty = DotProductEngine::new(
            DpeConfig {
                nonideal: NonIdealitySpec {
                    faults: FaultSpec::cells(0.1),
                    ..NonIdealitySpec::none()
                },
                ..DpeConfig::default()
            },
            5,
        );
        let ideal = a.matmul(&b);
        let re_clean = clean.matmul(&a, &b, &med, &med).relative_error(&ideal);
        let re_faulty = faulty.matmul(&a, &b, &med, &med).relative_error(&ideal);
        assert!(
            re_faulty > re_clean,
            "10% stuck cells must degrade accuracy: {re_faulty} vs {re_clean}"
        );
    }

    /// Engine with programming noise, stuck-at faults, and ADC error all
    /// active — the adversarial setting for repair bit-identity tests.
    fn faulty_engine(seed: u64, cell_rate: f64) -> DotProductEngine {
        use crate::device::faults::AdcErrorSpec;
        DotProductEngine::new(
            DpeConfig {
                nonideal: NonIdealitySpec {
                    faults: FaultSpec::cells(cell_rate),
                    adc: AdcErrorSpec {
                        gain_std: 0.02,
                        offset_std_lsb: 0.3,
                        ..AdcErrorSpec::none()
                    },
                    ..NonIdealitySpec::none()
                },
                ..DpeConfig::default()
            },
            seed,
        )
    }

    #[test]
    fn disabled_repair_spec_bit_identical_to_plain_program() {
        // Acceptance criterion: an all-off [repair] spec must be
        // hard-bit-identical to the existing program path, under active
        // noise + faults + ADC error, on both the identity and a mapped
        // stream list.
        let e = faulty_engine(19, 0.05);
        let med = SliceMethod::int(SliceSpec::int8());
        let a = rand_mat(5, 130, 841);
        let b = rand_mat(130, 70, 842);
        let t = e.weight_template(&b, &med);
        let plain = t.program(&e, 3);
        let (verified, report) = t.program_verified(&e, 3, &RepairSpec::none());
        assert!(report.blocks.is_empty(), "disabled spec must not report");
        assert_eq!(
            e.matmul_prepared(&a, &plain, &med, 0).data,
            e.matmul_prepared(&a, &verified, &med, 0).data,
            "disabled repair spec drifted from the plain program path"
        );
        let streams: Vec<u64> = (0..t.blocks.len() as u64).map(|s| 77 + 3 * s).collect();
        let plain_m = e.prepare_weights_mapped(&b, &med, 3, &streams);
        let (verified_m, _) = t.program_verified_mapped(&e, 3, &RepairSpec::none(), &streams);
        assert_eq!(
            e.matmul_prepared(&a, &plain_m, &med, 0).data,
            e.matmul_prepared(&a, &verified_m, &med, 0).data,
            "disabled repair spec drifted from the mapped program path"
        );
    }

    #[test]
    fn prop_repair_verify_pass_is_fixed_point_and_deterministic() {
        // Satellite properties: a verify pass on clean planes is a fixed
        // point (no plane reprograms, bits identical to the non-verified
        // path), and the whole report is deterministic per seed.
        crate::util::prop::prop_check("repair verify pass fixed point", 15, |g| {
            let k = g.usize_in(1..=100);
            let n = g.usize_in(1..=100);
            let mut cfg = DpeConfig::default();
            if g.bool() {
                cfg.device.read_cv = 0.02;
            }
            let e = DotProductEngine::new(cfg, 500 + g.case as u64);
            let med = SliceMethod::int(SliceSpec::int8());
            let b = Matrix::from_vec(k, n, g.vec_f64(k * n, -1.0..1.0));
            let a = Matrix::from_vec(4, k, g.vec_f64(4 * k, -1.0..1.0));
            // No faults and a tolerance above any noise excursion: every
            // plane passes its first read-back.
            let spec = RepairSpec { verify: true, tolerance: 1e9, ..RepairSpec::default() };
            let t = e.weight_template(&b, &med);
            let plain = t.program(&e, 1);
            let (v1, r1) = t.program_verified(&e, 1, &spec);
            let (v2, r2) = t.program_verified(&e, 1, &spec);
            if r1 != r2 {
                return Err("verified report not deterministic per seed".into());
            }
            if r1.total_retries() != 0 {
                return Err(format!("clean planes reprogrammed: {} retries", r1.total_retries()));
            }
            if !r1.unconverged_blocks().is_empty() {
                return Err("clean planes reported unconverged".into());
            }
            let want = e.matmul_prepared(&a, &plain, &med, 0).data;
            if e.matmul_prepared(&a, &v1, &med, 0).data != want
                || e.matmul_prepared(&a, &v2, &med, 0).data != want
            {
                return Err("clean verify pass is not a fixed point".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_repair_retry_count_deterministic_per_seed() {
        // With stuck cells the verify loop retries and condemns; both the
        // accounting and the programmed bits must be reproducible.
        crate::util::prop::prop_check("repair retry count deterministic", 10, |g| {
            let k = g.usize_in(32..=100);
            let n = g.usize_in(32..=100);
            let e = faulty_engine(900 + g.case as u64, 0.08);
            let med = SliceMethod::int(SliceSpec::int8());
            let b = Matrix::from_vec(k, n, g.vec_f64(k * n, -1.0..1.0));
            let a = Matrix::from_vec(3, k, g.vec_f64(3 * k, -1.0..1.0));
            let spec = RepairSpec { verify: true, max_retries: 2, ..RepairSpec::enabled() };
            let t = e.weight_template(&b, &med);
            let (v1, r1) = t.program_verified(&e, 2, &spec);
            let (v2, r2) = t.program_verified(&e, 2, &spec);
            if r1 != r2 {
                return Err("retry accounting differs across identical runs".into());
            }
            let o1 = e.matmul_prepared(&a, &v1, &med, 0);
            if o1.data != e.matmul_prepared(&a, &v2, &med, 0).data {
                return Err("verified programming not reproducible per seed".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_repair_zero_tolerance_noiseless_bit_identical() {
        // Satellite property: a zero-tolerance spec with no faults is
        // bit-identical to the non-verified path — exactly (with zero
        // retries) on a noise-free engine, and bit-for-bit on a cv = 0
        // engine too (programming is draw-free there, so even a paranoid
        // tolerance cannot change the programmed values).
        crate::util::prop::prop_check("zero tolerance + no faults bit-identical", 15, |g| {
            let k = g.usize_in(1..=80);
            let n = g.usize_in(1..=80);
            let med = SliceMethod::int(SliceSpec::int8());
            let b = Matrix::from_vec(k, n, g.vec_f64(k * n, -1.0..1.0));
            let a = Matrix::from_vec(3, k, g.vec_f64(3 * k, -1.0..1.0));
            let spec = RepairSpec {
                verify: true,
                tolerance: 0.0,
                max_retries: 2,
                ..RepairSpec::default()
            };
            let mut nf = DpeConfig { noise_free: true, ..DpeConfig::default() };
            nf.device.read_cv = 0.0;
            let e = DotProductEngine::new(nf, 40 + g.case as u64);
            let t = e.weight_template(&b, &med);
            let (v, r) = t.program_verified(&e, 1, &spec);
            if r.total_retries() != 0 || !r.unconverged_blocks().is_empty() {
                return Err("noise-free zero-tolerance pass retried".into());
            }
            let want = e.matmul_prepared(&a, &t.program(&e, 1), &med, 0).data;
            if e.matmul_prepared(&a, &v, &med, 0).data != want {
                return Err("noise-free zero-tolerance path not bit-identical".into());
            }
            let mut cv0 = DpeConfig::default();
            cv0.device.cv = 0.0;
            cv0.device.read_cv = 0.0;
            let e = DotProductEngine::new(cv0, 40 + g.case as u64);
            let t = e.weight_template(&b, &med);
            let (v, _) = t.program_verified(&e, 1, &spec);
            let want = e.matmul_prepared(&a, &t.program(&e, 1), &med, 0).data;
            if e.matmul_prepared(&a, &v, &med, 0).data != want {
                return Err("cv=0 zero-tolerance path not bit-identical".into());
            }
            Ok(())
        });
    }

    #[test]
    fn verified_program_flags_stuck_cells_as_unconverged() {
        // The detection signal: stuck cells are pinned identically on
        // every retry, so planes hosting a large-error one burn all
        // retries and report unconverged.
        let e = faulty_engine(23, 0.1);
        let med = SliceMethod::int(SliceSpec::int8());
        let b = rand_mat(128, 64, 851);
        let spec = RepairSpec { max_retries: 2, ..RepairSpec::enabled() };
        let t = e.weight_template(&b, &med);
        let (_, report) = t.program_verified(&e, 1, &spec);
        assert_eq!(report.blocks.len(), 2, "2 k-blocks × 1 n-block");
        assert!(report.total_retries() > 0, "10% stuck cells must trigger retries");
        assert!(
            !report.unconverged_blocks().is_empty(),
            "stuck cells must never converge: {report:?}"
        );
        let hist = report.retry_histogram(4);
        assert_eq!(hist.iter().sum::<usize>(), report.blocks.len());
        // A clean engine under the same spec converges without retries.
        let clean = DotProductEngine::new(DpeConfig::default(), 23);
        let t = clean.weight_template(&b, &med);
        let (_, report) = t.program_verified(&clean, 1, &spec);
        assert_eq!(report.total_retries(), 0, "clean arrays must pass first try: {report:?}");
        assert!(report.unconverged_blocks().is_empty());
    }

    #[test]
    fn reprogram_moved_blocks_bit_identical_to_full_remap() {
        // Remap-to-spare correctness (bugfix-sweep satellite): moving a
        // block to a new physical stream via the partial reprogram must
        // equal a full prepare at the updated stream list — i.e. the
        // moved block draws programming noise, fault masks, AND its ADC
        // chain from the *new* slot's streams, untouched blocks keep
        // their bits.
        let e = faulty_engine(13, 0.04);
        let med = SliceMethod::int(SliceSpec::int8());
        let a = rand_mat(6, 130, 861);
        let b = rand_mat(130, 70, 862);
        let streams: Vec<u64> = (0..4u64).collect(); // 2×2 block grid
        let w_orig = e.prepare_weights_mapped(&b, &med, 1, &streams);
        let mut w_moved = w_orig.clone();
        e.reprogram_prepared_blocks(&mut w_moved, &b, &[(1, 500), (2, 600)], 1);
        let mut full_streams = streams.clone();
        full_streams[1] = 500;
        full_streams[2] = 600;
        let w_full = e.prepare_weights_mapped(&b, &med, 1, &full_streams);
        assert_eq!(
            e.matmul_prepared(&a, &w_moved, &med, 0).data,
            e.matmul_prepared(&a, &w_full, &med, 0).data,
            "partial reprogram must equal full remap at the new streams"
        );
        assert_ne!(
            e.matmul_prepared(&a, &w_moved, &med, 0).data,
            e.matmul_prepared(&a, &w_orig, &med, 0).data,
            "moving blocks must resample their noise/faults/ADC"
        );
    }

    #[test]
    fn two_placements_of_same_layer_differ_in_fault_masks() {
        // Regression (bugfix-sweep satellite): with programming noise and
        // ADC error silenced (cv = 0, ideal ADC), the ONLY stream-keyed
        // draws left are the fault masks — two placements of the same
        // layer must still produce different programmed bits, proving
        // masks are drawn from the physical slot's stream and not from
        // the layer-local block index.
        let mut cfg = DpeConfig {
            nonideal: NonIdealitySpec {
                faults: FaultSpec::cells(0.05),
                ..NonIdealitySpec::none()
            },
            ..DpeConfig::default()
        };
        cfg.device.cv = 0.0;
        cfg.device.read_cv = 0.0;
        let e = DotProductEngine::new(cfg, 29);
        let med = SliceMethod::int(SliceSpec::int8());
        let a = rand_mat(6, 128, 871);
        let b = rand_mat(128, 64, 872);
        let placement_a: Vec<u64> = vec![0, 4];
        let placement_b: Vec<u64> = vec![64, 68];
        let wa = e.prepare_weights_mapped(&b, &med, 1, &placement_a);
        let wb2 = e.prepare_weights_mapped(&b, &med, 1, &placement_b);
        assert_ne!(
            e.matmul_prepared(&a, &wa, &med, 0).data,
            e.matmul_prepared(&a, &wb2, &med, 0).data,
            "fault masks must be keyed by physical slot, not layer-local index"
        );
    }
}

//! The variable-precision bit-slicing dot-product engine (DPE) — the
//! paper's core contribution (§3.3).
//!
//! - [`quant`] — DAC/ADC converter models;
//! - [`slicing`] — dynamic INT bit-slicing + block quantization /
//!   FP shared-exponent pre-alignment;
//! - [`blocks`] — block matrix mapping onto fixed-size arrays;
//! - [`engine`] — the DPE itself ([`DotProductEngine`]), with weight
//!   preparation for reuse across calls and the stacked slice-plane GEMM
//!   pipeline over byte-packed digit planes on the matmul hot path (see
//!   `engine` §Perf);
//! - [`montecarlo`] — the Monte-Carlo nonideality analysis driver (Fig 12)
//!   plus the fault-injection accuracy/yield sweep
//!   ([`montecarlo::sweep_faults`], backing the `fig_faults` experiment;
//!   knobs live in [`crate::device::faults`]).

pub mod blocks;
pub mod engine;
pub mod montecarlo;
pub mod quant;
pub mod slicing;

pub use engine::{
    BlockProgramStats, DeltaReport, DotProductEngine, DpeConfig, PreparedInputs,
    PreparedWeights, ProgramReport, RepairSpec, SliceMethod, WeightTemplate,
};
pub use slicing::{quantize_slice_block, DataMode, SliceSpec, SliceTables, SlicedBlock};

//! DAC / ADC converter models (paper Fig 4(b)).
//!
//! Both converters are modeled as uniform mid-tread quantizers over a known
//! full-scale range. In the digit-domain DPE the DAC reproduces input slice
//! digits exactly whenever the slice width fits its resolution (`rdac` of
//! 256 covers any ≤8-bit slice), while the ADC quantizes each partial
//! dot-product to `radc` levels over the block's worst-case output range —
//! the dominant peripheral-circuit error source.

/// Uniform quantizer: `levels` output codes over `[0, full_scale]`.
#[derive(Debug, Clone, Copy)]
pub struct UniformQuantizer {
    pub levels: usize,
    pub full_scale: f64,
}

impl UniformQuantizer {
    pub fn new(levels: usize, full_scale: f64) -> Self {
        assert!(levels >= 2, "quantizer needs ≥2 levels");
        assert!(full_scale > 0.0, "full scale must be positive");
        UniformQuantizer { levels, full_scale }
    }

    /// Quantization step.
    #[inline]
    pub fn step(&self) -> f64 {
        self.full_scale / (self.levels as f64 - 1.0)
    }

    /// Quantize a value: clamp to range, round to nearest code, return the
    /// reconstructed analog value.
    #[inline]
    pub fn quantize(&self, x: f64) -> f64 {
        let step = self.step();
        let code = (x / step).round().clamp(0.0, self.levels as f64 - 1.0);
        code * step
    }

    /// Quantize in place over a slice.
    pub fn quantize_slice(&self, xs: &mut [f64]) {
        let step = self.step();
        let max_code = self.levels as f64 - 1.0;
        let inv = 1.0 / step;
        for x in xs.iter_mut() {
            *x = (*x * inv).round().clamp(0.0, max_code) * step;
        }
    }
}

/// DAC model: `rdac` voltage levels (Table 2: 256). A slice digit `d` of
/// width `w` is representable exactly iff `2^w ≤ rdac`.
#[derive(Debug, Clone, Copy)]
pub struct Dac {
    pub rdac: usize,
    /// Read voltage corresponding to full scale (V); affects only the
    /// physical-units view, the digit-domain engine works normalized.
    pub v_read: f64,
}

impl Dac {
    pub fn new(rdac: usize) -> Self {
        Dac { rdac, v_read: 0.2 }
    }

    /// Can a `width`-bit slice digit be converted exactly?
    pub fn supports_width(&self, width: usize) -> bool {
        (1usize << width) <= self.rdac
    }

    /// Convert digit to normalized drive level, quantized to rdac levels
    /// over `[0, max_digit]`.
    pub fn convert(&self, digit: f64, max_digit: u32) -> f64 {
        if max_digit == 0 {
            return 0.0;
        }
        UniformQuantizer::new(self.rdac, max_digit as f64).quantize(digit)
    }
}

/// ADC model: `radc` codes (Table 2: 1024) over the per-readout worst-case
/// range.
#[derive(Debug, Clone, Copy)]
pub struct Adc {
    pub radc: usize,
}

impl Adc {
    pub fn new(radc: usize) -> Self {
        Adc { radc }
    }

    /// Quantizer for one partial readout with the given full scale.
    pub fn for_full_scale(&self, full_scale: f64) -> UniformQuantizer {
        UniformQuantizer::new(self.radc, full_scale.max(f64::MIN_POSITIVE))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn prop_quantize_roundtrip_error_bound() {
        // dequant(quant(x)) round-trip: for any in-range input the
        // reconstruction error is at most half an LSB (mid-tread rounding),
        // across random resolutions and full-scale ranges.
        prop_check("quantize roundtrip error ≤ step/2", 200, |g| {
            let levels = g.usize_in(2..=4096);
            let full_scale = g.f64_in(1e-6..1e6);
            let q = UniformQuantizer::new(levels, full_scale);
            let step = q.step();
            for _ in 0..16 {
                let x = g.f64_in(0.0..full_scale);
                let y = q.quantize(x);
                if (y - x).abs() > step / 2.0 + full_scale * 1e-12 {
                    return Err(format!(
                        "levels={levels} fs={full_scale:.3e}: |q({x}) - {x}| = {} > step/2 = {}",
                        (y - x).abs(),
                        step / 2.0
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_quantize_idempotent_and_clamped() {
        prop_check("quantize idempotent + clamped", 200, |g| {
            let levels = g.usize_in(2..=1024);
            let full_scale = g.f64_in(1e-3..1e3);
            let q = UniformQuantizer::new(levels, full_scale);
            // Idempotence on arbitrary (also out-of-range) inputs.
            let x = g.f64_in(-2.0 * full_scale..3.0 * full_scale);
            let once = q.quantize(x);
            if q.quantize(once) != once {
                return Err(format!("q(q({x})) != q({x})"));
            }
            // Output always lands on a code in [0, full_scale].
            if !(0.0..=full_scale * (1.0 + 1e-12)).contains(&once) {
                return Err(format!("q({x}) = {once} outside [0, {full_scale}]"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_dac_conversion_error_bounded() {
        // DAC conversion error is at most half its LSB for any in-range
        // digit (exactness additionally needs `max_digit | rdac−1`, e.g.
        // the Table-2 rdac=256 with 4-bit slices — covered by the unit
        // tests below).
        prop_check("DAC conversion error ≤ step/2", 100, |g| {
            let rdac_bits = g.usize_in(2..=12);
            let dac = Dac::new(1 << rdac_bits);
            let width = g.usize_in(1..=rdac_bits.min(8));
            let max_digit = (1u32 << width) - 1;
            let d = g.usize_in(0..=max_digit as usize) as f64;
            let got = dac.convert(d, max_digit);
            let step = max_digit as f64 / ((1usize << rdac_bits) as f64 - 1.0);
            if (got - d).abs() > step / 2.0 + 1e-12 {
                return Err(format!(
                    "rdac=2^{rdac_bits} width={width}: |convert({d}) - {d}| = {} > {}",
                    (got - d).abs(),
                    step / 2.0
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn quantize_is_idempotent() {
        let q = UniformQuantizer::new(1024, 64.0);
        for &x in &[0.0, 0.03, 1.0, 17.77, 63.9, 64.0] {
            let once = q.quantize(x);
            assert_eq!(q.quantize(once), once);
        }
    }

    #[test]
    fn quantize_error_bounded_by_half_step() {
        let q = UniformQuantizer::new(1024, 64.0);
        let step = q.step();
        let mut x = 0.0;
        while x < 64.0 {
            assert!((q.quantize(x) - x).abs() <= step / 2.0 + 1e-12);
            x += 0.0173;
        }
    }

    #[test]
    fn quantize_clamps() {
        let q = UniformQuantizer::new(16, 15.0);
        assert_eq!(q.quantize(-3.0), 0.0);
        assert_eq!(q.quantize(99.0), 15.0);
    }

    #[test]
    fn integers_exact_when_levels_cover() {
        // step=1 when levels-1 == full_scale: integers survive exactly.
        let q = UniformQuantizer::new(65, 64.0);
        for d in 0..=64 {
            assert_eq!(q.quantize(d as f64), d as f64);
        }
    }

    #[test]
    fn dac_supports_paper_slices() {
        let dac = Dac::new(256);
        for w in 1..=8 {
            assert!(dac.supports_width(w));
        }
        assert!(!dac.supports_width(9));
    }

    #[test]
    fn dac_exact_for_small_digits() {
        let dac = Dac::new(256);
        for d in 0..=15u32 {
            assert_eq!(dac.convert(d as f64, 15), d as f64);
        }
    }

    #[test]
    fn adc_step_scales_with_full_scale() {
        let adc = Adc::new(1024);
        let q1 = adc.for_full_scale(64.0);
        let q2 = adc.for_full_scale(640.0);
        assert!((q2.step() / q1.step() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn quantize_slice_matches_scalar() {
        let q = UniformQuantizer::new(1024, 10.0);
        let xs: Vec<f64> = (0..100).map(|i| i as f64 * 0.1).collect();
        let mut ys = xs.clone();
        q.quantize_slice(&mut ys);
        for (x, y) in xs.iter().zip(&ys) {
            assert_eq!(q.quantize(*x), *y);
        }
    }
}

//! Hardware and digital layers (paper §3.4, Fig 8).
//!
//! `LinearMem` / `Conv2dMem` run their forward dot products on the bound
//! DPE when one is attached, or in full precision otherwise; backward is
//! always full-precision straight-through. All hardware state (engine
//! binding, programmed weights, programming generation, physical-slot
//! streams, and the opt-in input cache) lives in one shared
//! [`MemCore`] embedded in each layer. Pooling, ReLU, BatchNorm and
//! Flatten are digital layers.
//!
//! Every layer also implements the immutable eval entry points
//! (`forward_eval`, `forward_batched`) used by the mapped inference
//! executor ([`crate::arch::MappedModel`]); they are bit-identical to
//! `forward(x, false)`.

use super::{HwSpec, Layer, MemCore, Param, TrainError};
use crate::dpe::DeltaReport;
use crate::tensor::{col2im_accumulate, im2col, matmul_train, Conv2dDims, Matrix, Tensor};
use crate::util::parallel::par_map;
use crate::util::rng::Pcg64;

/// Fully-connected layer: `y = x·W + b`, `W (in × out)`.
pub struct LinearMem {
    pub in_features: usize,
    pub out_features: usize,
    pub w: Param,
    pub b: Param,
    /// Shared hardware state (engine binding, programmed weights, slot
    /// streams, input cache).
    pub core: MemCore,
    cache_x: Option<Matrix>,
}

impl LinearMem {
    pub fn new(inf: usize, outf: usize, hw: Option<HwSpec>, rng: &mut Pcg64) -> Self {
        // He-uniform init.
        let bound = (6.0 / inf as f64).sqrt();
        let w = (0..inf * outf).map(|_| rng.uniform_range(-bound, bound)).collect();
        let mut l = LinearMem {
            in_features: inf,
            out_features: outf,
            w: Param::new(w),
            b: Param::new(vec![0.0; outf]),
            core: MemCore::new(hw),
            cache_x: None,
        };
        l.update_weight();
        l
    }

    /// Opt into caching the quantized + sliced input across eval-mode
    /// forward calls (see [`MemCore::set_input_caching`]).
    pub fn set_input_caching(&mut self, on: bool) {
        self.core.set_input_caching(on);
    }

    fn weight_matrix(&self) -> Matrix {
        Matrix::from_vec(self.in_features, self.out_features, self.w.value.clone())
    }

    /// The linear map (no bias): hardware when bound, digital otherwise.
    fn eval_y(&self, xm: &Matrix) -> Matrix {
        match self.core.matmul_eval(xm) {
            Some(y) => y,
            None => xm.matmul(&self.weight_matrix()),
        }
    }

    fn add_bias(&self, y: &mut Matrix) {
        for i in 0..y.rows {
            for (v, b) in y.row_mut(i).iter_mut().zip(&self.b.value) {
                *v += b;
            }
        }
    }

    fn check_shape(&self, x: &Tensor) {
        assert_eq!(x.shape.len(), 2, "LinearMem expects (B, in)");
        assert_eq!(x.shape[1], self.in_features);
    }
}

impl Layer for LinearMem {
    fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        self.check_shape(x);
        let xm = x.to_matrix();
        // The input cache only pays off in eval loops over a repeated
        // batch; training batches differ every step, so skip the cache
        // there (same gating as Conv2dMem).
        let mut y = if !train && self.core.input_caching_enabled() && self.core.is_prepared() {
            if !self.core.input_cache_hit(&xm.data) {
                self.core.cache_inputs(xm.data.clone(), &xm);
            }
            self.core.matmul_from_cache().expect("cache filled above")
        } else {
            self.eval_y(&xm)
        };
        self.add_bias(&mut y);
        if train {
            self.cache_x = Some(xm);
        }
        Tensor::from_matrix(&y)
    }

    fn forward_eval(&self, x: &Tensor) -> Tensor {
        self.check_shape(x);
        let xm = x.to_matrix();
        let mut y = self.eval_y(&xm);
        self.add_bias(&mut y);
        Tensor::from_matrix(&y)
    }

    fn forward_batched(&self, x: &Tensor, micro_batch: usize) -> Tensor {
        self.check_shape(x);
        let xm = x.to_matrix();
        let mut y = match self.core.matmul_batched(&xm, micro_batch, 1) {
            Some(y) => y,
            None => xm.matmul(&self.weight_matrix()),
        };
        self.add_bias(&mut y);
        Tensor::from_matrix(&y)
    }

    fn backward(&mut self, grad_out: &Tensor) -> Tensor {
        self.try_backward(grad_out).unwrap_or_else(|e| panic!("{e}"))
    }

    fn try_backward(&mut self, grad_out: &Tensor) -> Result<Tensor, TrainError> {
        let g = grad_out.to_matrix();
        let x = self
            .cache_x
            .take()
            .ok_or(TrainError::BackwardBeforeForward { layer: "LinearMem" })?;
        // Full-precision gradients (straight-through), both GEMMs routed
        // through the packed register-tiled training kernel — bit-identical
        // to `Matrix::matmul` on the same operands.
        let grad_w = matmul_train(&x.transpose(), &g);
        for (gw, &v) in self.w.grad.iter_mut().zip(&grad_w.data) {
            *gw += v;
        }
        for j in 0..self.out_features {
            let mut acc = 0.0;
            for i in 0..g.rows {
                acc += g.at(i, j);
            }
            self.b.grad[j] += acc;
        }
        let grad_x = matmul_train(&g, &self.weight_matrix().transpose());
        Ok(Tensor::from_matrix(&grad_x))
    }

    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        f(&mut self.w);
        f(&mut self.b);
    }

    fn for_each_param(&self, f: &mut dyn FnMut(&Param)) {
        f(&self.w);
        f(&self.b);
    }

    fn update_weight(&mut self) {
        self.core.program(&self.weight_matrix());
    }

    fn update_weight_delta(&mut self) -> DeltaReport {
        self.core.program_delta(&self.weight_matrix())
    }

    fn reprogram(&mut self) {
        self.core.reprogram(&self.weight_matrix());
    }

    fn visit_cores(&mut self, f: &mut dyn FnMut(&mut MemCore)) {
        f(&mut self.core);
    }

    fn cores(&self) -> Vec<&MemCore> {
        vec![&self.core]
    }

    fn name(&self) -> &'static str {
        "LinearMem"
    }

    fn out_shape(&self, in_shape: &[usize]) -> Vec<usize> {
        vec![in_shape[0], self.out_features]
    }
}

/// 2-D convolution via im2col (paper Fig 8(c)). Weights `(out_c, C·kh·kw)`.
pub struct Conv2dMem {
    pub dims_chw: (usize, usize, usize), // expected input C,H,W
    pub out_c: usize,
    pub kernel: usize,
    pub stride: usize,
    pub pad: usize,
    pub w: Param,
    pub b: Param,
    /// Shared hardware state; the prepared copy holds the transposed
    /// weights `(patch, out_c)`.
    pub core: MemCore,
    /// Per-sample **transposed** im2col columns `(OH·OW, patch)` — kept in
    /// stacked-row order so forward stacking and the weight-gradient GEMM
    /// both use them without re-transposing.
    cache: Option<(Vec<Matrix>, Conv2dDims)>,
}

impl Conv2dMem {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        in_c: usize,
        in_h: usize,
        in_w: usize,
        out_c: usize,
        kernel: usize,
        stride: usize,
        pad: usize,
        hw: Option<HwSpec>,
        rng: &mut Pcg64,
    ) -> Self {
        let patch = in_c * kernel * kernel;
        let bound = (6.0 / patch as f64).sqrt();
        let w = (0..out_c * patch).map(|_| rng.uniform_range(-bound, bound)).collect();
        let mut l = Conv2dMem {
            dims_chw: (in_c, in_h, in_w),
            out_c,
            kernel,
            stride,
            pad,
            w: Param::new(w),
            b: Param::new(vec![0.0; out_c]),
            core: MemCore::new(hw),
            cache: None,
        };
        l.update_weight();
        l
    }

    /// Opt into caching the im2col + quantize/slice of the input across
    /// eval-mode forward calls — a hit skips im2col, stacking, and
    /// quantize/slice entirely (see [`MemCore::set_input_caching`]).
    pub fn set_input_caching(&mut self, on: bool) {
        self.core.set_input_caching(on);
    }

    fn conv_dims(&self) -> Conv2dDims {
        let (c, h, w) = self.dims_chw;
        Conv2dDims { in_c: c, in_h: h, in_w: w, kh: self.kernel, kw: self.kernel, stride: self.stride, pad: self.pad }
    }

    /// Per-sample transposed im2col columns plus their stacked
    /// `(B·OH·OW, patch)` batch matrix.
    fn im2col_stacked(&self, x: &Tensor) -> (Vec<Matrix>, Matrix) {
        let (c, h, w) = self.dims_chw;
        let bsz = x.shape[0];
        let d = self.conv_dims();
        let (oh, ow) = (d.out_h(), d.out_w());
        let sample_len = c * h * w;
        // Transposed im2col per sample (parallel): `(OH·OW, patch)` is the
        // stacked-row layout, so building the batch matrix below is one
        // contiguous copy per sample instead of an element-wise transpose.
        let cols_t: Vec<Matrix> = par_map(bsz, |i| {
            im2col(&x.data[i * sample_len..(i + 1) * sample_len], d).transpose()
        });
        let patch = self.patch_len();
        let rows = bsz * oh * ow;
        let sample_rows = oh * ow * patch;
        let mut stacked = Matrix::zeros(rows, patch);
        for (i, colt) in cols_t.iter().enumerate() {
            stacked.data[i * sample_rows..(i + 1) * sample_rows].copy_from_slice(&colt.data);
        }
        (cols_t, stacked)
    }

    fn patch_len(&self) -> usize {
        let (c, _, _) = self.dims_chw;
        c * self.kernel * self.kernel
    }

    /// Weight as `(patch, out_c)` — the layout mapped onto the arrays.
    fn weight_t(&self) -> Matrix {
        Matrix::from_vec(self.out_c, self.patch_len(), self.w.value.clone()).transpose()
    }

    fn check_shape(&self, x: &Tensor) {
        let (c, h, w) = self.dims_chw;
        assert_eq!(x.shape, vec![x.shape[0], c, h, w], "Conv2dMem input shape");
    }

    /// `(B·OH·OW, out_c)` → `(B, out_c, OH, OW)` + bias.
    fn reshape_bias(&self, y: &Matrix, bsz: usize, oh: usize, ow: usize) -> Tensor {
        let mut out = Tensor::zeros(&[bsz, self.out_c, oh, ow]);
        for i in 0..bsz {
            for q in 0..oh * ow {
                for oc in 0..self.out_c {
                    out.data[((i * self.out_c + oc) * oh * ow) + q] =
                        y.at(i * oh * ow + q, oc) + self.b.value[oc];
                }
            }
        }
        out
    }
}

impl Layer for Conv2dMem {
    fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        self.check_shape(x);
        let bsz = x.shape[0];
        let d = self.conv_dims();
        let (oh, ow) = (d.out_h(), d.out_w());
        // Cached-input eval path: a repeated input skips im2col, stacking,
        // and quantize/slice entirely (eval only — training needs the
        // im2col columns for backward anyway).
        let use_cached =
            !train && self.core.input_caching_enabled() && self.core.is_prepared();
        let mut train_cols: Option<Vec<Matrix>> = None;
        let y = if use_cached {
            if !self.core.input_cache_hit(&x.data) {
                let (_, stacked) = self.im2col_stacked(x);
                self.core.cache_inputs(x.data.clone(), &stacked);
            }
            self.core.matmul_from_cache().expect("cache filled above")
        } else {
            // Stack columns: (B·OH·OW, patch) then one DPE matmul routed
            // through the stacked slice-plane pipeline.
            let (cols_t, stacked) = self.im2col_stacked(x);
            let y = match self.core.matmul_eval(&stacked) {
                Some(y) => y,
                None => stacked.matmul(&self.weight_t()),
            };
            if train {
                train_cols = Some(cols_t);
            }
            y
        };
        let out = self.reshape_bias(&y, bsz, oh, ow);
        if train {
            self.cache = Some((train_cols.expect("train path computes im2col"), d));
        }
        out
    }

    fn forward_eval(&self, x: &Tensor) -> Tensor {
        // A single full-batch chunk — identical to the uncached eval
        // branch of `forward`.
        self.forward_batched(x, usize::MAX)
    }

    fn forward_batched(&self, x: &Tensor, micro_batch: usize) -> Tensor {
        self.check_shape(x);
        let bsz = x.shape[0];
        let d = self.conv_dims();
        let (oh, ow) = (d.out_h(), d.out_w());
        let (_, stacked) = self.im2col_stacked(x);
        let y = match self.core.matmul_batched(&stacked, micro_batch, oh * ow) {
            Some(y) => y,
            None => stacked.matmul(&self.weight_t()),
        };
        self.reshape_bias(&y, bsz, oh, ow)
    }

    fn backward(&mut self, grad_out: &Tensor) -> Tensor {
        self.try_backward(grad_out).unwrap_or_else(|e| panic!("{e}"))
    }

    fn try_backward(&mut self, grad_out: &Tensor) -> Result<Tensor, TrainError> {
        let (cols_t, d) = self
            .cache
            .take()
            .ok_or(TrainError::BackwardBeforeForward { layer: "Conv2dMem" })?;
        let bsz = grad_out.shape[0];
        let (oh, ow) = (d.out_h(), d.out_w());
        let ohow = oh * ow;
        let patch = self.patch_len();
        let wt = Matrix::from_vec(self.out_c, patch, self.w.value.clone());
        // Batch-stacked gradient GEMMs: instead of B small per-sample
        // matmuls, assemble the gradients once and run two stacked GEMMs
        // through the packed training kernel.
        //
        // grad_y as (out_c, B·OH·OW): row `oc` is the per-sample grad
        // planes for that output channel concatenated in sample order —
        // one contiguous copy per (oc, sample) pair.
        let mut gyt = Matrix::zeros(self.out_c, bsz * ohow);
        for oc in 0..self.out_c {
            let dst_row = gyt.row_mut(oc);
            for i in 0..bsz {
                let src = (i * self.out_c + oc) * ohow;
                dst_row[i * ohow..(i + 1) * ohow]
                    .copy_from_slice(&grad_out.data[src..src + ohow]);
            }
        }
        // Re-stack the cached transposed im2col columns into the same
        // `(B·OH·OW, patch)` batch matrix the forward pass used — the
        // input slicing/im2col work is done once per batch and reused
        // here for the weight-gradient GEMM.
        let sample_rows = ohow * patch;
        let mut stacked = Matrix::zeros(bsz * ohow, patch);
        for (i, colt) in cols_t.iter().enumerate() {
            stacked.data[i * sample_rows..(i + 1) * sample_rows].copy_from_slice(&colt.data);
        }
        // grad_w (out_c, patch) = grad_yᵀ-stacked · cols-stacked.
        let grad_w = matmul_train(&gyt, &stacked);
        for (acc, &v) in self.w.grad.iter_mut().zip(&grad_w.data) {
            *acc += v;
        }
        for oc in 0..self.out_c {
            self.b.grad[oc] += gyt.row(oc).iter().sum::<f64>();
        }
        // Input grads: one stacked GEMM (B·OH·OW, out_c)·(out_c, patch)
        // yields every sample's transposed grad-columns; col2im per sample
        // stays parallel.
        let mut gys = Matrix::zeros(bsz * ohow, self.out_c);
        for i in 0..bsz {
            for oc in 0..self.out_c {
                let src = (i * self.out_c + oc) * ohow;
                for q in 0..ohow {
                    gys.data[(i * ohow + q) * self.out_c + oc] = grad_out.data[src + q];
                }
            }
        }
        let gcols_t = matmul_train(&gys, &wt);
        let sample_len = d.in_c * d.in_h * d.in_w;
        let gx_all: Vec<Vec<f64>> = par_map(bsz, |i| {
            let gc = Matrix::from_vec(
                ohow,
                patch,
                gcols_t.data[i * sample_rows..(i + 1) * sample_rows].to_vec(),
            )
            .transpose();
            let mut gx = vec![0.0; sample_len];
            col2im_accumulate(&gc, d, &mut gx);
            gx
        });
        let mut grad_x = Tensor::zeros(&[bsz, d.in_c, d.in_h, d.in_w]);
        for (i, gx) in gx_all.into_iter().enumerate() {
            grad_x.data[i * sample_len..(i + 1) * sample_len].copy_from_slice(&gx);
        }
        Ok(grad_x)
    }

    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        f(&mut self.w);
        f(&mut self.b);
    }

    fn for_each_param(&self, f: &mut dyn FnMut(&Param)) {
        f(&self.w);
        f(&self.b);
    }

    fn update_weight(&mut self) {
        self.core.program(&self.weight_t());
    }

    fn update_weight_delta(&mut self) -> DeltaReport {
        self.core.program_delta(&self.weight_t())
    }

    fn reprogram(&mut self) {
        self.core.reprogram(&self.weight_t());
    }

    fn visit_cores(&mut self, f: &mut dyn FnMut(&mut MemCore)) {
        f(&mut self.core);
    }

    fn cores(&self) -> Vec<&MemCore> {
        vec![&self.core]
    }

    fn name(&self) -> &'static str {
        "Conv2dMem"
    }

    fn out_shape(&self, in_shape: &[usize]) -> Vec<usize> {
        let d = self.conv_dims();
        vec![in_shape[0], self.out_c, d.out_h(), d.out_w()]
    }
}

/// ReLU.
pub struct Relu {
    mask: Option<Vec<bool>>,
}

impl Relu {
    pub fn new() -> Self {
        Relu { mask: None }
    }
}

impl Default for Relu {
    fn default() -> Self {
        Self::new()
    }
}

impl Layer for Relu {
    fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        if train {
            self.mask = Some(x.data.iter().map(|&v| v > 0.0).collect());
        }
        self.forward_eval(x)
    }

    fn forward_eval(&self, x: &Tensor) -> Tensor {
        let mut out = x.clone();
        for v in out.data.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        out
    }

    fn backward(&mut self, grad_out: &Tensor) -> Tensor {
        let mask = self.mask.take().expect("forward before backward");
        let mut g = grad_out.clone();
        for (v, keep) in g.data.iter_mut().zip(mask) {
            if !keep {
                *v = 0.0;
            }
        }
        g
    }

    fn name(&self) -> &'static str {
        "Relu"
    }

    fn out_shape(&self, in_shape: &[usize]) -> Vec<usize> {
        in_shape.to_vec()
    }
}

/// 2×2 average pooling (LeNet subsampling).
pub struct AvgPool2 {
    cache_shape: Option<Vec<usize>>,
}

impl AvgPool2 {
    pub fn new() -> Self {
        AvgPool2 { cache_shape: None }
    }
}

impl Default for AvgPool2 {
    fn default() -> Self {
        Self::new()
    }
}

impl Layer for AvgPool2 {
    fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        if train {
            self.cache_shape = Some(x.shape.clone());
        }
        self.forward_eval(x)
    }

    fn forward_eval(&self, x: &Tensor) -> Tensor {
        let (b, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
        assert!(h % 2 == 0 && w % 2 == 0, "AvgPool2 needs even dims");
        let (oh, ow) = (h / 2, w / 2);
        let mut out = Tensor::zeros(&[b, c, oh, ow]);
        for bc in 0..b * c {
            let src = &x.data[bc * h * w..(bc + 1) * h * w];
            let dst = &mut out.data[bc * oh * ow..(bc + 1) * oh * ow];
            for i in 0..oh {
                for j in 0..ow {
                    dst[i * ow + j] = 0.25
                        * (src[2 * i * w + 2 * j]
                            + src[2 * i * w + 2 * j + 1]
                            + src[(2 * i + 1) * w + 2 * j]
                            + src[(2 * i + 1) * w + 2 * j + 1]);
                }
            }
        }
        out
    }

    fn backward(&mut self, grad_out: &Tensor) -> Tensor {
        let shape = self.cache_shape.take().expect("forward before backward");
        let (b, c, h, w) = (shape[0], shape[1], shape[2], shape[3]);
        let (oh, ow) = (h / 2, w / 2);
        let mut g = Tensor::zeros(&shape);
        for bc in 0..b * c {
            let src = &grad_out.data[bc * oh * ow..(bc + 1) * oh * ow];
            let dst = &mut g.data[bc * h * w..(bc + 1) * h * w];
            for i in 0..oh {
                for j in 0..ow {
                    let v = 0.25 * src[i * ow + j];
                    dst[2 * i * w + 2 * j] = v;
                    dst[2 * i * w + 2 * j + 1] = v;
                    dst[(2 * i + 1) * w + 2 * j] = v;
                    dst[(2 * i + 1) * w + 2 * j + 1] = v;
                }
            }
        }
        g
    }

    fn name(&self) -> &'static str {
        "AvgPool2"
    }

    fn out_shape(&self, in_shape: &[usize]) -> Vec<usize> {
        vec![in_shape[0], in_shape[1], in_shape[2] / 2, in_shape[3] / 2]
    }
}

/// 2×2 max pooling (VGG-style).
pub struct MaxPool2 {
    cache: Option<(Vec<usize>, Vec<usize>)>, // input shape, argmax indices
}

impl MaxPool2 {
    pub fn new() -> Self {
        MaxPool2 { cache: None }
    }

    /// The pooled output plus (optionally) the argmax indices backward
    /// needs — one code path so train and eval stay bit-identical.
    fn pool(x: &Tensor, want_argmax: bool) -> (Tensor, Vec<usize>) {
        let (b, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
        assert!(h % 2 == 0 && w % 2 == 0, "MaxPool2 needs even dims");
        let (oh, ow) = (h / 2, w / 2);
        let mut out = Tensor::zeros(&[b, c, oh, ow]);
        let mut argmax = if want_argmax { vec![0usize; b * c * oh * ow] } else { Vec::new() };
        for bc in 0..b * c {
            let src = &x.data[bc * h * w..(bc + 1) * h * w];
            for i in 0..oh {
                for j in 0..ow {
                    let cand = [
                        2 * i * w + 2 * j,
                        2 * i * w + 2 * j + 1,
                        (2 * i + 1) * w + 2 * j,
                        (2 * i + 1) * w + 2 * j + 1,
                    ];
                    let (best, &val) = cand
                        .iter()
                        .map(|&k| (k, &src[k]))
                        .max_by(|a, b| a.1.total_cmp(b.1))
                        .unwrap();
                    out.data[bc * oh * ow + i * ow + j] = val;
                    if want_argmax {
                        argmax[bc * oh * ow + i * ow + j] = bc * h * w + best;
                    }
                }
            }
        }
        (out, argmax)
    }
}

impl Default for MaxPool2 {
    fn default() -> Self {
        Self::new()
    }
}

impl Layer for MaxPool2 {
    fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        let (out, argmax) = Self::pool(x, train);
        if train {
            self.cache = Some((x.shape.clone(), argmax));
        }
        out
    }

    fn forward_eval(&self, x: &Tensor) -> Tensor {
        Self::pool(x, false).0
    }

    fn backward(&mut self, grad_out: &Tensor) -> Tensor {
        let (shape, argmax) = self.cache.take().expect("forward before backward");
        let mut g = Tensor::zeros(&shape);
        for (o, &src_idx) in argmax.iter().enumerate() {
            g.data[src_idx] += grad_out.data[o];
        }
        g
    }

    fn name(&self) -> &'static str {
        "MaxPool2"
    }

    fn out_shape(&self, in_shape: &[usize]) -> Vec<usize> {
        vec![in_shape[0], in_shape[1], in_shape[2] / 2, in_shape[3] / 2]
    }
}

/// Global average pooling over spatial dims: (B, C, H, W) → (B, C).
pub struct GlobalAvgPool {
    cache_shape: Option<Vec<usize>>,
}

impl GlobalAvgPool {
    pub fn new() -> Self {
        GlobalAvgPool { cache_shape: None }
    }
}

impl Default for GlobalAvgPool {
    fn default() -> Self {
        Self::new()
    }
}

impl Layer for GlobalAvgPool {
    fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        if train {
            self.cache_shape = Some(x.shape.clone());
        }
        self.forward_eval(x)
    }

    fn forward_eval(&self, x: &Tensor) -> Tensor {
        let (b, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
        let mut out = Tensor::zeros(&[b, c]);
        for bc in 0..b * c {
            out.data[bc] =
                x.data[bc * h * w..(bc + 1) * h * w].iter().sum::<f64>() / (h * w) as f64;
        }
        out
    }

    fn backward(&mut self, grad_out: &Tensor) -> Tensor {
        let shape = self.cache_shape.take().expect("forward before backward");
        let (h, w) = (shape[2], shape[3]);
        let mut g = Tensor::zeros(&shape);
        let inv = 1.0 / (h * w) as f64;
        for (bc, &go) in grad_out.data.iter().enumerate() {
            for v in g.data[bc * h * w..(bc + 1) * h * w].iter_mut() {
                *v = go * inv;
            }
        }
        g
    }

    fn name(&self) -> &'static str {
        "GlobalAvgPool"
    }

    fn out_shape(&self, in_shape: &[usize]) -> Vec<usize> {
        vec![in_shape[0], in_shape[1]]
    }
}

/// Flatten (B, ...) → (B, prod).
pub struct Flatten {
    cache_shape: Option<Vec<usize>>,
}

impl Flatten {
    pub fn new() -> Self {
        Flatten { cache_shape: None }
    }
}

impl Default for Flatten {
    fn default() -> Self {
        Self::new()
    }
}

impl Layer for Flatten {
    fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        if train {
            self.cache_shape = Some(x.shape.clone());
        }
        self.forward_eval(x)
    }

    fn forward_eval(&self, x: &Tensor) -> Tensor {
        let b = x.shape[0];
        let d: usize = x.shape[1..].iter().product();
        Tensor::from_vec(&[b, d], x.data.clone())
    }

    fn backward(&mut self, grad_out: &Tensor) -> Tensor {
        let shape = self.cache_shape.take().expect("forward before backward");
        Tensor::from_vec(&shape, grad_out.data.clone())
    }

    fn name(&self) -> &'static str {
        "Flatten"
    }

    fn out_shape(&self, in_shape: &[usize]) -> Vec<usize> {
        vec![in_shape[0], in_shape[1..].iter().product()]
    }
}

/// Digital batch normalization over channels of (B, C, H, W) — IMC designs
/// keep normalization in the digital domain; required for ResNet/VGG
/// training stability.
pub struct BatchNorm2d {
    pub channels: usize,
    pub gamma: Param,
    pub beta: Param,
    pub running_mean: Vec<f64>,
    pub running_var: Vec<f64>,
    pub momentum: f64,
    pub eps: f64,
    cache: Option<(Tensor, Vec<f64>, Vec<f64>)>, // x_hat, mean, var
}

impl BatchNorm2d {
    pub fn new(channels: usize) -> Self {
        BatchNorm2d {
            channels,
            gamma: Param::new(vec![1.0; channels]),
            beta: Param::new(vec![0.0; channels]),
            running_mean: vec![0.0; channels],
            running_var: vec![1.0; channels],
            momentum: 0.1,
            eps: 1e-5,
            cache: None,
        }
    }

    /// Normalize with the given statistics; returns `(out, x_hat)` — the
    /// single code path shared by train-mode forward (batch stats) and
    /// eval (running stats), keeping both bit-identical per statistic set.
    fn normalize(&self, x: &Tensor, mean: &[f64], var: &[f64]) -> (Tensor, Tensor) {
        let (b, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
        let mut out = x.clone();
        let mut x_hat = Tensor::zeros(&x.shape);
        for bi in 0..b {
            for ci in 0..c {
                let base = (bi * c + ci) * h * w;
                let inv_std = 1.0 / (var[ci] + self.eps).sqrt();
                for k in 0..h * w {
                    let xh = (x.data[base + k] - mean[ci]) * inv_std;
                    x_hat.data[base + k] = xh;
                    out.data[base + k] = self.gamma.value[ci] * xh + self.beta.value[ci];
                }
            }
        }
        (out, x_hat)
    }
}

impl Layer for BatchNorm2d {
    fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        let (b, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
        assert_eq!(c, self.channels);
        if !train {
            return self.forward_eval(x);
        }
        let n = (b * h * w) as f64;
        let mut mean = vec![0.0; c];
        let mut var = vec![0.0; c];
        for bi in 0..b {
            for ci in 0..c {
                let base = (bi * c + ci) * h * w;
                for &v in &x.data[base..base + h * w] {
                    mean[ci] += v;
                }
            }
        }
        for m in mean.iter_mut() {
            *m /= n;
        }
        for bi in 0..b {
            for ci in 0..c {
                let base = (bi * c + ci) * h * w;
                for &v in &x.data[base..base + h * w] {
                    var[ci] += (v - mean[ci]) * (v - mean[ci]);
                }
            }
        }
        for v in var.iter_mut() {
            *v /= n;
        }
        for ci in 0..c {
            self.running_mean[ci] =
                (1.0 - self.momentum) * self.running_mean[ci] + self.momentum * mean[ci];
            self.running_var[ci] =
                (1.0 - self.momentum) * self.running_var[ci] + self.momentum * var[ci];
        }
        let (out, x_hat) = self.normalize(x, &mean, &var);
        self.cache = Some((x_hat, mean, var));
        out
    }

    fn forward_eval(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.shape[1], self.channels);
        self.normalize(x, &self.running_mean, &self.running_var).0
    }

    fn backward(&mut self, grad_out: &Tensor) -> Tensor {
        let (x_hat, _mean, var) = self.cache.take().expect("forward before backward");
        let (b, c, h, w) = (
            grad_out.shape[0],
            grad_out.shape[1],
            grad_out.shape[2],
            grad_out.shape[3],
        );
        let n = (b * h * w) as f64;
        let mut g = Tensor::zeros(&grad_out.shape);
        for ci in 0..c {
            let mut sum_gy = 0.0;
            let mut sum_gy_xh = 0.0;
            for bi in 0..b {
                let base = (bi * c + ci) * h * w;
                for k in 0..h * w {
                    sum_gy += grad_out.data[base + k];
                    sum_gy_xh += grad_out.data[base + k] * x_hat.data[base + k];
                }
            }
            self.beta.grad[ci] += sum_gy;
            self.gamma.grad[ci] += sum_gy_xh;
            let inv_std = 1.0 / (var[ci] + self.eps).sqrt();
            let gamma = self.gamma.value[ci];
            for bi in 0..b {
                let base = (bi * c + ci) * h * w;
                for k in 0..h * w {
                    let gy = grad_out.data[base + k];
                    let xh = x_hat.data[base + k];
                    g.data[base + k] =
                        gamma * inv_std * (gy - sum_gy / n - xh * sum_gy_xh / n);
                }
            }
        }
        g
    }

    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        f(&mut self.gamma);
        f(&mut self.beta);
    }

    fn for_each_param(&self, f: &mut dyn FnMut(&Param)) {
        f(&self.gamma);
        f(&self.beta);
    }

    fn visit_buffers(&mut self, f: &mut dyn FnMut(&mut Vec<f64>)) {
        f(&mut self.running_mean);
        f(&mut self.running_var);
    }

    fn for_each_buffer(&self, f: &mut dyn FnMut(&Vec<f64>)) {
        f(&self.running_mean);
        f(&self.running_var);
    }

    fn name(&self) -> &'static str {
        "BatchNorm2d"
    }

    fn out_shape(&self, in_shape: &[usize]) -> Vec<usize> {
        in_shape.to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpe::{DotProductEngine, SliceMethod, SliceSpec};

    fn num_grad(
        layer: &mut dyn Layer,
        x: &Tensor,
        loss: &dyn Fn(&Tensor) -> f64,
        idx: usize,
        eps: f64,
    ) -> f64 {
        let mut xp = x.clone();
        xp.data[idx] += eps;
        let mut xm = x.clone();
        xm.data[idx] -= eps;
        (loss(&layer.forward(&xp, false)) - loss(&layer.forward(&xm, false))) / (2.0 * eps)
    }

    /// Quadratic test loss: L = Σ y²/2, dL/dy = y.
    fn qloss(y: &Tensor) -> f64 {
        y.data.iter().map(|v| v * v).sum::<f64>() / 2.0
    }

    #[test]
    fn linear_gradcheck_digital() {
        let mut rng = Pcg64::seeded(5);
        let mut l = LinearMem::new(7, 4, None, &mut rng);
        let x = Tensor::from_vec(&[3, 7], (0..21).map(|i| (i as f64) / 10.0 - 1.0).collect());
        let y = l.forward(&x, true);
        let gx = l.backward(&y); // dL/dy = y for quadratic loss
        for idx in [0usize, 5, 13, 20] {
            let want = num_grad(&mut l, &x, &qloss, idx, 1e-5);
            assert!((gx.data[idx] - want).abs() < 1e-6, "idx {idx}: {} vs {want}", gx.data[idx]);
        }
    }

    #[test]
    fn linear_weight_gradcheck() {
        let mut rng = Pcg64::seeded(6);
        let mut l = LinearMem::new(5, 3, None, &mut rng);
        let x = Tensor::from_vec(&[2, 5], (0..10).map(|i| (i as f64) / 7.0 - 0.6).collect());
        let y = l.forward(&x, true);
        l.backward(&y);
        for idx in [0usize, 7, 14] {
            let orig = l.w.value[idx];
            let eps = 1e-5;
            l.w.value[idx] = orig + eps;
            let lp = qloss(&l.forward(&x, false));
            l.w.value[idx] = orig - eps;
            let lm = qloss(&l.forward(&x, false));
            l.w.value[idx] = orig;
            let want = (lp - lm) / (2.0 * eps);
            assert!((l.w.grad[idx] - want).abs() < 1e-5, "{} vs {want}", l.w.grad[idx]);
        }
    }

    #[test]
    fn conv_gradcheck_digital() {
        let mut rng = Pcg64::seeded(7);
        let mut l = Conv2dMem::new(2, 6, 6, 3, 3, 1, 1, None, &mut rng);
        let x = Tensor::from_vec(
            &[2, 2, 6, 6],
            (0..144).map(|i| ((i * 31 % 17) as f64) / 8.0 - 1.0).collect(),
        );
        let y = l.forward(&x, true);
        let gx = l.backward(&y);
        for idx in [0usize, 50, 99, 143] {
            let want = num_grad(&mut l, &x, &qloss, idx, 1e-5);
            assert!((gx.data[idx] - want).abs() < 1e-5, "idx {idx}: {} vs {want}", gx.data[idx]);
        }
    }

    #[test]
    fn conv_matches_linear_semantics_1x1() {
        // A 1×1 conv over 1×1 spatial dims is a linear layer.
        let mut rng = Pcg64::seeded(8);
        let mut conv = Conv2dMem::new(4, 1, 1, 3, 1, 1, 0, None, &mut rng);
        let mut lin = LinearMem::new(4, 3, None, &mut rng);
        lin.w.value = Matrix::from_vec(3, 4, conv.w.value.clone()).transpose().data;
        lin.b.value = conv.b.value.clone();
        let x = Tensor::from_vec(&[2, 4, 1, 1], (0..8).map(|i| i as f64 * 0.3).collect());
        let xf = Tensor::from_vec(&[2, 4], x.data.clone());
        let yc = conv.forward(&x, false);
        let yl = lin.forward(&xf, false);
        for (a, b) in yc.data.iter().zip(&yl.data) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn hw_linear_close_to_digital() {
        let mut rng = Pcg64::seeded(9);
        let hw = HwSpec::uniform(
            DotProductEngine::ideal((64, 64)),
            SliceMethod::int(SliceSpec::int8()),
        );
        let mut l_hw = LinearMem::new(32, 16, Some(hw), &mut rng);
        let mut l_dig = LinearMem::new(32, 16, None, &mut rng);
        l_dig.w.value = l_hw.w.value.clone();
        l_dig.b.value = l_hw.b.value.clone();
        let x = Tensor::from_vec(&[4, 32], (0..128).map(|i| ((i % 13) as f64) / 6.5 - 1.0).collect());
        let y_hw = l_hw.forward(&x, false).to_matrix();
        let y_dig = l_dig.forward(&x, false).to_matrix();
        let re = y_hw.relative_error(&y_dig);
        assert!(re < 0.02, "re={re}");
    }

    #[test]
    fn relu_and_pool_shapes() {
        let x = Tensor::from_vec(&[1, 2, 4, 4], (0..32).map(|i| i as f64 - 16.0).collect());
        let mut r = Relu::new();
        let y = r.forward(&x, true);
        assert!(y.data.iter().all(|&v| v >= 0.0));
        let g = r.backward(&Tensor::from_vec(&x.shape, vec![1.0; 32]));
        assert_eq!(g.data.iter().filter(|&&v| v > 0.0).count(), 15); // x > 0 count
        let mut p = AvgPool2::new();
        let y = p.forward(&x, true);
        assert_eq!(y.shape, vec![1, 2, 2, 2]);
        let g = p.backward(&Tensor::from_vec(&[1, 2, 2, 2], vec![4.0; 8]));
        assert!(g.data.iter().all(|&v| (v - 1.0).abs() < 1e-12));
    }

    #[test]
    fn avgpool_gradcheck() {
        let x = Tensor::from_vec(&[1, 1, 4, 4], (0..16).map(|i| i as f64 * 0.37 - 2.0).collect());
        let mut p = AvgPool2::new();
        let y = p.forward(&x, true);
        let gx = p.backward(&y);
        for idx in [0usize, 7, 15] {
            let want = num_grad(&mut p, &x, &qloss, idx, 1e-5);
            assert!((gx.data[idx] - want).abs() < 1e-8);
        }
    }

    #[test]
    fn batchnorm_normalizes_and_gradchecks() {
        let mut bn = BatchNorm2d::new(2);
        let x = Tensor::from_vec(
            &[4, 2, 2, 2],
            (0..32).map(|i| ((i * 7 % 23) as f64) - 11.0).collect(),
        );
        let y = bn.forward(&x, true);
        // Per-channel mean ≈ 0, var ≈ 1 after affine with γ=1, β=0.
        for c in 0..2 {
            let mut vals = vec![];
            for b in 0..4 {
                let base = (b * 2 + c) * 4;
                vals.extend_from_slice(&y.data[base..base + 4]);
            }
            let mean: f64 = vals.iter().sum::<f64>() / vals.len() as f64;
            let var: f64 = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / vals.len() as f64;
            assert!(mean.abs() < 1e-10);
            assert!((var - 1.0).abs() < 1e-6);
        }
        // Gradcheck input grads.
        let y = bn.forward(&x, true);
        let gx = bn.backward(&y);
        for idx in [0usize, 9, 31] {
            let mut xp = x.clone();
            xp.data[idx] += 1e-5;
            let mut xm = x.clone();
            xm.data[idx] -= 1e-5;
            let lp = qloss(&bn.forward(&xp, true));
            let lm = qloss(&bn.forward(&xm, true));
            bn.cache = None;
            let want = (lp - lm) / 2e-5;
            assert!((gx.data[idx] - want).abs() < 1e-4, "{} vs {want}", gx.data[idx]);
        }
    }

    #[test]
    fn global_pool_and_flatten() {
        let x = Tensor::from_vec(&[2, 3, 2, 2], (0..24).map(|i| i as f64).collect());
        let mut g = GlobalAvgPool::new();
        let y = g.forward(&x, true);
        assert_eq!(y.shape, vec![2, 3]);
        assert!((y.data[0] - 1.5).abs() < 1e-12);
        let gx = g.backward(&Tensor::from_vec(&[2, 3], vec![4.0; 6]));
        assert!(gx.data.iter().all(|&v| (v - 1.0).abs() < 1e-12));
        let mut f = Flatten::new();
        let y = f.forward(&x, true);
        assert_eq!(y.shape, vec![2, 12]);
        let back = f.backward(&y);
        assert_eq!(back.shape, x.shape);
    }

    #[test]
    fn linear_input_cache_bit_identical_across_reprogramming() {
        // Twin layers (same weights, same engine seed), one with the
        // cached-input eval path: outputs must match bit for bit, and the
        // cache must survive update_weight (slicing is weight-independent)
        // while still tracking a changed input.
        let mk = || {
            let mut rng = Pcg64::seeded(21);
            let hw = HwSpec::uniform(
                DotProductEngine::new(Default::default(), 7),
                SliceMethod::int(SliceSpec::int8()),
            );
            LinearMem::new(16, 8, Some(hw), &mut rng)
        };
        let mut plain = mk();
        let mut cached = mk();
        cached.set_input_caching(true);
        let x = Tensor::from_vec(&[3, 16], (0..48).map(|i| ((i % 7) as f64) / 3.5 - 1.0).collect());
        assert_eq!(cached.forward(&x, false).data, plain.forward(&x, false).data);
        // Repeat (cache hit) and after reprogramming.
        assert_eq!(cached.forward(&x, false).data, plain.forward(&x, false).data);
        plain.update_weight();
        cached.update_weight();
        assert_eq!(cached.forward(&x, false).data, plain.forward(&x, false).data);
        // A different input must invalidate the cache, not reuse it.
        let x2 = Tensor::from_vec(&[3, 16], (0..48).map(|i| ((i % 5) as f64) / 2.5 - 1.0).collect());
        assert_eq!(cached.forward(&x2, false).data, plain.forward(&x2, false).data);
    }

    #[test]
    fn conv_input_cache_bit_identical() {
        let mk = || {
            let mut rng = Pcg64::seeded(22);
            let hw = HwSpec::uniform(
                DotProductEngine::new(Default::default(), 8),
                SliceMethod::int(SliceSpec::int8()),
            );
            Conv2dMem::new(2, 6, 6, 3, 3, 1, 1, Some(hw), &mut rng)
        };
        let mut plain = mk();
        let mut cached = mk();
        cached.set_input_caching(true);
        let x = Tensor::from_vec(
            &[2, 2, 6, 6],
            (0..144).map(|i| ((i * 13 % 19) as f64) / 9.0 - 1.0).collect(),
        );
        assert_eq!(cached.forward(&x, false).data, plain.forward(&x, false).data);
        plain.update_weight();
        cached.update_weight();
        assert_eq!(cached.forward(&x, false).data, plain.forward(&x, false).data);
    }

    #[test]
    fn update_weight_reprograms_noise() {
        let mut rng = Pcg64::seeded(10);
        let hw = HwSpec::uniform(
            DotProductEngine::new(Default::default(), 3),
            SliceMethod::int(SliceSpec::int8()),
        );
        let mut l = LinearMem::new(16, 8, Some(hw), &mut rng);
        let x = Tensor::from_vec(&[2, 16], vec![0.5; 32]);
        let y1 = l.forward(&x, false);
        let y1b = l.forward(&x, false);
        assert_eq!(y1.data, y1b.data, "same programming → same output");
        l.update_weight();
        let y2 = l.forward(&x, false);
        assert_ne!(y1.data, y2.data, "reprogramming must resample noise");
    }

    #[test]
    fn prop_linear_conv_gradcheck_digital() {
        // Finite-difference gradient checks over random shapes: the
        // packed-kernel backward must produce the analytic gradients of
        // the digital forward for both hardware layer kinds.
        use crate::util::prop::prop_check;
        prop_check("linear/conv backward == finite differences", 12, |g| {
            let bsz = g.usize_in(1..=3);
            let inf = g.usize_in(2..=10);
            let outf = g.usize_in(1..=6);
            let mut lin = LinearMem::new(inf, outf, None, g.rng());
            let x = Tensor::from_vec(&[bsz, inf], g.vec_f64(bsz * inf, -1.0..1.0));
            let y = lin.forward(&x, true);
            let gx = lin.try_backward(&y).map_err(|e| e.to_string())?;
            for _ in 0..3 {
                let idx = g.usize_in(0..=bsz * inf - 1);
                let want = num_grad(&mut lin, &x, &qloss, idx, 1e-5);
                if (gx.data[idx] - want).abs() > 1e-5 {
                    return Err(format!("linear d={idx}: {} vs {want}", gx.data[idx]));
                }
            }
            let (c, hw_dim, oc) = (g.usize_in(1..=2), g.usize_in(4..=6), g.usize_in(1..=3));
            let mut conv = Conv2dMem::new(c, hw_dim, hw_dim, oc, 3, 1, 1, None, g.rng());
            let xc = Tensor::from_vec(
                &[bsz, c, hw_dim, hw_dim],
                g.vec_f64(bsz * c * hw_dim * hw_dim, -1.0..1.0),
            );
            let y = conv.forward(&xc, true);
            let gx = conv.try_backward(&y).map_err(|e| e.to_string())?;
            for _ in 0..3 {
                let idx = g.usize_in(0..=xc.data.len() - 1);
                let want = num_grad(&mut conv, &xc, &qloss, idx, 1e-5);
                if (gx.data[idx] - want).abs() > 1e-5 {
                    return Err(format!("conv d={idx}: {} vs {want}", gx.data[idx]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn hw_gradcheck_tolerance_scales_with_quantization_step() {
        // Straight-through estimator on a noise-free engine: backward
        // returns the full-precision gradient while the forward is
        // quantized, so finite differences of the hardware forward agree
        // only up to the measured quantization jitter — the tolerance is
        // derived from that step, not hard-coded.
        let mut rng = Pcg64::seeded(61);
        let hw = HwSpec::uniform(
            DotProductEngine::ideal((64, 64)),
            SliceMethod::int(SliceSpec::int8()),
        );
        let mut l = LinearMem::new(12, 6, Some(hw), &mut rng);
        let mut dig = LinearMem::new(12, 6, None, &mut rng);
        dig.w.value = l.w.value.clone();
        dig.b.value = l.b.value.clone();
        let x = Tensor::from_vec(&[2, 12], (0..24).map(|i| ((i * 5 % 13) as f64) / 6.5 - 1.0).collect());
        let y_hw = l.forward(&x, false);
        let y_dig = dig.forward(&x, false);
        let qerr = y_hw
            .data
            .iter()
            .zip(&y_dig.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        let ymax = y_hw.data.iter().fold(0.0, |m: f64, v| m.max(v.abs()));
        let y = l.forward(&x, true);
        let gx = l.backward(&y);
        let eps = 0.05;
        // d(quadratic loss) jitter ≤ Σ|y|·|Δy| ≤ len·ymax·qerr, felt at
        // 1/eps by the central difference.
        let tol = (y.data.len() as f64 * ymax * qerr) / eps + 1e-4;
        for idx in [0usize, 7, 23] {
            let want = num_grad(&mut l, &x, &qloss, idx, eps);
            assert!(
                (gx.data[idx] - want).abs() <= tol,
                "idx {idx}: {} vs {want} (tol {tol})",
                gx.data[idx]
            );
        }
    }

    #[test]
    fn linear_backward_matches_naive_dense_reference() {
        // The packed training kernel replaced naive `Matrix::matmul`
        // calls; on identical operands the gradients must be bit-equal.
        let mut rng = Pcg64::seeded(62);
        let mut l = LinearMem::new(9, 5, None, &mut rng);
        let x = Tensor::from_vec(&[4, 9], (0..36).map(|i| ((i * 7 % 11) as f64) / 5.5 - 1.0).collect());
        let _ = l.forward(&x, true);
        let g = Tensor::from_vec(&[4, 5], (0..20).map(|i| ((i * 3 % 7) as f64) / 3.5 - 1.0).collect());
        let xm = x.to_matrix();
        let gm = g.to_matrix();
        let want_gw = xm.transpose().matmul(&gm);
        let want_gx = gm.matmul(&l.weight_matrix().transpose());
        let gx = l.backward(&g);
        assert_eq!(gx.data, want_gx.data, "grad_x must match the dense reference bitwise");
        assert_eq!(l.w.grad, want_gw.data, "grad_w must match the dense reference bitwise");
    }

    #[test]
    fn backward_before_forward_is_typed_error() {
        let mut rng = Pcg64::seeded(40);
        let mut lin = LinearMem::new(6, 4, None, &mut rng);
        let g = Tensor::from_vec(&[2, 4], vec![0.1; 8]);
        assert_eq!(
            lin.try_backward(&g).err(),
            Some(TrainError::BackwardBeforeForward { layer: "LinearMem" })
        );
        // Double-backward: the cache is consumed by the first backward.
        let x = Tensor::from_vec(&[2, 6], vec![0.3; 12]);
        lin.forward(&x, true);
        assert!(lin.try_backward(&g).is_ok());
        assert_eq!(
            lin.try_backward(&g).err(),
            Some(TrainError::BackwardBeforeForward { layer: "LinearMem" })
        );
        let mut conv = Conv2dMem::new(1, 4, 4, 2, 3, 1, 1, None, &mut rng);
        let gc = Tensor::from_vec(&[1, 2, 4, 4], vec![0.2; 32]);
        assert_eq!(
            conv.try_backward(&gc).err(),
            Some(TrainError::BackwardBeforeForward { layer: "Conv2dMem" })
        );
        let xc = Tensor::from_vec(&[1, 1, 4, 4], vec![0.4; 16]);
        conv.forward(&xc, true);
        assert!(conv.try_backward(&gc).is_ok());
        assert_eq!(
            conv.try_backward(&gc).err(),
            Some(TrainError::BackwardBeforeForward { layer: "Conv2dMem" })
        );
    }

    #[test]
    fn delta_reprogram_touches_only_dirty_blocks() {
        // Two hardware layers; change one weight in the first only. The
        // delta path must redraw cells only in the first layer's affected
        // block, and report every block of the untouched layer clean.
        let mk = |stream: u64| {
            let mut rng = Pcg64::seeded(50 + stream);
            let hw = HwSpec::uniform(
                DotProductEngine::new(Default::default(), 17 + stream),
                SliceMethod::int(SliceSpec::int8()),
            );
            LinearMem::new(80, 40, Some(hw), &mut rng)
        };
        let mut l0 = mk(0);
        let mut l1 = mk(1);
        // First delta call after construction falls back to a full
        // program (no template cached yet) and seeds the template.
        let r0 = l0.update_weight_delta();
        let r1 = l1.update_weight_delta();
        assert_eq!(r0.full_reprograms, 1);
        assert_eq!(r1.full_reprograms, 1);
        // No weight change → every block clean, zero cells redrawn.
        let r = l0.update_weight_delta();
        assert_eq!(r.full_reprograms, 0);
        assert_eq!(r.blocks_clean, r.blocks);
        assert_eq!(r.cells_redrawn, 0);
        // Bump one weight enough to move its quantized digit.
        l0.w.value[3] += 0.2;
        let r0 = l0.update_weight_delta();
        let r1 = l1.update_weight_delta();
        assert_eq!(r0.full_reprograms, 0);
        assert!(r0.dirty_blocks() >= 1, "changed layer must redraw");
        assert!(
            r0.dirty_blocks() < r0.blocks,
            "a one-element change must not dirty every block"
        );
        assert_eq!(r1.blocks_clean, r1.blocks, "untouched layer stays clean");
        assert_eq!(r1.cells_redrawn, 0);
        // Cumulative per-core counters add up across calls.
        let stats = l0.core.program_stats();
        assert_eq!(stats.full_reprograms, 2); // construction + first delta
    }

    #[test]
    fn delta_preserves_clean_cell_noise() {
        // The perf claim in miniature: a delta step over unchanged weights
        // redraws nothing, so the noisy output is bit-identical — while a
        // full update_weight resamples every cell and shifts it.
        let mut rng = Pcg64::seeded(51);
        let hw = HwSpec::uniform(
            DotProductEngine::new(Default::default(), 23),
            SliceMethod::int(SliceSpec::int8()),
        );
        let mut l = LinearMem::new(24, 12, Some(hw), &mut rng);
        l.update_weight_delta(); // seed the template (full fallback)
        let x = Tensor::from_vec(&[2, 24], (0..48).map(|i| ((i % 9) as f64) / 4.5 - 1.0).collect());
        let y0 = l.forward(&x, false);
        l.update_weight_delta();
        let y1 = l.forward(&x, false);
        assert_eq!(y0.data, y1.data, "clean delta must keep programmed noise");
        l.update_weight();
        let y2 = l.forward(&x, false);
        assert_ne!(y0.data, y2.data, "full reprogram must resample noise");
    }

    #[test]
    fn delta_bit_identical_to_full_reprogram_noise_free() {
        // On a noise-free engine the redrawn cells carry no randomness, so
        // the delta path must land on exactly the bits a full reprogram
        // writes — for linear and conv layers alike.
        let mut rng = Pcg64::seeded(52);
        let hw = HwSpec::uniform(
            DotProductEngine::ideal((64, 64)),
            SliceMethod::int(SliceSpec::int8()),
        );
        let mut a = LinearMem::new(20, 10, Some(hw.clone()), &mut rng);
        let mut rng2 = Pcg64::seeded(52);
        let mut b = LinearMem::new(20, 10, Some(hw.clone()), &mut rng2);
        a.update_weight_delta();
        for step in 0..3 {
            for (i, (wa, wb)) in a.w.value.iter_mut().zip(b.w.value.iter_mut()).enumerate() {
                let d = 0.01 * ((i + step) % 5) as f64 - 0.02;
                *wa += d;
                *wb += d;
            }
            a.update_weight_delta();
            b.update_weight();
            let x =
                Tensor::from_vec(&[3, 20], (0..60).map(|i| ((i % 7) as f64) / 3.5 - 1.0).collect());
            assert_eq!(a.forward(&x, false).data, b.forward(&x, false).data, "step {step}");
        }
        let mut rng = Pcg64::seeded(53);
        let mut ca = Conv2dMem::new(2, 6, 6, 3, 3, 1, 1, Some(hw.clone()), &mut rng);
        let mut rng2 = Pcg64::seeded(53);
        let mut cb = Conv2dMem::new(2, 6, 6, 3, 3, 1, 1, Some(hw), &mut rng2);
        ca.update_weight_delta();
        for (i, (wa, wb)) in ca.w.value.iter_mut().zip(cb.w.value.iter_mut()).enumerate() {
            let d = 0.015 * ((i % 3) as f64 - 1.0);
            *wa += d;
            *wb += d;
        }
        ca.update_weight_delta();
        cb.update_weight();
        let xc = Tensor::from_vec(
            &[2, 2, 6, 6],
            (0..144).map(|i| ((i * 11 % 19) as f64) / 9.5 - 1.0).collect(),
        );
        assert_eq!(ca.forward(&xc, false).data, cb.forward(&xc, false).data);
    }

    #[test]
    fn forward_eval_bit_identical_to_forward() {
        // The executor contract: forward_eval == forward(x, false), for
        // both hardware layer kinds and the digital fallbacks.
        let mut rng = Pcg64::seeded(33);
        let hw = HwSpec::uniform(
            DotProductEngine::new(Default::default(), 12),
            SliceMethod::int(SliceSpec::int8()),
        );
        let mut lin = LinearMem::new(20, 6, Some(hw.clone()), &mut rng);
        let x = Tensor::from_vec(&[5, 20], (0..100).map(|i| ((i % 11) as f64) / 5.5 - 1.0).collect());
        assert_eq!(lin.forward_eval(&x).data, lin.forward(&x, false).data);
        // Micro-batched path: batch-global slicing keeps it bit-identical.
        for mb in [1usize, 2, 5, 100] {
            assert_eq!(lin.forward_batched(&x, mb).data, lin.forward(&x, false).data, "mb={mb}");
        }
        let mut conv = Conv2dMem::new(2, 6, 6, 3, 3, 1, 1, Some(hw), &mut rng);
        let xc = Tensor::from_vec(
            &[3, 2, 6, 6],
            (0..216).map(|i| ((i * 7 % 23) as f64) / 11.5 - 1.0).collect(),
        );
        assert_eq!(conv.forward_eval(&xc).data, conv.forward(&xc, false).data);
        for mb in [1usize, 2, 3] {
            assert_eq!(conv.forward_batched(&xc, mb).data, conv.forward(&xc, false).data, "mb={mb}");
        }
        let mut bn = BatchNorm2d::new(2);
        // Push some training stats into the running buffers first.
        let _ = bn.forward(&xc, true);
        assert_eq!(bn.forward_eval(&xc).data, bn.forward(&xc, false).data);
        let mut mp = MaxPool2::new();
        assert_eq!(mp.forward_eval(&xc).data, mp.forward(&xc, false).data);
    }
}
